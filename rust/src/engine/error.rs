//! Typed execution errors for the engine layer.
//!
//! Every failure a kernel or the registry can produce is one of three
//! shapes: the requested kernel does not exist, the operands do not
//! compose, or the backend itself failed. Callers (the coordinator, the
//! CLI, eval drivers) match on the variant instead of scraping strings;
//! the coordinator lifts these into `coordinator::JobError` via `From`.

use std::fmt;

use crate::formats::error::FormatError;
use crate::formats::traits::FormatKind;

use super::kernel::Algorithm;

/// What went wrong while resolving or running a kernel.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// No kernel is registered under the requested key. `None`/`None`
    /// means the registry itself is empty (auto-selection has nothing to
    /// choose from).
    KernelUnavailable {
        format: Option<FormatKind>,
        algorithm: Option<Algorithm>,
    },
    /// Inner dimensions do not agree: `A` is `a`, `B` is `b`.
    ShapeMismatch {
        a: (usize, usize),
        b: (usize, usize),
    },
    /// An operand could not be ingested or converted — the formats layer's
    /// typed failure, lifted losslessly (bad InCRS geometry, counter
    /// overflow, unknown format name).
    Format(FormatError),
    /// The kernel's prepare or execute step failed (backend error,
    /// operand prepared for a different kernel).
    ExecFailed(String),
}

impl fmt::Display for EngineError {
    fn fmt(&self, w: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::KernelUnavailable {
                format: Some(f),
                algorithm: Some(alg),
            } => write!(w, "no kernel registered for {}/{}", f.name(), alg.name()),
            EngineError::KernelUnavailable { .. } => write!(w, "empty kernel registry"),
            EngineError::ShapeMismatch { a, b } => {
                write!(w, "dimension mismatch: A is {a:?}, B is {b:?}")
            }
            EngineError::Format(e) => write!(w, "format error: {e}"),
            EngineError::ExecFailed(msg) => write!(w, "execution failed: {msg}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// Formats-layer failures lift losslessly into the engine's error surface.
impl From<FormatError> for EngineError {
    fn from(e: FormatError) -> EngineError {
        EngineError::Format(e)
    }
}

/// Legacy bridge for `Result<_, String>` call sites (CLI, scripts) so `?`
/// keeps working while they migrate to matching on the variants.
impl From<EngineError> for String {
    fn from(e: EngineError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_keeps_the_established_phrasing() {
        let miss = EngineError::KernelUnavailable {
            format: Some(FormatKind::Jad),
            algorithm: Some(Algorithm::Inner),
        };
        assert!(miss.to_string().contains("no kernel registered"));
        let empty = EngineError::KernelUnavailable {
            format: None,
            algorithm: None,
        };
        assert_eq!(empty.to_string(), "empty kernel registry");
        let dims = EngineError::ShapeMismatch { a: (4, 5), b: (7, 4) };
        assert!(dims.to_string().contains("dimension mismatch"));
        let exec = EngineError::ExecFailed("backend died".into());
        assert!(exec.to_string().contains("backend died"));
    }

    #[test]
    fn implements_std_error_and_string_bridge() {
        let e: Box<dyn std::error::Error> =
            Box::new(EngineError::ExecFailed("x".into()));
        assert!(!e.to_string().is_empty());
        let s: String = EngineError::ShapeMismatch { a: (1, 2), b: (3, 4) }.into();
        assert!(s.contains("dimension mismatch"));
    }

    #[test]
    fn format_errors_lift_losslessly() {
        let fe = FormatError::UnknownFormat("nope".into());
        let e = EngineError::from(fe.clone());
        assert_eq!(e, EngineError::Format(fe));
        assert!(e.to_string().contains("unknown format"));
    }
}
