//! Cross-job `PreparedB` reuse: content fingerprinting for `Arc<Csr>`
//! operands plus a bounded LRU cache of prepared representations.
//!
//! The paper's core economics is amortizing the one-time cost of a sparse
//! representation (the InCRS counter-vector build) across many multiplies
//! that share the operand. The coordinator's coalescing dispatcher keys
//! jobs by the *content* of `B` — not the `Arc` pointer — so two clients
//! submitting bit-identical matrices still share one `SpmmKernel::prepare`.
//!
//! Collision safety: the fingerprint is a fast 64-bit FNV-1a digest, so the
//! cache never trusts it alone. Every hit re-verifies the stored source
//! against the requested operand (`Arc` pointer fast path, full bitwise
//! content comparison otherwise); a colliding key with different content is
//! a miss and builds its own entry, keeping results bit-identical to the
//! uncached path by construction.

use std::sync::Arc;

use crate::formats::csr::Csr;
use crate::formats::error::FormatError;
use crate::formats::operand::MatrixOperand;
use crate::formats::traits::{FormatKind, SparseMatrix};

use super::kernel::{Algorithm, PreparedB};

/// 64-bit FNV-1a content digest of a CSR matrix: shape, structure, and
/// value bits. Stable across `Arc` identities and clones.
pub fn fingerprint_csr(m: &Csr) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut mix = |word: u64| {
        for byte in word.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    mix(m.rows() as u64);
    mix(m.cols() as u64);
    for &p in &m.row_ptr {
        mix(p as u64);
    }
    for &c in &m.col_idx {
        mix(c as u64);
    }
    for &v in &m.vals {
        mix(v.to_bits() as u64);
    }
    h
}

/// Bitwise content equality (shape, structure, and value bits). Used to
/// confirm cache hits so fingerprint collisions can never alias two
/// different operands.
pub fn same_content(x: &Csr, y: &Csr) -> bool {
    x.shape() == y.shape()
        && x.row_ptr == y.row_ptr
        && x.col_idx == y.col_idx
        && x.vals.len() == y.vals.len()
        && x.vals
            .iter()
            .zip(&y.vals)
            .all(|(p, q)| p.to_bits() == q.to_bits())
}

/// Bounded pointer-keyed memo of content fingerprints. Holding an `Arc`
/// clone per entry pins the allocation, so a pointer can never be recycled
/// by a different matrix while memoized — `Arc::ptr_eq` hits are always
/// content-correct, and steady-state traffic re-submitting the same
/// `Arc<Csr>` pays the O(nnz) hash once instead of once per micro-batch.
pub struct FingerprintMemo {
    cap: usize,
    entries: Vec<(Arc<Csr>, u64)>,
}

impl FingerprintMemo {
    pub fn new(cap: usize) -> FingerprintMemo {
        FingerprintMemo { cap, entries: Vec::new() }
    }

    /// The content fingerprint of `b`, memoized by `Arc` identity.
    pub fn get(&mut self, b: &Arc<Csr>) -> u64 {
        if let Some((_, f)) = self.entries.iter().find(|(src, _)| Arc::ptr_eq(src, b)) {
            return *f;
        }
        let f = fingerprint_csr(b);
        if self.cap > 0 {
            if self.entries.len() >= self.cap {
                self.entries.remove(0); // oldest first — insertion order
            }
            self.entries.push((Arc::clone(b), f));
        }
        f
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Bounded identity-keyed memo of operand→CSR conversions: the ingestion
/// twin of [`FingerprintMemo`]. A non-CSR [`MatrixOperand`] submitted
/// repeatedly (steady-state serving traffic reusing one `Arc`) pays its
/// canonical-CSR conversion once per worker instead of once per job; CSR
/// operands bypass the memo entirely (their `to_csr` is an `Arc` share).
/// Entries hold an operand clone, pinning the source allocation so an
/// identity hit can never alias a recycled pointer.
pub struct CsrMemo {
    cap: usize,
    entries: Vec<(MatrixOperand, Arc<Csr>)>,
    conversions: u64,
}

impl CsrMemo {
    pub fn new(cap: usize) -> CsrMemo {
        CsrMemo { cap, entries: Vec::new(), conversions: 0 }
    }

    /// The operand's canonical CSR, memoized by source identity.
    pub fn get(&mut self, op: &MatrixOperand) -> Result<Arc<Csr>, FormatError> {
        if let MatrixOperand::Csr(m) = op {
            return Ok(Arc::clone(m));
        }
        if let Some(pos) = self.entries.iter().position(|(src, _)| src.same_source(op)) {
            // refresh recency: a hot shared operand (B reused across jobs)
            // must survive a stream of cold one-shot operands (per-job As)
            let entry = self.entries.remove(pos);
            let csr = Arc::clone(&entry.1);
            self.entries.push(entry);
            return Ok(csr);
        }
        let csr = op.to_csr()?;
        self.conversions += 1;
        if self.cap > 0 {
            if self.entries.len() >= self.cap {
                self.entries.remove(0); // least recently used is in front
            }
            self.entries.push((op.clone(), Arc::clone(&csr)));
        }
        Ok(csr)
    }

    /// Conversions actually performed (memo misses on non-CSR operands).
    pub fn conversions(&self) -> u64 {
        self.conversions
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Cache key: the operand's content fingerprint plus the identity of the
/// kernel that prepared it (different kernels build different
/// representations of the same `B`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct PreparedKey {
    pub fingerprint: u64,
    pub format: FormatKind,
    pub algorithm: Algorithm,
}

struct Entry {
    key: PreparedKey,
    /// The operand the entry was built from, kept to verify hits under
    /// fingerprint collisions (an `Arc` clone — no matrix copy).
    src: Arc<Csr>,
    prepared: PreparedB,
    last_used: u64,
}

/// Bounded LRU cache of `PreparedB` values, surviving across micro-batches.
/// Owned per server worker (never shared across threads — the same rule
/// that keeps PJRT clients worker-local).
pub struct PreparedCache {
    cap: usize,
    tick: u64,
    entries: Vec<Entry>,
    hits: u64,
    builds: u64,
}

impl PreparedCache {
    /// A cache holding at most `cap` entries; `cap == 0` disables caching
    /// (every lookup builds, the uncoalesced behavior).
    pub fn new(cap: usize) -> PreparedCache {
        PreparedCache {
            cap,
            tick: 0,
            entries: Vec::new(),
            hits: 0,
            builds: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Lookups answered from the cache so far.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Times `build` actually ran (cache misses + collision rebuilds).
    pub fn builds(&self) -> u64 {
        self.builds
    }

    /// Return the cached `PreparedB` for (`key`, `b`) or build, store, and
    /// return it. A hit requires both the key *and* the stored source
    /// matching `b` (pointer or bitwise content), so a fingerprint
    /// collision degrades to a build — never to a wrong operand.
    pub fn get_or_build<E>(
        &mut self,
        key: PreparedKey,
        b: &Arc<Csr>,
        build: impl FnOnce(&Arc<Csr>) -> Result<PreparedB, E>,
    ) -> Result<PreparedB, E> {
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.entries.iter_mut().find(|e| {
            e.key == key && (Arc::ptr_eq(&e.src, b) || same_content(&e.src, b))
        }) {
            e.last_used = tick;
            self.hits += 1;
            return Ok(e.prepared.clone());
        }
        let prepared = build(b)?;
        self.builds += 1;
        if self.cap > 0 {
            if self.entries.len() >= self.cap {
                if let Some((idx, _)) = self
                    .entries
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, e)| e.last_used)
                {
                    self.entries.swap_remove(idx);
                }
            }
            self.entries.push(Entry {
                key,
                src: Arc::clone(b),
                prepared: prepared.clone(),
                last_used: tick,
            });
        }
        Ok(prepared)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::engine::error::EngineError;

    fn key(fp: u64) -> PreparedKey {
        PreparedKey {
            fingerprint: fp,
            format: FormatKind::Csr,
            algorithm: Algorithm::Gustavson,
        }
    }

    fn passthrough(b: &Arc<Csr>) -> Result<PreparedB, EngineError> {
        Ok(PreparedB::Csr(Arc::clone(b)))
    }

    #[test]
    fn fingerprint_is_content_stable_and_discriminating() {
        let m = uniform(20, 30, 0.2, 1);
        let clone = m.clone();
        assert_eq!(fingerprint_csr(&m), fingerprint_csr(&clone));
        let other = uniform(20, 30, 0.2, 2);
        assert_ne!(fingerprint_csr(&m), fingerprint_csr(&other));
        assert!(same_content(&m, &clone));
        assert!(!same_content(&m, &other));
    }

    #[test]
    fn shared_content_hits_once_built() {
        let b1 = Arc::new(uniform(16, 16, 0.3, 7));
        let b2 = Arc::new(b1.as_ref().clone()); // same bits, different Arc
        let fp = fingerprint_csr(&b1);
        let mut cache = PreparedCache::new(4);
        cache.get_or_build(key(fp), &b1, passthrough).unwrap();
        cache.get_or_build(key(fp), &b2, passthrough).unwrap();
        assert_eq!(cache.builds(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn fingerprint_collision_is_a_miss_not_an_alias() {
        // force a "collision": two different matrices filed under one key
        let b1 = Arc::new(uniform(12, 12, 0.4, 1));
        let b2 = Arc::new(uniform(12, 12, 0.4, 2));
        let forced = key(0xDEAD_BEEF);
        let mut cache = PreparedCache::new(4);
        let p1 = cache.get_or_build(forced, &b1, passthrough).unwrap();
        let p2 = cache.get_or_build(forced, &b2, passthrough).unwrap();
        assert_eq!(cache.builds(), 2, "collision must rebuild");
        // each caller got a representation of ITS OWN operand — identical
        // bits to the uncached path
        match (&p1, &p2) {
            (PreparedB::Csr(x), PreparedB::Csr(y)) => {
                assert!(Arc::ptr_eq(x, &b1));
                assert!(Arc::ptr_eq(y, &b2));
            }
            other => panic!("unexpected prepared pair {other:?}"),
        }
        // both colliding entries are independently retrievable afterwards
        cache.get_or_build(forced, &b1, passthrough).unwrap();
        cache.get_or_build(forced, &b2, passthrough).unwrap();
        assert_eq!(cache.builds(), 2);
        assert_eq!(cache.hits(), 2);
    }

    #[test]
    fn lru_eviction_respects_capacity() {
        let mats: Vec<Arc<Csr>> =
            (0..5).map(|s| Arc::new(uniform(8, 8, 0.5, s))).collect();
        let mut cache = PreparedCache::new(2);
        for m in &mats {
            cache.get_or_build(key(fingerprint_csr(m)), m, passthrough).unwrap();
        }
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.builds(), 5);
        // most recently inserted entry is still resident
        let last = mats.last().unwrap();
        cache
            .get_or_build(key(fingerprint_csr(last)), last, passthrough)
            .unwrap();
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn lru_evicts_least_recently_used_not_insertion_order() {
        let mats: Vec<Arc<Csr>> =
            (0..3).map(|s| Arc::new(uniform(8, 8, 0.5, s + 10))).collect();
        let (m0, m1, m2) = (&mats[0], &mats[1], &mats[2]);
        let (k0, k1, k2) = (
            key(fingerprint_csr(m0)),
            key(fingerprint_csr(m1)),
            key(fingerprint_csr(m2)),
        );
        let mut cache = PreparedCache::new(2);
        cache.get_or_build(k0, m0, passthrough).unwrap(); // tick 1
        cache.get_or_build(k1, m1, passthrough).unwrap(); // tick 2
        // touch the OLDER entry: m0 becomes most recently used
        cache.get_or_build(k0, m0, passthrough).unwrap(); // tick 3, hit
        assert_eq!(cache.hits(), 1);
        // capacity forces an eviction: m1 (LRU) must go, not m0 (oldest
        // by insertion)
        cache.get_or_build(k2, m2, passthrough).unwrap(); // tick 4
        assert_eq!(cache.len(), 2);
        let hits_before = cache.hits();
        cache.get_or_build(k0, m0, passthrough).unwrap();
        assert_eq!(cache.hits(), hits_before + 1, "recently-used entry was evicted");
        let builds_before = cache.builds();
        cache.get_or_build(k1, m1, passthrough).unwrap();
        assert_eq!(cache.builds(), builds_before + 1, "LRU entry survived eviction");
    }

    #[test]
    fn collision_fallback_prefers_the_matching_source() {
        // two entries under one forced key: lookups must resolve to the
        // entry whose source matches, in either order
        let b1 = Arc::new(uniform(10, 10, 0.4, 21));
        let b2 = Arc::new(uniform(10, 10, 0.4, 22));
        let forced = key(0xC0FF_EE00);
        let mut cache = PreparedCache::new(4);
        cache.get_or_build(forced, &b1, passthrough).unwrap();
        cache.get_or_build(forced, &b2, passthrough).unwrap();
        for (src, want) in [(&b2, &b2), (&b1, &b1), (&b2, &b2)] {
            match cache.get_or_build(forced, src, passthrough).unwrap() {
                PreparedB::Csr(got) => assert!(Arc::ptr_eq(&got, want)),
                other => panic!("unexpected prepared operand {other:?}"),
            }
        }
        assert_eq!(cache.builds(), 2);
        assert_eq!(cache.hits(), 3);
        // a content clone under a third Arc still hits via bitwise compare
        let b1_clone = Arc::new(b1.as_ref().clone());
        match cache.get_or_build(forced, &b1_clone, passthrough).unwrap() {
            PreparedB::Csr(got) => assert!(Arc::ptr_eq(&got, &b1)),
            other => panic!("unexpected prepared operand {other:?}"),
        }
        assert_eq!(cache.builds(), 2, "content-equal operand rebuilt");
    }

    #[test]
    fn fingerprint_memo_reuses_across_arc_clones() {
        let src = Arc::new(uniform(16, 16, 0.3, 30));
        let fp = fingerprint_csr(&src);
        let mut memo = FingerprintMemo::new(4);
        assert_eq!(memo.get(&src), fp);
        assert_eq!(memo.len(), 1);
        // Arc clones share the allocation: pointer hit, no new entry
        for _ in 0..3 {
            let clone = Arc::clone(&src);
            assert_eq!(memo.get(&clone), fp);
        }
        assert_eq!(memo.len(), 1, "Arc clones must not grow the memo");
        // a content clone under a different allocation is a fresh entry
        // with the same (content-stable) fingerprint
        let content_clone = Arc::new(src.as_ref().clone());
        assert_eq!(memo.get(&content_clone), fp);
        assert_eq!(memo.len(), 2);
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let b = Arc::new(uniform(8, 8, 0.5, 3));
        let fp = fingerprint_csr(&b);
        let mut cache = PreparedCache::new(0);
        cache.get_or_build(key(fp), &b, passthrough).unwrap();
        cache.get_or_build(key(fp), &b, passthrough).unwrap();
        assert_eq!(cache.builds(), 2);
        assert_eq!(cache.hits(), 0);
        assert!(cache.is_empty());
    }

    #[test]
    fn fingerprint_memo_pins_arcs_and_bounds_itself() {
        let mats: Vec<Arc<Csr>> =
            (0..4).map(|s| Arc::new(uniform(8, 8, 0.5, s))).collect();
        let mut memo = FingerprintMemo::new(2);
        for m in &mats {
            assert_eq!(memo.get(m), fingerprint_csr(m));
        }
        assert_eq!(memo.len(), 2);
        // memoized answer matches a fresh hash (ptr hit, same value)
        let last = mats.last().unwrap();
        assert_eq!(memo.get(last), fingerprint_csr(last));
        // entries hold strong Arcs: the memoized matrix has >1 refcount
        assert!(Arc::strong_count(last) > 1);
    }

    #[test]
    fn csr_memo_shares_csr_and_memoizes_conversions() {
        let csr = Arc::new(uniform(12, 12, 0.4, 1));
        let mut memo = CsrMemo::new(4);
        // CSR passthrough: Arc share, no entry, no conversion
        let got = memo.get(&MatrixOperand::from(Arc::clone(&csr))).unwrap();
        assert!(Arc::ptr_eq(&got, &csr));
        assert_eq!(memo.conversions(), 0);
        assert!(memo.is_empty());
        // a non-CSR operand converts once per source identity
        let coo_op = MatrixOperand::from(Arc::new(csr.to_coo()));
        let c1 = memo.get(&coo_op).unwrap();
        let c2 = memo.get(&coo_op.clone()).unwrap();
        assert!(Arc::ptr_eq(&c1, &c2), "identity hit must share the conversion");
        assert_eq!(memo.conversions(), 1);
        assert_eq!(memo.len(), 1);
        assert!(same_content(&c1, &csr), "conversion changed content");
        // a different allocation of the same content converts again
        let other = MatrixOperand::from(Arc::new(csr.to_coo()));
        memo.get(&other).unwrap();
        assert_eq!(memo.conversions(), 2);
    }

    #[test]
    fn csr_memo_bounds_itself_and_hits_refresh_recency() {
        let mut memo = CsrMemo::new(2);
        let ops: Vec<MatrixOperand> = (0..4)
            .map(|s| MatrixOperand::from(Arc::new(uniform(8, 8, 0.5, s).to_coo())))
            .collect();
        for op in &ops {
            memo.get(op).unwrap();
        }
        assert_eq!(memo.len(), 2);
        assert_eq!(memo.conversions(), 4);
        // most recent entry is still memoized
        let before = memo.conversions();
        memo.get(ops.last().unwrap()).unwrap();
        assert_eq!(memo.conversions(), before);
        // hot shared operand survives a stream of cold one-shot operands:
        // touching ops[2] makes ops[3] the LRU, so inserting a new entry
        // must evict ops[3], not ops[2]
        memo.get(&ops[2]).unwrap();
        let cold = MatrixOperand::from(Arc::new(uniform(8, 8, 0.5, 99).to_coo()));
        memo.get(&cold).unwrap();
        let before = memo.conversions();
        memo.get(&ops[2]).unwrap();
        assert_eq!(memo.conversions(), before, "recently-used entry was evicted");
        memo.get(&ops[3]).unwrap();
        assert_eq!(memo.conversions(), before + 1, "LRU entry survived eviction");
    }

    #[test]
    fn build_errors_pass_through_and_store_nothing() {
        let b = Arc::new(uniform(8, 8, 0.5, 4));
        let mut cache = PreparedCache::new(2);
        let err = cache
            .get_or_build(key(1), &b, |_| {
                Err::<PreparedB, _>(EngineError::ExecFailed("nope".into()))
            })
            .unwrap_err();
        assert!(matches!(err, EngineError::ExecFailed(_)));
        assert!(cache.is_empty());
        assert_eq!(cache.builds(), 0);
    }
}
