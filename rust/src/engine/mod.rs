//! Unified SpMM execution engine: one kernel contract, one registry, one
//! parallel executor — the dispatch layer every consumer (coordinator, CLI,
//! eval drivers, benches) routes through.
//!
//! # Why this layer exists
//!
//! The paper's speedups come from pairing the right *representation* (InCRS
//! instead of CRS) with the right *compute organization* (the comparator
//! mesh instead of FPIC/conventional MM). Those are two independent axes,
//! and a serving system needs to pick per job: Gustavson for row-order
//! traffic, inner-product over InCRS when column access dominates, the
//! blocked accelerator path when the MXU is available. This module makes
//! the axes explicit:
//!
//! * [`Algorithm`] — the compute organization (dense oracle, Gustavson —
//!   scalar and the vectorized workspace-pooled fast variant —
//!   inner-product, outer-product multiway merge for hyper-sparse inputs,
//!   tiled, accelerator block plan);
//! * [`kernel::SpmmKernel`] — the execution contract: `cost_hint` (choose
//!   without running), `prepare` (build B's representation once, cacheable),
//!   `execute` (the multiply);
//! * [`Registry`] — `(FormatKind, Algorithm)` → kernel resolution plus
//!   cost-hint-based selection ([`Registry::select`]); the typed variants
//!   ([`Registry::resolve_or_err`] / [`Registry::select_or_err`]) return
//!   [`EngineError`] for serving-path callers;
//! * [`learn`] — the learned-selection loop: least-squares calibration of
//!   each kernel's cost constants from serving observations
//!   ([`learn::FittedModel`]), fed back into selection live through a
//!   [`learn::CostModel`] handle with hysteresis, persisted to a
//!   versioned plain-text model file;
//! * [`EngineError`] — the typed failure surface (kernel unavailable,
//!   shape mismatch, backend failure) every kernel and registry path
//!   reports; the coordinator lifts it into `JobError`;
//! * [`prepared`] — content fingerprinting for `Arc<Csr>` operands and a
//!   bounded LRU [`PreparedCache`] so jobs sharing `B` reuse one
//!   `prepare` (the coordinator's micro-batch coalescing rides on this);
//! * [`tiled`] — a multi-threaded tile-pair executor (std threads over
//!   `blocks::BlockGrid` intersections, per-worker scratch, deterministic
//!   K-ordered reduction → bit-identical results at any worker count);
//! * [`shard`] — contiguous row-band sharding of one job across
//!   channel-connected shard workers with a reduction-free merge; wraps
//!   any kernel ([`shard::ShardedKernel`]) and stays bit-identical to the
//!   unsharded run at every shard count (see its invariants);
//! * [`transport`] — the [`transport::ShardTransport`] boundary under the
//!   shard executor: [`transport::InProcess`] (the PR-3 channel workers)
//!   and a versioned, bit-exact wire format ([`transport::wire`]) for
//!   band frames and every [`PreparedB`] variant;
//! * [`remote`] — the socket transport ([`remote::SocketTransport`]) and
//!   the worker loop ([`remote::serve`]) behind the `worker` CLI
//!   subcommand: content-fingerprint-keyed `B` replication, weighted band
//!   placement, per-band timeout/retry, hedged stragglers, and
//!   lost-band-only resubmission on worker death — all metered through
//!   [`transport::TransportCounters`];
//! * [`accel::AccelKernel`] — `runtime::NumericEngine` (PJRT or its CPU
//!   twin) adapted onto the same contract.
//!
//! # Registering a new backend
//!
//! ```ignore
//! struct MyGpuKernel { /* queue, streams, ... */ }
//! impl SpmmKernel for MyGpuKernel {
//!     fn algorithm(&self) -> Algorithm { Algorithm::Block }
//!     fn format(&self) -> FormatKind { FormatKind::Csr }
//!     fn name(&self) -> &'static str { "my-gpu" }
//!     fn cost_hint(&self, a: &Csr, b: &Csr) -> CostHint { /* estimate */ }
//!     fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> { /* upload */ }
//!     fn execute(&self, a: &Csr, b: &PreparedB) -> Result<EngineOutput, EngineError> { /* run */ }
//! }
//! let mut reg = Registry::with_default_kernels(geom, workers);
//! reg.register(Arc::new(MyGpuKernel { ... }));
//! // the coordinator, CLI (`spmm-accel kernels`), property tests, and
//! // benches now dispatch to it via (Csr, Block)
//! ```
//!
//! The coordinator's `Server` resolves kernels per worker (so non-`Sync`
//! device handles like PJRT clients stay worker-local) and per job (fixed
//! key, per-job override, or `Auto` cost-hint selection) — see
//! `coordinator::server`.

pub mod accel;
pub mod error;
pub mod kernel;
pub mod kernels;
pub mod learn;
pub mod prepared;
pub mod registry;
pub mod remote;
pub mod shard;
pub mod tiled;
pub mod transport;

pub use accel::AccelKernel;
pub use error::EngineError;
pub use kernel::{
    Algorithm, BlockedB, CostHint, EngineOutput, ExecStats, OuterB, PooledCsrB, PreparedB,
    SpmmKernel,
};
pub use kernels::{
    DenseOracleKernel, GustavsonFastKernel, GustavsonKernel, InnerKernel, OuterKernel, TiledKernel,
};
pub use learn::{Calibration, CostModel, FittedModel, ModelError, Sample};
pub use prepared::{fingerprint_csr, CsrMemo, FingerprintMemo, PreparedCache, PreparedKey};
pub use registry::{KernelKey, Registry, SelectionScores};
pub use remote::SocketTransport;
pub use shard::{ShardBand, ShardConfig, ShardPlan, ShardPlanner, ShardedKernel};
pub use tiled::TiledConfig;
pub use transport::{InProcess, RetryPolicy, ShardTransport, TransportCounters};
