//! Shard transports: how planned row bands reach their workers.
//!
//! PR 3's shard executor hard-wired in-process channel workers. This
//! module puts that machinery behind [`ShardTransport`] so the *same*
//! planner, merge, and bit-reproducibility contract drive both:
//!
//! * [`InProcess`] — today's `thread::scope` + channel workers, verbatim.
//!   A panicking worker is a lost reply and fails the job typed
//!   ([`EngineError::ExecFailed`] naming the lost shards) — in-process
//!   there is nowhere else to resubmit.
//! * `Socket` ([`super::remote::SocketTransport`]) — length-prefixed
//!   [`wire`] frames over TCP to `worker` processes, with retry, hedging,
//!   and lost-band resubmission (there, worker loss is survivable).
//!
//! The transport owns *placement and delivery* only. Planning stays in
//! [`super::shard::ShardPlanner`]; merging stays in the executor
//! ([`super::shard::execute_with`]); both are transport-blind, which is
//! what keeps remote output bit-identical to local — a transport can
//! reorder or re-place bands freely because no reduction ever crosses a
//! band.

pub mod wire;

use std::sync::mpsc::{channel, sync_channel};
use std::time::{Duration, Instant};

use crate::formats::csr::Csr;

use super::error::EngineError;
use super::kernel::{EngineOutput, PreparedB, SpmmKernel};
use super::prepared::{fingerprint_csr, PreparedKey};
use super::shard::ShardPlan;

/// Delivery-robustness policy for transports that can lose or re-place
/// work (the socket transport; [`InProcess`] ignores it — an in-process
/// panic has no surviving worker to retry on).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Per-attempt band deadline; a band not answered within it is
    /// resubmitted (consuming retry budget).
    pub band_timeout: Duration,
    /// Extra attempts allowed per band beyond the first submission.
    pub retry_budget: u32,
    /// Straggler threshold: a band still outstanding after this long is
    /// *hedged* — duplicated to another live worker, first answer wins.
    pub hedge_after: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            band_timeout: Duration::from_secs(30),
            retry_budget: 2,
            hedge_after: Duration::from_secs(5),
        }
    }
}

/// Delivery accounting for one sharded run. All zeros for [`InProcess`];
/// the socket transport meters every robustness action here, and the
/// coordinator folds them into its metrics counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportCounters {
    /// Bands whose result was computed by a remote worker.
    pub remote_bands: u64,
    /// Band resubmissions (timeout or worker loss).
    pub band_retries: u64,
    /// Hedged duplicates that answered before the original submission.
    pub hedges_won: u64,
    /// Worker connections lost mid-run.
    pub workers_lost: u64,
    /// `Prepare` frames shipped (a B replicated to a worker's cache).
    pub prepare_replications: u64,
    /// Bands that found B already staged on their worker (remote
    /// `PreparedCache` reuse).
    pub prepare_reuse: u64,
    /// Lost workers revived through the circuit-breaker re-admission
    /// path (reconnect + re-handshake; staged B re-replicates lazily).
    pub workers_readmitted: u64,
}

impl TransportCounters {
    /// Fold another run's counters into this accumulator.
    pub fn merge(&mut self, other: &TransportCounters) {
        self.remote_bands += other.remote_bands;
        self.band_retries += other.band_retries;
        self.hedges_won += other.hedges_won;
        self.workers_lost += other.workers_lost;
        self.prepare_replications += other.prepare_replications;
        self.prepare_reuse += other.prepare_reuse;
        self.workers_readmitted += other.workers_readmitted;
    }
}

/// One sharded job as the transport sees it: the plan, the operands, and
/// the content key the socket transport stages B under remotely.
pub struct BandJob<'a> {
    pub kernel: &'a dyn SpmmKernel,
    pub a: &'a Csr,
    pub prepared: &'a PreparedB,
    pub plan: &'a ShardPlan,
    /// Content-addressed identity of `prepared` (see [`content_key`]);
    /// remote workers cache staged operands under this key.
    pub key: PreparedKey,
    /// The submitting job's absolute deadline, if any. The socket
    /// transport caps each band attempt's timeout at the remaining
    /// budget; [`InProcess`] ignores it (the coordinator already killed
    /// expired jobs before dispatch).
    pub deadline: Option<Instant>,
}

/// One band's finished result, however it travelled.
pub struct BandResult {
    pub shard: usize,
    pub rows: (usize, usize),
    /// Submission → dequeue (in-process queue wait, or wire + remote
    /// queue time for socket bands).
    pub queue: Duration,
    /// Kernel execute wall time on whichever worker ran the band.
    pub wall: Duration,
    pub output: EngineOutput,
}

/// A transport run: exactly one result per planned band (any order — the
/// executor sorts by shard before merging), plus delivery accounting.
pub struct BandRun {
    pub bands: Vec<BandResult>,
    pub counters: TransportCounters,
}

/// Delivers a job's planned bands to workers and collects their results.
///
/// Contract: on `Ok`, `bands` holds exactly one bit-exact result per
/// entry of `job.plan.bands`. A transport that cannot complete every band
/// (worker loss with no survivors, retry budget exhausted, a band's typed
/// execute error) returns `Err` naming the shards it lost.
pub trait ShardTransport: Send + Sync {
    fn name(&self) -> &'static str;
    fn run(&self, job: &BandJob<'_>) -> Result<BandRun, EngineError>;
}

/// FNV-1a over raw bytes — the same hash family `prepared::fingerprint_csr`
/// uses, for operands with no canonical CSR source.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Content-addressed identity for a prepared operand under a kernel: the
/// existing CSR content fingerprint when the operand carries its canonical
/// source (`Csr`/`Blocked`/`Pooled`/`OuterPooled`, or the job's explicit
/// `b`), else FNV-1a over the operand's wire encoding. Same content ⇒ same
/// key ⇒ a remote worker's staged cache hits across jobs.
pub fn content_key(
    kernel: &dyn SpmmKernel,
    prepared: &PreparedB,
    b: Option<&Csr>,
) -> PreparedKey {
    let fingerprint = match (b, prepared) {
        (Some(b), _) => fingerprint_csr(b),
        (None, PreparedB::Csr(m)) => fingerprint_csr(m),
        (None, PreparedB::Blocked(bb)) => fingerprint_csr(&bb.src),
        (None, PreparedB::Pooled(pb)) => fingerprint_csr(&pb.src),
        (None, PreparedB::OuterPooled(ob)) => fingerprint_csr(&ob.src),
        (None, _) => {
            let mut w = wire::WireWriter::new();
            wire::put_prepared(&mut w, prepared);
            fnv1a64(&w.into_bytes())
        }
    };
    PreparedKey {
        fingerprint,
        format: kernel.format(),
        algorithm: kernel.algorithm(),
    }
}

struct ShardTask {
    shard: usize,
    rows: (usize, usize),
    a_band: Csr,
    enqueued: Instant,
}

struct ShardReply {
    shard: usize,
    rows: (usize, usize),
    queue: Duration,
    wall: Duration,
    result: Result<EngineOutput, EngineError>,
}

/// The channel-connected in-process transport: one thread + task channel
/// per band, one shared reply channel — PR 3's executor machinery moved
/// behind the trait unchanged. A panicked worker surfaces as
/// [`EngineError::ExecFailed`] naming the lost shards; the caller's
/// thread is never poisoned. No retry/hedging: in-process, a panic means
/// the kernel itself is broken and every "worker" shares it.
#[derive(Clone, Copy, Debug, Default)]
pub struct InProcess;

impl ShardTransport for InProcess {
    fn name(&self) -> &'static str {
        "in-process"
    }

    fn run(&self, job: &BandJob<'_>) -> Result<BandRun, EngineError> {
        let kernel = job.kernel;
        let prepared = job.prepared;
        let n_workers = job.plan.bands.len();
        let (reply_tx, reply_rx) = channel::<ShardReply>();
        let mut replies: Vec<ShardReply> = Vec::with_capacity(n_workers);
        let mut lost_workers = 0usize;

        std::thread::scope(|s| {
            let mut task_txs = Vec::with_capacity(n_workers);
            let handles: Vec<_> = (0..n_workers)
                .map(|_| {
                    let (task_tx, task_rx) = sync_channel::<ShardTask>(1);
                    task_txs.push(task_tx);
                    let reply_tx = reply_tx.clone();
                    s.spawn(move || {
                        // each worker serves exactly one band today; the
                        // loop is the shape a socket worker keeps
                        while let Ok(task) = task_rx.recv() {
                            let queue = task.enqueued.elapsed();
                            let t0 = Instant::now();
                            let result = kernel.execute(&task.a_band, prepared);
                            let _ = reply_tx.send(ShardReply {
                                shard: task.shard,
                                rows: task.rows,
                                queue,
                                wall: t0.elapsed(),
                                result,
                            });
                        }
                    })
                })
                .collect();
            drop(reply_tx);

            // leader side: slice and dispatch one band per worker (the
            // socket transport serializes exactly this slice as a frame)
            for (band, task_tx) in job.plan.bands.iter().zip(&task_txs) {
                let _ = task_tx.send(ShardTask {
                    shard: band.shard,
                    rows: band.rows,
                    a_band: job.a.row_band(band.rows.0, band.rows.1),
                    enqueued: Instant::now(),
                });
            }
            drop(task_txs);

            while let Ok(reply) = reply_rx.recv() {
                replies.push(reply);
            }
            for h in handles {
                if h.join().is_err() {
                    lost_workers += 1;
                }
            }
        });

        if replies.len() < n_workers {
            let got: Vec<usize> = replies.iter().map(|r| r.shard).collect();
            let missing: Vec<usize> =
                (0..n_workers).filter(|i| !got.contains(i)).collect();
            return Err(EngineError::ExecFailed(format!(
                "lost {lost_workers} shard worker(s): shard(s) {missing:?} of {n_workers} \
                 never replied (worker panicked)"
            )));
        }

        replies.sort_by_key(|r| r.shard);
        let mut bands = Vec::with_capacity(replies.len());
        for reply in replies {
            bands.push(BandResult {
                shard: reply.shard,
                rows: reply.rows,
                queue: reply.queue,
                wall: reply.wall,
                output: reply.result?,
            });
        }
        Ok(BandRun {
            bands,
            counters: TransportCounters::default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::engine::kernels::GustavsonKernel;
    use crate::engine::shard::{ShardConfig, ShardPlanner};
    use std::sync::Arc;

    #[test]
    fn in_process_run_answers_every_band() {
        let k = GustavsonKernel;
        let a = uniform(48, 64, 0.2, 1);
        let b = uniform(64, 32, 0.2, 2);
        let prepared = k.prepare(&b).unwrap();
        let plan =
            ShardPlanner::plan(&a, Some(&b), ShardConfig { shards: 3, block: 16 });
        let key = content_key(&k, &prepared, Some(&b));
        let run = InProcess
            .run(&BandJob {
                kernel: &k,
                a: &a,
                prepared: &prepared,
                plan: &plan,
                key,
                deadline: None,
            })
            .unwrap();
        assert_eq!(run.bands.len(), plan.bands.len());
        assert_eq!(run.counters, TransportCounters::default());
        let mut shards: Vec<usize> = run.bands.iter().map(|r| r.shard).collect();
        shards.sort();
        assert_eq!(shards, (0..plan.bands.len()).collect::<Vec<_>>());
    }

    #[test]
    fn content_key_tracks_content_not_identity() {
        let k = GustavsonKernel;
        let b1 = uniform(40, 30, 0.2, 5);
        let b2 = b1.clone();
        let b3 = uniform(40, 30, 0.2, 6);
        let p1 = k.prepare(&b1).unwrap();
        let p2 = k.prepare(&b2).unwrap();
        let p3 = k.prepare(&b3).unwrap();
        let k1 = content_key(&k, &p1, None);
        let k2 = content_key(&k, &p2, None);
        let k3 = content_key(&k, &p3, None);
        assert_eq!(k1, k2, "same content must share a key");
        assert_ne!(k1, k3, "different content must not collide");
        assert_eq!(k1.format, k.format());
        assert_eq!(k1.algorithm, k.algorithm());
    }

    #[test]
    fn content_key_covers_operands_without_a_csr_source() {
        use crate::formats::dense::Dense;
        use crate::formats::traits::SparseMatrix;
        let k = GustavsonKernel;
        let b = uniform(16, 12, 0.4, 7);
        let dense = PreparedB::Dense(Arc::new(Dense::from_coo(&b.to_coo())));
        let again = PreparedB::Dense(Arc::new(Dense::from_coo(&b.to_coo())));
        assert_eq!(
            content_key(&k, &dense, None),
            content_key(&k, &again, None),
            "wire-encoding fingerprint must be deterministic"
        );
    }
}
