//! Versioned, deterministic wire format for distributed shard execution.
//!
//! Everything that crosses a process boundary — row-band task frames and
//! every [`PreparedB`] variant — encodes through this module. The format
//! is little-endian, length-free at the field level (the transport adds a
//! single length prefix per frame), and **bit-exact**: every f32 matrix
//! value travels as its IEEE-754 bit pattern ([`WireWriter::put_f32_bits`])
//! and every f64 as its 64-bit pattern ([`WireWriter::put_f64_bits`], the
//! same convention the cost-model file uses), so a band executed on a
//! remote worker returns exactly the bits the local run would produce.
//!
//! Versioning: every frame starts with [`WIRE_MAGIC`] + [`WIRE_VERSION`].
//! A reader that sees a different version rejects the frame whole
//! ([`WireError::BadVersion`]) — no partial parses of future layouts.
//!
//! Pool-carrying prepared operands (`Pooled`, `OuterPooled`) serialize
//! their canonical `src` only; the receiving host rebuilds the
//! workspace/merge pool locally ([`PooledCsrB::new`] / [`OuterB::new`]) —
//! pools are scratch, not content, and never cross the wire. `Blocked`
//! ships its tile size and rebuilds the grid ([`BlockedB::build`], a
//! deterministic function of `src`); `InCrs` ships its
//! [`InCrsParams`] and rebuilds the counter vectors.
//!
//! Decoding is total: malformed input yields a typed [`WireError`], never
//! a panic — structure is validated *before* the formats' constructors
//! (whose debug assertions then hold by construction).

use std::sync::Arc;

use crate::formats::csr::Csr;
use crate::formats::dense::Dense;
use crate::formats::incrs::{InCrs, InCrsParams};
use crate::formats::traits::{FormatKind, SparseMatrix};

use super::super::kernel::{
    Algorithm, BlockedB, ExecStats, OuterB, PooledCsrB, PreparedB,
};
use super::super::prepared::PreparedKey;

/// Frame preamble: "SPMM" in ASCII.
pub const WIRE_MAGIC: u32 = 0x5350_4d4d;
/// Bump on any layout change; readers reject other versions whole.
pub const WIRE_VERSION: u16 = 1;

/// Typed decode failure. Lifted into `EngineError::ExecFailed` at the
/// transport boundary (see `engine::transport`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ended before the field did.
    Truncated { need: usize, have: usize },
    /// The frame does not start with [`WIRE_MAGIC`].
    BadMagic(u32),
    /// The frame was written by a different wire version.
    BadVersion(u16),
    /// An enum tag (frame kind, prepared variant, format, algorithm) is
    /// out of range.
    BadTag { what: &'static str, tag: u8 },
    /// Structurally invalid payload (non-monotone row pointers, index out
    /// of bounds, length mismatch, …).
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, w: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(w, "truncated frame: need {need} bytes, have {have}")
            }
            WireError::BadMagic(m) => write!(w, "bad frame magic {m:#010x}"),
            WireError::BadVersion(v) => write!(
                w,
                "wire version {v} (this build speaks {WIRE_VERSION})"
            ),
            WireError::BadTag { what, tag } => write!(w, "unknown {what} tag {tag}"),
            WireError::Malformed(msg) => write!(w, "malformed frame: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<WireError> for super::super::error::EngineError {
    fn from(e: WireError) -> Self {
        super::super::error::EngineError::ExecFailed(format!("wire: {e}"))
    }
}

/// Little-endian byte-buffer writer. All floats go through the `_bits`
/// methods so the encoding is a bit pattern, never a formatted value.
#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> WireWriter {
        WireWriter { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// An f32 as its IEEE-754 bit pattern (NaN payloads, -0.0, and
    /// subnormals survive the round trip untouched).
    pub fn put_f32_bits(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    /// An f64 as its IEEE-754 bit pattern — the same convention the
    /// cost-model persistence layer uses (`engine::learn`).
    pub fn put_f64_bits(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    /// Length-prefixed u32 slice.
    pub fn put_u32_slice(&mut self, xs: &[u32]) {
        self.put_u64(xs.len() as u64);
        for &x in xs {
            self.put_u32(x);
        }
    }

    /// Length-prefixed f32 slice, each value as its bit pattern.
    pub fn put_f32_slice(&mut self, xs: &[f32]) {
        self.put_u64(xs.len() as u64);
        for &x in xs {
            self.put_f32_bits(x);
        }
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

/// Bounds-checked reader over one frame's bytes.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> WireReader<'a> {
        WireReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { need: n, have: self.remaining() });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn get_u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn get_u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn get_u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn get_f32_bits(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.get_u32()?))
    }

    pub fn get_f64_bits(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    fn get_len(&mut self, what: &str) -> Result<usize, WireError> {
        let n = self.get_u64()?;
        let n = usize::try_from(n)
            .map_err(|_| WireError::Malformed(format!("{what} length {n} overflows")))?;
        // a length can never exceed the bytes left (every element is ≥ 1
        // byte), so a hostile length cannot force a huge allocation
        if n > self.remaining() {
            return Err(WireError::Truncated { need: n, have: self.remaining() });
        }
        Ok(n)
    }

    pub fn get_u32_vec(&mut self) -> Result<Vec<u32>, WireError> {
        let n = self.get_len("u32 slice")?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_u32()?);
        }
        Ok(out)
    }

    pub fn get_f32_vec(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.get_len("f32 slice")?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_f32_bits()?);
        }
        Ok(out)
    }

    pub fn get_str(&mut self) -> Result<String, WireError> {
        let n = self.get_len("string")?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|e| WireError::Malformed(format!("non-UTF-8 string: {e}")))
    }
}

fn get_usize(r: &mut WireReader<'_>, what: &str) -> Result<usize, WireError> {
    let v = r.get_u64()?;
    usize::try_from(v).map_err(|_| WireError::Malformed(format!("{what} {v} overflows usize")))
}

// ---------------------------------------------------------------------------
// FormatKind / Algorithm codes — explicit exhaustive maps, so adding an enum
// variant without a wire code fails to compile here (and detlint C1 checks
// that every `PreparedB` variant has an arm in this file).
// ---------------------------------------------------------------------------

/// Stable wire code for a [`FormatKind`] (NOT the enum discriminant — the
/// wire contract survives enum reordering).
pub fn format_code(f: FormatKind) -> u8 {
    match f {
        FormatKind::Dense => 0,
        FormatKind::Csr => 1,
        FormatKind::Csc => 2,
        FormatKind::Coo => 3,
        FormatKind::Sll => 4,
        FormatKind::Ellpack => 5,
        FormatKind::Lil => 6,
        FormatKind::Jad => 7,
        FormatKind::InCrs => 8,
    }
}

pub fn format_from_code(c: u8) -> Result<FormatKind, WireError> {
    Ok(match c {
        0 => FormatKind::Dense,
        1 => FormatKind::Csr,
        2 => FormatKind::Csc,
        3 => FormatKind::Coo,
        4 => FormatKind::Sll,
        5 => FormatKind::Ellpack,
        6 => FormatKind::Lil,
        7 => FormatKind::Jad,
        8 => FormatKind::InCrs,
        tag => return Err(WireError::BadTag { what: "format", tag }),
    })
}

/// Stable wire code for an [`Algorithm`].
pub fn algorithm_code(a: Algorithm) -> u8 {
    match a {
        Algorithm::Dense => 0,
        Algorithm::Gustavson => 1,
        Algorithm::GustavsonFast => 2,
        Algorithm::Inner => 3,
        Algorithm::OuterProduct => 4,
        Algorithm::Tiled => 5,
        Algorithm::Block => 6,
    }
}

pub fn algorithm_from_code(c: u8) -> Result<Algorithm, WireError> {
    Ok(match c {
        0 => Algorithm::Dense,
        1 => Algorithm::Gustavson,
        2 => Algorithm::GustavsonFast,
        3 => Algorithm::Inner,
        4 => Algorithm::OuterProduct,
        5 => Algorithm::Tiled,
        6 => Algorithm::Block,
        tag => return Err(WireError::BadTag { what: "algorithm", tag }),
    })
}

// ---------------------------------------------------------------------------
// Matrix payloads
// ---------------------------------------------------------------------------

fn put_raw_csr(
    w: &mut WireWriter,
    rows: usize,
    cols: usize,
    row_ptr: &[u32],
    col_idx: &[u32],
    vals: &[f32],
) {
    w.put_u64(rows as u64);
    w.put_u64(cols as u64);
    w.put_u32_slice(row_ptr);
    w.put_u32_slice(col_idx);
    w.put_f32_slice(vals);
}

/// Serialize a CSR matrix: shape, structure, value bits.
pub fn put_csr(w: &mut WireWriter, m: &Csr) {
    put_raw_csr(w, m.rows(), m.cols(), &m.row_ptr, &m.col_idx, &m.vals);
}

/// Decode and structurally validate a CSR matrix. Validation happens
/// *here*, so [`Csr::from_parts`]'s construction assertions hold for any
/// byte stream — a malformed frame is a typed error, never a panic.
pub fn get_csr(r: &mut WireReader<'_>) -> Result<Csr, WireError> {
    let rows = get_usize(r, "rows")?;
    let cols = get_usize(r, "cols")?;
    let row_ptr = r.get_u32_vec()?;
    let col_idx = r.get_u32_vec()?;
    let vals = r.get_f32_vec()?;
    if row_ptr.len() != rows + 1 {
        return Err(WireError::Malformed(format!(
            "row_ptr has {} entries for {rows} rows",
            row_ptr.len()
        )));
    }
    if row_ptr[0] != 0 {
        return Err(WireError::Malformed("row_ptr[0] != 0".into()));
    }
    if row_ptr.windows(2).any(|w| w[0] > w[1]) {
        return Err(WireError::Malformed("row_ptr not monotone".into()));
    }
    let nnz = row_ptr[rows] as usize;
    if col_idx.len() != nnz || vals.len() != nnz {
        return Err(WireError::Malformed(format!(
            "nnz mismatch: row_ptr says {nnz}, col_idx {}, vals {}",
            col_idx.len(),
            vals.len()
        )));
    }
    for i in 0..rows {
        let (lo, hi) = (row_ptr[i] as usize, row_ptr[i + 1] as usize);
        let row = &col_idx[lo..hi];
        if row.windows(2).any(|w| w[0] >= w[1]) {
            return Err(WireError::Malformed(format!("row {i} indices not sorted")));
        }
        if row.iter().any(|&c| c as usize >= cols) {
            return Err(WireError::Malformed(format!("row {i} index out of bounds")));
        }
    }
    Ok(Csr::from_parts(rows, cols, row_ptr, col_idx, vals))
}

/// Serialize a dense matrix: shape + value bit patterns.
pub fn put_dense(w: &mut WireWriter, m: &Dense) {
    let (rows, cols) = m.shape();
    w.put_u64(rows as u64);
    w.put_u64(cols as u64);
    w.put_f32_slice(&m.data);
}

pub fn get_dense(r: &mut WireReader<'_>) -> Result<Dense, WireError> {
    let rows = get_usize(r, "rows")?;
    let cols = get_usize(r, "cols")?;
    let data = r.get_f32_vec()?;
    let want = rows
        .checked_mul(cols)
        .ok_or_else(|| WireError::Malformed(format!("dense shape {rows}x{cols} overflows")))?;
    if data.len() != want {
        return Err(WireError::Malformed(format!(
            "dense {rows}x{cols} carries {} values",
            data.len()
        )));
    }
    Ok(Dense::new(rows, cols, data))
}

fn put_stats(w: &mut WireWriter, s: &ExecStats) {
    w.put_u64(s.dispatches);
    w.put_u64(s.real_pairs);
    w.put_u64(s.padded_pairs);
    w.put_u64(s.macs_issued);
    w.put_u64(s.threads as u64);
}

fn get_stats(r: &mut WireReader<'_>) -> Result<ExecStats, WireError> {
    Ok(ExecStats {
        dispatches: r.get_u64()?,
        real_pairs: r.get_u64()?,
        padded_pairs: r.get_u64()?,
        macs_issued: r.get_u64()?,
        threads: get_usize(r, "threads")?,
    })
}

fn put_key(w: &mut WireWriter, key: PreparedKey) {
    w.put_u64(key.fingerprint);
    w.put_u8(format_code(key.format));
    w.put_u8(algorithm_code(key.algorithm));
}

fn get_key(r: &mut WireReader<'_>) -> Result<PreparedKey, WireError> {
    Ok(PreparedKey {
        fingerprint: r.get_u64()?,
        format: format_from_code(r.get_u8()?)?,
        algorithm: algorithm_from_code(r.get_u8()?)?,
    })
}

// ---------------------------------------------------------------------------
// PreparedB — one wire arm per variant (detlint C1 cross-checks this file
// against the enum in engine/kernel.rs)
// ---------------------------------------------------------------------------

const PREP_CSR: u8 = 0;
const PREP_INCRS: u8 = 1;
const PREP_DENSE: u8 = 2;
const PREP_BLOCKED: u8 = 3;
const PREP_POOLED: u8 = 4;
const PREP_OUTER_POOLED: u8 = 5;

/// Serialize a prepared operand. Pools never cross the wire: `Pooled` /
/// `OuterPooled` ship their canonical `src` and the receiver rebuilds the
/// pool host-local; `Blocked` ships `src` + tile size and the receiver
/// re-runs the deterministic blockization; `InCrs` ships its params and
/// underlying arrays and the receiver rebuilds the counter vectors.
pub fn put_prepared(w: &mut WireWriter, b: &PreparedB) {
    match b {
        PreparedB::Csr(m) => {
            w.put_u8(PREP_CSR);
            put_csr(w, m);
        }
        PreparedB::InCrs(m) => {
            w.put_u8(PREP_INCRS);
            w.put_u64(m.params.section as u64);
            w.put_u64(m.params.block as u64);
            let (rows, cols) = m.shape();
            put_raw_csr(w, rows, cols, &m.row_ptr, &m.col_idx, &m.vals);
        }
        PreparedB::Dense(m) => {
            w.put_u8(PREP_DENSE);
            put_dense(w, m);
        }
        PreparedB::Blocked(bb) => {
            w.put_u8(PREP_BLOCKED);
            w.put_u64(bb.block() as u64);
            put_csr(w, &bb.src);
        }
        PreparedB::Pooled(pb) => {
            w.put_u8(PREP_POOLED);
            put_csr(w, &pb.src);
        }
        PreparedB::OuterPooled(ob) => {
            w.put_u8(PREP_OUTER_POOLED);
            put_csr(w, &ob.src);
        }
    }
}

/// Decode a prepared operand, rebuilding host-local state (pools, block
/// grids, counter vectors) deterministically from the shipped source.
pub fn get_prepared(r: &mut WireReader<'_>) -> Result<PreparedB, WireError> {
    let tag = r.get_u8()?;
    Ok(match tag {
        PREP_CSR => PreparedB::Csr(Arc::new(get_csr(r)?)),
        PREP_INCRS => {
            let section = get_usize(r, "incrs section")?;
            let block = get_usize(r, "incrs block")?;
            let src = get_csr(r)?;
            let params = InCrsParams { section, block };
            let incrs = InCrs::from_csr_params(&src, params)
                .map_err(|e| WireError::Malformed(format!("incrs rebuild: {e}")))?;
            PreparedB::InCrs(Arc::new(incrs))
        }
        PREP_DENSE => PreparedB::Dense(Arc::new(get_dense(r)?)),
        PREP_BLOCKED => {
            let block = get_usize(r, "blocked tile size")?;
            if block == 0 {
                return Err(WireError::Malformed("blocked tile size 0".into()));
            }
            let src = get_csr(r)?;
            PreparedB::Blocked(Arc::new(BlockedB::build(Arc::new(src), block)))
        }
        PREP_POOLED => {
            let src = get_csr(r)?;
            PreparedB::Pooled(Arc::new(PooledCsrB::new(Arc::new(src))))
        }
        PREP_OUTER_POOLED => {
            let src = get_csr(r)?;
            PreparedB::OuterPooled(Arc::new(OuterB::new(Arc::new(src))))
        }
        tag => return Err(WireError::BadTag { what: "prepared operand", tag }),
    })
}

// ---------------------------------------------------------------------------
// Frames
// ---------------------------------------------------------------------------

const FRAME_HELLO: u8 = 0;
const FRAME_HELLO_ACK: u8 = 1;
const FRAME_PREPARE: u8 = 2;
const FRAME_BAND: u8 = 3;
const FRAME_BAND_OK: u8 = 4;
const FRAME_BAND_ERR: u8 = 5;
const FRAME_SHUTDOWN: u8 = 6;

/// One protocol message. The transport length-prefixes the encoded bytes;
/// the frame itself carries magic + version so a desynchronized or
/// cross-version stream is rejected typed.
#[derive(Debug)]
pub enum Frame {
    /// Leader → worker, first frame on a connection.
    Hello,
    /// Worker → leader: the handshake answer.
    HelloAck,
    /// Leader → worker: stage a prepared operand under its content key.
    Prepare { key: PreparedKey, prepared: PreparedB },
    /// Leader → worker: execute one row band of A against a staged operand.
    Band {
        /// Leader-assigned submission id (retries/hedges get fresh seqs).
        seq: u64,
        shard: u64,
        rows: (u64, u64),
        key: PreparedKey,
        a_band: Csr,
    },
    /// Worker → leader: a band's bit-exact result.
    BandOk {
        seq: u64,
        shard: u64,
        wall_us: u64,
        stats: ExecStats,
        c: Dense,
    },
    /// Worker → leader: a band failed typed (kernel missing, operand not
    /// staged, execute error).
    BandErr { seq: u64, shard: u64, message: String },
    /// Leader → worker: drain and close this connection.
    Shutdown,
}

/// Encode one frame (magic + version + tag + payload).
pub fn encode_frame(f: &Frame) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.put_u32(WIRE_MAGIC);
    w.put_u16(WIRE_VERSION);
    match f {
        Frame::Hello => w.put_u8(FRAME_HELLO),
        Frame::HelloAck => w.put_u8(FRAME_HELLO_ACK),
        Frame::Prepare { key, prepared } => {
            w.put_u8(FRAME_PREPARE);
            put_key(&mut w, *key);
            put_prepared(&mut w, prepared);
        }
        Frame::Band { seq, shard, rows, key, a_band } => {
            w.put_u8(FRAME_BAND);
            w.put_u64(*seq);
            w.put_u64(*shard);
            w.put_u64(rows.0);
            w.put_u64(rows.1);
            put_key(&mut w, *key);
            put_csr(&mut w, a_band);
        }
        Frame::BandOk { seq, shard, wall_us, stats, c } => {
            w.put_u8(FRAME_BAND_OK);
            w.put_u64(*seq);
            w.put_u64(*shard);
            w.put_u64(*wall_us);
            put_stats(&mut w, stats);
            put_dense(&mut w, c);
        }
        Frame::BandErr { seq, shard, message } => {
            w.put_u8(FRAME_BAND_ERR);
            w.put_u64(*seq);
            w.put_u64(*shard);
            w.put_str(message);
        }
        Frame::Shutdown => w.put_u8(FRAME_SHUTDOWN),
    }
    w.into_bytes()
}

/// Decode one frame; rejects foreign magic and other wire versions whole.
pub fn decode_frame(bytes: &[u8]) -> Result<Frame, WireError> {
    let mut r = WireReader::new(bytes);
    let magic = r.get_u32()?;
    if magic != WIRE_MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = r.get_u16()?;
    if version != WIRE_VERSION {
        return Err(WireError::BadVersion(version));
    }
    let tag = r.get_u8()?;
    Ok(match tag {
        FRAME_HELLO => Frame::Hello,
        FRAME_HELLO_ACK => Frame::HelloAck,
        FRAME_PREPARE => Frame::Prepare {
            key: get_key(&mut r)?,
            prepared: get_prepared(&mut r)?,
        },
        FRAME_BAND => Frame::Band {
            seq: r.get_u64()?,
            shard: r.get_u64()?,
            rows: (r.get_u64()?, r.get_u64()?),
            key: get_key(&mut r)?,
            a_band: get_csr(&mut r)?,
        },
        FRAME_BAND_OK => Frame::BandOk {
            seq: r.get_u64()?,
            shard: r.get_u64()?,
            wall_us: r.get_u64()?,
            stats: get_stats(&mut r)?,
            c: get_dense(&mut r)?,
        },
        FRAME_BAND_ERR => Frame::BandErr {
            seq: r.get_u64()?,
            shard: r.get_u64()?,
            message: r.get_str()?,
        },
        FRAME_SHUTDOWN => Frame::Shutdown,
        tag => return Err(WireError::BadTag { what: "frame", tag }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;

    fn roundtrip_prepared(b: &PreparedB) -> PreparedB {
        let mut w = WireWriter::new();
        put_prepared(&mut w, b);
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        let out = get_prepared(&mut r).expect("decode");
        assert_eq!(r.remaining(), 0, "trailing bytes after decode");
        out
    }

    #[test]
    fn csr_roundtrip_is_bit_exact() {
        let m = uniform(37, 53, 0.13, 7);
        let mut w = WireWriter::new();
        put_csr(&mut w, &m);
        let bytes = w.into_bytes();
        let got = get_csr(&mut WireReader::new(&bytes)).unwrap();
        assert_eq!(got.bit_pattern(), m.bit_pattern());
    }

    #[test]
    fn awkward_float_bit_patterns_survive() {
        // NaN payloads, -0.0, subnormals, infinities — for both widths
        let f32s = [
            f32::from_bits(0x7fc0_dead), // quiet NaN with payload
            f32::from_bits(0xff80_0001), // signaling-ish NaN
            -0.0f32,
            f32::from_bits(1),           // smallest subnormal
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE / 2.0,
        ];
        let f64s = [
            f64::from_bits(0x7ff8_0000_0000_beef),
            f64::from_bits(0xfff0_0000_0000_0001),
            -0.0f64,
            f64::from_bits(1),
            f64::INFINITY,
            f64::MIN_POSITIVE / 2.0,
        ];
        let mut w = WireWriter::new();
        for &v in &f32s {
            w.put_f32_bits(v);
        }
        for &v in &f64s {
            w.put_f64_bits(v);
        }
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        for &v in &f32s {
            assert_eq!(r.get_f32_bits().unwrap().to_bits(), v.to_bits());
        }
        for &v in &f64s {
            assert_eq!(r.get_f64_bits().unwrap().to_bits(), v.to_bits());
        }
    }

    #[test]
    fn every_prepared_variant_roundtrips() {
        let src = Arc::new(uniform(24, 40, 0.2, 3));
        let cases: Vec<PreparedB> = vec![
            PreparedB::Csr(Arc::clone(&src)),
            PreparedB::InCrs(Arc::new(
                InCrs::from_csr_params(&src, InCrsParams { section: 8, block: 4 }).unwrap(),
            )),
            PreparedB::Dense(Arc::new(Dense::from_coo(&src.to_coo()))),
            PreparedB::Blocked(Arc::new(BlockedB::build(Arc::clone(&src), 16))),
            PreparedB::Pooled(Arc::new(PooledCsrB::new(Arc::clone(&src)))),
            PreparedB::OuterPooled(Arc::new(OuterB::new(Arc::clone(&src)))),
        ];
        for case in &cases {
            let got = roundtrip_prepared(case);
            assert_eq!(got.label(), case.label());
            assert_eq!(got.shape(), case.shape());
        }
    }

    #[test]
    fn frame_roundtrip_and_version_gate() {
        let m = uniform(8, 8, 0.4, 1);
        let key = PreparedKey {
            fingerprint: 0xfeed_beef,
            format: FormatKind::Csr,
            algorithm: Algorithm::Gustavson,
        };
        let frame = Frame::Band {
            seq: 42,
            shard: 3,
            rows: (16, 32),
            key,
            a_band: m.clone(),
        };
        let bytes = encode_frame(&frame);
        match decode_frame(&bytes).unwrap() {
            Frame::Band { seq, shard, rows, key: k, a_band } => {
                assert_eq!((seq, shard, rows), (42, 3, (16, 32)));
                assert_eq!(k, key);
                assert_eq!(a_band.bit_pattern(), m.bit_pattern());
            }
            other => panic!("wrong frame {other:?}"),
        }
        // corrupt the magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(matches!(decode_frame(&bad), Err(WireError::BadMagic(_))));
        // bump the version
        let mut bad = bytes.clone();
        bad[4] = bad[4].wrapping_add(1);
        assert!(matches!(decode_frame(&bad), Err(WireError::BadVersion(_))));
        // truncate
        assert!(matches!(
            decode_frame(&bytes[..bytes.len() - 3]),
            Err(WireError::Truncated { .. })
        ));
    }

    #[test]
    fn malformed_structure_is_typed_not_a_panic() {
        // row_ptr says 4 nnz but only 2 indices follow
        let mut w = WireWriter::new();
        put_raw_csr(&mut w, 1, 8, &[0, 4], &[1, 2], &[1.0, 2.0]);
        let bytes = w.into_bytes();
        assert!(matches!(
            get_csr(&mut WireReader::new(&bytes)),
            Err(WireError::Malformed(_))
        ));
        // out-of-bounds column index
        let mut w = WireWriter::new();
        put_raw_csr(&mut w, 1, 2, &[0, 1], &[5], &[1.0]);
        let bytes = w.into_bytes();
        assert!(matches!(
            get_csr(&mut WireReader::new(&bytes)),
            Err(WireError::Malformed(_))
        ));
        // unsorted row
        let mut w = WireWriter::new();
        put_raw_csr(&mut w, 1, 8, &[0, 2], &[3, 1], &[1.0, 2.0]);
        let bytes = w.into_bytes();
        assert!(matches!(
            get_csr(&mut WireReader::new(&bytes)),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn format_and_algorithm_codes_roundtrip_exhaustively() {
        for f in FormatKind::ALL {
            assert_eq!(format_from_code(format_code(f)).unwrap(), f);
        }
        for a in Algorithm::ALL {
            assert_eq!(algorithm_from_code(algorithm_code(a)).unwrap(), a);
        }
        assert!(format_from_code(200).is_err());
        assert!(algorithm_from_code(200).is_err());
    }
}
