//! Kernel registry: `(FormatKind, Algorithm)` → [`SpmmKernel`], the single
//! dispatch surface every execution consumer (coordinator, CLI, eval
//! drivers, benches) resolves through.
//!
//! Registering a new backend is one call: implement [`SpmmKernel`] and
//! `registry.register(Arc::new(MyKernel))` — the server, router, property
//! tests, and `spmm-accel kernels` pick it up with no further wiring.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::formats::csr::Csr;
use crate::formats::incrs::InCrsParams;
use crate::formats::traits::FormatKind;
use crate::spmm::plan::Geometry;

use super::accel::AccelKernel;
use super::error::EngineError;
use super::kernel::{Algorithm, SpmmKernel};
use super::kernels::{
    DenseOracleKernel, GustavsonFastKernel, GustavsonKernel, InnerKernel, OuterKernel,
    TiledKernel,
};
use super::tiled::TiledConfig;
use crate::spmm::outer::OuterConfig;

/// The registry key: which representation of `B` the kernel consumes and
/// which compute organization it applies.
pub type KernelKey = (FormatKind, Algorithm);

/// The exact numbers selection ranked for the winning kernel — threaded
/// through the serving path so `KernelObservation` records what the model
/// predicted, not a post-hoc recomputation that can disagree (negotiated
/// InCRS siblings, native-CSC arrivals).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SelectionScores {
    /// `SpmmKernel::cost_hint(a, b).total()` at selection time.
    pub cost_hint: f64,
    /// `SpmmKernel::ingest_cost(b, b_native)` at selection time (may be
    /// negative: a kernel adopting the native representation is credited).
    pub ingest_cost: f64,
}

impl SelectionScores {
    pub fn total(&self) -> f64 {
        self.cost_hint + self.ingest_cost
    }

    /// The NaN-clamped value selection actually compares (see
    /// [`Registry::select_native`]'s NaN-safety note).
    pub fn ranked(&self) -> f64 {
        let c = self.total();
        if c.is_nan() {
            f64::INFINITY
        } else {
            c
        }
    }
}

#[derive(Default)]
pub struct Registry {
    map: BTreeMap<KernelKey, Arc<dyn SpmmKernel>>,
    /// Optional learned-selection handle (see [`super::learn`]): when set
    /// *and* every candidate is calibrated, `select_native` ranks on
    /// predicted microseconds with hysteresis instead of raw hint units.
    cost_model: Option<super::learn::CostModel>,
}

impl Registry {
    /// Empty registry (register kernels explicitly).
    pub fn new() -> Registry {
        Registry { map: BTreeMap::new(), cost_model: None }
    }

    /// The standard CPU kernel set: dense oracle, Gustavson (scalar and the
    /// vectorized workspace-pooled fast variant, the latter running
    /// `tile_workers` A-row bands), inner-product over CRS and InCRS, the
    /// outer-product merge kernel (`tile_workers` k-range workers), the
    /// `tile_workers`-threaded tiled executor, and the CPU accelerator-plan
    /// twin at `geom`.
    pub fn with_default_kernels(geom: Geometry, tile_workers: usize) -> Registry {
        let mut r = Registry::new();
        r.register(Arc::new(DenseOracleKernel));
        r.register(Arc::new(GustavsonKernel));
        r.register(Arc::new(GustavsonFastKernel::new(tile_workers)));
        r.register(Arc::new(InnerKernel::csr()));
        r.register(Arc::new(InnerKernel::incrs(InCrsParams::default())));
        r.register(Arc::new(OuterKernel::new(OuterConfig {
            fan_in: 4,
            workers: tile_workers.max(1),
        })));
        r.register(Arc::new(TiledKernel::new(TiledConfig {
            block: geom.block,
            workers: tile_workers.max(1),
        })));
        r.register(Arc::new(AccelKernel::cpu(geom)));
        r
    }

    /// Register (or replace) the kernel under its own `(format, algorithm)`
    /// key. Returns the key it was registered under.
    pub fn register(&mut self, kernel: Arc<dyn SpmmKernel>) -> KernelKey {
        let key = (kernel.format(), kernel.algorithm());
        self.map.insert(key, kernel);
        key
    }

    /// Exact lookup.
    pub fn resolve(&self, format: FormatKind, algorithm: Algorithm) -> Option<Arc<dyn SpmmKernel>> {
        self.map.get(&(format, algorithm)).cloned()
    }

    /// Exact lookup with a typed error — the serving path's resolver
    /// (misses become [`EngineError::KernelUnavailable`], which the
    /// coordinator lifts into `JobError::KernelUnavailable`).
    pub fn resolve_or_err(
        &self,
        format: FormatKind,
        algorithm: Algorithm,
    ) -> Result<Arc<dyn SpmmKernel>, EngineError> {
        self.resolve(format, algorithm)
            .ok_or(EngineError::KernelUnavailable {
                format: Some(format),
                algorithm: Some(algorithm),
            })
    }

    /// First kernel implementing `algorithm`, any format (key order).
    pub fn resolve_algorithm(&self, algorithm: Algorithm) -> Option<Arc<dyn SpmmKernel>> {
        self.map
            .iter()
            .find(|((_, alg), _)| *alg == algorithm)
            .map(|(_, k)| Arc::clone(k))
    }

    /// Pick the cheapest kernel for `A × B` by cost hint, excluding the
    /// dense oracle (it exists for verification, not serving). Returns the
    /// oracle only when nothing else is registered. Assumes `B` is already
    /// canonical CSR — [`Registry::select_native`] is the operand-aware
    /// variant.
    pub fn select(&self, a: &Csr, b: &Csr) -> Option<Arc<dyn SpmmKernel>> {
        self.select_native(a, b, None)
    }

    /// Operand-aware selection: negotiate storage format and kernel
    /// *jointly* from `B`'s native arrival form (`None` = canonical CSR).
    /// Each kernel's cost is its [`SpmmKernel::cost_hint`] **plus** its
    /// [`SpmmKernel::ingest_cost`] for the native operand — so non-CSR
    /// ingestion is charged (instead of assumed free), and a kernel that
    /// adopts the native representation directly (inner-InCRS consuming an
    /// InCRS operand with matching geometry) is credited its skipped
    /// prepare. `b` is `B`'s canonical CSR rendering, used only to size
    /// the estimates.
    pub fn select_native(
        &self,
        a: &Csr,
        b: &Csr,
        b_native: Option<&crate::formats::operand::MatrixOperand>,
    ) -> Option<Arc<dyn SpmmKernel>> {
        self.select_native_scored(a, b, b_native).map(|(k, _)| k)
    }

    /// [`Registry::select_native`] returning the winner *with* the exact
    /// `(cost_hint, ingest_cost)` it was ranked on — the serving path
    /// threads these into `KernelObservation` so the fitted model learns
    /// from the scores selection actually compared.
    pub fn select_native_scored(
        &self,
        a: &Csr,
        b: &Csr,
        b_native: Option<&crate::formats::operand::MatrixOperand>,
    ) -> Option<(Arc<dyn SpmmKernel>, SelectionScores)> {
        let mut candidates: Vec<Arc<dyn SpmmKernel>> = self
            .map
            .values()
            .filter(|k| k.algorithm() != Algorithm::Dense)
            .cloned()
            .collect();
        // per-operand negotiation: a kernel may offer a sibling specialized
        // to B's native form (inner-InCRS re-parameterized to the operand's
        // own InCrsParams) — the sibling competes on the same cost basis,
        // so the operand's geometry is passed through instead of being
        // re-derived from defaults
        if let Some(native) = b_native {
            let negotiated: Vec<Arc<dyn SpmmKernel>> = candidates
                .iter()
                .filter_map(|k| k.negotiate(native))
                .collect();
            candidates.extend(negotiated);
        }
        let scores_for = |k: &Arc<dyn SpmmKernel>| SelectionScores {
            cost_hint: k.cost_hint(a, b).total(),
            ingest_cost: k.ingest_cost(b, b_native),
        };
        if candidates.is_empty() {
            return self
                .resolve_algorithm(Algorithm::Dense)
                .map(|k| {
                    let s = scores_for(&k);
                    (k, s)
                });
        }
        // NaN-safe total-ordered scoring (SelectionScores::ranked): a
        // kernel whose hint arithmetic produces NaN must never *win*
        // selection (total_cmp orders -NaN below every real number, so a
        // raw min_by would hand it the whole registry); clamping NaN to
        // +inf demotes it instead, keeping the comparison total and
        // deterministic
        let scored: Vec<SelectionScores> = candidates.iter().map(scores_for).collect();
        // fitted path: only when a cost model is set and can price every
        // candidate — partial calibration falls back to the static ranking
        if let Some(model) = &self.cost_model {
            let keyed: Vec<(KernelKey, f64)> = candidates
                .iter()
                .zip(&scored)
                .map(|(k, s)| ((k.format(), k.algorithm()), s.ranked()))
                .collect();
            if let Some(i) = model.choose(super::learn::workload_class(a, b), &keyed) {
                return Some((Arc::clone(&candidates[i]), scored[i]));
            }
        }
        (0..candidates.len())
            .min_by(|&x, &y| scored[x].ranked().total_cmp(&scored[y].ranked()))
            .map(|i| (Arc::clone(&candidates[i]), scored[i]))
    }

    /// Attach (or replace) the learned-selection cost model consulted by
    /// [`Registry::select_native`]. The handle is shared: a refit loop
    /// publishing into a clone is immediately visible here. The handle is
    /// also fanned out to every registered kernel via
    /// [`SpmmKernel::observe_model`], so kernels with fittable constants
    /// inside their own hint arithmetic (the outer kernel's merge-round
    /// weight) see each published fit live.
    pub fn set_cost_model(&mut self, model: super::learn::CostModel) {
        for k in self.map.values() {
            k.observe_model(&model);
        }
        self.cost_model = Some(model);
    }

    pub fn cost_model(&self) -> Option<&super::learn::CostModel> {
        self.cost_model.as_ref()
    }

    /// [`Registry::select`] with a typed error for the empty-registry case.
    pub fn select_or_err(&self, a: &Csr, b: &Csr) -> Result<Arc<dyn SpmmKernel>, EngineError> {
        self.select_native_or_err(a, b, None)
    }

    /// [`Registry::select_native`] with a typed error for the
    /// empty-registry case — the serving path's auto-selection resolver.
    pub fn select_native_or_err(
        &self,
        a: &Csr,
        b: &Csr,
        b_native: Option<&crate::formats::operand::MatrixOperand>,
    ) -> Result<Arc<dyn SpmmKernel>, EngineError> {
        self.select_native(a, b, b_native)
            .ok_or(EngineError::KernelUnavailable {
                format: None,
                algorithm: None,
            })
    }

    /// [`Registry::select_native_scored`] with a typed error for the
    /// empty-registry case.
    pub fn select_native_scored_or_err(
        &self,
        a: &Csr,
        b: &Csr,
        b_native: Option<&crate::formats::operand::MatrixOperand>,
    ) -> Result<(Arc<dyn SpmmKernel>, SelectionScores), EngineError> {
        self.select_native_scored(a, b, b_native)
            .ok_or(EngineError::KernelUnavailable {
                format: None,
                algorithm: None,
            })
    }

    /// Wrap every registered kernel in [`super::shard::ShardedKernel`] so
    /// all traffic for every key runs row-band sharded at `cfg` —
    /// bit-identical by the shard layer's invariants (the executor aligns
    /// bands to each kernel's `band_alignment`). Kernels already wrapped
    /// are left alone, so calling this twice never nests shard executors.
    /// Mostly for soak tests and benches; the serving path prefers
    /// per-job `JobOptions::shards`.
    pub fn shard_all(&mut self, cfg: super::shard::ShardConfig) {
        let kernels: Vec<Arc<dyn SpmmKernel>> = self.map.values().cloned().collect();
        for k in kernels {
            if k.name() == "sharded" {
                continue;
            }
            self.register(Arc::new(super::shard::ShardedKernel::wrap(k, cfg)));
        }
    }

    /// Registered keys, sorted.
    pub fn keys(&self) -> Vec<KernelKey> {
        self.map.keys().copied().collect()
    }

    /// Iterate registered kernels in key order.
    pub fn kernels(&self) -> impl Iterator<Item = &Arc<dyn SpmmKernel>> {
        self.map.values()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list()
            .entries(self.map.keys().map(|(fmt, alg)| {
                format!("{}/{}", fmt.name(), alg.name())
            }))
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::spmm::dense::multiply as dense_ref;

    fn default_registry() -> Registry {
        Registry::with_default_kernels(Geometry { block: 16, pairs: 32, slots: 16 }, 2)
    }

    #[test]
    fn default_kernels_cover_three_formats_and_algorithms() {
        let r = default_registry();
        let keys = r.keys();
        let formats: std::collections::BTreeSet<_> = keys.iter().map(|k| k.0).collect();
        let algos: std::collections::BTreeSet<_> = keys.iter().map(|k| k.1).collect();
        assert!(formats.len() >= 3, "{keys:?}");
        assert!(algos.len() >= 5, "{keys:?}");
        assert!(r.resolve(FormatKind::Csr, Algorithm::Gustavson).is_some());
        assert!(r.resolve(FormatKind::Csr, Algorithm::GustavsonFast).is_some());
        assert!(r.resolve(FormatKind::InCrs, Algorithm::Inner).is_some());
        assert!(r.resolve(FormatKind::Csc, Algorithm::OuterProduct).is_some());
        assert!(r.resolve(FormatKind::Dense, Algorithm::Dense).is_some());
        assert!(r.resolve(FormatKind::Csr, Algorithm::Block).is_some());
    }

    #[test]
    fn every_registered_kernel_agrees_with_the_oracle() {
        let r = default_registry();
        let a = uniform(22, 37, 0.2, 5);
        let b = uniform(37, 29, 0.2, 6);
        let want = dense_ref(&a, &b);
        for k in r.kernels() {
            let out = k.run(&a, &b).unwrap_or_else(|e| panic!("{}: {e}", k.name()));
            assert!(
                out.c.max_abs_diff(&want) < 1e-3,
                "{}/{} diverges",
                k.format().name(),
                k.algorithm().name()
            );
        }
    }

    #[test]
    fn resolve_misses_cleanly() {
        let r = Registry::new();
        assert!(r.is_empty());
        assert!(r.resolve(FormatKind::Csr, Algorithm::Gustavson).is_none());
        assert!(r.select(&uniform(4, 4, 0.5, 1), &uniform(4, 4, 0.5, 2)).is_none());
    }

    #[test]
    fn select_avoids_the_oracle_and_scales_with_sparsity() {
        let r = default_registry();
        let a = uniform(64, 128, 0.02, 7);
        let b = uniform(128, 64, 0.02, 8);
        let k = r.select(&a, &b).unwrap();
        assert_ne!(k.algorithm(), Algorithm::Dense);
        // and the selected kernel actually works
        let out = k.run(&a, &b).unwrap();
        assert!(out.c.max_abs_diff(&dense_ref(&a, &b)) < 1e-3);
    }

    #[test]
    fn select_native_charges_conversion_and_credits_adoption() {
        use crate::formats::incrs::InCrs;
        use crate::formats::operand::MatrixOperand;
        use crate::formats::traits::SparseMatrix;
        let r = default_registry();
        let a = uniform(64, 128, 0.02, 7);
        let b = uniform(128, 64, 0.02, 8);
        // CSR-native selection is exactly the legacy select
        let legacy = r.select(&a, &b).unwrap();
        let native = r.select_native(&a, &b, None).unwrap();
        assert_eq!(
            (legacy.format(), legacy.algorithm()),
            (native.format(), native.algorithm())
        );
        // an InCRS arrival with MATCHING geometry credits the adopting
        // kernel (its adjusted cost drops vs CSR-native), while a
        // mismatched-params arrival — which prepare_operand would refuse
        // to adopt — is charged like any conversion
        let incrs_kernel = r.resolve(FormatKind::InCrs, Algorithm::Inner).unwrap();
        let matching =
            MatrixOperand::from(InCrs::from_csr_params(&b, InCrsParams::default()).unwrap());
        let foreign = MatrixOperand::from(
            InCrs::from_csr_params(&b, InCrsParams { section: 64, block: 8 }).unwrap(),
        );
        let base = incrs_kernel.cost_hint(&a, &b).total();
        let csr_cost = base + incrs_kernel.ingest_cost(&b, None);
        let adopted_cost = base + incrs_kernel.ingest_cost(&b, Some(&matching));
        let foreign_cost = base + incrs_kernel.ingest_cost(&b, Some(&foreign));
        assert!(adopted_cost < csr_cost, "{adopted_cost} !< {csr_cost}");
        assert!(foreign_cost > csr_cost, "{foreign_cost} !> {csr_cost}");
        // and whatever wins for a Coo arrival still computes correctly
        let coo_op = MatrixOperand::from(b.to_coo());
        let k = r.select_native(&a, &b, Some(&coo_op)).unwrap();
        assert_ne!(k.algorithm(), Algorithm::Dense);
        let out = k.run(&a, &b).unwrap();
        assert!(out.c.max_abs_diff(&dense_ref(&a, &b)) < 1e-3);
    }

    #[test]
    fn select_native_negotiates_per_operand_incrs_params() {
        use crate::formats::incrs::InCrs;
        use crate::formats::operand::MatrixOperand;
        // restricted registry: only the default-params inner-InCRS kernel
        // is registered, so what decides selection is whether the operand's
        // own geometry is passed through (the negotiated sibling adopts the
        // native arrays) instead of re-derived from defaults
        let mut r = Registry::new();
        r.register(Arc::new(InnerKernel::incrs(InCrsParams::default())));
        let a = uniform(32, 64, 0.1, 17);
        let b = uniform(64, 48, 0.1, 18);
        let params = InCrsParams { section: 64, block: 8 };
        let native = Arc::new(InCrs::from_csr_params(&b, params).unwrap());
        let op = MatrixOperand::InCrs(Arc::clone(&native));
        let k = r.select_native(&a, &b, Some(&op)).unwrap();
        assert_eq!(
            (k.format(), k.algorithm()),
            (FormatKind::InCrs, Algorithm::Inner)
        );
        assert!(
            k.ingest_cost(&b, Some(&op)) < 0.0,
            "the winner must be the negotiated sibling that adopts the operand"
        );
        let b_arc = Arc::new(b.clone());
        match k.prepare_operand(&op, &b_arc).unwrap() {
            crate::engine::PreparedB::InCrs(adopted) => {
                assert!(Arc::ptr_eq(&adopted, &native), "adoption must Arc-share")
            }
            other => panic!("expected adoption, got {other:?}"),
        }
        // without a native operand, selection is unchanged by negotiation
        let plain = r.select_native(&a, &b, None).unwrap();
        assert!(plain.ingest_cost(&b, None) >= 0.0);
    }

    #[test]
    fn scored_selection_reports_exactly_what_it_ranked() {
        use crate::formats::incrs::InCrs;
        use crate::formats::operand::MatrixOperand;
        // the negotiated-sibling case is where a post-hoc recomputation
        // would disagree: the winner's ingest is a *credit* computed
        // against the operand's own params
        let mut r = Registry::new();
        r.register(Arc::new(InnerKernel::incrs(InCrsParams::default())));
        let a = uniform(32, 64, 0.1, 17);
        let b = uniform(64, 48, 0.1, 18);
        let params = InCrsParams { section: 64, block: 8 };
        let op = MatrixOperand::from(InCrs::from_csr_params(&b, params).unwrap());
        let (k, scores) = r.select_native_scored(&a, &b, Some(&op)).unwrap();
        assert_eq!(scores.cost_hint, k.cost_hint(&a, &b).total());
        assert_eq!(scores.ingest_cost, k.ingest_cost(&b, Some(&op)));
        assert!(scores.ingest_cost < 0.0, "winner must be the credited sibling");
        assert_eq!(scores.total(), scores.cost_hint + scores.ingest_cost);
        // scored and unscored selection agree on the winner everywhere
        let full = default_registry();
        let plain = full.select_native(&a, &b, Some(&op)).unwrap();
        let (scored, _) = full.select_native_scored(&a, &b, Some(&op)).unwrap();
        assert_eq!(
            (plain.format(), plain.algorithm()),
            (scored.format(), scored.algorithm())
        );
    }

    #[test]
    fn fast_gustavson_hint_undercuts_scalar_so_selection_never_picks_scalar() {
        let r = default_registry();
        let scalar = r.resolve(FormatKind::Csr, Algorithm::Gustavson).unwrap();
        let fast = r.resolve(FormatKind::Csr, Algorithm::GustavsonFast).unwrap();
        for (m, k, n, d) in [(64usize, 128usize, 64usize, 0.02), (200, 100, 50, 0.2)] {
            let a = uniform(m, k, d, 91);
            let b = uniform(k, n, d, 92);
            assert!(
                fast.cost_hint(&a, &b).total() < scalar.cost_hint(&a, &b).total(),
                "fast must undercut scalar on {m}x{k}x{n} @ {d}"
            );
        }
    }

    #[test]
    fn typed_resolution_errors() {
        let r = Registry::new();
        assert_eq!(
            r.resolve_or_err(FormatKind::Csr, Algorithm::Gustavson).unwrap_err(),
            EngineError::KernelUnavailable {
                format: Some(FormatKind::Csr),
                algorithm: Some(Algorithm::Gustavson),
            }
        );
        assert_eq!(
            r.select_or_err(&uniform(4, 4, 0.5, 1), &uniform(4, 4, 0.5, 2))
                .unwrap_err(),
            EngineError::KernelUnavailable { format: None, algorithm: None }
        );
        let full = default_registry();
        assert!(full.resolve_or_err(FormatKind::Csr, Algorithm::Tiled).is_ok());
    }

    #[test]
    fn shard_all_wraps_every_key_and_stays_correct() {
        let mut r = Registry::with_default_kernels(
            Geometry { block: 16, pairs: 32, slots: 16 },
            1,
        );
        let keys_before = r.keys();
        r.shard_all(crate::engine::ShardConfig { shards: 2, block: 16 });
        assert_eq!(r.keys(), keys_before, "sharding must not change the key space");
        let a = uniform(40, 50, 0.2, 13);
        let b = uniform(50, 30, 0.2, 14);
        let want = dense_ref(&a, &b);
        for k in r.kernels() {
            assert_eq!(k.name(), "sharded");
            let out = k.run(&a, &b).unwrap_or_else(|e| panic!("{e}"));
            assert!(out.c.max_abs_diff(&want) < 1e-3);
        }
        // idempotent: a second call must not nest wrappers
        let before = r.resolve(FormatKind::Csr, Algorithm::Gustavson).unwrap();
        r.shard_all(crate::engine::ShardConfig { shards: 2, block: 16 });
        let after = r.resolve(FormatKind::Csr, Algorithm::Gustavson).unwrap();
        assert!(Arc::ptr_eq(&before, &after), "shard_all re-wrapped a sharded kernel");
    }

    #[test]
    fn selection_is_nan_safe() {
        use super::super::kernel::{CostHint, EngineOutput, PreparedB};
        // total_cmp orders -NaN below every real number: without the score
        // clamp, one kernel returning NaN from its hint arithmetic could
        // win selection for the whole registry
        struct NanCostKernel;
        impl SpmmKernel for NanCostKernel {
            fn algorithm(&self) -> Algorithm {
                Algorithm::Gustavson
            }
            fn format(&self) -> FormatKind {
                FormatKind::Jad
            }
            fn name(&self) -> &'static str {
                "nan-cost"
            }
            fn cost_hint(&self, _a: &Csr, _b: &Csr) -> CostHint {
                CostHint { flops: -f64::NAN, prepare_words: 0.0 }
            }
            fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
                GustavsonKernel.prepare(b)
            }
            fn execute(&self, a: &Csr, b: &PreparedB) -> Result<EngineOutput, EngineError> {
                GustavsonKernel.execute(a, b)
            }
        }
        let a = uniform(24, 32, 0.2, 41);
        let b = uniform(32, 24, 0.2, 42);
        let mut r = default_registry();
        r.register(Arc::new(NanCostKernel));
        let k = r.select(&a, &b).unwrap();
        assert_ne!(k.name(), "nan-cost", "a NaN-scored kernel won selection");
        // with no finite-cost competition, selection still returns it
        // (demoted, not excluded) rather than panicking or yielding None
        let mut only = Registry::new();
        only.register(Arc::new(NanCostKernel));
        assert_eq!(only.select(&a, &b).unwrap().name(), "nan-cost");
    }

    #[test]
    fn register_replaces_same_key() {
        let mut r = Registry::new();
        let k1 = r.register(Arc::new(GustavsonKernel));
        let k2 = r.register(Arc::new(GustavsonKernel));
        assert_eq!(k1, k2);
        assert_eq!(r.len(), 1);
    }
}
