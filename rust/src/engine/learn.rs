//! Learned kernel selection: fit per-`(FormatKind, Algorithm)` scale
//! constants from serving observations and feed them back into
//! [`Registry::select_native`](super::Registry::select_native) live.
//!
//! The registry's static hints (`cost_hint + ingest_cost`) rank kernels in
//! *model units* — products touched, words moved — with hand-tuned factors
//! (the fast Gustavson 0.5× vectorization discount is the canonical
//! example). Every executed job logs the hint it was ranked on next to the
//! wall time it actually took (`Metrics::kernel_log`); this module closes
//! the loop:
//!
//! * [`FittedModel::fit`] — per-kernel least squares through the origin:
//!   `scale = Σ(x·y) / Σ(x²)` over `(x = hint, y = wall_us)`, the
//!   closed-form minimizer of `Σ(scale·x − y)²`. One constant per kernel
//!   is exactly the ROADMAP's "fit the constants" item: it converts each
//!   kernel's private cost units into commensurable microseconds, so
//!   selection compares predicted *time* instead of incomparable unit
//!   systems.
//! * [`CostModel`] — the live handle the registry consults. A refit
//!   [`publish`](CostModel::publish)es atomically; selection prices every
//!   candidate only when *all* of them are calibrated (otherwise it falls
//!   back to the static ranking, bit-for-bit the uncalibrated behavior).
//! * Hysteresis — [`CostModel::choose`] remembers the incumbent winner per
//!   coarse workload class and only switches when the challenger's
//!   predicted time beats the incumbent's by more than a configurable
//!   margin. Near-tied kernels therefore never flap across refits on
//!   timing noise.
//! * Persistence — [`FittedModel::to_text`]/[`from_text`](FittedModel::from_text)
//!   round-trip the model through a versioned plain-text file (f64 fields
//!   serialized as IEEE-754 bit patterns in hex, so the round-trip is
//!   bit-exact); a restarted server warm-loads instead of relearning from
//!   zero.
//!
//! Selection may change *which* kernel runs, never *what* it computes:
//! every registered kernel is oracle-checked, so routing is a pure
//! performance decision (`tests/prop_learn.rs` locks this).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::formats::traits::{FormatKind, SparseMatrix};
use crate::util::lock_unpoisoned;

use super::kernel::Algorithm;
use super::registry::KernelKey;

/// First line of every persisted model file. Bumped when the record layout
/// changes; a mismatched file is rejected, never misread.
pub const MODEL_FILE_VERSION: &str = "spmm-accel-cost-model v1";

/// Default hysteresis margin: a challenger must predict at least this
/// fractional win over the incumbent to take over a workload class.
pub const DEFAULT_MARGIN: f64 = 0.10;

/// Default minimum observations per kernel before a fit is trusted.
pub const DEFAULT_MIN_SAMPLES: usize = 8;

/// Incumbent workload classes remembered before the hysteresis table is
/// reset (bounds memory under adversarial shape churn).
const MAX_INCUMBENT_CLASSES: usize = 64;

/// One fitting datapoint: what selection predicted for a kernel vs the
/// wall time it measured. The coordinator derives these from
/// `KernelObservation`s (`predicted = cost_hint + ingest_cost` — exactly
/// the score `select_native` ranked).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sample {
    pub format: FormatKind,
    pub algorithm: Algorithm,
    /// The ranked score, in the kernel's own cost units.
    pub predicted: f64,
    /// Measured execute wall time, microseconds.
    pub wall_us: u64,
}

/// One kernel's fitted constant: `scale` converts its raw score into
/// predicted microseconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Calibration {
    /// Microseconds per raw cost unit (always finite and positive).
    pub scale: f64,
    /// Observations the fit used.
    pub samples: u64,
    /// Mean |predicted − measured| over those observations, microseconds —
    /// the per-kernel calibration error surfaced in metrics.
    pub mean_abs_err_us: f64,
}

/// Model-file I/O and parse failures.
#[derive(Clone, Debug, PartialEq)]
pub enum ModelError {
    Io(String),
    Parse { line: usize, detail: String },
}

impl std::fmt::Display for ModelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelError::Io(detail) => write!(f, "model file io: {detail}"),
            ModelError::Parse { line, detail } => {
                write!(f, "model file parse (line {line}): {detail}")
            }
        }
    }
}

impl std::error::Error for ModelError {}

/// A fitted set of per-kernel calibrations. Immutable snapshot semantics:
/// refits build a fresh model and [`CostModel::publish`] swaps it in.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FittedModel {
    entries: BTreeMap<KernelKey, Calibration>,
}

impl FittedModel {
    pub fn new() -> FittedModel {
        FittedModel::default()
    }

    /// Least squares through the origin, per kernel key: the `scale`
    /// minimizing `Σ(scale·x − y)²` is `Σ(x·y) / Σ(x²)`. Samples with a
    /// non-finite or non-positive predicted score are skipped (a score of
    /// zero carries no information about the constant), and a key is only
    /// calibrated once it has `min_samples` usable observations *and* the
    /// fitted scale is finite and positive — all-zero walls (sub-µs
    /// kernels below timer resolution) therefore stay uncalibrated rather
    /// than predicting that everything is free.
    pub fn fit(samples: &[Sample], min_samples: usize) -> FittedModel {
        struct Acc {
            sum_xy: f64,
            sum_xx: f64,
            n: u64,
        }
        let mut accs: BTreeMap<KernelKey, Acc> = BTreeMap::new();
        // explicit accumulation order: samples in slice order (D2)
        for s in samples {
            if !s.predicted.is_finite() || s.predicted <= 0.0 {
                continue;
            }
            let acc = accs
                .entry((s.format, s.algorithm))
                .or_insert_with(|| Acc { sum_xy: 0.0, sum_xx: 0.0, n: 0 });
            acc.sum_xy += s.predicted * s.wall_us as f64;
            acc.sum_xx += s.predicted * s.predicted;
            acc.n += 1;
        }
        let mut entries = BTreeMap::new();
        for (key, acc) in &accs {
            if acc.n < min_samples.max(1) as u64 || acc.sum_xx <= 0.0 {
                continue;
            }
            let scale = acc.sum_xy / acc.sum_xx;
            if !scale.is_finite() || scale <= 0.0 {
                continue;
            }
            let mut abs_err = 0.0f64;
            for s in samples {
                if (s.format, s.algorithm) != *key
                    || !s.predicted.is_finite()
                    || s.predicted <= 0.0
                {
                    continue;
                }
                abs_err += (scale * s.predicted - s.wall_us as f64).abs();
            }
            entries.insert(
                *key,
                Calibration {
                    scale,
                    samples: acc.n,
                    mean_abs_err_us: abs_err / acc.n as f64,
                },
            );
        }
        FittedModel { entries }
    }

    pub fn get(&self, key: KernelKey) -> Option<Calibration> {
        self.entries.get(&key).copied()
    }

    /// Insert (or replace) one calibration — test and tooling surface; the
    /// serving path builds models through [`FittedModel::fit`].
    pub fn insert(&mut self, key: KernelKey, cal: Calibration) {
        self.entries.insert(key, cal);
    }

    /// Calibrated entries in key order.
    pub fn entries(&self) -> impl Iterator<Item = (&KernelKey, &Calibration)> {
        self.entries.iter()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Predicted wall time (µs) for a kernel's raw score, if calibrated.
    pub fn predict_us(&self, key: KernelKey, raw_score: f64) -> Option<f64> {
        self.get(key).map(|c| c.scale * raw_score)
    }

    /// Versioned plain-text rendering. Each record stores its f64 fields
    /// as IEEE-754 bit patterns in hex so [`FittedModel::from_text`]
    /// reproduces them bit-exactly; the trailing `#` comment is a
    /// human-readable gloss the parser ignores.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str(MODEL_FILE_VERSION);
        out.push('\n');
        out.push_str("# <format> <algorithm> <scale:f64-bits-hex> <samples> <err:f64-bits-hex>\n");
        for ((format, algorithm), c) in &self.entries {
            out.push_str(&format!(
                "{} {} {:016x} {} {:016x} # scale~{:.3e} us/unit, err~{:.1} us\n",
                format.name(),
                algorithm.name(),
                c.scale.to_bits(),
                c.samples,
                c.mean_abs_err_us.to_bits(),
                c.scale,
                c.mean_abs_err_us,
            ));
        }
        out
    }

    /// Parse [`FittedModel::to_text`] output. The first non-empty,
    /// non-comment line must be [`MODEL_FILE_VERSION`]; every malformed
    /// record is a typed error (a stale or corrupted model is rejected
    /// whole, never half-loaded).
    pub fn from_text(text: &str) -> Result<FittedModel, ModelError> {
        let mut entries = BTreeMap::new();
        let mut version_seen = false;
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = match raw.split('#').next() {
                Some(l) => l.trim(),
                None => "",
            };
            if line.is_empty() {
                continue;
            }
            if !version_seen {
                if line != MODEL_FILE_VERSION {
                    return Err(ModelError::Parse {
                        line: lineno,
                        detail: format!("expected version header `{MODEL_FILE_VERSION}`, got `{line}`"),
                    });
                }
                version_seen = true;
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            if toks.len() != 5 {
                return Err(ModelError::Parse {
                    line: lineno,
                    detail: format!(
                        "expected `<format> <algorithm> <scale> <samples> <err>`, got {} fields",
                        toks.len()
                    ),
                });
            }
            let parse_err = |detail: String| ModelError::Parse { line: lineno, detail };
            let format = FormatKind::parse(toks[0]).map_err(|e| parse_err(e.to_string()))?;
            let algorithm = Algorithm::parse(toks[1]).map_err(|e| parse_err(e.to_string()))?;
            let scale = u64::from_str_radix(toks[2], 16)
                .map(f64::from_bits)
                .map_err(|e| parse_err(format!("scale bits: {e}")))?;
            let samples = toks[3]
                .parse::<u64>()
                .map_err(|e| parse_err(format!("samples: {e}")))?;
            let mean_abs_err_us = u64::from_str_radix(toks[4], 16)
                .map(f64::from_bits)
                .map_err(|e| parse_err(format!("err bits: {e}")))?;
            entries.insert(
                (format, algorithm),
                Calibration { scale, samples, mean_abs_err_us },
            );
        }
        if !version_seen {
            return Err(ModelError::Parse {
                line: 1,
                detail: format!("empty model file (expected `{MODEL_FILE_VERSION}`)"),
            });
        }
        Ok(FittedModel { entries })
    }

    pub fn save(&self, path: &Path) -> Result<(), ModelError> {
        std::fs::write(path, self.to_text())
            .map_err(|e| ModelError::Io(format!("{}: {e}", path.display())))
    }

    pub fn load(path: &Path) -> Result<FittedModel, ModelError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ModelError::Io(format!("{}: {e}", path.display())))?;
        FittedModel::from_text(&text)
    }
}

#[derive(Debug, Default)]
struct CostModelState {
    fitted: FittedModel,
    /// Workload class → the kernel currently winning it (hysteresis
    /// memory; survives refits, which is what damps flapping).
    incumbents: BTreeMap<u64, KernelKey>,
    publishes: u64,
    switches: u64,
}

/// The live fitted-selection handle: cloneable, shared between the refit
/// loop (publisher) and every per-worker registry (consumers). One short
/// lock per selection and per refit — off every per-row hot path.
#[derive(Clone, Debug, Default)]
pub struct CostModel {
    state: Arc<Mutex<CostModelState>>,
    margin: f64,
}

impl CostModel {
    /// `margin` is the hysteresis knob: the fractional predicted win a
    /// challenger needs before it displaces an incumbent (clamped to
    /// ≥ 0; 0 = switch on any strict improvement).
    pub fn new(margin: f64) -> CostModel {
        CostModel {
            state: Arc::new(Mutex::new(CostModelState::default())),
            margin: margin.max(0.0),
        }
    }

    pub fn margin(&self) -> f64 {
        self.margin
    }

    /// Swap in a freshly fitted model. Incumbents are kept: a refit alone
    /// never changes selection unless the new predictions clear the
    /// hysteresis margin.
    pub fn publish(&self, fitted: FittedModel) {
        let mut state = lock_unpoisoned(&self.state);
        state.fitted = fitted;
        state.publishes += 1;
    }

    /// Snapshot of the current fitted model.
    pub fn fitted(&self) -> FittedModel {
        lock_unpoisoned(&self.state).fitted.clone()
    }

    /// Models published so far (warm-load included).
    pub fn publishes(&self) -> u64 {
        lock_unpoisoned(&self.state).publishes
    }

    /// Incumbent changes so far — the flap count hysteresis bounds.
    pub fn switches(&self) -> u64 {
        lock_unpoisoned(&self.state).switches
    }

    /// Pick among `scored` candidates (`(key, NaN-clamped raw score)`) for
    /// one workload class. Returns the chosen index only when every
    /// candidate is calibrated — partial calibration falls back to the
    /// caller's static ranking (`None`), so a half-learned model can never
    /// compare fitted µs against unfitted model units.
    pub fn choose(&self, class: u64, scored: &[(KernelKey, f64)]) -> Option<usize> {
        if scored.is_empty() {
            return None;
        }
        let mut state = lock_unpoisoned(&self.state);
        if state.fitted.is_empty() {
            return None;
        }
        let mut predicted: Vec<f64> = Vec::with_capacity(scored.len());
        for (key, raw) in scored {
            let cal = state.fitted.get(*key)?;
            let p = cal.scale * raw;
            predicted.push(if p.is_nan() { f64::INFINITY } else { p });
        }
        // same argmin convention as the registry's static path (min_by:
        // last minimum wins ties), total-ordered and deterministic
        let best = match (0..predicted.len())
            .min_by(|&x, &y| predicted[x].total_cmp(&predicted[y]))
        {
            Some(i) => i,
            None => return None,
        };
        let chosen = match state.incumbents.get(&class).copied() {
            Some(inc_key) if inc_key != scored[best].0 => {
                // cheapest candidate still carrying the incumbent key (a
                // negotiated sibling competes under its parent's key)
                let mut inc_best: Option<usize> = None;
                for (i, (key, _)) in scored.iter().enumerate() {
                    let better = match inc_best {
                        Some(j) => predicted[i].total_cmp(&predicted[j]).is_lt(),
                        None => true,
                    };
                    if *key == inc_key && better {
                        inc_best = Some(i);
                    }
                }
                match inc_best {
                    // incumbent left the candidate set: hand over
                    None => best,
                    Some(i) => {
                        let win_bar = predicted[i] * (1.0 - self.margin);
                        if predicted[best].total_cmp(&win_bar).is_lt() {
                            best
                        } else {
                            i
                        }
                    }
                }
            }
            _ => best,
        };
        let chosen_key = scored[chosen].0;
        if state.incumbents.get(&class) != Some(&chosen_key) {
            if state.incumbents.contains_key(&class) {
                state.switches += 1;
            } else if state.incumbents.len() >= MAX_INCUMBENT_CLASSES {
                state.incumbents.clear();
            }
            state.incumbents.insert(class, chosen_key);
        }
        Some(chosen)
    }
}

/// Coarse workload-class signature for hysteresis: log2 buckets of the
/// operand dimensions and populations, packed. Workloads in the same
/// bucket share one incumbent; a different shape regime gets its own.
pub fn workload_class(a: &crate::formats::csr::Csr, b: &crate::formats::csr::Csr) -> u64 {
    fn lg(x: usize) -> u64 {
        (usize::BITS - x.max(1).leading_zeros()) as u64
    }
    (lg(a.rows()) << 24) | (lg(a.nnz()) << 16) | (lg(b.cols()) << 8) | lg(b.nnz())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key_fast() -> KernelKey {
        (FormatKind::Csr, Algorithm::GustavsonFast)
    }

    fn key_tiled() -> KernelKey {
        (FormatKind::Csr, Algorithm::Tiled)
    }

    fn planted(key: KernelKey, scale: f64, n: usize) -> Vec<Sample> {
        let mut out = Vec::new();
        for i in 0..n {
            let x = 1.0e4 * (i + 1) as f64;
            out.push(Sample {
                format: key.0,
                algorithm: key.1,
                predicted: x,
                wall_us: (scale * x).round() as u64,
            });
        }
        out
    }

    #[test]
    fn fit_recovers_a_planted_constant() {
        let samples = planted(key_fast(), 2.5e-3, 32);
        let m = FittedModel::fit(&samples, 8);
        let cal = m.get(key_fast()).unwrap();
        assert!((cal.scale - 2.5e-3).abs() / 2.5e-3 < 0.02, "{cal:?}");
        assert_eq!(cal.samples, 32);
        assert!(cal.mean_abs_err_us < 1.0, "{cal:?}");
    }

    #[test]
    fn fit_skips_sparse_degenerate_and_unusable_keys() {
        let mut samples = planted(key_fast(), 1.0e-3, 4); // below min_samples
        samples.extend(planted(key_tiled(), 0.0, 16)); // all-zero walls
        samples.push(Sample {
            format: FormatKind::Csc,
            algorithm: Algorithm::OuterProduct,
            predicted: f64::NAN,
            wall_us: 10,
        });
        let m = FittedModel::fit(&samples, 8);
        assert!(m.is_empty(), "{m:?}");
    }

    #[test]
    fn text_round_trip_is_bit_exact() {
        let mut m = FittedModel::new();
        m.insert(
            key_fast(),
            Calibration { scale: 1.0 / 3.0, samples: 17, mean_abs_err_us: 0.1 + 0.2 },
        );
        m.insert(
            (FormatKind::Csc, Algorithm::OuterProduct),
            Calibration { scale: 7.25e-9, samples: 4096, mean_abs_err_us: 1234.5 },
        );
        let text = m.to_text();
        let back = FittedModel::from_text(&text).unwrap();
        assert_eq!(back, m);
        for (key, cal) in m.entries() {
            let b = back.get(*key).unwrap();
            assert_eq!(b.scale.to_bits(), cal.scale.to_bits());
            assert_eq!(b.mean_abs_err_us.to_bits(), cal.mean_abs_err_us.to_bits());
        }
    }

    #[test]
    fn malformed_model_files_are_rejected_whole() {
        assert!(matches!(
            FittedModel::from_text(""),
            Err(ModelError::Parse { line: 1, .. })
        ));
        assert!(FittedModel::from_text("some-other-header v9\n").is_err());
        let truncated = format!("{MODEL_FILE_VERSION}\ncsr gustavson-fast 3f00\n");
        assert!(FittedModel::from_text(&truncated).is_err());
        let bad_alg = format!("{MODEL_FILE_VERSION}\ncsr warp 0 1 0\n");
        assert!(FittedModel::from_text(&bad_alg).is_err());
        // comments and blank lines are fine
        let ok = format!("{MODEL_FILE_VERSION}\n\n# a comment\n");
        assert_eq!(FittedModel::from_text(&ok).unwrap(), FittedModel::new());
    }

    #[test]
    fn choose_requires_full_calibration() {
        let model = CostModel::new(0.1);
        let scored = vec![(key_fast(), 100.0), (key_tiled(), 50.0)];
        // empty model: static fallback
        assert_eq!(model.choose(1, &scored), None);
        let mut m = FittedModel::new();
        m.insert(key_fast(), Calibration { scale: 1.0, samples: 8, mean_abs_err_us: 0.0 });
        model.publish(m.clone());
        // partially calibrated: still static
        assert_eq!(model.choose(1, &scored), None);
        m.insert(key_tiled(), Calibration { scale: 1.0, samples: 8, mean_abs_err_us: 0.0 });
        model.publish(m);
        assert_eq!(model.choose(1, &scored), Some(1));
    }

    #[test]
    fn hysteresis_keeps_the_incumbent_inside_the_margin() {
        let model = CostModel::new(0.25);
        let mut m = FittedModel::new();
        m.insert(key_fast(), Calibration { scale: 1.0, samples: 8, mean_abs_err_us: 0.0 });
        m.insert(key_tiled(), Calibration { scale: 1.0, samples: 8, mean_abs_err_us: 0.0 });
        model.publish(m.clone());
        // fast wins class 7 and becomes incumbent
        assert_eq!(model.choose(7, &[(key_fast(), 10.0), (key_tiled(), 20.0)]), Some(0));
        // refit: tiled now predicts 10% cheaper — inside the 25% margin,
        // the incumbent holds, across repeated selections and republishes
        for _ in 0..5 {
            model.publish(m.clone());
            assert_eq!(
                model.choose(7, &[(key_fast(), 10.0), (key_tiled(), 9.0)]),
                Some(0)
            );
        }
        assert_eq!(model.switches(), 0);
        // a 50% win clears the margin: exactly one switch, then stable
        for _ in 0..5 {
            assert_eq!(
                model.choose(7, &[(key_fast(), 10.0), (key_tiled(), 5.0)]),
                Some(1)
            );
        }
        assert_eq!(model.switches(), 1);
        // a different workload class has its own incumbent
        assert_eq!(model.choose(8, &[(key_fast(), 10.0), (key_tiled(), 9.0)]), Some(1));
        assert_eq!(model.switches(), 1);
    }

    #[test]
    fn workload_class_buckets_by_magnitude() {
        use crate::datasets::synth::uniform;
        let a1 = uniform(64, 64, 0.1, 1);
        let a2 = uniform(64, 64, 0.1, 2); // same regime, different values
        let b = uniform(64, 32, 0.1, 3);
        assert_eq!(workload_class(&a1, &b), workload_class(&a2, &b));
        let big = uniform(512, 64, 0.1, 4);
        assert_ne!(workload_class(&a1, &b), workload_class(&big, &b));
    }
}
