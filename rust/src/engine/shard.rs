//! Sharded row-band execution: one SpMM job split into contiguous row
//! bands, run on channel-connected shard workers, merged without any
//! cross-shard reduction — the software analogue of the paper's mesh
//! splitting the output grid across PEs that share input bands.
//!
//! # Invariants
//!
//! * **Contiguous, block-aligned bands.** [`ShardPlanner`] cuts A's rows
//!   into contiguous bands whose boundaries are multiples of
//!   [`ShardConfig::block`], weighted by per-block-row tile-pair counts
//!   ([`crate::spmm::blocks::block_row_pair_weights`]) — the same
//!   weighted-contiguous-partition heuristic `engine::tiled` uses for its
//!   worker chunks.
//! * **No cross-shard reduction.** Output rows belong to exactly one band,
//!   so [the merge](execute) is a pure row copy. Every reduction (the
//!   K-sum per output cell) happens *inside* one shard, in the wrapped
//!   kernel's own deterministic order.
//! * **Bit-reproducibility.** Every registered kernel is
//!   *row-decomposable*: executing a block-aligned row band of A produces
//!   exactly the bits the full run produces for those rows. Scalar kernels
//!   (dense, Gustavson, inner) reduce per output row in A-row order; the
//!   tiled executor reduces per output tile in ascending K order; the
//!   accelerator plan chunks dispatches within (never across) output block
//!   rows (`spmm::plan`). Hence merged shard output == unsharded output,
//!   bit for bit, at any shard count. The executor enforces the alignment
//!   precondition itself: the effective band alignment is
//!   `lcm(ShardConfig::block, kernel.band_alignment())`, so a blocked
//!   kernel whose tile size disagrees with the requested block (e.g. a
//!   PJRT manifest geometry) still shards bit-identically.
//!
//! # Topology
//!
//! Band delivery lives behind [`ShardTransport`]
//! (`engine::transport`): [`execute`] runs today's in-process
//! channel-connected threads ([`InProcess`]), and [`execute_with`] accepts
//! any transport — notably the socket transport
//! (`engine::remote::SocketTransport`), which ships the same band slices
//! as length-prefixed wire frames to `worker` processes, replicates the
//! shared `PreparedB` by content fingerprint, and survives worker loss by
//! resubmitting only the lost bands. In-process, a shard worker that
//! panics is detected as a lost reply and surfaces as
//! [`EngineError::ExecFailed`] on the job, never as a poisoned server
//! worker. Planning and merging are transport-blind: the merge is a pure
//! row copy, so *where* a band ran can never change the bits.

use std::sync::Arc;
use std::time::Duration;

use crate::formats::csr::Csr;
use crate::formats::dense::Dense;
use crate::formats::traits::{FormatKind, SparseMatrix};
use crate::spmm::blocks::block_row_pair_weights;

use super::error::EngineError;
use super::kernel::{
    Algorithm, CostHint, EngineOutput, ExecStats, PreparedB, SpmmKernel,
};
use super::tiled::partition_by_weight;
use super::transport::{content_key, BandJob, InProcess, ShardTransport, TransportCounters};

/// Sharding policy: how many row bands, and the band alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardConfig {
    /// Number of row-band shards (1 = one band covering every row; the
    /// planner may produce fewer bands than requested when A has fewer
    /// block rows).
    pub shards: usize,
    /// Requested band boundary alignment. [`execute`] rounds this up to
    /// the least common multiple with the kernel's own
    /// [`SpmmKernel::band_alignment`], so bands never cut inside a
    /// blocked kernel's tile even when the two disagree (e.g. a PJRT
    /// manifest block differing from the server geometry).
    pub block: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig { shards: 1, block: 32 }
    }
}

/// One planned row band: `rows.0 .. rows.1` of A (and of the output).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardBand {
    pub shard: usize,
    /// `[lo, hi)` output rows. `lo` is block-aligned; `hi` is the next
    /// band's `lo` (or A's row count for the last band).
    pub rows: (usize, usize),
    /// Estimated tile pairs in this band (the partition weight).
    pub weight: usize,
}

/// A job's shard decomposition: contiguous bands covering every row once.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub bands: Vec<ShardBand>,
    pub rows: usize,
}

impl ShardPlan {
    /// Total estimated tile pairs across all bands.
    pub fn total_weight(&self) -> usize {
        self.bands.iter().map(|b| b.weight).sum()
    }
}

/// Cuts a job's rows into weighted contiguous row-band shards.
pub struct ShardPlanner;

impl ShardPlanner {
    /// Plan `cfg.shards` bands over A's block rows. When `b` is available
    /// the weights are exact per-block-row tile-pair counts; otherwise
    /// (e.g. wrapping a kernel whose prepared operand is not CSR) the
    /// fallback weight is A's per-block-row nnz — a coarser balance with
    /// the identical bit-reproducibility (band cuts only move work between
    /// shards, never reorder a reduction).
    pub fn plan(a: &Csr, b: Option<&Csr>, cfg: ShardConfig) -> ShardPlan {
        let block = cfg.block.max(1);
        let rows = a.rows();
        let grid_rows = (rows + block - 1) / block;
        let weights: Vec<usize> = match b {
            Some(b) => block_row_pair_weights(a, b, block),
            None => (0..grid_rows)
                .map(|bi| {
                    let lo = bi * block;
                    let hi = (lo + block).min(rows);
                    (a.row_ptr[hi] - a.row_ptr[lo]) as usize
                })
                .collect(),
        };
        let bounds = partition_by_weight(&weights, cfg.shards.max(1));
        let bands = bounds
            .iter()
            .enumerate()
            .map(|(shard, &(blo, bhi))| ShardBand {
                shard,
                rows: (blo * block, (bhi * block).min(rows)),
                weight: weights[blo..bhi].iter().sum(),
            })
            .collect();
        ShardPlan { bands, rows }
    }
}

/// Per-shard accounting, surfaced through the coordinator's shard metrics.
#[derive(Clone, Copy, Debug)]
pub struct ShardStat {
    pub shard: usize,
    pub rows: (usize, usize),
    /// Task send → worker dequeue (the shard queue wait).
    pub queue: Duration,
    /// Kernel execute wall time on the shard worker.
    pub wall: Duration,
    pub stats: ExecStats,
}

/// A sharded run's result: the merged product, summed accounting, the
/// per-shard breakdown, and the transport's delivery counters (all zero
/// for in-process runs).
#[derive(Debug)]
pub struct ShardOutput {
    pub c: Dense,
    pub stats: ExecStats,
    pub shards: Vec<ShardStat>,
    pub counters: TransportCounters,
}

fn lcm(x: usize, y: usize) -> usize {
    fn gcd(mut a: usize, mut b: usize) -> usize {
        while b != 0 {
            (a, b) = (b, a % b);
        }
        a
    }
    x / gcd(x, y) * y
}

/// Run `C = A × B` sharded over the in-process transport: plan row bands,
/// execute each band's `kernel.execute` on its own channel-connected
/// worker against the shared `prepared` operand, and stitch the band
/// outputs back row-for-row.
///
/// `b` feeds the planner's weight heuristic; pass the job's CSR `B` when
/// available (the planner falls back to the `prepared` operand's CSR, then
/// to A-only nnz weights). A panicked shard worker yields
/// [`EngineError::ExecFailed`] naming the lost shards; the caller's thread
/// is never poisoned.
pub fn execute(
    kernel: &dyn SpmmKernel,
    a: &Csr,
    b: Option<&Csr>,
    prepared: &PreparedB,
    cfg: ShardConfig,
) -> Result<ShardOutput, EngineError> {
    execute_with(&InProcess, kernel, a, b, prepared, cfg)
}

/// [`execute`] over an explicit [`ShardTransport`] — the socket transport
/// here ships each band to a remote `worker` process and the output stays
/// bit-identical, because planning and the row-copy merge never leave this
/// function.
pub fn execute_with(
    transport: &dyn ShardTransport,
    kernel: &dyn SpmmKernel,
    a: &Csr,
    b: Option<&Csr>,
    prepared: &PreparedB,
    cfg: ShardConfig,
) -> Result<ShardOutput, EngineError> {
    execute_with_deadline(transport, kernel, a, b, prepared, cfg, None)
}

/// [`execute_with`] carrying the submitting job's absolute deadline: the
/// socket transport caps each band attempt's timeout at the remaining
/// budget, so a remote band can never out-wait the job that asked for it.
/// `None` (and any in-process run) behaves exactly as [`execute_with`].
#[allow(clippy::too_many_arguments)]
pub fn execute_with_deadline(
    transport: &dyn ShardTransport,
    kernel: &dyn SpmmKernel,
    a: &Csr,
    b: Option<&Csr>,
    prepared: &PreparedB,
    cfg: ShardConfig,
    deadline: Option<std::time::Instant>,
) -> Result<ShardOutput, EngineError> {
    let (b_rows, b_cols) = prepared.shape();
    if a.cols() != b_rows {
        return Err(EngineError::ShapeMismatch {
            a: a.shape(),
            b: (b_rows, b_cols),
        });
    }
    // `strict-invariants` builds validate operands entering the shard
    // executor (no-op otherwise — see `formats::strict_check`)
    crate::formats::strict_check("shard::execute(A)", || a.validate_invariants());
    if let Some(b) = b {
        crate::formats::strict_check("shard::execute(B)", || b.validate_invariants());
    }
    let b_struct: Option<&Csr> = match (b, prepared) {
        (Some(b), _) => Some(b),
        (None, PreparedB::Csr(m)) => Some(m.as_ref()),
        // blocked/pooled operands carry their canonical CSR source: exact
        // tile-pair weights even when wrapping a blocked or pooled kernel
        (None, PreparedB::Blocked(bb)) => Some(bb.src.as_ref()),
        (None, PreparedB::Pooled(pb)) => Some(pb.src.as_ref()),
        (None, PreparedB::OuterPooled(ob)) => Some(ob.src.as_ref()),
        (None, _) => None,
    };
    // bands must never cut inside the kernel's own tile rows — round the
    // requested alignment up to a common multiple (the bit-reproducibility
    // precondition, enforced here rather than trusted from the caller)
    let cfg = ShardConfig {
        shards: cfg.shards,
        block: lcm(cfg.block.max(1), kernel.band_alignment().max(1)),
    };
    let plan = ShardPlanner::plan(a, b_struct, cfg);
    let (m, n) = (a.rows(), b_cols);
    if plan.bands.is_empty() {
        return Ok(ShardOutput {
            c: Dense::zeros(m, n),
            stats: ExecStats::default(),
            shards: Vec::new(),
            counters: TransportCounters::default(),
        });
    }

    let key = content_key(kernel, prepared, b_struct);
    let run = transport.run(&BandJob {
        kernel,
        a,
        prepared,
        plan: &plan,
        key,
        deadline,
    })?;

    // every planned band must come back exactly once, whatever route (or
    // retry) it took — the transport contract, re-checked here because a
    // hole in the output grid is silent data loss
    let mut results = run.bands;
    results.sort_by_key(|r| r.shard);
    let complete = results.len() == plan.bands.len()
        && results
            .iter()
            .zip(&plan.bands)
            .all(|(r, band)| r.shard == band.shard && r.rows == band.rows);
    if !complete {
        let got: Vec<usize> = results.iter().map(|r| r.shard).collect();
        return Err(EngineError::ExecFailed(format!(
            "transport {:?} returned bands {got:?} for a {}-band plan",
            transport.name(),
            plan.bands.len()
        )));
    }

    let mut c = Dense::zeros(m, n);
    let mut total = ExecStats::default();
    let mut shard_stats = Vec::with_capacity(results.len());
    for result in results {
        let out = result.output;
        let (lo, hi) = result.rows;
        debug_assert_eq!(out.c.shape(), (hi - lo, n));
        // the merge: a pure row copy — no reduction crosses a shard
        c.data[lo * n..hi * n].copy_from_slice(&out.c.data);
        total.dispatches += out.stats.dispatches;
        total.real_pairs += out.stats.real_pairs;
        total.padded_pairs += out.stats.padded_pairs;
        total.macs_issued += out.stats.macs_issued;
        total.threads += out.stats.threads;
        shard_stats.push(ShardStat {
            shard: result.shard,
            rows: result.rows,
            queue: result.queue,
            wall: result.wall,
            stats: out.stats,
        });
    }
    Ok(ShardOutput {
        c,
        stats: total,
        shards: shard_stats,
        counters: run.counters,
    })
}

/// Any [`SpmmKernel`] behind the sharded executor, itself an `SpmmKernel`:
/// `registry.register(Arc::new(ShardedKernel::wrap(inner, cfg)))` replaces
/// the inner kernel's `(format, algorithm)` key, so every consumer of that
/// key — server workers, CLI, benches — transparently runs sharded.
pub struct ShardedKernel {
    inner: Arc<dyn SpmmKernel>,
    cfg: ShardConfig,
    transport: Arc<dyn ShardTransport>,
}

impl ShardedKernel {
    /// Wrap over the in-process transport (PR 3 behavior, unchanged).
    pub fn wrap(inner: Arc<dyn SpmmKernel>, cfg: ShardConfig) -> ShardedKernel {
        ShardedKernel::wrap_with(inner, cfg, Arc::new(InProcess))
    }

    /// Wrap over an explicit transport — pass the socket transport here
    /// and every consumer of the kernel's registry key runs its bands on
    /// remote workers, bit-identically.
    pub fn wrap_with(
        inner: Arc<dyn SpmmKernel>,
        cfg: ShardConfig,
        transport: Arc<dyn ShardTransport>,
    ) -> ShardedKernel {
        ShardedKernel { inner, cfg, transport }
    }

    pub fn config(&self) -> ShardConfig {
        self.cfg
    }

    pub fn inner(&self) -> &Arc<dyn SpmmKernel> {
        &self.inner
    }

    pub fn transport(&self) -> &Arc<dyn ShardTransport> {
        &self.transport
    }
}

impl SpmmKernel for ShardedKernel {
    fn algorithm(&self) -> Algorithm {
        self.inner.algorithm()
    }
    fn format(&self) -> FormatKind {
        self.inner.format()
    }
    fn name(&self) -> &'static str {
        "sharded"
    }
    fn cost_hint(&self, a: &Csr, b: &Csr) -> CostHint {
        self.inner.cost_hint(a, b)
    }
    fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
        self.inner.prepare(b)
    }
    fn prepare_shared(&self, b: &Arc<Csr>) -> Result<PreparedB, EngineError> {
        self.inner.prepare_shared(b)
    }
    fn prepare_is_trivial(&self) -> bool {
        self.inner.prepare_is_trivial()
    }
    fn prepare_operand(
        &self,
        native: &crate::formats::operand::MatrixOperand,
        b: &Arc<Csr>,
    ) -> Result<PreparedB, EngineError> {
        self.inner.prepare_operand(native, b)
    }
    fn ingest_cost(
        &self,
        b: &Csr,
        native: Option<&crate::formats::operand::MatrixOperand>,
    ) -> f64 {
        self.inner.ingest_cost(b, native)
    }
    /// Delegate negotiation, then re-wrap: a sibling the inner kernel
    /// offers for this operand must keep running sharded at this config.
    fn negotiate(
        &self,
        native: &crate::formats::operand::MatrixOperand,
    ) -> Option<Arc<dyn SpmmKernel>> {
        let sibling = self.inner.negotiate(native)?;
        Some(Arc::new(ShardedKernel::wrap_with(
            sibling,
            self.cfg,
            Arc::clone(&self.transport),
        )))
    }
    fn band_alignment(&self) -> usize {
        self.inner.band_alignment()
    }
    fn observe_model(&self, model: &crate::engine::learn::CostModel) {
        self.inner.observe_model(model)
    }
    fn execute(&self, a: &Csr, b: &PreparedB) -> Result<EngineOutput, EngineError> {
        let out = execute_with(self.transport.as_ref(), self.inner.as_ref(), a, None, b, self.cfg)?;
        Ok(EngineOutput { c: out.c, stats: out.stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::engine::kernels::{GustavsonKernel, TiledKernel};
    use crate::engine::tiled::TiledConfig;
    use crate::engine::Registry;
    use crate::spmm::plan::Geometry;

    fn bits(c: &Dense) -> Vec<u32> {
        c.bit_pattern()
    }

    #[test]
    fn planner_bands_are_contiguous_aligned_and_cover_all_rows() {
        let a = uniform(70, 90, 0.1, 1);
        let b = uniform(90, 40, 0.1, 2);
        for shards in [1usize, 2, 3, 5, 8, 64] {
            let plan = ShardPlanner::plan(&a, Some(&b), ShardConfig { shards, block: 16 });
            assert!(!plan.bands.is_empty());
            assert!(plan.bands.len() <= shards.max(1));
            assert_eq!(plan.bands[0].rows.0, 0, "shards={shards}");
            assert_eq!(plan.bands.last().unwrap().rows.1, 70);
            for w in plan.bands.windows(2) {
                assert_eq!(w[0].rows.1, w[1].rows.0, "gap at shards={shards}");
            }
            for band in &plan.bands {
                assert_eq!(band.rows.0 % 16, 0, "unaligned band start");
                assert!(band.rows.1 > band.rows.0, "empty band");
            }
        }
    }

    #[test]
    fn planner_weights_balance_roughly() {
        let a = uniform(128, 128, 0.2, 3);
        let b = uniform(128, 64, 0.2, 4);
        let plan = ShardPlanner::plan(&a, Some(&b), ShardConfig { shards: 4, block: 16 });
        let total = plan.total_weight();
        assert!(total > 0);
        assert_eq!(plan.bands.len(), 4, "dense 8-block-row input must fill 4 bands");
        // greedy prefix cuts overshoot the ideal share by at most one
        // block row's weight
        let max_row_w = block_row_pair_weights(&a, &b, 16)
            .into_iter()
            .max()
            .unwrap();
        for band in &plan.bands {
            assert!(
                band.weight <= total / plan.bands.len() + max_row_w,
                "band dwarfs its share: {band:?} (total {total}, max row {max_row_w})"
            );
        }
        assert_eq!(
            plan.bands.iter().map(|b| b.weight).sum::<usize>(),
            total
        );
    }

    #[test]
    fn sharded_gustavson_is_bit_identical_to_unsharded() {
        let k = GustavsonKernel;
        let a = uniform(60, 80, 0.15, 5);
        let b = uniform(80, 44, 0.15, 6);
        let prepared = k.prepare(&b).unwrap();
        let want = bits(&k.execute(&a, &prepared).unwrap().c);
        for shards in [1usize, 2, 3, 5, 8] {
            let out = execute(&k, &a, Some(&b), &prepared, ShardConfig { shards, block: 16 })
                .unwrap();
            assert_eq!(bits(&out.c), want, "{shards} shards diverge");
            assert_eq!(out.shards.len(), out.stats.threads);
        }
    }

    #[test]
    fn sharded_tiled_conserves_pair_counts() {
        let k = TiledKernel::new(TiledConfig { block: 16, workers: 2 });
        let a = uniform(96, 64, 0.2, 7);
        let b = uniform(64, 48, 0.2, 8);
        let prepared = k.prepare(&b).unwrap();
        let whole = k.execute(&a, &prepared).unwrap();
        let out = execute(&k, &a, Some(&b), &prepared, ShardConfig { shards: 4, block: 16 })
            .unwrap();
        assert_eq!(bits(&out.c), bits(&whole.c));
        // bands partition the tile pairs exactly
        assert_eq!(out.stats.real_pairs, whole.stats.real_pairs);
        assert_eq!(out.stats.dispatches, whole.stats.dispatches);
    }

    #[test]
    fn misaligned_request_rounds_up_to_kernel_alignment() {
        // tiled kernel tiles at 16; ask for 8-aligned bands — the executor
        // must round to lcm(8,16)=16, keeping bands tile-aligned and the
        // output bit-identical
        let k = TiledKernel::new(TiledConfig { block: 16, workers: 1 });
        let a = uniform(80, 64, 0.2, 15);
        let b = uniform(64, 40, 0.2, 16);
        let prepared = k.prepare(&b).unwrap();
        let want = bits(&k.execute(&a, &prepared).unwrap().c);
        let out = execute(&k, &a, Some(&b), &prepared, ShardConfig { shards: 3, block: 8 })
            .unwrap();
        assert_eq!(bits(&out.c), want, "misaligned shard request diverged");
        for s in &out.shards {
            assert_eq!(s.rows.0 % 16, 0, "band start {} not tile-aligned", s.rows.0);
        }
        assert_eq!(lcm(8, 16), 16);
        assert_eq!(lcm(10, 16), 80);
        assert_eq!(lcm(1, 7), 7);
    }

    #[test]
    fn empty_matrix_and_zero_rows() {
        let k = GustavsonKernel;
        let a = uniform(20, 30, 0.0, 1);
        let b = uniform(30, 10, 0.3, 2);
        let prepared = k.prepare(&b).unwrap();
        let out = execute(&k, &a, Some(&b), &prepared, ShardConfig { shards: 4, block: 8 })
            .unwrap();
        assert!(out.c.data.iter().all(|&v| v == 0.0));
        assert_eq!(out.c.shape(), (20, 10));
    }

    #[test]
    fn shape_mismatch_is_typed() {
        let k = GustavsonKernel;
        let a = uniform(8, 9, 0.5, 1);
        let b = uniform(10, 8, 0.5, 2);
        let prepared = k.prepare(&b).unwrap();
        let err = execute(&k, &a, Some(&b), &prepared, ShardConfig::default()).unwrap_err();
        assert!(matches!(err, EngineError::ShapeMismatch { a: (8, 9), b: (10, 8) }));
    }

    #[test]
    fn wrapped_kernel_registers_and_matches_inner() {
        let mut reg = Registry::with_default_kernels(
            Geometry { block: 16, pairs: 32, slots: 16 },
            1,
        );
        let inner = reg
            .resolve(FormatKind::Csr, Algorithm::Gustavson)
            .unwrap();
        let a = uniform(40, 50, 0.2, 9);
        let b = uniform(50, 30, 0.2, 10);
        let want = bits(&inner.run(&a, &b).unwrap().c);
        let key = reg.register(Arc::new(ShardedKernel::wrap(
            Arc::clone(&inner),
            ShardConfig { shards: 3, block: 16 },
        )));
        assert_eq!(key, (FormatKind::Csr, Algorithm::Gustavson));
        let sharded = reg.resolve(FormatKind::Csr, Algorithm::Gustavson).unwrap();
        assert_eq!(sharded.name(), "sharded");
        assert_eq!(bits(&sharded.run(&a, &b).unwrap().c), want);
    }

    #[test]
    fn sharded_outer_kernel_is_bit_identical_to_unsharded() {
        use crate::engine::kernels::OuterKernel;
        use crate::spmm::outer::OuterConfig;
        let k = OuterKernel::new(OuterConfig { fan_in: 3, workers: 2 });
        let a = uniform(60, 80, 0.08, 25);
        let b = uniform(80, 44, 0.08, 26);
        let prepared = k.prepare(&b).unwrap();
        let want = bits(&k.execute(&a, &prepared).unwrap().c);
        for shards in [1usize, 2, 3, 5, 8] {
            let out = execute(&k, &a, Some(&b), &prepared, ShardConfig { shards, block: 16 })
                .unwrap();
            assert_eq!(bits(&out.c), want, "{shards} shards diverge");
        }
        // the prepared operand's CSR source also feeds the planner when no
        // explicit B is passed (the ShardedKernel wrapper's path)
        let out = execute(&k, &a, None, &prepared, ShardConfig { shards: 3, block: 16 })
            .unwrap();
        assert_eq!(bits(&out.c), want);
    }

    #[test]
    fn sharded_wrapper_re_wraps_negotiated_siblings() {
        use crate::engine::kernels::InnerKernel;
        use crate::formats::incrs::{InCrs, InCrsParams};
        use crate::formats::operand::MatrixOperand;
        let inner: Arc<dyn SpmmKernel> = Arc::new(InnerKernel::incrs(InCrsParams::default()));
        let wrapped = ShardedKernel::wrap(inner, ShardConfig { shards: 2, block: 16 });
        let b = uniform(24, 300, 0.2, 9);
        let foreign =
            InCrs::from_csr_params(&b, InCrsParams { section: 64, block: 8 }).unwrap();
        let op = MatrixOperand::from(foreign);
        let negotiated = wrapped.negotiate(&op).expect("wrapper must delegate negotiation");
        assert_eq!(negotiated.name(), "sharded", "sibling must stay sharded");
        assert!(negotiated.ingest_cost(&b, Some(&op)) < 0.0, "sibling must adopt");
        // a kernel with nothing to offer stays silent through the wrapper
        let plain = ShardedKernel::wrap(
            Arc::new(GustavsonKernel),
            ShardConfig { shards: 2, block: 16 },
        );
        assert!(plain.negotiate(&op).is_none());
    }

    #[test]
    fn panicking_worker_is_an_exec_error_not_a_poisoned_caller() {
        struct PanicKernel;
        impl SpmmKernel for PanicKernel {
            fn algorithm(&self) -> Algorithm {
                Algorithm::Gustavson
            }
            fn format(&self) -> FormatKind {
                FormatKind::Csr
            }
            fn name(&self) -> &'static str {
                "panic-injector"
            }
            fn cost_hint(&self, _: &Csr, _: &Csr) -> CostHint {
                CostHint::default()
            }
            fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
                Ok(PreparedB::Csr(Arc::new(b.clone())))
            }
            fn execute(&self, _: &Csr, _: &PreparedB) -> Result<EngineOutput, EngineError> {
                panic!("injected shard fault");
            }
        }
        let a = uniform(32, 32, 0.3, 11);
        let prepared = PanicKernel.prepare(&a).unwrap();
        let err = execute(
            &PanicKernel,
            &a,
            None,
            &prepared,
            ShardConfig { shards: 2, block: 16 },
        )
        .unwrap_err();
        match err {
            EngineError::ExecFailed(msg) => {
                assert!(msg.contains("shard"), "{msg}")
            }
            other => panic!("unexpected error {other:?}"),
        }
        // the caller thread is alive and can shard again with a good kernel
        let ok = execute(
            &GustavsonKernel,
            &a,
            None,
            &prepared,
            ShardConfig { shards: 2, block: 16 },
        );
        assert!(ok.is_ok());
    }
}
