//! The `SpmmKernel` trait: the single execution contract every SpMM path in
//! this crate implements — CPU algorithms, the tiled parallel executor, and
//! the accelerator (plan/PJRT) adapter alike.
//!
//! A kernel is identified by the `(FormatKind, Algorithm)` pair it serves:
//! which representation of `B` it consumes and which compute organization it
//! uses. Execution is split into `prepare` (one-time representation build,
//! e.g. the InCRS counter vectors — cacheable across jobs that share `B`)
//! and `execute` (the multiply itself). `cost_hint` lets the registry and
//! router choose among kernels without running them.

use std::sync::Arc;

use crate::formats::csr::Csr;
use crate::formats::dense::Dense;
use crate::formats::error::FormatError;
use crate::formats::incrs::InCrs;
use crate::formats::operand::MatrixOperand;
use crate::formats::traits::FormatKind;
use crate::spmm::blocks::BlockGrid;
use crate::spmm::gustavson_fast::WorkspacePool;
use crate::spmm::outer::MergePool;

use super::error::EngineError;

/// Compute organization of a kernel (the paper's §II algorithm axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Algorithm {
    /// Row-expansion reference multiply — the numeric oracle.
    Dense,
    /// Row-order CRS×CRS with a sparse accumulator (CPU baseline).
    Gustavson,
    /// Vectorized, workspace-pooled Gustavson: symbolic row sizing,
    /// epoch-stamped accumulator, unrolled 8-lane accumulate, parallel
    /// A-row bands — bit-identical to [`Algorithm::Gustavson`]
    /// (`spmm::gustavson_fast` + `engine::kernels::GustavsonFastKernel`).
    GustavsonFast,
    /// Inner-product SpMM reading `B` column-wise through `locate`.
    Inner,
    /// Outer-product SpGEMM (SpArch-style): A streamed by column against B
    /// by row, per-column partial-product runs combined by a deterministic
    /// k-ordered multiway merge (`spmm::outer`) — bit-identical to
    /// [`Algorithm::Gustavson`] at any merge fan-in or worker count, and
    /// the backend of choice for hyper-sparse (power-law) inputs.
    OuterProduct,
    /// Multi-threaded 32×32 tile-pair executor (`engine::tiled`).
    Tiled,
    /// Accelerator dispatch path: sorted tile-pair plan executed by the
    /// PJRT Pallas kernel, or its bit-equivalent CPU twin.
    Block,
}

impl Algorithm {
    pub const ALL: [Algorithm; 7] = [
        Algorithm::Dense,
        Algorithm::Gustavson,
        Algorithm::GustavsonFast,
        Algorithm::Inner,
        Algorithm::OuterProduct,
        Algorithm::Tiled,
        Algorithm::Block,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Dense => "dense",
            Algorithm::Gustavson => "gustavson",
            Algorithm::GustavsonFast => "gustavson-fast",
            Algorithm::Inner => "inner",
            Algorithm::OuterProduct => "outer",
            Algorithm::Tiled => "tiled",
            Algorithm::Block => "block",
        }
    }

    /// Parse a CLI/spelled-out algorithm name. The inverse of
    /// [`Algorithm::name`]: `parse(name(a)) == a` for every variant (locked
    /// by `algorithm_names_roundtrip`).
    pub fn parse(s: &str) -> Result<Algorithm, FormatError> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dense" | "oracle" => Algorithm::Dense,
            "gustavson" | "row" => Algorithm::Gustavson,
            "gustavson-fast" | "gfast" | "simd" => Algorithm::GustavsonFast,
            "inner" => Algorithm::Inner,
            "outer" | "sparch" => Algorithm::OuterProduct,
            "tiled" => Algorithm::Tiled,
            "block" | "accel" => Algorithm::Block,
            other => return Err(FormatError::UnknownAlgorithm(other.into())),
        })
    }
}

/// Execution accounting for one SpMM run, shared by every kernel. Scalar
/// kernels report one "dispatch" and count scalar MACs as pairs; blocked
/// kernels report tile-pair counts exactly as the old `ExecReport` did.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    /// Accelerator calls (Block), tile tasks (Tiled), or 1 (scalar kernels).
    pub dispatches: u64,
    /// Real (unpadded) units of useful work: tile pairs or scalar MACs.
    pub real_pairs: u64,
    /// Units issued including padding (Block path only; else == real_pairs).
    pub padded_pairs: u64,
    /// MACs issued including padding.
    pub macs_issued: u64,
    /// Worker threads that executed the job (1 for serial kernels).
    pub threads: usize,
}

/// A kernel's result: the dense product plus its accounting.
#[derive(Debug)]
pub struct EngineOutput {
    pub c: Dense,
    pub stats: ExecStats,
}

/// Rough cost estimate used for kernel selection — same spirit as the
/// router's N·D/(b+2) estimate (§III.C): cheap to compute, monotone in the
/// real cost, not a cycle count.
#[derive(Clone, Copy, Debug, Default)]
pub struct CostHint {
    /// Estimated multiply-side work (scalar-MAC-equivalents).
    pub flops: f64,
    /// One-time operand preparation cost in words touched (format builds).
    pub prepare_words: f64,
}

impl CostHint {
    pub fn total(&self) -> f64 {
        self.flops + self.prepare_words
    }
}

/// `B` blockized once at a fixed tile size — the blocked kernels' prepared
/// representation. Built in `prepare` (LRU-cached by the coordinator,
/// shared across micro-batches and shard workers) so tiled/accel `execute`
/// never re-blockizes `B` — closing the per-shard O(nnz(B)) re-blockization
/// tax the ROADMAP named.
#[derive(Debug)]
pub struct BlockedB {
    /// The canonical CSR the grid was built from — kept (as an `Arc`
    /// share, not a copy) for shard planning, shape checks, and the
    /// planner's weight heuristic.
    pub src: Arc<Csr>,
    /// Non-empty `block × block` dense tiles of `B`.
    pub grid: BlockGrid,
}

impl BlockedB {
    /// Blockize `src` at `block` (the one place B blockization happens on
    /// the blocked kernels' path).
    pub fn build(src: Arc<Csr>, block: usize) -> BlockedB {
        let grid = crate::spmm::blocks::blockize(&src, block);
        BlockedB { src, grid }
    }

    /// Tile size the grid was built at.
    pub fn block(&self) -> usize {
        self.grid.block
    }
}

/// Canonical CSR `B` paired with a shared [`WorkspacePool`] — the fast
/// Gustavson kernel's prepared representation. The matrix itself is an
/// `Arc` share (no copy); what makes this prepare worth caching is the
/// pool: the coordinator's `PreparedCache` carries it across micro-batches
/// and every shard worker sharing the `PreparedB` draws accumulator
/// workspaces from the same pool instead of reallocating per job
/// (SpArch's data-reuse argument applied to the workspace, not just `B`).
#[derive(Debug)]
pub struct PooledCsrB {
    /// The canonical CSR operand (shared, never copied).
    pub src: Arc<Csr>,
    /// Accumulator workspaces reused across rows, jobs, and shard workers.
    pub pool: WorkspacePool,
}

impl PooledCsrB {
    pub fn new(src: Arc<Csr>) -> PooledCsrB {
        PooledCsrB {
            src,
            pool: WorkspacePool::new(),
        }
    }
}

/// Canonical CSR `B` paired with a shared [`MergePool`] — the
/// outer-product kernel's prepared representation, the merge-buffer mirror
/// of [`PooledCsrB`]. The matrix is an `Arc` share (B is streamed row `k`
/// at a time, which canonical CSR already serves); the pool of
/// partial-product merge buffers is what makes the prepare non-trivial, so
/// the coordinator's content-keyed `PreparedCache` carries the scratch
/// across micro-batches and every shard worker sharing the `PreparedB`.
#[derive(Debug)]
pub struct OuterB {
    /// The canonical CSR operand (shared, never copied).
    pub src: Arc<Csr>,
    /// Partial-product merge buffers reused across jobs and shard workers.
    pub pool: MergePool,
}

impl OuterB {
    pub fn new(src: Arc<Csr>) -> OuterB {
        OuterB {
            src,
            pool: MergePool::new(),
        }
    }
}

/// `B` converted into the representation a kernel consumes. Built by
/// `SpmmKernel::prepare`; callers may cache it across jobs sharing `B`.
#[derive(Clone, Debug)]
pub enum PreparedB {
    Csr(Arc<Csr>),
    InCrs(Arc<InCrs>),
    Dense(Arc<Dense>),
    /// Blockized `B` (tiled/accel kernels): tiles + the canonical source.
    Blocked(Arc<BlockedB>),
    /// Canonical CSR plus a shared accumulator-workspace pool (the fast
    /// Gustavson kernel).
    Pooled(Arc<PooledCsrB>),
    /// Canonical CSR plus a shared partial-product merge-buffer pool (the
    /// outer-product kernel).
    OuterPooled(Arc<OuterB>),
}

impl PreparedB {
    /// Canonical format of the prepared operand. `Blocked`, `Pooled`, and
    /// `OuterPooled` report [`FormatKind::Csr`] — each carries its
    /// canonical CSR source (the outer kernel's CSC registry key names the
    /// *algorithm's* column-major view of A, not B's storage); use
    /// [`PreparedB::label`] when the exact representation matters (error
    /// messages).
    pub fn format(&self) -> FormatKind {
        match self {
            PreparedB::Csr(_) => FormatKind::Csr,
            PreparedB::InCrs(_) => FormatKind::InCrs,
            PreparedB::Dense(_) => FormatKind::Dense,
            PreparedB::Blocked(_) => FormatKind::Csr,
            PreparedB::Pooled(_) => FormatKind::Csr,
            PreparedB::OuterPooled(_) => FormatKind::Csr,
        }
    }

    /// Human-readable representation name (distinguishes `Blocked` from
    /// plain CSR, unlike [`PreparedB::format`]).
    pub fn label(&self) -> &'static str {
        match self {
            PreparedB::Csr(_) => "CRS",
            PreparedB::InCrs(_) => "InCRS",
            PreparedB::Dense(_) => "dense",
            PreparedB::Blocked(_) => "blocked",
            PreparedB::Pooled(_) => "pooled-CRS",
            PreparedB::OuterPooled(_) => "outer-pooled",
        }
    }

    /// Shape of the prepared operand (rows, cols) regardless of
    /// representation — shape checks without unwrapping the variant.
    pub fn shape(&self) -> (usize, usize) {
        use crate::formats::traits::SparseMatrix;
        match self {
            PreparedB::Csr(m) => m.shape(),
            PreparedB::InCrs(m) => m.shape(),
            PreparedB::Dense(m) => m.shape(),
            PreparedB::Blocked(b) => (b.grid.rows, b.grid.cols),
            PreparedB::Pooled(p) => p.src.shape(),
            PreparedB::OuterPooled(p) => p.src.shape(),
        }
    }
}

/// The unified execution contract. Object-safe; kernels are registered as
/// `Arc<dyn SpmmKernel>` in an [`crate::engine::Registry`] and shared across
/// server workers (hence `Send + Sync`).
pub trait SpmmKernel: Send + Sync {
    /// Compute organization this kernel implements.
    fn algorithm(&self) -> Algorithm;
    /// Representation of `B` this kernel consumes (the registry key's
    /// format half).
    fn format(&self) -> FormatKind;
    /// Stable display name ("cpu"/"pjrt" for the accel adapter, else the
    /// algorithm name).
    fn name(&self) -> &'static str;
    /// Estimate the cost of running this kernel on `A × B` without running
    /// it (used by [`crate::engine::Registry::select`]).
    fn cost_hint(&self, a: &Csr, b: &Csr) -> CostHint;
    /// Build this kernel's representation of `B` (cacheable).
    fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError>;
    /// Like [`SpmmKernel::prepare`], but sharing the caller's `Arc` when
    /// the kernel consumes CSR as-is — the serving hot path calls this so
    /// per-job preparation is O(1) for CSR-consuming kernels instead of an
    /// O(nnz) copy. Conversion kernels fall back to [`SpmmKernel::prepare`].
    fn prepare_shared(&self, b: &Arc<Csr>) -> Result<PreparedB, EngineError> {
        if self.prepare_is_trivial() {
            Ok(PreparedB::Csr(Arc::clone(b)))
        } else {
            self.prepare(b)
        }
    }

    /// Whether `prepare_shared` is an O(1) `Arc` share (plain-CSR
    /// consumers) rather than a real representation build worth caching
    /// across jobs (InCRS counter vectors, densification, blockization).
    /// The coordinator keys its `PreparedB` cache on this: trivial
    /// prepares bypass the content-fingerprint cache entirely. Kernels
    /// whose prepare does real work despite a CSR registry key (tiled,
    /// accel) override this to `false`.
    fn prepare_is_trivial(&self) -> bool {
        self.format() == FormatKind::Csr
    }

    /// Prepare from a native-format operand: `native` is the operand as it
    /// arrived, `b` its canonical CSR rendering (already converted by the
    /// caller, memoized server-side). The default ignores the native form;
    /// kernels that can adopt a native representation directly — the
    /// inner-InCRS kernel consuming an InCRS operand with matching
    /// geometry — override this to skip their rebuild.
    fn prepare_operand(
        &self,
        native: &MatrixOperand,
        b: &Arc<Csr>,
    ) -> Result<PreparedB, EngineError> {
        let _ = native;
        self.prepare_shared(b)
    }

    /// One-time ingestion words this kernel charges for a `B` arriving as
    /// `native` (`None` = canonical CSR in hand), on top of
    /// [`SpmmKernel::cost_hint`]. The default is the canonical conversion
    /// cost — zero when `B` already is CSR. Kernels that adopt a native
    /// representation (see [`SpmmKernel::prepare_operand`]) override this
    /// with a credit so `Registry::select_native` can prefer them: format
    /// choice drives cost, and the registry now sees it. The full operand
    /// is passed (not just its [`FormatKind`]) so adoption credits can
    /// check the geometry they depend on.
    fn ingest_cost(&self, b: &Csr, native: Option<&MatrixOperand>) -> f64 {
        use crate::formats::traits::SparseMatrix;
        let kind = native.map_or(FormatKind::Csr, MatrixOperand::format);
        crate::formats::operand::conversion_words(kind, b.nnz(), b.rows())
    }
    /// Per-operand kernel specialization: given `B`'s native arrival form,
    /// return a variant of this kernel tuned to that operand — e.g. the
    /// inner-InCRS kernel re-parameterized to a native InCRS operand's own
    /// [`crate::formats::incrs::InCrsParams`], so its `prepare_operand` can
    /// adopt the arrays instead of rebuilding them under default params.
    /// [`crate::engine::Registry::select_native`] adds the returned kernel
    /// to its candidate set, where it competes on the same
    /// `cost_hint + ingest_cost` basis as every registered kernel. `None`
    /// (the default) means this kernel has no operand-specific variant.
    fn negotiate(&self, native: &MatrixOperand) -> Option<Arc<dyn SpmmKernel>> {
        let _ = native;
        None
    }

    /// Row-band alignment required for sharded execution to stay
    /// bit-identical (`engine::shard`): blocked kernels return their tile
    /// block (band cuts inside a tile would re-blockize rows differently
    /// and reassociate the f32 reduction); scalar kernels accept any
    /// boundary. The shard executor rounds its band alignment up to a
    /// multiple of this.
    fn band_alignment(&self) -> usize {
        1
    }

    /// Hand this kernel a live [`crate::engine::learn::CostModel`] handle.
    /// Called by [`crate::engine::Registry::set_cost_model`] for every
    /// registered kernel; the default ignores it. Kernels with fittable
    /// constants inside their own `cost_hint` arithmetic (the outer
    /// kernel's merge-round weight) keep the handle and consult the
    /// fitted calibration on each hint — falling back to their static
    /// constant while uncalibrated, so selection behavior is unchanged
    /// until the learn loop has published a fit.
    fn observe_model(&self, model: &crate::engine::learn::CostModel) {
        let _ = model;
    }

    /// Run `C = A × B` on a prepared operand.
    fn execute(&self, a: &Csr, b: &PreparedB) -> Result<EngineOutput, EngineError>;

    /// Convenience: prepare + execute in one call.
    fn run(&self, a: &Csr, b: &Csr) -> Result<EngineOutput, EngineError> {
        // `strict-invariants` builds validate operands where they enter
        // the engine (no-op otherwise — see `formats::strict_check`)
        crate::formats::strict_check("SpmmKernel::run(A)", || a.validate_invariants());
        crate::formats::strict_check("SpmmKernel::run(B)", || b.validate_invariants());
        let prepared = self.prepare(b)?;
        self.execute(a, &prepared)
    }
}

/// Expected non-empty tile count of `m` blocked at `block`, from per-tile
/// Poisson occupancy.
pub fn expected_tiles(m: &Csr, block: usize) -> f64 {
    use crate::formats::traits::SparseMatrix;
    let bsz = block as f64;
    let cells = (m.rows() as f64 / bsz).ceil() * (m.cols() as f64 / bsz).ceil();
    let lambda = m.nnz() as f64 / cells.max(1.0);
    cells * (1.0 - (-lambda).exp())
}

/// Expected tile-pair count for `A × B` blocked at `block` — the shared
/// estimate behind the tiled and accelerator kernels' cost hints (keep
/// them in sync when fitting constants from serve metrics).
pub fn expected_tile_pairs(a: &Csr, b: &Csr, block: usize) -> f64 {
    use crate::formats::traits::SparseMatrix;
    let gk = (a.cols() as f64 / block as f64).ceil().max(1.0);
    expected_tiles(a, block) * expected_tiles(b, block) / gk
}

/// Standard operand-mismatch error for `execute` implementations.
pub fn wrong_operand(kernel: &dyn SpmmKernel, got: &PreparedB) -> EngineError {
    EngineError::ExecFailed(format!(
        "kernel {}/{} expects B prepared for {}, got {}",
        kernel.algorithm().name(),
        kernel.name(),
        kernel.format().name(),
        got.label()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm_names_roundtrip() {
        for alg in Algorithm::ALL {
            assert_eq!(Algorithm::parse(alg.name()).unwrap(), alg);
        }
        assert_eq!(Algorithm::parse("ACCEL").unwrap(), Algorithm::Block);
        assert_eq!(Algorithm::parse("sparch").unwrap(), Algorithm::OuterProduct);
        assert!(Algorithm::parse("nope").is_err());
    }

    #[test]
    fn cost_hint_totals() {
        let h = CostHint { flops: 10.0, prepare_words: 5.0 };
        assert_eq!(h.total(), 15.0);
    }
}
