//! CPU kernels: the scalar SpMM algorithms (`spmm::{dense, gustavson,
//! inner}`), the vectorized pooled Gustavson, the outer-product multiway
//! merge (`spmm::outer`), and the multi-threaded tiled executor, each
//! wrapped behind [`SpmmKernel`] so the registry dispatches them
//! interchangeably.
//!
//! Cost hints follow the paper's access-count models (§II/§III): Gustavson
//! pays `nnz(A)·N·D_B` streaming work; inner-product pays one `locate` per
//! (A-nonzero, B-column) pair — ≈ ½·N·D per locate in CRS vs ≈ b/2+1 in
//! InCRS; the dense oracle pays the full m·k·n.

use std::sync::Arc;

use crate::formats::csr::Csr;
use crate::formats::dense::Dense;
use crate::formats::incrs::{InCrs, InCrsParams};
use crate::formats::operand::MatrixOperand;
use crate::formats::traits::{FormatKind, NullSink, SparseMatrix};
use crate::spmm;
use crate::spmm::gustavson_fast;

use super::error::EngineError;
use super::kernel::{
    wrong_operand, Algorithm, BlockedB, CostHint, EngineOutput, ExecStats, OuterB, PooledCsrB,
    PreparedB, SpmmKernel,
};
use super::tiled::{self, TiledConfig};

fn scalar_stats(macs: u64) -> ExecStats {
    ExecStats {
        dispatches: 1,
        real_pairs: macs,
        padded_pairs: macs,
        macs_issued: macs,
        threads: 1,
    }
}

/// Average nonzeros per row of `m` (the paper's N·D).
fn nd(m: &Csr) -> f64 {
    m.nnz() as f64 / m.rows().max(1) as f64
}

// ---------------------------------------------------------------- dense

/// The numeric oracle: `B` densified, row-expansion multiply. Never fast,
/// always exact — registered so every other kernel can be checked against
/// the same dispatch surface it runs behind.
pub struct DenseOracleKernel;

impl SpmmKernel for DenseOracleKernel {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Dense
    }
    fn format(&self) -> FormatKind {
        FormatKind::Dense
    }
    fn name(&self) -> &'static str {
        "dense"
    }
    fn cost_hint(&self, a: &Csr, b: &Csr) -> CostHint {
        CostHint {
            flops: a.rows() as f64 * a.cols() as f64 * b.cols() as f64,
            prepare_words: b.rows() as f64 * b.cols() as f64,
        }
    }
    fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
        Ok(PreparedB::Dense(Arc::new(Dense::from_coo(&b.to_coo()))))
    }
    fn execute(&self, a: &Csr, b: &PreparedB) -> Result<EngineOutput, EngineError> {
        let bd = match b {
            PreparedB::Dense(d) => d,
            other => return Err(wrong_operand(self, other)),
        };
        if a.cols() != bd.rows() {
            return Err(EngineError::ShapeMismatch {
                a: a.shape(),
                b: bd.shape(),
            });
        }
        let (m, n) = (a.rows(), bd.cols());
        let mut c = Dense::zeros(m, n);
        let mut macs = 0u64;
        for i in 0..m {
            let (cols, vals) = a.row(i);
            for (&k, &av) in cols.iter().zip(vals) {
                for j in 0..n {
                    *c.at_mut(i, j) += av * bd.at(k as usize, j);
                }
                macs += n as u64;
            }
        }
        Ok(EngineOutput { c, stats: scalar_stats(macs) })
    }
}

// ------------------------------------------------------------- gustavson

/// Row-order CRS×CRS (the CPU baseline that avoids column access).
pub struct GustavsonKernel;

impl SpmmKernel for GustavsonKernel {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Gustavson
    }
    fn format(&self) -> FormatKind {
        FormatKind::Csr
    }
    fn name(&self) -> &'static str {
        "gustavson"
    }
    fn cost_hint(&self, a: &Csr, b: &Csr) -> CostHint {
        // each A-nonzero streams one B-row: nnz(A) · N·D_B MACs expected
        CostHint {
            flops: a.nnz() as f64 * nd(b),
            prepare_words: 0.0,
        }
    }
    fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
        Ok(PreparedB::Csr(Arc::new(b.clone())))
    }
    fn execute(&self, a: &Csr, b: &PreparedB) -> Result<EngineOutput, EngineError> {
        let bc = match b {
            PreparedB::Csr(m) => m,
            other => return Err(wrong_operand(self, other)),
        };
        if a.cols() != bc.rows() {
            return Err(EngineError::ShapeMismatch {
                a: a.shape(),
                b: bc.shape(),
            });
        }
        let (c_sparse, macs) = spmm::gustavson::multiply_counted(a, bc);
        let c = Dense::from_coo(&c_sparse.to_coo());
        Ok(EngineOutput { c, stats: scalar_stats(macs) })
    }
}

// -------------------------------------------------------- gustavson-fast

/// Vectorized, workspace-pooled Gustavson (`spmm::gustavson_fast`):
/// symbolic row sizing, epoch-stamped accumulator, unrolled 8-lane
/// accumulate, and parallel execution over weighted contiguous A-row bands
/// (the tiled executor's partition heuristic). Bit-identical to
/// [`GustavsonKernel`] at any worker count — per-output-element
/// accumulation order never changes; bands only move whole rows between
/// threads.
///
/// `prepare` builds a [`PooledCsrB`]: the CSR is an `Arc` share, but the
/// attached [`crate::spmm::gustavson_fast::WorkspacePool`] is the reason
/// the prepare is non-trivial — routed through the coordinator's
/// content-keyed `PreparedCache`, the pool persists across micro-batches
/// and is shared by every shard worker, so accumulator workspaces are
/// reused instead of reallocated per job.
pub struct GustavsonFastKernel {
    /// A-row-band threads per execute (1 = serial, same code path).
    pub workers: usize,
}

impl GustavsonFastKernel {
    pub fn new(workers: usize) -> GustavsonFastKernel {
        GustavsonFastKernel { workers: workers.max(1) }
    }
}

impl SpmmKernel for GustavsonFastKernel {
    fn algorithm(&self) -> Algorithm {
        Algorithm::GustavsonFast
    }
    fn format(&self) -> FormatKind {
        FormatKind::Csr
    }
    fn name(&self) -> &'static str {
        "gustavson-fast"
    }
    fn cost_hint(&self, a: &Csr, b: &Csr) -> CostHint {
        // same nnz(A)·N·D_B streaming traversal as scalar Gustavson, run
        // twice (symbolic + numeric) — but the unrolled accumulate retires
        // several lanes per issue, so the net per-MAC cost is charged at
        // half the scalar kernel's. The 0.5 constant is exactly what the
        // server's kernel-observation log (Metrics::kernel_log) exists to
        // re-fit.
        CostHint {
            flops: a.nnz() as f64 * nd(b) * 0.5,
            prepare_words: 0.0,
        }
    }
    fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
        Ok(PreparedB::Pooled(Arc::new(PooledCsrB::new(Arc::new(
            b.clone(),
        )))))
    }
    fn prepare_shared(&self, b: &Arc<Csr>) -> Result<PreparedB, EngineError> {
        Ok(PreparedB::Pooled(Arc::new(PooledCsrB::new(Arc::clone(b)))))
    }
    /// Non-trivial on purpose: the CSR share is O(1), but the attached
    /// workspace pool must survive across jobs — routing through the
    /// content-keyed `PreparedCache` is what makes pool reuse happen.
    fn prepare_is_trivial(&self) -> bool {
        false
    }
    fn execute(&self, a: &Csr, b: &PreparedB) -> Result<EngineOutput, EngineError> {
        let pb = match b {
            PreparedB::Pooled(pb) => pb,
            other => return Err(wrong_operand(self, other)),
        };
        let src = pb.src.as_ref();
        if a.cols() != src.rows() {
            return Err(EngineError::ShapeMismatch {
                a: a.shape(),
                b: src.shape(),
            });
        }
        let (m, n) = (a.rows(), src.cols());
        // exact per-row MAC weights (one B-row length per A-nonzero) feed
        // the same weighted contiguous partition the tiled executor uses;
        // a serial kernel is one band by definition, so the default
        // serving configuration never pays the extra pass over A
        let bounds = if self.workers <= 1 || m <= 1 {
            if m == 0 { Vec::new() } else { vec![(0, m)] }
        } else {
            let weights: Vec<usize> = (0..m)
                .map(|i| a.row(i).0.iter().map(|&k| src.row_nnz(k as usize)).sum())
                .collect();
            tiled::partition_by_weight(&weights, self.workers)
        };
        let mut c = Dense::zeros(m, n);
        let mut macs = 0u64;
        let pool = &pb.pool;
        let scatter = |c: &mut Dense, lo: usize, band: &gustavson_fast::BandResult| {
            for (r, w) in band.row_ptr.windows(2).enumerate() {
                let row = &mut c.data[(lo + r) * n..(lo + r + 1) * n];
                let (e0, e1) = (w[0] as usize, w[1] as usize);
                for (&j, &v) in band.col_idx[e0..e1].iter().zip(&band.vals[e0..e1]) {
                    row[j as usize] = v;
                }
            }
        };
        if bounds.len() <= 1 {
            if let Some(&(lo, hi)) = bounds.first() {
                let mut ws = pool.checkout(n);
                let band = gustavson_fast::multiply_band(a, lo, hi, src, &mut ws);
                pool.give_back(ws);
                macs = band.macs;
                scatter(&mut c, lo, &band);
            }
        } else {
            // every handle is joined inside the scope (a panicked worker
            // must not escape as a scope re-panic); lost bands surface as
            // a typed error after the scope closes
            let joined: Vec<std::thread::Result<(usize, gustavson_fast::BandResult)>> =
                std::thread::scope(|s| {
                    let handles: Vec<_> = bounds
                        .iter()
                        .map(|&(lo, hi)| {
                            s.spawn(move || {
                                let mut ws = pool.checkout(n);
                                let band =
                                    gustavson_fast::multiply_band(a, lo, hi, src, &mut ws);
                                pool.give_back(ws);
                                (lo, band)
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join()).collect()
                });
            let mut results = Vec::with_capacity(joined.len());
            for r in joined {
                match r {
                    Ok(band) => results.push(band),
                    Err(_) => {
                        return Err(EngineError::ExecFailed(
                            "gustavson-fast band worker panicked".into(),
                        ))
                    }
                }
            }
            // bands cover disjoint row ranges: the merge is a pure scatter,
            // no reduction crosses a band
            for (lo, band) in &results {
                macs += band.macs;
                scatter(&mut c, *lo, band);
            }
        }
        Ok(EngineOutput {
            c,
            stats: ExecStats {
                dispatches: bounds.len() as u64,
                real_pairs: macs,
                padded_pairs: macs,
                macs_issued: macs,
                threads: bounds.len().max(1),
            },
        })
    }
}

// ----------------------------------------------------------------- inner

/// Inner-product SpMM reading `B` column-wise through `locate`, in either
/// plain CRS (the paper's baseline) or InCRS (the paper's proposal) —
/// registered once per format so the registry key distinguishes them.
pub struct InnerKernel {
    format: FormatKind,
    params: InCrsParams,
}

impl InnerKernel {
    pub fn csr() -> InnerKernel {
        InnerKernel {
            format: FormatKind::Csr,
            params: InCrsParams::default(),
        }
    }
    pub fn incrs(params: InCrsParams) -> InnerKernel {
        InnerKernel {
            format: FormatKind::InCrs,
            params,
        }
    }
}

impl SpmmKernel for InnerKernel {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Inner
    }
    fn format(&self) -> FormatKind {
        self.format
    }
    fn name(&self) -> &'static str {
        match self.format {
            FormatKind::InCrs => "inner-incrs",
            _ => "inner-crs",
        }
    }
    fn cost_hint(&self, a: &Csr, b: &Csr) -> CostHint {
        // one locate per (A-nonzero, B-column): §III.C access models
        let locates = a.nnz() as f64 * b.cols() as f64;
        match self.format {
            FormatKind::InCrs => CostHint {
                flops: locates * (self.params.block as f64 / 2.0 + 1.0),
                prepare_words: b.nnz() as f64 + b.rows() as f64,
            },
            _ => CostHint {
                flops: locates * (nd(b) / 2.0).max(1.0),
                prepare_words: 0.0,
            },
        }
    }
    fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
        match self.format {
            FormatKind::InCrs => Ok(PreparedB::InCrs(Arc::new(
                InCrs::from_csr_params(b, self.params)?,
            ))),
            _ => Ok(PreparedB::Csr(Arc::new(b.clone()))),
        }
    }
    /// An operand already stored as InCRS with this kernel's geometry is
    /// adopted directly — no CSR round-trip, no counter rebuild. The
    /// adopted arrays are the deterministic function of the matrix content
    /// and params, so the result stays bit-identical to the rebuilt path.
    fn prepare_operand(
        &self,
        native: &MatrixOperand,
        b: &Arc<Csr>,
    ) -> Result<PreparedB, EngineError> {
        if let (FormatKind::InCrs, MatrixOperand::InCrs(m)) = (self.format, native) {
            if m.params == self.params {
                return Ok(PreparedB::InCrs(Arc::clone(m)));
            }
        }
        self.prepare_shared(b)
    }
    /// A native InCRS operand whose geometry differs from this kernel's
    /// can't be adopted here — but a sibling parameterized to the
    /// operand's **own** params can adopt it for free. Hand selection that
    /// sibling, so the router negotiates per-operand `InCrsParams` instead
    /// of re-deriving defaults and rebuilding the counter vectors.
    fn negotiate(&self, native: &MatrixOperand) -> Option<Arc<dyn SpmmKernel>> {
        if let (FormatKind::InCrs, MatrixOperand::InCrs(m)) = (self.format, native) {
            if m.params != self.params {
                return Some(Arc::new(InnerKernel::incrs(m.params)));
            }
        }
        None
    }
    /// Credit the adopted-native path: an InCRS operand with **matching
    /// geometry** skips both the CSR conversion and the counter build this
    /// kernel's `cost_hint.prepare_words` assumes. A mismatched-params
    /// InCRS arrival gets no credit — `prepare_operand` would refuse to
    /// adopt it and rebuild instead.
    fn ingest_cost(&self, b: &Csr, native: Option<&MatrixOperand>) -> f64 {
        if self.format == FormatKind::InCrs {
            if let Some(MatrixOperand::InCrs(m)) = native {
                if m.params == self.params {
                    return -(b.nnz() as f64 + b.rows() as f64);
                }
            }
        }
        let kind = native.map_or(FormatKind::Csr, MatrixOperand::format);
        crate::formats::operand::conversion_words(kind, b.nnz(), b.rows())
    }
    fn execute(&self, a: &Csr, b: &PreparedB) -> Result<EngineOutput, EngineError> {
        let mut sink = NullSink;
        let (c, b_shape) = match (self.format, b) {
            (FormatKind::InCrs, PreparedB::InCrs(m)) => (
                (a.cols() == m.rows()).then(|| spmm::inner::multiply_b_incrs(a, m, &mut sink)),
                m.shape(),
            ),
            (FormatKind::Csr, PreparedB::Csr(m)) => (
                (a.cols() == m.rows()).then(|| spmm::inner::multiply_b_csr(a, m, &mut sink)),
                m.shape(),
            ),
            (_, other) => return Err(wrong_operand(self, other)),
        };
        let c = c.ok_or_else(|| EngineError::ShapeMismatch {
            a: a.shape(),
            b: b_shape,
        })?;
        let macs = a.nnz() as u64 * c.cols() as u64;
        Ok(EngineOutput { c, stats: scalar_stats(macs) })
    }
}

// ----------------------------------------------------------------- outer

/// Outer-product SpGEMM (`spmm::outer`, SpArch-style): A streamed
/// column-by-column against the matching B row, per-column partial-product
/// runs combined by a deterministic k-ordered multiway merge — bit-identical
/// to [`GustavsonKernel`] at any merge fan-in or worker count. Wins on
/// hyper-sparse inputs (power-law graphs, adjacency chains) where A's rows
/// are near-empty: work is proportional to the partial products actually
/// produced, with no per-output-row machinery over `m` mostly-empty rows.
///
/// Registered under `(Csc, OuterProduct)`: the CSC key names the
/// algorithm's column-major consumption of A — `execute` transposes the
/// canonical row-ordered A (A's columns *are* Aᵀ's rows) and `cost_hint`
/// charges that transpose — while `B` stays canonical CSR inside
/// [`OuterB`] (row `k` streaming is what CSR already serves). CSC-native
/// operand arrivals are credited automatically through the default
/// `ingest_cost`: `MatrixOperand::to_csr` converts CSC by direct transpose
/// (no COO hop), the cheapest non-trivial tier in `conversion_words`.
///
/// `prepare` builds an [`OuterB`]: the CSR is an `Arc` share, but the
/// attached [`crate::spmm::outer::MergePool`] makes the prepare
/// non-trivial — routed through the coordinator's content-keyed
/// `PreparedCache`, the merge scratch persists across micro-batches and is
/// shared by every shard worker (the same reuse argument as
/// [`GustavsonFastKernel`]'s workspace pool).
pub struct OuterKernel {
    pub cfg: spmm::outer::OuterConfig,
    /// Live learned-selection handle (set via `observe_model`): scales the
    /// merge-round term of [`OuterKernel::cost_hint`] by the fitted
    /// outer-vs-fast-Gustavson calibration ratio instead of the static
    /// constant. `None`/uncalibrated ⇒ the original hard-coded weight.
    model: std::sync::Mutex<Option<super::learn::CostModel>>,
}

impl OuterKernel {
    pub fn new(cfg: spmm::outer::OuterConfig) -> OuterKernel {
        OuterKernel { cfg, model: std::sync::Mutex::new(None) }
    }

    /// The fitted merge-round weight: the ratio of this kernel's fitted
    /// per-hint-unit scale to fast Gustavson's (the reference row-centric
    /// kernel the hint competes against), clamped to `[0.25, 4]` so one
    /// noisy refit can never invert selection wholesale. `1.0` — the
    /// original constant, bit-for-bit the pre-fit hint — whenever no model
    /// is attached or either calibration is missing or degenerate.
    fn merge_round_weight(&self) -> f64 {
        let guard = crate::util::lock_unpoisoned(&self.model);
        let Some(model) = guard.as_ref() else {
            return 1.0;
        };
        let fitted = model.fitted();
        let outer = fitted.get((FormatKind::Csc, Algorithm::OuterProduct));
        let fast = fitted.get((FormatKind::Csr, Algorithm::GustavsonFast));
        match (outer, fast) {
            (Some(o), Some(g))
                if o.scale.is_finite()
                    && g.scale.is_finite()
                    && o.scale > 0.0
                    && g.scale > 0.0 =>
            {
                (o.scale / g.scale).clamp(0.25, 4.0)
            }
            _ => 1.0,
        }
    }
}

impl SpmmKernel for OuterKernel {
    fn algorithm(&self) -> Algorithm {
        Algorithm::OuterProduct
    }
    fn format(&self) -> FormatKind {
        FormatKind::Csc
    }
    fn name(&self) -> &'static str {
        "outer"
    }
    fn cost_hint(&self, a: &Csr, b: &Csr) -> CostHint {
        // the same Σₖ |A·col k|·|B·row k| partial products Gustavson
        // performs (estimated nnz(A)·N·D_B), each passed through one pure
        // merge per hierarchical round plus the final accumulating pass —
        // plus the per-execute CSR→CSC transpose of A that column
        // streaming requires. Honest on ordinary inputs: the merge rounds
        // keep this above the fast-Gustavson hint, so auto-selection only
        // reaches for outer where hyper-sparsity makes the row-centric
        // constants dominate. The per-round weight starts at the static
        // constant (1.0) and is replaced by the kernel-observation-log fit
        // once `observe_model` has attached a calibrated `CostModel` — see
        // `merge_round_weight`.
        let products = a.nnz() as f64 * nd(b);
        let runs = a.cols().min(a.nnz()).max(2) as f64;
        let fan = self.cfg.fan_in.max(2) as f64;
        let rounds = (runs.ln() / fan.ln()).ceil().max(1.0);
        let weight = self.merge_round_weight();
        CostHint {
            flops: products * (1.0 + rounds * weight) + (2 * a.nnz() + a.cols()) as f64,
            prepare_words: 0.0,
        }
    }
    fn observe_model(&self, model: &super::learn::CostModel) {
        *crate::util::lock_unpoisoned(&self.model) = Some(model.clone());
    }
    fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
        Ok(PreparedB::OuterPooled(Arc::new(OuterB::new(Arc::new(
            b.clone(),
        )))))
    }
    fn prepare_shared(&self, b: &Arc<Csr>) -> Result<PreparedB, EngineError> {
        Ok(PreparedB::OuterPooled(Arc::new(OuterB::new(Arc::clone(b)))))
    }
    /// Non-trivial on purpose: the CSR share is O(1), but the attached
    /// merge-buffer pool must survive across jobs — routing through the
    /// content-keyed `PreparedCache` is what makes scratch reuse happen.
    fn prepare_is_trivial(&self) -> bool {
        false
    }
    fn execute(&self, a: &Csr, b: &PreparedB) -> Result<EngineOutput, EngineError> {
        let ob = match b {
            PreparedB::OuterPooled(ob) => ob,
            other => return Err(wrong_operand(self, other)),
        };
        let src = ob.src.as_ref();
        if a.cols() != src.rows() {
            return Err(EngineError::ShapeMismatch {
                a: a.shape(),
                b: src.shape(),
            });
        }
        let (c_sparse, macs, bands) = spmm::outer::multiply_counted(a, src, &self.cfg, &ob.pool);
        let c = Dense::from_coo(&c_sparse.to_coo());
        Ok(EngineOutput {
            c,
            stats: ExecStats {
                dispatches: bands.max(1) as u64,
                real_pairs: macs,
                padded_pairs: macs,
                macs_issued: macs,
                threads: bands.max(1),
            },
        })
    }
}

// ----------------------------------------------------------------- tiled

/// The multi-threaded tiled executor behind the kernel contract (see
/// [`super::tiled`]): any registered caller gets parallel execution for
/// free by resolving `(Csr, Tiled)`.
pub struct TiledKernel {
    pub cfg: TiledConfig,
}

impl TiledKernel {
    pub fn new(cfg: TiledConfig) -> TiledKernel {
        TiledKernel { cfg }
    }
}

impl SpmmKernel for TiledKernel {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Tiled
    }
    fn format(&self) -> FormatKind {
        FormatKind::Csr
    }
    fn name(&self) -> &'static str {
        "tiled"
    }
    fn cost_hint(&self, a: &Csr, b: &Csr) -> CostHint {
        // expected tile-pair count from shared per-tile occupancy; total
        // work, NOT wall time — hints must stay unit-consistent across
        // kernels for Registry::select
        let bsz = self.cfg.block as f64;
        let pairs = super::kernel::expected_tile_pairs(a, b, self.cfg.block);
        let a_tiles = super::kernel::expected_tiles(a, self.cfg.block).max(1.0);
        // per pair: scan the A tile (bsz²) + MAC rows for its nonzeros
        let per_pair = bsz * bsz + (a.nnz() as f64 / a_tiles) * bsz;
        CostHint {
            flops: pairs * per_pair,
            prepare_words: (a.nnz() + b.nnz()) as f64,
        }
    }
    fn band_alignment(&self) -> usize {
        self.cfg.block
    }
    fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
        // B is blockized HERE, once — execute (and every shard worker
        // sharing this PreparedB) consumes the prebuilt grid
        Ok(PreparedB::Blocked(Arc::new(BlockedB::build(
            Arc::new(b.clone()),
            self.cfg.block,
        ))))
    }
    fn prepare_shared(&self, b: &Arc<Csr>) -> Result<PreparedB, EngineError> {
        Ok(PreparedB::Blocked(Arc::new(BlockedB::build(
            Arc::clone(b),
            self.cfg.block,
        ))))
    }
    fn prepare_is_trivial(&self) -> bool {
        false // blockization is a real O(nnz) build worth caching
    }
    fn execute(&self, a: &Csr, b: &PreparedB) -> Result<EngineOutput, EngineError> {
        let bb = match b {
            PreparedB::Blocked(bb) => bb,
            other => return Err(wrong_operand(self, other)),
        };
        if bb.block() != self.cfg.block {
            return Err(EngineError::ExecFailed(format!(
                "B blockized at {} but the tiled kernel tiles at {}",
                bb.block(),
                self.cfg.block
            )));
        }
        let (c, stats) = tiled::execute_blocked(a, &bb.grid, self.cfg.workers)?;
        Ok(EngineOutput { c, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::engine::learn::{Calibration, CostModel, FittedModel};
    use crate::spmm::dense::multiply as dense_ref;
    use crate::spmm::outer::OuterConfig;

    fn kernels() -> Vec<Box<dyn SpmmKernel>> {
        vec![
            Box::new(DenseOracleKernel),
            Box::new(GustavsonKernel),
            Box::new(GustavsonFastKernel::new(2)),
            Box::new(InnerKernel::csr()),
            Box::new(InnerKernel::incrs(InCrsParams::default())),
            Box::new(OuterKernel::new(OuterConfig { fan_in: 2, workers: 2 })),
            Box::new(TiledKernel::new(TiledConfig { block: 16, workers: 2 })),
        ]
    }

    #[test]
    fn every_kernel_matches_the_oracle() {
        let a = uniform(26, 40, 0.2, 1);
        let b = uniform(40, 31, 0.2, 2);
        let want = dense_ref(&a, &b);
        for k in kernels() {
            let out = k.run(&a, &b).unwrap_or_else(|e| panic!("{}: {e}", k.name()));
            assert!(
                out.c.max_abs_diff(&want) < 1e-3,
                "{} diverges from oracle",
                k.name()
            );
            assert!(out.stats.dispatches >= 1, "{}", k.name());
        }
    }

    #[test]
    fn prepare_shared_shares_the_csr_arc() {
        let b = Arc::new(uniform(12, 12, 0.3, 1));
        match GustavsonKernel.prepare_shared(&b).unwrap() {
            PreparedB::Csr(shared) => assert!(Arc::ptr_eq(&shared, &b)),
            other => panic!("unexpected prepared operand {other:?}"),
        }
        // conversion kernels still build their own representation
        match InnerKernel::incrs(InCrsParams::default()).prepare_shared(&b).unwrap() {
            PreparedB::InCrs(_) => {}
            other => panic!("unexpected prepared operand {other:?}"),
        }
    }

    #[test]
    fn kernels_reject_mismatched_prepared_operands() {
        let a = uniform(8, 8, 0.5, 1);
        let wrong = PreparedB::Dense(Arc::new(Dense::zeros(8, 8)));
        let err = GustavsonKernel.execute(&a, &wrong).unwrap_err();
        assert!(err.to_string().contains("expects B prepared"), "{err}");
    }

    #[test]
    fn kernels_reject_dimension_mismatch_with_typed_error() {
        let a = uniform(6, 7, 0.5, 1);
        let b = uniform(9, 6, 0.5, 2);
        for k in kernels() {
            let err = k.run(&a, &b).unwrap_err();
            assert!(
                matches!(err, EngineError::ShapeMismatch { a: (6, 7), b: (9, 6) }),
                "{}: {err}",
                k.name()
            );
        }
    }

    #[test]
    fn tiled_prepare_blockizes_once_and_execute_consumes_the_grid() {
        let k = TiledKernel::new(TiledConfig { block: 16, workers: 2 });
        let b = uniform(40, 31, 0.2, 2);
        let prepared = k.prepare(&b).unwrap();
        match &prepared {
            PreparedB::Blocked(bb) => {
                assert_eq!(bb.block(), 16);
                assert_eq!((bb.grid.rows, bb.grid.cols), (40, 31));
            }
            other => panic!("tiled prepare must blockize, got {other:?}"),
        }
        assert!(!k.prepare_is_trivial());
        let a = uniform(26, 40, 0.2, 1);
        let out = k.execute(&a, &prepared).unwrap();
        assert!(out.c.max_abs_diff(&dense_ref(&a, &b)) < 1e-3);
        // a grid built at a different tile size is rejected, not re-blockized
        let foreign = TiledKernel::new(TiledConfig { block: 8, workers: 1 })
            .prepare(&b)
            .unwrap();
        let err = k.execute(&a, &foreign).unwrap_err();
        assert!(err.to_string().contains("blockized at"), "{err}");
    }

    #[test]
    fn inner_incrs_adopts_matching_native_operands() {
        let k = InnerKernel::incrs(InCrsParams::default());
        let b = uniform(24, 300, 0.2, 9);
        let b_arc = Arc::new(b.clone());
        let native = Arc::new(InCrs::from_csr(&b).unwrap());
        let op = MatrixOperand::InCrs(Arc::clone(&native));
        match k.prepare_operand(&op, &b_arc).unwrap() {
            PreparedB::InCrs(adopted) => assert!(Arc::ptr_eq(&adopted, &native)),
            other => panic!("expected adoption, got {other:?}"),
        }
        // mismatched geometry falls back to a rebuild
        let other_params = InCrsParams { section: 64, block: 8 };
        let foreign = Arc::new(InCrs::from_csr_params(&b, other_params).unwrap());
        match k
            .prepare_operand(&MatrixOperand::InCrs(Arc::clone(&foreign)), &b_arc)
            .unwrap()
        {
            PreparedB::InCrs(built) => assert!(!Arc::ptr_eq(&built, &foreign)),
            other => panic!("expected rebuild, got {other:?}"),
        }
        // and the cost model credits ONLY the adoptable path: a matching
        // native InCRS is credited, a mismatched-params one is charged
        // like any conversion, CSR-native is free
        assert!(k.ingest_cost(&b, Some(&op)) < 0.0);
        let foreign_op = MatrixOperand::InCrs(Arc::clone(&foreign));
        assert!(k.ingest_cost(&b, Some(&foreign_op)) > 0.0);
        assert_eq!(k.ingest_cost(&b, None), 0.0);
        let coo_op = MatrixOperand::from(b.to_coo());
        assert!(GustavsonKernel.ingest_cost(&b, Some(&coo_op)) > 0.0);
    }

    #[test]
    fn fast_gustavson_is_bit_identical_to_scalar_at_any_worker_count() {
        let a = uniform(60, 80, 0.18, 40);
        let b = uniform(80, 52, 0.18, 41);
        let want = GustavsonKernel.run(&a, &b).unwrap().c;
        for workers in [1usize, 2, 3, 7] {
            let k = GustavsonFastKernel::new(workers);
            let out = k.run(&a, &b).unwrap();
            assert_eq!(
                want.bit_pattern(),
                out.c.bit_pattern(),
                "{workers} workers diverge bitwise from scalar Gustavson"
            );
            assert!(out.stats.threads <= workers);
            assert_eq!(out.stats.dispatches as usize, out.stats.threads);
        }
        // MAC accounting matches the scalar kernel's
        let scalar = GustavsonKernel.run(&a, &b).unwrap().stats.real_pairs;
        let fast = GustavsonFastKernel::new(4).run(&a, &b).unwrap().stats.real_pairs;
        assert_eq!(scalar, fast);
    }

    #[test]
    fn fast_gustavson_pool_is_reused_across_executes_and_shared_arcs() {
        let k = GustavsonFastKernel::new(1);
        let a = uniform(40, 48, 0.2, 42);
        let b = Arc::new(uniform(48, 36, 0.2, 43));
        let prepared = k.prepare_shared(&b).unwrap();
        let pool = match &prepared {
            PreparedB::Pooled(pb) => {
                assert!(Arc::ptr_eq(&pb.src, &b), "prepare_shared must Arc-share B");
                &pb.pool
            }
            other => panic!("unexpected prepared operand {other:?}"),
        };
        assert!(!k.prepare_is_trivial(), "pool must route through the PreparedCache");
        // serial kernel: deterministic counts — one allocation ever, every
        // later execute against the same PreparedB reuses it
        k.execute(&a, &prepared).unwrap();
        assert_eq!((pool.hits(), pool.misses(), pool.pooled()), (0, 1, 1));
        k.execute(&a, &prepared).unwrap();
        k.execute(&a, &prepared).unwrap();
        assert_eq!((pool.hits(), pool.misses(), pool.pooled()), (2, 1, 1));
        // a parallel kernel drawing on the SAME prepared operand (the shard
        // workers' shape) keeps reusing the pool: everything it checks out
        // is returned, and the workspace count never exceeds the peak
        // concurrency it actually needed
        let k3 = GustavsonFastKernel::new(3);
        k3.execute(&a, &prepared).unwrap();
        let allocated = pool.misses();
        assert_eq!(pool.pooled() as u64, allocated, "workspaces not returned");
        assert!(allocated <= 3, "over-allocated: {allocated}");
        assert!(pool.hits() >= 3, "parallel execute bypassed the pool");
    }

    #[test]
    fn outer_kernel_is_bit_identical_and_pools_merge_buffers() {
        let k = OuterKernel::new(OuterConfig { fan_in: 2, workers: 2 });
        let a = uniform(48, 64, 0.08, 21);
        let b = Arc::new(uniform(64, 40, 0.08, 22));
        let want = GustavsonKernel.run(&a, &b).unwrap();
        let prepared = k.prepare_shared(&b).unwrap();
        let pool = match &prepared {
            PreparedB::OuterPooled(ob) => {
                assert!(Arc::ptr_eq(&ob.src, &b), "prepare_shared must Arc-share B");
                &ob.pool
            }
            other => panic!("unexpected prepared operand {other:?}"),
        };
        assert!(!k.prepare_is_trivial(), "pool must route through the PreparedCache");
        let out = k.execute(&a, &prepared).unwrap();
        assert_eq!(
            out.c.bit_pattern(),
            want.c.bit_pattern(),
            "outer diverges bitwise from scalar Gustavson"
        );
        assert_eq!(out.stats.real_pairs, want.stats.real_pairs, "MAC accounting");
        // every merge buffer returns to the pool, and later executes
        // against the same PreparedB reuse them instead of allocating
        let allocated = pool.misses();
        assert!(allocated > 0);
        assert_eq!(pool.pooled() as u64, allocated, "merge buffers leaked");
        k.execute(&a, &prepared).unwrap();
        assert!(pool.hits() > 0, "second execute bypassed the pool");
        // CSC-native ingestion is credited the direct-transpose tier,
        // below the generic COO round-trip other foreign formats pay
        let csc_op = MatrixOperand::from(b.as_ref().clone())
            .convert(FormatKind::Csc)
            .unwrap();
        let coo_op = MatrixOperand::from(b.to_coo());
        assert!(k.ingest_cost(&b, Some(&csc_op)) > 0.0);
        assert!(k.ingest_cost(&b, Some(&csc_op)) < k.ingest_cost(&b, Some(&coo_op)));
        assert_eq!(k.ingest_cost(&b, None), 0.0);
    }

    #[test]
    fn outer_cost_hint_uncalibrated_matches_static_constant() {
        let k = OuterKernel::new(OuterConfig { fan_in: 4, workers: 2 });
        let a = uniform(60, 80, 0.05, 31);
        let b = uniform(80, 50, 0.05, 32);
        // the pre-fit formula, reproduced by hand: no model attached ⇒
        // the hint must be bit-for-bit the original constant-weight form
        let products = a.nnz() as f64 * (b.nnz() as f64 / b.rows().max(1) as f64);
        let runs = a.cols().min(a.nnz()).max(2) as f64;
        let rounds = (runs.ln() / 4f64.ln()).ceil().max(1.0);
        let want = products * (1.0 + rounds) + (2 * a.nnz() + a.cols()) as f64;
        assert_eq!(k.cost_hint(&a, &b).flops.to_bits(), want.to_bits());
        // an attached but EMPTY model (nothing calibrated yet) is the same
        k.observe_model(&CostModel::default());
        assert_eq!(k.cost_hint(&a, &b).flops.to_bits(), want.to_bits());
    }

    #[test]
    fn outer_cost_hint_uses_fitted_merge_round_scale() {
        let k = OuterKernel::new(OuterConfig { fan_in: 4, workers: 2 });
        let a = uniform(60, 80, 0.05, 31);
        let b = uniform(80, 50, 0.05, 32);
        let uncalibrated = k.cost_hint(&a, &b).flops;
        let cal = |scale: f64| Calibration { scale, samples: 8, mean_abs_err_us: 0.5 };

        let model = CostModel::default();
        let mut fm = FittedModel::default();
        fm.insert((FormatKind::Csc, Algorithm::OuterProduct), cal(3.0));
        fm.insert((FormatKind::Csr, Algorithm::GustavsonFast), cal(1.0));
        model.publish(fm);
        k.observe_model(&model);
        // weight = 3.0/1.0: exactly the calibrated formula, and dearer
        // than the static constant (so selection actually moves)
        let products = a.nnz() as f64 * (b.nnz() as f64 / b.rows().max(1) as f64);
        let runs = a.cols().min(a.nnz()).max(2) as f64;
        let rounds = (runs.ln() / 4f64.ln()).ceil().max(1.0);
        let want = products * (1.0 + rounds * 3.0) + (2 * a.nnz() + a.cols()) as f64;
        let fitted_hint = k.cost_hint(&a, &b).flops;
        assert_eq!(fitted_hint.to_bits(), want.to_bits());
        assert!(fitted_hint > uncalibrated);

        // extreme ratios clamp to [0.25, 4] so one bad refit can't flip
        // selection wholesale
        let mut fm = FittedModel::default();
        fm.insert((FormatKind::Csc, Algorithm::OuterProduct), cal(100.0));
        fm.insert((FormatKind::Csr, Algorithm::GustavsonFast), cal(1.0));
        model.publish(fm);
        let clamped = products * (1.0 + rounds * 4.0) + (2 * a.nnz() + a.cols()) as f64;
        assert_eq!(k.cost_hint(&a, &b).flops.to_bits(), clamped.to_bits());

        // a one-sided fit (reference kernel uncalibrated) falls back to
        // the static constant instead of inventing a ratio
        let mut fm = FittedModel::default();
        fm.insert((FormatKind::Csc, Algorithm::OuterProduct), cal(3.0));
        model.publish(fm);
        assert_eq!(k.cost_hint(&a, &b).flops.to_bits(), uncalibrated.to_bits());
    }

    #[test]
    fn registry_set_cost_model_reaches_outer_merge_round_fit() {
        let mut r = crate::engine::Registry::new();
        r.register(Arc::new(OuterKernel::new(OuterConfig { fan_in: 4, workers: 1 })));
        let a = uniform(60, 80, 0.05, 31);
        let b = uniform(80, 50, 0.05, 32);
        let k = r.resolve(FormatKind::Csc, Algorithm::OuterProduct).unwrap();
        let before = k.cost_hint(&a, &b).flops;
        let model = CostModel::default();
        let mut fm = FittedModel::default();
        let cal = |scale: f64| Calibration { scale, samples: 4, mean_abs_err_us: 0.5 };
        fm.insert((FormatKind::Csc, Algorithm::OuterProduct), cal(2.0));
        fm.insert((FormatKind::Csr, Algorithm::GustavsonFast), cal(1.0));
        model.publish(fm);
        r.set_cost_model(model);
        // the registry fan-out must have attached the handle to the live
        // kernel Arc — the hint moves without re-registering anything
        assert!(k.cost_hint(&a, &b).flops > before);
    }

    #[test]
    fn inner_incrs_negotiates_a_sibling_for_foreign_params() {
        let k = InnerKernel::incrs(InCrsParams::default());
        let b = uniform(24, 300, 0.2, 9);
        let foreign_params = InCrsParams { section: 64, block: 8 };
        let foreign = Arc::new(InCrs::from_csr_params(&b, foreign_params).unwrap());
        let op = MatrixOperand::InCrs(Arc::clone(&foreign));
        let negotiated = k.negotiate(&op).expect("foreign params must negotiate a sibling");
        // the sibling adopts the native operand outright: credited ingest,
        // Arc-shared arrays
        assert!(negotiated.ingest_cost(&b, Some(&op)) < 0.0);
        let b_arc = Arc::new(b.clone());
        match negotiated.prepare_operand(&op, &b_arc).unwrap() {
            PreparedB::InCrs(adopted) => assert!(Arc::ptr_eq(&adopted, &foreign)),
            other => panic!("expected adoption, got {other:?}"),
        }
        // matching params need no sibling; non-InCRS kernels never negotiate
        let matching = MatrixOperand::InCrs(Arc::new(InCrs::from_csr(&b).unwrap()));
        assert!(k.negotiate(&matching).is_none());
        assert!(GustavsonKernel.negotiate(&op).is_none());
        assert!(InnerKernel::csr().negotiate(&op).is_none());
    }

    #[test]
    fn cost_hints_rank_oracle_last_on_sparse_inputs() {
        let a = uniform(200, 400, 0.01, 3);
        let b = uniform(400, 300, 0.01, 4);
        let dense_cost = DenseOracleKernel.cost_hint(&a, &b).total();
        let gust_cost = GustavsonKernel.cost_hint(&a, &b).total();
        assert!(gust_cost < dense_cost);
    }
}
