//! Multi-threaded tiled SpMM executor — parallel execution over the
//! `blocks::BlockGrid` tile pairs with deterministic reduction order.
//!
//! The work decomposition mirrors the paper's mesh: both operands are
//! blocked at `block × block` granularity and A/B tiles are intersected
//! along K (the comparator step). The unit of scheduling is one *output*
//! tile together with its K-ordered pair list, so
//!
//! * no two workers ever write the same output cell (no locks, no atomics),
//! * each output tile is accumulated by exactly one worker in ascending K
//!   order — the reduction order is fixed, so results are **bit-identical**
//!   for any worker count, and
//! * each worker fills one preallocated scratch buffer for all of its tiles
//!   (per-worker scratch reuse; no per-tile allocation in the hot loop).
//!
//! Load balance: output tiles carry very different pair counts, so the
//! contiguous partition is weighted by pairs rather than by tile count.

use std::collections::BTreeMap;

use crate::formats::csr::Csr;
use crate::formats::dense::Dense;
use crate::formats::traits::SparseMatrix;
use crate::spmm::blocks::{blockize, BlockGrid};

use super::error::EngineError;
use super::kernel::ExecStats;

/// Tiled executor configuration: tile size and worker count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TiledConfig {
    pub block: usize,
    /// 1 = serial (same code path, same reduction order).
    pub workers: usize,
}

impl Default for TiledConfig {
    fn default() -> Self {
        TiledConfig { block: 32, workers: 1 }
    }
}

/// Split task indices `0..n` into at most `workers` contiguous chunks with
/// nearly equal total `weight` (greedy prefix cuts at the ideal boundaries).
/// Shared with `engine::shard`, whose planner cuts row bands over
/// per-block-row tile-pair weights with the same heuristic.
pub(crate) fn partition_by_weight(weights: &[usize], workers: usize) -> Vec<(usize, usize)> {
    let n = weights.len();
    if n == 0 || workers == 0 {
        return Vec::new();
    }
    let w = workers.min(n);
    let total: usize = weights.iter().sum();
    let mut bounds = Vec::with_capacity(w);
    let mut start = 0usize;
    let mut acc = 0usize;
    for (i, &wt) in weights.iter().enumerate() {
        acc += wt;
        // cut when this chunk reached its proportional share of the total
        // weight, always leaving at least one task for the final chunk
        let chunks_done = bounds.len();
        let target = (total * (chunks_done + 1) + w - 1) / w;
        if acc >= target && chunks_done < w - 1 && i + 1 < n {
            bounds.push((start, i + 1));
            start = i + 1;
        }
    }
    bounds.push((start, n));
    bounds
}

/// C = A × B through the blocked tile-pair decomposition, executed by
/// `cfg.workers` std threads. Returns the dense product and its accounting.
/// Convenience wrapper over [`execute_blocked`] that blockizes `B` itself —
/// the kernel path (`TiledKernel`) blockizes once in `prepare` instead.
pub fn execute(a: &Csr, b: &Csr, cfg: TiledConfig) -> Result<(Dense, ExecStats), EngineError> {
    if a.cols() != b.rows() {
        return Err(EngineError::ShapeMismatch {
            a: a.shape(),
            b: b.shape(),
        });
    }
    execute_blocked(a, &blockize(b, cfg.block), cfg.workers)
}

/// C = A × B where `B` arrives pre-blockized (`gb`, built once by
/// `TiledKernel::prepare` and shared across jobs, micro-batches, and shard
/// workers). The tile size is `gb.block`; `A` is blockized per call (it is
/// the per-job/per-band operand).
pub fn execute_blocked(
    a: &Csr,
    gb: &BlockGrid,
    workers: usize,
) -> Result<(Dense, ExecStats), EngineError> {
    if a.cols() != gb.rows {
        return Err(EngineError::ShapeMismatch {
            a: a.shape(),
            b: (gb.rows, gb.cols),
        });
    }
    let bsz = gb.block;
    let (m, n) = (a.rows(), gb.cols);
    let ga = blockize(a, bsz);

    // index B tiles by K-block for the intersection
    let mut b_by_k: Vec<Vec<(u32, &Vec<f32>)>> = vec![Vec::new(); gb.grid_rows];
    for (&(bk, bj), tile) in &gb.tiles {
        b_by_k[bk as usize].push((bj, tile));
    }

    // one task per output tile; BTreeMap iteration keeps the per-tile pair
    // list in ascending K order (the deterministic reduction order)
    let mut by_out: BTreeMap<(u32, u32), Vec<(&Vec<f32>, &Vec<f32>)>> = BTreeMap::new();
    for (&(bi, bk), a_tile) in &ga.tiles {
        for &(bj, b_tile) in &b_by_k[bk as usize] {
            by_out.entry((bi, bj)).or_default().push((a_tile, b_tile));
        }
    }
    let tasks: Vec<((u32, u32), Vec<(&Vec<f32>, &Vec<f32>)>)> = by_out.into_iter().collect();
    let total_pairs: usize = tasks.iter().map(|(_, p)| p.len()).sum();

    let weights: Vec<usize> = tasks.iter().map(|(_, p)| p.len()).collect();
    let bounds = partition_by_weight(&weights, workers.max(1));

    // each worker owns one scratch buffer covering all of its output
    // tiles; every handle is joined inside the scope (a panicked worker
    // must not escape as a scope re-panic) and lost workers surface as a
    // typed error after the scope closes
    let joined: Vec<std::thread::Result<Vec<f32>>> = std::thread::scope(|s| {
        let handles: Vec<_> = bounds
            .iter()
            .map(|&(lo, hi)| {
                let chunk = &tasks[lo..hi];
                s.spawn(move || {
                    let mut scratch = vec![0.0f32; chunk.len() * bsz * bsz];
                    for (t, (_, pairs)) in chunk.iter().enumerate() {
                        let acc = &mut scratch[t * bsz * bsz..(t + 1) * bsz * bsz];
                        for (a_tile, b_tile) in pairs {
                            mac_tile(acc, a_tile, b_tile, bsz);
                        }
                    }
                    scratch
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join()).collect()
    });
    let mut buffers = Vec::with_capacity(joined.len());
    for r in joined {
        match r {
            Ok(buf) => buffers.push(buf),
            Err(_) => return Err(EngineError::ExecFailed("tile worker panicked".into())),
        }
    }

    // scatter: every output tile is written exactly once (crop ragged edges)
    let mut c = Dense::zeros(m, n);
    for (&(lo, hi), buf) in bounds.iter().zip(&buffers) {
        for (t, &((bi, bj), _)) in tasks[lo..hi].iter().enumerate() {
            let tile = &buf[t * bsz * bsz..(t + 1) * bsz * bsz];
            let r0 = bi as usize * bsz;
            let c0 = bj as usize * bsz;
            let r_lim = bsz.min(m - r0);
            let c_lim = bsz.min(n - c0);
            for r in 0..r_lim {
                for cc in 0..c_lim {
                    *c.at_mut(r0 + r, c0 + cc) = tile[r * bsz + cc];
                }
            }
        }
    }

    let stats = ExecStats {
        dispatches: tasks.len() as u64,
        real_pairs: total_pairs as u64,
        padded_pairs: total_pairs as u64,
        macs_issued: total_pairs as u64 * (bsz * bsz * bsz) as u64,
        threads: bounds.len().max(1),
    };
    Ok((c, stats))
}

/// acc += a_tile × b_tile (dense `bsz²` row-major tiles, zero-skip on A).
#[inline]
fn mac_tile(acc: &mut [f32], a_tile: &[f32], b_tile: &[f32], bsz: usize) {
    for i in 0..bsz {
        for k in 0..bsz {
            let av = a_tile[i * bsz + k];
            if av == 0.0 {
                continue;
            }
            let row = &b_tile[k * bsz..(k + 1) * bsz];
            let out = &mut acc[i * bsz..(i + 1) * bsz];
            for j in 0..bsz {
                out[j] += av * row[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::spmm::dense::multiply as dense_ref;

    #[test]
    fn matches_dense_reference() {
        for seed in 0..3 {
            let a = uniform(45, 70, 0.15, seed);
            let b = uniform(70, 38, 0.18, seed + 7);
            let (c, stats) = execute(&a, &b, TiledConfig { block: 16, workers: 3 }).unwrap();
            let want = dense_ref(&a, &b);
            assert!(c.max_abs_diff(&want) < 1e-3, "seed {seed}");
            assert!(stats.real_pairs > 0);
        }
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let a = uniform(64, 96, 0.2, 11);
        let b = uniform(96, 80, 0.2, 12);
        let (c1, s1) = execute(&a, &b, TiledConfig { block: 16, workers: 1 }).unwrap();
        for workers in [2, 3, 4, 7] {
            let (cw, sw) = execute(&a, &b, TiledConfig { block: 16, workers }).unwrap();
            assert_eq!(c1.data, cw.data, "workers={workers} not bit-identical");
            assert_eq!(s1.real_pairs, sw.real_pairs);
        }
    }

    #[test]
    fn dimension_mismatch_is_an_error() {
        let a = uniform(8, 9, 0.5, 1);
        let b = uniform(10, 8, 0.5, 2);
        assert!(execute(&a, &b, TiledConfig::default()).is_err());
    }

    #[test]
    fn empty_operands() {
        let a = uniform(20, 30, 0.0, 1);
        let b = uniform(30, 20, 0.3, 2);
        let (c, stats) = execute(&a, &b, TiledConfig { block: 8, workers: 4 }).unwrap();
        assert!(c.data.iter().all(|&v| v == 0.0));
        assert_eq!(stats.real_pairs, 0);
        assert_eq!(stats.dispatches, 0);
    }

    #[test]
    fn prebuilt_grid_is_bit_identical_to_the_wrapper() {
        let a = uniform(45, 70, 0.15, 3);
        let b = uniform(70, 38, 0.18, 4);
        let (want, ws) = execute(&a, &b, TiledConfig { block: 16, workers: 3 }).unwrap();
        let gb = blockize(&b, 16);
        let (got, gs) = execute_blocked(&a, &gb, 3).unwrap();
        assert_eq!(want.data, got.data, "prebuilt grid changed bits");
        assert_eq!(ws.real_pairs, gs.real_pairs);
        // shape mismatch is typed on the blocked path too (A has 60
        // columns vs the grid's 70 rows)
        let bad = uniform(9, 60, 0.2, 5);
        assert!(matches!(
            execute_blocked(&bad, &gb, 2),
            Err(EngineError::ShapeMismatch { a: (9, 60), b: (70, 38) })
        ));
    }

    #[test]
    fn weighted_partition_covers_exactly_once() {
        for (weights, workers) in [
            (vec![1usize; 10], 3usize),
            (vec![100, 1, 1, 1, 1, 1], 3),
            (vec![5], 4),
            (vec![2, 2, 2, 2], 4),
        ] {
            let b = partition_by_weight(&weights, workers);
            assert!(!b.is_empty());
            assert_eq!(b[0].0, 0);
            assert_eq!(b.last().unwrap().1, weights.len());
            for pair in b.windows(2) {
                assert_eq!(pair[0].1, pair[1].0);
            }
            assert!(b.len() <= workers.min(weights.len()));
            assert!(b.iter().all(|&(lo, hi)| hi > lo), "{b:?}");
        }
        assert!(partition_by_weight(&[], 4).is_empty());
    }
}
