//! Socket shard transport and the worker it speaks to: cross-host
//! execution of the shard planner's row bands over length-prefixed
//! [`wire`](super::transport::wire) frames — hand-rolled TCP, no new
//! dependencies, bit-identical output.
//!
//! # Topology
//!
//! One **leader** (the process running [`SocketTransport`]) connects to N
//! **workers** (processes running [`serve`], the `worker` CLI subcommand).
//! Per job the leader:
//!
//! 1. **replicates** the shared `PreparedB` to every live worker that has
//!    not yet staged it, keyed by the content fingerprint
//!    ([`super::transport::content_key`]) — a worker's staged cache is the
//!    remote mirror of the coordinator's `PreparedCache`, and reuse across
//!    jobs is metered (`prepare_reuse` vs `prepare_replications`);
//! 2. **routes** bands by the shard planner's weights: heaviest band
//!    first, each to the least-loaded live worker (deterministic
//!    index-order tie-break);
//! 3. **collects** replies on per-worker reader threads feeding one event
//!    queue, enforcing the [`RetryPolicy`]: a band unanswered past
//!    `band_timeout` is resubmitted (bounded by `retry_budget`); a
//!    straggler past `hedge_after` is *hedged* — duplicated to another
//!    live worker, first answer wins (`hedges_won`); a dead worker loses
//!    only its in-flight bands, which are resubmitted to survivors
//!    (`workers_lost`, `band_retries`) — the socket analogue of the
//!    in-process executor's named-lost-shards path, except here the job
//!    survives.
//!
//! The job fails typed only when a band exhausts its retry budget or no
//! live worker remains — and the error names the unfinished shards.
//!
//! # Why results stay bit-identical
//!
//! A worker executes exactly the band slice the in-process transport would
//! have handed a thread, against a `PreparedB` rebuilt from the same CSR
//! bits, with the same kernel resolved by `(format, algorithm)` from its
//! own registry. Matrix values cross the wire as IEEE-754 bit patterns,
//! and the leader's merge is the transport-blind row copy in
//! `shard::execute_with` — so retries, hedges, and re-placements can
//! change *where* a band runs but never *what* it returns. Leader and
//! workers must register comparable kernels (same `Geometry`, worker
//! counts may differ — thread counts never change result bits; the
//! `worker` subcommand takes the same kernel flags as `spmm`/`serve`).

use std::collections::{BTreeMap, BTreeSet};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::formats::csr::Csr;
use crate::util::lock_unpoisoned;

use super::error::EngineError;
use super::kernel::{EngineOutput, PreparedB};
use super::prepared::PreparedKey;
use super::registry::Registry;
use super::transport::wire::{decode_frame, encode_frame, Frame};
use super::transport::{
    BandJob, BandResult, BandRun, RetryPolicy, ShardTransport, TransportCounters,
};

/// Upper bound on one frame's byte length — a desynchronized or hostile
/// peer cannot make us allocate unboundedly.
const MAX_FRAME: usize = 1 << 30;

/// How often the leader's event loop wakes to sweep timeouts and hedges.
const TICK: Duration = Duration::from_millis(20);

/// Circuit-breaker backoff for re-admitting a lost worker: first probe is
/// immediate (next run), then delays double per consecutive failure.
const RECONNECT_BASE: Duration = Duration::from_millis(50);
/// Backoff ceiling — a long-dead peer is probed at most this rarely.
const RECONNECT_MAX: Duration = Duration::from_secs(30);
/// Dial + handshake budget for one re-admission probe, so probing a
/// black-holed peer can't stall a live run.
const RECONNECT_PROBE: Duration = Duration::from_millis(250);

fn write_frame(stream: &mut TcpStream, frame: &Frame) -> io::Result<()> {
    let bytes = encode_frame(frame);
    let mut msg = Vec::with_capacity(4 + bytes.len());
    msg.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    msg.extend_from_slice(&bytes);
    stream.write_all(&msg)
}

fn read_frame(stream: &mut TcpStream) -> io::Result<Vec<u8>> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "oversized frame"));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Ok(body)
}

fn exec_err(msg: String) -> EngineError {
    EngineError::ExecFailed(msg)
}

// ---------------------------------------------------------------------------
// Leader side
// ---------------------------------------------------------------------------

struct WorkerLink {
    addr: String,
    stream: TcpStream,
    /// Content keys staged on this worker (its remote prepared cache).
    staged: BTreeSet<PreparedKey>,
    alive: bool,
    /// Circuit breaker: consecutive failed re-admission probes since the
    /// link died (drives the exponential backoff).
    reconnect_failures: u32,
    /// Earliest instant the next re-admission probe may run (`None` =
    /// probe immediately on the next run).
    next_retry: Option<Instant>,
}

struct LinkState {
    workers: Vec<WorkerLink>,
    /// Globally unique submission ids — never reused, so a late reply from
    /// a previous job's hedge loser is recognized as stale and ignored.
    next_seq: u64,
}

/// The cross-host [`ShardTransport`]: ships bands to `worker` processes as
/// wire frames, replicates `B` by content fingerprint, and survives worker
/// loss/stragglers per the [`RetryPolicy`]. Jobs serialize through one
/// transport (the connection set is a shared resource); clone-free band
/// routing keeps each run deterministic given the reply timing.
pub struct SocketTransport {
    state: Mutex<LinkState>,
    policy: RetryPolicy,
}

impl SocketTransport {
    /// Connect and handshake every peer (`host:port`) with the default
    /// [`RetryPolicy`]. Fails typed if any peer is unreachable or speaks a
    /// different wire version — a half-connected fleet would silently
    /// shrink capacity.
    pub fn connect(peers: &[String]) -> Result<SocketTransport, EngineError> {
        SocketTransport::connect_with(peers, RetryPolicy::default())
    }

    /// [`SocketTransport::connect`] with an explicit policy (tests use
    /// tight timeouts; batch jobs may want a larger hedge threshold).
    pub fn connect_with(
        peers: &[String],
        policy: RetryPolicy,
    ) -> Result<SocketTransport, EngineError> {
        if peers.is_empty() {
            return Err(exec_err("socket transport: no worker peers given".into()));
        }
        let mut workers = Vec::with_capacity(peers.len());
        for addr in peers {
            let stream = dial(addr, None)?;
            workers.push(WorkerLink {
                addr: addr.clone(),
                stream,
                staged: BTreeSet::new(),
                alive: true,
                reconnect_failures: 0,
                next_retry: None,
            });
        }
        Ok(SocketTransport {
            state: Mutex::new(LinkState { workers, next_seq: 0 }),
            policy,
        })
    }

    pub fn policy(&self) -> RetryPolicy {
        self.policy
    }

    /// Workers still connected (drops as runs observe failures).
    pub fn live_workers(&self) -> usize {
        lock_unpoisoned(&self.state)
            .workers
            .iter()
            .filter(|w| w.alive)
            .count()
    }

    /// Peer addresses, in connect order (for logs and `JobOutput`).
    pub fn peer_addrs(&self) -> Vec<String> {
        lock_unpoisoned(&self.state)
            .workers
            .iter()
            .map(|w| w.addr.clone())
            .collect()
    }

    /// Circuit-breaker re-admission: probe every lost peer whose backoff
    /// window elapsed. A probe that dials and re-handshakes replaces the
    /// link's stream, clears its staged view (the revived process holds
    /// nothing — B re-replicates lazily through the normal staging path),
    /// and returns the worker to the routable pool; a failed probe doubles
    /// the backoff. Runs at the top of every [`SocketTransport::run`], so
    /// a dead peer stays dead for at most one run plus its backoff.
    fn try_readmit(st: &mut LinkState, counters: &mut TransportCounters) {
        let now = Instant::now();
        for w in st.workers.iter_mut().filter(|w| !w.alive) {
            if let Some(t) = w.next_retry {
                if now < t {
                    continue; // breaker still open
                }
            }
            match dial(&w.addr, Some(RECONNECT_PROBE)) {
                Ok(stream) => {
                    w.stream = stream;
                    w.staged.clear();
                    w.alive = true;
                    w.reconnect_failures = 0;
                    w.next_retry = None;
                    counters.workers_readmitted += 1;
                }
                Err(_) => {
                    let shift = w.reconnect_failures.min(9);
                    w.reconnect_failures = w.reconnect_failures.saturating_add(1);
                    let delay = RECONNECT_BASE
                        .saturating_mul(1u32 << shift)
                        .min(RECONNECT_MAX);
                    w.next_retry = Some(now + delay);
                }
            }
        }
    }
}

/// Dial a worker and complete the Hello/HelloAck handshake. `timeout`
/// bounds both the connect and the handshake read (re-admission probes);
/// `None` blocks, as the initial fleet connect always has.
fn dial(addr: &str, timeout: Option<Duration>) -> Result<TcpStream, EngineError> {
    let mut stream = match timeout {
        None => TcpStream::connect(addr)
            .map_err(|e| exec_err(format!("socket transport: connect {addr}: {e}")))?,
        Some(t) => {
            let addrs = addr
                .to_socket_addrs()
                .map_err(|e| exec_err(format!("socket transport: resolve {addr}: {e}")))?;
            let mut last: Option<io::Error> = None;
            let mut conn: Option<TcpStream> = None;
            for sa in addrs {
                match TcpStream::connect_timeout(&sa, t) {
                    Ok(s) => {
                        conn = Some(s);
                        break;
                    }
                    Err(e) => last = Some(e),
                }
            }
            match conn {
                Some(s) => s,
                None => {
                    let detail = match last {
                        Some(e) => e.to_string(),
                        None => "no resolved addresses".into(),
                    };
                    return Err(exec_err(format!(
                        "socket transport: connect {addr}: {detail}"
                    )));
                }
            }
        }
    };
    let _ = stream.set_nodelay(true);
    // bound the handshake read so a black-holed peer can't stall a probe;
    // the reader threads set their own timeout after this returns
    let _ = stream.set_read_timeout(timeout);
    write_frame(&mut stream, &Frame::Hello)
        .map_err(|e| exec_err(format!("socket transport: hello {addr}: {e}")))?;
    let body = read_frame(&mut stream)
        .map_err(|e| exec_err(format!("socket transport: handshake {addr}: {e}")))?;
    let _ = stream.set_read_timeout(None);
    match decode_frame(&body) {
        Ok(Frame::HelloAck) => Ok(stream),
        Ok(other) => Err(exec_err(format!(
            "socket transport: {addr} answered hello with {other:?}"
        ))),
        Err(e) => Err(exec_err(format!("socket transport: {addr} handshake: {e}"))),
    }
}

/// A band submission in flight.
struct Pending {
    shard: usize,
    rows: (usize, usize),
    worker: usize,
    sent: Instant,
    hedge: bool,
}

/// Per-shard delivery bookkeeping.
struct Slot {
    rows: (usize, usize),
    weight: usize,
    /// Submissions so far (first + retries; hedges don't count).
    attempts: u32,
    hedged: bool,
    done: bool,
}

enum Event {
    Frame(usize, Vec<u8>),
    Dead(usize),
}

/// Least-loaded live worker, preferring not-`exclude` when another live
/// worker exists; index order breaks ties, keeping placement deterministic.
fn pick_worker(
    workers: &[WorkerLink],
    loads: &[usize],
    exclude: Option<usize>,
) -> Option<usize> {
    let candidate = |skip: Option<usize>| {
        (0..workers.len())
            .filter(|&i| workers[i].alive && Some(i) != skip)
            .min_by_key(|&i| loads[i])
    };
    candidate(exclude).or_else(|| candidate(None))
}

fn reader_loop(idx: usize, mut stream: TcpStream, tx: Sender<Event>, stop: &AtomicBool) {
    let mut acc: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 64 * 1024];
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                let _ = tx.send(Event::Dead(idx));
                return;
            }
            Ok(n) => {
                acc.extend_from_slice(&chunk[..n]);
                loop {
                    if acc.len() < 4 {
                        break;
                    }
                    let len =
                        u32::from_le_bytes([acc[0], acc[1], acc[2], acc[3]]) as usize;
                    if len > MAX_FRAME {
                        // stream desync: unrecoverable, drop the worker
                        let _ = tx.send(Event::Dead(idx));
                        return;
                    }
                    if acc.len() < 4 + len {
                        break;
                    }
                    let frame = acc[4..4 + len].to_vec();
                    acc.drain(..4 + len);
                    if tx.send(Event::Frame(idx, frame)).is_err() {
                        return;
                    }
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(_) => {
                let _ = tx.send(Event::Dead(idx));
                return;
            }
        }
    }
}

impl ShardTransport for SocketTransport {
    fn name(&self) -> &'static str {
        "socket"
    }

    fn run(&self, job: &BandJob<'_>) -> Result<BandRun, EngineError> {
        let mut guard = lock_unpoisoned(&self.state);
        let st = &mut *guard;
        let mut counters = TransportCounters::default();
        let total = job.plan.bands.len();
        if total == 0 {
            return Ok(BandRun { bands: Vec::new(), counters });
        }

        // re-admit lost workers before placement: a revived peer joins
        // this run's routable pool (and re-stages B below)
        Self::try_readmit(st, &mut counters);

        // the job's remaining deadline budget caps every band attempt's
        // timeout — a remote band can never out-wait the job that asked
        // for it (floored at one tick so a nearly-spent budget degrades
        // to fast typed retries, not a spin)
        let band_timeout = match job.deadline {
            Some(d) => self
                .policy
                .band_timeout
                .min(d.saturating_duration_since(Instant::now()))
                .max(TICK),
            None => self.policy.band_timeout,
        };

        // --- stage B on every live worker missing it (content-keyed) ---
        let mut lost_on_stage = Vec::new();
        for (idx, w) in st.workers.iter_mut().enumerate().filter(|(_, w)| w.alive) {
            if w.staged.contains(&job.key) {
                counters.prepare_reuse += 1;
                continue;
            }
            let frame = Frame::Prepare {
                key: job.key,
                prepared: job.prepared.clone(),
            };
            if write_frame(&mut w.stream, &frame).is_ok() {
                w.staged.insert(job.key);
                counters.prepare_replications += 1;
            } else {
                lost_on_stage.push(idx);
            }
        }
        for idx in lost_on_stage {
            st.workers[idx].alive = false;
            counters.workers_lost += 1;
        }
        if !st.workers.iter().any(|w| w.alive) {
            return Err(exec_err(
                "socket transport: no live workers (all connections lost)".into(),
            ));
        }

        // --- per-shard bookkeeping; heaviest band routes first ---
        let mut slots: Vec<Slot> = job
            .plan
            .bands
            .iter()
            .map(|b| Slot {
                rows: b.rows,
                weight: b.weight,
                attempts: 0,
                hedged: false,
                done: false,
            })
            .collect();
        let mut order: Vec<usize> = (0..total).collect();
        order.sort_by(|&x, &y| {
            slots[y]
                .weight
                .cmp(&slots[x].weight)
                .then(x.cmp(&y))
        });
        let mut loads: Vec<usize> = vec![0; st.workers.len()];
        let mut outstanding: BTreeMap<u64, Pending> = BTreeMap::new();
        let mut results: Vec<BandResult> = Vec::with_capacity(total);

        let stop = AtomicBool::new(false);
        let (ev_tx, ev_rx) = channel::<Event>();

        let outcome = std::thread::scope(|scope| -> Result<(), EngineError> {
            for (idx, w) in st.workers.iter().enumerate() {
                if !w.alive {
                    continue;
                }
                let stream = match w.stream.try_clone() {
                    Ok(s) => s,
                    Err(_) => {
                        let _ = ev_tx.send(Event::Dead(idx));
                        continue;
                    }
                };
                let _ = stream.set_read_timeout(Some(TICK));
                let tx = ev_tx.clone();
                let stop = &stop;
                scope.spawn(move || reader_loop(idx, stream, tx, stop));
            }

            // the main loop runs in an immediately-invoked closure so that
            // EVERY exit path — success or typed failure — flips the stop
            // flag before the scope joins the reader threads (they poll it
            // each read-timeout tick; without this the join would block
            // on readers that never exit)
            let main_loop = (|| -> Result<(), EngineError> {
            // submit one band attempt; marks dead workers it trips over
            // and keeps trying survivors. `hedge` submissions don't spend
            // the retry budget.
            let submit = |st: &mut LinkState,
                          loads: &mut Vec<usize>,
                          outstanding: &mut BTreeMap<u64, Pending>,
                          counters: &mut TransportCounters,
                          slots: &mut Vec<Slot>,
                          shard: usize,
                          exclude: Option<usize>,
                          hedge: bool|
             -> Result<(), EngineError> {
                let (lo, hi) = slots[shard].rows;
                loop {
                    let Some(widx) = pick_worker(&st.workers, loads, exclude) else {
                        let undone: Vec<usize> = slots
                            .iter()
                            .enumerate()
                            .filter(|(_, s)| !s.done)
                            .map(|(i, _)| i)
                            .collect();
                        return Err(exec_err(format!(
                            "socket transport: no live workers left; shard(s) {undone:?} \
                             of {} unfinished",
                            slots.len()
                        )));
                    };
                    // a worker that missed the staging pass (it was busy
                    // dying) or a survivor taking over a lost band may not
                    // hold B yet — stage before the band, same frame order
                    // the wire contract expects
                    if !st.workers[widx].staged.contains(&job.key) {
                        let frame = Frame::Prepare {
                            key: job.key,
                            prepared: job.prepared.clone(),
                        };
                        if write_frame(&mut st.workers[widx].stream, &frame).is_err() {
                            st.workers[widx].alive = false;
                            counters.workers_lost += 1;
                            continue;
                        }
                        st.workers[widx].staged.insert(job.key);
                        counters.prepare_replications += 1;
                    }
                    let seq = st.next_seq;
                    st.next_seq += 1;
                    let frame = Frame::Band {
                        seq,
                        shard: shard as u64,
                        rows: (lo as u64, hi as u64),
                        key: job.key,
                        a_band: job.a.row_band(lo, hi),
                    };
                    if write_frame(&mut st.workers[widx].stream, &frame).is_err() {
                        st.workers[widx].alive = false;
                        counters.workers_lost += 1;
                        continue;
                    }
                    loads[widx] += slots[shard].weight.max(1);
                    if !hedge {
                        slots[shard].attempts += 1;
                    }
                    outstanding.insert(
                        seq,
                        Pending {
                            shard,
                            rows: (lo, hi),
                            worker: widx,
                            sent: Instant::now(),
                            hedge,
                        },
                    );
                    return Ok(());
                }
            };

            for &shard in &order {
                submit(
                    st, &mut loads, &mut outstanding, &mut counters, &mut slots,
                    shard, None, false,
                )?;
            }

            while results.len() < total {
                match ev_rx.recv_timeout(TICK) {
                    Ok(Event::Frame(idx, bytes)) => match decode_frame(&bytes) {
                        Ok(Frame::BandOk { seq, shard: _, wall_us, stats, c }) => {
                            let Some(p) = outstanding.remove(&seq) else {
                                continue; // stale (hedge loser or prior job)
                            };
                            if slots[p.shard].done {
                                continue;
                            }
                            slots[p.shard].done = true;
                            counters.remote_bands += 1;
                            if p.hedge {
                                counters.hedges_won += 1;
                            }
                            let wall = Duration::from_micros(wall_us);
                            let queue = p.sent.elapsed().saturating_sub(wall);
                            results.push(BandResult {
                                shard: p.shard,
                                rows: p.rows,
                                queue,
                                wall,
                                output: EngineOutput { c, stats },
                            });
                            // forget sibling submissions for this shard
                            let stale: Vec<u64> = outstanding
                                .iter()
                                .filter(|(_, q)| q.shard == p.shard)
                                .map(|(&s, _)| s)
                                .collect();
                            for s in stale {
                                outstanding.remove(&s);
                            }
                        }
                        Ok(Frame::BandErr { seq, shard: _, message }) => {
                            let Some(p) = outstanding.remove(&seq) else {
                                continue;
                            };
                            if slots[p.shard].done {
                                continue;
                            }
                            if slots[p.shard].attempts > self.policy.retry_budget {
                                return Err(exec_err(format!(
                                    "socket transport: shard {} failed on worker {}: \
                                     {message} (retry budget {} exhausted)",
                                    p.shard,
                                    st.workers[p.worker].addr,
                                    self.policy.retry_budget
                                )));
                            }
                            counters.band_retries += 1;
                            submit(
                                st, &mut loads, &mut outstanding, &mut counters,
                                &mut slots, p.shard, Some(p.worker), false,
                            )?;
                        }
                        Ok(_) => {} // protocol noise; ignore
                        Err(_) => {
                            // undecodable bytes mean the stream is desynced
                            let _ = ev_tx.send(Event::Dead(idx));
                        }
                    },
                    Ok(Event::Dead(idx)) => {
                        if st.workers[idx].alive {
                            st.workers[idx].alive = false;
                            counters.workers_lost += 1;
                        }
                        // resubmit ONLY this worker's in-flight bands — the
                        // named-lost-shards path, now survivable
                        let lost: Vec<u64> = outstanding
                            .iter()
                            .filter(|(_, p)| p.worker == idx)
                            .map(|(&s, _)| s)
                            .collect();
                        for seq in lost {
                            let Some(p) = outstanding.remove(&seq) else {
                                continue;
                            };
                            if slots[p.shard].done {
                                continue;
                            }
                            if slots[p.shard].attempts > self.policy.retry_budget {
                                return Err(exec_err(format!(
                                    "socket transport: lost worker {} and shard {} \
                                     exhausted its retry budget ({})",
                                    st.workers[idx].addr, p.shard, self.policy.retry_budget
                                )));
                            }
                            counters.band_retries += 1;
                            submit(
                                st, &mut loads, &mut outstanding, &mut counters,
                                &mut slots, p.shard, Some(idx), false,
                            )?;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        let now = Instant::now();
                        // timeout sweep: resubmit overdue bands
                        let overdue: Vec<u64> = outstanding
                            .iter()
                            .filter(|(_, p)| now.duration_since(p.sent) > band_timeout)
                            .map(|(&s, _)| s)
                            .collect();
                        for seq in overdue {
                            let Some(p) = outstanding.remove(&seq) else {
                                continue;
                            };
                            if slots[p.shard].done {
                                continue;
                            }
                            if slots[p.shard].attempts > self.policy.retry_budget {
                                return Err(exec_err(format!(
                                    "socket transport: shard {} timed out {} time(s), \
                                     retry budget {} exhausted",
                                    p.shard,
                                    slots[p.shard].attempts,
                                    self.policy.retry_budget
                                )));
                            }
                            counters.band_retries += 1;
                            submit(
                                st, &mut loads, &mut outstanding, &mut counters,
                                &mut slots, p.shard, Some(p.worker), false,
                            )?;
                        }
                        // hedge sweep: duplicate stragglers once, first
                        // answer wins
                        let live = st.workers.iter().filter(|w| w.alive).count();
                        if live > 1 {
                            let stragglers: Vec<(usize, usize)> = outstanding
                                .values()
                                .filter(|p| {
                                    !p.hedge
                                        && !slots[p.shard].hedged
                                        && !slots[p.shard].done
                                        && now.duration_since(p.sent)
                                            > self.policy.hedge_after
                                })
                                .map(|p| (p.shard, p.worker))
                                .collect();
                            for (shard, worker) in stragglers {
                                slots[shard].hedged = true;
                                submit(
                                    st, &mut loads, &mut outstanding, &mut counters,
                                    &mut slots, shard, Some(worker), true,
                                )?;
                            }
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        let undone: Vec<usize> = slots
                            .iter()
                            .enumerate()
                            .filter(|(_, s)| !s.done)
                            .map(|(i, _)| i)
                            .collect();
                        return Err(exec_err(format!(
                            "socket transport: every reader exited; shard(s) {undone:?} \
                             of {total} unfinished"
                        )));
                    }
                }
            }
            Ok(())
            })();
            stop.store(true, Ordering::Relaxed);
            main_loop
        });
        outcome?;
        Ok(BandRun { bands: results, counters })
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// Serve shard bands forever: accept leader connections on `listener`,
/// one handler thread per connection, each holding a content-keyed staged
/// operand cache and executing bands against `registry`'s kernels
/// (resolved by the frame key's `(format, algorithm)` — workers run bands
/// unsharded; thread-count differences never change result bits).
///
/// A kernel panic kills only that connection's handler thread — the
/// dropped socket is what tells the leader to resubmit the in-flight
/// bands elsewhere. The accept loop itself returns only on listener
/// errors.
pub fn serve(listener: TcpListener, registry: Arc<Registry>) -> io::Result<()> {
    loop {
        let (stream, _peer) = listener.accept()?;
        let registry = Arc::clone(&registry);
        std::thread::spawn(move || {
            let _ = handle_leader(stream, registry);
        });
    }
}

fn handle_leader(mut stream: TcpStream, registry: Arc<Registry>) -> io::Result<()> {
    let _ = stream.set_nodelay(true);
    let mut staged: BTreeMap<PreparedKey, PreparedB> = BTreeMap::new();
    loop {
        let body = read_frame(&mut stream)?;
        let frame = match decode_frame(&body) {
            Ok(f) => f,
            // protocol/version error: drop the connection, the leader's
            // reader surfaces it as a dead worker
            Err(_) => return Ok(()),
        };
        match frame {
            Frame::Hello => write_frame(&mut stream, &Frame::HelloAck)?,
            Frame::Prepare { key, prepared } => {
                staged.insert(key, prepared);
            }
            Frame::Band { seq, shard, rows: _, key, a_band } => {
                let reply = run_band(seq, shard, key, &a_band, &staged, &registry);
                write_frame(&mut stream, &reply)?;
            }
            Frame::Shutdown => return Ok(()),
            // frames only a leader should receive; ignore
            Frame::HelloAck | Frame::BandOk { .. } | Frame::BandErr { .. } => {}
        }
    }
}

fn run_band(
    seq: u64,
    shard: u64,
    key: PreparedKey,
    a_band: &Csr,
    staged: &BTreeMap<PreparedKey, PreparedB>,
    registry: &Registry,
) -> Frame {
    let Some(prepared) = staged.get(&key) else {
        return Frame::BandErr {
            seq,
            shard,
            message: format!("operand {key:?} not staged on this worker"),
        };
    };
    let Some(kernel) = registry.resolve(key.format, key.algorithm) else {
        return Frame::BandErr {
            seq,
            shard,
            message: format!(
                "no kernel for ({}, {}) on this worker",
                key.format.name(),
                key.algorithm.name()
            ),
        };
    };
    let t0 = Instant::now();
    match kernel.execute(a_band, prepared) {
        Ok(out) => Frame::BandOk {
            seq,
            shard,
            wall_us: u64::try_from(t0.elapsed().as_micros()).unwrap_or(u64::MAX),
            stats: out.stats,
            c: out.c,
        },
        Err(e) => Frame::BandErr { seq, shard, message: format!("{e}") },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::engine::kernels::GustavsonKernel;
    use crate::engine::shard::{execute, execute_with, ShardConfig};
    use crate::engine::SpmmKernel;
    use crate::spmm::plan::Geometry;

    fn test_registry() -> Arc<Registry> {
        Arc::new(Registry::with_default_kernels(
            Geometry { block: 16, pairs: 32, slots: 16 },
            2,
        ))
    }

    fn spawn_worker(registry: Arc<Registry>) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        std::thread::spawn(move || {
            let _ = serve(listener, registry);
        });
        addr
    }

    #[test]
    fn socket_transport_matches_in_process_bit_for_bit() {
        let addr1 = spawn_worker(test_registry());
        let addr2 = spawn_worker(test_registry());
        let transport =
            SocketTransport::connect(&[addr1, addr2]).expect("connect");
        let k = GustavsonKernel;
        let a = uniform(96, 80, 0.12, 31);
        let b = uniform(80, 56, 0.12, 32);
        let prepared = k.prepare(&b).unwrap();
        let cfg = ShardConfig { shards: 4, block: 16 };
        let local = execute(&k, &a, Some(&b), &prepared, cfg).unwrap();
        let remote =
            execute_with(&transport, &k, &a, Some(&b), &prepared, cfg).unwrap();
        assert_eq!(remote.c.bit_pattern(), local.c.bit_pattern());
        assert_eq!(remote.counters.remote_bands as usize, remote.shards.len());
        assert_eq!(remote.counters.workers_lost, 0);
        // a second job over the same B reuses the staged operands
        let remote2 =
            execute_with(&transport, &k, &a, Some(&b), &prepared, cfg).unwrap();
        assert_eq!(remote2.c.bit_pattern(), local.c.bit_pattern());
        assert!(remote2.counters.prepare_reuse >= 1);
        assert_eq!(remote2.counters.prepare_replications, 0);
    }

    #[test]
    fn lost_worker_is_readmitted_with_a_fresh_handshake() {
        use crate::engine::kernel::{Algorithm, CostHint};
        use crate::formats::traits::FormatKind;

        // panics on the first band it executes, then behaves
        struct FlakyKernel {
            fail_once: Arc<AtomicBool>,
        }
        impl crate::engine::SpmmKernel for FlakyKernel {
            fn algorithm(&self) -> Algorithm {
                Algorithm::Gustavson
            }
            fn format(&self) -> FormatKind {
                FormatKind::Csr
            }
            fn name(&self) -> &'static str {
                "flaky"
            }
            fn cost_hint(&self, a: &Csr, b: &Csr) -> CostHint {
                GustavsonKernel.cost_hint(a, b)
            }
            fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
                GustavsonKernel.prepare(b)
            }
            fn execute(&self, a: &Csr, b: &PreparedB) -> Result<EngineOutput, EngineError> {
                if self.fail_once.swap(false, Ordering::SeqCst) {
                    panic!("injected worker fault");
                }
                GustavsonKernel.execute(a, b)
            }
        }

        let fail_once = Arc::new(AtomicBool::new(true));
        let mut reg = crate::engine::Registry::with_default_kernels(
            Geometry { block: 16, pairs: 32, slots: 16 },
            2,
        );
        reg.register(Arc::new(FlakyKernel { fail_once: Arc::clone(&fail_once) }));
        let addr = spawn_worker(Arc::new(reg));
        let transport = SocketTransport::connect_with(
            &[addr],
            RetryPolicy {
                band_timeout: Duration::from_secs(5),
                retry_budget: 1,
                hedge_after: Duration::from_secs(5),
            },
        )
        .expect("connect");

        let k = GustavsonKernel;
        let a = uniform(64, 48, 0.2, 33);
        let b = uniform(48, 40, 0.2, 34);
        let prepared = k.prepare(&b).unwrap();
        let cfg = ShardConfig { shards: 2, block: 16 };
        // first run: the only worker's handler panics mid-band, the
        // connection drops, and with no survivors the job fails typed
        let first = execute_with(&transport, &k, &a, Some(&b), &prepared, cfg);
        assert!(first.is_err(), "sole-worker loss must fail the job");
        assert_eq!(transport.live_workers(), 0);
        // second run: the circuit breaker re-dials, the worker's accept
        // loop answers a fresh Hello, B re-replicates, and the revived
        // worker serves bit-identical bands
        let local = execute(&k, &a, Some(&b), &prepared, cfg).unwrap();
        let remote = execute_with(&transport, &k, &a, Some(&b), &prepared, cfg).unwrap();
        assert_eq!(remote.c.bit_pattern(), local.c.bit_pattern());
        assert!(remote.counters.workers_readmitted >= 1, "revival must be metered");
        assert!(remote.counters.prepare_replications >= 1, "B must re-stage after revival");
        assert_eq!(transport.live_workers(), 1);
    }

    #[test]
    fn readmission_backs_off_while_the_peer_stays_down() {
        // bind-then-drop: the address is real but nothing listens there
        let gone = {
            let l = TcpListener::bind("127.0.0.1:0").expect("bind");
            l.local_addr().expect("addr").to_string()
        };
        let mut st = LinkState {
            workers: vec![WorkerLink {
                addr: gone,
                // self-connected placeholder stream (never read)
                stream: {
                    let l = TcpListener::bind("127.0.0.1:0").expect("bind");
                    let a = l.local_addr().expect("addr");
                    let s = TcpStream::connect(a).expect("self-connect");
                    let _ = l.accept();
                    s
                },
                staged: BTreeSet::new(),
                alive: false,
                reconnect_failures: 0,
                next_retry: None,
            }],
            next_seq: 0,
        };
        let mut counters = TransportCounters::default();
        SocketTransport::try_readmit(&mut st, &mut counters);
        assert_eq!(counters.workers_readmitted, 0);
        assert!(!st.workers[0].alive);
        assert_eq!(st.workers[0].reconnect_failures, 1);
        let first_retry = st.workers[0].next_retry.expect("breaker must arm");
        // a probe inside the backoff window is skipped entirely
        SocketTransport::try_readmit(&mut st, &mut counters);
        assert_eq!(st.workers[0].reconnect_failures, 1, "breaker window must gate probes");
        // force the window open: the next probe fails again and doubles
        st.workers[0].next_retry = Some(Instant::now());
        SocketTransport::try_readmit(&mut st, &mut counters);
        assert_eq!(st.workers[0].reconnect_failures, 2);
        let second_retry = st.workers[0].next_retry.expect("breaker stays armed");
        assert!(second_retry > first_retry, "backoff must extend");
    }

    #[test]
    fn connect_refuses_empty_and_unreachable_peers() {
        assert!(SocketTransport::connect(&[]).is_err());
        // a listener that never answers the handshake is bound but we
        // close it immediately: connect must fail typed, not hang/panic
        let gone = {
            let l = TcpListener::bind("127.0.0.1:0").expect("bind");
            l.local_addr().expect("addr").to_string()
        };
        assert!(SocketTransport::connect(&[gone]).is_err());
    }
}
