//! Accelerator adapter: `runtime::NumericEngine` (the sorted tile-pair plan
//! executed by the AOT Pallas kernel over PJRT, or its bit-equivalent CPU
//! twin) behind the [`SpmmKernel`] contract.
//!
//! This is the kernel the serving layer runs by default — identical math to
//! the old `EngineKind::{Cpu,Pjrt}` paths, now interchangeable with every
//! other registered kernel.

use std::path::Path;
use std::sync::Arc;

use crate::formats::csr::Csr;
use crate::formats::traits::{FormatKind, SparseMatrix};
use crate::runtime::numeric::NumericEngine;
use crate::spmm::plan::Geometry;

use super::error::EngineError;
use super::kernel::{
    wrong_operand, Algorithm, BlockedB, CostHint, EngineOutput, PreparedB, SpmmKernel,
};

// NOTE on `SpmmKernel: Send + Sync` and the `pjrt` feature: each server
// worker builds its own AccelKernel (PJRT clients stay thread-local by
// construction), but the trait bound still requires the type to be
// Send + Sync. The default (CPU) build trivially is. When the vendored
// `xla` bindings land, check `PjRtClient`'s auto traits: if it is !Sync,
// wrap `NumericEngine`'s Pjrt backend in a `Mutex` (uncontended in the
// per-worker setup) before enabling the feature.
pub struct AccelKernel {
    engine: NumericEngine,
}

impl AccelKernel {
    /// CPU plan executor at `geom` (always available).
    pub fn cpu(geom: Geometry) -> AccelKernel {
        AccelKernel { engine: NumericEngine::cpu(geom) }
    }

    /// PJRT-backed executor from an artifact directory. Errors when the
    /// artifacts are missing or the crate was built without the `pjrt`
    /// feature.
    pub fn pjrt(artifacts_dir: &Path) -> Result<AccelKernel, String> {
        Ok(AccelKernel { engine: NumericEngine::pjrt(artifacts_dir)? })
    }

    /// Wrap an existing engine (workers build their own so PJRT clients are
    /// never shared across threads).
    pub fn from_engine(engine: NumericEngine) -> AccelKernel {
        AccelKernel { engine }
    }

    pub fn geometry(&self) -> Geometry {
        self.engine.geometry()
    }
}

impl SpmmKernel for AccelKernel {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Block
    }
    fn format(&self) -> FormatKind {
        FormatKind::Csr
    }
    fn name(&self) -> &'static str {
        // "cpu" / "pjrt" — the backend identity callers log and assert on
        self.engine.backend_name()
    }
    fn cost_hint(&self, a: &Csr, b: &Csr) -> CostHint {
        // the plan issues full block³ MACs per pair, padding included;
        // tile-pair estimate shared with TiledKernel (engine::kernel)
        let block = self.engine.geometry().block;
        let pairs = super::kernel::expected_tile_pairs(a, b, block);
        CostHint {
            flops: pairs * (block * block * block) as f64,
            prepare_words: (a.nnz() + b.nnz()) as f64,
        }
    }
    fn band_alignment(&self) -> usize {
        // the engine's own geometry — the PJRT manifest block can differ
        // from the server's configured geometry, and shard bands must
        // align to the block the plan actually uses
        self.engine.geometry().block
    }
    fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
        // B is blockized HERE, once, at the engine's own geometry —
        // execute (and every shard worker sharing this PreparedB) plans
        // from the prebuilt grid
        Ok(PreparedB::Blocked(Arc::new(BlockedB::build(
            Arc::new(b.clone()),
            self.engine.geometry().block,
        ))))
    }
    fn prepare_shared(&self, b: &Arc<Csr>) -> Result<PreparedB, EngineError> {
        Ok(PreparedB::Blocked(Arc::new(BlockedB::build(
            Arc::clone(b),
            self.engine.geometry().block,
        ))))
    }
    fn prepare_is_trivial(&self) -> bool {
        false // blockization is a real O(nnz) build worth caching
    }
    fn execute(&self, a: &Csr, b: &PreparedB) -> Result<EngineOutput, EngineError> {
        let bb = match b {
            PreparedB::Blocked(bb) => bb,
            other => return Err(wrong_operand(self, other)),
        };
        if a.cols() != bb.grid.rows {
            return Err(EngineError::ShapeMismatch {
                a: a.shape(),
                b: (bb.grid.rows, bb.grid.cols),
            });
        }
        let (c, stats) = self
            .engine
            .spmm_blocked(a, &bb.grid)
            .map_err(EngineError::ExecFailed)?;
        Ok(EngineOutput { c, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::spmm::dense::multiply as dense_ref;

    #[test]
    fn cpu_accel_kernel_matches_oracle() {
        let k = AccelKernel::cpu(Geometry { block: 8, pairs: 16, slots: 8 });
        assert_eq!(k.name(), "cpu");
        assert_eq!(k.algorithm(), Algorithm::Block);
        let a = uniform(30, 40, 0.2, 1);
        let b = uniform(40, 22, 0.2, 2);
        let out = k.run(&a, &b).unwrap();
        assert!(out.c.max_abs_diff(&dense_ref(&a, &b)) < 1e-3);
        assert!(out.stats.dispatches > 0);
        assert!(out.stats.real_pairs <= out.stats.padded_pairs);
    }

    #[test]
    fn prepare_blockizes_at_the_engine_geometry() {
        let k = AccelKernel::cpu(Geometry { block: 8, pairs: 16, slots: 8 });
        let b = uniform(40, 22, 0.2, 2);
        let prepared = k.prepare(&b).unwrap();
        match &prepared {
            PreparedB::Blocked(bb) => {
                assert_eq!(bb.block(), 8);
                assert_eq!((bb.grid.rows, bb.grid.cols), (40, 22));
            }
            other => panic!("accel prepare must blockize, got {other:?}"),
        }
        assert!(!k.prepare_is_trivial());
        // executing on the prebuilt grid matches the full spmm path bitwise
        let a = uniform(30, 40, 0.2, 1);
        let via_prepared = k.execute(&a, &prepared).unwrap();
        let (direct, _) = k.engine.spmm(&a, &b).unwrap();
        assert_eq!(via_prepared.c.bit_pattern(), direct.bit_pattern());
    }

    #[test]
    fn pjrt_constructor_fails_cleanly_without_feature_or_artifacts() {
        let missing = std::path::Path::new("/nonexistent/artifacts");
        let err = AccelKernel::pjrt(missing).err().expect("must not succeed");
        assert!(!err.is_empty());
    }
}
