//! Lock-free service metrics: counters and a fixed-bucket latency
//! histogram, shared between workers and observers.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Power-of-two microsecond buckets: [<1us, <2us, <4us, ... , <2^30us, rest]
const BUCKETS: usize = 32;

#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub dispatches: AtomicU64,
    pub real_pairs: AtomicU64,
    pub busy_ns: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn observe_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.latency_us[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate latency quantile from the histogram (upper bucket bound).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self
            .latency_us
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            dispatches: self.dispatches.load(Ordering::Relaxed),
            real_pairs: self.real_pairs.load(Ordering::Relaxed),
            busy_ns: self.busy_ns.load(Ordering::Relaxed),
            p50_us: self.latency_quantile_us(0.5),
            p99_us: self.latency_quantile_us(0.99),
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub dispatches: u64,
    pub real_pairs: u64,
    pub busy_ns: u64,
    pub p50_us: u64,
    pub p99_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.observe_latency(Duration::from_micros(10)); // bucket <16
        }
        for _ in 0..10 {
            m.observe_latency(Duration::from_micros(5_000)); // bucket <8192
        }
        assert!(m.latency_quantile_us(0.5) <= 16);
        assert!(m.latency_quantile_us(0.99) >= 4096);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.jobs_completed.fetch_add(3, Ordering::Relaxed);
        m.real_pairs.fetch_add(100, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.jobs_completed, 3);
        assert_eq!(s.real_pairs, 100);
    }

    #[test]
    fn empty_histogram() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile_us(0.99), 0);
    }
}
