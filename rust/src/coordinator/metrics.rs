//! Service metrics: lock-free counters plus fixed-bucket latency
//! histograms (service time *and* queue wait), shared between workers and
//! observers — and a bounded, mutex-guarded kernel-observation log (the
//! raw `(cost_hint, ingest_cost, measured_wall)` datapoints the ROADMAP's
//! "fit the constants" item needs; one short lock per completed job, off
//! every per-row hot path).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::job::PRIORITY_CLASSES;
use crate::engine::Algorithm;
use crate::formats::traits::FormatKind;
use crate::util::lock_unpoisoned;

/// Power-of-two microsecond buckets: [<1us, <2us, <4us, ... , <2^30us, rest]
const BUCKETS: usize = 32;

/// Lock-free power-of-two-bucket latency histogram.
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
}

impl Histogram {
    pub fn observe(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Approximate quantile from the histogram (upper bucket bound in µs).
    /// The last bucket is unbounded ("rest"): a quantile landing there
    /// reports `u64::MAX` — there is no honest upper bound, and reporting
    /// `1 << 32` would silently cap p99 at ~71 minutes.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i + 1 >= BUCKETS {
                    u64::MAX
                } else {
                    1u64 << (i + 1)
                };
            }
        }
        u64::MAX
    }
}

/// One executed job's kernel-selection datapoint: what the registry's cost
/// model predicted vs the wall time the kernel actually took. Collected so
/// the static `cost_hint`/`ingest_cost` constants can be fitted from real
/// serving traffic (`Registry::select` today ranks on unfitted hints).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KernelObservation {
    /// Registry key of the kernel that executed.
    pub format: FormatKind,
    pub algorithm: Algorithm,
    /// `SpmmKernel::cost_hint(a, b).total()` for the job's operands.
    pub cost_hint: f64,
    /// `SpmmKernel::ingest_cost(b, native)` for the job's native `B`.
    pub ingest_cost: f64,
    /// Measured kernel execute wall time (sharded execution included,
    /// verify/render excluded), in microseconds.
    pub wall_us: u64,
}

/// One kernel's published calibration state, surfaced from the refit loop
/// for observability (`serve` prints these; `mean_abs_err_us` is the
/// per-kernel calibration error).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CalibrationEntry {
    pub format: FormatKind,
    pub algorithm: Algorithm,
    /// Fitted microseconds per raw cost unit.
    pub scale: f64,
    /// Observations the fit used.
    pub samples: u64,
    /// Mean |predicted − measured| wall time, microseconds.
    pub mean_abs_err_us: f64,
}

/// Observations kept in the ring (newest overwrite oldest beyond this).
const KERNEL_LOG_CAP: usize = 4096;

#[derive(Debug, Default)]
struct KernelLogInner {
    entries: Vec<KernelObservation>,
    cursor: usize,
}

/// Bounded ring of [`KernelObservation`]s.
#[derive(Debug, Default)]
pub struct KernelLog {
    inner: Mutex<KernelLogInner>,
}

impl KernelLog {
    fn record(&self, obs: KernelObservation) {
        // the ring is structurally valid after any holder's panic (single
        // push or slot overwrite), so recover rather than drop samples
        let mut inner = lock_unpoisoned(&self.inner);
        if inner.entries.len() < KERNEL_LOG_CAP {
            inner.entries.push(obs);
        } else {
            let cursor = inner.cursor;
            inner.entries[cursor] = obs;
            inner.cursor = (cursor + 1) % KERNEL_LOG_CAP;
        }
    }

    /// The retained observations (ring order, not chronological once the
    /// cap has wrapped — irrelevant for fitting).
    fn entries(&self) -> Vec<KernelObservation> {
        lock_unpoisoned(&self.inner).entries.clone()
    }
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    /// Workers that asked for PJRT but degraded to the CPU kernel.
    pub pjrt_fallbacks: AtomicU64,
    pub dispatches: AtomicU64,
    pub real_pairs: AtomicU64,
    pub busy_ns: AtomicU64,
    /// Times a worker actually ran `SpmmKernel::prepare` for a job's `B`
    /// (cache misses). With B-sharing coalescing this stays well below
    /// `jobs_completed`; without it the two march together.
    pub prepare_builds: AtomicU64,
    /// Micro-batch groups whose `PreparedB` came from the cross-batch LRU
    /// cache instead of a fresh build.
    pub prepare_cache_hits: AtomicU64,
    /// Sharing groups (one per distinct `B`+kernel within a micro-batch)
    /// in which ≥ 2 jobs shared one `PreparedB`. A micro-batch holding two
    /// shared-B groups counts twice.
    pub coalesced_batches: AtomicU64,
    /// Jobs beyond the first in each sharing group — multiplies that rode
    /// on a batch-mate's prepare (the paper's amortization, measured).
    pub coalesced_jobs: AtomicU64,
    /// Operand→canonical-CSR conversions performed at ingestion (non-CSR
    /// `MatrixOperand` submissions; identity-memoized, so steady-state
    /// traffic reusing an operand handle converts once per worker).
    pub operand_conversions: AtomicU64,
    /// Jobs that executed through the row-band shard path (`shards > 1`).
    pub sharded_jobs: AtomicU64,
    /// Row-band shards executed across all sharded jobs.
    pub shards_executed: AtomicU64,
    /// Sharded executions that failed (worker panic or band exec error).
    pub shard_failures: AtomicU64,
    /// Sharded jobs whose requested shard count exceeded what the planner
    /// could honor (more shards than plannable row bands) — the planner
    /// clamps silently; this surfaces it (see `JobOutput::shards_requested`).
    pub shard_clamps: AtomicU64,
    /// Row bands executed on a *remote* socket worker (subset of
    /// `shards_executed`; zero under the in-process transport).
    pub remote_bands: AtomicU64,
    /// Band submissions beyond each band's first attempt (timeouts, worker
    /// errors, and lost-worker resubmissions).
    pub band_retries: AtomicU64,
    /// Bands whose hedged duplicate submission finished first.
    pub hedges_won: AtomicU64,
    /// Remote workers that died (EOF/write failure) mid-service.
    pub workers_lost: AtomicU64,
    /// `PreparedB` replications shipped to remote workers (wire-format
    /// `Prepare` frames actually sent).
    pub prepare_replications: AtomicU64,
    /// Bands routed to a worker that already held the job's `B` under its
    /// content fingerprint — the remote `PreparedCache` reuse, measured.
    pub prepare_reuse: AtomicU64,
    /// Accumulator-workspace checkouts served from a `PreparedB` pool
    /// (the fast Gustavson kernel's workspace reuse across jobs,
    /// micro-batches, and shard workers).
    pub workspace_pool_hits: AtomicU64,
    /// Workspace checkouts that had to allocate (pool empty).
    pub workspace_pool_misses: AtomicU64,
    /// Jobs the admission gate refused ([`JobError::Overloaded`]): the
    /// predicted queue delay exceeded the configured budget, so the job
    /// was shed with a retry-after hint instead of parking the caller.
    pub jobs_shed: AtomicU64,
    /// Jobs dropped because their deadline expired before execution —
    /// at dequeue, pre-`prepare`, or pre-band-dispatch
    /// ([`JobError::DeadlineExceeded`]). Subset of `jobs_failed`.
    pub deadline_drops: AtomicU64,
    /// Remote workers revived by the transport's circuit breaker (Hello
    /// re-handshake after loss; staged `B`s re-replicate on first use).
    pub workers_readmitted: AtomicU64,
    /// Kernel-selection datapoints recorded (total, including any beyond
    /// the bounded log's retention).
    pub kernel_observations: AtomicU64,
    /// Cost-model refits published by the learned-selection loop
    /// (`engine::learn`); warm-loads at startup are not counted.
    pub model_refits: AtomicU64,
    /// Latest per-kernel calibration published by the refit loop (scale +
    /// mean absolute prediction error) — read with
    /// [`Metrics::calibration`]. Kept out of [`MetricsSnapshot`] so the
    /// snapshot stays `Copy`.
    calibration: Mutex<Vec<CalibrationEntry>>,
    /// Bounded `(cost_hint, ingest_cost, wall)` log per executed kernel —
    /// read it with [`Metrics::kernel_log`].
    pub kernel_log: KernelLog,
    /// Per-job service time (dequeue → response ready).
    pub latency: Histogram,
    /// Per-job queue wait (submit → dequeue) — the backpressure signal.
    pub queue_wait: Histogram,
    /// Service time split by priority class (index = `Priority::class()`).
    /// The aggregate `latency` histogram still sees every job.
    pub latency_by_class: [Histogram; PRIORITY_CLASSES],
    /// Queue wait split by priority class — the fairness signal: under
    /// load, low-priority queue p99 may grow, but the starvation bound
    /// keeps it finite.
    pub queue_wait_by_class: [Histogram; PRIORITY_CLASSES],
    /// Per-shard execute wall time on the shard worker.
    pub shard_wall: Histogram,
    /// Per-shard queue wait (band dispatch → shard worker dequeue).
    pub shard_queue_wait: Histogram,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn observe_latency(&self, d: Duration) {
        self.latency.observe(d);
    }

    pub fn observe_queue_wait(&self, d: Duration) {
        self.queue_wait.observe(d);
    }

    /// Observe service latency into both the aggregate histogram and the
    /// job's priority-class split. Out-of-range classes (future-proofing)
    /// fold into the lowest class.
    pub fn observe_latency_class(&self, d: Duration, class: usize) {
        self.latency.observe(d);
        self.latency_by_class[class.min(PRIORITY_CLASSES - 1)].observe(d);
    }

    /// Observe queue wait into both the aggregate and per-class histograms.
    pub fn observe_queue_wait_class(&self, d: Duration, class: usize) {
        self.queue_wait.observe(d);
        self.queue_wait_by_class[class.min(PRIORITY_CLASSES - 1)].observe(d);
    }

    pub fn observe_shard_wall(&self, d: Duration) {
        self.shard_wall.observe(d);
    }

    pub fn observe_shard_queue_wait(&self, d: Duration) {
        self.shard_queue_wait.observe(d);
    }

    /// Approximate service-latency quantile (upper bucket bound, µs).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        self.latency.quantile_us(q)
    }

    /// Record one executed kernel's `(cost_hint, ingest_cost, wall)`
    /// datapoint — the raw material for fitting the selection constants.
    pub fn record_kernel_observation(&self, obs: KernelObservation) {
        self.kernel_observations.fetch_add(1, Ordering::Relaxed);
        self.kernel_log.record(obs);
    }

    /// The retained kernel observations (a bounded ring of the newest
    /// few thousand entries).
    pub fn kernel_log(&self) -> Vec<KernelObservation> {
        self.kernel_log.entries()
    }

    /// Fold one sharded run's transport counters into the service totals
    /// (called once per completed sharded job, whatever the transport —
    /// the in-process transport contributes all-zero counters).
    pub fn record_transport(&self, c: &crate::engine::TransportCounters) {
        self.remote_bands.fetch_add(c.remote_bands, Ordering::Relaxed);
        self.band_retries.fetch_add(c.band_retries, Ordering::Relaxed);
        self.hedges_won.fetch_add(c.hedges_won, Ordering::Relaxed);
        self.workers_lost.fetch_add(c.workers_lost, Ordering::Relaxed);
        self.prepare_replications
            .fetch_add(c.prepare_replications, Ordering::Relaxed);
        self.prepare_reuse.fetch_add(c.prepare_reuse, Ordering::Relaxed);
        self.workers_readmitted
            .fetch_add(c.workers_readmitted, Ordering::Relaxed);
    }

    /// Publish the latest per-kernel calibration (refit loop only).
    pub fn set_calibration(&self, entries: Vec<CalibrationEntry>) {
        *lock_unpoisoned(&self.calibration) = entries;
    }

    /// The latest published per-kernel calibration (empty until the first
    /// refit or warm-load).
    pub fn calibration(&self) -> Vec<CalibrationEntry> {
        lock_unpoisoned(&self.calibration).clone()
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            pjrt_fallbacks: self.pjrt_fallbacks.load(Ordering::Relaxed),
            dispatches: self.dispatches.load(Ordering::Relaxed),
            real_pairs: self.real_pairs.load(Ordering::Relaxed),
            busy_ns: self.busy_ns.load(Ordering::Relaxed),
            prepare_builds: self.prepare_builds.load(Ordering::Relaxed),
            prepare_cache_hits: self.prepare_cache_hits.load(Ordering::Relaxed),
            coalesced_batches: self.coalesced_batches.load(Ordering::Relaxed),
            coalesced_jobs: self.coalesced_jobs.load(Ordering::Relaxed),
            operand_conversions: self.operand_conversions.load(Ordering::Relaxed),
            sharded_jobs: self.sharded_jobs.load(Ordering::Relaxed),
            shards_executed: self.shards_executed.load(Ordering::Relaxed),
            shard_failures: self.shard_failures.load(Ordering::Relaxed),
            shard_clamps: self.shard_clamps.load(Ordering::Relaxed),
            remote_bands: self.remote_bands.load(Ordering::Relaxed),
            band_retries: self.band_retries.load(Ordering::Relaxed),
            hedges_won: self.hedges_won.load(Ordering::Relaxed),
            workers_lost: self.workers_lost.load(Ordering::Relaxed),
            prepare_replications: self.prepare_replications.load(Ordering::Relaxed),
            prepare_reuse: self.prepare_reuse.load(Ordering::Relaxed),
            workspace_pool_hits: self.workspace_pool_hits.load(Ordering::Relaxed),
            workspace_pool_misses: self.workspace_pool_misses.load(Ordering::Relaxed),
            jobs_shed: self.jobs_shed.load(Ordering::Relaxed),
            deadline_drops: self.deadline_drops.load(Ordering::Relaxed),
            workers_readmitted: self.workers_readmitted.load(Ordering::Relaxed),
            kernel_observations: self.kernel_observations.load(Ordering::Relaxed),
            model_refits: self.model_refits.load(Ordering::Relaxed),
            p50_us: self.latency.quantile_us(0.5),
            p99_us: self.latency.quantile_us(0.99),
            queue_p50_us: self.queue_wait.quantile_us(0.5),
            queue_p99_us: self.queue_wait.quantile_us(0.99),
            class_p50_us: quantiles(&self.latency_by_class, 0.5),
            class_p99_us: quantiles(&self.latency_by_class, 0.99),
            class_queue_p50_us: quantiles(&self.queue_wait_by_class, 0.5),
            class_queue_p99_us: quantiles(&self.queue_wait_by_class, 0.99),
            shard_wall_p50_us: self.shard_wall.quantile_us(0.5),
            shard_wall_p99_us: self.shard_wall.quantile_us(0.99),
            shard_queue_p50_us: self.shard_queue_wait.quantile_us(0.5),
            shard_queue_p99_us: self.shard_queue_wait.quantile_us(0.99),
        }
    }
}

fn quantiles(hists: &[Histogram; PRIORITY_CLASSES], q: f64) -> [u64; PRIORITY_CLASSES] {
    let mut out = [0u64; PRIORITY_CLASSES];
    for (slot, h) in out.iter_mut().zip(hists.iter()) {
        *slot = h.quantile_us(q);
    }
    out
}

#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub pjrt_fallbacks: u64,
    pub dispatches: u64,
    pub real_pairs: u64,
    pub busy_ns: u64,
    pub prepare_builds: u64,
    pub prepare_cache_hits: u64,
    pub coalesced_batches: u64,
    pub coalesced_jobs: u64,
    pub operand_conversions: u64,
    pub sharded_jobs: u64,
    pub shards_executed: u64,
    pub shard_failures: u64,
    pub shard_clamps: u64,
    pub remote_bands: u64,
    pub band_retries: u64,
    pub hedges_won: u64,
    pub workers_lost: u64,
    pub prepare_replications: u64,
    pub prepare_reuse: u64,
    pub workspace_pool_hits: u64,
    pub workspace_pool_misses: u64,
    pub jobs_shed: u64,
    pub deadline_drops: u64,
    pub workers_readmitted: u64,
    pub kernel_observations: u64,
    pub model_refits: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub queue_p50_us: u64,
    pub queue_p99_us: u64,
    /// Service-latency quantiles per priority class (index =
    /// `Priority::class()`: 0 = high, 1 = normal, 2 = low).
    pub class_p50_us: [u64; PRIORITY_CLASSES],
    pub class_p99_us: [u64; PRIORITY_CLASSES],
    pub class_queue_p50_us: [u64; PRIORITY_CLASSES],
    pub class_queue_p99_us: [u64; PRIORITY_CLASSES],
    pub shard_wall_p50_us: u64,
    pub shard_wall_p99_us: u64,
    pub shard_queue_p50_us: u64,
    pub shard_queue_p99_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.observe_latency(Duration::from_micros(10)); // bucket <16
        }
        for _ in 0..10 {
            m.observe_latency(Duration::from_micros(5_000)); // bucket <8192
        }
        assert!(m.latency_quantile_us(0.5) <= 16);
        assert!(m.latency_quantile_us(0.99) >= 4096);
    }

    #[test]
    fn queue_wait_is_tracked_separately() {
        let m = Metrics::new();
        m.observe_latency(Duration::from_micros(1_000));
        m.observe_queue_wait(Duration::from_micros(2));
        let s = m.snapshot();
        assert!(s.queue_p50_us <= 4, "{s:?}");
        assert!(s.p50_us >= 512, "{s:?}");
        assert_eq!(m.queue_wait.count(), 1);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.jobs_completed.fetch_add(3, Ordering::Relaxed);
        m.real_pairs.fetch_add(100, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.jobs_completed, 3);
        assert_eq!(s.real_pairs, 100);
    }

    #[test]
    fn shard_metrics_are_tracked() {
        let m = Metrics::new();
        m.sharded_jobs.fetch_add(1, Ordering::Relaxed);
        m.shards_executed.fetch_add(4, Ordering::Relaxed);
        m.shard_failures.fetch_add(1, Ordering::Relaxed);
        m.observe_shard_wall(Duration::from_micros(300));
        m.observe_shard_queue_wait(Duration::from_micros(3));
        let s = m.snapshot();
        assert_eq!(s.sharded_jobs, 1);
        assert_eq!(s.shards_executed, 4);
        assert_eq!(s.shard_failures, 1);
        assert!(s.shard_wall_p50_us >= 256, "{s:?}");
        assert!(s.shard_queue_p50_us <= 4, "{s:?}");
    }

    #[test]
    fn kernel_log_records_bounded_observations() {
        let m = Metrics::new();
        let obs = KernelObservation {
            format: FormatKind::Csr,
            algorithm: Algorithm::GustavsonFast,
            cost_hint: 1234.5,
            ingest_cost: 67.0,
            wall_us: 89,
        };
        m.record_kernel_observation(obs);
        assert_eq!(m.snapshot().kernel_observations, 1);
        assert_eq!(m.kernel_log(), vec![obs]);
        // the ring stays bounded and keeps counting past the cap
        for i in 0..(KERNEL_LOG_CAP as u64 + 10) {
            m.record_kernel_observation(KernelObservation { wall_us: i, ..obs });
        }
        assert_eq!(
            m.snapshot().kernel_observations,
            KERNEL_LOG_CAP as u64 + 11
        );
        assert_eq!(m.kernel_log().len(), KERNEL_LOG_CAP);
    }

    #[test]
    fn rest_bucket_quantile_is_not_falsely_bounded() {
        let m = Metrics::new();
        // > 2^31 µs lands in the unbounded rest bucket: the only honest
        // answer is u64::MAX, not the old 1 << 32 cap
        m.observe_latency(Duration::from_micros((1u64 << 33) + 17));
        assert_eq!(m.latency_quantile_us(0.5), u64::MAX);
        assert_eq!(m.latency_quantile_us(1.0), u64::MAX);
        // a bounded sibling population still reports bounded quantiles
        m.observe_latency(Duration::from_micros(10));
        assert!(m.latency_quantile_us(0.25) <= 16);
        assert_eq!(m.latency_quantile_us(1.0), u64::MAX);
    }

    #[test]
    fn kernel_log_wrap_retains_exactly_the_newest_cap() {
        let base = KernelObservation {
            format: FormatKind::Csr,
            algorithm: Algorithm::Gustavson,
            cost_hint: 1.0,
            ingest_cost: 0.0,
            wall_us: 0,
        };
        for k in [1u64, 7, 100, KERNEL_LOG_CAP as u64 + 3] {
            let m = Metrics::new();
            let total = KERNEL_LOG_CAP as u64 + k;
            for i in 0..total {
                m.record_kernel_observation(KernelObservation { wall_us: i, ..base });
            }
            let mut walls: Vec<u64> = m.kernel_log().iter().map(|o| o.wall_us).collect();
            walls.sort_unstable();
            let want: Vec<u64> = (k..total).collect();
            assert_eq!(
                walls, want,
                "after {total} records the ring must hold exactly the newest {KERNEL_LOG_CAP} (k={k})"
            );
        }
    }

    #[test]
    fn quantiles_are_monotone_in_q_and_bracket_observed_buckets() {
        let m = Metrics::new();
        let observed_us = [1u64, 3, 10, 100, 5_000, 250_000, (1 << 31) + 9];
        for &us in &observed_us {
            m.observe_latency(Duration::from_micros(us));
        }
        // the only values quantile_us can honestly report are the upper
        // bounds of buckets that actually hold observations
        let valid: Vec<u64> = observed_us
            .iter()
            .map(|&us| {
                let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
                if bucket + 1 >= BUCKETS {
                    u64::MAX
                } else {
                    1u64 << (bucket + 1)
                }
            })
            .collect();
        let qs = [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0];
        let mut prev = 0u64;
        for q in qs {
            let v = m.latency_quantile_us(q);
            assert!(v >= prev, "quantile must be monotone in q: q={q} gave {v} < {prev}");
            assert!(valid.contains(&v), "q={q} reported {v}, not an observed bucket bound");
            prev = v;
        }
        // brackets the population: the low quantile is the smallest
        // observed bound, the high one the rest bucket
        assert_eq!(m.latency_quantile_us(0.01), 2);
        assert_eq!(m.latency_quantile_us(1.0), u64::MAX);
    }

    #[test]
    fn model_refits_and_calibration_surface() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().model_refits, 0);
        assert!(m.calibration().is_empty());
        m.model_refits.fetch_add(1, Ordering::Relaxed);
        let entry = CalibrationEntry {
            format: FormatKind::Csr,
            algorithm: Algorithm::GustavsonFast,
            scale: 2.5e-3,
            samples: 64,
            mean_abs_err_us: 1.5,
        };
        m.set_calibration(vec![entry]);
        assert_eq!(m.snapshot().model_refits, 1);
        assert_eq!(m.calibration(), vec![entry]);
    }

    #[test]
    fn transport_counters_fold_into_the_snapshot() {
        let m = Metrics::new();
        m.record_transport(&crate::engine::TransportCounters {
            remote_bands: 4,
            band_retries: 2,
            hedges_won: 1,
            workers_lost: 1,
            prepare_replications: 3,
            prepare_reuse: 5,
            workers_readmitted: 2,
        });
        // folding accumulates across jobs
        m.record_transport(&crate::engine::TransportCounters {
            remote_bands: 1,
            ..Default::default()
        });
        m.shard_clamps.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.remote_bands, 5);
        assert_eq!(s.band_retries, 2);
        assert_eq!(s.hedges_won, 1);
        assert_eq!(s.workers_lost, 1);
        assert_eq!(s.prepare_replications, 3);
        assert_eq!(s.prepare_reuse, 5);
        assert_eq!(s.workers_readmitted, 2);
        assert_eq!(s.shard_clamps, 1);
    }

    #[test]
    fn shed_and_deadline_counters_surface_in_the_snapshot() {
        let m = Metrics::new();
        m.jobs_shed.fetch_add(3, Ordering::Relaxed);
        m.deadline_drops.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.jobs_shed, 3);
        assert_eq!(s.deadline_drops, 2);
        assert_eq!(s.workers_readmitted, 0);
    }

    #[test]
    fn per_class_histograms_split_by_priority_and_feed_the_aggregate() {
        let m = Metrics::new();
        // class 0 (high) fast, class 2 (low) slow; aggregate sees both
        for _ in 0..10 {
            m.observe_latency_class(Duration::from_micros(10), 0);
            m.observe_queue_wait_class(Duration::from_micros(2), 0);
        }
        for _ in 0..10 {
            m.observe_latency_class(Duration::from_micros(50_000), 2);
            m.observe_queue_wait_class(Duration::from_micros(20_000), 2);
        }
        let s = m.snapshot();
        assert!(s.class_p50_us[0] <= 16, "{s:?}");
        assert!(s.class_p50_us[2] >= 32_768, "{s:?}");
        assert_eq!(s.class_p50_us[1], 0, "no normal-class traffic: {s:?}");
        assert!(s.class_queue_p50_us[0] <= 4, "{s:?}");
        assert!(s.class_queue_p50_us[2] >= 16_384, "{s:?}");
        assert_eq!(m.latency.count(), 20, "aggregate must see every job");
        assert_eq!(m.queue_wait.count(), 20);
        // out-of-range classes clamp to the lowest class, never panic
        m.observe_latency_class(Duration::from_micros(1), 99);
        assert_eq!(m.latency_by_class[PRIORITY_CLASSES - 1].count(), 11);
    }

    #[test]
    fn workspace_pool_counters_surface_in_the_snapshot() {
        let m = Metrics::new();
        m.workspace_pool_hits.fetch_add(5, Ordering::Relaxed);
        m.workspace_pool_misses.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!((s.workspace_pool_hits, s.workspace_pool_misses), (5, 2));
    }

    #[test]
    fn empty_histogram() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile_us(0.99), 0);
        assert_eq!(m.snapshot().queue_p99_us, 0);
    }
}
