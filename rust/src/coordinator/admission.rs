//! Traffic resilience in front of the micro-batch coalescer: admission
//! control (predictive load shedding) and priority-aware weighted fair
//! queuing with an explicit starvation bound.
//!
//! **Admission** ([`AdmissionGate`]): the client consults the gate before
//! enqueueing. The gate predicts queue delay as `backlog × observed
//! service time / workers` (service time is an EWMA fed by the workers);
//! when the prediction exceeds the configured budget the job is shed with
//! a typed [`JobError::Overloaded`] carrying a `retry_after` hint —
//! replacing the old behavior of silently parking the caller on the
//! bounded channel. With no budget configured the gate admits everything
//! and submission behaves exactly as before.
//!
//! **Fair queuing** (`FairQueue`, crate-internal): each server worker drains available
//! envelopes into a small reorder window and picks micro-batches by
//! priority class ([`Priority`]), round-robin across tenants within a
//! class, FIFO within a tenant. B-sharing coalescing still applies — the
//! batch is extended with every windowed job sharing the anchor's `B`,
//! whatever its class, because riding an existing `prepare` delays nobody.
//! Every job left in the window ages by one *bypass*; a job bypassed
//! [`AdmissionConfig::starvation_bound`] times is promoted ahead of
//! everything newer regardless of class, so coalescing and priorities can
//! no longer defer a singleton job indefinitely.
//!
//! [`JobError::Overloaded`]: super::error::JobError::Overloaded

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::job::{Priority, PRIORITY_CLASSES};
use super::server::JobEnvelope;

/// Admission + fairness knobs (part of `ServerConfig`).
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Queue-delay budget: shed a submission when `backlog × observed
    /// service time / workers` exceeds this. `None` disables the gate
    /// (submission blocks under backpressure, as before).
    pub max_queue_delay: Option<Duration>,
    /// How many micro-batches may bypass a queued job before it is forced
    /// to anchor the next batch regardless of priority class or tenant.
    pub starvation_bound: u32,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_queue_delay: None,
            starvation_bound: 4,
        }
    }
}

/// Shared gate state: clients consult it at submit time, workers feed it
/// observations. Lock-free — two atomics, no queue traversal.
#[derive(Debug)]
pub struct AdmissionGate {
    /// Budget in µs; `None` = gate disabled.
    max_delay_us: Option<u64>,
    workers: u64,
    /// Jobs accepted (enqueued or windowed in a worker's fair queue) but
    /// not yet executing — the true backlog, channel + reorder windows.
    backlog: AtomicU64,
    /// EWMA of per-job service time, µs (0 = no observation yet; the gate
    /// admits everything until the first job completes).
    service_ewma_us: AtomicU64,
}

impl AdmissionGate {
    pub fn new(cfg: &AdmissionConfig, workers: usize) -> AdmissionGate {
        AdmissionGate {
            max_delay_us: cfg.max_queue_delay.map(|d| d.as_micros() as u64),
            workers: workers.max(1) as u64,
            backlog: AtomicU64::new(0),
            service_ewma_us: AtomicU64::new(0),
        }
    }

    /// Jobs accepted but not yet executing.
    pub fn backlog(&self) -> u64 {
        self.backlog.load(Ordering::Relaxed)
    }

    /// Current per-job service-time estimate, µs (0 until the first job).
    pub fn service_estimate_us(&self) -> u64 {
        self.service_ewma_us.load(Ordering::Relaxed)
    }

    /// Predicted queue delay for a job admitted now.
    pub fn predicted_delay(&self) -> Duration {
        Duration::from_micros(self.predicted_delay_us())
    }

    fn predicted_delay_us(&self) -> u64 {
        let backlog = self.backlog.load(Ordering::Relaxed);
        let ewma = self.service_ewma_us.load(Ordering::Relaxed);
        backlog.saturating_mul(ewma) / self.workers
    }

    /// Admit or shed. `Err(retry_after)` means the predicted queue delay
    /// exceeds the budget; the hint is how long until enough backlog
    /// drains for the prediction to fit again (at least one service slot).
    pub fn admit(&self) -> Result<(), Duration> {
        let Some(budget) = self.max_delay_us else {
            return Ok(());
        };
        let predicted = self.predicted_delay_us();
        if predicted <= budget {
            Ok(())
        } else {
            let excess = predicted - budget;
            Err(Duration::from_micros(excess.max(self.retry_slot_us())))
        }
    }

    /// Backoff hint when shedding without a prediction (e.g. a bounded
    /// wait that timed out): one service slot, floored at 1ms.
    pub fn retry_hint(&self) -> Duration {
        Duration::from_micros(self.retry_slot_us())
    }

    fn retry_slot_us(&self) -> u64 {
        self.service_ewma_us.load(Ordering::Relaxed).max(1_000)
    }

    /// A job was enqueued (call after a successful send).
    pub fn on_enqueue(&self) {
        self.backlog.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` jobs left the backlog (entered an executing batch, or were
    /// drained at shutdown). Saturating: a miscount can never wrap the
    /// gate into refusing everything.
    pub fn on_start(&self, n: usize) {
        let _ = self
            .backlog
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(n as u64))
            });
    }

    /// Feed one completed job's service time into the EWMA (¾ old + ¼
    /// new). The update is load/store racy across workers — acceptable:
    /// the EWMA is a smoothed estimate, not an invariant.
    pub fn observe_service(&self, service: Duration) {
        let us = (service.as_micros() as u64).max(1);
        let prev = self.service_ewma_us.load(Ordering::Relaxed);
        let next = if prev == 0 { us } else { (3 * prev + us) / 4 };
        self.service_ewma_us.store(next, Ordering::Relaxed);
    }
}

struct PendingJob {
    env: JobEnvelope,
    /// Micro-batches that have been taken while this job waited.
    bypassed: u32,
}

/// Per-worker reorder window implementing weighted fair queuing over the
/// FIFO channel: priority class first, tenant round-robin within a class,
/// FIFO within a tenant, same-`B` coalescing across everything, and the
/// starvation bound overriding all of it.
pub(crate) struct FairQueue {
    pending: Vec<PendingJob>,
    bound: u32,
    /// Last tenant served per class — the round-robin cursor.
    last_tenant: [Option<u32>; PRIORITY_CLASSES],
}

impl FairQueue {
    pub(crate) fn new(starvation_bound: u32) -> FairQueue {
        FairQueue {
            pending: Vec::new(),
            bound: starvation_bound.max(1),
            last_tenant: [None; PRIORITY_CLASSES],
        }
    }

    pub(crate) fn push(&mut self, env: JobEnvelope) {
        self.pending.push(PendingJob { env, bypassed: 0 });
    }

    pub(crate) fn len(&self) -> usize {
        self.pending.len()
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Select the next micro-batch (≥ 1 job when non-empty): the anchor by
    /// starvation override → priority → tenant round-robin → FIFO, then
    /// every windowed job sharing the anchor's `B` (any class/tenant) up
    /// to `max_batch`. Jobs left behind age by one bypass.
    pub(crate) fn take_batch(&mut self, max_batch: usize) -> Vec<JobEnvelope> {
        if self.pending.is_empty() {
            return Vec::new();
        }
        let anchor = self.pending.remove(self.select_anchor());
        let mut batch = vec![anchor.env];
        let cap = max_batch.max(1);
        let mut i = 0;
        while i < self.pending.len() && batch.len() < cap {
            if self.pending[i].env.job.b.same_source(&batch[0].job.b) {
                batch.push(self.pending.remove(i).env);
            } else {
                i += 1;
            }
        }
        for p in &mut self.pending {
            p.bypassed += 1;
        }
        batch
    }

    fn select_anchor(&mut self) -> usize {
        // starvation override: the most-bypassed job at/over the bound
        // (earliest wins ties, preserving FIFO among equally starved jobs)
        let mut starved: Option<usize> = None;
        for (i, p) in self.pending.iter().enumerate() {
            if p.bypassed >= self.bound {
                let beats = match starved {
                    Some(j) => p.bypassed > self.pending[j].bypassed,
                    None => true,
                };
                if beats {
                    starved = Some(i);
                }
            }
        }
        if let Some(i) = starved {
            return i;
        }
        // highest priority class present in the window
        let best = self
            .pending
            .iter()
            .map(|p| p.env.job.opts.priority.class())
            .min()
            .unwrap_or(Priority::Normal.class());
        // round-robin across the class's tenants so one tenant's burst
        // cannot monopolize the worker within its own class
        let mut tenants: Vec<u32> = self
            .pending
            .iter()
            .filter(|p| p.env.job.opts.priority.class() == best)
            .map(|p| p.env.job.opts.tenant)
            .collect();
        tenants.sort_unstable();
        tenants.dedup();
        let next_tenant = match self.last_tenant[best] {
            Some(last) => tenants
                .iter()
                .copied()
                .find(|&t| t > last)
                .unwrap_or(tenants[0]),
            None => tenants[0],
        };
        self.last_tenant[best] = Some(next_tenant);
        self.pending
            .iter()
            .position(|p| {
                p.env.job.opts.priority.class() == best && p.env.job.opts.tenant == next_tenant
            })
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::SpmmJob;
    use crate::datasets::synth::uniform;
    use std::sync::mpsc::sync_channel;
    use std::sync::Arc;
    use std::time::Instant;

    fn env(id: u64, b: &Arc<crate::formats::csr::Csr>, tenant: u32, prio: Priority) -> JobEnvelope {
        let a = Arc::new(uniform(4, 4, 0.5, 1));
        let (reply, _rx) = sync_channel(1);
        // leak the receiver so replies don't error (irrelevant here)
        std::mem::forget(_rx);
        JobEnvelope {
            job: SpmmJob::new(id, a, Arc::clone(b))
                .with_tenant(tenant)
                .with_priority(prio),
            reply,
            enqueued: Instant::now(),
        }
    }

    fn ids(batch: &[JobEnvelope]) -> Vec<u64> {
        batch.iter().map(|e| e.job.id).collect()
    }

    #[test]
    fn gate_disabled_admits_everything() {
        let g = AdmissionGate::new(&AdmissionConfig::default(), 1);
        g.observe_service(Duration::from_millis(100));
        for _ in 0..1000 {
            g.on_enqueue();
        }
        assert!(g.admit().is_ok());
    }

    #[test]
    fn gate_sheds_when_predicted_delay_exceeds_budget() {
        let cfg = AdmissionConfig {
            max_queue_delay: Some(Duration::from_millis(10)),
            ..Default::default()
        };
        let g = AdmissionGate::new(&cfg, 2);
        // no observations yet: everything admits
        g.on_enqueue();
        assert!(g.admit().is_ok());
        // 10ms/job, 2 workers, 4 queued -> predicted 20ms > 10ms budget
        g.observe_service(Duration::from_millis(10));
        for _ in 0..3 {
            g.on_enqueue();
        }
        let retry = g.admit().expect_err("must shed over budget");
        assert!(retry >= Duration::from_millis(1), "{retry:?}");
        // draining the backlog re-admits
        g.on_start(4);
        assert_eq!(g.backlog(), 0);
        assert!(g.admit().is_ok());
    }

    #[test]
    fn gate_backlog_never_underflows() {
        let g = AdmissionGate::new(&AdmissionConfig::default(), 1);
        g.on_start(10);
        assert_eq!(g.backlog(), 0);
        g.on_enqueue();
        g.on_start(100);
        assert_eq!(g.backlog(), 0);
    }

    #[test]
    fn ewma_converges_toward_observations() {
        let g = AdmissionGate::new(&AdmissionConfig::default(), 1);
        assert_eq!(g.service_estimate_us(), 0);
        g.observe_service(Duration::from_micros(1_000));
        assert_eq!(g.service_estimate_us(), 1_000);
        for _ in 0..32 {
            g.observe_service(Duration::from_micros(2_000));
        }
        let est = g.service_estimate_us();
        assert!((1_900..=2_000).contains(&est), "{est}");
    }

    #[test]
    fn higher_priority_anchors_before_lower() {
        let b1 = Arc::new(uniform(4, 4, 0.5, 2));
        let b2 = Arc::new(uniform(4, 4, 0.5, 3));
        let mut q = FairQueue::new(8);
        q.push(env(1, &b1, 0, Priority::Low));
        q.push(env(2, &b2, 0, Priority::High));
        assert_eq!(ids(&q.take_batch(1)), vec![2]);
        assert_eq!(ids(&q.take_batch(1)), vec![1]);
        assert!(q.is_empty());
    }

    #[test]
    fn same_b_jobs_coalesce_across_classes() {
        let b = Arc::new(uniform(4, 4, 0.5, 2));
        let b_other = Arc::new(uniform(4, 4, 0.5, 3));
        let mut q = FairQueue::new(8);
        q.push(env(1, &b, 0, Priority::Low));
        q.push(env(2, &b_other, 0, Priority::High));
        q.push(env(3, &b, 1, Priority::Normal));
        // anchor = job 2 (high); no other job shares its B
        assert_eq!(ids(&q.take_batch(4)), vec![2]);
        // next anchor = job 3 (normal beats low); job 1 shares its B and rides
        assert_eq!(ids(&q.take_batch(4)), vec![3, 1]);
    }

    #[test]
    fn tenants_round_robin_within_a_class() {
        let mut q = FairQueue::new(100);
        let bs: Vec<_> = (0..6)
            .map(|i| Arc::new(uniform(4, 4, 0.5, 10 + i)))
            .collect();
        // tenant 0: jobs 0,1,2 queued first; tenant 1: jobs 3,4; tenant 2: job 5
        q.push(env(0, &bs[0], 0, Priority::Normal));
        q.push(env(1, &bs[1], 0, Priority::Normal));
        q.push(env(2, &bs[2], 0, Priority::Normal));
        q.push(env(3, &bs[3], 1, Priority::Normal));
        q.push(env(4, &bs[4], 1, Priority::Normal));
        q.push(env(5, &bs[5], 2, Priority::Normal));
        let mut order = Vec::new();
        while !q.is_empty() {
            order.extend(ids(&q.take_batch(1)));
        }
        // round-robin 0,1,2 then wrap: tenant 0's burst cannot monopolize
        assert_eq!(order, vec![0, 3, 5, 1, 4, 2]);
    }

    #[test]
    fn starvation_bound_promotes_bypassed_jobs() {
        let bound = 3;
        let mut q = FairQueue::new(bound);
        let b_low = Arc::new(uniform(4, 4, 0.5, 2));
        q.push(env(0, &b_low, 0, Priority::Low));
        // keep feeding high-priority singletons; the low job must still
        // run within `bound + 1` batches
        let mut served_low_after = None;
        for round in 0..10u32 {
            let b = Arc::new(uniform(4, 4, 0.5, 100 + round as u64));
            q.push(env(1000 + round as u64, &b, 0, Priority::High));
            let batch = q.take_batch(1);
            if ids(&batch) == vec![0] {
                served_low_after = Some(round);
                break;
            }
        }
        let round = served_low_after.expect("low-priority job starved forever");
        assert!(
            round <= bound,
            "low job served only after {round} batches (bound {bound})"
        );
    }

    #[test]
    fn take_batch_respects_max_batch_and_empty_queue() {
        let mut q = FairQueue::new(4);
        assert!(q.take_batch(8).is_empty());
        let b = Arc::new(uniform(4, 4, 0.5, 2));
        for i in 0..5 {
            q.push(env(i, &b, 0, Priority::Normal));
        }
        assert_eq!(q.take_batch(3).len(), 3);
        assert_eq!(q.take_batch(3).len(), 2);
        assert!(q.is_empty());
    }
}
