//! Typed job errors — the serving layer's failure surface.
//!
//! Everything a submitted job can die of is one of these variants; callers
//! match instead of scraping strings. Engine-side failures
//! ([`crate::engine::EngineError`]) lift losslessly via `From`, and the
//! coordinator adds the failure modes only it can observe: a full bounded
//! queue, an admission gate shedding load ([`JobError::Overloaded`]), a
//! deadline that expired before execution ([`JobError::DeadlineExceeded`]),
//! and a server that shut down before (or while) the job ran.

use std::fmt;
use std::time::Duration;

use crate::engine::{Algorithm, EngineError};
use crate::formats::error::FormatError;
use crate::formats::traits::FormatKind;

/// Why a job failed. Implements [`std::error::Error`]; `Display` keeps the
/// established phrasing ("dimension mismatch…", "no kernel registered…")
/// so logs stay greppable across the API migration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobError {
    /// `try_submit` found the bounded queue at capacity (backpressure).
    /// Transient: resubmit later or fall back to the blocking `submit`.
    QueueFull,
    /// No kernel registered under the requested `(format, algorithm)` key;
    /// `None`/`None` means the worker's registry is empty.
    KernelUnavailable {
        format: Option<FormatKind>,
        algorithm: Option<Algorithm>,
    },
    /// Inner dimensions do not agree: `A` is `a`, `B` is `b`.
    ShapeMismatch {
        a: (usize, usize),
        b: (usize, usize),
    },
    /// An operand could not be ingested/converted (formats-layer failure,
    /// lifted losslessly — e.g. an InCRS counter overflow on conversion).
    Format(FormatError),
    /// The kernel's prepare or execute step failed.
    ExecFailed(String),
    /// The admission gate shed this job: predicted queue delay (depth ×
    /// observed service time) exceeded the configured budget. `retry_after`
    /// is the gate's estimate of when capacity frees up — resubmit after
    /// that long (or route to another server).
    Overloaded { retry_after: Duration },
    /// The job's [`super::JobOptions::deadline`] expired before it could be
    /// (fully) executed. Expired work is dropped at the cheapest possible
    /// point — dequeue, pre-`prepare`, or pre-band-dispatch — never run.
    DeadlineExceeded,
    /// The server shut down before the job could complete (or the reply
    /// channel was lost). Accepted-but-unserved jobs drain with this.
    Shutdown,
}

impl JobError {
    /// Transient conditions worth retrying (against this or another
    /// server); the other variants are deterministic job defects.
    /// `Overloaded` is transient by construction (it carries a
    /// `retry_after` hint); `DeadlineExceeded` is *not* — the caller's
    /// budget is spent, and resubmitting the same expired deadline would
    /// only be shed again. Mint a fresh deadline to retry.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            JobError::QueueFull | JobError::Overloaded { .. } | JobError::Shutdown
        )
    }

    /// For [`JobError::Overloaded`], the gate's backoff hint; `None` for
    /// every other variant. Lets retry loops sleep exactly as long as the
    /// server predicted instead of guessing.
    pub fn retry_after(&self) -> Option<Duration> {
        match self {
            JobError::Overloaded { retry_after } => Some(*retry_after),
            _ => None,
        }
    }
}

impl From<EngineError> for JobError {
    fn from(e: EngineError) -> JobError {
        match e {
            EngineError::KernelUnavailable { format, algorithm } => {
                JobError::KernelUnavailable { format, algorithm }
            }
            EngineError::ShapeMismatch { a, b } => JobError::ShapeMismatch { a, b },
            EngineError::Format(fe) => JobError::Format(fe),
            EngineError::ExecFailed(msg) => JobError::ExecFailed(msg),
        }
    }
}

impl From<FormatError> for JobError {
    fn from(e: FormatError) -> JobError {
        JobError::Format(e)
    }
}

impl fmt::Display for JobError {
    fn fmt(&self, w: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobError::QueueFull => write!(w, "queue full (backpressure)"),
            JobError::KernelUnavailable {
                format: Some(f),
                algorithm: Some(alg),
            } => write!(w, "no kernel registered for {}/{}", f.name(), alg.name()),
            JobError::KernelUnavailable { .. } => write!(w, "empty kernel registry"),
            JobError::ShapeMismatch { a, b } => {
                write!(w, "dimension mismatch: A is {a:?}, B is {b:?}")
            }
            JobError::Format(e) => write!(w, "format error: {e}"),
            JobError::ExecFailed(msg) => write!(w, "execution failed: {msg}"),
            JobError::Overloaded { retry_after } => write!(
                w,
                "overloaded (load shed): retry after {}ms",
                retry_after.as_millis()
            ),
            JobError::DeadlineExceeded => write!(w, "deadline exceeded"),
            JobError::Shutdown => write!(w, "server shut down"),
        }
    }
}

impl std::error::Error for JobError {}

/// Legacy bridge so `?` keeps working in `Result<_, String>` contexts (the
/// CLI) without reintroducing `.map_err(|e| e.to_string())` round-trips.
impl From<JobError> for String {
    fn from(e: JobError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_errors_lift_losslessly() {
        let e = EngineError::ShapeMismatch { a: (3, 4), b: (5, 3) };
        assert_eq!(
            JobError::from(e),
            JobError::ShapeMismatch { a: (3, 4), b: (5, 3) }
        );
        let e = EngineError::KernelUnavailable {
            format: Some(FormatKind::Jad),
            algorithm: Some(Algorithm::Inner),
        };
        assert!(matches!(
            JobError::from(e),
            JobError::KernelUnavailable { format: Some(FormatKind::Jad), .. }
        ));
        assert_eq!(
            JobError::from(EngineError::ExecFailed("x".into())),
            JobError::ExecFailed("x".into())
        );
    }

    #[test]
    fn transience_classification() {
        assert!(JobError::QueueFull.is_transient());
        assert!(JobError::Shutdown.is_transient());
        assert!(JobError::Overloaded { retry_after: Duration::from_millis(5) }.is_transient());
        assert!(!JobError::DeadlineExceeded.is_transient());
        assert!(!JobError::ShapeMismatch { a: (1, 1), b: (2, 2) }.is_transient());
        assert!(!JobError::ExecFailed("x".into()).is_transient());
        assert!(!JobError::Format(FormatError::UnknownFormat("x".into())).is_transient());
    }

    #[test]
    fn retry_after_surfaces_only_on_overloaded() {
        let e = JobError::Overloaded { retry_after: Duration::from_millis(40) };
        assert_eq!(e.retry_after(), Some(Duration::from_millis(40)));
        assert_eq!(JobError::QueueFull.retry_after(), None);
        assert_eq!(JobError::DeadlineExceeded.retry_after(), None);
        // Display carries the hint so the CLI error text shows it verbatim.
        assert!(e.to_string().contains("retry after 40ms"));
        assert!(JobError::DeadlineExceeded.to_string().contains("deadline exceeded"));
    }

    #[test]
    fn format_errors_lift_losslessly() {
        let fe = FormatError::UnknownFormat("nope".into());
        assert_eq!(JobError::from(fe.clone()), JobError::Format(fe.clone()));
        assert_eq!(
            JobError::from(EngineError::Format(fe.clone())),
            JobError::Format(fe)
        );
    }

    #[test]
    fn display_phrasing_is_stable() {
        assert!(JobError::ShapeMismatch { a: (4, 5), b: (7, 4) }
            .to_string()
            .contains("dimension mismatch"));
        assert!(JobError::KernelUnavailable {
            format: Some(FormatKind::Csr),
            algorithm: Some(Algorithm::Block),
        }
        .to_string()
        .contains("no kernel registered"));
        let s: String = JobError::Shutdown.into();
        assert_eq!(s, "server shut down");
    }
}
