//! Typed job errors — the serving layer's failure surface.
//!
//! Everything a submitted job can die of is one of five variants; callers
//! match instead of scraping strings. Engine-side failures
//! ([`crate::engine::EngineError`]) lift losslessly via `From`, and the
//! coordinator adds the two failure modes only it can observe: a full
//! bounded queue and a server that shut down before (or while) the job ran.

use std::fmt;

use crate::engine::{Algorithm, EngineError};
use crate::formats::error::FormatError;
use crate::formats::traits::FormatKind;

/// Why a job failed. Implements [`std::error::Error`]; `Display` keeps the
/// established phrasing ("dimension mismatch…", "no kernel registered…")
/// so logs stay greppable across the API migration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobError {
    /// `try_submit` found the bounded queue at capacity (backpressure).
    /// Transient: resubmit later or fall back to the blocking `submit`.
    QueueFull,
    /// No kernel registered under the requested `(format, algorithm)` key;
    /// `None`/`None` means the worker's registry is empty.
    KernelUnavailable {
        format: Option<FormatKind>,
        algorithm: Option<Algorithm>,
    },
    /// Inner dimensions do not agree: `A` is `a`, `B` is `b`.
    ShapeMismatch {
        a: (usize, usize),
        b: (usize, usize),
    },
    /// An operand could not be ingested/converted (formats-layer failure,
    /// lifted losslessly — e.g. an InCRS counter overflow on conversion).
    Format(FormatError),
    /// The kernel's prepare or execute step failed.
    ExecFailed(String),
    /// The server shut down before the job could complete (or the reply
    /// channel was lost). Accepted-but-unserved jobs drain with this.
    Shutdown,
}

impl JobError {
    /// Transient conditions worth retrying (against this or another
    /// server); the other variants are deterministic job defects.
    pub fn is_transient(&self) -> bool {
        matches!(self, JobError::QueueFull | JobError::Shutdown)
    }
}

impl From<EngineError> for JobError {
    fn from(e: EngineError) -> JobError {
        match e {
            EngineError::KernelUnavailable { format, algorithm } => {
                JobError::KernelUnavailable { format, algorithm }
            }
            EngineError::ShapeMismatch { a, b } => JobError::ShapeMismatch { a, b },
            EngineError::Format(fe) => JobError::Format(fe),
            EngineError::ExecFailed(msg) => JobError::ExecFailed(msg),
        }
    }
}

impl From<FormatError> for JobError {
    fn from(e: FormatError) -> JobError {
        JobError::Format(e)
    }
}

impl fmt::Display for JobError {
    fn fmt(&self, w: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobError::QueueFull => write!(w, "queue full (backpressure)"),
            JobError::KernelUnavailable {
                format: Some(f),
                algorithm: Some(alg),
            } => write!(w, "no kernel registered for {}/{}", f.name(), alg.name()),
            JobError::KernelUnavailable { .. } => write!(w, "empty kernel registry"),
            JobError::ShapeMismatch { a, b } => {
                write!(w, "dimension mismatch: A is {a:?}, B is {b:?}")
            }
            JobError::Format(e) => write!(w, "format error: {e}"),
            JobError::ExecFailed(msg) => write!(w, "execution failed: {msg}"),
            JobError::Shutdown => write!(w, "server shut down"),
        }
    }
}

impl std::error::Error for JobError {}

/// Legacy bridge so `?` keeps working in `Result<_, String>` contexts (the
/// CLI) without reintroducing `.map_err(|e| e.to_string())` round-trips.
impl From<JobError> for String {
    fn from(e: JobError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_errors_lift_losslessly() {
        let e = EngineError::ShapeMismatch { a: (3, 4), b: (5, 3) };
        assert_eq!(
            JobError::from(e),
            JobError::ShapeMismatch { a: (3, 4), b: (5, 3) }
        );
        let e = EngineError::KernelUnavailable {
            format: Some(FormatKind::Jad),
            algorithm: Some(Algorithm::Inner),
        };
        assert!(matches!(
            JobError::from(e),
            JobError::KernelUnavailable { format: Some(FormatKind::Jad), .. }
        ));
        assert_eq!(
            JobError::from(EngineError::ExecFailed("x".into())),
            JobError::ExecFailed("x".into())
        );
    }

    #[test]
    fn transience_classification() {
        assert!(JobError::QueueFull.is_transient());
        assert!(JobError::Shutdown.is_transient());
        assert!(!JobError::ShapeMismatch { a: (1, 1), b: (2, 2) }.is_transient());
        assert!(!JobError::ExecFailed("x".into()).is_transient());
        assert!(!JobError::Format(FormatError::UnknownFormat("x".into())).is_transient());
    }

    #[test]
    fn format_errors_lift_losslessly() {
        let fe = FormatError::UnknownFormat("nope".into());
        assert_eq!(JobError::from(fe.clone()), JobError::Format(fe.clone()));
        assert_eq!(
            JobError::from(EngineError::Format(fe.clone())),
            JobError::Format(fe)
        );
    }

    #[test]
    fn display_phrasing_is_stable() {
        assert!(JobError::ShapeMismatch { a: (4, 5), b: (7, 4) }
            .to_string()
            .contains("dimension mismatch"));
        assert!(JobError::KernelUnavailable {
            format: Some(FormatKind::Csr),
            algorithm: Some(Algorithm::Block),
        }
        .to_string()
        .contains("no kernel registered"));
        let s: String = JobError::Shutdown.into();
        assert_eq!(s, "server shut down");
    }
}
