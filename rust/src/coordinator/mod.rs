//! L3 coordinator: the serving layer that owns process topology, routing,
//! batching, and metrics (DESIGN.md §1).
//!
//! * [`job`] — SpMM job descriptors/results.
//! * [`router`] — format strategy (InCRS or not) + engine selection, the
//!   paper's §II/§III decision as an explicit, testable policy.
//! * [`scheduler`] — dispatch batching with exactly-once coverage.
//! * [`server`] — bounded-queue worker pool (backpressure, per-worker PJRT
//!   engines, graceful shutdown).
//! * [`metrics`] — lock-free counters + latency histogram.

pub mod job;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod server;

pub use job::{JobOptions, JobOutput, JobResult, SpmmJob};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::{route, AccessStrategy, EngineKind, Route, RoutingPolicy};
pub use scheduler::{describe, split_batches, Batch, ScheduleInfo};
pub use server::{Server, ServerConfig};
