//! L3 coordinator: the serving layer that owns process topology, routing,
//! batching, and metrics (DESIGN.md §1).
//!
//! * [`client`] — **the public serving API**: [`SpmmClient`] handles,
//!   [`JobBuilder`] construction, [`JobHandle`] futures
//!   (`wait`/`wait_timeout`/`try_poll`/`batch_wait_all`), and batch entry
//!   points (`submit_many`/`stream`). Jobs ingest typed
//!   [`crate::formats::MatrixOperand`]s — any Table-I format, CSR staying
//!   zero-cost.
//! * [`admission`] — the traffic-resilience layer: an [`AdmissionGate`]
//!   shedding load with typed `Overloaded { retry_after }` when predicted
//!   queue delay exceeds the budget, and the per-worker [`admission`] fair
//!   queue (priority classes, tenant round-robin, same-`B` coalescing,
//!   explicit starvation bound).
//! * [`error`] — typed [`JobError`] (queue full, overloaded/shed with
//!   retry-after, deadline exceeded, kernel unavailable, shape mismatch,
//!   format/ingestion failure, exec failure, shutdown); engine and formats
//!   errors lift via `From`.
//! * [`job`] — SpMM job descriptors/results (with per-job kernel override).
//! * [`router`] — format strategy (InCRS or not) + kernel-key selection
//!   over the engine registry, the paper's §II/§III decision as an
//!   explicit, testable policy.
//! * [`scheduler`] — dispatch batching with exactly-once coverage.
//! * [`server`] — bounded-queue worker pool (backpressure, per-worker
//!   kernel registries, drain-on-shutdown) with B-sharing micro-batch
//!   coalescing: jobs with bit-identical `B` share one
//!   `SpmmKernel::prepare`, LRU-cached across batches. Jobs asking for
//!   `shards > 1` execute through `engine::shard`'s row-band workers
//!   (bit-identical merge, `ExecFailed` on shard loss).
//! * [`metrics`] — lock-free counters + latency/queue-wait histograms +
//!   coalescing stats (`prepare_builds`, `prepare_cache_hits`,
//!   `coalesced_jobs`) + per-shard wall/queue histograms
//!   (`shard_wall_p50_us`, `shard_queue_p50_us`, `shards_executed`) + the
//!   learned-selection surface (`kernel_log`, `model_refits`, per-kernel
//!   [`metrics::CalibrationEntry`] calibration errors).
//!
//! The learned-selection loop (`engine::learn`) rides the server: every
//! executed job logs the scores selection ranked, a refit every
//! [`LearnConfig::refit_every`] completed jobs republishes the fitted
//! cost model to all workers (with hysteresis damping flapping), and the
//! model persists to [`LearnConfig::model_path`] across restarts.

pub mod admission;
pub mod client;
pub mod error;
pub mod job;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod server;

pub use admission::{AdmissionConfig, AdmissionGate};
pub use client::{JobBuilder, JobHandle, JobStream, SpmmClient};
pub use error::JobError;
pub use job::{JobOptions, JobOutput, JobResult, Priority, SpmmJob, PRIORITY_CLASSES};
pub use metrics::{CalibrationEntry, Histogram, KernelObservation, Metrics, MetricsSnapshot};
pub use router::{route, AccessStrategy, KernelSpec, Route, RoutingPolicy};
pub use scheduler::{describe, split_batches, Batch, ScheduleInfo};
pub use server::{CoalesceConfig, LearnConfig, RegistryHook, Server, ServerConfig};
