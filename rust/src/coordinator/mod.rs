//! L3 coordinator: the serving layer that owns process topology, routing,
//! batching, and metrics (DESIGN.md §1).
//!
//! * [`job`] — SpMM job descriptors/results (with per-job kernel override).
//! * [`router`] — format strategy (InCRS or not) + kernel-key selection
//!   over the engine registry, the paper's §II/§III decision as an
//!   explicit, testable policy.
//! * [`scheduler`] — dispatch batching with exactly-once coverage.
//! * [`server`] — bounded-queue worker pool (backpressure, per-worker
//!   kernel registries, drain-on-shutdown).
//! * [`metrics`] — lock-free counters + latency/queue-wait histograms.

pub mod job;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod server;

pub use job::{JobOptions, JobOutput, JobResult, SpmmJob};
pub use metrics::{Histogram, Metrics, MetricsSnapshot};
pub use router::{route, AccessStrategy, KernelSpec, Route, RoutingPolicy};
pub use scheduler::{describe, split_batches, Batch, ScheduleInfo};
pub use server::{Server, ServerConfig};
