//! Format/engine routing — the paper's §II decision, made explicit.
//!
//! When an SpMM job needs column-order access to a row-stored `B`, the
//! router decides whether to pay the one-time InCRS counter-vector build.
//! The paper's estimate (§III.C): column access in CRS costs ≈ ½·N·D per
//! locate vs ≈ b/2+1 in InCRS, a ratio of N·D/(b+2). InCRS pays off when
//! that ratio clears a threshold — e.g. Table II shows Mks at only ≈3×,
//! where the counter storage (12% extra) may not be worth it.

use crate::formats::csr::Csr;
use crate::formats::incrs::InCrsParams;
use crate::formats::traits::SparseMatrix;

/// How B will be accessed by the chosen algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessStrategy {
    /// Row-order Gustavson on the CPU — no column access at all.
    RowOrder,
    /// Column access through plain CRS scans (paper's baseline).
    ColumnCrs,
    /// Column access through InCRS counter-vectors (paper's proposal).
    ColumnInCrs,
}

/// Which execution backend gets the job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT Pallas kernels via PJRT (block-sparse dispatch path).
    Pjrt,
    /// Pure-Rust fallback of the same plan.
    Cpu,
}

#[derive(Clone, Copy, Debug)]
pub struct RoutingPolicy {
    /// Minimum estimated MA ratio N·D/(b+2) for InCRS to pay off.
    pub incrs_min_ratio: f64,
    pub incrs_params: InCrsParams,
    pub prefer_pjrt: bool,
}

impl Default for RoutingPolicy {
    fn default() -> Self {
        RoutingPolicy {
            // Table II: Mks at ratio 3 is the paper's marginal case; below
            // ~2 the counter storage and build time aren't justified.
            incrs_min_ratio: 2.0,
            incrs_params: InCrsParams::default(),
            prefer_pjrt: true,
        }
    }
}

/// The routing decision with its rationale (logged + asserted in tests).
#[derive(Clone, Copy, Debug)]
pub struct Route {
    pub access: AccessStrategy,
    pub engine: EngineKind,
    /// estimated N·D/(b+2) for B.
    pub estimated_ma_ratio: f64,
}

/// Decide how to run C = A × B given that `b` is stored row-ordered and the
/// chosen kernel needs it by column (`needs_column_access` = the accelerator
/// / inner-product path; Gustavson jobs pass false).
pub fn route(
    b: &Csr,
    needs_column_access: bool,
    pjrt_available: bool,
    policy: &RoutingPolicy,
) -> Route {
    let nd = b.nnz() as f64 / b.rows().max(1) as f64; // avg nnz/row = N·D
    let ratio = nd / (policy.incrs_params.block as f64 + 2.0);
    let access = if !needs_column_access {
        AccessStrategy::RowOrder
    } else if ratio >= policy.incrs_min_ratio {
        AccessStrategy::ColumnInCrs
    } else {
        AccessStrategy::ColumnCrs
    };
    let engine = if policy.prefer_pjrt && pjrt_available {
        EngineKind::Pjrt
    } else {
        EngineKind::Cpu
    };
    Route {
        access,
        engine,
        estimated_ma_ratio: ratio,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;

    #[test]
    fn dense_rows_choose_incrs() {
        // docword-like: 480 nnz/row -> ratio ≈ 14
        let b = uniform(64, 12_000, 0.04, 1);
        let r = route(&b, true, true, &RoutingPolicy::default());
        assert_eq!(r.access, AccessStrategy::ColumnInCrs);
        assert!(r.estimated_ma_ratio > 10.0);
        assert_eq!(r.engine, EngineKind::Pjrt);
    }

    #[test]
    fn sparse_rows_stay_on_crs() {
        // ~17 nnz/row -> ratio ≈ 0.5: counters don't pay off
        let b = uniform(64, 3_000, 0.0055, 2);
        let r = route(&b, true, true, &RoutingPolicy::default());
        assert_eq!(r.access, AccessStrategy::ColumnCrs);
    }

    #[test]
    fn row_order_jobs_skip_the_question() {
        let b = uniform(64, 12_000, 0.04, 3);
        let r = route(&b, false, true, &RoutingPolicy::default());
        assert_eq!(r.access, AccessStrategy::RowOrder);
    }

    #[test]
    fn engine_falls_back_without_pjrt() {
        let b = uniform(8, 64, 0.2, 4);
        let r = route(&b, true, false, &RoutingPolicy::default());
        assert_eq!(r.engine, EngineKind::Cpu);
    }
}
