//! Format/kernel routing — the paper's §II decision, made explicit over the
//! engine registry's `(FormatKind, Algorithm)` key space.
//!
//! When an SpMM job needs column-order access to a row-stored `B`, the
//! router decides whether to pay the one-time InCRS counter-vector build.
//! The paper's estimate (§III.C): column access in CRS costs ≈ ½·N·D per
//! locate vs ≈ b/2+1 in InCRS, a ratio of N·D/(b+2). InCRS pays off when
//! that ratio clears a threshold — e.g. Table II shows Mks at only ≈3×,
//! where the counter storage (12% extra) may not be worth it. The routing
//! result is a registry key the caller resolves through
//! [`crate::engine::Registry`].

use crate::engine::Algorithm;
use crate::formats::csr::Csr;
use crate::formats::incrs::InCrsParams;
use crate::formats::traits::{FormatKind, SparseMatrix};

/// How B will be accessed by the chosen algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessStrategy {
    /// Row-order Gustavson on the CPU — no column access at all.
    RowOrder,
    /// Column access through plain CRS scans (paper's baseline).
    ColumnCrs,
    /// Column access through InCRS counter-vectors (paper's proposal).
    ColumnInCrs,
}

/// How the server picks the kernel for a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelSpec {
    /// Cost-hint selection across the whole registry per job
    /// ([`Registry::select`]).
    Auto,
    /// Always resolve this registry key (jobs may still override via
    /// `JobOptions::kernel`).
    Fixed(FormatKind, Algorithm),
}

impl Default for KernelSpec {
    /// The accelerator dispatch path — the old `EngineKind::Cpu` default.
    fn default() -> Self {
        KernelSpec::Fixed(FormatKind::Csr, Algorithm::Block)
    }
}

impl KernelSpec {
    /// The registry key an algorithm is registered under by default
    /// (inner-product → InCRS, the dense oracle → Dense, outer-product →
    /// CCS — the key names its column-major view of A — everything else →
    /// CSR) — the single place the CLI and examples map `--kernel` names.
    pub fn for_algorithm(alg: Algorithm) -> KernelSpec {
        let fmt = match alg {
            Algorithm::Inner => FormatKind::InCrs,
            Algorithm::Dense => FormatKind::Dense,
            Algorithm::OuterProduct => FormatKind::Csc,
            _ => FormatKind::Csr,
        };
        KernelSpec::Fixed(fmt, alg)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct RoutingPolicy {
    /// Minimum estimated MA ratio N·D/(b+2) for InCRS to pay off.
    pub incrs_min_ratio: f64,
    pub incrs_params: InCrsParams,
    /// Prefer the blocked accelerator kernel when it is available.
    pub prefer_accel: bool,
}

impl Default for RoutingPolicy {
    fn default() -> Self {
        RoutingPolicy {
            // Table II: Mks at ratio 3 is the paper's marginal case; below
            // ~2 the counter storage and build time aren't justified.
            incrs_min_ratio: 2.0,
            incrs_params: InCrsParams::default(),
            prefer_accel: true,
        }
    }
}

/// The routing decision with its rationale (logged + asserted in tests).
#[derive(Clone, Copy, Debug)]
pub struct Route {
    pub access: AccessStrategy,
    /// Registry key to resolve: `(B's format, algorithm)`.
    pub kernel: (FormatKind, Algorithm),
    /// estimated N·D/(b+2) for B.
    pub estimated_ma_ratio: f64,
}

/// Decide how to run C = A × B given that `b` is stored row-ordered and the
/// chosen kernel needs it by column (`needs_column_access` = the accelerator
/// / inner-product path; Gustavson jobs pass false). `accel_available` means
/// the blocked accelerator kernel is usable (PJRT artifacts loaded, or the
/// CPU twin is acceptable).
pub fn route(
    b: &Csr,
    needs_column_access: bool,
    accel_available: bool,
    policy: &RoutingPolicy,
) -> Route {
    let nd = b.nnz() as f64 / b.rows().max(1) as f64; // avg nnz/row = N·D
    let ratio = nd / (policy.incrs_params.block as f64 + 2.0);
    let access = if !needs_column_access {
        AccessStrategy::RowOrder
    } else if ratio >= policy.incrs_min_ratio {
        AccessStrategy::ColumnInCrs
    } else {
        AccessStrategy::ColumnCrs
    };
    let kernel = if policy.prefer_accel && accel_available {
        (FormatKind::Csr, Algorithm::Block)
    } else {
        match access {
            AccessStrategy::RowOrder => (FormatKind::Csr, Algorithm::Gustavson),
            AccessStrategy::ColumnCrs => (FormatKind::Csr, Algorithm::Inner),
            AccessStrategy::ColumnInCrs => (FormatKind::InCrs, Algorithm::Inner),
        }
    };
    Route {
        access,
        kernel,
        estimated_ma_ratio: ratio,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::engine::SpmmKernel;
    use crate::spmm::plan::Geometry;

    #[test]
    fn dense_rows_choose_incrs() {
        // docword-like: 480 nnz/row -> ratio ≈ 14
        let b = uniform(64, 12_000, 0.04, 1);
        let r = route(&b, true, true, &RoutingPolicy::default());
        assert_eq!(r.access, AccessStrategy::ColumnInCrs);
        assert!(r.estimated_ma_ratio > 10.0);
        assert_eq!(r.kernel, (FormatKind::Csr, Algorithm::Block));
    }

    #[test]
    fn sparse_rows_stay_on_crs() {
        // ~17 nnz/row -> ratio ≈ 0.5: counters don't pay off
        let b = uniform(64, 3_000, 0.0055, 2);
        let r = route(&b, true, false, &RoutingPolicy::default());
        assert_eq!(r.access, AccessStrategy::ColumnCrs);
        assert_eq!(r.kernel, (FormatKind::Csr, Algorithm::Inner));
    }

    #[test]
    fn row_order_jobs_skip_the_question() {
        let b = uniform(64, 12_000, 0.04, 3);
        let r = route(&b, false, false, &RoutingPolicy::default());
        assert_eq!(r.access, AccessStrategy::RowOrder);
        assert_eq!(r.kernel, (FormatKind::Csr, Algorithm::Gustavson));
    }

    #[test]
    fn column_jobs_route_to_the_incrs_kernel_without_accel() {
        let b = uniform(64, 12_000, 0.04, 4);
        let r = route(&b, true, false, &RoutingPolicy::default());
        assert_eq!(r.kernel, (FormatKind::InCrs, Algorithm::Inner));
    }

    #[test]
    fn routes_resolve_against_the_default_registry() {
        let reg = crate::engine::Registry::with_default_kernels(
            Geometry { block: 8, pairs: 16, slots: 8 },
            1,
        );
        let b = uniform(64, 32, 0.2, 6);
        for (needs_col, accel) in [(false, false), (true, false), (true, true)] {
            let r = route(&b, needs_col, accel, &RoutingPolicy::default());
            let k = reg.resolve(r.kernel.0, r.kernel.1).expect("kernel");
            assert_eq!((k.format(), k.algorithm()), r.kernel);
        }
    }

    #[test]
    fn for_algorithm_maps_to_registered_keys() {
        let reg = crate::engine::Registry::with_default_kernels(
            Geometry { block: 8, pairs: 16, slots: 8 },
            1,
        );
        for alg in Algorithm::ALL {
            let KernelSpec::Fixed(f, a) = KernelSpec::for_algorithm(alg) else {
                panic!("for_algorithm must return Fixed");
            };
            assert_eq!(a, alg);
            assert!(reg.resolve(f, a).is_some(), "{f:?}/{alg:?} not registered");
        }
    }
}
