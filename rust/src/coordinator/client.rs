//! The public serving API: [`SpmmClient`] handles, [`JobBuilder`]
//! construction, and [`JobHandle`] futures.
//!
//! A client is a cheap, cloneable, `Send` handle onto a running
//! [`super::server::Server`] (`server.client()`). Jobs ingest typed
//! [`MatrixOperand`]s — any Table-I storage format, CSR staying zero-cost
//! via `Arc` identity. Submission returns a
//! [`JobHandle`] — a one-shot future over the job's reply channel with
//! blocking (`wait`), bounded (`wait_timeout`), and non-blocking
//! (`try_poll`) completion, plus [`JobHandle::batch_wait_all`] for fleets.
//! Errors are typed [`JobError`]s end to end; nothing here returns a
//! stringly error.
//!
//! Throughput callers use [`SpmmClient::submit_many`] / [`SpmmClient::stream`]:
//! jobs are submitted back-to-back (blocking under backpressure), which
//! lands jobs sharing a `B` operand adjacently in the queue — exactly what
//! the server's micro-batch coalescer needs to build each `PreparedB` once
//! and reuse it across the batch.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError,
};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::admission::AdmissionGate;
use super::error::JobError;
use super::job::{JobOptions, JobOutput, JobResult, Priority, SpmmJob};
use super::metrics::{Metrics, MetricsSnapshot};
use super::server::{Envelope, JobEnvelope};
use crate::engine::Algorithm;
use crate::formats::operand::MatrixOperand;
use crate::formats::traits::FormatKind;

/// Cloneable, thread-safe handle for submitting SpMM jobs to a server.
#[derive(Clone)]
pub struct SpmmClient {
    tx: SyncSender<Envelope>,
    metrics: Arc<Metrics>,
    closed: Arc<AtomicBool>,
    next_id: Arc<AtomicU64>,
    admission: Arc<AdmissionGate>,
}

impl SpmmClient {
    pub(crate) fn new(
        tx: SyncSender<Envelope>,
        metrics: Arc<Metrics>,
        closed: Arc<AtomicBool>,
        next_id: Arc<AtomicU64>,
        admission: Arc<AdmissionGate>,
    ) -> SpmmClient {
        SpmmClient { tx, metrics, closed, next_id, admission }
    }

    /// Consult the admission gate; shed with a typed error when over
    /// budget. A disabled gate (no `max_queue_delay`) admits everything.
    fn gate(&self) -> Result<(), JobError> {
        match self.admission.admit() {
            Ok(()) => Ok(()),
            Err(retry_after) => {
                self.metrics.jobs_shed.fetch_add(1, Ordering::Relaxed);
                Err(JobError::Overloaded { retry_after })
            }
        }
    }

    /// Start building a job for `C = A × B`. Operands may arrive in **any**
    /// storage format (anything `Into<MatrixOperand>`: an `Arc<Csr>` as
    /// before — still zero-cost — or a `Coo`/`InCrs`/`Ellpack`/… handle);
    /// the server ingests, costs, and converts as needed, bit-identically
    /// to pre-converted submission. IDs are assigned from the server-wide
    /// counter unless overridden with [`JobBuilder::id`].
    pub fn job(
        &self,
        a: impl Into<MatrixOperand>,
        b: impl Into<MatrixOperand>,
    ) -> JobBuilder<'_> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        JobBuilder {
            client: self,
            job: SpmmJob::from_operands(id, a, b),
        }
    }

    /// A point-in-time copy of the server's service metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Submit a job; blocks when the bounded queue is full (backpressure).
    /// When the server has an admission budget configured
    /// (`AdmissionConfig::max_queue_delay`), an over-budget submission is
    /// shed up front with [`JobError::Overloaded`] instead of parking this
    /// thread behind a queue it predictably cannot clear in time.
    pub fn submit(&self, job: SpmmJob) -> Result<JobHandle, JobError> {
        if self.closed.load(Ordering::Acquire) {
            return Err(JobError::Shutdown);
        }
        self.gate()?;
        let id = job.id;
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Envelope::Job(JobEnvelope {
                job,
                reply: rtx,
                enqueued: Instant::now(),
            }))
            .map_err(|_| JobError::Shutdown)?;
        self.admission.on_enqueue();
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        Ok(JobHandle::new(id, rrx))
    }

    /// Bounded-wait submit: block under backpressure for at most
    /// `max_wait`, then shed with [`JobError::Overloaded`] (the retry hint
    /// is the gate's current service-slot estimate). The admission gate
    /// still applies up front, exactly as in [`SpmmClient::submit`].
    pub fn submit_within(&self, job: SpmmJob, max_wait: Duration) -> Result<JobHandle, JobError> {
        if self.closed.load(Ordering::Acquire) {
            return Err(JobError::Shutdown);
        }
        self.gate()?;
        let give_up = Instant::now() + max_wait;
        let id = job.id;
        let (rtx, rrx) = sync_channel(1);
        let mut envelope = JobEnvelope {
            job,
            reply: rtx,
            enqueued: Instant::now(),
        };
        loop {
            match self.tx.try_send(Envelope::Job(envelope)) {
                Ok(()) => {
                    self.admission.on_enqueue();
                    self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
                    return Ok(JobHandle::new(id, rrx));
                }
                Err(TrySendError::Full(Envelope::Job(je))) => {
                    let now = Instant::now();
                    if now >= give_up {
                        self.metrics.jobs_shed.fetch_add(1, Ordering::Relaxed);
                        return Err(JobError::Overloaded {
                            retry_after: self.admission.retry_hint(),
                        });
                    }
                    let remaining = give_up - now;
                    std::thread::sleep(remaining.min(Duration::from_millis(1)));
                    envelope = je;
                }
                Err(_) => return Err(JobError::Shutdown),
            }
        }
    }

    /// Non-blocking submit: [`JobError::QueueFull`] when the bounded queue
    /// is at capacity, [`JobError::Overloaded`] when the admission gate
    /// sheds first (`SpmmJob` is cheap to clone — two `Arc`s — so keep
    /// a copy if you intend to retry; or use
    /// [`SpmmClient::try_submit_reclaim`] to get the job back un-cloned).
    pub fn try_submit(&self, job: SpmmJob) -> Result<JobHandle, JobError> {
        self.try_submit_reclaim(job).map_err(|(_, e)| e)
    }

    /// Non-blocking submit that hands the job back on refusal, without
    /// cloning it: `Err((job, reason))` where `reason` is
    /// [`JobError::QueueFull`], [`JobError::Overloaded`], or
    /// [`JobError::Shutdown`].
    pub fn try_submit_reclaim(
        &self,
        job: SpmmJob,
    ) -> Result<JobHandle, (SpmmJob, JobError)> {
        if self.closed.load(Ordering::Acquire) {
            return Err((job, JobError::Shutdown));
        }
        if let Err(e) = self.gate() {
            return Err((job, e));
        }
        let id = job.id;
        let (rtx, rrx) = sync_channel(1);
        match self.tx.try_send(Envelope::Job(JobEnvelope {
            job,
            reply: rtx,
            enqueued: Instant::now(),
        })) {
            Ok(()) => {
                self.admission.on_enqueue();
                self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
                Ok(JobHandle::new(id, rrx))
            }
            Err(TrySendError::Full(Envelope::Job(je))) => Err((je.job, JobError::QueueFull)),
            Err(TrySendError::Disconnected(Envelope::Job(je))) => {
                Err((je.job, JobError::Shutdown))
            }
            Err(TrySendError::Full(Envelope::Stop))
            | Err(TrySendError::Disconnected(Envelope::Stop)) => {
                // lint: allow(P1) — try_send returns the exact value passed in, always a Job here
                unreachable!("try_send returned a different envelope than sent")
            }
        }
    }

    /// Submit a batch back-to-back (blocking under backpressure) and
    /// return one handle per job, in submission order. Jobs sharing a `B`
    /// operand land adjacently in the queue, so the server coalesces their
    /// `prepare` into one `PreparedB` build.
    ///
    /// Never loses accepted work: if a submission fails mid-batch (e.g.
    /// the server shuts down), that job's handle resolves to the submit
    /// error while the handles of already-accepted jobs stay live.
    pub fn submit_many(&self, jobs: impl IntoIterator<Item = SpmmJob>) -> Vec<JobHandle> {
        jobs.into_iter()
            .map(|j| {
                let id = j.id;
                self.submit(j).unwrap_or_else(|e| JobHandle::failed(id, e))
            })
            .collect()
    }

    /// Submit a batch and iterate its results in submission order — the
    /// simplest way to pump a stream of multiplies through the server.
    pub fn stream(&self, jobs: impl IntoIterator<Item = SpmmJob>) -> JobStream {
        JobStream {
            handles: self.submit_many(jobs).into_iter(),
        }
    }
}

/// Fluent construction of an [`SpmmJob`] — replaces hand-rolling
/// `SpmmJob`/`JobOptions` literals at call sites.
pub struct JobBuilder<'c> {
    client: &'c SpmmClient,
    job: SpmmJob,
}

impl JobBuilder<'_> {
    /// Override the auto-assigned job id.
    pub fn id(mut self, id: u64) -> Self {
        self.job.id = id;
        self
    }

    /// Cross-check the result against the CPU oracle (adds a full
    /// reference multiply — test/debug traffic only).
    pub fn verify(mut self, on: bool) -> Self {
        self.job.opts.verify = on;
        self
    }

    /// Keep the dense result (large!) or return only the report.
    pub fn keep_result(mut self, on: bool) -> Self {
        self.job.opts.keep_result = on;
        self
    }

    /// Pin this job to one registry key instead of the server's
    /// [`super::router::KernelSpec`].
    pub fn kernel(mut self, format: FormatKind, algorithm: Algorithm) -> Self {
        self.job.opts.kernel = Some((format, algorithm));
        self
    }

    /// Execute across `n` row-band shards (`engine::shard`): channel-
    /// connected shard workers, reduction-free merge, bit-identical to the
    /// unsharded run. 1 (the default) keeps the single-kernel path.
    pub fn shards(mut self, n: usize) -> Self {
        self.job.opts.shards = n.max(1);
        self
    }

    /// Tenant id: jobs from different tenants in the same priority class
    /// are drained round-robin, so one tenant's burst cannot monopolize a
    /// worker. 0 (the default) is the anonymous tenant.
    pub fn tenant(mut self, tenant: u32) -> Self {
        self.job.opts.tenant = tenant;
        self
    }

    /// Priority class for the fair-queuing drain. Higher classes are
    /// served first, bounded by the server's starvation bound.
    pub fn priority(mut self, priority: Priority) -> Self {
        self.job.opts.priority = priority;
        self
    }

    /// Absolute deadline: the job is dropped with
    /// [`JobError::DeadlineExceeded`] at the cheapest point after expiry
    /// (dequeue, pre-`prepare`, or pre-band-dispatch) instead of running.
    pub fn deadline(mut self, deadline: Instant) -> Self {
        self.job.opts.deadline = Some(deadline);
        self
    }

    /// Relative deadline: `now + budget`.
    pub fn deadline_in(mut self, budget: Duration) -> Self {
        self.job.opts.deadline = Some(Instant::now() + budget);
        self
    }

    /// Replace all options at once (escape hatch for stored configs).
    pub fn opts(mut self, opts: JobOptions) -> Self {
        self.job.opts = opts;
        self
    }

    /// The described job, without submitting it (for `submit_many`).
    pub fn build(self) -> SpmmJob {
        self.job
    }

    /// Submit; blocks when the queue is full (backpressure).
    pub fn submit(self) -> Result<JobHandle, JobError> {
        let JobBuilder { client, job } = self;
        client.submit(job)
    }

    /// Non-blocking submit ([`JobError::QueueFull`] at capacity).
    pub fn try_submit(self) -> Result<JobHandle, JobError> {
        let JobBuilder { client, job } = self;
        client.try_submit(job)
    }

    /// Bounded-wait submit: blocks under backpressure for at most
    /// `max_wait`, then sheds with [`JobError::Overloaded`].
    pub fn submit_within(self, max_wait: Duration) -> Result<JobHandle, JobError> {
        let JobBuilder { client, job } = self;
        client.submit_within(job, max_wait)
    }
}

/// A one-shot future for a submitted job. Exactly one completion call
/// observes the result; after `try_poll`/`wait_timeout` return `Some`,
/// the handle is spent.
pub struct JobHandle {
    id: u64,
    rx: Receiver<JobResult>,
}

impl JobHandle {
    fn new(id: u64, rx: Receiver<JobResult>) -> JobHandle {
        JobHandle { id, rx }
    }

    /// A handle that is already resolved to `err` — used by `submit_many`
    /// so a mid-batch submission failure never drops sibling handles.
    fn failed(id: u64, err: JobError) -> JobHandle {
        let (tx, rx) = sync_channel(1);
        let _ = tx.send(JobResult { id, result: Err(err) });
        JobHandle { id, rx }
    }

    /// The submitted job's id (results carry it too, for correlation).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the job completes. A reply channel lost to server
    /// shutdown reports [`JobError::Shutdown`].
    pub fn wait(self) -> Result<JobOutput, JobError> {
        match self.rx.recv() {
            Ok(r) => r.result,
            Err(_) => Err(JobError::Shutdown),
        }
    }

    /// Block for at most `timeout`. `None` = still running (the handle
    /// stays live); `Some(result)` spends the handle.
    pub fn wait_timeout(&mut self, timeout: Duration) -> Option<Result<JobOutput, JobError>> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r.result),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(Err(JobError::Shutdown)),
        }
    }

    /// Non-blocking completion check. `None` = still running; `Some`
    /// spends the handle.
    pub fn try_poll(&mut self) -> Option<Result<JobOutput, JobError>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r.result),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(Err(JobError::Shutdown)),
        }
    }

    /// Wait for a whole fleet, preserving input order.
    pub fn batch_wait_all(
        handles: impl IntoIterator<Item = JobHandle>,
    ) -> Vec<Result<JobOutput, JobError>> {
        handles.into_iter().map(JobHandle::wait).collect()
    }

    /// Legacy escape hatch: the raw reply channel (`Receiver<JobResult>`),
    /// as the pre-client `Server::submit` returned. Kept for one release.
    pub fn into_receiver(self) -> Receiver<JobResult> {
        self.rx
    }
}

/// Iterator over a submitted batch's results, in submission order.
pub struct JobStream {
    handles: std::vec::IntoIter<JobHandle>,
}

impl JobStream {
    /// Jobs still pending in the stream.
    pub fn remaining(&self) -> usize {
        self.handles.len()
    }
}

impl Iterator for JobStream {
    type Item = (u64, Result<JobOutput, JobError>);

    fn next(&mut self) -> Option<Self::Item> {
        let h = self.handles.next()?;
        Some((h.id(), h.wait()))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.handles.size_hint()
    }
}

impl ExactSizeIterator for JobStream {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::{Server, ServerConfig};
    use crate::datasets::synth::uniform;
    use crate::spmm::plan::Geometry;

    fn small_server(workers: usize, depth: usize) -> Server {
        Server::start(ServerConfig {
            workers,
            queue_depth: depth,
            geometry: Geometry { block: 8, pairs: 16, slots: 8 },
            ..Default::default()
        })
    }

    #[test]
    fn builder_submit_wait_roundtrip() {
        let s = small_server(2, 8);
        let client = s.client();
        let a = Arc::new(uniform(20, 28, 0.2, 1));
        let b = Arc::new(uniform(28, 16, 0.2, 2));
        let out = client
            .job(a, b)
            .verify(true)
            .keep_result(true)
            .submit()
            .unwrap()
            .wait()
            .unwrap();
        assert!(out.max_err.unwrap() < 1e-3);
        assert!(out.c.is_some());
        assert_eq!(client.metrics().jobs_completed, 1);
        drop(client);
        s.shutdown();
    }

    #[test]
    fn builder_ids_are_unique_and_overridable() {
        let s = small_server(1, 4);
        let client = s.client();
        let a = Arc::new(uniform(8, 8, 0.5, 3));
        let j0 = client.job(a.clone(), a.clone()).build();
        let j1 = client.job(a.clone(), a.clone()).build();
        assert_ne!(j0.id, j1.id);
        let j9 = client.job(a.clone(), a.clone()).id(99).build();
        assert_eq!(j9.id, 99);
        drop(client);
        s.shutdown();
    }

    #[test]
    fn try_poll_and_wait_timeout() {
        let s = small_server(1, 4);
        let client = s.client();
        let a = Arc::new(uniform(24, 24, 0.3, 4));
        let mut h = client.job(a.clone(), a).submit().unwrap();
        // poll until done (worker is running; must complete eventually)
        let result = loop {
            if let Some(r) = h.try_poll() {
                break r;
            }
            match h.wait_timeout(Duration::from_millis(50)) {
                Some(r) => break r,
                None => continue,
            }
        };
        assert!(result.is_ok());
        drop(client);
        s.shutdown();
    }

    #[test]
    fn stream_yields_in_submission_order() {
        let s = small_server(2, 8);
        let client = s.client();
        let a = Arc::new(uniform(16, 16, 0.3, 5));
        let jobs: Vec<SpmmJob> = (0..6)
            .map(|i| client.job(a.clone(), a.clone()).id(i).build())
            .collect();
        let stream = client.stream(jobs);
        assert_eq!(stream.len(), 6);
        let ids: Vec<u64> = stream.map(|(id, r)| {
            assert!(r.is_ok());
            id
        }).collect();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
        drop(client);
        s.shutdown();
    }

    #[test]
    fn try_submit_reports_queue_full() {
        let s = small_server(1, 1);
        let client = s.client();
        let a = Arc::new(uniform(64, 64, 0.4, 6));
        let mut handles = Vec::new();
        let mut saw_full = false;
        for i in 0..30 {
            let job = client.job(a.clone(), a.clone()).id(i).build();
            match client.try_submit(job) {
                Ok(h) => handles.push(h),
                Err(e) => {
                    assert_eq!(e, JobError::QueueFull);
                    assert!(e.is_transient());
                    saw_full = true;
                }
            }
        }
        assert!(saw_full, "queue never filled");
        for r in JobHandle::batch_wait_all(handles) {
            assert!(r.is_ok());
        }
        drop(client);
        s.shutdown();
    }

    #[test]
    fn try_submit_reclaim_hands_the_job_back_uncloned() {
        let s = small_server(1, 1);
        let client = s.client();
        let a = Arc::new(uniform(64, 64, 0.4, 7));
        let mut handles = Vec::new();
        let mut reclaimed = None;
        for i in 0..30 {
            let job = client.job(a.clone(), a.clone()).id(i).build();
            match client.try_submit_reclaim(job) {
                Ok(h) => handles.push(h),
                Err((job, e)) => {
                    assert_eq!(e, JobError::QueueFull);
                    assert_eq!(job.id, i, "must get the same job back");
                    reclaimed = Some(job);
                }
            }
        }
        let job = reclaimed.expect("queue never filled");
        for r in JobHandle::batch_wait_all(handles) {
            assert!(r.is_ok());
        }
        // the reclaimed job is fully usable: resubmit it blocking
        assert!(client.submit(job).unwrap().wait().is_ok());
        drop(client);
        s.shutdown();
    }

    #[test]
    fn submit_within_sheds_with_a_typed_overloaded_error() {
        let s = small_server(1, 1);
        let client = s.client();
        let a = Arc::new(uniform(64, 64, 0.4, 8));
        let mut handles = Vec::new();
        let mut shed = 0;
        for i in 0..30 {
            let job = client.job(a.clone(), a.clone()).id(i).build();
            match client.submit_within(job, Duration::from_micros(200)) {
                Ok(h) => handles.push(h),
                Err(e) => {
                    assert!(matches!(e, JobError::Overloaded { .. }), "{e}");
                    assert!(e.is_transient());
                    assert!(e.retry_after().is_some());
                    shed += 1;
                }
            }
        }
        assert!(shed > 0, "bounded wait never gave up");
        for r in JobHandle::batch_wait_all(handles) {
            assert!(r.is_ok());
        }
        assert_eq!(client.metrics().jobs_shed, shed);
        drop(client);
        s.shutdown();
    }

    #[test]
    fn builder_carries_traffic_options() {
        use crate::coordinator::job::Priority;
        let s = small_server(1, 2);
        let client = s.client();
        let a = Arc::new(uniform(8, 8, 0.5, 9));
        let soon = Instant::now() + Duration::from_secs(60);
        let job = client
            .job(a.clone(), a)
            .tenant(5)
            .priority(Priority::Low)
            .deadline(soon)
            .build();
        assert_eq!(job.opts.tenant, 5);
        assert_eq!(job.opts.priority, Priority::Low);
        assert_eq!(job.opts.deadline, Some(soon));
        drop(client);
        s.shutdown();
    }
}
