//! Dispatch scheduling: split a job's plan into worker batches and verify
//! coverage — the block-granular analogue of the paper's mesh tiling
//! (every output tile pass covered exactly once, round order preserved).

use crate::spmm::plan::Plan;

/// A contiguous range of a plan's dispatches assigned to one worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Batch {
    pub start: usize,
    pub end: usize, // exclusive
}

impl Batch {
    pub fn len(&self) -> usize {
        self.end - self.start
    }
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Split `n_dispatches` into at most `workers` contiguous batches of nearly
/// equal size (contiguity keeps each output tile's split pair-groups on one
/// worker whenever they fit in one dispatch run — scatter-add makes splits
/// correct regardless, contiguity just minimizes partial-sum traffic).
pub fn split_batches(n_dispatches: usize, workers: usize) -> Vec<Batch> {
    if n_dispatches == 0 || workers == 0 {
        return Vec::new();
    }
    let w = workers.min(n_dispatches);
    let base = n_dispatches / w;
    let extra = n_dispatches % w;
    let mut out = Vec::with_capacity(w);
    let mut start = 0;
    for i in 0..w {
        let len = base + usize::from(i < extra);
        out.push(Batch {
            start,
            end: start + len,
        });
        start += len;
    }
    out
}

/// Schedule summary for a plan (used by metrics and the serve demo).
#[derive(Clone, Copy, Debug, Default)]
pub struct ScheduleInfo {
    pub dispatches: usize,
    pub batches: usize,
    pub real_pairs: usize,
    pub padding_fraction: f64,
}

pub fn describe(plan: &Plan, workers: usize) -> ScheduleInfo {
    let batches = split_batches(plan.dispatches.len(), workers);
    let padded = plan.dispatches.len() * plan.geom.pairs;
    ScheduleInfo {
        dispatches: plan.dispatches.len(),
        batches: batches.len(),
        real_pairs: plan.total_pairs,
        padding_fraction: if padded == 0 {
            0.0
        } else {
            1.0 - plan.total_pairs as f64 / padded as f64
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::spmm::plan::{plan, Geometry};

    #[test]
    fn batches_cover_exactly_once() {
        for (n, w) in [(10usize, 3usize), (1, 4), (7, 7), (100, 8), (5, 1)] {
            let b = split_batches(n, w);
            assert_eq!(b.len(), w.min(n));
            assert_eq!(b[0].start, 0);
            assert_eq!(b.last().unwrap().end, n);
            for pair in b.windows(2) {
                assert_eq!(pair[0].end, pair[1].start, "gap/overlap at {pair:?}");
            }
            // balanced within 1
            let lens: Vec<usize> = b.iter().map(Batch::len).collect();
            assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    fn empty_cases() {
        assert!(split_batches(0, 4).is_empty());
        assert!(split_batches(4, 0).is_empty());
    }

    #[test]
    fn describe_reports_padding() {
        let a = uniform(40, 40, 0.15, 1);
        let p = plan(&a, &a.transpose(), Geometry { block: 8, pairs: 16, slots: 8 });
        let info = describe(&p, 4);
        assert_eq!(info.dispatches, p.dispatches.len());
        assert!(info.padding_fraction >= 0.0 && info.padding_fraction < 1.0);
        assert_eq!(info.real_pairs, p.total_pairs);
    }
}
