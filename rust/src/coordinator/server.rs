//! Batching SpMM server: a worker pool over bounded channels, dispatching
//! through the kernel registry.
//!
//! The L3 serving shape (DESIGN.md §1): callers `submit` jobs and get a
//! per-job response channel; a bounded queue applies backpressure (submit
//! blocks when `queue_depth` jobs are in flight); each worker owns its own
//! kernel registry (PJRT clients are not shared across threads) and
//! processes whole jobs — parallelism *inside* a job comes from the tiled
//! kernel's worker threads.
//!
//! Shutdown drains: [`Server::shutdown`] closes the submit side and joins
//! the workers, which keep serving until the queue is empty — no in-flight
//! job is ever dropped.
//!
//! Built on std threads + mpsc because the offline registry has no tokio
//! (DESIGN.md §2); the batching/backpressure semantics are identical.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::job::{JobOutput, JobResult, SpmmJob};
use super::metrics::Metrics;
use super::router::KernelSpec;
use crate::engine::{AccelKernel, Registry, SpmmKernel};
use crate::spmm::plan::Geometry;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub workers: usize,
    /// Max queued jobs before `submit` blocks (backpressure).
    pub queue_depth: usize,
    /// How workers pick the kernel for each job (jobs can still override
    /// via `JobOptions::kernel`).
    pub kernel: KernelSpec,
    /// Try to load PJRT artifacts for the `Block` kernel; degrade to its
    /// CPU twin (and count `pjrt_fallbacks`) when unavailable.
    pub prefer_pjrt: bool,
    /// Geometry for the CPU block kernel; PJRT reads its own manifest.
    pub geometry: Geometry,
    /// Threads inside the tiled kernel (per job, per worker).
    pub tile_workers: usize,
    pub artifacts_dir: std::path::PathBuf,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 2,
            queue_depth: 16,
            kernel: KernelSpec::default(),
            prefer_pjrt: false,
            geometry: Geometry::default(),
            tile_workers: 1,
            artifacts_dir: crate::runtime::Manifest::default_dir(),
        }
    }
}

struct Envelope {
    job: SpmmJob,
    reply: SyncSender<JobResult>,
    enqueued: Instant,
}

pub struct Server {
    tx: SyncSender<Envelope>,
    handles: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Server {
    pub fn start(cfg: ServerConfig) -> Server {
        assert!(cfg.workers > 0, "need at least one worker");
        let (tx, rx) = sync_channel::<Envelope>(cfg.queue_depth);
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for wid in 0..cfg.workers {
            let rx = Arc::clone(&rx);
            let metrics = Arc::clone(&metrics);
            let cfg = cfg.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("spmm-worker-{wid}"))
                    .spawn(move || worker_loop(wid, cfg, rx, metrics))
                    .expect("spawn worker"),
            );
        }
        Server {
            tx,
            handles,
            metrics,
        }
    }

    /// Submit a job; blocks when the queue is full (backpressure). Returns
    /// the response channel.
    pub fn submit(&self, job: SpmmJob) -> Receiver<JobResult> {
        let (rtx, rrx) = sync_channel(1);
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Envelope {
                job,
                reply: rtx,
                enqueued: Instant::now(),
            })
            .expect("server shut down");
        rrx
    }

    /// Non-blocking submit: `Err(job)` when the queue is full.
    pub fn try_submit(&self, job: SpmmJob) -> Result<Receiver<JobResult>, SpmmJob> {
        let (rtx, rrx) = sync_channel(1);
        match self.tx.try_send(Envelope {
            job,
            reply: rtx,
            enqueued: Instant::now(),
        }) {
            Ok(()) => {
                self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
                Ok(rrx)
            }
            Err(TrySendError::Full(env)) | Err(TrySendError::Disconnected(env)) => Err(env.job),
        }
    }

    /// Graceful shutdown: closes the submit side, then joins workers. The
    /// workers keep draining the bounded queue until it is empty, so every
    /// accepted job gets a response before shutdown returns.
    pub fn shutdown(self) {
        let Server { tx, handles, metrics: _ } = self;
        drop(tx); // disconnect: workers exit once the queue is drained
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Build this worker's registry: the default CPU kernel set plus — when
/// asked and possible — the PJRT-backed block kernel. Each worker owns its
/// registry because PJRT clients must stay thread-local.
fn worker_registry(cfg: &ServerConfig, metrics: &Metrics) -> Registry {
    let mut reg = Registry::with_default_kernels(cfg.geometry, cfg.tile_workers);
    if cfg.prefer_pjrt {
        match AccelKernel::pjrt(&cfg.artifacts_dir) {
            Ok(k) => {
                reg.register(Arc::new(k));
            }
            Err(e) => {
                eprintln!("worker PJRT init failed ({e}); falling back to CPU block kernel");
                metrics.pjrt_fallbacks.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    reg
}

fn worker_loop(
    _wid: usize,
    cfg: ServerConfig,
    rx: Arc<std::sync::Mutex<Receiver<Envelope>>>,
    metrics: Arc<Metrics>,
) {
    let registry = worker_registry(&cfg, &metrics);

    loop {
        let env = {
            let guard = rx.lock().expect("queue lock");
            guard.recv()
        };
        match env {
            // disconnected + drained: shutdown
            Err(_) => return,
            Ok(Envelope { job, reply, enqueued }) => {
                metrics.observe_queue_wait(enqueued.elapsed());
                let start = Instant::now();
                let result = run_job(&registry, cfg.kernel, &job);
                let wall = start.elapsed();
                metrics.busy_ns.fetch_add(wall.as_nanos() as u64, Ordering::Relaxed);
                metrics.observe_latency(wall);
                match &result {
                    Ok(out) => {
                        metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                        metrics
                            .dispatches
                            .fetch_add(out.report.dispatches, Ordering::Relaxed);
                        metrics
                            .real_pairs
                            .fetch_add(out.report.real_pairs, Ordering::Relaxed);
                    }
                    Err(_) => {
                        metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
                let _ = reply.send(JobResult {
                    id: job.id,
                    result,
                });
            }
        }
    }
}

/// Resolve the kernel for `job` (per-job override > server spec) and run it.
fn run_job(registry: &Registry, spec: KernelSpec, job: &SpmmJob) -> Result<JobOutput, String> {
    use crate::formats::traits::SparseMatrix;
    if job.a.cols() != job.b.rows() {
        return Err(format!(
            "dimension mismatch: A is {:?}, B is {:?}",
            job.a.shape(),
            job.b.shape()
        ));
    }
    let kernel: Arc<dyn SpmmKernel> = match job.opts.kernel {
        Some((f, alg)) => registry
            .resolve(f, alg)
            .ok_or_else(|| format!("no kernel registered for {}/{}", f.name(), alg.name()))?,
        None => match spec {
            KernelSpec::Fixed(f, alg) => registry
                .resolve(f, alg)
                .ok_or_else(|| format!("no kernel registered for {}/{}", f.name(), alg.name()))?,
            KernelSpec::Auto => registry
                .select(&job.a, &job.b)
                .ok_or_else(|| "empty kernel registry".to_string())?,
        },
    };
    let start = Instant::now();
    // prepare_shared: CSR-consuming kernels share the job's Arc (no per-job
    // O(nnz) copy of B); conversion kernels build their representation
    let prepared = kernel.prepare_shared(&job.b)?;
    let out = kernel.execute(&job.a, &prepared)?;
    let max_err = if job.opts.verify {
        let oracle = crate::spmm::dense::multiply(&job.a, &job.b);
        Some(out.c.max_abs_diff(&oracle))
    } else {
        None
    };
    Ok(JobOutput {
        c: job.opts.keep_result.then_some(out.c),
        report: out.stats,
        backend: kernel.name(),
        wall: start.elapsed(),
        max_err,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::JobOptions;
    use crate::datasets::synth::uniform;
    use crate::engine::Algorithm;
    use crate::formats::traits::FormatKind;

    fn cpu_server(workers: usize, depth: usize) -> Server {
        Server::start(ServerConfig {
            workers,
            queue_depth: depth,
            geometry: Geometry { block: 8, pairs: 16, slots: 8 },
            ..Default::default()
        })
    }

    #[test]
    fn serves_jobs_and_verifies() {
        let s = cpu_server(2, 8);
        let a = Arc::new(uniform(24, 32, 0.2, 1));
        let b = Arc::new(uniform(32, 20, 0.2, 2));
        let rx = s.submit(SpmmJob::new(1, a, b).with_opts(JobOptions {
            verify: true,
            keep_result: true,
            kernel: None,
        }));
        let res = rx.recv().unwrap();
        let out = res.result.unwrap();
        assert!(out.max_err.unwrap() < 1e-3);
        assert!(out.c.is_some());
        assert_eq!(out.backend, "cpu");
        let snap = s.metrics.snapshot();
        assert_eq!(snap.jobs_completed, 1);
        assert!(snap.queue_p50_us > 0);
        s.shutdown();
    }

    #[test]
    fn many_concurrent_jobs_all_complete() {
        let s = cpu_server(4, 4);
        let a = Arc::new(uniform(16, 16, 0.3, 3));
        let rxs: Vec<_> = (0..20)
            .map(|i| s.submit(SpmmJob::new(i, a.clone(), a.clone())))
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        assert_eq!(s.metrics.snapshot().jobs_completed, 20);
        s.shutdown();
    }

    #[test]
    fn dimension_mismatch_fails_cleanly() {
        let s = cpu_server(1, 2);
        let a = Arc::new(uniform(4, 5, 0.5, 1));
        let b = Arc::new(uniform(7, 4, 0.5, 2));
        let res = s.submit(SpmmJob::new(9, a, b)).recv().unwrap();
        assert!(res.result.unwrap_err().contains("dimension mismatch"));
        assert_eq!(s.metrics.snapshot().jobs_failed, 1);
        s.shutdown();
    }

    #[test]
    fn try_submit_backpressure() {
        // 1 worker, tiny queue, slow-ish jobs: try_submit must eventually Err
        let s = cpu_server(1, 1);
        let a = Arc::new(uniform(64, 64, 0.4, 5));
        let mut accepted = Vec::new();
        let mut rejected = 0;
        for i in 0..30 {
            match s.try_submit(SpmmJob::new(i, a.clone(), a.clone())) {
                Ok(rx) => accepted.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "queue never filled");
        for rx in accepted {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        s.shutdown();
    }

    #[test]
    fn shutdown_drains_every_accepted_job() {
        // single worker + deep queue: most jobs are still queued when
        // shutdown is called; all must be answered anyway
        let s = cpu_server(1, 16);
        let a = Arc::new(uniform(48, 48, 0.3, 6));
        let rxs: Vec<_> = (0..10)
            .map(|i| s.submit(SpmmJob::new(i, a.clone(), a.clone())))
            .collect();
        s.shutdown();
        for rx in rxs {
            // every response was delivered before shutdown returned
            assert!(rx.try_recv().unwrap().result.is_ok());
        }
    }

    #[test]
    fn per_job_kernel_override() {
        let s = cpu_server(1, 4);
        let a = Arc::new(uniform(20, 30, 0.2, 7));
        let b = Arc::new(uniform(30, 24, 0.2, 8));
        for (f, alg, name) in [
            (FormatKind::Csr, Algorithm::Gustavson, "gustavson"),
            (FormatKind::InCrs, Algorithm::Inner, "inner-incrs"),
            (FormatKind::Csr, Algorithm::Tiled, "tiled"),
        ] {
            let rx = s.submit(
                SpmmJob::new(1, a.clone(), b.clone())
                    .with_opts(JobOptions { verify: true, ..Default::default() })
                    .with_kernel(f, alg),
            );
            let out = rx.recv().unwrap().result.unwrap();
            assert_eq!(out.backend, name);
            assert!(out.max_err.unwrap() < 1e-3, "{name}");
        }
        s.shutdown();
    }

    #[test]
    fn unregistered_kernel_is_a_job_error_not_a_crash() {
        let s = cpu_server(1, 2);
        let a = Arc::new(uniform(8, 8, 0.5, 9));
        let rx = s.submit(
            SpmmJob::new(1, a.clone(), a.clone()).with_kernel(FormatKind::Jad, Algorithm::Inner),
        );
        let err = rx.recv().unwrap().result.unwrap_err();
        assert!(err.contains("no kernel registered"), "{err}");
        // the worker survives and serves the next job
        let ok = s.submit(SpmmJob::new(2, a.clone(), a)).recv().unwrap();
        assert!(ok.result.is_ok());
        s.shutdown();
    }

    #[test]
    fn auto_selection_serves_jobs() {
        let s = Server::start(ServerConfig {
            workers: 2,
            queue_depth: 8,
            kernel: KernelSpec::Auto,
            geometry: Geometry { block: 8, pairs: 16, slots: 8 },
            ..Default::default()
        });
        let a = Arc::new(uniform(32, 48, 0.1, 10));
        let b = Arc::new(uniform(48, 40, 0.1, 11));
        let rx = s.submit(SpmmJob::new(1, a, b).with_opts(JobOptions {
            verify: true,
            ..Default::default()
        }));
        let out = rx.recv().unwrap().result.unwrap();
        assert!(out.max_err.unwrap() < 1e-3);
        assert_ne!(out.backend, "dense"); // auto never picks the oracle
        s.shutdown();
    }
}
