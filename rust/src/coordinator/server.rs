//! Batching SpMM server: a worker pool over bounded channels, dispatching
//! through the kernel registry with B-sharing micro-batch coalescing.
//!
//! The L3 serving shape (DESIGN.md §1): callers talk to the server through
//! an [`SpmmClient`] handle (`server.client()`); a bounded queue applies
//! backpressure (blocking submits stall when `queue_depth` jobs are in
//! flight); each worker owns its own kernel registry (PJRT clients are not
//! shared across threads) and drains the queue in micro-batches bounded to
//! the current shared-`B` run (so unrelated bursts still fan out across
//! workers). Jobs ingest typed `MatrixOperand`s: workers render each
//! operand to canonical CSR on arrival (O(1) `Arc` share for CSR,
//! identity-memoized conversion otherwise — metered as
//! `operand_conversions`), and auto-selection charges that conversion from
//! the operand's *native* format (`Registry::select_native`). Within a
//! batch, jobs resolving to the same kernel share one
//! [`SpmmKernel::prepare`]: real-prepare kernels (InCRS counter build,
//! densification, tiled/accel blockization) are keyed by a content
//! fingerprint of `B` — bit-identical operands share even across `Arc`s
//! and, via a bounded per-worker LRU, across batches — while
//! trivial-prepare kernels group by `Arc` identity and skip hashing
//! entirely (their prepare is an O(1) `Arc` share). This is the paper's
//! amortization — one representation build, many multiplies — applied at
//! the serving layer.
//!
//! Shutdown drains: [`Server::shutdown`] marks the server closed, sends one
//! stop pill per worker, and joins them. Pills queue *behind* every
//! accepted job, so no in-flight job is ever dropped; jobs racing past the
//! closed flag are answered with [`JobError::Shutdown`].
//!
//! Built on std threads + mpsc because the offline registry has no tokio
//! (DESIGN.md §2); the batching/backpressure semantics are identical.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::admission::{AdmissionConfig, AdmissionGate, FairQueue};
use super::client::SpmmClient;
use super::error::JobError;
use super::job::{JobOutput, JobResult, SpmmJob};
use super::metrics::{CalibrationEntry, Metrics};
use super::router::KernelSpec;
use crate::engine::learn::{CostModel, FittedModel, Sample, DEFAULT_MARGIN, DEFAULT_MIN_SAMPLES};
use crate::engine::{
    shard, AccelKernel, CsrMemo, EngineError, FingerprintMemo, InProcess, PreparedCache,
    PreparedKey, Registry, RetryPolicy, SelectionScores, ShardTransport, SocketTransport,
    SpmmKernel,
};
use crate::formats::csr::Csr;
use crate::formats::operand::MatrixOperand;
use crate::spmm::plan::Geometry;
use crate::util::lock_unpoisoned;

/// Micro-batch coalescing policy (per worker).
#[derive(Clone, Copy, Debug)]
pub struct CoalesceConfig {
    /// Drain queued jobs into micro-batches and share `PreparedB` among
    /// jobs with bit-identical `B`. Off = the PR 1 one-job-at-a-time path.
    pub enabled: bool,
    /// Max jobs drained into one micro-batch.
    pub max_batch: usize,
    /// `PreparedB` LRU entries kept across batches, per worker
    /// (0 disables the cross-batch cache; in-batch sharing still applies).
    pub cache_capacity: usize,
}

impl Default for CoalesceConfig {
    fn default() -> Self {
        CoalesceConfig {
            enabled: true,
            max_batch: 16,
            cache_capacity: 8,
        }
    }
}

/// Extends each worker's kernel registry after the defaults (and PJRT)
/// register — custom backends, sharded wrappers, fault injection in tests.
pub type RegistryHook = Arc<dyn Fn(&mut Registry) + Send + Sync>;

/// Learned-selection loop configuration (see `engine::learn`): how often
/// the cost model is refitted from the kernel-observation log, how sticky
/// selection is, and where the fitted model persists.
#[derive(Clone, Debug)]
pub struct LearnConfig {
    /// Refit the shared cost model every N completed jobs (server-wide;
    /// exactly one worker performs each refit). 0 disables refitting —
    /// selection stays static (or warm-loaded, if `model_path` has one).
    pub refit_every: u64,
    /// Hysteresis margin: the fractional predicted win a challenger needs
    /// before it displaces the incumbent kernel for a workload class.
    pub margin: f64,
    /// Persist the fitted model here after every refit (and load it at
    /// startup, so a restarted server doesn't relearn from zero). Plain
    /// versioned text; load failures log and start uncalibrated.
    pub model_path: Option<std::path::PathBuf>,
    /// Minimum observations per kernel before its fit is trusted.
    pub min_samples: usize,
}

impl Default for LearnConfig {
    fn default() -> Self {
        LearnConfig {
            refit_every: 0,
            margin: DEFAULT_MARGIN,
            model_path: None,
            min_samples: DEFAULT_MIN_SAMPLES,
        }
    }
}

#[derive(Clone)]
pub struct ServerConfig {
    pub workers: usize,
    /// Max queued jobs before blocking submits stall (backpressure).
    pub queue_depth: usize,
    /// How workers pick the kernel for each job (jobs can still override
    /// via `JobOptions::kernel`).
    pub kernel: KernelSpec,
    /// Try to load PJRT artifacts for the `Block` kernel; degrade to its
    /// CPU twin (and count `pjrt_fallbacks`) when unavailable.
    pub prefer_pjrt: bool,
    /// Geometry for the CPU block kernel; PJRT reads its own manifest.
    /// Also the *requested* shard-band alignment for sharded jobs — the
    /// shard executor rounds it up to each kernel's own `band_alignment`
    /// (e.g. a differing PJRT manifest block), so blocked kernels stay
    /// bit-identical under sharding regardless.
    pub geometry: Geometry,
    /// Threads inside the tiled kernel (per job, per worker).
    pub tile_workers: usize,
    pub artifacts_dir: std::path::PathBuf,
    /// B-sharing micro-batch coalescing (see [`CoalesceConfig`]).
    pub coalesce: CoalesceConfig,
    /// Optional per-worker registry extension hook (see [`RegistryHook`]).
    pub registry_hook: Option<RegistryHook>,
    /// Learned-selection loop (see [`LearnConfig`]; default: disabled).
    pub learn: LearnConfig,
    /// Remote shard workers (`host:port`, `engine::remote::serve` peers).
    /// Empty = sharded jobs run on in-process channel workers. Non-empty =
    /// the server dials every peer at startup and routes row bands over the
    /// socket transport ([`SocketTransport`]); if the dial fails it logs
    /// and degrades to in-process rather than refusing to start.
    pub remote_peers: Vec<String>,
    /// Timeout/retry/hedging policy for the socket transport (ignored when
    /// `remote_peers` is empty).
    pub retry: RetryPolicy,
    /// Admission control + fair-queuing policy (see
    /// [`super::admission::AdmissionConfig`]). Default: gate disabled,
    /// starvation bound 4.
    pub admission: AdmissionConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 2,
            queue_depth: 16,
            kernel: KernelSpec::default(),
            prefer_pjrt: false,
            geometry: Geometry::default(),
            tile_workers: 1,
            artifacts_dir: crate::runtime::Manifest::default_dir(),
            coalesce: CoalesceConfig::default(),
            registry_hook: None,
            learn: LearnConfig::default(),
            remote_peers: Vec::new(),
            retry: RetryPolicy::default(),
            admission: AdmissionConfig::default(),
        }
    }
}

impl std::fmt::Debug for ServerConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerConfig")
            .field("workers", &self.workers)
            .field("queue_depth", &self.queue_depth)
            .field("kernel", &self.kernel)
            .field("prefer_pjrt", &self.prefer_pjrt)
            .field("geometry", &self.geometry)
            .field("tile_workers", &self.tile_workers)
            .field("artifacts_dir", &self.artifacts_dir)
            .field("coalesce", &self.coalesce)
            .field("registry_hook", &self.registry_hook.as_ref().map(|_| "…"))
            .field("learn", &self.learn)
            .field("remote_peers", &self.remote_peers)
            .field("retry", &self.retry)
            .field("admission", &self.admission)
            .finish()
    }
}

/// What travels down the queue: a job with its reply channel, or a stop
/// pill (one per worker, sent by [`Server::shutdown`] behind all accepted
/// jobs).
pub(crate) enum Envelope {
    Job(JobEnvelope),
    Stop,
}

pub(crate) struct JobEnvelope {
    pub(crate) job: SpmmJob,
    pub(crate) reply: SyncSender<JobResult>,
    pub(crate) enqueued: Instant,
}

pub struct Server {
    tx: SyncSender<Envelope>,
    rx: Arc<Mutex<Receiver<Envelope>>>,
    handles: Vec<JoinHandle<()>>,
    closed: Arc<AtomicBool>,
    next_id: Arc<AtomicU64>,
    workers: usize,
    learn: LearnConfig,
    cost_model: CostModel,
    admission: Arc<AdmissionGate>,
    pub metrics: Arc<Metrics>,
}

impl Server {
    pub fn start(cfg: ServerConfig) -> Server {
        assert!(cfg.workers > 0, "need at least one worker");
        let (tx, rx) = sync_channel::<Envelope>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        // one cost model shared by every worker's registry and the refit
        // loop; warm-load the persisted fit so a restart serves calibrated
        // from the first job (load failures start uncalibrated = static)
        let cost_model = CostModel::new(cfg.learn.margin);
        if let Some(path) = &cfg.learn.model_path {
            match FittedModel::load(path) {
                Ok(fitted) => {
                    if !fitted.is_empty() {
                        metrics.set_calibration(calibration_entries(&fitted));
                        cost_model.publish(fitted);
                    }
                }
                Err(e) => {
                    if path.exists() {
                        eprintln!(
                            "cost-model load failed ({}): {e}; starting uncalibrated",
                            path.display()
                        );
                    }
                }
            }
        }
        // one shard transport shared by every worker: remote jobs
        // serialize on its link state, so the whole pool shares one set of
        // sockets (and one staged-B view) instead of dialing per worker
        let transport: Arc<dyn ShardTransport> = if cfg.remote_peers.is_empty() {
            Arc::new(InProcess)
        } else {
            match SocketTransport::connect_with(&cfg.remote_peers, cfg.retry) {
                Ok(t) => Arc::new(t),
                Err(e) => {
                    eprintln!(
                        "remote shard transport unavailable ({e}); \
                         degrading to in-process shard workers"
                    );
                    Arc::new(InProcess)
                }
            }
        };
        // one admission gate shared by every client handle (enqueue side)
        // and every worker (dequeue + service-rate side)
        let admission = Arc::new(AdmissionGate::new(&cfg.admission, cfg.workers));
        let mut handles = Vec::new();
        for wid in 0..cfg.workers {
            let rx = Arc::clone(&rx);
            let metrics = Arc::clone(&metrics);
            let cfg = cfg.clone();
            let model = cost_model.clone();
            let transport = Arc::clone(&transport);
            let admission = Arc::clone(&admission);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("spmm-worker-{wid}"))
                    .spawn(move || worker_loop(wid, cfg, rx, metrics, model, transport, admission))
                    // lint: allow(P1) — no worker thread at startup leaves no server to return
                    .expect("spawn worker"),
            );
        }
        Server {
            tx,
            rx,
            handles,
            closed: Arc::new(AtomicBool::new(false)),
            next_id: Arc::new(AtomicU64::new(0)),
            workers: cfg.workers,
            learn: cfg.learn,
            cost_model,
            admission,
            metrics,
        }
    }

    /// The live learned-selection handle (shared with every worker).
    pub fn cost_model(&self) -> &CostModel {
        &self.cost_model
    }

    /// A cheap, cloneable, `Send` handle for submitting work — the public
    /// serving API ([`SpmmClient`], `JobBuilder`, `JobHandle`). Any number
    /// of client threads may hold one.
    pub fn client(&self) -> SpmmClient {
        SpmmClient::new(
            self.tx.clone(),
            Arc::clone(&self.metrics),
            Arc::clone(&self.closed),
            Arc::clone(&self.next_id),
            Arc::clone(&self.admission),
        )
    }

    /// Legacy blocking submit — a thin shim over [`Server::client`], kept
    /// for one release. Prefer `server.client().submit(job)?.wait()`.
    /// Panics if the server already shut down (the client returns
    /// [`JobError::Shutdown`] instead).
    pub fn submit(&self, job: SpmmJob) -> Receiver<JobResult> {
        self.client()
            .submit(job)
            .map(|h| h.into_receiver())
            // lint: allow(P1) — documented legacy contract: panics after shutdown; SpmmClient::submit is the typed path
            .expect("server shut down")
    }

    /// Legacy non-blocking submit — a thin shim over [`Server::client`]:
    /// `Err(job)` hands the job back when the queue is full. Prefer
    /// `client.try_submit(job)`, which reports [`JobError::QueueFull`].
    pub fn try_submit(&self, job: SpmmJob) -> Result<Receiver<JobResult>, SpmmJob> {
        // try_submit_reclaim moves the job and hands it back un-cloned on
        // rejection, so even multi-MB operands never copy on this path
        match self.client().try_submit_reclaim(job) {
            Ok(h) => Ok(h.into_receiver()),
            Err((job, _)) => Err(job),
        }
    }

    /// Graceful shutdown: marks the server closed, queues one stop pill
    /// per worker *behind* every accepted job, joins the workers, then
    /// answers any straggler jobs (races against the closed flag) with
    /// [`JobError::Shutdown`]. Every accepted job gets exactly one reply
    /// (result, drained error, or reply-channel disconnect), and jobs are
    /// counted completed/failed best-effort across the final race window.
    pub fn shutdown(self) {
        let Server {
            tx,
            rx,
            handles,
            closed,
            next_id: _,
            workers,
            learn,
            cost_model,
            admission,
            metrics,
        } = self;
        closed.store(true, Ordering::Release);
        for _ in 0..workers {
            // try_send + liveness check instead of a blocking send: if
            // every worker has died (e.g. a kernel panicked) while the
            // queue is full, a blocking send would never complete
            loop {
                match try_send_stop(&tx) {
                    PillSend::Sent | PillSend::Disconnected => break,
                    PillSend::Full => {
                        if handles.iter().all(|h| h.is_finished()) {
                            break; // nobody left to drain or consume pills
                        }
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                }
            }
        }
        drop(tx);
        for h in handles {
            let _ = h.join();
        }
        // stragglers that raced past the closed flag: answer + count,
        // don't strand (keeps submitted == completed + failed). Two drain
        // passes with a settle window catch a blocking send completing
        // just as the first pass reads Empty; a send landing after the
        // final pass still resolves (reply channel disconnects when `rx`
        // drops below -> the waiting JobHandle sees Shutdown) but is not
        // counted in jobs_failed — the invariant is best-effort across
        // that last race window.
        // poisoning (a worker panicked holding the queue lock) must not
        // skip the drain: the Receiver stays valid, so recover the guard
        let guard = lock_unpoisoned(&rx);
        for pass in 0..2 {
            while let Ok(env) = guard.try_recv() {
                if let Envelope::Job(je) = env {
                    admission.on_start(1); // keep the backlog gauge honest
                    metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    let _ = je.reply.send(JobResult {
                        id: je.job.id,
                        result: Err(JobError::Shutdown),
                    });
                }
            }
            if pass == 0 {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        // final fit + persist: a short-lived server (fewer completed jobs
        // than the refit cadence) still leaves its observations behind for
        // the next start's warm-load
        if learn.model_path.is_some() {
            refit_model(&cost_model, &metrics, &learn);
        }
    }
}

enum PillSend {
    Sent,
    Full,
    Disconnected,
}

fn try_send_stop(tx: &SyncSender<Envelope>) -> PillSend {
    match tx.try_send(Envelope::Stop) {
        Ok(()) => PillSend::Sent,
        Err(TrySendError::Full(_)) => PillSend::Full,
        Err(TrySendError::Disconnected(_)) => PillSend::Disconnected,
    }
}

/// Build this worker's registry: the default CPU kernel set plus — when
/// asked and possible — the PJRT-backed block kernel. Each worker owns its
/// registry because PJRT clients must stay thread-local.
fn worker_registry(cfg: &ServerConfig, metrics: &Metrics, model: &CostModel) -> Registry {
    let mut reg = Registry::with_default_kernels(cfg.geometry, cfg.tile_workers);
    if cfg.prefer_pjrt {
        match AccelKernel::pjrt(&cfg.artifacts_dir) {
            Ok(k) => {
                reg.register(Arc::new(k));
            }
            Err(e) => {
                eprintln!("worker PJRT init failed ({e}); falling back to CPU block kernel");
                metrics.pjrt_fallbacks.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    if let Some(hook) = &cfg.registry_hook {
        hook(&mut reg);
    }
    // after the hook, so a hook replacing kernels can't detach the shared
    // learned-selection handle
    reg.set_cost_model(model.clone());
    reg
}

/// Refit the shared cost model from the kernel-observation log, surface
/// the calibration in metrics, and persist it. A fit with nothing
/// calibrated (too few samples per kernel, or sub-µs walls) publishes
/// nothing — selection stays as it was.
fn refit_model(model: &CostModel, metrics: &Metrics, learn: &LearnConfig) {
    let log = metrics.kernel_log();
    let mut samples: Vec<Sample> = Vec::with_capacity(log.len());
    for obs in &log {
        samples.push(Sample {
            format: obs.format,
            algorithm: obs.algorithm,
            // exactly the score selection ranked (threaded through
            // exec_one), so the fit's x-values match the model's inputs
            predicted: obs.cost_hint + obs.ingest_cost,
            wall_us: obs.wall_us,
        });
    }
    let fitted = FittedModel::fit(&samples, learn.min_samples);
    if fitted.is_empty() {
        return;
    }
    metrics.set_calibration(calibration_entries(&fitted));
    if let Some(path) = &learn.model_path {
        if let Err(e) = fitted.save(path) {
            eprintln!("cost-model persist failed: {e}");
        }
    }
    model.publish(fitted);
    metrics.model_refits.fetch_add(1, Ordering::Relaxed);
}

fn calibration_entries(fitted: &FittedModel) -> Vec<CalibrationEntry> {
    let mut out = Vec::new();
    for ((format, algorithm), cal) in fitted.entries() {
        out.push(CalibrationEntry {
            format: *format,
            algorithm: *algorithm,
            scale: cal.scale,
            samples: cal.samples,
            mean_abs_err_us: cal.mean_abs_err_us,
        });
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    _wid: usize,
    cfg: ServerConfig,
    rx: Arc<Mutex<Receiver<Envelope>>>,
    metrics: Arc<Metrics>,
    model: CostModel,
    transport: Arc<dyn ShardTransport>,
    admission: Arc<AdmissionGate>,
) {
    let registry = worker_registry(&cfg, &metrics, &model);
    let cap = if cfg.coalesce.enabled {
        cfg.coalesce.cache_capacity
    } else {
        0
    };
    let mut cache = PreparedCache::new(cap);
    // content fingerprints memoized by Arc identity across batches (the
    // memo pins each Arc, so pointers can't be recycled under it)
    let mut fp_memo = FingerprintMemo::new(cap);
    // operand→CSR ingestion conversions, memoized by source identity so
    // steady-state non-CSR traffic converts once per worker, not per job
    let mut csr_memo = CsrMemo::new(cap.max(4) * 2);
    // the per-worker reorder window: priority classes beat FIFO, tenants
    // round-robin within a class, same-B jobs coalesce into the anchor's
    // batch, and the starvation bound caps how often a queued job may be
    // bypassed (see `coordinator::admission::FairQueue`). The window is
    // bounded by max_batch, so a burst of unrelated jobs still fans out
    // across the other workers instead of pooling behind one.
    let mut fair = FairQueue::new(cfg.admission.starvation_bound);
    let window = if cfg.coalesce.enabled {
        cfg.coalesce.max_batch.max(1)
    } else {
        1
    };
    let mut stopping = false;

    loop {
        {
            // a sibling worker panicking mid-recv poisons this mutex; the
            // Receiver itself is still sound, so keep serving rather than
            // silently exiting the pool (see `util::lock_unpoisoned`)
            let guard = lock_unpoisoned(&rx);
            if fair.is_empty() && !stopping {
                match guard.recv() {
                    // disconnected + drained: shutdown
                    Err(_) => return,
                    // our pill: drain the window first, then exit
                    Ok(Envelope::Stop) => stopping = true,
                    Ok(Envelope::Job(je)) => fair.push(je),
                }
            }
            // opportunistic, non-blocking refill of the reorder window
            while !stopping && fair.len() < window {
                match guard.try_recv() {
                    Ok(Envelope::Job(je)) => fair.push(je),
                    Ok(Envelope::Stop) => stopping = true,
                    Err(_) => break,
                }
            }
        } // queue unlocked while the batch executes
        if fair.is_empty() {
            if stopping {
                return;
            }
            continue;
        }
        let batch = fair.take_batch(window);
        admission.on_start(batch.len());
        run_batch(
            &registry,
            &cfg,
            &mut cache,
            &mut fp_memo,
            &mut csr_memo,
            batch,
            &metrics,
            &model,
            transport.as_ref(),
            &admission,
        );
        if stopping && fair.is_empty() {
            return;
        }
    }
}

/// Jobs in one micro-batch that share a `PreparedB`: same `B` content
/// fingerprint, same resolved kernel. Each envelope rides with its own
/// ingested (canonical-CSR) `A`; `b_csr`/`native` come from the group's
/// first job.
struct PrepGroup {
    key: PreparedKey,
    kernel: Arc<dyn SpmmKernel>,
    /// The first job's `B` as it arrived (for native-representation
    /// adoption in `prepare_operand`).
    native: MatrixOperand,
    b_csr: Arc<Csr>,
    envs: Vec<(JobEnvelope, Arc<Csr>, SelectionScores)>,
}

/// Resolve the kernel for `job` (per-job override > server spec), plus the
/// exact scores selection ranked for it. Auto selection is operand-aware:
/// conversion cost is charged from `B`'s native arrival format. The scores
/// are computed here — once, at resolve time — and threaded through to the
/// `KernelObservation`: recomputing them at execute time can disagree with
/// what selection compared (a batch-mate's negotiated InCRS sibling
/// executes the group, native-operand credits differ per job), which would
/// hand the fitter wrong x-values.
fn resolve_kernel(
    registry: &Registry,
    spec: KernelSpec,
    job: &SpmmJob,
    a: &Csr,
    b: &Csr,
) -> Result<(Arc<dyn SpmmKernel>, SelectionScores), EngineError> {
    let fixed = |f, alg| {
        registry.resolve_or_err(f, alg).map(|k| {
            let scores = SelectionScores {
                cost_hint: k.cost_hint(a, b).total(),
                ingest_cost: k.ingest_cost(b, Some(&job.b)),
            };
            (k, scores)
        })
    };
    match job.opts.kernel {
        Some((f, alg)) => fixed(f, alg),
        None => match spec {
            KernelSpec::Fixed(f, alg) => fixed(f, alg),
            KernelSpec::Auto => registry.select_native_scored_or_err(a, b, Some(&job.b)),
        },
    }
}

/// Reply with a failure, keeping the metric invariants: the job counts as
/// failed and still lands in the service-latency histogram (`batch_start`
/// is its dequeue time), split by its priority class.
fn reply_err(env: JobEnvelope, err: JobError, metrics: &Metrics, batch_start: Instant) {
    metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
    metrics.observe_latency_class(batch_start.elapsed(), env.job.opts.priority.class());
    let _ = env.reply.send(JobResult {
        id: env.job.id,
        result: Err(err),
    });
}

/// Whether a job's deadline has already passed. Jobs without a deadline
/// never expire.
fn deadline_expired(job: &SpmmJob) -> bool {
    match job.opts.deadline {
        Some(d) => Instant::now() >= d,
        None => false,
    }
}

/// Execute one micro-batch: ingest each job's operands to canonical CSR
/// (memoized by source identity; conversions are metered), group by (B
/// fingerprint, kernel), prepare once per group (LRU-cached across
/// batches), execute each job.
#[allow(clippy::too_many_arguments)]
fn run_batch(
    registry: &Registry,
    cfg: &ServerConfig,
    cache: &mut PreparedCache,
    fp_memo: &mut FingerprintMemo,
    csr_memo: &mut CsrMemo,
    batch: Vec<JobEnvelope>,
    metrics: &Metrics,
    model: &CostModel,
    transport: &dyn ShardTransport,
    admission: &AdmissionGate,
) {
    // service latency is dequeue -> response ready: every job in this
    // batch was dequeued "now", so each one's latency (observed at reply
    // time below) includes group prepare and waiting behind batch-mates
    let batch_start = Instant::now();
    let mut groups: Vec<PrepGroup> = Vec::new();

    for env in batch {
        metrics.observe_queue_wait_class(env.enqueued.elapsed(), env.job.opts.priority.class());
        // deadline check at dequeue: a job whose budget expired while
        // queued dies here, before any conversion or kernel work
        if deadline_expired(&env.job) {
            metrics.deadline_drops.fetch_add(1, Ordering::Relaxed);
            reply_err(env, JobError::DeadlineExceeded, metrics, batch_start);
            continue;
        }
        // shape check on the native operands, before any conversion
        if env.job.a.cols() != env.job.b.rows() {
            let err = JobError::ShapeMismatch {
                a: env.job.a.shape(),
                b: env.job.b.shape(),
            };
            reply_err(env, err, metrics, batch_start);
            continue;
        }
        // ingest: canonical CSR views of both operands (O(1) Arc share for
        // CSR arrivals; conversion memoized by source identity otherwise)
        let conv_before = csr_memo.conversions();
        let t_ingest = Instant::now();
        let ingested = match csr_memo.get(&env.job.a) {
            Ok(a) => csr_memo.get(&env.job.b).map(|b| (a, b)),
            Err(e) => Err(e),
        };
        metrics
            .busy_ns
            .fetch_add(t_ingest.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let converted = csr_memo.conversions() - conv_before;
        if converted > 0 {
            metrics
                .operand_conversions
                .fetch_add(converted, Ordering::Relaxed);
        }
        let (a_csr, b_csr) = match ingested {
            Ok(pair) => pair,
            Err(e) => {
                reply_err(env, JobError::from(e), metrics, batch_start);
                continue;
            }
        };
        // `strict-invariants` builds validate what ingestion produced
        // before it reaches any kernel (no-op otherwise)
        crate::formats::strict_check("server ingest(A)", || a_csr.validate_invariants());
        crate::formats::strict_check("server ingest(B)", || b_csr.validate_invariants());
        let (kernel, scores) = match resolve_kernel(registry, cfg.kernel, &env.job, &a_csr, &b_csr)
        {
            Ok(pair) => pair,
            Err(e) => {
                reply_err(env, e.into(), metrics, batch_start);
                continue;
            }
        };
        // Trivial-prepare kernels (plain-CSR consumers) have an O(1)
        // prepare (Arc share): group them by Arc identity of the ingested
        // CSR and never pay an O(nnz) content hash. Real-prepare kernels
        // (InCRS build, densification, blockization) key by content so the
        // cross-batch cache amortizes their prepare; with coalescing off
        // (single-job batches, no cache) no hash is needed at all.
        let fingerprint = if kernel.prepare_is_trivial() {
            Arc::as_ptr(&b_csr) as usize as u64
        } else if cfg.coalesce.enabled {
            fp_memo.get(&b_csr)
        } else {
            0
        };
        let key = PreparedKey {
            fingerprint,
            format: kernel.format(),
            algorithm: kernel.algorithm(),
        };
        match groups.iter_mut().find(|g| g.key == key) {
            Some(g) => g.envs.push((env, a_csr, scores)),
            None => {
                let native = env.job.b.clone();
                groups.push(PrepGroup {
                    key,
                    kernel,
                    native,
                    b_csr,
                    envs: vec![(env, a_csr, scores)],
                });
            }
        }
    }

    for PrepGroup { key, kernel, native, b_csr, envs } in groups {
        // pre-`prepare` deadline check: jobs whose budget expired while
        // earlier groups executed die before this group pays its prepare
        let mut live = Vec::with_capacity(envs.len());
        for (env, a_csr, scores) in envs {
            if deadline_expired(&env.job) {
                metrics.deadline_drops.fetch_add(1, Ordering::Relaxed);
                reply_err(env, JobError::DeadlineExceeded, metrics, batch_start);
            } else {
                live.push((env, a_csr, scores));
            }
        }
        if live.is_empty() {
            continue;
        }
        let envs = live;
        let t_prep = Instant::now();
        // trivial keys are Arc identities (only unique within this batch),
        // so they bypass the content-keyed cross-batch cache — their
        // prepare is a free Arc share anyway
        let (prepared, built) = if kernel.prepare_is_trivial() {
            (kernel.prepare_operand(&native, &b_csr), true)
        } else {
            let builds_before = cache.builds();
            let p = cache.get_or_build(key, &b_csr, |b| kernel.prepare_operand(&native, b));
            let built = cache.builds() > builds_before;
            (p, built)
        };
        metrics
            .busy_ns
            .fetch_add(t_prep.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let prepared = match prepared {
            Ok(p) => p,
            Err(e) => {
                let err = JobError::from(e);
                for (env, _, _) in envs {
                    reply_err(env, err.clone(), metrics, batch_start);
                }
                continue;
            }
        };
        if built {
            metrics.prepare_builds.fetch_add(1, Ordering::Relaxed);
        } else {
            metrics.prepare_cache_hits.fetch_add(1, Ordering::Relaxed);
        }
        if envs.len() > 1 {
            metrics.coalesced_batches.fetch_add(1, Ordering::Relaxed);
            metrics
                .coalesced_jobs
                .fetch_add(envs.len() as u64 - 1, Ordering::Relaxed);
        }

        for (env, a_csr, scores) in envs {
            let start = Instant::now();
            let result = exec_one(
                kernel.as_ref(),
                &env.job,
                &a_csr,
                &b_csr,
                &prepared,
                scores,
                cfg,
                metrics,
                transport,
            );
            metrics
                .busy_ns
                .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
            // feed the admission gate's service-rate estimate: per-job
            // execute wall (prepare amortizes across the group, so the
            // EWMA tracks marginal cost per admitted job)
            admission.observe_service(start.elapsed());
            match &result {
                Ok(out) => {
                    let done = metrics.jobs_completed.fetch_add(1, Ordering::Relaxed) + 1;
                    metrics
                        .dispatches
                        .fetch_add(out.report.dispatches, Ordering::Relaxed);
                    metrics
                        .real_pairs
                        .fetch_add(out.report.real_pairs, Ordering::Relaxed);
                    // refit cadence rides the shared completion counter:
                    // fetch_add hands each job a unique count, so exactly
                    // one worker performs each scheduled refit
                    if cfg.learn.refit_every > 0 && done % cfg.learn.refit_every == 0 {
                        refit_model(model, metrics, &cfg.learn);
                    }
                }
                Err(_) => {
                    metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                }
            }
            metrics.observe_latency_class(batch_start.elapsed(), env.job.opts.priority.class());
            let _ = env.reply.send(JobResult {
                id: env.job.id,
                result,
            });
        }
    }
}

/// Run one job on an already-prepared `B` — directly, or through the
/// row-band shard executor when the job asked for `shards > 1` (band
/// alignment comes from the server geometry, so blocked kernels stay
/// bit-identical; see `engine::shard`). A lost shard worker (panic)
/// surfaces as [`JobError::ExecFailed`] and the server worker keeps
/// serving.
#[allow(clippy::too_many_arguments)]
fn exec_one(
    kernel: &dyn SpmmKernel,
    job: &SpmmJob,
    a_csr: &Arc<Csr>,
    b_csr: &Arc<Csr>,
    prepared: &crate::engine::PreparedB,
    scores: SelectionScores,
    cfg: &ServerConfig,
    metrics: &Metrics,
    transport: &dyn ShardTransport,
) -> Result<JobOutput, JobError> {
    // pre-dispatch deadline check: an expired job dies here — before the
    // kernel runs or any remote band ships — instead of burning cycles on
    // an answer whose caller already gave up
    if deadline_expired(job) {
        metrics.deadline_drops.fetch_add(1, Ordering::Relaxed);
        return Err(JobError::DeadlineExceeded);
    }
    let start = Instant::now();
    let shards = job.opts.shards.max(1);
    // pooled operands (the fast Gustavson kernel's row workspaces, the
    // outer kernel's merge buffers) report scratch reuse: snapshot the
    // pool counters around the execute and meter the deltas (the pool is
    // owned by this worker's PreparedCache, so only this job's execute —
    // including its shard workers — moves them meanwhile)
    fn pool_counts(prepared: &crate::engine::PreparedB) -> Option<(u64, u64)> {
        match prepared {
            crate::engine::PreparedB::Pooled(pb) => Some((pb.pool.hits(), pb.pool.misses())),
            crate::engine::PreparedB::OuterPooled(ob) => {
                Some((ob.pool.hits(), ob.pool.misses()))
            }
            _ => None,
        }
    }
    let pool_before = pool_counts(prepared);
    // a kernel that is already a shard wrapper (registry_hook /
    // Registry::shard_all) shards itself — re-sharding here would nest
    // executors (bands × bands workers, double band slicing)
    let (c, stats, bands) = if shards > 1 && kernel.name() != "sharded" {
        let shard_cfg = shard::ShardConfig {
            shards,
            block: cfg.geometry.block,
        };
        // remote bands inherit the job's remaining deadline budget as a
        // cap on the transport's per-band timeout (no-op in-process)
        let out = shard::execute_with_deadline(
            transport,
            kernel,
            a_csr,
            Some(b_csr.as_ref()),
            prepared,
            shard_cfg,
            job.opts.deadline,
        )
        .map_err(|e| {
            metrics.shard_failures.fetch_add(1, Ordering::Relaxed);
            JobError::from(e)
        })?;
        metrics.sharded_jobs.fetch_add(1, Ordering::Relaxed);
        metrics
            .shards_executed
            .fetch_add(out.shards.len() as u64, Ordering::Relaxed);
        metrics.record_transport(&out.counters);
        for stat in &out.shards {
            metrics.observe_shard_wall(stat.wall);
            metrics.observe_shard_queue_wait(stat.queue);
        }
        let bands = out.shards.len().max(1);
        if bands < shards {
            // the planner honored fewer bands than the job asked for (few
            // rows, or alignment rounding) — it used to clamp silently
            metrics.shard_clamps.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "job {}: requested {shards} shards, planner produced {bands} band(s)",
                job.id
            );
        }
        (out.c, out.stats, bands)
    } else {
        let out = kernel.execute(a_csr, prepared)?;
        (out.c, out.stats, 1)
    };
    // learned-selection datapoint: the *selection-time* scores (threaded
    // from resolve_kernel) next to the wall time the kernel actually took
    // (execute only — verify/render below is not the kernel's cost).
    // Never recomputed here: the group kernel × this job's operands can
    // score differently from what selection ranked, and the fitter must
    // see the model's own x-values.
    metrics.record_kernel_observation(crate::coordinator::metrics::KernelObservation {
        format: kernel.format(),
        algorithm: kernel.algorithm(),
        cost_hint: scores.cost_hint,
        ingest_cost: scores.ingest_cost,
        wall_us: start.elapsed().as_micros() as u64,
    });
    if let (Some((h0, m0)), Some((h1, m1))) = (pool_before, pool_counts(prepared)) {
        // only this job's execute moves the pool counters, so they are
        // monotone here; strict builds verify that, release builds degrade
        // a regression to a zero delta instead of a panicking underflow
        #[cfg(feature = "strict-invariants")]
        debug_assert!(
            h1 >= h0 && m1 >= m0,
            "workspace pool counters regressed: hits {h0}->{h1}, misses {m0}->{m1}"
        );
        metrics
            .workspace_pool_hits
            .fetch_add(h1.saturating_sub(h0), Ordering::Relaxed);
        metrics
            .workspace_pool_misses
            .fetch_add(m1.saturating_sub(m0), Ordering::Relaxed);
    }
    let max_err = if job.opts.verify {
        let oracle = crate::spmm::dense::multiply(a_csr, b_csr);
        Some(c.max_abs_diff(&oracle))
    } else {
        None
    };
    Ok(JobOutput {
        c: job.opts.keep_result.then_some(c),
        report: stats,
        backend: kernel.name(),
        wall: start.elapsed(),
        max_err,
        shards: bands,
        shards_requested: shards,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{JobOptions, Priority};
    use crate::datasets::synth::uniform;
    use crate::engine::Algorithm;
    use crate::formats::traits::{FormatKind, SparseMatrix};
    use std::time::Duration;

    fn cpu_server(workers: usize, depth: usize) -> Server {
        Server::start(ServerConfig {
            workers,
            queue_depth: depth,
            geometry: Geometry { block: 8, pairs: 16, slots: 8 },
            ..Default::default()
        })
    }

    #[test]
    fn serves_jobs_and_verifies() {
        let s = cpu_server(2, 8);
        let a = Arc::new(uniform(24, 32, 0.2, 1));
        let b = Arc::new(uniform(32, 20, 0.2, 2));
        let rx = s.submit(SpmmJob::new(1, a, b).with_opts(JobOptions {
            verify: true,
            keep_result: true,
            ..Default::default()
        }));
        let res = rx.recv().unwrap();
        let out = res.result.unwrap();
        assert!(out.max_err.unwrap() < 1e-3);
        assert!(out.c.is_some());
        assert_eq!(out.backend, "cpu");
        assert_eq!(out.shards, 1);
        let snap = s.metrics.snapshot();
        assert_eq!(snap.jobs_completed, 1);
        assert_eq!(snap.prepare_builds, 1);
        assert!(snap.queue_p50_us > 0);
        s.shutdown();
    }

    #[test]
    fn many_concurrent_jobs_all_complete() {
        let s = cpu_server(4, 4);
        let a = Arc::new(uniform(16, 16, 0.3, 3));
        let rxs: Vec<_> = (0..20)
            .map(|i| s.submit(SpmmJob::new(i, a.clone(), a.clone())))
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.jobs_completed, 20);
        // all 20 share one B: prepares amortize across micro-batches
        assert!(snap.prepare_builds <= 20);
        s.shutdown();
    }

    #[test]
    fn dimension_mismatch_is_a_typed_error() {
        let s = cpu_server(1, 2);
        let a = Arc::new(uniform(4, 5, 0.5, 1));
        let b = Arc::new(uniform(7, 4, 0.5, 2));
        let res = s.submit(SpmmJob::new(9, a, b)).recv().unwrap();
        assert_eq!(
            res.result.unwrap_err(),
            JobError::ShapeMismatch { a: (4, 5), b: (7, 4) }
        );
        assert_eq!(s.metrics.snapshot().jobs_failed, 1);
        s.shutdown();
    }

    #[test]
    fn try_submit_backpressure() {
        // 1 worker, tiny queue, slow-ish jobs: try_submit must eventually Err
        let s = cpu_server(1, 1);
        let a = Arc::new(uniform(64, 64, 0.4, 5));
        let mut accepted = Vec::new();
        let mut rejected = 0;
        for i in 0..30 {
            match s.try_submit(SpmmJob::new(i, a.clone(), a.clone())) {
                Ok(rx) => accepted.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "queue never filled");
        for rx in accepted {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        s.shutdown();
    }

    #[test]
    fn expired_deadline_dies_cheaply_with_a_typed_error() {
        let s = cpu_server(1, 4);
        let a = Arc::new(uniform(16, 16, 0.3, 30));
        // a deadline of "now" is already expired by the time a worker
        // dequeues the job
        let rx = s.submit(SpmmJob::new(1, a.clone(), a.clone()).with_deadline(Instant::now()));
        assert_eq!(
            rx.recv().unwrap().result.unwrap_err(),
            JobError::DeadlineExceeded
        );
        // a generous budget sails through
        let rx = s.submit(
            SpmmJob::new(2, a.clone(), a).with_deadline_in(Duration::from_secs(60)),
        );
        assert!(rx.recv().unwrap().result.is_ok());
        let snap = s.metrics.snapshot();
        assert_eq!(snap.deadline_drops, 1);
        assert_eq!(snap.jobs_failed, 1);
        assert_eq!(snap.jobs_completed, 1);
        s.shutdown();
    }

    #[test]
    fn overload_sheds_with_a_typed_retry_after() {
        // zero queue-delay budget: once the service estimate trains, any
        // backlog at all predicts delay > 0 and the gate sheds
        let s = Server::start(ServerConfig {
            workers: 1,
            queue_depth: 16,
            geometry: Geometry { block: 8, pairs: 16, slots: 8 },
            admission: AdmissionConfig {
                max_queue_delay: Some(Duration::ZERO),
                ..Default::default()
            },
            ..Default::default()
        });
        let client = s.client();
        let a = Arc::new(uniform(64, 64, 0.4, 31));
        // train the service-rate estimate (an untrained gate admits all)
        client
            .submit(SpmmJob::new(0, a.clone(), a.clone()))
            .unwrap()
            .wait()
            .unwrap();
        let mut accepted = Vec::new();
        let mut shed = 0u64;
        for i in 1..=12 {
            match client.submit(SpmmJob::new(i, a.clone(), a.clone())) {
                Ok(h) => accepted.push(h),
                Err(e) => {
                    assert!(e.is_transient());
                    assert!(e.retry_after().is_some_and(|d| d > Duration::ZERO));
                    shed += 1;
                }
            }
        }
        assert!(shed >= 1, "zero-budget gate never shed under a burst");
        // shedding rejects at the door — it never drops accepted work
        for h in accepted {
            assert!(h.wait().is_ok());
        }
        assert_eq!(s.metrics.snapshot().jobs_shed, shed);
        s.shutdown();
    }

    #[test]
    fn high_priority_overtakes_queued_low_priority_work() {
        // single worker: while the blocker executes, three low-priority
        // jobs and one high-priority job queue behind it. The fair queue
        // anchors the next batch at the high job although it arrived last.
        let s = cpu_server(1, 8);
        let blocker_a = Arc::new(uniform(96, 96, 0.4, 40));
        let blocker = s.submit(SpmmJob::new(0, blocker_a.clone(), blocker_a));
        let low_a = Arc::new(uniform(96, 96, 0.4, 41));
        let lows: Vec<_> = (1..=3)
            .map(|i| {
                s.submit(
                    SpmmJob::new(i, low_a.clone(), low_a.clone()).with_priority(Priority::Low),
                )
            })
            .collect();
        let high_a = Arc::new(uniform(24, 24, 0.3, 42));
        let high =
            s.submit(SpmmJob::new(9, high_a.clone(), high_a).with_priority(Priority::High));
        assert!(blocker.recv().unwrap().result.is_ok());
        assert!(high.recv().unwrap().result.is_ok());
        // right after the high reply the lows (each a real 96×96
        // multiply) cannot all have finished: high was served first
        let done_lows = lows.iter().filter(|rx| rx.try_recv().is_ok()).count();
        assert!(done_lows < 3, "high-priority job was served last");
        for rx in lows {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        s.shutdown();
    }

    #[test]
    fn shutdown_drains_every_accepted_job() {
        // single worker + deep queue: most jobs are still queued when
        // shutdown is called; all must be answered anyway
        let s = cpu_server(1, 16);
        let a = Arc::new(uniform(48, 48, 0.3, 6));
        let rxs: Vec<_> = (0..10)
            .map(|i| s.submit(SpmmJob::new(i, a.clone(), a.clone())))
            .collect();
        s.shutdown();
        for rx in rxs {
            // every response was delivered before shutdown returned
            assert!(rx.try_recv().unwrap().result.is_ok());
        }
    }

    #[test]
    fn submit_after_shutdown_is_a_typed_error() {
        let s = cpu_server(1, 2);
        let client = s.client();
        let a = Arc::new(uniform(8, 8, 0.5, 1));
        s.shutdown();
        let err = client
            .submit(SpmmJob::new(1, a.clone(), a))
            .expect_err("closed server must reject");
        assert_eq!(err, JobError::Shutdown);
    }

    #[test]
    fn per_job_kernel_override() {
        let s = cpu_server(1, 4);
        let a = Arc::new(uniform(20, 30, 0.2, 7));
        let b = Arc::new(uniform(30, 24, 0.2, 8));
        for (f, alg, name) in [
            (FormatKind::Csr, Algorithm::Gustavson, "gustavson"),
            (FormatKind::InCrs, Algorithm::Inner, "inner-incrs"),
            (FormatKind::Csr, Algorithm::Tiled, "tiled"),
        ] {
            let rx = s.submit(
                SpmmJob::new(1, a.clone(), b.clone())
                    .with_opts(JobOptions { verify: true, ..Default::default() })
                    .with_kernel(f, alg),
            );
            let out = rx.recv().unwrap().result.unwrap();
            assert_eq!(out.backend, name);
            assert!(out.max_err.unwrap() < 1e-3, "{name}");
        }
        s.shutdown();
    }

    #[test]
    fn unregistered_kernel_is_a_job_error_not_a_crash() {
        let s = cpu_server(1, 2);
        let a = Arc::new(uniform(8, 8, 0.5, 9));
        let rx = s.submit(
            SpmmJob::new(1, a.clone(), a.clone()).with_kernel(FormatKind::Jad, Algorithm::Inner),
        );
        let err = rx.recv().unwrap().result.unwrap_err();
        assert_eq!(
            err,
            JobError::KernelUnavailable {
                format: Some(FormatKind::Jad),
                algorithm: Some(Algorithm::Inner),
            }
        );
        // the worker survives and serves the next job
        let ok = s.submit(SpmmJob::new(2, a.clone(), a)).recv().unwrap();
        assert!(ok.result.is_ok());
        s.shutdown();
    }

    #[test]
    fn auto_selection_serves_jobs() {
        let s = Server::start(ServerConfig {
            workers: 2,
            queue_depth: 8,
            kernel: KernelSpec::Auto,
            geometry: Geometry { block: 8, pairs: 16, slots: 8 },
            ..Default::default()
        });
        let a = Arc::new(uniform(32, 48, 0.1, 10));
        let b = Arc::new(uniform(48, 40, 0.1, 11));
        let rx = s.submit(SpmmJob::new(1, a, b).with_opts(JobOptions {
            verify: true,
            ..Default::default()
        }));
        let out = rx.recv().unwrap().result.unwrap();
        assert!(out.max_err.unwrap() < 1e-3);
        assert_ne!(out.backend, "dense"); // auto never picks the oracle
        s.shutdown();
    }

    #[test]
    fn sharded_jobs_match_unsharded_bitwise_and_are_metered() {
        let s = cpu_server(1, 8);
        let a = Arc::new(uniform(64, 48, 0.2, 20));
        let b = Arc::new(uniform(48, 40, 0.2, 21));
        let run = |shards: usize| {
            let rx = s.submit(
                SpmmJob::new(shards as u64, a.clone(), b.clone())
                    .with_kernel(FormatKind::Csr, Algorithm::Tiled)
                    .with_shards(shards),
            );
            rx.recv().unwrap().result.unwrap()
        };
        let base = run(1);
        assert_eq!(base.shards, 1);
        let sharded = run(4);
        assert!(sharded.shards > 1, "planner produced {} bands", sharded.shards);
        assert_eq!(
            base.c.as_ref().unwrap().bit_pattern(),
            sharded.c.as_ref().unwrap().bit_pattern(),
            "sharded result diverges bitwise"
        );
        let snap = s.metrics.snapshot();
        assert_eq!(snap.sharded_jobs, 1);
        assert_eq!(snap.shards_executed, sharded.shards as u64);
        assert!(snap.shard_wall_p50_us > 0, "{snap:?}");
        assert!(snap.shard_queue_p50_us > 0, "{snap:?}");
        s.shutdown();
    }

    #[test]
    fn shard_clamp_is_surfaced_and_metered() {
        let s = cpu_server(1, 4);
        let a = Arc::new(uniform(6, 16, 0.5, 40));
        let b = Arc::new(uniform(16, 12, 0.5, 41));
        let rx = s.submit(
            SpmmJob::new(1, a.clone(), b.clone())
                .with_kernel(FormatKind::Csr, Algorithm::Gustavson)
                .with_shards(16),
        );
        let out = rx.recv().unwrap().result.unwrap();
        assert_eq!(out.shards_requested, 16);
        assert!(
            out.shards < out.shards_requested,
            "a 6-row job cannot honor 16 shards (got {})",
            out.shards
        );
        // unsharded jobs report request == actual and never count as clamps
        let rx = s.submit(SpmmJob::new(2, a, b));
        let out1 = rx.recv().unwrap().result.unwrap();
        assert_eq!((out1.shards, out1.shards_requested), (1, 1));
        assert_eq!(s.metrics.snapshot().shard_clamps, 1);
        s.shutdown();
    }

    #[test]
    fn remote_peers_route_sharded_jobs_over_socket_workers() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let geom = Geometry { block: 8, pairs: 16, slots: 8 };
        let remote_reg = Arc::new(Registry::with_default_kernels(geom, 1));
        std::thread::spawn(move || {
            let _ = crate::engine::remote::serve(listener, remote_reg);
        });
        let s = Server::start(ServerConfig {
            workers: 1,
            queue_depth: 4,
            geometry: geom,
            remote_peers: vec![addr],
            ..Default::default()
        });
        let a = Arc::new(uniform(64, 48, 0.2, 50));
        let b = Arc::new(uniform(48, 40, 0.2, 51));
        let run = |id: u64, shards: usize| {
            s.submit(
                SpmmJob::new(id, a.clone(), b.clone())
                    .with_kernel(FormatKind::Csr, Algorithm::Gustavson)
                    .with_shards(shards),
            )
            .recv()
            .unwrap()
            .result
            .unwrap()
        };
        let base = run(1, 1);
        let remote = run(2, 4);
        assert!(remote.shards > 1, "planner produced {} bands", remote.shards);
        assert_eq!(
            base.c.as_ref().unwrap().bit_pattern(),
            remote.c.as_ref().unwrap().bit_pattern(),
            "remote sharded result diverges bitwise from the local run"
        );
        let snap = s.metrics.snapshot();
        assert_eq!(snap.remote_bands, remote.shards as u64);
        assert!(snap.prepare_replications >= 1, "{snap:?}");
        s.shutdown();
    }

    #[test]
    fn registry_hook_extends_worker_registries() {
        let hook: RegistryHook = Arc::new(|reg: &mut Registry| {
            reg.register(Arc::new(crate::engine::ShardedKernel::wrap(
                reg.resolve(FormatKind::Csr, Algorithm::Gustavson).unwrap(),
                crate::engine::ShardConfig { shards: 2, block: 8 },
            )));
        });
        let s = Server::start(ServerConfig {
            workers: 1,
            queue_depth: 4,
            geometry: Geometry { block: 8, pairs: 16, slots: 8 },
            registry_hook: Some(hook),
            ..Default::default()
        });
        let a = Arc::new(uniform(24, 24, 0.3, 22));
        let rx = s.submit(
            SpmmJob::new(1, a.clone(), a)
                .with_opts(JobOptions { verify: true, ..Default::default() })
                .with_kernel(FormatKind::Csr, Algorithm::Gustavson),
        );
        let out = rx.recv().unwrap().result.unwrap();
        assert_eq!(out.backend, "sharded");
        assert!(out.max_err.unwrap() < 1e-3);
        s.shutdown();
    }

    #[test]
    fn non_csr_operands_serve_bit_identically_to_csr() {
        let s = cpu_server(1, 8);
        let client = s.client();
        let a = Arc::new(uniform(40, 32, 0.2, 30));
        let b = Arc::new(uniform(32, 24, 0.2, 31));
        let a_coo = MatrixOperand::from(Arc::clone(&a))
            .convert(FormatKind::Coo)
            .unwrap();
        let b_ell = MatrixOperand::from(Arc::clone(&b))
            .convert(FormatKind::Ellpack)
            .unwrap();
        let want = client
            .job(Arc::clone(&a), Arc::clone(&b))
            .kernel(FormatKind::Csr, Algorithm::Tiled)
            .submit()
            .unwrap()
            .wait()
            .unwrap();
        let got = client
            .job(a_coo, b_ell)
            .kernel(FormatKind::Csr, Algorithm::Tiled)
            .submit()
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(
            want.c.as_ref().unwrap().bit_pattern(),
            got.c.as_ref().unwrap().bit_pattern(),
            "native-format submission diverges from pre-converted CSR"
        );
        let snap = client.metrics();
        assert!(snap.operand_conversions >= 2, "{snap:?}");
        assert_eq!(snap.jobs_failed, 0);
        drop(client);
        s.shutdown();
    }

    #[test]
    fn operand_shape_mismatch_is_checked_before_conversion() {
        let s = cpu_server(1, 2);
        let client = s.client();
        let a = uniform(4, 5, 0.5, 1).to_coo();
        let b = uniform(7, 4, 0.5, 2).to_coo();
        let err = client.job(a, b).submit().unwrap().wait().unwrap_err();
        assert_eq!(err, JobError::ShapeMismatch { a: (4, 5), b: (7, 4) });
        // nothing was converted for the doomed job
        assert_eq!(client.metrics().operand_conversions, 0);
        drop(client);
        s.shutdown();
    }

    #[test]
    fn coalescing_off_prepares_per_job() {
        let s = Server::start(ServerConfig {
            workers: 1,
            queue_depth: 16,
            kernel: KernelSpec::Fixed(FormatKind::InCrs, Algorithm::Inner),
            geometry: Geometry { block: 8, pairs: 16, slots: 8 },
            coalesce: CoalesceConfig { enabled: false, ..Default::default() },
            ..Default::default()
        });
        let a = Arc::new(uniform(16, 24, 0.3, 12));
        let b = Arc::new(uniform(24, 16, 0.3, 13));
        let rxs: Vec<_> = (0..6)
            .map(|i| s.submit(SpmmJob::new(i, a.clone(), b.clone())))
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.prepare_builds, 6, "{snap:?}");
        assert_eq!(snap.coalesced_jobs, 0, "{snap:?}");
        s.shutdown();
    }

    #[test]
    fn fast_gustavson_pools_workspaces_across_a_coalesced_micro_batch() {
        // single worker + B-sharing coalescing: 8 jobs sharing one B
        // resolve to one PreparedB (pool included), so the first job
        // allocates the workspace and the rest reuse it
        let s = Server::start(ServerConfig {
            workers: 1,
            queue_depth: 16,
            kernel: KernelSpec::Fixed(FormatKind::Csr, Algorithm::GustavsonFast),
            geometry: Geometry { block: 8, pairs: 16, slots: 8 },
            ..Default::default()
        });
        let a = Arc::new(uniform(48, 64, 0.2, 80));
        let b = Arc::new(uniform(64, 40, 0.2, 81));
        let rxs: Vec<_> = (0..8)
            .map(|i| s.submit(SpmmJob::new(i, a.clone(), b.clone())))
            .collect();
        let mut outs = Vec::new();
        for rx in rxs {
            outs.push(rx.recv().unwrap().result.unwrap());
        }
        for out in &outs {
            assert_eq!(out.backend, "gustavson-fast");
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.jobs_completed, 8);
        assert!(snap.prepare_builds < 8, "B-sharing did not coalesce: {snap:?}");
        assert!(
            snap.workspace_pool_hits > 0,
            "workspace pool never reused across the micro-batch: {snap:?}"
        );
        // one serial band per job: 8 checkouts total, and every PreparedB
        // rebuild (batch boundaries notwithstanding, the content-keyed LRU
        // returns the same pool) allocates exactly one workspace
        assert_eq!(
            snap.workspace_pool_hits + snap.workspace_pool_misses,
            8,
            "{snap:?}"
        );
        assert_eq!(snap.workspace_pool_misses, snap.prepare_builds, "{snap:?}");
        s.shutdown();
    }

    #[test]
    fn every_executed_kernel_logs_a_selection_observation() {
        let s = cpu_server(1, 8);
        let a = Arc::new(uniform(32, 40, 0.2, 82));
        let b = Arc::new(uniform(40, 24, 0.2, 83));
        for (f, alg) in [
            (FormatKind::Csr, Algorithm::Gustavson),
            (FormatKind::Csr, Algorithm::GustavsonFast),
            (FormatKind::Csr, Algorithm::Tiled),
        ] {
            let rx = s.submit(SpmmJob::new(1, a.clone(), b.clone()).with_kernel(f, alg));
            rx.recv().unwrap().result.unwrap();
        }
        assert_eq!(s.metrics.snapshot().kernel_observations, 3);
        let log = s.metrics.kernel_log();
        assert_eq!(log.len(), 3);
        let algs: Vec<Algorithm> = log.iter().map(|o| o.algorithm).collect();
        for alg in [Algorithm::Gustavson, Algorithm::GustavsonFast, Algorithm::Tiled] {
            assert!(algs.contains(&alg), "{alg:?} missing from {algs:?}");
        }
        for obs in &log {
            assert!(obs.cost_hint > 0.0, "{obs:?}");
            // B arrived as canonical CSR: ingestion is free
            assert_eq!(obs.ingest_cost, 0.0, "{obs:?}");
        }
        s.shutdown();
    }

    #[test]
    fn observation_records_selection_time_scores_for_native_csc_jobs() {
        let geometry = Geometry { block: 8, pairs: 16, slots: 8 };
        let s = Server::start(ServerConfig {
            workers: 1,
            queue_depth: 4,
            kernel: KernelSpec::Auto,
            geometry,
            ..Default::default()
        });
        let client = s.client();
        let a = Arc::new(uniform(32, 48, 0.05, 70));
        let b = Arc::new(uniform(48, 40, 0.05, 71));
        let b_csc = MatrixOperand::from(Arc::clone(&b))
            .convert(FormatKind::Csc)
            .unwrap();
        // job 1: explicit outer kernel on the native-CSC operand — the
        // charged ingest is the CSC direct-transpose tier, computed at
        // resolve time and recorded verbatim
        let out = client
            .job(MatrixOperand::from(Arc::clone(&a)), b_csc.clone())
            .kernel(FormatKind::Csc, Algorithm::OuterProduct)
            .submit()
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(out.backend, "outer");
        // job 2: auto selection on the same native-CSC operand
        let out2 = client
            .job(MatrixOperand::from(Arc::clone(&a)), b_csc.clone())
            .submit()
            .unwrap()
            .wait()
            .unwrap();
        let log = s.metrics.kernel_log();
        assert_eq!(log.len(), 2);
        // recompute what resolve-time selection scored, on an identically
        // constructed registry: the observation must match *exactly*
        let reg = Registry::with_default_kernels(geometry, 1);
        let b_ing = b_csc.to_csr().unwrap();
        let k = reg.resolve(FormatKind::Csc, Algorithm::OuterProduct).unwrap();
        let want_hint = k.cost_hint(&a, &b_ing).total();
        let want_ingest = k.ingest_cost(&b_ing, Some(&b_csc));
        assert!(want_ingest > 0.0, "CSC arrival must be charged its transpose");
        assert_eq!(log[0].cost_hint, want_hint, "{:?}", log[0]);
        assert_eq!(log[0].ingest_cost, want_ingest, "{:?}", log[0]);
        let (want_k, want_scores) = reg.select_native_scored(&a, &b_ing, Some(&b_csc)).unwrap();
        assert_eq!(out2.backend, want_k.name());
        assert_eq!(
            (log[1].format, log[1].algorithm),
            (want_k.format(), want_k.algorithm())
        );
        assert_eq!(log[1].cost_hint, want_scores.cost_hint, "{:?}", log[1]);
        assert_eq!(log[1].ingest_cost, want_scores.ingest_cost, "{:?}", log[1]);
        drop(client);
        s.shutdown();
    }

    #[test]
    fn refit_cadence_fits_persists_and_warm_loads() {
        let dir = std::env::temp_dir().join(format!("spmm_learn_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.txt");
        let _ = std::fs::remove_file(&path);
        let cfg = ServerConfig {
            workers: 1,
            queue_depth: 16,
            kernel: KernelSpec::Auto,
            geometry: Geometry { block: 8, pairs: 16, slots: 8 },
            learn: LearnConfig {
                refit_every: 4,
                min_samples: 2,
                model_path: Some(path.clone()),
                ..Default::default()
            },
            ..Default::default()
        };
        let s = Server::start(cfg.clone());
        // big enough that execute walls are comfortably over 1µs, so the
        // fit has usable y-values
        let a = Arc::new(uniform(128, 128, 0.3, 90));
        let b = Arc::new(uniform(128, 96, 0.3, 91));
        for i in 0..12 {
            let rx = s.submit(SpmmJob::new(i, a.clone(), b.clone()));
            assert!(rx.recv().unwrap().result.is_ok());
        }
        let snap = s.metrics.snapshot();
        assert!(snap.model_refits >= 1, "{snap:?}");
        let cal = s.metrics.calibration();
        assert!(!cal.is_empty());
        for c in &cal {
            assert!(c.scale.is_finite() && c.scale > 0.0, "{c:?}");
            assert!(c.samples >= 2, "{c:?}");
        }
        assert!(path.exists(), "refit must persist the model");
        s.shutdown();
        // restart warm: the persisted model loads bit-exactly and the
        // server serves calibrated from the first job
        let s2 = Server::start(ServerConfig {
            learn: LearnConfig {
                refit_every: 0,
                model_path: Some(path.clone()),
                ..Default::default()
            },
            ..cfg
        });
        let warm = s2.cost_model().fitted();
        assert!(!warm.is_empty(), "warm-load failed");
        assert_eq!(warm, crate::engine::FittedModel::load(&path).unwrap());
        assert!(!s2.metrics.calibration().is_empty(), "warm-load must surface calibration");
        let rx = s2.submit(SpmmJob::new(99, a.clone(), b.clone()));
        assert!(rx.recv().unwrap().result.is_ok());
        s2.shutdown();
        let _ = std::fs::remove_file(&path);
    }
}
