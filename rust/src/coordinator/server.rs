//! Batching SpMM server: a worker pool over bounded channels.
//!
//! The L3 serving shape (DESIGN.md §1): callers `submit` jobs and get a
//! per-job response channel; a bounded queue applies backpressure (submit
//! blocks when `queue_depth` jobs are in flight); each worker owns its own
//! execution engine (PJRT clients are not shared across threads) and
//! processes whole jobs — dispatch-level parallelism inside a job uses the
//! scheduler's batches.
//!
//! Built on std threads + mpsc because the offline registry has no tokio
//! (DESIGN.md §2); the batching/backpressure semantics are identical.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::job::{JobOutput, JobResult, SpmmJob};
use super::metrics::Metrics;
use super::router::EngineKind;
use crate::runtime::numeric::NumericEngine;
use crate::spmm::plan::Geometry;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub workers: usize,
    /// Max queued jobs before `submit` blocks (backpressure).
    pub queue_depth: usize,
    pub engine: EngineKind,
    /// Geometry for CPU engines; PJRT engines read theirs from the manifest.
    pub geometry: Geometry,
    pub artifacts_dir: std::path::PathBuf,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 2,
            queue_depth: 16,
            engine: EngineKind::Cpu,
            geometry: Geometry::default(),
            artifacts_dir: crate::runtime::Manifest::default_dir(),
        }
    }
}

enum Envelope {
    Job(SpmmJob, SyncSender<JobResult>),
    Shutdown,
}

pub struct Server {
    tx: SyncSender<Envelope>,
    handles: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Server {
    pub fn start(cfg: ServerConfig) -> Server {
        assert!(cfg.workers > 0, "need at least one worker");
        let (tx, rx) = sync_channel::<Envelope>(cfg.queue_depth);
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for wid in 0..cfg.workers {
            let rx = Arc::clone(&rx);
            let metrics = Arc::clone(&metrics);
            let cfg = cfg.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("spmm-worker-{wid}"))
                    .spawn(move || worker_loop(wid, cfg, rx, metrics))
                    .expect("spawn worker"),
            );
        }
        Server {
            tx,
            handles,
            metrics,
        }
    }

    /// Submit a job; blocks when the queue is full (backpressure). Returns
    /// the response channel.
    pub fn submit(&self, job: SpmmJob) -> Receiver<JobResult> {
        let (rtx, rrx) = sync_channel(1);
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Envelope::Job(job, rtx))
            .expect("server shut down");
        rrx
    }

    /// Non-blocking submit: `Err(job)` when the queue is full.
    pub fn try_submit(&self, job: SpmmJob) -> Result<Receiver<JobResult>, SpmmJob> {
        let (rtx, rrx) = sync_channel(1);
        match self.tx.try_send(Envelope::Job(job, rtx)) {
            Ok(()) => {
                self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
                Ok(rrx)
            }
            Err(TrySendError::Full(Envelope::Job(job, _))) => Err(job),
            Err(TrySendError::Disconnected(Envelope::Job(job, _))) => Err(job),
            Err(_) => unreachable!("only jobs are try-sent"),
        }
    }

    /// Graceful shutdown: drains queued jobs, then joins workers.
    pub fn shutdown(self) {
        for _ in &self.handles {
            let _ = self.tx.send(Envelope::Shutdown);
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    _wid: usize,
    cfg: ServerConfig,
    rx: Arc<std::sync::Mutex<Receiver<Envelope>>>,
    metrics: Arc<Metrics>,
) {
    // Each worker owns its engine; PJRT load failure degrades to CPU with
    // an explicit failure counter rather than killing the worker.
    let engine = match cfg.engine {
        EngineKind::Pjrt => match NumericEngine::pjrt(&cfg.artifacts_dir) {
            Ok(e) => e,
            Err(e) => {
                log::warn!("worker PJRT init failed ({e:#}); falling back to CPU");
                metrics.jobs_failed.fetch_add(0, Ordering::Relaxed);
                NumericEngine::cpu(cfg.geometry)
            }
        },
        EngineKind::Cpu => NumericEngine::cpu(cfg.geometry),
    };

    loop {
        let env = {
            let guard = rx.lock().expect("queue lock");
            guard.recv()
        };
        match env {
            Err(_) | Ok(Envelope::Shutdown) => return,
            Ok(Envelope::Job(job, reply)) => {
                let start = Instant::now();
                let result = run_job(&engine, &job);
                let wall = start.elapsed();
                metrics.busy_ns.fetch_add(wall.as_nanos() as u64, Ordering::Relaxed);
                metrics.observe_latency(wall);
                match &result {
                    Ok(out) => {
                        metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                        metrics
                            .dispatches
                            .fetch_add(out.report.dispatches, Ordering::Relaxed);
                        metrics
                            .real_pairs
                            .fetch_add(out.report.real_pairs, Ordering::Relaxed);
                    }
                    Err(_) => {
                        metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
                let _ = reply.send(JobResult {
                    id: job.id,
                    result,
                });
            }
        }
    }
}

fn run_job(engine: &NumericEngine, job: &SpmmJob) -> Result<JobOutput, String> {
    use crate::formats::traits::SparseMatrix;
    if job.a.cols() != job.b.rows() {
        return Err(format!(
            "dimension mismatch: A is {:?}, B is {:?}",
            job.a.shape(),
            job.b.shape()
        ));
    }
    let start = Instant::now();
    let (c, report) = engine.spmm(&job.a, &job.b).map_err(|e| format!("{e:#}"))?;
    let max_err = if job.opts.verify {
        let oracle = crate::spmm::dense::multiply(&job.a, &job.b);
        Some(c.max_abs_diff(&oracle))
    } else {
        None
    };
    Ok(JobOutput {
        c: job.opts.keep_result.then_some(c),
        report,
        backend: engine.backend_name(),
        wall: start.elapsed(),
        max_err,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::JobOptions;
    use crate::datasets::synth::uniform;

    fn cpu_server(workers: usize, depth: usize) -> Server {
        Server::start(ServerConfig {
            workers,
            queue_depth: depth,
            engine: EngineKind::Cpu,
            geometry: Geometry { block: 8, pairs: 16, slots: 8 },
            ..Default::default()
        })
    }

    #[test]
    fn serves_jobs_and_verifies() {
        let s = cpu_server(2, 8);
        let a = Arc::new(uniform(24, 32, 0.2, 1));
        let b = Arc::new(uniform(32, 20, 0.2, 2));
        let rx = s.submit(
            SpmmJob::new(1, a, b).with_opts(JobOptions { verify: true, keep_result: true }),
        );
        let res = rx.recv().unwrap();
        let out = res.result.unwrap();
        assert!(out.max_err.unwrap() < 1e-3);
        assert!(out.c.is_some());
        assert_eq!(out.backend, "cpu");
        let snap = s.metrics.snapshot();
        assert_eq!(snap.jobs_completed, 1);
        s.shutdown();
    }

    #[test]
    fn many_concurrent_jobs_all_complete() {
        let s = cpu_server(4, 4);
        let a = Arc::new(uniform(16, 16, 0.3, 3));
        let rxs: Vec<_> = (0..20)
            .map(|i| s.submit(SpmmJob::new(i, a.clone(), a.clone())))
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        assert_eq!(s.metrics.snapshot().jobs_completed, 20);
        s.shutdown();
    }

    #[test]
    fn dimension_mismatch_fails_cleanly() {
        let s = cpu_server(1, 2);
        let a = Arc::new(uniform(4, 5, 0.5, 1));
        let b = Arc::new(uniform(7, 4, 0.5, 2));
        let res = s.submit(SpmmJob::new(9, a, b)).recv().unwrap();
        assert!(res.result.unwrap_err().contains("dimension mismatch"));
        assert_eq!(s.metrics.snapshot().jobs_failed, 1);
        s.shutdown();
    }

    #[test]
    fn try_submit_backpressure() {
        // 1 worker, tiny queue, slow-ish jobs: try_submit must eventually Err
        let s = cpu_server(1, 1);
        let a = Arc::new(uniform(64, 64, 0.4, 5));
        let mut accepted = Vec::new();
        let mut rejected = 0;
        for i in 0..30 {
            match s.try_submit(SpmmJob::new(i, a.clone(), a.clone())) {
                Ok(rx) => accepted.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "queue never filled");
        for rx in accepted {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        s.shutdown();
    }

    #[test]
    fn shutdown_drains() {
        let s = cpu_server(2, 8);
        let a = Arc::new(uniform(8, 8, 0.5, 6));
        let rx = s.submit(SpmmJob::new(1, a.clone(), a));
        s.shutdown();
        // response was delivered before shutdown completed
        assert!(rx.try_recv().is_ok());
    }
}
