//! SpMM job descriptors and results — the unit of work the coordinator
//! routes, schedules, and dispatches through the kernel registry.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::error::JobError;
use crate::engine::{Algorithm, ExecStats};
use crate::formats::csr::Csr;
use crate::formats::dense::Dense;
use crate::formats::operand::MatrixOperand;
use crate::formats::traits::FormatKind;

/// What the caller wants done. Operands are typed [`MatrixOperand`]
/// handles — any Table-I format, submitted as it arrived; cloning a job is
/// two `Arc` bumps.
#[derive(Clone)]
pub struct SpmmJob {
    pub id: u64,
    pub a: MatrixOperand,
    pub b: MatrixOperand,
    pub opts: JobOptions,
}

/// Priority class for the fair-queuing drain (`coordinator::admission`).
/// Higher classes are served first, but the starvation bound guarantees
/// lower classes still run: a job bypassed `starvation_bound` times is
/// promoted ahead of everything newer regardless of class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    High,
    #[default]
    Normal,
    Low,
}

/// Number of priority classes — sizes the per-class metric histograms.
pub const PRIORITY_CLASSES: usize = 3;

impl Priority {
    /// Dense class index (0 = High … 2 = Low) for per-class metrics.
    pub fn class(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    /// Parse a CLI/bench spelling; `None` for unknown names.
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "low" => Some(Priority::Low),
            _ => None,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct JobOptions {
    /// Cross-check the result against the CPU oracle (test/debug traffic;
    /// adds a full reference multiply).
    pub verify: bool,
    /// Keep the dense result (large!) or return only the report.
    pub keep_result: bool,
    /// Per-job kernel override: resolve exactly this registry key instead
    /// of the server's configured [`super::router::KernelSpec`].
    pub kernel: Option<(FormatKind, Algorithm)>,
    /// Row-band shard count for this job (`engine::shard`). 1 = unsharded;
    /// > 1 splits execution across that many channel-connected shard
    /// workers, bit-identical to the unsharded run.
    pub shards: usize,
    /// Tenant id for fair queuing — jobs from different tenants in the same
    /// priority class are drained round-robin instead of FIFO, so one
    /// tenant's burst cannot monopolize a worker. 0 = the default tenant.
    pub tenant: u32,
    /// Priority class ([`Priority`]). Higher classes drain first, bounded
    /// by the admission layer's starvation bound.
    pub priority: Priority,
    /// Absolute deadline. Checked at dequeue, pre-`prepare`, and pre-band-
    /// dispatch; expired jobs die cheaply with
    /// [`JobError::DeadlineExceeded`] instead of burning a `prepare`.
    /// Remote bands inherit the remaining budget as their wire timeout.
    pub deadline: Option<Instant>,
}

impl Default for JobOptions {
    fn default() -> Self {
        JobOptions {
            verify: false,
            keep_result: true,
            kernel: None,
            shards: 1,
            tenant: 0,
            priority: Priority::Normal,
            deadline: None,
        }
    }
}

/// Outcome of one job. Errors are typed ([`JobError`]) — match on the
/// variant, don't scrape the message.
#[derive(Debug)]
pub struct JobResult {
    pub id: u64,
    pub result: Result<JobOutput, JobError>,
}

#[derive(Debug)]
pub struct JobOutput {
    pub c: Option<Dense>,
    pub report: ExecStats,
    /// Name of the kernel that ran the job ("cpu", "pjrt", "gustavson", …).
    pub backend: &'static str,
    pub wall: Duration,
    /// max |result - oracle| when `verify` was requested.
    pub max_err: Option<f32>,
    /// Row-band shards the job actually executed on (1 = unsharded; the
    /// planner may use fewer bands than requested on small matrices).
    pub shards: usize,
    /// Shards the caller *asked* for ([`JobOptions::shards`]). When the
    /// planner clamps (`shards < shards_requested`) the server logs it once
    /// and bumps the `shard_clamps` metric — the clamp used to be silent.
    pub shards_requested: usize,
}

impl SpmmJob {
    /// The primary constructor: operands in any native format ([`Csr`],
    /// [`crate::formats::Coo`], [`crate::formats::InCrs`], … — anything
    /// `Into<MatrixOperand>`, owned or `Arc`-wrapped).
    pub fn from_operands(
        id: u64,
        a: impl Into<MatrixOperand>,
        b: impl Into<MatrixOperand>,
    ) -> SpmmJob {
        SpmmJob {
            id,
            a: a.into(),
            b: b.into(),
            opts: JobOptions::default(),
        }
    }

    /// CSR-only construction — the pre-operand API, kept as a one-release
    /// shim. Prefer [`SpmmJob::from_operands`].
    pub fn new(id: u64, a: Arc<Csr>, b: Arc<Csr>) -> SpmmJob {
        Self::from_operands(id, a, b)
    }

    pub fn with_opts(mut self, opts: JobOptions) -> SpmmJob {
        self.opts = opts;
        self
    }

    /// Builder-style per-job kernel override.
    pub fn with_kernel(mut self, format: FormatKind, algorithm: Algorithm) -> SpmmJob {
        self.opts.kernel = Some((format, algorithm));
        self
    }

    /// Builder-style row-band shard count (`engine::shard`).
    pub fn with_shards(mut self, shards: usize) -> SpmmJob {
        self.opts.shards = shards.max(1);
        self
    }

    /// Builder-style tenant id (fair-queuing round-robin key).
    pub fn with_tenant(mut self, tenant: u32) -> SpmmJob {
        self.opts.tenant = tenant;
        self
    }

    /// Builder-style priority class.
    pub fn with_priority(mut self, priority: Priority) -> SpmmJob {
        self.opts.priority = priority;
        self
    }

    /// Builder-style absolute deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> SpmmJob {
        self.opts.deadline = Some(deadline);
        self
    }

    /// Builder-style relative deadline: `now + budget`.
    pub fn with_deadline_in(self, budget: Duration) -> SpmmJob {
        let deadline = Instant::now() + budget;
        self.with_deadline(deadline)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::formats::traits::SparseMatrix;

    #[test]
    fn job_construction() {
        let a = Arc::new(uniform(4, 4, 0.5, 1));
        let j = SpmmJob::new(7, a.clone(), a).with_opts(JobOptions {
            verify: true,
            keep_result: false,
            ..Default::default()
        });
        assert_eq!(j.id, 7);
        assert!(j.opts.verify);
        assert!(!j.opts.keep_result);
        assert!(j.opts.kernel.is_none());
        assert_eq!(j.opts.shards, 1);
    }

    #[test]
    fn shards_builder_clamps_to_one() {
        let a = Arc::new(uniform(4, 4, 0.5, 1));
        let j = SpmmJob::new(1, a.clone(), a.clone()).with_shards(4);
        assert_eq!(j.opts.shards, 4);
        let j0 = SpmmJob::new(2, a.clone(), a).with_shards(0);
        assert_eq!(j0.opts.shards, 1);
    }

    #[test]
    fn traffic_options_default_neutral_and_build() {
        let a = Arc::new(uniform(4, 4, 0.5, 1));
        let j = SpmmJob::new(1, a.clone(), a.clone());
        assert_eq!(j.opts.tenant, 0);
        assert_eq!(j.opts.priority, Priority::Normal);
        assert!(j.opts.deadline.is_none());

        let soon = Instant::now() + Duration::from_millis(50);
        let j = SpmmJob::new(2, a.clone(), a)
            .with_tenant(7)
            .with_priority(Priority::High)
            .with_deadline(soon);
        assert_eq!(j.opts.tenant, 7);
        assert_eq!(j.opts.priority, Priority::High);
        assert_eq!(j.opts.deadline, Some(soon));
    }

    #[test]
    fn priority_classes_are_dense_and_parse_round_trips() {
        let all = [Priority::High, Priority::Normal, Priority::Low];
        for (i, p) in all.iter().enumerate() {
            assert_eq!(p.class(), i);
            assert!(p.class() < PRIORITY_CLASSES);
            assert_eq!(Priority::parse(p.name()), Some(*p));
        }
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::default(), Priority::Normal);
    }

    #[test]
    fn kernel_override_builder() {
        let a = Arc::new(uniform(4, 4, 0.5, 1));
        let j = SpmmJob::new(1, a.clone(), a)
            .with_kernel(FormatKind::InCrs, Algorithm::Inner);
        assert_eq!(j.opts.kernel, Some((FormatKind::InCrs, Algorithm::Inner)));
    }

    #[test]
    fn operands_arrive_in_any_format() {
        let csr = uniform(6, 6, 0.5, 2);
        let coo = csr.to_coo();
        let j = SpmmJob::from_operands(3, coo, Arc::new(csr));
        assert_eq!(j.a.format(), FormatKind::Coo);
        assert_eq!(j.b.format(), FormatKind::Csr);
        assert_eq!(j.a.shape(), j.b.shape());
        // the CSR shim wraps into the same typed operand
        let a = Arc::new(uniform(4, 4, 0.5, 1));
        let legacy = SpmmJob::new(1, a.clone(), a);
        assert_eq!(legacy.a.format(), FormatKind::Csr);
        assert!(legacy.a.same_source(&legacy.b));
    }
}
