//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` recording the dispatch
//! geometry (block/pairs/slots/dense_dim) and each artifact's operand
//! shapes. We parse and *assert* against it at load time so the planner and
//! the compiled HLO can never drift apart silently.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::spmm::plan::Geometry;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub file: PathBuf,
    pub args: Vec<ArgSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub block: usize,
    pub pairs: usize,
    pub slots: usize,
    pub dense_dim: usize,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    pub fn geometry(&self) -> Geometry {
        Geometry {
            block: self.block,
            pairs: self.pairs,
            slots: self.slots,
        }
    }

    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{path:?}: {e} (run `make artifacts` first)"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text)?;
        let get = |k: &str| -> Result<usize, String> {
            j.at(&[k])?
                .as_usize()
                .ok_or_else(|| format!("manifest key {k} not a number"))
        };
        let mut artifacts = BTreeMap::new();
        for (name, entry) in j
            .at(&["artifacts"])?
            .as_obj()
            .ok_or("artifacts not an object")?
        {
            let file = entry
                .at(&["file"])?
                .as_str()
                .ok_or("file not a string")?;
            let mut args = Vec::new();
            for a in entry
                .at(&["args"])?
                .as_arr()
                .ok_or("args not an array")?
            {
                let shape = a
                    .at(&["shape"])?
                    .as_arr()
                    .ok_or("shape not an array")?
                    .iter()
                    .map(|x| x.as_usize().ok_or("bad dim".to_string()))
                    .collect::<Result<Vec<_>, _>>()?;
                let dtype = a
                    .at(&["dtype"])?
                    .as_str()
                    .ok_or("dtype not a string")?
                    .to_string();
                args.push(ArgSpec { shape, dtype });
            }
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    file: dir.join(file),
                    args,
                },
            );
        }
        let m = Manifest {
            dir: dir.to_path_buf(),
            block: get("block")?,
            pairs: get("pairs")?,
            slots: get("slots")?,
            dense_dim: get("dense_dim")?,
            artifacts,
        };
        m.validate()?;
        Ok(m)
    }

    /// Cross-check the geometry against each artifact's declared shapes.
    fn validate(&self) -> Result<(), String> {
        if let Some(e) = self.artifacts.get("spmm_block") {
            let want = [
                vec![self.pairs],
                vec![self.pairs, self.block, self.block],
                vec![self.pairs, self.block, self.block],
            ];
            if e.args.len() != 3 {
                return Err(format!("spmm_block: {} args, want 3", e.args.len()));
            }
            for (a, w) in e.args.iter().zip(&want) {
                if &a.shape != w {
                    return Err(format!(
                        "spmm_block arg shape {:?} != geometry {:?}",
                        a.shape, w
                    ));
                }
            }
            if e.args[0].dtype != "int32" {
                return Err(format!("seg dtype {} != int32", e.args[0].dtype));
            }
        }
        if let Some(e) = self.artifacts.get("dense_mm") {
            for a in &e.args {
                if a.shape != vec![self.dense_dim, self.dense_dim] {
                    return Err(format!(
                        "dense_mm arg shape {:?} != [{0:?}, {0:?}]",
                        a.shape
                    ));
                }
            }
        }
        Ok(())
    }

    /// Standard artifact directory resolution: `$SPMM_ARTIFACTS`, else
    /// `./artifacts` relative to the current dir, else next to the exe.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("SPMM_ARTIFACTS") {
            return PathBuf::from(p);
        }
        PathBuf::from("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "block": 32, "pairs": 128, "slots": 64, "dense_dim": 256,
      "artifacts": {
        "spmm_block": {
          "file": "spmm_block.hlo.txt",
          "args": [
            {"shape": [128], "dtype": "int32"},
            {"shape": [128, 32, 32], "dtype": "float32"},
            {"shape": [128, 32, 32], "dtype": "float32"}
          ],
          "hlo_bytes": 1
        },
        "dense_mm": {
          "file": "dense_mm.hlo.txt",
          "args": [
            {"shape": [256, 256], "dtype": "float32"},
            {"shape": [256, 256], "dtype": "float32"}
          ],
          "hlo_bytes": 1
        }
      }
    }"#;

    #[test]
    fn parses_and_validates() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.geometry(), Geometry { block: 32, pairs: 128, slots: 64 });
        assert_eq!(m.artifacts["spmm_block"].args[1].shape, vec![128, 32, 32]);
        assert!(m.artifacts["spmm_block"].file.ends_with("spmm_block.hlo.txt"));
    }

    #[test]
    fn rejects_geometry_drift() {
        let bad = SAMPLE.replace("\"pairs\": 128", "\"pairs\": 64");
        let err = Manifest::parse(Path::new("/tmp/x"), &bad).unwrap_err();
        assert!(err.contains("spmm_block"), "{err}");
    }

    #[test]
    fn rejects_wrong_seg_dtype() {
        let bad = SAMPLE.replace("\"dtype\": \"int32\"", "\"dtype\": \"float32\"");
        assert!(Manifest::parse(Path::new("/tmp/x"), &bad).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // when `make artifacts` has run, the shipped manifest must parse
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert_eq!(m.block, 32);
            assert!(m.artifacts.contains_key("spmm_block"));
        }
    }
}
