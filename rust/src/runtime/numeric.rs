//! NumericEngine: full-value SpMM through the accelerator path.
//!
//! CSR operands → 32×32 block pair plan (the coordinator-side comparator
//! work) → PJRT `spmm_block` dispatches (the MXU-side MAC work) → scattered
//! dense product. Cross-checked against `spmm::dense` by the integration
//! tests: this is the proof that all three layers compose.
//!
//! The PJRT backend is feature-gated (`pjrt`, see Cargo.toml): without it,
//! [`NumericEngine::pjrt`] returns an error and callers fall back to the
//! CPU plan executor, which runs the identical math. Registered in the
//! kernel registry via [`crate::engine::AccelKernel`].

use std::path::Path;

#[cfg(feature = "pjrt")]
use super::engine::Engine;
use crate::engine::ExecStats;
use crate::formats::csr::Csr;
use crate::formats::dense::Dense;
use crate::formats::traits::SparseMatrix;
use crate::spmm::plan::{plan, Geometry, Plan};

/// Execution backend selector (the CPU fallback keeps every code path
/// testable without artifacts and serves as the ablation baseline).
pub enum Backend {
    /// AOT Pallas kernels on the PJRT CPU client (`--features pjrt`).
    #[cfg(feature = "pjrt")]
    Pjrt(Box<Engine>),
    /// Pure-Rust execution of the same plan (identical math).
    Cpu(Geometry),
}

pub struct NumericEngine {
    backend: Backend,
}

impl NumericEngine {
    /// PJRT-backed engine from an artifact directory. Errors when the
    /// crate was built without the `pjrt` feature or the artifacts are
    /// missing/invalid.
    pub fn pjrt(dir: &Path) -> Result<NumericEngine, String> {
        #[cfg(feature = "pjrt")]
        {
            Ok(NumericEngine {
                backend: Backend::Pjrt(Box::new(Engine::load(dir)?)),
            })
        }
        #[cfg(not(feature = "pjrt"))]
        {
            Err(format!(
                "built without the `pjrt` feature: cannot load artifacts from {dir:?} \
                 (rebuild with `--features pjrt` and the vendored xla dependency)"
            ))
        }
    }

    /// CPU fallback with explicit geometry.
    pub fn cpu(geom: Geometry) -> NumericEngine {
        NumericEngine {
            backend: Backend::Cpu(geom),
        }
    }

    pub fn geometry(&self) -> Geometry {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => e.manifest.geometry(),
            Backend::Cpu(g) => *g,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
            Backend::Cpu(_) => "cpu",
        }
    }

    /// C = A × B with full values.
    pub fn spmm(&self, a: &Csr, b: &Csr) -> Result<(Dense, ExecStats), String> {
        let p = plan(a, b, self.geometry());
        self.execute_plan(&p)
    }

    /// C = A × B where `B` arrives pre-blockized (the `AccelKernel`
    /// prepared-operand path: the grid is built once per `B` and shared
    /// across jobs and shard workers, so only `A` is blockized here).
    pub fn spmm_blocked(
        &self,
        a: &Csr,
        gb: &crate::spmm::blocks::BlockGrid,
    ) -> Result<(Dense, ExecStats), String> {
        let geom = self.geometry();
        if gb.block != geom.block {
            return Err(format!(
                "B blockized at {} but the engine geometry block is {}",
                gb.block, geom.block
            ));
        }
        let p = crate::spmm::plan::plan_blocked(a, gb, geom);
        self.execute_plan(&p)
    }

    /// Execute a prebuilt plan (the coordinator pre-plans jobs off-thread).
    pub fn execute_plan(&self, p: &Plan) -> Result<(Dense, ExecStats), String> {
        let geom = self.geometry();
        let stats = ExecStats {
            dispatches: p.dispatches.len() as u64,
            real_pairs: p.total_pairs as u64,
            padded_pairs: (p.dispatches.len() * geom.pairs) as u64,
            macs_issued: (p.dispatches.len() * geom.pairs) as u64
                * (geom.block * geom.block * geom.block) as u64,
            threads: 1,
        };
        let c = match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => p.execute(|d| e.spmm_block(&d.seg, &d.a, &d.b))?,
            Backend::Cpu(_) => p.execute_cpu(),
        };
        Ok((c, stats))
    }

    /// Dense matmul via the `dense_mm` artifact (conventional-MM numeric
    /// twin). Operands must be `dense_dim × dense_dim`.
    pub fn dense_mm(&self, x: &Dense, y: &Dense) -> Result<Dense, String> {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => {
                let d = e.manifest.dense_dim;
                if x.shape() != (d, d) || y.shape() != (d, d) {
                    return Err(format!(
                        "dense_mm operands must be {d}x{d}, got {:?} and {:?}",
                        x.shape(),
                        y.shape()
                    ));
                }
                let out = e.dense_mm(&x.data, &y.data)?;
                Ok(Dense::new(d, d, out))
            }
            Backend::Cpu(_) => Ok(crate::spmm::dense::multiply_dense(x, y)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::spmm::dense::multiply as dense_ref;

    #[test]
    fn cpu_backend_matches_reference() {
        let eng = NumericEngine::cpu(Geometry { block: 8, pairs: 16, slots: 8 });
        let a = uniform(30, 40, 0.2, 1);
        let b = uniform(40, 22, 0.2, 2);
        let (c, stats) = eng.spmm(&a, &b).unwrap();
        let want = dense_ref(&a, &b);
        assert!(c.max_abs_diff(&want) < 1e-3);
        assert!(stats.dispatches > 0);
        assert!(stats.real_pairs <= stats.padded_pairs);
    }

    #[test]
    fn report_padding_accounting() {
        let eng = NumericEngine::cpu(Geometry { block: 8, pairs: 64, slots: 32 });
        let a = uniform(16, 16, 0.3, 3);
        let (_, stats) = eng.spmm(&a, &a.transpose()).unwrap();
        assert_eq!(stats.padded_pairs % 64, 0);
        assert_eq!(stats.macs_issued, stats.padded_pairs * (8 * 8 * 8) as u64);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_is_a_clean_error_without_the_feature() {
        let err = NumericEngine::pjrt(Path::new("/tmp/nope")).unwrap_err();
        assert!(err.contains("pjrt"), "{err}");
    }
}
