//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `make artifacts` and executes them from the Rust hot path. Python never
//! runs at serve time — the build-time contract is enforced through
//! [`artifact::Manifest`].

pub mod artifact;
pub mod engine;
pub mod numeric;

pub use artifact::Manifest;
pub use engine::Engine;
pub use numeric::{Backend, ExecReport, NumericEngine};
