//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `make artifacts` and executes them from the Rust hot path. Python never
//! runs at serve time — the build-time contract is enforced through
//! [`artifact::Manifest`].
//!
//! The PJRT path itself is feature-gated (`--features pjrt`, requires the
//! vendored `xla` bindings); the default build ships only the CPU twin of
//! the plan executor, which runs the same math. Either way, execution is
//! reached through the kernel registry via [`crate::engine::AccelKernel`].

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod numeric;

pub use artifact::Manifest;
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use numeric::{Backend, NumericEngine};

/// Execution accounting (kept as a re-export for older call sites; the
/// canonical type lives with the kernel contract).
pub use crate::engine::ExecStats as ExecReport;
