//! PJRT execution engine: load HLO-text artifacts, compile once per process
//! on the CPU PJRT client, execute from the L3 hot path.
//!
//! Only compiled with `--features pjrt` (requires the vendored `xla`
//! bindings — see Cargo.toml). Follows /opt/xla-example/load_hlo: HLO
//! *text* is the interchange format (xla_extension 0.5.1 rejects jax≥0.5
//! serialized protos), computations are lowered with `return_tuple=True` so
//! results unwrap with `to_tuple1()`.

use std::collections::BTreeMap;
use std::path::Path;

use super::artifact::Manifest;

fn err<E: std::fmt::Display>(ctx: &str) -> impl FnOnce(E) -> String + '_ {
    move |e| format!("{ctx}: {e}")
}

pub struct Engine {
    client: xla::PjRtClient,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
}

impl Engine {
    /// Load the manifest and compile every artifact. One-time cost at
    /// process start; execution afterwards is Python-free.
    pub fn load(dir: &Path) -> Result<Engine, String> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(err("create PJRT CPU client"))?;
        let mut exes = BTreeMap::new();
        for (name, entry) in &manifest.artifacts {
            let path = entry
                .file
                .to_str()
                .ok_or_else(|| format!("non-utf8 path {:?}", entry.file))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| format!("parse HLO text for {name}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| format!("compile {name}: {e}"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Engine {
            client,
            exes,
            manifest,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.exes.keys().map(|s| s.as_str()).collect()
    }

    fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal, String> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| format!("unknown artifact {name:?}"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(err("execute"))?[0][0]
            .to_literal_sync()
            .map_err(err("sync result"))?;
        // lowered with return_tuple=True: unwrap the 1-tuple
        result.to_tuple1().map_err(err("unwrap 1-tuple"))
    }

    /// Execute `spmm_block`: P sorted tile pairs -> T slot tiles
    /// (`slots × block × block` f32, flattened).
    pub fn spmm_block(&self, seg: &[i32], a: &[f32], b: &[f32]) -> Result<Vec<f32>, String> {
        let (p, bl, t) = (
            self.manifest.pairs,
            self.manifest.block,
            self.manifest.slots,
        );
        if seg.len() != p {
            return Err(format!("seg len {} != {p}", seg.len()));
        }
        if a.len() != p * bl * bl || b.len() != p * bl * bl {
            return Err(format!("operand lens {} / {}", a.len(), b.len()));
        }
        let dims = [p as i64, bl as i64, bl as i64];
        let seg_l = xla::Literal::vec1(seg);
        let a_l = xla::Literal::vec1(a).reshape(&dims).map_err(err("reshape a"))?;
        let b_l = xla::Literal::vec1(b).reshape(&dims).map_err(err("reshape b"))?;
        let out = self.run("spmm_block", &[seg_l, a_l, b_l])?;
        let v = out.to_vec::<f32>().map_err(err("read result"))?;
        if v.len() != t * bl * bl {
            return Err(format!("output len {}", v.len()));
        }
        Ok(v)
    }

    /// Execute `spmm_pairs`: P tile pairs -> P product tiles.
    pub fn spmm_pairs(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>, String> {
        let (p, bl) = (self.manifest.pairs, self.manifest.block);
        if a.len() != p * bl * bl || b.len() != p * bl * bl {
            return Err(format!("operand lens {} / {}", a.len(), b.len()));
        }
        let dims = [p as i64, bl as i64, bl as i64];
        let a_l = xla::Literal::vec1(a).reshape(&dims).map_err(err("reshape a"))?;
        let b_l = xla::Literal::vec1(b).reshape(&dims).map_err(err("reshape b"))?;
        let out = self.run("spmm_pairs", &[a_l, b_l])?;
        out.to_vec::<f32>().map_err(err("read result"))
    }

    /// Execute `dense_mm`: D×D × D×D -> D×D.
    pub fn dense_mm(&self, x: &[f32], y: &[f32]) -> Result<Vec<f32>, String> {
        let d = self.manifest.dense_dim;
        if x.len() != d * d || y.len() != d * d {
            return Err(format!("operand lens {} / {}", x.len(), y.len()));
        }
        let dims = [d as i64, d as i64];
        let x_l = xla::Literal::vec1(x).reshape(&dims).map_err(err("reshape x"))?;
        let y_l = xla::Literal::vec1(y).reshape(&dims).map_err(err("reshape y"))?;
        let out = self.run("dense_mm", &[x_l, y_l])?;
        out.to_vec::<f32>().map_err(err("read result"))
    }
}
