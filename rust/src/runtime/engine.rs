//! PJRT execution engine: load HLO-text artifacts, compile once per process
//! on the CPU PJRT client, execute from the L3 hot path.
//!
//! Follows /opt/xla-example/load_hlo: HLO *text* is the interchange format
//! (xla_extension 0.5.1 rejects jax≥0.5 serialized protos), computations
//! are lowered with `return_tuple=True` so results unwrap with
//! `to_tuple1()`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::artifact::Manifest;

pub struct Engine {
    client: xla::PjRtClient,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
}

impl Engine {
    /// Load the manifest and compile every artifact. One-time cost at
    /// process start; execution afterwards is Python-free.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut exes = BTreeMap::new();
        for (name, entry) in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                entry
                    .file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path {:?}", entry.file))?,
            )
            .with_context(|| format!("parse HLO text for {name}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {name}"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Engine {
            client,
            exes,
            manifest,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.exes.keys().map(|s| s.as_str()).collect()
    }

    fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // lowered with return_tuple=True: unwrap the 1-tuple
        Ok(result.to_tuple1()?)
    }

    /// Execute `spmm_block`: P sorted tile pairs -> T slot tiles
    /// (`slots × block × block` f32, flattened).
    pub fn spmm_block(&self, seg: &[i32], a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let (p, bl, t) = (
            self.manifest.pairs,
            self.manifest.block,
            self.manifest.slots,
        );
        anyhow::ensure!(seg.len() == p, "seg len {} != {p}", seg.len());
        anyhow::ensure!(a.len() == p * bl * bl, "a len {}", a.len());
        anyhow::ensure!(b.len() == p * bl * bl, "b len {}", b.len());
        let dims = [p as i64, bl as i64, bl as i64];
        let seg_l = xla::Literal::vec1(seg);
        let a_l = xla::Literal::vec1(a).reshape(&dims)?;
        let b_l = xla::Literal::vec1(b).reshape(&dims)?;
        let out = self.run("spmm_block", &[seg_l, a_l, b_l])?;
        let v = out.to_vec::<f32>()?;
        anyhow::ensure!(v.len() == t * bl * bl, "output len {}", v.len());
        Ok(v)
    }

    /// Execute `spmm_pairs`: P tile pairs -> P product tiles.
    pub fn spmm_pairs(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let (p, bl) = (self.manifest.pairs, self.manifest.block);
        anyhow::ensure!(a.len() == p * bl * bl && b.len() == p * bl * bl);
        let dims = [p as i64, bl as i64, bl as i64];
        let a_l = xla::Literal::vec1(a).reshape(&dims)?;
        let b_l = xla::Literal::vec1(b).reshape(&dims)?;
        let out = self.run("spmm_pairs", &[a_l, b_l])?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute `dense_mm`: D×D × D×D -> D×D.
    pub fn dense_mm(&self, x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        let d = self.manifest.dense_dim;
        anyhow::ensure!(x.len() == d * d && y.len() == d * d);
        let dims = [d as i64, d as i64];
        let x_l = xla::Literal::vec1(x).reshape(&dims)?;
        let y_l = xla::Literal::vec1(y).reshape(&dims)?;
        let out = self.run("dense_mm", &[x_l, y_l])?;
        Ok(out.to_vec::<f32>()?)
    }
}
