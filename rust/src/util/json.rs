//! Minimal JSON reader/writer (serde is unavailable offline — DESIGN.md §2).
//!
//! Supports the subset this repo produces/consumes: objects, arrays, strings,
//! numbers, booleans, null. Used to read `artifacts/manifest.json` and to
//! write experiment reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]...` chain that errors with the path on absence.
    pub fn at(&self, path: &[&str]) -> Result<&Json, String> {
        let mut cur = self;
        for (n, key) in path.iter().enumerate() {
            cur = cur
                .get(key)
                .ok_or_else(|| format!("missing key {:?}", &path[..=n]))?;
        }
        Ok(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    x.write(out, indent + 1);
                    out.push_str(if i + 1 < v.len() { ",\n" } else { "\n" });
                }
                let _ = write!(out, "{pad}]");
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, x)) in m.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    write_escaped(out, k);
                    out.push_str(": ");
                    x.write(out, indent + 1);
                    out.push_str(if i + 1 < m.len() { ",\n" } else { "\n" });
                }
                let _ = write!(out, "{pad}}}");
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl FromIterator<(String, Json)> for Json {
    fn from_iter<T: IntoIterator<Item = (String, Json)>>(it: T) -> Self {
        Json::Obj(it.into_iter().collect())
    }
}
impl FromIterator<Json> for Json {
    fn from_iter<T: IntoIterator<Item = Json>>(it: T) -> Self {
        Json::Arr(it.into_iter().collect())
    }
}

/// Convenience: build an object from `(&str, Json)` pairs.
pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("short \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"block": 32, "artifacts": {"m": {"file": "m.hlo.txt", "args": [{"shape": [128, 32, 32], "dtype": "float32"}]}}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".into())
        );
    }

    #[test]
    fn at_reports_path() {
        let j = Json::parse(r#"{"a": {"b": 1}}"#).unwrap();
        assert!(j.at(&["a", "x"]).unwrap_err().contains("x"));
        assert_eq!(j.at(&["a", "b"]).unwrap().as_f64(), Some(1.0));
    }
}
