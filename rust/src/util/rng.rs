//! Deterministic PRNG (xoshiro256**) — every dataset, test, and benchmark in
//! this repo is reproducible from a seed; no OS entropy anywhere.

/// xoshiro256** by Blackman & Vigna (public domain reference implementation).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so that small/consecutive seeds give unrelated
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller (one value; fine for non-hot paths).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct values from `[0, n)`, sorted ascending.
    ///
    /// Uses a bitmap-rejection strategy (O(k) expected when k << n, Floyd
    /// fallback when dense) — this is the dataset-generator hot loop.
    pub fn sample_sorted(&mut self, n: usize, k: usize, scratch: &mut Vec<u64>) -> Vec<u32> {
        assert!(k <= n, "sample {k} from {n}");
        if k * 2 >= n {
            // dense: shuffle a full index vector prefix
            let mut all: Vec<u32> = (0..n as u32).collect();
            for i in 0..k {
                let j = i + self.usize_below(n - i);
                all.swap(i, j);
            }
            let mut out = all[..k].to_vec();
            out.sort_unstable();
            return out;
        }
        let words = (n + 63) / 64;
        scratch.clear();
        scratch.resize(words, 0);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let v = self.usize_below(n);
            let (w, b) = (v / 64, v % 64);
            if scratch[w] >> b & 1 == 0 {
                scratch[w] |= 1 << b;
                out.push(v as u32);
            }
        }
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 10_000).abs() < 800, "{counts:?}");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_sorted_distinct_and_sorted() {
        let mut r = Rng::new(5);
        let mut scratch = Vec::new();
        for &(n, k) in &[(100usize, 5usize), (100, 60), (1000, 999), (10, 10), (1, 1)] {
            let s = r.sample_sorted(n, k, &mut scratch);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1], "not strictly sorted: {s:?}");
            }
            assert!(s.iter().all(|&v| (v as usize) < n));
        }
    }

    #[test]
    fn sample_sorted_zero() {
        let mut r = Rng::new(5);
        let mut scratch = Vec::new();
        assert!(r.sample_sorted(10, 0, &mut scratch).is_empty());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
