//! Minimal benchmarking support (criterion is unavailable offline —
//! DESIGN.md §2): warmup + N timed iterations, median/mean/min reporting,
//! and a black-box to stop the optimizer from deleting work.

use std::time::{Duration, Instant};

/// Prevent dead-code elimination of a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[derive(Clone, Copy, Debug)]
pub struct BenchResult {
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
}

impl BenchResult {
    /// items/second at the median iteration time.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median.as_secs_f64()
    }
}

/// Run `f` `iters` times (after `warmup` runs) and report timing stats.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed());
    }
    times.sort_unstable();
    let mean = times.iter().sum::<Duration>() / iters as u32;
    BenchResult {
        iters,
        median: times[iters / 2],
        mean,
        min: times[0],
    }
}

/// Print one bench line in a stable, grep-able format.
pub fn report(name: &str, r: BenchResult, items_per_iter: f64, unit: &str) {
    println!(
        "bench {name:<44} median {:>12?}  mean {:>12?}  {:>14.3e} {unit}/s",
        r.median,
        r.mean,
        r.throughput(items_per_iter)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let mut n = 0u64;
        let r = bench(2, 5, || {
            n += 1;
            black_box(n);
        });
        assert_eq!(n, 7);
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            iters: 1,
            median: Duration::from_millis(100),
            mean: Duration::from_millis(100),
            min: Duration::from_millis(100),
        };
        assert!((r.throughput(1000.0) - 10_000.0).abs() < 1e-6);
    }
}
