//! Tiny CLI argument parser (clap is unavailable offline — DESIGN.md §2).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional arguments,
//! with typed getters that report usable errors.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.entry(stripped.to_string()).or_default().push(v);
                } else {
                    out.flags.entry(stripped.to_string()).or_default().push(String::new());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str_opt(key).unwrap_or(default)
    }

    pub fn get<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.str_opt(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("--{key} {s:?}: {e}")),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get(key)?.unwrap_or(default))
    }

    /// Comma-separated list, e.g. `--sizes 16,32,64`.
    pub fn list<T: std::str::FromStr>(&self, key: &str) -> Result<Option<Vec<T>>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.str_opt(key) {
            None => Ok(None),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| p.parse::<T>().map_err(|e| format!("--{key} {p:?}: {e}")))
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        // note: a bare word after a flag is taken as that flag's value, so
        // positionals must precede flags or follow `--key=value` forms
        let a = parse(&["run", "extra", "--n", "64", "--verbose"]);
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.get::<usize>("n").unwrap(), Some(64));
        assert!(a.has("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--out=path/x.json", "--k=3"]);
        assert_eq!(a.str_opt("out"), Some("path/x.json"));
        assert_eq!(a.get_or::<u32>("k", 0).unwrap(), 3);
    }

    #[test]
    fn repeated_takes_last_value() {
        let a = parse(&["--n", "1", "--n", "2"]);
        assert_eq!(a.get::<usize>("n").unwrap(), Some(2));
    }

    #[test]
    fn bad_parse_reports_key() {
        let a = parse(&["--n", "abc"]);
        let err = a.get::<usize>("n").unwrap_err();
        assert!(err.contains("--n"), "{err}");
    }

    #[test]
    fn list_parse() {
        let a = parse(&["--sizes", "16,32,64"]);
        assert_eq!(a.list::<usize>("sizes").unwrap(), Some(vec![16, 32, 64]));
        assert_eq!(a.list::<usize>("absent").unwrap(), None);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--dry-run"]);
        assert!(a.has("dry-run"));
        assert_eq!(a.str_opt("dry-run"), Some(""));
    }
}
