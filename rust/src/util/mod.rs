//! Offline-friendly utilities: deterministic RNG, minimal JSON, CLI args,
//! a mini property-testing harness, and table rendering.
//!
//! These exist because the build environment resolves crates from a vendored
//! registry that contains only the `xla` crate's dependency closure
//! (DESIGN.md §2) — so rand/serde/clap/proptest are replaced by ~600 lines
//! of focused std-only code.

pub mod args;
pub mod bench;
pub mod json;
pub mod ptest;
pub mod rng;
pub mod sync;
pub mod tables;

pub use args::Args;
pub use sync::lock_unpoisoned;
pub use json::Json;
pub use rng::Rng;
pub use tables::Table;
