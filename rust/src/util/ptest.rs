//! Mini property-testing harness (proptest is unavailable offline —
//! DESIGN.md §2).
//!
//! `check(seed, cases, gen, prop)` runs `prop` on `cases` generated inputs;
//! on failure it re-generates the failing input, attempts the registered
//! shrink steps, and panics with the smallest reproducer plus the replay
//! seed. Deliberately tiny: inputs are generated from a [`Rng`] so every
//! failure is replayable from the printed case seed alone.

use super::rng::Rng;

/// Run `prop` over `cases` inputs produced by `gen`. Panics on first failure
/// after shrinking, printing the case seed for replay.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check_shrink(seed, cases, &mut gen, |_| Vec::new(), &mut prop)
}

/// Like [`check`], with a `shrink` hook that proposes smaller variants of a
/// failing input (tried breadth-first, greedily, up to 1000 steps).
pub fn check_shrink<T, G, S, P>(seed: u64, cases: usize, gen: &mut G, shrink: S, prop: &mut P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut best = input;
            let mut best_msg = first_msg;
            let mut budget = 1000usize;
            'outer: while budget > 0 {
                for cand in shrink(&best) {
                    budget -= 1;
                    if let Err(msg) = prop(&cand) {
                        best = cand;
                        best_msg = msg;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, case_seed {case_seed:#x}):\n  input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(
            1,
            50,
            |r| r.below(100),
            |&x| {
                n += 1;
                if x < 100 {
                    Ok(())
                } else {
                    Err("impossible".into())
                }
            },
        );
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(
            2,
            100,
            |r| r.below(1000),
            |&x| if x < 990 { Ok(()) } else { Err(format!("{x} too big")) },
        );
    }

    #[test]
    fn shrink_finds_smaller_reproducer() {
        let caught = std::panic::catch_unwind(|| {
            check_shrink(
                3,
                100,
                &mut |r| r.below(1000) + 500,
                |&x| if x > 0 { vec![x / 2, x - 1] } else { vec![] },
                &mut |&x| if x < 100 { Ok(()) } else { Err("big".into()) },
            );
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        // greedy halving from >=500 lands exactly at the boundary region
        assert!(msg.contains("input: 1") || msg.contains("input: 10"), "{msg}");
    }
}
