//! Synchronization policy shared across the crate.

use std::sync::{Mutex, MutexGuard};

/// The crate's single poisoned-lock policy: recover the guard and keep
/// serving.
///
/// A poisoned `Mutex` only records that *some* holder panicked while the
/// lock was held — it says nothing about the guarded data. Every
/// structure this crate guards with a `Mutex` (the workspace and
/// merge-buffer pool free lists, the kernel-observation ring, the
/// server's shared job receiver) stays structurally valid across a
/// holder's panic: the critical sections only push/pop whole elements or
/// receive from a channel, so the worst a panicking holder leaves behind
/// is a shorter free list or an un-recorded observation. Recovering via
/// `into_inner` is therefore sound here, and strictly better than the
/// failure modes it replaces — a server worker silently exiting, a pool
/// silently ceasing to pool, metrics silently dropping records.
///
/// Panicking *kernels* are a separate concern with a separate mechanism:
/// band/tile/shard workers are joined explicitly and surface as typed
/// `EngineError::ExecFailed`. This helper is the only place the crate
/// makes a lock-poisoning decision; new `Mutex` call sites should use it
/// (or justify why not).
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn recovers_the_guard_after_a_holder_panicked() {
        let m = Mutex::new(vec![1u32, 2]);
        // poison it: a thread panics while holding the lock
        let poisoner = std::thread::scope(|s| {
            s.spawn(|| {
                let _guard = m.lock().unwrap();
                panic!("holder dies");
            })
            .join()
        });
        assert!(poisoner.is_err(), "the holder should have panicked");
        assert!(m.lock().is_err(), "the mutex should be poisoned");
        let mut guard = lock_unpoisoned(&m);
        assert_eq!(*guard, vec![1, 2], "data survives the poison");
        guard.push(3);
        drop(guard);
        assert_eq!(*lock_unpoisoned(&m), vec![1, 2, 3]);
    }

    #[test]
    fn behaves_like_lock_on_a_healthy_mutex() {
        let m = Mutex::new(7u32);
        *lock_unpoisoned(&m) += 1;
        assert_eq!(*lock_unpoisoned(&m), 8);
    }
}
