//! Aligned table printer — every experiment driver reports its results as a
//! paper-style table through this.

/// A simple column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float with engineering-style precision (3 significant-ish digits).
pub fn sig(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let a = x.abs();
    if a >= 1e6 {
        format!("{:.3e}", x)
    } else if a >= 100.0 {
        format!("{:.0}", x)
    } else if a >= 10.0 {
        format!("{:.1}", x)
    } else if a >= 1.0 {
        format!("{:.2}", x)
    } else {
        format!("{:.3}", x)
    }
}

/// Human-readable large integer (e.g. 12_345_678 -> "12.3M").
pub fn human(x: u64) -> String {
    let x = x as f64;
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.1}k", x / 1e3)
    } else {
        format!("{}", x as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        let lines: Vec<&str> = r.lines().collect();
        // header + sep + 2 rows + title
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn sig_formats() {
        assert_eq!(sig(0.0), "0");
        assert_eq!(sig(3.14159), "3.14");
        assert_eq!(sig(42.123), "42.1");
        assert_eq!(sig(1234.6), "1235");
        assert_eq!(sig(0.001234), "0.001");
    }

    #[test]
    fn human_formats() {
        assert_eq!(human(999), "999");
        assert_eq!(human(12_345), "12.3k");
        assert_eq!(human(12_345_678), "12.35M");
        assert_eq!(human(2_500_000_000), "2.50G");
    }
}
