//! Lint findings and the aggregate report `cargo test --test repo_lint`
//! prints on failure.

use std::fmt;

/// One rule violation at one location.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Rule id: `D1`, `D2`, `P1`, `C1`, `A0`, or `IO`.
    pub rule: &'static str,
    /// Path relative to the crate (e.g. `src/engine/registry.rs`), or a
    /// logical location for cross-file findings.
    pub path: String,
    /// 1-indexed line, or 0 for findings without a line anchor.
    pub line: usize,
    /// What fired and why it matters.
    pub detail: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, w: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line == 0 {
            write!(w, "[{}] {} — {}", self.rule, self.path, self.detail)
        } else {
            write!(
                w,
                "[{}] {}:{} — {}",
                self.rule, self.path, self.line, self.detail
            )
        }
    }
}

/// The aggregate result of one lint run over the crate.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    /// Every surviving finding, sorted by (path, line, rule).
    pub findings: Vec<Finding>,
    /// Source files scanned under `src/`.
    pub files_scanned: usize,
    /// Total lines scanned.
    pub lines_scanned: usize,
    /// `lint: allow(...)` annotations that suppressed a finding.
    pub allows_used: usize,
    /// Individual cross-file consistency assertions performed (C1).
    pub consistency_checks: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }
}

impl fmt::Display for LintReport {
    fn fmt(&self, w: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            w,
            "detlint: {} finding(s) over {} files / {} lines \
             ({} allow(s) honored, {} consistency checks)",
            self.findings.len(),
            self.files_scanned,
            self.lines_scanned,
            self.allows_used,
            self.consistency_checks,
        )?;
        for f in &self.findings {
            writeln!(w, "  {f}")?;
        }
        if !self.findings.is_empty() {
            writeln!(
                w,
                "  fix the code, or annotate a genuinely-unreachable site with\n  \
                 `// lint: allow(<rule>) — <why>` (see README \"Correctness tooling\")"
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_rule_location_and_detail() {
        let f = Finding {
            rule: "P1",
            path: "src/engine/x.rs".into(),
            line: 42,
            detail: "`.unwrap()` in non-test engine code".into(),
        };
        let s = f.to_string();
        assert!(s.contains("[P1]"));
        assert!(s.contains("src/engine/x.rs:42"));
        let report = LintReport {
            findings: vec![f],
            files_scanned: 3,
            lines_scanned: 100,
            allows_used: 1,
            consistency_checks: 7,
        };
        assert!(!report.is_clean());
        let text = report.to_string();
        assert!(text.contains("1 finding(s)"));
        assert!(text.contains("lint: allow"));
        assert!(LintReport::default().is_clean());
    }
}
