//! A lightweight Rust source scanner for the repo lint (`detlint`).
//!
//! This is deliberately *not* a parser: the rules in [`super::rules`] only
//! need to know, per line, (a) what the code says once comments, string
//! literals, and char literals are blanked out, (b) whether the line is
//! inside a `#[cfg(test)] mod` region, and (c) whether a justifying
//! allow annotation covers it (see [`AllowEntry`]). A character-level
//! state machine provides exactly that, with no dependencies — the same
//! trade rust-lang's `tidy` makes.
//!
//! Handled Rust lexical structure: line comments, nested block comments,
//! string literals (with escapes), raw strings (`r"…"`, `r#"…"#`, any hash
//! depth), byte strings, char/byte-char literals, and the
//! lifetime-vs-char-literal ambiguity (`'a` in `&'a str` is a lifetime;
//! `'a'` is a literal).

/// One allow annotation parsed out of a comment: `lint: allow` followed
/// by a parenthesized rule list, a dash separator, and the justification.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AllowEntry {
    /// 1-indexed line the annotation sits on.
    pub line: usize,
    /// Rule ids the annotation names, e.g. `["P1"]`.
    pub rules: Vec<String>,
    /// Justification text after the rule list. Empty = unjustified (the
    /// lint reports it instead of honoring it).
    pub reason: String,
}

/// A scanned source file, ready for the rule engine.
#[derive(Clone, Debug)]
pub struct SourceFile {
    /// Path relative to `src/`, `/`-separated (e.g. `engine/registry.rs`).
    pub rel_path: String,
    /// Per-line code with comments/strings/chars blanked to spaces. Line
    /// structure (count and per-line column positions) is preserved.
    pub code: Vec<String>,
    /// Per-line flag: inside a `#[cfg(test)] mod … { … }` region.
    pub in_test: Vec<bool>,
    /// Every allow annotation in the file, in line order.
    pub allows: Vec<AllowEntry>,
}

impl SourceFile {
    /// Top-level module directory of this file (`engine` for
    /// `engine/registry.rs`), or `""` for files directly under `src/`
    /// (`lib.rs`, `main.rs`) — the scoping key the rules match on.
    pub fn top_module(&self) -> &str {
        match self.rel_path.split_once('/') {
            Some((top, _)) => top,
            None => "",
        }
    }
}

/// Scan one source file: blank non-code text, mark test regions, collect
/// allow annotations.
pub fn scan_source(rel_path: &str, src: &str) -> SourceFile {
    let (code_text, comment_text) = blank_non_code(src);
    let code: Vec<String> = code_text.split('\n').map(str::to_string).collect();
    let in_test = test_regions(&code);
    let mut allows = Vec::new();
    for (idx, comment_line) in comment_text.split('\n').enumerate() {
        if let Some(entry) = parse_allow(comment_line, idx + 1) {
            allows.push(entry);
        }
    }
    SourceFile {
        rel_path: rel_path.to_string(),
        code,
        in_test,
        allows,
    }
}

/// Lexer states for [`blank_non_code`].
#[derive(Clone, Copy)]
enum State {
    Code,
    LineComment,
    /// Block comment with its current nesting depth.
    BlockComment(u32),
    Str,
    /// Raw string with the hash count of its delimiter.
    RawStr(usize),
}

/// Replace comments, string/char literals with spaces in the first returned
/// string (the *code* view) and everything that is not comment text with
/// spaces in the second (the *comment* view). Newlines are preserved in
/// both, so line/column positions survive.
fn blank_non_code(src: &str) -> (String, String) {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut code = String::with_capacity(src.len());
    let mut comment = String::with_capacity(src.len());
    let mut state = State::Code;
    let mut i = 0usize;
    // push one char to both views, keeping newlines in sync
    let push = |code: &mut String, comment: &mut String, c: char, keep_code: bool, keep_comment: bool| {
        if c == '\n' {
            code.push('\n');
            comment.push('\n');
            return;
        }
        code.push(if keep_code { c } else { ' ' });
        comment.push(if keep_comment { c } else { ' ' });
    };
    while i < n {
        let c = chars[i];
        let next = chars.get(i + 1).copied();
        match state {
            State::Code => {
                if c == '/' && next == Some('/') {
                    state = State::LineComment;
                    push(&mut code, &mut comment, c, false, true);
                    push(&mut code, &mut comment, '/', false, true);
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(1);
                    push(&mut code, &mut comment, c, false, false);
                    push(&mut code, &mut comment, '*', false, false);
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    push(&mut code, &mut comment, c, false, false);
                    i += 1;
                } else if c == 'r' && matches!(next, Some('"') | Some('#')) {
                    // possible raw string r"…" / r#"…"# (any hash depth)
                    let mut j = i + 1;
                    let mut hashes = 0usize;
                    while j < n && chars[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && chars[j] == '"' {
                        state = State::RawStr(hashes);
                        for k in i..=j {
                            push(&mut code, &mut comment, chars[k], false, false);
                        }
                        i = j + 1;
                    } else {
                        // `r` was an ordinary identifier char (e.g. `r#raw` idents
                        // don't appear in this codebase; treat as code)
                        push(&mut code, &mut comment, c, true, false);
                        i += 1;
                    }
                } else if c == 'b' && next == Some('"') {
                    state = State::Str;
                    push(&mut code, &mut comment, c, false, false);
                    push(&mut code, &mut comment, '"', false, false);
                    i += 2;
                } else if c == '\'' {
                    // lifetime (`'a`) vs char literal (`'a'`, `'\n'`)
                    let c2 = chars.get(i + 1).copied();
                    let c3 = chars.get(i + 2).copied();
                    let lifetime = matches!(c2, Some(x) if x.is_alphabetic() || x == '_')
                        && c3 != Some('\'');
                    if lifetime {
                        push(&mut code, &mut comment, c, true, false);
                        i += 1;
                    } else {
                        // char literal: consume through the closing quote
                        let mut j = i + 1;
                        if chars.get(j) == Some(&'\\') {
                            j += 2;
                            while j < n && chars[j] != '\'' {
                                j += 1;
                            }
                        } else if j < n {
                            j += 1;
                        }
                        let end = (j + 1).min(n);
                        for k in i..end {
                            push(&mut code, &mut comment, chars[k], false, false);
                        }
                        i = end;
                    }
                } else {
                    push(&mut code, &mut comment, c, true, false);
                    i += 1;
                }
            }
            State::LineComment => {
                if c == '\n' {
                    state = State::Code;
                }
                push(&mut code, &mut comment, c, false, true);
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == '/' && next == Some('*') {
                    state = State::BlockComment(depth + 1);
                    push(&mut code, &mut comment, c, false, true);
                    push(&mut code, &mut comment, '*', false, true);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    push(&mut code, &mut comment, c, false, true);
                    push(&mut code, &mut comment, '/', false, true);
                    i += 2;
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                } else {
                    push(&mut code, &mut comment, c, false, true);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    push(&mut code, &mut comment, c, false, false);
                    if let Some(nx) = next {
                        push(&mut code, &mut comment, nx, false, false);
                    }
                    i += 2;
                } else {
                    if c == '"' {
                        state = State::Code;
                    }
                    push(&mut code, &mut comment, c, false, false);
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && chars[j] == '#' && h < hashes {
                        h += 1;
                        j += 1;
                    }
                    if h == hashes {
                        state = State::Code;
                        for k in i..j {
                            push(&mut code, &mut comment, chars[k], false, false);
                        }
                        i = j;
                        continue;
                    }
                }
                push(&mut code, &mut comment, c, false, false);
                i += 1;
            }
        }
    }
    (code, comment)
}

/// Mark every line inside a `#[cfg(test)] mod … { … }` region, tracked by
/// brace depth over the blanked code. A `#[cfg(test)]` attribute that is
/// *not* followed by a `mod` before the next item boundary (`;`) does not
/// open a region (e.g. a cfg-gated `use`).
fn test_regions(code: &[String]) -> Vec<bool> {
    let mut in_test = vec![false; code.len()];
    let mut depth = 0i64;
    let mut pending = false;
    let mut saw_mod = false;
    let mut test_depth: Option<i64> = None;
    for (ln, line) in code.iter().enumerate() {
        if line.contains("#[cfg(test)]") {
            pending = true;
            saw_mod = false;
        }
        if pending && super::rules::has_ident(line, "mod") {
            saw_mod = true;
        }
        for ch in line.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if pending && saw_mod && test_depth.is_none() {
                        test_depth = Some(depth);
                        pending = false;
                    }
                }
                '}' => {
                    if test_depth == Some(depth) {
                        test_depth = None;
                    }
                    depth -= 1;
                }
                ';' => {
                    if pending && !saw_mod {
                        pending = false;
                    }
                }
                _ => {}
            }
        }
        if test_depth.is_some() {
            in_test[ln] = true;
        }
    }
    in_test
}

/// Parse an allow annotation out of one line of comment text. The
/// separator between the rule list and the reason may be an em dash,
/// `--`, or `-`; the reason may be empty (which the lint then reports as
/// unjustified). The marker must open the comment (only whitespace and
/// comment sigils before it), so documentation *describing* the
/// annotation syntax mid-sentence never registers as one.
fn parse_allow(comment_line: &str, lineno: usize) -> Option<AllowEntry> {
    const MARKER: &str = "lint: allow(";
    let pos = comment_line.find(MARKER)?;
    if !comment_line[..pos]
        .chars()
        .all(|c| c.is_whitespace() || matches!(c, '/' | '!' | '*'))
    {
        return None;
    }
    let rest = &comment_line[pos + MARKER.len()..];
    let close = rest.find(')')?;
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    if rules.is_empty() {
        return None;
    }
    let reason = rest[close + 1..]
        .trim_start_matches(|c: char| c.is_whitespace() || c == '—' || c == '–' || c == '-')
        .trim()
        .to_string();
    Some(AllowEntry {
        line: lineno,
        rules,
        reason,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(src: &str) -> SourceFile {
        scan_source("engine/fake.rs", src)
    }

    #[test]
    fn strings_comments_and_chars_are_blanked() {
        let f = scan(concat!(
            "let s = \"HashMap in a string\"; // HashMap in a comment\n",
            "let c = 'x'; let l: &'a str = s; /* HashMap\nstill comment */\n",
            "let r = r#\"HashMap raw\"#;\n",
            "let real: usize = 1;\n",
        ));
        assert!(!f.code.iter().any(|l| l.contains("HashMap")));
        // code outside literals survives blanking
        assert!(f.code[3].contains("let real: usize = 1;"));
        // the lifetime tick did not open a char literal
        assert!(f.code[1].contains("str"));
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let f = scan("/* outer /* inner */ still-comment */ let x = 1;\n");
        assert!(!f.code[0].contains("still-comment"));
        assert!(f.code[0].contains("let x = 1;"));
    }

    #[test]
    fn cfg_test_regions_cover_the_module_body_only() {
        let f = scan(concat!(
            "fn live() { x.unwrap(); }\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn t() { x.unwrap(); }\n",
            "}\n",
            "fn live2() {}\n",
        ));
        assert_eq!(f.in_test, vec![false, false, true, true, true, false]);
    }

    #[test]
    fn cfg_test_on_a_non_mod_item_does_not_open_a_region() {
        let f = scan(concat!(
            "#[cfg(test)]\n",
            "use std::collections::BTreeMap;\n",
            "fn live() { let b: BTreeMap<u32, u32> = BTreeMap::new(); }\n",
        ));
        assert!(f.in_test.iter().all(|&t| !t));
    }

    #[test]
    fn allow_annotations_parse_rules_and_reason() {
        let f = scan(concat!(
            "// lint: allow(P1) — startup failure is unrecoverable\n",
            "x.expect(\"boom\");\n",
            "// lint: allow(D1, D2)\n",
        ));
        assert_eq!(f.allows.len(), 2);
        assert_eq!(f.allows[0].line, 1);
        assert_eq!(f.allows[0].rules, vec!["P1".to_string()]);
        assert_eq!(f.allows[0].reason, "startup failure is unrecoverable");
        assert_eq!(
            f.allows[1].rules,
            vec!["D1".to_string(), "D2".to_string()]
        );
        assert!(f.allows[1].reason.is_empty());
    }

    #[test]
    fn top_module_is_the_first_path_component() {
        assert_eq!(scan_source("engine/registry.rs", "").top_module(), "engine");
        assert_eq!(scan_source("lib.rs", "").top_module(), "");
        assert_eq!(
            scan_source("coordinator/server.rs", "").top_module(),
            "coordinator"
        );
    }
}
