//! The per-file lint rules enforcing the crate's determinism and
//! panic-safety contracts (see the module docs in [`super`] for the rule
//! catalogue and scopes).
//!
//! Matching is line-oriented over the scanner's blanked code view
//! ([`super::scan::SourceFile::code`]), so text inside comments and string
//! literals never fires a rule — including the pattern strings in this
//! very file.

use super::report::Finding;
use super::scan::SourceFile;

/// Modules where iteration order feeds numeric results or serving
/// decisions — rule **D1** bans unordered hash collections here outright
/// (test code included: a test asserting on hash order is still flaky).
const D1_SCOPE: &[&str] = &["spmm", "engine", "formats", "coordinator", "transport"];

/// Kernel modules where **D2** looks for accumulation-order hazards.
const D2_SCOPE: &[&str] = &["spmm", "engine"];

/// Serving-path modules where **P1** audits the non-test panic surface.
const P1_SCOPE: &[&str] = &["coordinator", "engine", "transport"];

/// Identifiers D1 rejects: the unordered-hash surface of `std`.
const D1_IDENTS: &[&str] = &["HashMap", "HashSet", "RandomState", "hash_map", "hash_set"];

/// Methods P1 rejects in non-test code (typed errors instead).
const P1_METHODS: &[&str] = &["unwrap", "expect"];

/// Macros P1 rejects in non-test code.
const P1_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Rule ids an allow annotation may name. (`C1` findings are cross-file
/// and have no single line to annotate, so they cannot be allowed away;
/// `A0` findings are about the annotations themselves.)
const ALLOWABLE: &[&str] = &["D1", "D2", "P1"];

fn is_ident_char(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Does `line` contain `word` as a standalone identifier (not as a
/// substring of a longer identifier)?
pub fn has_ident(line: &str, word: &str) -> bool {
    ident_positions(line, word).next().is_some()
}

/// Byte offsets of standalone-identifier occurrences of `word` in `line`.
fn ident_positions<'a>(line: &'a str, word: &'a str) -> impl Iterator<Item = usize> + 'a {
    let bytes = line.as_bytes();
    line.match_indices(word).filter_map(move |(i, _)| {
        let before_ok = i == 0 || !is_ident_char(bytes[i - 1]);
        let after = i + word.len();
        let after_ok = after >= bytes.len() || !is_ident_char(bytes[after]);
        (before_ok && after_ok).then_some(i)
    })
}

/// Does `line` contain a `.name(` method call (whitespace tolerated around
/// the dot and before the paren)?
fn method_call(line: &str, name: &str) -> bool {
    let bytes = line.as_bytes();
    ident_positions(line, name).any(|i| {
        let before_dot = bytes[..i]
            .iter()
            .rev()
            .find(|b| !b.is_ascii_whitespace())
            == Some(&b'.');
        let after_paren = bytes[i + name.len()..]
            .iter()
            .find(|b| !b.is_ascii_whitespace())
            == Some(&b'(');
        before_dot && after_paren
    })
}

/// Does `line` invoke the macro `name!`?
fn macro_call(line: &str, name: &str) -> bool {
    let bytes = line.as_bytes();
    ident_positions(line, name).any(|i| {
        bytes[i + name.len()..]
            .iter()
            .find(|b| !b.is_ascii_whitespace())
            == Some(&b'!')
    })
}

/// Does `line` call `name` with a float turbofish (`.sum::<f32>()`,
/// `.product::<f64>()`, …)? The whole-iterator float reductions share one
/// hazard: the accumulation order is the iterator's, not a documented one.
fn float_turbofish(line: &str, name: &str) -> bool {
    ident_positions(line, name).any(|i| {
        let rest: String = line[i + name.len()..]
            .chars()
            .filter(|c| !c.is_whitespace())
            .take(8)
            .collect();
        rest.starts_with("::<f32>") || rest.starts_with("::<f64>")
    })
}

/// Run every per-file rule over one scanned file. Returns the surviving
/// findings plus the number of allow annotations that were honored.
pub fn check_file(file: &SourceFile) -> (Vec<Finding>, usize) {
    let top = file.top_module();
    let d1 = D1_SCOPE.contains(&top);
    let d2 = D2_SCOPE.contains(&top);
    let p1 = P1_SCOPE.contains(&top);
    let display_path = format!("src/{}", file.rel_path);

    let mut raw: Vec<Finding> = Vec::new();
    for (idx, line) in file.code.iter().enumerate() {
        let lineno = idx + 1;
        if d1 {
            for &w in D1_IDENTS {
                if has_ident(line, w) {
                    raw.push(Finding {
                        rule: "D1",
                        path: display_path.clone(),
                        line: lineno,
                        detail: format!(
                            "`{w}` in determinism-critical module `{top}` — iteration \
                             order is unspecified; use BTreeMap/BTreeSet or index vectors"
                        ),
                    });
                }
            }
        }
        if d2 {
            if has_ident(line, "partial_cmp") {
                raw.push(Finding {
                    rule: "D2",
                    path: display_path.clone(),
                    line: lineno,
                    detail: "`partial_cmp` in a kernel module — NaN makes the order \
                             partial (and `.unwrap()` on it panics); use `f64::total_cmp` \
                             with explicit NaN policy"
                        .into(),
                });
            }
            for reduction in ["sum", "product"] {
                if float_turbofish(line, reduction) {
                    raw.push(Finding {
                        rule: "D2",
                        path: display_path.clone(),
                        line: lineno,
                        detail: format!(
                            "float `.{reduction}::<fN>()` in a kernel module — iterator \
                             reduction order is an accumulation-order hazard; fold in an \
                             explicit, documented order"
                        ),
                    });
                }
            }
            for folding in ["reduce", "scan"] {
                if method_call(line, folding)
                    && (line.contains("f32") || line.contains("f64"))
                {
                    raw.push(Finding {
                        rule: "D2",
                        path: display_path.clone(),
                        line: lineno,
                        detail: format!(
                            "`.{folding}(…)` near floats in a kernel module — the \
                             accumulation order is the iterator's, not a documented one; \
                             use an explicit indexed fold (or annotate the order)"
                        ),
                    });
                }
            }
            if has_ident(line, "sort_unstable")
                && (line.contains("f32") || line.contains("f64"))
            {
                raw.push(Finding {
                    rule: "D2",
                    path: display_path.clone(),
                    line: lineno,
                    detail: "`sort_unstable` near float keys in a kernel module — \
                             unstable order of equal keys reorders reductions; sort on \
                             integer keys or use a total order"
                        .into(),
                });
            }
        }
        if p1 && !file.in_test[idx] {
            for &m in P1_METHODS {
                if method_call(line, m) {
                    raw.push(Finding {
                        rule: "P1",
                        path: display_path.clone(),
                        line: lineno,
                        detail: format!(
                            "`.{m}(…)` in non-test `{top}` code — return a typed \
                             EngineError/JobError, or justify with \
                             `// lint: allow(P1) — <why>`"
                        ),
                    });
                }
            }
            for &m in P1_MACROS {
                if macro_call(line, m) {
                    raw.push(Finding {
                        rule: "P1",
                        path: display_path.clone(),
                        line: lineno,
                        detail: format!(
                            "`{m}!` in non-test `{top}` code — return a typed error, \
                             or justify with `// lint: allow(P1) — <why>`"
                        ),
                    });
                }
            }
        }
    }

    // Apply the allowlist: an annotation suppresses findings of its named
    // rules on its own line and the line below, but only when justified
    // (non-empty reason). Unused or unjustified annotations are findings
    // themselves (A0), so the allowlist can never silently rot.
    let mut used = vec![false; file.allows.len()];
    let mut findings: Vec<Finding> = Vec::new();
    for f in raw {
        let mut suppressed = false;
        for (ai, allow) in file.allows.iter().enumerate() {
            let covers = allow.line == f.line || allow.line + 1 == f.line;
            if covers && !allow.reason.is_empty() && allow.rules.iter().any(|r| r == f.rule) {
                suppressed = true;
                used[ai] = true;
            }
        }
        if !suppressed {
            findings.push(f);
        }
    }
    let mut allows_used = 0usize;
    for (ai, allow) in file.allows.iter().enumerate() {
        if allow.reason.is_empty() {
            findings.push(Finding {
                rule: "A0",
                path: display_path.clone(),
                line: allow.line,
                detail: format!(
                    "allow({}) without a justification — write \
                     `// lint: allow(<rule>) — <why>`",
                    allow.rules.join(",")
                ),
            });
        } else if let Some(bad) = allow.rules.iter().find(|r| !ALLOWABLE.contains(&r.as_str()))
        {
            findings.push(Finding {
                rule: "A0",
                path: display_path.clone(),
                line: allow.line,
                detail: format!("allow({bad}) names an unknown or non-allowable rule"),
            });
        } else if !used[ai] {
            findings.push(Finding {
                rule: "A0",
                path: display_path.clone(),
                line: allow.line,
                detail: format!(
                    "unused allow({}) — no finding on this or the next line; delete it",
                    allow.rules.join(",")
                ),
            });
        } else {
            allows_used += 1;
        }
    }
    (findings, allows_used)
}

#[cfg(test)]
mod tests {
    use super::super::scan::scan_source;
    use super::*;

    fn run(rel_path: &str, src: &str) -> Vec<Finding> {
        check_file(&scan_source(rel_path, src)).0
    }

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // --- D1: one positive + one negative fixture ---

    #[test]
    fn d1_fires_on_hash_collections_in_scope() {
        let found = run(
            "engine/fake.rs",
            "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, f32> = HashMap::new(); }\n",
        );
        assert!(rules_of(&found).contains(&"D1"), "{found:?}");
        // scoped: the same text outside a determinism-critical module is fine
        assert!(run("eval/fake.rs", "use std::collections::HashMap;\n").is_empty());
    }

    #[test]
    fn d1_ignores_ordered_collections_and_comment_mentions() {
        let clean = run(
            "formats/fake.rs",
            "use std::collections::BTreeMap; // HashMap considered and rejected\nfn f() { let m: BTreeMap<u32, f32> = BTreeMap::new(); }\n",
        );
        assert!(clean.is_empty(), "{clean:?}");
    }

    // --- D2: one positive + one negative fixture ---

    #[test]
    fn d2_fires_on_partial_cmp_and_float_sum() {
        let found = run(
            "spmm/fake.rs",
            "fn f(xs: &mut [f64]) { xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n",
        );
        assert!(rules_of(&found).contains(&"D2"), "{found:?}");
        let found = run(
            "spmm/fake.rs",
            "fn f(xs: &[f32]) -> f32 { xs.iter().sum::<f32>() }\n",
        );
        assert!(rules_of(&found).contains(&"D2"), "{found:?}");
        let found = run(
            "engine/fake.rs",
            "fn f(xs: &mut [(f64, u32)]) { xs.sort_unstable(); }\n",
        );
        assert!(rules_of(&found).contains(&"D2"), "{found:?}");
    }

    #[test]
    fn d2_accepts_total_cmp_and_integer_sums() {
        let clean = run(
            "spmm/fake.rs",
            "fn f(xs: &mut [f64]) -> usize { xs.sort_by(f64::total_cmp); \
             [1usize, 2].iter().sum::<usize>() }\n",
        );
        assert!(clean.is_empty(), "{clean:?}");
    }

    #[test]
    fn d2_fires_on_float_product_reduce_and_scan() {
        let found = run(
            "spmm/fake.rs",
            "fn f(xs: &[f64]) -> f64 { xs.iter().product::<f64>() }\n",
        );
        assert!(rules_of(&found).contains(&"D2"), "{found:?}");
        let found = run(
            "engine/fake.rs",
            "fn f(xs: &[f32]) -> Option<f32> { xs.iter().copied().reduce(|a, b| a + b) }\n",
        );
        assert!(rules_of(&found).contains(&"D2"), "{found:?}");
        let found = run(
            "engine/fake.rs",
            "fn f(xs: &[f64]) { let _ = xs.iter().scan(0.0f64, |s, x| { *s += x; Some(*s) }); }\n",
        );
        assert!(rules_of(&found).contains(&"D2"), "{found:?}");
    }

    #[test]
    fn d2_accepts_integer_reductions_and_explicit_folds() {
        // integer product / reduce on integer lines carry no float hazard
        let clean = run(
            "spmm/fake.rs",
            "fn f(xs: &[usize]) -> usize { xs.iter().product::<usize>() }\n",
        );
        assert!(clean.is_empty(), "{clean:?}");
        let clean = run(
            "engine/fake.rs",
            "fn f(xs: &[u32]) -> Option<u32> { xs.iter().copied().reduce(|a, b| a.max(b)) }\n",
        );
        assert!(clean.is_empty(), "{clean:?}");
        // fold is the sanctioned idiom: the closure states the order
        let clean = run(
            "spmm/fake.rs",
            "fn f(xs: &[f64]) -> f64 { xs.iter().fold(0.0f64, |acc, x| acc + x) }\n",
        );
        assert!(clean.is_empty(), "{clean:?}");
    }

    // --- P1: one positive + one negative fixture ---

    #[test]
    fn p1_fires_on_unwrap_expect_and_panic_macros_outside_tests() {
        let found = run(
            "coordinator/fake.rs",
            "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n",
        );
        assert!(rules_of(&found).contains(&"P1"), "{found:?}");
        let found = run("engine/fake.rs", "fn f() { panic!(\"boom\"); }\n");
        assert!(rules_of(&found).contains(&"P1"), "{found:?}");
        // scoped: spmm algorithm bodies are not part of the serving panic audit
        assert!(run("spmm/fake.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n").is_empty());
    }

    #[test]
    fn p1_skips_test_modules_and_non_panicking_lookalikes() {
        let clean = run(
            "coordinator/fake.rs",
            concat!(
                "fn f(x: Option<u32>) -> u32 { x.unwrap_or_default() }\n",
                "#[cfg(test)]\n",
                "mod tests {\n",
                "    #[test]\n",
                "    fn t() { Some(1u32).unwrap(); panic!(\"fine in tests\"); }\n",
                "}\n",
            ),
        );
        assert!(clean.is_empty(), "{clean:?}");
    }

    // --- A0 + suppression: positive + negative fixtures ---

    #[test]
    fn justified_allow_suppresses_and_counts_as_used() {
        let (found, used) = check_file(&scan_source(
            "coordinator/fake.rs",
            "// lint: allow(P1) — startup spawn failure is unrecoverable\nlet t = b.spawn(f).expect(\"spawn\");\n",
        ));
        assert!(found.is_empty(), "{found:?}");
        assert_eq!(used, 1);
    }

    #[test]
    fn a0_fires_on_unjustified_unused_or_unknown_allows() {
        // no reason: the finding survives AND the annotation is reported
        let found = run(
            "coordinator/fake.rs",
            "// lint: allow(P1)\nlet t = b.spawn(f).expect(\"spawn\");\n",
        );
        assert_eq!(rules_of(&found), vec!["P1", "A0"], "{found:?}");
        // nothing to suppress: unused annotation is reported
        let found = run(
            "coordinator/fake.rs",
            "// lint: allow(P1) — stale justification\nlet x = 1;\n",
        );
        assert_eq!(rules_of(&found), vec!["A0"], "{found:?}");
        // unknown rule id
        let found = run("engine/fake.rs", "// lint: allow(Z9) — nonsense\n");
        assert_eq!(rules_of(&found), vec!["A0"], "{found:?}");
    }

    #[test]
    fn matching_is_identifier_exact() {
        // `HashMapLike` / `my_unwrap` must not fire
        assert!(run("engine/fake.rs", "struct HashMapLike;\n").is_empty());
        assert!(run("engine/fake.rs", "fn f() { my_unwrap(); }\n").is_empty());
        // field access without a call is not a method call
        assert!(run("engine/fake.rs", "let u = s.unwrap;\n").is_empty());
    }
}
