//! `detlint` — the repo's dependency-free static-analysis pass.
//!
//! The crate's north-star contract (ROADMAP) is that every kernel is
//! **bit-identical** to scalar Gustavson at any worker/shard/fan-in count,
//! and that the serving layer fails with **typed errors**, never panics.
//! The property suites sample that contract; this pass enforces the coding
//! discipline that makes it hold *by construction*, the way rust-lang's
//! `tidy` enforces repo policy — no external deps, runs as
//! `cargo test --test repo_lint`.
//!
//! ## Rules
//!
//! | rule | scope | what it rejects |
//! |---|---|---|
//! | **D1** | `spmm`, `engine`, `formats`, `coordinator`, `transport` | `HashMap`/`HashSet`/`RandomState` — unspecified iteration order feeding numeric results or serving decisions; use `BTreeMap`/`BTreeSet` or index vectors |
//! | **D2** | `spmm`, `engine` | accumulation-order hazards: `partial_cmp` (NaN makes the order partial), float `.sum::<fN>()`/`.product::<fN>()` turbofish, `.reduce(…)`/`.scan(…)` near floats, `sort_unstable` near float keys (`fold` with an explicit order is the sanctioned idiom) |
//! | **P1** | `coordinator`, `engine`, `transport` (non-test code) | `.unwrap()` / `.expect()` / `panic!` / `unreachable!` / `todo!` / `unimplemented!` — the serving path returns typed `EngineError`/`JobError` |
//! | **C1** | cross-file | a kernel registered in `Registry::with_default_kernels` that the `prop_engine` all-kernels suite, the README Backends table, or the CLI (`kernels` listing + `--kernel` help) doesn't cover; a `PreparedB` variant without a wire-format arm in `engine/transport/wire.rs`; a `JobError` variant without a row in the README error table |
//! | **A0** | everywhere | allowlist hygiene: unused or unjustified `lint: allow` annotations |
//!
//! A genuinely-unreachable panic site is annotated in place — a comment
//! on the offending line or the line above, reading `lint: allow` with
//! the rule id in parentheses, then a dash and the justification (see the
//! README "Correctness tooling" section for a literal example). The
//! justification is mandatory and the annotation must keep matching a
//! finding — otherwise rule **A0** reports the annotation itself, so the
//! allowlist can never silently rot.
//!
//! The static pass is paired with a runtime layer: the core formats expose
//! `validate_invariants()` (monotone index pointers, strictly-sorted
//! in-bounds indices, nnz consistency), asserted at engine boundaries via
//! [`crate::formats::strict_check`] under the `strict-invariants` feature
//! (CI runs the full suite with it on).

pub mod consistency;
pub mod report;
pub mod rules;
pub mod scan;

pub use report::{Finding, LintReport};

use std::fs;
use std::path::{Path, PathBuf};

/// Run the full lint over a crate rooted at `crate_root` (the directory
/// holding `Cargo.toml` and `src/`): every per-file rule over `src/**/*.rs`
/// plus the cross-file consistency checks. I/O problems surface as `IO`
/// findings rather than panics, so the lint itself honors rule P1's
/// spirit.
pub fn run_repo_lint(crate_root: &Path) -> LintReport {
    let src_root = crate_root.join("src");
    let mut files: Vec<PathBuf> = Vec::new();
    let mut report = LintReport::default();
    collect_rs_files(&src_root, &mut files, &mut report);
    files.sort();

    for path in &files {
        let rel = path
            .strip_prefix(&src_root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = match fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                report.findings.push(Finding {
                    rule: "IO",
                    path: format!("src/{rel}"),
                    line: 0,
                    detail: format!("unreadable source file: {e}"),
                });
                continue;
            }
        };
        let scanned = scan::scan_source(&rel, &src);
        report.files_scanned += 1;
        report.lines_scanned += scanned.code.len();
        let (findings, used) = rules::check_file(&scanned);
        report.findings.extend(findings);
        report.allows_used += used;
    }

    // Cross-file consistency: a missing input is itself a finding (the
    // checks would silently weaken if the files moved).
    let read = |rel: &str, report: &mut LintReport| -> String {
        let path = crate_root.join(rel);
        match fs::read_to_string(&path) {
            Ok(s) => s,
            Err(e) => {
                report.findings.push(Finding {
                    rule: "IO",
                    path: rel.to_string(),
                    line: 0,
                    detail: format!("consistency input unreadable: {e}"),
                });
                String::new()
            }
        }
    };
    let kernel_src = read("src/engine/kernel.rs", &mut report);
    let registry_src = read("src/engine/registry.rs", &mut report);
    let prop_engine_src = read("tests/prop_engine.rs", &mut report);
    let readme_src = read("../README.md", &mut report);
    let main_src = read("src/main.rs", &mut report);
    let wire_src = read("src/engine/transport/wire.rs", &mut report);
    let error_src = read("src/coordinator/error.rs", &mut report);
    let (findings, checks) = consistency::check(&consistency::ConsistencyInput {
        kernel_src: &kernel_src,
        registry_src: &registry_src,
        prop_engine_src: &prop_engine_src,
        readme_src: &readme_src,
        main_src: &main_src,
        wire_src: &wire_src,
        error_src: &error_src,
    });
    report.findings.extend(findings);
    report.consistency_checks = checks;

    report
        .findings
        .sort_by(|a, b| (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule)));
    report
}

/// Depth-first collection of `.rs` files; unreadable directories surface
/// as `IO` findings.
fn collect_rs_files(dir: &Path, files: &mut Vec<PathBuf>, report: &mut LintReport) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) => {
            report.findings.push(Finding {
                rule: "IO",
                path: dir.to_string_lossy().into_owned(),
                line: 0,
                detail: format!("unreadable directory: {e}"),
            });
            return;
        }
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, files, report);
        } else if path.extension().is_some_and(|e| e == "rs") {
            files.push(path);
        }
    }
}
