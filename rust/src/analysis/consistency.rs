//! Rule **C1** — cross-file consistency between the kernel registry, the
//! all-kernels property suite, the README Backends table, and the CLI.
//!
//! The contract: every `Algorithm` variant and every kernel registered by
//! `Registry::with_default_kernels` is (a) exercised by
//! `tests/prop_engine.rs` (whose registry-size assertion must keep up with
//! the default kernel count), (b) documented in the README `## Backends`
//! table under its `Algorithm::name()` string, and (c) reachable from the
//! CLI — `src/main.rs` keeps a `kernels` listing that walks the registry
//! and mentions every algorithm name in its `--kernel` help. Additionally,
//! every `PreparedB` variant must have a wire-format arm in
//! `src/engine/transport/wire.rs` — a prepared representation the socket
//! transport cannot ship would make remote sharding silently partial —
//! and every `JobError` variant must have a row in the README error table
//! (`| \`Variant\` |`), so a new failure mode is documented the moment it
//! exists. A new kernel (or error) that skips the suite, the docs, the
//! CLI, or the wire format fails `cargo test --test repo_lint`.
//!
//! The checks are pure functions over file contents so the fixtures in the
//! test module can prove each one fires; [`super::run_repo_lint`] feeds
//! them the real files.

use super::report::Finding;
use super::scan::scan_source;

/// The file contents C1 cross-references.
pub struct ConsistencyInput<'a> {
    /// `src/engine/kernel.rs` (declares `Algorithm` and its `name()` map).
    pub kernel_src: &'a str,
    /// `src/engine/registry.rs` (declares `with_default_kernels`).
    pub registry_src: &'a str,
    /// `tests/prop_engine.rs` (the all-kernels bit-identity suite).
    pub prop_engine_src: &'a str,
    /// The repo `README.md` (the `## Backends` table).
    pub readme_src: &'a str,
    /// `src/main.rs` (the CLI: the `kernels` listing and `--kernel` help).
    pub main_src: &'a str,
    /// `src/engine/transport/wire.rs` (the serialization arms for every
    /// `PreparedB` variant).
    pub wire_src: &'a str,
    /// `src/coordinator/error.rs` (declares `JobError`, the serving
    /// layer's complete failure surface).
    pub error_src: &'a str,
}

/// Run every cross-file check. Returns the findings plus the number of
/// individual assertions performed (so the lint harness can prove the
/// layer actually ran).
pub fn check(input: &ConsistencyInput<'_>) -> (Vec<Finding>, usize) {
    let mut findings = Vec::new();
    let mut checks = 0usize;

    let variants = algorithm_variants(input.kernel_src);
    if variants.is_empty() {
        findings.push(Finding {
            rule: "C1",
            path: "src/engine/kernel.rs".into(),
            line: 0,
            detail: "could not locate `pub enum Algorithm` — the consistency \
                     pass needs updating"
                .into(),
        });
        return (findings, checks);
    }
    let names = algorithm_names(input.kernel_src);

    // (a) every variant has a name() string
    for v in &variants {
        checks += 1;
        if !names.iter().any(|(var, _)| var == v) {
            findings.push(Finding {
                rule: "C1",
                path: "src/engine/kernel.rs".into(),
                line: 0,
                detail: format!("Algorithm::{v} has no `name()` string mapping"),
            });
        }
    }

    // (b) every variant appears in the all-kernels property suite
    for v in &variants {
        checks += 1;
        if !input.prop_engine_src.contains(&format!("Algorithm::{v}")) {
            findings.push(Finding {
                rule: "C1",
                path: "tests/prop_engine.rs".into(),
                line: 0,
                detail: format!(
                    "Algorithm::{v} is registered but never referenced by the \
                     all-kernels suite — add it to the contracted-kernels list"
                ),
            });
        }
    }

    // (c) every algorithm name string appears in the README Backends table
    match backends_section(input.readme_src) {
        None => findings.push(Finding {
            rule: "C1",
            path: "README.md".into(),
            line: 0,
            detail: "no `## Backends` section found".into(),
        }),
        Some(section) => {
            for (v, name) in &names {
                checks += 1;
                if !section.contains(&format!(", {name})")) {
                    findings.push(Finding {
                        rule: "C1",
                        path: "README.md".into(),
                        line: 0,
                        detail: format!(
                            "Algorithm::{v} (`{name}`) missing from the \
                             `## Backends` table — document the new kernel"
                        ),
                    });
                }
            }
        }
    }

    // (d) the CLI's `kernels` listing actually walks the registry, so a
    // registered kernel can never be invisible from the command line
    checks += 1;
    if !(input.main_src.contains("\"kernels\"") && input.main_src.contains(".kernels()")) {
        findings.push(Finding {
            rule: "C1",
            path: "src/main.rs".into(),
            line: 0,
            detail: "no `kernels` subcommand iterating `Registry::kernels()` — the \
                     CLI listing no longer reflects the registry"
                .into(),
        });
    }

    // (e) every algorithm name is spellable from the CLI help
    for (v, name) in &names {
        checks += 1;
        if !input.main_src.contains(name.as_str()) {
            findings.push(Finding {
                rule: "C1",
                path: "src/main.rs".into(),
                line: 0,
                detail: format!(
                    "Algorithm::{v} (`{name}`) is never mentioned in the CLI — add it \
                     to the `--kernel` algorithms line in the help text"
                ),
            });
        }
    }

    // (f) the suite's registry-size floor keeps up with the default set
    let registered = default_register_count(input.registry_src);
    checks += 1;
    match prop_engine_len_floor(input.prop_engine_src) {
        None => findings.push(Finding {
            rule: "C1",
            path: "tests/prop_engine.rs".into(),
            line: 0,
            detail: "no `registry.len() >= N` assertion found — the all-kernels \
                     suite no longer guards the default kernel count"
                .into(),
        }),
        Some(floor) if floor < registered => findings.push(Finding {
            rule: "C1",
            path: "tests/prop_engine.rs".into(),
            line: 0,
            detail: format!(
                "`registry.len() >= {floor}` lags `with_default_kernels` \
                 ({registered} kernels registered) — raise the floor so a \
                 dropped kernel fails the suite"
            ),
        }),
        Some(_) => {}
    }

    // (g) every `PreparedB` variant has a wire-format arm, so the socket
    // transport can ship whatever any kernel's prepare produced
    let prepared = prepared_variants(input.kernel_src);
    if prepared.is_empty() {
        findings.push(Finding {
            rule: "C1",
            path: "src/engine/kernel.rs".into(),
            line: 0,
            detail: "could not locate `pub enum PreparedB` — the consistency \
                     pass needs updating"
                .into(),
        });
    }
    for v in &prepared {
        checks += 1;
        if !input.wire_src.contains(&format!("PreparedB::{v}")) {
            findings.push(Finding {
                rule: "C1",
                path: "src/engine/transport/wire.rs".into(),
                line: 0,
                detail: format!(
                    "PreparedB::{v} has no wire-format arm — remote shard \
                     workers cannot receive this prepared representation"
                ),
            });
        }
    }

    // (h) every `JobError` variant has a row in the README error table, so
    // the documented failure surface can never lag the typed one
    let errors = job_error_variants(input.error_src);
    if errors.is_empty() {
        findings.push(Finding {
            rule: "C1",
            path: "src/coordinator/error.rs".into(),
            line: 0,
            detail: "could not locate `pub enum JobError` — the consistency \
                     pass needs updating"
                .into(),
        });
    }
    for v in &errors {
        checks += 1;
        if !input.readme_src.contains(&format!("| `{v}`")) {
            findings.push(Finding {
                rule: "C1",
                path: "README.md".into(),
                line: 0,
                detail: format!(
                    "JobError::{v} missing from the README error table — add \
                     a row documenting when callers see it"
                ),
            });
        }
    }

    (findings, checks)
}

/// Variant names of `pub enum JobError` (unit, tuple, or struct-shaped),
/// parsed from the blanked code view with brace-depth tracking so a
/// struct variant's fields are never mistaken for variants.
fn job_error_variants(error_src: &str) -> Vec<String> {
    let file = scan_source("coordinator/error.rs", error_src);
    let mut variants = Vec::new();
    let mut inside = false;
    let mut depth = 0i32;
    for line in &file.code {
        if !inside {
            if line.contains("pub enum JobError") {
                inside = true;
                for c in line.chars() {
                    match c {
                        '{' => depth += 1,
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
            }
            continue;
        }
        if depth == 1 {
            let ident: String = line
                .trim()
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric())
                .collect();
            if !ident.is_empty()
                && ident.chars().next().is_some_and(|c| c.is_ascii_uppercase())
            {
                variants.push(ident);
            }
        }
        for c in line.chars() {
            match c {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
        }
        if depth == 0 {
            break;
        }
    }
    variants
}

/// Variant names of `pub enum PreparedB` (tuple variants: the identifier
/// before the `(`), parsed from the blanked code view.
fn prepared_variants(kernel_src: &str) -> Vec<String> {
    let file = scan_source("engine/kernel.rs", kernel_src);
    let mut variants = Vec::new();
    let mut inside = false;
    for line in &file.code {
        if line.contains("pub enum PreparedB") {
            inside = true;
            continue;
        }
        if inside {
            let t = line.trim();
            if t.starts_with('}') {
                break;
            }
            let ident: String = t
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric())
                .collect();
            if !ident.is_empty()
                && ident.chars().next().is_some_and(|c| c.is_ascii_uppercase())
            {
                variants.push(ident);
            }
        }
    }
    variants
}

/// Unit-variant names of `pub enum Algorithm`, parsed from the blanked
/// code view (doc comments with braces can't break the depth tracking).
fn algorithm_variants(kernel_src: &str) -> Vec<String> {
    let file = scan_source("engine/kernel.rs", kernel_src);
    let mut variants = Vec::new();
    let mut inside = false;
    for line in &file.code {
        if line.contains("pub enum Algorithm") {
            inside = true;
            continue;
        }
        if inside {
            let t = line.trim();
            if t.starts_with('}') {
                break;
            }
            let ident = t.trim_end_matches(',');
            if !ident.is_empty()
                && ident.chars().next().is_some_and(|c| c.is_ascii_uppercase())
                && ident.chars().all(|c| c.is_ascii_alphanumeric())
            {
                variants.push(ident.to_string());
            }
        }
    }
    variants
}

/// `(variant, name-string)` pairs from lines shaped `Algorithm::X => "y"`
/// (the body of `Algorithm::name`).
fn algorithm_names(kernel_src: &str) -> Vec<(String, String)> {
    let mut pairs = Vec::new();
    for line in kernel_src.lines() {
        let Some(pos) = line.find("Algorithm::") else {
            continue;
        };
        let rest = &line[pos + "Algorithm::".len()..];
        let variant: String = rest
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric())
            .collect();
        let Some(arrow) = rest.find("=> \"") else {
            continue;
        };
        let after = &rest[arrow + 4..];
        let Some(close) = after.find('"') else {
            continue;
        };
        if !variant.is_empty() {
            pairs.push((variant, after[..close].to_string()));
        }
    }
    pairs.sort();
    pairs.dedup();
    pairs
}

/// The README text between `## Backends` and the next `## ` heading.
fn backends_section(readme: &str) -> Option<&str> {
    let start = readme.find("## Backends")?;
    let rest = &readme[start..];
    match rest[2..].find("\n## ") {
        Some(end) => Some(&rest[..end + 2]),
        None => Some(rest),
    }
}

/// Number of `r.register(` calls inside `with_default_kernels`.
fn default_register_count(registry_src: &str) -> usize {
    let Some(start) = registry_src.find("fn with_default_kernels") else {
        return 0;
    };
    let body = &registry_src[start..];
    let end = body.find("\n    }").map(|e| e + 1).unwrap_or(body.len());
    body[..end].matches("r.register(").count()
}

/// `N` from the suite's `registry.len() >= N` assertion.
fn prop_engine_len_floor(prop_engine_src: &str) -> Option<usize> {
    let pos = prop_engine_src.find("registry.len() >= ")?;
    let after = &prop_engine_src[pos + "registry.len() >= ".len()..];
    let digits: String = after.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    const KERNEL_FIXTURE: &str = r#"
/// Which algorithm a kernel implements.
pub enum Algorithm {
    /// The oracle { braces in doc comments are fine }.
    Dense,
    Gustavson,
}

impl Algorithm {
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Dense => "dense",
            Algorithm::Gustavson => "gustavson",
        }
    }
}

/// A kernel's prepared B-operand { braces again }.
pub enum PreparedB {
    /// Plain CSR share.
    Csr(Arc<Csr>),
    Blocked(Arc<BlockedB>),
}
"#;

    const WIRE_FIXTURE: &str = "
    match prepared {
        PreparedB::Csr(m) => put_csr(w, m),
        PreparedB::Blocked(bb) => put_blocked(w, bb),
    }
";

    const REGISTRY_FIXTURE: &str = "
    pub fn with_default_kernels() -> Registry {
        let mut r = Registry::new();
        r.register(Arc::new(DenseOracleKernel));
        r.register(Arc::new(GustavsonKernel));
        r
    }
";

    const MAIN_FIXTURE: &str = "
    match cmd {
        \"kernels\" => {
            for k in reg.kernels() { println!(\"{}\", k.name()); }
        }
        _ => println!(\"algorithms (--kernel): dense | gustavson\"),
    }
";

    const ERROR_FIXTURE: &str = "
/// What went wrong with a submitted job.
pub enum JobError {
    QueueFull,
    Overloaded {
        /// How long the caller should wait before retrying.
        retry_after: Duration,
    },
}

impl JobError {
    pub fn is_transient(&self) -> bool {
        matches!(self, JobError::QueueFull | JobError::Overloaded { .. })
    }
}
";

    fn input<'a>(prop_engine: &'a str, readme: &'a str) -> ConsistencyInput<'a> {
        input_with_main(prop_engine, readme, MAIN_FIXTURE)
    }

    fn input_with_main<'a>(
        prop_engine: &'a str,
        readme: &'a str,
        main_src: &'a str,
    ) -> ConsistencyInput<'a> {
        ConsistencyInput {
            kernel_src: KERNEL_FIXTURE,
            registry_src: REGISTRY_FIXTURE,
            prop_engine_src: prop_engine,
            readme_src: readme,
            main_src,
            wire_src: WIRE_FIXTURE,
            error_src: ERROR_FIXTURE,
        }
    }

    const GOOD_PROP: &str =
        "assert!(registry.len() >= 2); Algorithm::Dense; Algorithm::Gustavson;";
    const GOOD_README: &str = "## Backends\n| `(dense, dense)` | x |\n\
         | `(crs, gustavson)` | y |\n\n## Errors\n| `QueueFull` | bounded |\n\
         | `Overloaded` | shed |\n\n## Next\n";

    #[test]
    fn clean_inputs_produce_no_findings_and_count_checks() {
        let (findings, checks) = check(&input(GOOD_PROP, GOOD_README));
        assert!(findings.is_empty(), "{findings:?}");
        // 2 name checks + 2 suite checks + 2 readme checks + 1 CLI-listing
        // check + 2 CLI-name checks + 1 floor check + 2 wire-arm checks
        // + 2 error-table checks
        assert_eq!(checks, 14);
    }

    #[test]
    fn missing_error_table_row_fires() {
        let readme = "## Backends\n| `(dense, dense)` | x |\n\
             | `(crs, gustavson)` | y |\n\n## Errors\n| `QueueFull` | bounded |\n";
        let (findings, _) = check(&input(GOOD_PROP, readme));
        assert!(
            findings.iter().any(|f| {
                f.path == "README.md" && f.detail.contains("JobError::Overloaded")
            }),
            "{findings:?}"
        );
    }

    #[test]
    fn missing_wire_arm_fires() {
        let mut inp = input(GOOD_PROP, GOOD_README);
        inp.wire_src = "match prepared { PreparedB::Csr(m) => put_csr(w, m) }";
        let (findings, _) = check(&inp);
        assert!(
            findings.iter().any(|f| {
                f.path == "src/engine/transport/wire.rs"
                    && f.detail.contains("PreparedB::Blocked")
            }),
            "{findings:?}"
        );
    }

    #[test]
    fn missing_suite_reference_fires() {
        let prop = "assert!(registry.len() >= 2); Algorithm::Dense;";
        let (findings, _) = check(&input(prop, GOOD_README));
        assert!(
            findings.iter().any(|f| f.detail.contains("Algorithm::Gustavson")),
            "{findings:?}"
        );
    }

    #[test]
    fn missing_readme_row_fires() {
        let readme = "## Backends\n| `(dense, dense)` | x |\n";
        let (findings, _) = check(&input(GOOD_PROP, readme));
        assert!(
            findings
                .iter()
                .any(|f| f.path == "README.md" && f.detail.contains("`gustavson`")),
            "{findings:?}"
        );
    }

    #[test]
    fn missing_cli_listing_or_name_fires() {
        // no `kernels` arm walking the registry
        let main = "match cmd { _ => println!(\"dense gustavson\") }";
        let (findings, _) = check(&input_with_main(GOOD_PROP, GOOD_README, main));
        assert!(
            findings
                .iter()
                .any(|f| f.path == "src/main.rs" && f.detail.contains("`kernels` subcommand")),
            "{findings:?}"
        );
        // listing present but one algorithm unspellable from the CLI
        let main = "\"kernels\" => reg.kernels(); // help: --kernel dense";
        let (findings, _) = check(&input_with_main(GOOD_PROP, GOOD_README, main));
        assert!(
            findings
                .iter()
                .any(|f| f.path == "src/main.rs" && f.detail.contains("`gustavson`")),
            "{findings:?}"
        );
    }

    #[test]
    fn lagging_registry_floor_fires() {
        let prop = "assert!(registry.len() >= 1); Algorithm::Dense; Algorithm::Gustavson;";
        let (findings, _) = check(&input(prop, GOOD_README));
        assert!(
            findings.iter().any(|f| f.detail.contains("lags")),
            "{findings:?}"
        );
        let prop = "Algorithm::Dense; Algorithm::Gustavson;";
        let (findings, _) = check(&input(prop, GOOD_README));
        assert!(
            findings.iter().any(|f| f.detail.contains("no `registry.len()")),
            "{findings:?}"
        );
    }

    #[test]
    fn parsers_extract_the_real_shapes() {
        assert_eq!(algorithm_variants(KERNEL_FIXTURE), vec!["Dense", "Gustavson"]);
        assert_eq!(prepared_variants(KERNEL_FIXTURE), vec!["Csr", "Blocked"]);
        assert_eq!(
            algorithm_names(KERNEL_FIXTURE),
            vec![
                ("Dense".to_string(), "dense".to_string()),
                ("Gustavson".to_string(), "gustavson".to_string()),
            ]
        );
        assert_eq!(default_register_count(REGISTRY_FIXTURE), 2);
        assert_eq!(
            job_error_variants(ERROR_FIXTURE),
            vec!["QueueFull", "Overloaded"]
        );
        assert_eq!(prop_engine_len_floor(GOOD_PROP), Some(2));
        assert!(backends_section(GOOD_README)
            .is_some_and(|s| s.contains("gustavson") && !s.contains("Next")));
    }
}
