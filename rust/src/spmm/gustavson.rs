//! Gustavson row-based SpMM (CRS × CRS → CRS) — the standard CPU algorithm
//! when *both* operands are row-ordered. This is the baseline the paper's
//! introduction contrasts with: it needs no column-order access at all, but
//! it only exists because B is re-traversed per A-row; the accelerator path
//! (and the paper's inner-product form) needs B by column.

use crate::formats::csr::Csr;
use crate::formats::traits::SparseMatrix;
use crate::spmm::gustavson_fast::Workspace;

/// C = A × B with a sparse accumulator per output row.
pub fn multiply(a: &Csr, b: &Csr) -> Csr {
    multiply_counted(a, b).0
}

/// Like [`multiply`], also returning the scalar MAC count performed — the
/// count falls out of the traversal the multiply already does, so callers
/// that want accounting (the engine's Gustavson kernel) don't pay a second
/// pass over A.
///
/// The accumulator is the epoch-stamped [`Workspace`] shared with the fast
/// backend: row clears are O(touched columns) and a value that cancels to
/// exactly `0.0` mid-row can no longer re-enter the touched list (the old
/// `acc[j] == 0.0` probe re-pushed such columns, wasting sort/scan work —
/// the emitted result was and is identical).
pub fn multiply_counted(a: &Csr, b: &Csr) -> (Csr, u64) {
    assert_eq!(a.cols(), b.rows(), "inner dimensions");
    let (m, n) = (a.rows(), b.cols());
    let mut macs = 0u64;
    let mut row_ptr = Vec::with_capacity(m + 1);
    row_ptr.push(0u32);
    let mut col_idx: Vec<u32> = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    let mut ws = Workspace::new(n);

    for i in 0..m {
        ws.begin_row();
        let (a_cols, a_vals) = a.row(i);
        for (&k, &av) in a_cols.iter().zip(a_vals) {
            let (b_cols, b_vals) = b.row(k as usize);
            macs += b_cols.len() as u64;
            for (&j, &bv) in b_cols.iter().zip(b_vals) {
                ws.accum(j, av * bv);
            }
        }
        for (j, v) in ws.drain_row_sorted() {
            // numerical cancellation can produce exact zeros; keep them out
            // of the sparse result to maintain the nnz invariant
            if v != 0.0 {
                col_idx.push(j);
                vals.push(v);
            }
        }
        row_ptr.push(col_idx.len() as u32);
    }
    (Csr::from_parts(m, n, row_ptr, col_idx, vals), macs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::formats::dense::Dense;
    use crate::spmm::dense::multiply as dense_ref;

    #[test]
    fn matches_dense_reference() {
        for seed in 0..5 {
            let a = uniform(20, 30, 0.15, seed);
            let b = uniform(30, 25, 0.2, seed + 50);
            let c = multiply(&a, &b);
            let want = dense_ref(&a, &b);
            let got = Dense::from_coo(&c.to_coo());
            assert!(got.max_abs_diff(&want) < 1e-4, "seed {seed}");
        }
    }

    #[test]
    fn result_rows_sorted_unique() {
        let a = uniform(15, 40, 0.2, 9);
        let b = uniform(40, 18, 0.2, 10);
        let c = multiply(&a, &b);
        for i in 0..15 {
            let (cols, _) = c.row(i);
            for w in cols.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn empty_operands() {
        let a = uniform(5, 8, 0.0, 1);
        let b = uniform(8, 6, 0.5, 2);
        let c = multiply(&a, &b);
        assert_eq!(c.nnz(), 0);
        assert_eq!(c.shape(), (5, 6));
    }
}
