//! High-performance Gustavson SpMM: the scalar baseline (`gustavson`)
//! restructured for throughput while staying **bit-identical** to it.
//!
//! Three changes, none of which touch per-output-element accumulation
//! order (the bit-identity invariant every execution path is tested on):
//!
//! 1. **Symbolic pass.** Each output row's structural nonzero count is
//!    computed up front, so the numeric pass writes into exactly-sized
//!    buffers — no `Vec` regrowth in the hot loop.
//! 2. **Epoch-stamped accumulator.** The dense accumulator is paired with
//!    a per-column epoch stamp; "is this column new for this row" is one
//!    integer compare, clears are free (bumping the epoch invalidates the
//!    whole row), and exact cancellation to `0.0` can never re-enter a
//!    column into the touched list (the scalar kernel's `acc[j] == 0.0`
//!    probe re-pushed and re-sorted such columns).
//! 3. **Unrolled accumulate.** Contributions from one B-row are processed
//!    in 8-lane chunks: the eight products are straight-line multiplies
//!    the compiler autovectorizes, and each add targets a distinct
//!    accumulator slot — so every output element still receives its
//!    contributions in the scalar kernel's exact order. (Real `std::simd`
//!    is the named follow-up once the toolchain allows; these chunks are
//!    the portable form.)
//!
//! Parallelism (contiguous A-row bands) and workspace pooling live in the
//! engine's `GustavsonFastKernel`; this module is the single-threaded
//! algorithm body plus the [`Workspace`]/[`WorkspacePool`] types both
//! layers share.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::lock_unpoisoned;

use crate::formats::csr::Csr;
use crate::formats::traits::SparseMatrix;

/// Unroll width of the accumulate loop (see module docs, point 3).
pub const LANES: usize = 8;

/// Reusable Gustavson accumulator: dense value array + epoch stamps +
/// touched-column list. One workspace serves any number of multiplies
/// against matrices with up to [`Workspace::width`] output columns;
/// [`WorkspacePool`] reuses them across rows, jobs, micro-batches, and
/// shard workers instead of reallocating per call.
#[derive(Debug)]
pub struct Workspace {
    acc: Vec<f32>,
    stamp: Vec<u32>,
    epoch: u32,
    touched: Vec<u32>,
}

impl Workspace {
    /// A workspace for products with `n` output columns.
    pub fn new(n: usize) -> Workspace {
        Workspace {
            acc: vec![0.0; n],
            stamp: vec![0; n],
            epoch: 0,
            touched: Vec::new(),
        }
    }

    /// Columns this workspace can accumulate over.
    pub fn width(&self) -> usize {
        self.acc.len()
    }

    /// Grow (never shrink) to serve `n` output columns.
    pub fn ensure(&mut self, n: usize) {
        if self.acc.len() < n {
            self.acc.resize(n, 0.0);
            self.stamp.resize(n, 0);
        }
    }

    /// Start accumulating a new output row: bump the epoch, which
    /// invalidates every previous stamp at once — no per-entry zeroing,
    /// and no `acc[j] == 0.0` probe that could re-admit a cancelled
    /// column (the scalar path's wasted re-push + re-sort).
    #[inline]
    pub(crate) fn begin_row(&mut self) {
        if self.epoch == u32::MAX {
            // one fill per 2³² rows: reset stamps so epoch 1 is fresh again
            self.stamp.fill(0);
            self.epoch = 0;
        }
        self.epoch += 1;
        self.touched.clear();
    }

    /// Accumulate one product into column `j`. First touch this row zeroes
    /// the slot then adds — the scalar kernel's exact `0.0 + p` sequence,
    /// so value bits (including the `-0.0` corner) never diverge.
    #[inline(always)]
    pub(crate) fn accum(&mut self, j: u32, p: f32) {
        let ji = j as usize;
        if self.stamp[ji] != self.epoch {
            self.stamp[ji] = self.epoch;
            self.acc[ji] = 0.0;
            self.touched.push(j);
        }
        self.acc[ji] += p;
    }

    /// Sort this row's touched columns ascending and iterate their
    /// `(column, accumulated value)` pairs — the emission order both the
    /// scalar and fast kernels share.
    pub(crate) fn drain_row_sorted(&mut self) -> impl Iterator<Item = (u32, f32)> + '_ {
        let Workspace { touched, acc, .. } = self;
        touched.sort_unstable();
        touched.iter().map(move |&j| (j, acc[j as usize]))
    }

    #[cfg(test)]
    fn force_epoch(&mut self, epoch: u32) {
        self.epoch = epoch;
    }
}

/// Shared pool of [`Workspace`]s. Lives inside the fast kernel's prepared
/// `B` (`engine::PooledCsrB`), so the coordinator's `PreparedCache` carries
/// it across micro-batches and every shard worker sharing the `PreparedB`
/// draws from the same pool. Checkout prefers a pooled workspace (a
/// **hit**) and falls back to allocating (a **miss**); the counters are the
/// reuse metric the serving layer reports.
#[derive(Debug, Default)]
pub struct WorkspacePool {
    free: Mutex<Vec<Workspace>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl WorkspacePool {
    pub fn new() -> WorkspacePool {
        WorkspacePool::default()
    }

    /// A workspace covering `n` output columns — pooled if available.
    pub fn checkout(&self, n: usize) -> Workspace {
        // pool free-list stays valid across a holder's panic (push/pop of
        // whole workspaces): recover instead of silently disabling reuse
        let pooled = lock_unpoisoned(&self.free).pop();
        match pooled {
            Some(mut ws) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                ws.ensure(n);
                ws
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Workspace::new(n)
            }
        }
    }

    /// Return a workspace for reuse.
    pub fn give_back(&self, ws: Workspace) {
        lock_unpoisoned(&self.free).push(ws);
    }

    /// Checkouts served from the pool.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Checkouts that had to allocate.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Workspaces currently parked in the pool.
    pub fn pooled(&self) -> usize {
        lock_unpoisoned(&self.free).len()
    }
}

/// Structural (pre-cancellation) nonzero count of each output row in
/// `lo..hi` — the symbolic pass. Upper-bounds the numeric row sizes
/// exactly (equality whenever no accumulation cancels to exactly `0.0`).
pub fn symbolic_row_nnz(a: &Csr, lo: usize, hi: usize, b: &Csr, ws: &mut Workspace) -> Vec<u32> {
    ws.ensure(b.cols());
    let mut counts = Vec::with_capacity(hi - lo);
    for i in lo..hi {
        ws.begin_row();
        let mut count = 0u32;
        let (a_cols, _) = a.row(i);
        for &k in a_cols {
            let (b_cols, _) = b.row(k as usize);
            for &j in b_cols {
                if ws.stamp[j as usize] != ws.epoch {
                    ws.stamp[j as usize] = ws.epoch;
                    count += 1;
                }
            }
        }
        counts.push(count);
    }
    counts
}

/// One computed A-row band of `C = A × B` in CSR parts (row pointers
/// relative to the band) plus its accounting.
#[derive(Debug)]
pub struct BandResult {
    /// Relative row pointers, length `hi - lo + 1`.
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
    /// Scalar MACs performed (identical to the scalar kernel's count).
    pub macs: u64,
    /// Total structural nnz the symbolic pass sized the buffers for
    /// (`>= col_idx.len()`; equal in the absence of exact cancellation).
    pub symbolic_nnz: usize,
}

/// Compute output rows `lo..hi` of `C = A × B`: symbolic pass sizes the
/// band's buffers, numeric pass fills them with the scalar kernel's exact
/// per-element accumulation order. Row-decomposable by construction —
/// the band's rows are bit-identical to the full run's rows.
pub fn multiply_band(a: &Csr, lo: usize, hi: usize, b: &Csr, ws: &mut Workspace) -> BandResult {
    debug_assert!(lo <= hi && hi <= a.rows());
    debug_assert_eq!(a.cols(), b.rows(), "inner dimensions");
    ws.ensure(b.cols());

    let counts = symbolic_row_nnz(a, lo, hi, b, ws);
    let symbolic_nnz: usize = counts.iter().map(|&c| c as usize).sum();

    // exact-capacity output buffers: the numeric pass never regrows them
    let mut row_ptr = Vec::with_capacity(hi - lo + 1);
    row_ptr.push(0u32);
    let mut col_idx: Vec<u32> = Vec::with_capacity(symbolic_nnz);
    let mut vals: Vec<f32> = Vec::with_capacity(symbolic_nnz);
    let mut macs = 0u64;

    for i in lo..hi {
        ws.begin_row();
        let (a_cols, a_vals) = a.row(i);
        for (&k, &av) in a_cols.iter().zip(a_vals) {
            let (b_cols, b_vals) = b.row(k as usize);
            macs += b_cols.len() as u64;
            let mut c_chunks = b_cols.chunks_exact(LANES);
            let mut v_chunks = b_vals.chunks_exact(LANES);
            for (c8, v8) in (&mut c_chunks).zip(&mut v_chunks) {
                // eight independent products in one straight-line block
                // (autovectorizable); the accumulates hit distinct slots,
                // so each output element's add order matches the scalar
                // kernel exactly
                let p = [
                    av * v8[0],
                    av * v8[1],
                    av * v8[2],
                    av * v8[3],
                    av * v8[4],
                    av * v8[5],
                    av * v8[6],
                    av * v8[7],
                ];
                for (&j, &pj) in c8.iter().zip(&p) {
                    ws.accum(j, pj);
                }
            }
            for (&j, &bv) in c_chunks.remainder().iter().zip(v_chunks.remainder()) {
                ws.accum(j, av * bv);
            }
        }
        for (j, v) in ws.drain_row_sorted() {
            // keep exact cancellations out of the sparse result (the
            // scalar kernel's nnz invariant)
            if v != 0.0 {
                col_idx.push(j);
                vals.push(v);
            }
        }
        row_ptr.push(col_idx.len() as u32);
    }
    debug_assert!(col_idx.len() <= symbolic_nnz);
    BandResult {
        row_ptr,
        col_idx,
        vals,
        macs,
        symbolic_nnz,
    }
}

/// `C = A × B` with a caller-provided workspace. Bit-identical to
/// [`super::gustavson::multiply_counted`] (locked by `tests/prop_gustavson`).
pub fn multiply_counted_ws(a: &Csr, b: &Csr, ws: &mut Workspace) -> (Csr, u64) {
    assert_eq!(a.cols(), b.rows(), "inner dimensions");
    let band = multiply_band(a, 0, a.rows(), b, ws);
    (
        Csr::from_parts(a.rows(), b.cols(), band.row_ptr, band.col_idx, band.vals),
        band.macs,
    )
}

/// Convenience wrapper allocating a fresh workspace.
pub fn multiply(a: &Csr, b: &Csr) -> Csr {
    let mut ws = Workspace::new(b.cols());
    multiply_counted_ws(a, b, &mut ws).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::formats::coo::Coo;
    use crate::spmm::gustavson;

    fn same_csr_bits(x: &Csr, y: &Csr) -> bool {
        x.bit_pattern() == y.bit_pattern()
    }

    #[test]
    fn matches_scalar_gustavson_bitwise() {
        let mut ws = Workspace::new(0);
        for seed in 0..6 {
            let a = uniform(30, 40, 0.2, seed);
            let b = uniform(40, 33, 0.2, seed + 100);
            let (want, want_macs) = gustavson::multiply_counted(&a, &b);
            let (got, got_macs) = multiply_counted_ws(&a, &b, &mut ws);
            assert!(same_csr_bits(&want, &got), "seed {seed}");
            assert_eq!(want_macs, got_macs, "seed {seed}");
        }
    }

    #[test]
    fn symbolic_counts_size_the_numeric_pass_exactly_without_cancellation() {
        // uniform values live in [0.5, 1.5): all positive, no cancellation,
        // so structural == numeric nnz per row
        let a = uniform(25, 30, 0.25, 3);
        let b = uniform(30, 28, 0.25, 4);
        let mut ws = Workspace::new(b.cols());
        let counts = symbolic_row_nnz(&a, 0, a.rows(), &b, &mut ws);
        let band = multiply_band(&a, 0, a.rows(), &b, &mut ws);
        assert_eq!(counts.len(), a.rows());
        assert_eq!(
            band.symbolic_nnz,
            counts.iter().map(|&c| c as usize).sum::<usize>()
        );
        assert_eq!(band.col_idx.len(), band.symbolic_nnz);
        for (i, &c) in counts.iter().enumerate() {
            assert_eq!(
                band.row_ptr[i + 1] - band.row_ptr[i],
                c,
                "row {i} sized wrong"
            );
        }
    }

    #[test]
    fn cancellation_shrinks_numeric_below_symbolic_and_drops_the_entry() {
        // A = [1, -1, 2] times B rows [3], [3], [7]: column 0 receives
        // 3, -3 (exact cancellation), then 14 — one output entry, while
        // the symbolic pass counts the column once and sizes for it
        let a = Csr::from_coo(&Coo::new(
            1,
            3,
            vec![(0, 0, 1.0), (0, 1, -1.0), (0, 2, 2.0)],
        ));
        let b = Csr::from_coo(&Coo::new(
            3,
            1,
            vec![(0, 0, 3.0), (1, 0, 3.0), (2, 0, 7.0)],
        ));
        let mut ws = Workspace::new(1);
        let band = multiply_band(&a, 0, 1, &b, &mut ws);
        assert_eq!(band.symbolic_nnz, 1);
        assert_eq!(band.vals, vec![14.0]);
        // full cancellation: the entry vanishes entirely
        let b0 = Csr::from_coo(&Coo::new(
            3,
            1,
            vec![(0, 0, 3.0), (1, 0, 3.0)],
        ));
        let band0 = multiply_band(&a, 0, 1, &b0, &mut ws);
        assert_eq!(band0.symbolic_nnz, 1);
        assert_eq!(band0.col_idx.len(), 0);
        // and both agree with the scalar kernel bitwise
        let (want, _) = gustavson::multiply_counted(&a, &b0);
        assert_eq!(want.nnz(), 0);
    }

    #[test]
    fn bands_compose_to_the_full_product() {
        let a = uniform(40, 32, 0.2, 9);
        let b = uniform(32, 26, 0.2, 10);
        let mut ws = Workspace::new(b.cols());
        let whole = multiply_band(&a, 0, 40, &b, &mut ws);
        let lo_band = multiply_band(&a, 0, 16, &b, &mut ws);
        let hi_band = multiply_band(&a, 16, 40, &b, &mut ws);
        assert_eq!(
            whole.col_idx.len(),
            lo_band.col_idx.len() + hi_band.col_idx.len()
        );
        assert_eq!(&whole.col_idx[..lo_band.col_idx.len()], &lo_band.col_idx[..]);
        assert_eq!(&whole.col_idx[lo_band.col_idx.len()..], &hi_band.col_idx[..]);
        let recombined: Vec<u32> = lo_band
            .vals
            .iter()
            .chain(&hi_band.vals)
            .map(|v| v.to_bits())
            .collect();
        let want: Vec<u32> = whole.vals.iter().map(|v| v.to_bits()).collect();
        assert_eq!(recombined, want, "band split changed value bits");
        assert_eq!(whole.macs, lo_band.macs + hi_band.macs);
    }

    #[test]
    fn epoch_wrap_resets_stamps_instead_of_aliasing() {
        let a = uniform(6, 8, 0.5, 20);
        let b = uniform(8, 7, 0.5, 21);
        let mut ws = Workspace::new(b.cols());
        let (want, _) = multiply_counted_ws(&a, &b, &mut ws);
        // park the epoch at the wrap boundary: the wrap must reset every
        // stamp before reusing small epoch values, or the first run's
        // stale stamps (1, 2, …) would alias the second run's epochs and
        // skip the zeroing of touched slots
        ws.force_epoch(u32::MAX);
        let (got, _) = multiply_counted_ws(&a, &b, &mut ws);
        assert!(same_csr_bits(&want, &got), "epoch wrap corrupted the workspace");
    }

    #[test]
    fn workspace_pool_reuses_and_counts() {
        let pool = WorkspacePool::new();
        assert_eq!((pool.hits(), pool.misses()), (0, 0));
        let ws1 = pool.checkout(16);
        let ws2 = pool.checkout(16);
        assert_eq!((pool.hits(), pool.misses()), (0, 2));
        pool.give_back(ws1);
        pool.give_back(ws2);
        assert_eq!(pool.pooled(), 2);
        // reuse grows the workspace when the next job is wider
        let ws = pool.checkout(64);
        assert_eq!((pool.hits(), pool.misses()), (1, 2));
        assert!(ws.width() >= 64);
        pool.give_back(ws);
        assert_eq!(pool.pooled(), 2);
    }

    #[test]
    fn empty_operands() {
        let a = uniform(5, 8, 0.0, 1);
        let b = uniform(8, 6, 0.5, 2);
        let c = multiply(&a, &b);
        assert_eq!(c.nnz(), 0);
        assert_eq!(c.shape(), (5, 6));
    }
}
