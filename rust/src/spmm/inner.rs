//! Inner-product SpMM with column-order access to B — the access pattern
//! the paper's §II problem statement is about.
//!
//! `C[i][j] = Σ_k A[i][k]·B[k][j]` computed per output cell, reading B's
//! column j through a *row-ordered* format's `locate` (CRS or InCRS). This
//! is the algorithm whose memory behavior Table II and Fig 3 measure; it is
//! also a correctness cross-check that `locate` semantics compose into a
//! correct multiply.

use crate::formats::csr::Csr;
use crate::formats::dense::Dense;
use crate::formats::incrs::InCrs;
use crate::formats::traits::{AccessSink, SparseMatrix};

/// C = A × B where B is accessed strictly by `locate(k, j)` through `sink`.
/// A is traversed in row order (free in both CRS and InCRS, §V.B).
pub fn multiply_via_locate<S, F>(a: &Csr, b_shape: (usize, usize), mut locate_b: F, sink: &mut S) -> Dense
where
    S: AccessSink,
    F: FnMut(usize, usize, &mut S) -> Option<f32>,
{
    let (b_rows, b_cols) = b_shape;
    assert_eq!(a.cols(), b_rows, "inner dimensions");
    let m = a.rows();
    let mut c = Dense::zeros(m, b_cols);
    for j in 0..b_cols {
        for i in 0..m {
            let (a_cols, a_vals) = a.row(i);
            let mut acc = 0.0f32;
            for (&k, &av) in a_cols.iter().zip(a_vals) {
                if let Some(bv) = locate_b(k as usize, j, sink) {
                    acc += av * bv;
                }
            }
            if acc != 0.0 {
                *c.at_mut(i, j) = acc;
            }
        }
    }
    c
}

/// Inner-product SpMM with B in CRS (the paper's "slow" baseline).
pub fn multiply_b_csr<S: AccessSink>(a: &Csr, b: &Csr, sink: &mut S) -> Dense {
    multiply_via_locate(a, b.shape(), |k, j, s| b.locate(k, j, s), sink)
}

/// Inner-product SpMM with B in InCRS (the paper's proposal).
pub fn multiply_b_incrs<S: AccessSink>(a: &Csr, b: &InCrs, sink: &mut S) -> Dense {
    multiply_via_locate(a, b.shape(), |k, j, s| b.locate(k, j, s), sink)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::formats::traits::{CountSink, NullSink};
    use crate::spmm::dense::multiply as dense_ref;

    #[test]
    fn csr_and_incrs_paths_compute_the_same_product() {
        let a = uniform(8, 20, 0.3, 1);
        let b = uniform(20, 12, 0.25, 2);
        let b_in = InCrs::from_csr(&b).unwrap();
        let want = dense_ref(&a, &b);
        let mut sink = NullSink;
        let c1 = multiply_b_csr(&a, &b, &mut sink);
        let c2 = multiply_b_incrs(&a, &b_in, &mut sink);
        assert!(c1.max_abs_diff(&want) < 1e-4);
        assert!(c2.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn incrs_needs_far_fewer_accesses_for_same_product() {
        let a = uniform(6, 64, 0.5, 3);
        let b = uniform(64, 512, 0.08, 4);
        let b_in = InCrs::from_csr(&b).unwrap();
        let mut s_crs = CountSink::default();
        let c1 = multiply_b_csr(&a, &b, &mut s_crs);
        let mut s_in = CountSink::default();
        let c2 = multiply_b_incrs(&a, &b_in, &mut s_in);
        assert!(c1.max_abs_diff(&c2) < 1e-4);
        let ratio = s_crs.total as f64 / s_in.total as f64;
        assert!(ratio > 3.0, "MA ratio {ratio}");
    }
}
