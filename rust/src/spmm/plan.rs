//! Execution planner: blocked operands → sorted tile-pair dispatches for
//! the AOT-compiled Pallas kernel (`spmm_block`).
//!
//! This is the Rust production twin of `python/compile/blocking.py` (the
//! numpy reference used by pytest): block pairs sorted by output tile so
//! the kernel's VMEM revisit-accumulation applies, chunked into fixed
//! `PAIRS`-sized dispatches with ≤ `SLOTS` distinct output tiles each,
//! zero-padded with the last real slot id. Dispatches additionally never
//! span output block rows, making the plan *row-decomposable*: the plan of
//! a block-aligned row band equals the corresponding sub-sequence of the
//! full plan's dispatches, so sharded execution (`engine::shard`) is
//! bit-identical to the unsharded run.

use super::blocks::{blockize, BlockGrid};
use crate::formats::csr::Csr;
use crate::formats::dense::Dense;
use crate::formats::traits::SparseMatrix;

/// Dispatch geometry — must equal the artifact manifest's values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Geometry {
    pub block: usize,
    pub pairs: usize,
    pub slots: usize,
}

impl Default for Geometry {
    /// The shipped artifacts' geometry (python/compile/model.py).
    fn default() -> Self {
        Geometry {
            block: 32,
            pairs: 128,
            slots: 64,
        }
    }
}

/// One accelerator call: `pairs` tile pairs, ≤ `slots` output tiles.
#[derive(Clone, Debug)]
pub struct Dispatch {
    /// int32[pairs], sorted; padding repeats the last real id.
    pub seg: Vec<i32>,
    /// f32[pairs × block × block], flattened.
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub n_real: usize,
    /// local slot -> output block coordinate.
    pub slot_map: Vec<(u32, u32)>,
}

/// A full SpMM job plan.
#[derive(Clone, Debug)]
pub struct Plan {
    pub geom: Geometry,
    pub out_rows: usize,
    pub out_cols: usize,
    pub dispatches: Vec<Dispatch>,
    /// Total real (unpadded) tile-pair MACs worth of work.
    pub total_pairs: usize,
}

/// Build the plan for C = A × B.
pub fn plan(a: &Csr, b: &Csr, geom: Geometry) -> Plan {
    assert_eq!(a.cols(), b.rows(), "inner dimensions");
    plan_blocked(a, &blockize(b, geom.block), geom)
}

/// Build the plan for C = A × B where `B` arrives pre-blockized (built once
/// by `AccelKernel::prepare` and reused across jobs and shard workers); `A`
/// is blockized per call. `gb.block` must equal `geom.block`.
pub fn plan_blocked(a: &Csr, gb: &BlockGrid, geom: Geometry) -> Plan {
    assert_eq!(a.cols(), gb.rows, "inner dimensions");
    assert_eq!(gb.block, geom.block, "B blockized at a different tile size");
    let ga = blockize(a, geom.block);
    plan_grids(&ga, gb, geom, a.rows(), gb.cols)
}

fn plan_grids(ga: &BlockGrid, gb: &BlockGrid, geom: Geometry, m: usize, n: usize) -> Plan {
    // index B's tiles by K-block for the intersection
    let mut b_by_k: Vec<Vec<(u32, &Vec<f32>)>> = vec![Vec::new(); gb.grid_rows];
    for (&(bk, bj), tile) in &gb.tiles {
        b_by_k[bk as usize].push((bj, tile));
    }

    // flat sorted pair list grouped by output tile: BTreeMap iterates
    // (bi,bk) in row-major order, so per out-tile K-order is preserved
    let mut by_out: std::collections::BTreeMap<(u32, u32), Vec<(&Vec<f32>, &Vec<f32>)>> =
        std::collections::BTreeMap::new();
    for (&(bi, bk), a_tile) in &ga.tiles {
        for &(bj, b_tile) in &b_by_k[bk as usize] {
            by_out.entry((bi, bj)).or_default().push((a_tile, b_tile));
        }
    }

    let tile_elems = geom.block * geom.block;
    let mut dispatches = Vec::new();
    let mut total_pairs = 0usize;

    let mut cur = Dispatch {
        seg: Vec::with_capacity(geom.pairs),
        a: Vec::with_capacity(geom.pairs * tile_elems),
        b: Vec::with_capacity(geom.pairs * tile_elems),
        n_real: 0,
        slot_map: Vec::new(),
    };
    let flush =
        |cur: &mut Dispatch, out: &mut Vec<Dispatch>, geom: Geometry, tile_elems: usize| {
            if cur.seg.is_empty() {
                return;
            }
            cur.n_real = cur.seg.len();
            let last = *cur.seg.last().unwrap();
            while cur.seg.len() < geom.pairs {
                cur.seg.push(last);
                cur.a.extend(std::iter::repeat(0.0).take(tile_elems));
                cur.b.extend(std::iter::repeat(0.0).take(tile_elems));
            }
            out.push(std::mem::replace(
                cur,
                Dispatch {
                    seg: Vec::with_capacity(geom.pairs),
                    a: Vec::with_capacity(geom.pairs * tile_elems),
                    b: Vec::with_capacity(geom.pairs * tile_elems),
                    n_real: 0,
                    slot_map: Vec::new(),
                },
            ));
        };

    let mut cur_block_row: Option<u32> = None;
    for (out_coord, pairs) in &by_out {
        // dispatches never span output block rows: each block row's chunk
        // boundaries depend only on its own pair sequence, so the plan for
        // any block-aligned row band is exactly the sub-sequence of
        // full-plan dispatches covering those rows. This row-decomposable
        // chunking is the sharding layer's bit-reproducibility invariant
        // (`engine::shard`): f32 accumulation association per output tile
        // is identical whether the matrix is planned whole or in bands.
        if cur_block_row.is_some() && cur_block_row != Some(out_coord.0) {
            flush(&mut cur, &mut dispatches, geom, tile_elems);
        }
        cur_block_row = Some(out_coord.0);
        for (a_tile, b_tile) in pairs {
            total_pairs += 1;
            // open a new slot if this output tile isn't current
            let need_new_slot = cur.slot_map.last() != Some(out_coord);
            if (need_new_slot && cur.slot_map.len() == geom.slots)
                || cur.seg.len() == geom.pairs
            {
                flush(&mut cur, &mut dispatches, geom, tile_elems);
            }
            if cur.slot_map.last() != Some(out_coord) {
                cur.slot_map.push(*out_coord);
            }
            cur.seg.push(cur.slot_map.len() as i32 - 1);
            cur.a.extend_from_slice(a_tile);
            cur.b.extend_from_slice(b_tile);
        }
    }
    flush(&mut cur, &mut dispatches, geom, tile_elems);

    Plan {
        geom,
        out_rows: m,
        out_cols: n,
        dispatches,
        total_pairs,
    }
}

impl Plan {
    /// Execute the plan with `exec(dispatch) -> slot tiles (slots×block²
    /// flattened)` and scatter-accumulate into dense C. `exec` is the PJRT
    /// engine in production and a CPU loop in tests.
    pub fn execute<E, Err>(&self, mut exec: E) -> Result<Dense, Err>
    where
        E: FnMut(&Dispatch) -> Result<Vec<f32>, Err>,
    {
        let bsz = self.geom.block;
        let grid_cols = (self.out_cols + bsz - 1) / bsz;
        let padded_rows = ((self.out_rows + bsz - 1) / bsz) * bsz;
        let mut c = Dense::zeros(padded_rows, grid_cols * bsz);
        for d in &self.dispatches {
            let tiles = exec(d)?;
            debug_assert_eq!(tiles.len(), self.geom.slots * bsz * bsz);
            for (slot, &(bi, bj)) in d.slot_map.iter().enumerate() {
                let tile = &tiles[slot * bsz * bsz..(slot + 1) * bsz * bsz];
                for r in 0..bsz {
                    let ci = bi as usize * bsz + r;
                    for cc in 0..bsz {
                        *c.at_mut(ci, bj as usize * bsz + cc) += tile[r * bsz + cc];
                    }
                }
            }
        }
        // crop padding
        let mut out = Dense::zeros(self.out_rows, self.out_cols);
        for i in 0..self.out_rows {
            for j in 0..self.out_cols {
                *out.at_mut(i, j) = c.at(i, j);
            }
        }
        Ok(out)
    }

    /// CPU reference executor (the same math the Pallas kernel does) — used
    /// by tests and as the no-artifact fallback engine.
    pub fn execute_cpu(&self) -> Dense {
        let bsz = self.geom.block;
        let slots = self.geom.slots;
        let r: Result<Dense, std::convert::Infallible> = self.execute(|d| {
            let mut out = vec![0.0f32; slots * bsz * bsz];
            for p in 0..d.n_real {
                let slot = d.seg[p] as usize;
                let at = &d.a[p * bsz * bsz..(p + 1) * bsz * bsz];
                let bt = &d.b[p * bsz * bsz..(p + 1) * bsz * bsz];
                let ot = &mut out[slot * bsz * bsz..(slot + 1) * bsz * bsz];
                for i in 0..bsz {
                    for k in 0..bsz {
                        let av = at[i * bsz + k];
                        if av == 0.0 {
                            continue;
                        }
                        for j in 0..bsz {
                            ot[i * bsz + j] += av * bt[k * bsz + j];
                        }
                    }
                }
            }
            Ok(out)
        });
        r.unwrap() // Infallible
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::spmm::dense::multiply as dense_ref;

    fn small_geom() -> Geometry {
        Geometry { block: 8, pairs: 6, slots: 3 }
    }

    #[test]
    fn dispatches_respect_geometry() {
        let a = uniform(32, 48, 0.2, 1);
        let b = uniform(48, 40, 0.2, 2);
        let p = plan(&a, &b, small_geom());
        assert!(!p.dispatches.is_empty());
        for d in &p.dispatches {
            assert_eq!(d.seg.len(), 6);
            assert_eq!(d.a.len(), 6 * 64);
            assert!(d.slot_map.len() <= 3);
            assert!(d.n_real >= 1 && d.n_real <= 6);
            // sorted + grouped segments
            for w in d.seg.windows(2) {
                assert!(w[0] <= w[1]);
            }
            // padding repeats the last real id
            for k in d.n_real..6 {
                assert_eq!(d.seg[k], d.seg[d.n_real - 1]);
            }
        }
    }

    #[test]
    fn cpu_execution_matches_dense_reference() {
        for seed in 0..4 {
            let a = uniform(33, 47, 0.15, seed);
            let b = uniform(47, 29, 0.18, seed + 9);
            let p = plan(&a, &b, small_geom());
            let got = p.execute_cpu();
            let want = dense_ref(&a, &b);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "seed {seed}: diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn group_split_across_dispatches_accumulates() {
        // one output tile needing more pairs than P
        let a = uniform(8, 128, 0.9, 3); // 1×16 blocks at block=8
        let b = uniform(128, 8, 0.9, 4);
        let p = plan(&a, &b, Geometry { block: 8, pairs: 3, slots: 2 });
        assert!(p.dispatches.len() >= 3);
        let got = p.execute_cpu();
        let want = dense_ref(&a, &b);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn disjoint_structure_plans_nothing() {
        use crate::formats::coo::Coo;
        use crate::formats::csr::Csr;
        let a = Csr::from_coo(&Coo::new(16, 16, vec![(0, 0, 1.0)]));
        let b = Csr::from_coo(&Coo::new(16, 16, vec![(15, 15, 1.0)]));
        let p = plan(&a, &b, Geometry { block: 8, pairs: 4, slots: 2 });
        assert_eq!(p.total_pairs, 0);
        assert!(p.dispatches.is_empty());
        let c = p.execute_cpu();
        assert!(c.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn plans_are_row_decomposable_for_block_aligned_bands() {
        // tiny pairs/slots force mid-tile dispatch splits — the case where
        // non-row-decomposable chunking would change f32 association
        let a = uniform(40, 64, 0.25, 7);
        let b = uniform(64, 48, 0.25, 8);
        let geom = Geometry { block: 8, pairs: 3, slots: 2 };
        let full = plan(&a, &b, geom);
        let mut banded_dispatches = 0;
        let mut merged = Dense::zeros(40, 48);
        for (lo, hi) in [(0usize, 16usize), (16, 32), (32, 40)] {
            let p = plan(&a.row_band(lo, hi), &b, geom);
            banded_dispatches += p.dispatches.len();
            let c = p.execute_cpu();
            for i in 0..(hi - lo) {
                for j in 0..48 {
                    *merged.at_mut(lo + i, j) = c.at(i, j);
                }
            }
        }
        // band plans are exactly the full plan's dispatches, partitioned
        assert_eq!(full.dispatches.len(), banded_dispatches);
        let whole = full.execute_cpu();
        assert_eq!(
            whole.bit_pattern(),
            merged.bit_pattern(),
            "banded plan changed result bits"
        );
    }

    #[test]
    fn default_geometry_matches_manifest_constants() {
        let g = Geometry::default();
        assert_eq!((g.block, g.pairs, g.slots), (32, 128, 64));
    }
}
