//! SpMM algorithm bodies and the accelerator dispatch planner. Callers
//! should normally go through [`crate::engine`] (the kernel registry),
//! which wraps these behind the unified `SpmmKernel` contract.
//!
//! * [`dense`] — the numeric oracle (row-expansion reference multiply).
//! * [`gustavson`] — row-order CRS×CRS (the CPU baseline that *avoids*
//!   column access).
//! * [`gustavson_fast`] — the same algorithm restructured for throughput
//!   (symbolic row sizing, epoch-stamped accumulator, unrolled 8-lane
//!   accumulate) while staying bit-identical to [`gustavson`]; the engine's
//!   `GustavsonFastKernel` adds A-row-band parallelism and workspace
//!   pooling on top.
//! * [`inner`] — inner-product SpMM with column-order `locate` access to B
//!   (the access pattern Tables I/II and Fig 3 measure).
//! * [`outer`] — outer-product SpMM (SpArch-style) for hyper-sparse
//!   inputs: A streamed by column against B by row, per-column
//!   partial-product runs combined by a deterministic k-ordered multiway
//!   merge — bit-identical to [`gustavson`] at any fan-in or worker count.
//! * [`blocks`]/[`plan`] — 32×32 blocking and sorted tile-pair dispatch
//!   planning for the AOT Pallas kernel (the TPU re-expression of the
//!   paper's comparator mesh, DESIGN.md §Hardware-Adaptation).

pub mod blocks;
pub mod dense;
pub mod gustavson;
pub mod gustavson_fast;
pub mod inner;
pub mod outer;
pub mod plan;

pub use blocks::{blockize, BlockGrid};
pub use plan::{plan, Dispatch, Geometry, Plan};
