//! Outer-product SpMM (SpArch-style, PAPERS.md) with a deterministic
//! k-ordered multiway merge — the column-major formulation for hyper-sparse
//! inputs where row-centric kernels collapse.
//!
//! # Algorithm
//!
//! `C = A × B` is the sum of K rank-1 outer products: column `k` of A times
//! row `k` of B. Each outer product is a *run* of partial products already
//! sorted by output coordinate `(i, j)` — A's column is row-ordered (CSC)
//! and B's row is column-ordered (CSR) — so the multiply reduces to merging
//! K sorted runs. That is exactly the shape SpArch builds its merge tree
//! around, and it does work proportional to the partial products actually
//! produced: a near-empty A row costs nothing, where Gustavson still pays
//! its per-row machinery over `m` mostly-empty rows.
//!
//! # Bit-reproducibility
//!
//! The scalar Gustavson oracle accumulates each output cell's products in
//! ascending-k order, folding left-to-right from `0.0`
//! (`gustavson_fast::Workspace::accum`). f32 addition is not associative,
//! so this module never lets the merge topology touch the fold:
//!
//! * runs carry **raw products**, never partial sums;
//! * every intermediate merge ([`merge_k_range`]'s hierarchical fan-in
//!   rounds) is a **pure stable merge** — equal coordinates drain in
//!   ascending-k order (lower run index first; runs are built in ascending
//!   k, and parallel k-ranges are contiguous and disjoint);
//! * accumulation happens **once**, in the single final pass over the
//!   globally (i, j, k)-ordered stream ([`accumulate_merged`]), folding
//!   each coordinate's products from `0.0` — the scalar fold, verbatim.
//!
//! The output is therefore bitwise identical to `gustavson::multiply` at
//! any merge fan-in and any worker count (locked by `tests/prop_outer.rs`).
//! Exact zeros (cancellation) are dropped on emission just like the scalar
//! kernel's `v != 0.0` filter.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::engine::tiled::partition_by_weight;
use crate::formats::csr::Csr;
use crate::formats::traits::SparseMatrix;
use crate::util::lock_unpoisoned;

/// One partial product: packed output coordinate (row in the high 32 bits,
/// column in the low 32) and the raw `a_ik · b_kj` value. Plain `u64`
/// ordering of the key is lexicographic `(i, j)` order.
pub type PartialProduct = (u64, f32);

#[inline(always)]
fn pack(i: u32, j: u32) -> u64 {
    ((i as u64) << 32) | j as u64
}

/// Merge policy for one outer-product multiply.
#[derive(Clone, Copy, Debug)]
pub struct OuterConfig {
    /// Runs combined per intermediate merge round (≤ 1 = one flat multiway
    /// merge per k-range, no intermediate rounds). Any value produces the
    /// same bits; it only trades merge passes against cursor fan-out.
    pub fan_in: usize,
    /// Worker threads, each merging a contiguous k-range (1 = serial).
    pub workers: usize,
}

impl Default for OuterConfig {
    fn default() -> Self {
        OuterConfig { fan_in: 4, workers: 1 }
    }
}

/// Shared pool of partial-product merge buffers — the outer kernel's
/// mirror of [`crate::spmm::gustavson_fast::WorkspacePool`]. Lives inside
/// the prepared `B` (`engine::OuterB`), so the coordinator's content-keyed
/// `PreparedCache` carries it across micro-batches and every shard worker
/// sharing the `PreparedB` draws merge scratch from the same pool.
/// Checkout prefers a pooled buffer (a **hit**) and falls back to
/// allocating (a **miss**).
#[derive(Debug, Default)]
pub struct MergePool {
    free: Mutex<Vec<Vec<PartialProduct>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl MergePool {
    pub fn new() -> MergePool {
        MergePool::default()
    }

    /// An empty partial-product buffer — pooled if available.
    pub fn checkout(&self) -> Vec<PartialProduct> {
        // pool free-list stays valid across a holder's panic (push/pop of
        // whole buffers): recover instead of silently disabling reuse
        let pooled = lock_unpoisoned(&self.free).pop();
        match pooled {
            Some(buf) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    /// Return a buffer for reuse (cleared, capacity kept).
    pub fn give_back(&self, mut buf: Vec<PartialProduct>) {
        buf.clear();
        lock_unpoisoned(&self.free).push(buf);
    }

    /// Checkouts served from the pool.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Checkouts that had to allocate.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Buffers currently parked in the pool.
    pub fn pooled(&self) -> usize {
        lock_unpoisoned(&self.free).len()
    }
}

/// C = A × B by outer products. Transposes A internally (the CSR→CSC step
/// the engine's cost hint charges) and delegates to
/// [`multiply_transposed_counted`]. Returns `(C, macs, k_bands)`.
pub fn multiply_counted(
    a: &Csr,
    b: &Csr,
    cfg: &OuterConfig,
    pool: &MergePool,
) -> (Csr, u64, usize) {
    assert_eq!(a.cols(), b.rows(), "inner dimensions");
    multiply_transposed_counted(&a.transpose(), b, cfg, pool)
}

/// C = A × B given `at` = Aᵀ (so `at.row(k)` is A's column `k`, already
/// sorted by ascending output row — exactly a CSC column). Streams column
/// `k` of A against row `k` of B, merges the per-column runs k-range by
/// k-range (parallel over `cfg.workers` contiguous ranges weighted by
/// per-column partial-product counts), then runs the single accumulating
/// merge. Returns `(C, macs, k_bands)` where `macs` is the scalar MAC
/// count (identical to Gustavson's) and `k_bands` the number of k-ranges
/// actually executed.
pub fn multiply_transposed_counted(
    at: &Csr,
    b: &Csr,
    cfg: &OuterConfig,
    pool: &MergePool,
) -> (Csr, u64, usize) {
    assert_eq!(at.rows(), b.rows(), "inner dimensions (Aᵀ rows vs B rows)");
    let kdim = at.rows();
    let (m, n) = (at.cols(), b.cols());

    // per-column flop weights: |A.col(k)| · |B.row(k)| partial products —
    // the same weighted contiguous partition the tiled executor uses
    let weights: Vec<usize> = (0..kdim).map(|k| at.row_nnz(k) * b.row_nnz(k)).collect();
    let macs: u64 = weights.iter().map(|&w| w as u64).sum();
    let ranges = partition_by_weight(&weights, cfg.workers.max(1));
    let bands = ranges.len();

    // stage 1: per-range pure merges, in parallel. Ranges are contiguous
    // and ascending in k, so range order preserves k order globally.
    let mut runs: Vec<Vec<PartialProduct>> = if bands <= 1 {
        ranges
            .iter()
            .map(|&(lo, hi)| merge_k_range(at, b, lo, hi, cfg.fan_in, pool))
            .collect()
    } else {
        std::thread::scope(|s| {
            let handles: Vec<_> = ranges
                .iter()
                .map(|&(lo, hi)| s.spawn(move || merge_k_range(at, b, lo, hi, cfg.fan_in, pool)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("outer merge worker panicked"))
                .collect()
        })
    };

    // stage 2: the one accumulating pass across the per-range streams
    let c = accumulate_merged(&runs, m, n);
    for run in runs.drain(..) {
        pool.give_back(run);
    }
    (c, macs, bands)
}

/// Pure (non-accumulating) merge of the per-column runs for `k` in
/// `[k_lo, k_hi)`: the returned stream is sorted by packed `(i, j)` key
/// with equal-key entries kept in ascending-k emission order. No values are
/// ever combined here — that is what makes the result invariant under
/// `fan_in`.
fn merge_k_range(
    at: &Csr,
    b: &Csr,
    k_lo: usize,
    k_hi: usize,
    fan_in: usize,
    pool: &MergePool,
) -> Vec<PartialProduct> {
    // per-column runs: A's column k (ascending i) × B's row k (ascending j)
    // — each run is born (i, j)-sorted, and the list is ascending in k
    let mut runs: Vec<Vec<PartialProduct>> = Vec::new();
    for k in k_lo..k_hi {
        let (is, a_vals) = at.row(k);
        let (js, b_vals) = b.row(k);
        if is.is_empty() || js.is_empty() {
            continue;
        }
        let mut run = pool.checkout();
        run.reserve(is.len() * js.len());
        for (&i, &av) in is.iter().zip(a_vals) {
            for (&j, &bv) in js.iter().zip(b_vals) {
                run.push((pack(i, j), av * bv));
            }
        }
        runs.push(run);
    }
    if fan_in >= 2 {
        // hierarchical rounds of `fan_in`-way merges (SpArch's merge tree):
        // chunking preserves run order, so ties keep draining lower k first
        while runs.len() > 1 {
            let mut next = Vec::with_capacity(runs.len().div_ceil(fan_in));
            for group in runs.chunks(fan_in) {
                next.push(multiway_merge(group, pool));
            }
            for run in runs.drain(..) {
                pool.give_back(run);
            }
            runs = next;
        }
        runs.pop().unwrap_or_else(|| pool.checkout())
    } else {
        // one flat multiway merge over every run in the range
        let merged = multiway_merge(&runs, pool);
        for run in runs.drain(..) {
            pool.give_back(run);
        }
        merged
    }
}

/// Stable multiway merge of sorted `streams` (stream order = ascending k):
/// equal keys drain lower-index streams first, preserving ascending-k
/// order at every output coordinate. Linear cursor scan — fan-in is small
/// by construction.
fn multiway_merge(streams: &[Vec<PartialProduct>], pool: &MergePool) -> Vec<PartialProduct> {
    let total: usize = streams.iter().map(Vec::len).sum();
    let mut out = pool.checkout();
    out.reserve(total);
    let mut cursors = vec![0usize; streams.len()];
    loop {
        let mut best: Option<(u64, usize)> = None;
        for (s, &c) in cursors.iter().enumerate() {
            if c < streams[s].len() {
                let key = streams[s][c].0;
                // strict `<` keeps ties on the earliest (lowest-k) stream
                let better = match best {
                    None => true,
                    Some((bk, _)) => key < bk,
                };
                if better {
                    best = Some((key, s));
                }
            }
        }
        match best {
            Some((_, s)) => {
                out.push(streams[s][cursors[s]]);
                cursors[s] += 1;
            }
            None => break,
        }
    }
    out
}

/// The single accumulating pass: multiway-merge the per-range streams
/// (range order = ascending k, so equal-key ties drain in ascending-k
/// order) and fold each output coordinate's products left-to-right from
/// `0.0` — exactly the scalar Gustavson accumulation. Exact zeros
/// (cancellation, including the `-0.0` corner) are dropped on emission,
/// matching the scalar kernel's `v != 0.0` filter.
fn accumulate_merged(runs: &[Vec<PartialProduct>], m: usize, n: usize) -> Csr {
    let mut row_ptr: Vec<u32> = Vec::with_capacity(m + 1);
    row_ptr.push(0);
    let mut col_idx: Vec<u32> = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    // rows [0, closed) have their end pointer pushed already
    let mut closed = 0usize;
    fn emit(
        key: u64,
        v: f32,
        closed: &mut usize,
        row_ptr: &mut Vec<u32>,
        col_idx: &mut Vec<u32>,
        vals: &mut Vec<f32>,
    ) {
        if v == 0.0 {
            return;
        }
        let i = (key >> 32) as usize;
        while *closed < i {
            row_ptr.push(col_idx.len() as u32);
            *closed += 1;
        }
        col_idx.push((key & 0xFFFF_FFFF) as u32);
        vals.push(v);
    }

    let mut cursors = vec![0usize; runs.len()];
    let mut pending: Option<(u64, f32)> = None;
    loop {
        let mut best: Option<(u64, usize)> = None;
        for (s, &c) in cursors.iter().enumerate() {
            if c < runs[s].len() {
                let key = runs[s][c].0;
                let better = match best {
                    None => true,
                    Some((bk, _)) => key < bk,
                };
                if better {
                    best = Some((key, s));
                }
            }
        }
        let Some((key, s)) = best else { break };
        let (_, p) = runs[s][cursors[s]];
        cursors[s] += 1;
        pending = Some(match pending {
            Some((k0, acc)) if k0 == key => (k0, acc + p),
            Some((k0, acc)) => {
                emit(k0, acc, &mut closed, &mut row_ptr, &mut col_idx, &mut vals);
                // first touch zeroes then adds — the scalar `0.0 + p`
                // sequence, so the `-0.0` bit never diverges
                (key, 0.0 + p)
            }
            None => (key, 0.0 + p),
        });
    }
    if let Some((k0, acc)) = pending {
        emit(k0, acc, &mut closed, &mut row_ptr, &mut col_idx, &mut vals);
    }
    while closed < m {
        row_ptr.push(col_idx.len() as u32);
        closed += 1;
    }
    Csr::from_parts(m, n, row_ptr, col_idx, vals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::formats::coo::Coo;
    use crate::spmm::gustavson;

    #[test]
    fn matches_gustavson_bitwise_across_fan_ins_and_workers() {
        for seed in 0..4 {
            let a = uniform(30, 40, 0.12, seed);
            let b = uniform(40, 26, 0.12, seed + 100);
            let (want, want_macs) = gustavson::multiply_counted(&a, &b);
            let want_bits = want.bit_pattern();
            for fan_in in [1usize, 2, 3, 7] {
                for workers in [1usize, 3] {
                    let pool = MergePool::new();
                    let cfg = OuterConfig { fan_in, workers };
                    let (c, macs, _) = multiply_counted(&a, &b, &cfg, &pool);
                    assert_eq!(
                        c.bit_pattern(),
                        want_bits,
                        "seed {seed}, fan_in {fan_in}, workers {workers}"
                    );
                    assert_eq!(macs, want_macs, "MAC accounting diverged");
                }
            }
        }
    }

    #[test]
    fn cancellation_drops_exact_zeros_like_the_scalar_kernel() {
        // C[0,0] = 1·1 + (-1)·1 folds to exactly 0.0 and must be dropped;
        // C[0,1] survives partial cancellation: (0 + 1 - 1) + 0.5 = 0.5
        let a = Csr::from_coo(&Coo::new(
            1,
            3,
            vec![(0, 0, 1.0), (0, 1, -1.0), (0, 2, 0.5)],
        ));
        let b = Csr::from_coo(&Coo::new(
            3,
            2,
            vec![(0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0), (2, 1, 1.0)],
        ));
        let (want, _) = gustavson::multiply_counted(&a, &b);
        let pool = MergePool::new();
        let (c, _, _) = multiply_counted(&a, &b, &OuterConfig::default(), &pool);
        assert_eq!(c.bit_pattern(), want.bit_pattern());
        assert_eq!(c.nnz(), 1, "cancelled cell must not be stored");
        assert_eq!(c.row(0), (&[1u32][..], &[0.5f32][..]));
    }

    #[test]
    fn empty_operands_produce_an_empty_result() {
        let a = uniform(5, 8, 0.0, 1);
        let b = uniform(8, 6, 0.5, 2);
        let pool = MergePool::new();
        let (c, macs, _) = multiply_counted(&a, &b, &OuterConfig::default(), &pool);
        assert_eq!(c.shape(), (5, 6));
        assert_eq!(c.nnz(), 0);
        assert_eq!(macs, 0);
    }

    #[test]
    fn merge_buffers_return_to_the_pool() {
        let a = uniform(24, 32, 0.2, 7);
        let b = uniform(32, 20, 0.2, 8);
        let pool = MergePool::new();
        let cfg = OuterConfig { fan_in: 2, workers: 1 };
        multiply_counted(&a, &b, &cfg, &pool);
        let allocated = pool.misses();
        assert!(allocated > 0);
        assert_eq!(pool.pooled() as u64, allocated, "buffers leaked from the pool");
        // a second multiply reuses parked buffers instead of allocating
        multiply_counted(&a, &b, &cfg, &pool);
        assert_eq!(pool.misses(), allocated, "second run re-allocated");
        assert!(pool.hits() > 0);
    }
}
