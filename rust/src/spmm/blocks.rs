//! 32×32 blocking of CSR matrices — the block-granular mirror of the
//! paper's comparator mesh for the TPU path (DESIGN.md §Hardware-Adaptation).
//!
//! A sparse matrix becomes a sorted list of non-empty `block × block` dense
//! tiles keyed by block coordinates. The planner intersects two block grids
//! along K exactly like the mesh's comparators intersect index streams,
//! at R=32 (= block) granularity.

use std::collections::BTreeMap;

use crate::formats::csr::Csr;
use crate::formats::traits::SparseMatrix;

/// A blocked matrix: non-empty tiles as dense row-major `block²` buffers.
#[derive(Clone, Debug)]
pub struct BlockGrid {
    pub block: usize,
    pub rows: usize,
    pub cols: usize,
    pub grid_rows: usize,
    pub grid_cols: usize,
    /// (block_row, block_col) -> dense tile, sorted by key (row-major).
    pub tiles: BTreeMap<(u32, u32), Vec<f32>>,
}

impl BlockGrid {
    /// Tile count (the "useful computation" density at block granularity).
    pub fn n_tiles(&self) -> usize {
        self.tiles.len()
    }

    /// Fraction of the block grid that is non-empty.
    pub fn block_density(&self) -> f64 {
        self.n_tiles() as f64 / (self.grid_rows * self.grid_cols).max(1) as f64
    }
}

/// Blockize a CSR matrix. Ragged edges are zero-padded inside the tile.
pub fn blockize(m: &Csr, block: usize) -> BlockGrid {
    let (rows, cols) = m.shape();
    let grid_rows = (rows + block - 1) / block;
    let grid_cols = (cols + block - 1) / block;
    let mut tiles: BTreeMap<(u32, u32), Vec<f32>> = BTreeMap::new();
    for i in 0..rows {
        let bi = (i / block) as u32;
        let ri = i % block;
        let (cs, vs) = m.row(i);
        for (&c, &v) in cs.iter().zip(vs) {
            let bj = (c as usize / block) as u32;
            let cj = c as usize % block;
            tiles
                .entry((bi, bj))
                .or_insert_with(|| vec![0.0f32; block * block])[ri * block + cj] = v;
        }
    }
    BlockGrid {
        block,
        rows,
        cols,
        grid_rows,
        grid_cols,
        tiles,
    }
}

/// Per-block-row tile-pair counts for `A × B` at `block` granularity,
/// computed from structure alone (no tile materialization): the weight of
/// block row `bi` is `Σ_{bk : A has a tile at (bi, bk)} |B tiles in K-row
/// bk|` — exactly the number of tile pairs the tiled executor schedules
/// for that band of output rows. `engine::shard`'s planner cuts contiguous
/// row bands with balanced totals over these weights, the same heuristic
/// `engine::tiled` applies per output tile.
pub fn block_row_pair_weights(a: &Csr, b: &Csr, block: usize) -> Vec<usize> {
    let grid_rows_a = (a.rows() + block - 1) / block;
    let grid_k = ((a.cols().max(b.rows())) + block - 1) / block;
    let grid_cols_b = (b.cols() + block - 1) / block;

    // |{bj : B has a tile at (bk, bj)}| per K block-row. Rows are visited
    // in order, so `bk` is non-decreasing and a stamp array dedups tiles.
    let mut b_tiles_per_k = vec![0usize; grid_k];
    let mut stamp = vec![usize::MAX; grid_cols_b.max(1)];
    for i in 0..b.rows() {
        let bk = i / block;
        let (cols, _) = b.row(i);
        for &c in cols {
            let bj = c as usize / block;
            if stamp[bj] != bk {
                stamp[bj] = bk;
                b_tiles_per_k[bk] += 1;
            }
        }
    }

    let mut weights = vec![0usize; grid_rows_a];
    let mut stamp_a = vec![usize::MAX; grid_k.max(1)];
    for i in 0..a.rows() {
        let bi = i / block;
        let (cols, _) = a.row(i);
        for &c in cols {
            let bk = c as usize / block;
            if stamp_a[bk] != bi {
                stamp_a[bk] = bi;
                weights[bi] += b_tiles_per_k[bk];
            }
        }
    }
    weights
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::formats::coo::Coo;

    #[test]
    fn tiles_cover_all_nonzeros() {
        let m = uniform(37, 53, 0.1, 1);
        let g = blockize(&m, 16);
        assert_eq!(g.grid_rows, 3);
        assert_eq!(g.grid_cols, 4);
        let total: usize = g
            .tiles
            .values()
            .map(|t| t.iter().filter(|&&v| v != 0.0).count())
            .sum();
        assert_eq!(total, m.nnz());
    }

    #[test]
    fn tile_contents_match_source() {
        let m = Csr::from_coo(&Coo::new(
            5,
            5,
            vec![(0, 0, 1.0), (1, 3, 2.0), (4, 4, 3.0)],
        ));
        let g = blockize(&m, 2);
        assert_eq!(g.tiles[&(0, 0)][0], 1.0); // (0,0) within tile (0,0)
        assert_eq!(g.tiles[&(0, 1)][1 * 2 + 1], 2.0); // (1,3) -> tile (0,1) cell (1,1)
        assert_eq!(g.tiles[&(2, 2)][0], 3.0); // (4,4) -> tile (2,2) cell (0,0)
        assert_eq!(g.n_tiles(), 3);
    }

    #[test]
    fn empty_blocks_are_absent() {
        let m = uniform(64, 64, 0.001, 2);
        let g = blockize(&m, 32);
        assert!(g.n_tiles() <= m.nnz().max(1));
        assert!(g.block_density() <= 1.0);
    }

    #[test]
    fn pair_weights_match_materialized_grids() {
        let a = uniform(70, 90, 0.08, 3);
        let b = uniform(90, 50, 0.12, 4);
        let block = 16;
        let weights = block_row_pair_weights(&a, &b, block);
        // reference: count tile pairs per A block-row from the real grids
        let ga = blockize(&a, block);
        let gb = blockize(&b, block);
        let mut b_per_k = vec![0usize; gb.grid_rows];
        for &(bk, _) in gb.tiles.keys() {
            b_per_k[bk as usize] += 1;
        }
        let mut want = vec![0usize; ga.grid_rows];
        for &(bi, bk) in ga.tiles.keys() {
            want[bi as usize] += b_per_k[bk as usize];
        }
        assert_eq!(weights, want);
        assert_eq!(
            weights.iter().sum::<usize>(),
            ga.tiles
                .keys()
                .map(|&(_, bk)| b_per_k[bk as usize])
                .sum::<usize>()
        );
    }

    #[test]
    fn pair_weights_handle_empty_operands() {
        let a = uniform(20, 30, 0.0, 1);
        let b = uniform(30, 20, 0.3, 2);
        assert!(block_row_pair_weights(&a, &b, 8).iter().all(|&w| w == 0));
        assert_eq!(block_row_pair_weights(&a, &b, 8).len(), 3);
    }
}
