//! Dense reference multiply — the numeric oracle every other SpMM path
//! (Gustavson, inner-product, mesh functional sim, PJRT block kernel) is
//! checked against.

use crate::formats::csr::Csr;
use crate::formats::dense::Dense;
use crate::formats::traits::SparseMatrix;

/// C = A × B via row-expansion of the CSR operands (exact, simple).
pub fn multiply(a: &Csr, b: &Csr) -> Dense {
    assert_eq!(a.cols(), b.rows(), "inner dimensions");
    let (m, n) = (a.rows(), b.cols());
    let mut c = Dense::zeros(m, n);
    for i in 0..m {
        let (a_cols, a_vals) = a.row(i);
        for (&k, &av) in a_cols.iter().zip(a_vals) {
            let (b_cols, b_vals) = b.row(k as usize);
            for (&j, &bv) in b_cols.iter().zip(b_vals) {
                *c.at_mut(i, j as usize) += av * bv;
            }
        }
    }
    c
}

/// Dense × dense (used by the conventional-MM numeric twin tests).
pub fn multiply_dense(a: &Dense, b: &Dense) -> Dense {
    let (m, k) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2);
    let mut c = Dense::zeros(m, n);
    for i in 0..m {
        for kk in 0..k {
            let av = a.at(i, kk);
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                *c.at_mut(i, j) += av * b.at(kk, j);
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::formats::coo::Coo;

    #[test]
    fn hand_example() {
        // [1 2] [5 6]   [19 22]
        // [3 4]×[7 8] = [43 50]
        let a = Csr::from_coo(&Coo::new(
            2,
            2,
            vec![(0, 0, 1.0), (0, 1, 2.0), (1, 0, 3.0), (1, 1, 4.0)],
        ));
        let b = Csr::from_coo(&Coo::new(
            2,
            2,
            vec![(0, 0, 5.0), (0, 1, 6.0), (1, 0, 7.0), (1, 1, 8.0)],
        ));
        let c = multiply(&a, &b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn csr_and_dense_paths_agree() {
        let a = uniform(9, 14, 0.4, 1);
        let b = uniform(14, 7, 0.4, 2);
        let c1 = multiply(&a, &b);
        let c2 = multiply_dense(
            &crate::formats::dense::Dense::from_coo(&a.to_coo()),
            &crate::formats::dense::Dense::from_coo(&b.to_coo()),
        );
        assert!(c1.max_abs_diff(&c2) < 1e-4);
    }

    #[test]
    #[should_panic(expected = "inner dimensions")]
    fn dimension_check() {
        let a = uniform(2, 3, 0.5, 1);
        let b = uniform(4, 2, 0.5, 2);
        multiply(&a, &b);
    }
}
