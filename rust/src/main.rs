//! `spmm-accel` CLI — leader entrypoint.
//!
//! Subcommands:
//!   exp        run a paper experiment (table1|table2|fig3|table4|fig4a|fig4b|fig5|table5|engines|all)
//!   gen        generate a synthetic dataset and write MatrixMarket
//!   convert    convert a MatrixMarket file between sparse formats (reports storage)
//!   locate     measure random-access cost of every format on a dataset
//!   spmm       run one SpMM job through the coordinator (any registered kernel)
//!   worker     join a leader as a remote shard worker (socket transport)
//!   serve      start the batching server and drive a synthetic workload
//!   kernels    list the registered (format, algorithm) kernels + cost hints
//!   info       print artifact/runtime info

use std::path::PathBuf;
use std::sync::Arc;

use spmm_accel::coordinator::{
    AdmissionConfig, CoalesceConfig, JobError, JobHandle, KernelSpec, LearnConfig, Server,
    ServerConfig,
};
use spmm_accel::datasets;
use spmm_accel::engine::{Algorithm, Registry, SpmmKernel};
use spmm_accel::eval::{run_experiment, ExpOptions};
use spmm_accel::formats::traits::{FormatKind, SparseMatrix};
use spmm_accel::formats::{Csr, MatrixOperand};
use spmm_accel::runtime::Manifest;
use spmm_accel::spmm::plan::Geometry;
use spmm_accel::util::args::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn exp_options(args: &Args) -> Result<ExpOptions, String> {
    Ok(ExpOptions {
        seed: args.get_or("seed", 42u64)?,
        scale: args.get_or("scale", 1.0f64)?,
    })
}

/// `--a-format/--b-format <kind>`: render a generated CSR operand into the
/// named native format (any `FormatKind` name via the typed parse) so
/// non-CSR ingestion is exercisable straight from the CLI. `None` keeps
/// the zero-cost CSR handle.
fn operand_in_format(m: Arc<Csr>, fmt: Option<&str>) -> Result<MatrixOperand, String> {
    let op = MatrixOperand::from(m);
    match fmt {
        None => Ok(op),
        Some(name) => Ok(op.convert(FormatKind::parse(name)?)?),
    }
}

/// `--kernel <auto|algorithm>` + `--format <fmt>` + legacy `--backend
/// <pjrt|cpu>` → the server's kernel spec and PJRT preference.
fn parse_kernel_spec(args: &Args) -> Result<(KernelSpec, bool), String> {
    let prefer_pjrt = match args.str_or("backend", "cpu") {
        "pjrt" => true,
        "cpu" => false,
        other => return Err(format!("unknown backend {other:?} (pjrt|cpu)")),
    };
    let spec = match args.str_or("kernel", "block") {
        "auto" => KernelSpec::Auto,
        name => {
            let alg = Algorithm::parse(name)?;
            match args.str_opt("format") {
                // explicit --format overrides the registry's default key
                Some(f) => KernelSpec::Fixed(spmm_accel::formats::parse_kind(f)?, alg),
                None => KernelSpec::for_algorithm(alg),
            }
        }
    };
    Ok((spec, prefer_pjrt))
}

fn run(cmd: &str, args: &Args) -> Result<(), String> {
    match cmd {
        "exp" => {
            let id = args
                .str_opt("id")
                .or_else(|| args.positional.get(1).map(String::as_str))
                .ok_or("usage: spmm-accel exp --id <table1|table2|fig3|table4|fig4a|fig4b|fig5|table5|engines|selection|all> [--scale F] [--seed N] [--save DIR]")?;
            let opts = exp_options(args)?;
            let results = run_experiment(id, opts)?;
            for r in &results {
                r.print();
                if let Some(dir) = args.str_opt("save") {
                    let p = r
                        .save(std::path::Path::new(dir))
                        .map_err(|e| e.to_string())?;
                    eprintln!("saved {}", p.display());
                }
            }
            Ok(())
        }
        "gen" => {
            let name = args
                .str_opt("dataset")
                .ok_or("usage: spmm-accel gen --dataset <name> --out <file.mtx> [--seed N]")?;
            let out = args.str_opt("out").ok_or("missing --out")?;
            let seed = args.get_or("seed", 42u64)?;
            let m = datasets::load(name, None, seed)?;
            datasets::mtx::write(&m.to_coo(), std::path::Path::new(out))?;
            println!(
                "wrote {}: {}x{} nnz={} D={:.3}%",
                out,
                m.rows(),
                m.cols(),
                m.nnz(),
                m.density() * 100.0
            );
            Ok(())
        }
        "convert" => {
            let input = args.str_opt("in").ok_or("usage: spmm-accel convert --in <file.mtx> --to <format> [--out <file.mtx>]")?;
            let to = spmm_accel::formats::parse_kind(args.str_or("to", "incrs"))?;
            let coo = datasets::mtx::read(std::path::Path::new(input))?;
            let m = spmm_accel::formats::from_coo(to, &coo)?;
            println!(
                "{}: {}x{} nnz={} storage={} words ({}b/nz)",
                m.kind().name(),
                m.rows(),
                m.cols(),
                m.nnz(),
                m.storage_words(),
                m.storage_words() * 4 / m.nnz().max(1)
            );
            if let Some(out) = args.str_opt("out") {
                datasets::mtx::write(&m.to_coo(), std::path::Path::new(out))?;
            }
            Ok(())
        }
        "locate" => {
            let opts = exp_options(args)?;
            let r = spmm_accel::eval::table1::run(opts);
            r.print();
            Ok(())
        }
        "spmm" => {
            let seed = args.get_or("seed", 42u64)?;
            let rows = args.get_or("rows", 256usize)?;
            let cols = args.get_or("cols", 256usize)?;
            let density = args.get_or("density", 0.05f64)?;
            let (kernel, prefer_pjrt) = parse_kernel_spec(args)?;
            // non-CSR ingestion from the CLI: --a-format/--b-format render
            // the generated operands into any Table-I format before submit
            let a = operand_in_format(
                Arc::new(datasets::uniform(rows, cols, density, seed)),
                args.str_opt("a-format"),
            )?;
            let b = operand_in_format(
                Arc::new(datasets::uniform(cols, rows, density, seed + 1)),
                args.str_opt("b-format"),
            )?;
            let (a_fmt, b_fmt) = (a.format(), b.format());
            let shards = args.get_or("shards", 1usize)?;
            // --transport socket --peers host:port[,host:port…] routes the
            // job's row bands to remote `worker` processes
            let remote_peers = match args.str_or("transport", "in-process") {
                "socket" => args
                    .list::<String>("peers")?
                    .filter(|p| !p.is_empty())
                    .ok_or("--transport socket needs --peers host:port[,host:port…]")?,
                "in-process" => Vec::new(),
                other => return Err(format!("unknown transport {other:?} (in-process|socket)")),
            };
            let remote = !remote_peers.is_empty();
            let server = Server::start(ServerConfig {
                workers: 1,
                kernel,
                prefer_pjrt,
                tile_workers: args.get_or("tile-workers", 4usize)?,
                remote_peers,
                ..Default::default()
            });
            let client = server.client();
            let out = client
                .job(a.clone(), b.clone())
                .verify(true)
                // the remote path keeps the dense result so it can be
                // bit-checked against a local run below
                .keep_result(remote)
                .shards(shards)
                .submit()?
                .wait()?;
            println!(
                "backend={} a={} b={} shards={} dispatches={} real_pairs={} wall={:?} max_err={:?}",
                out.backend,
                a_fmt.name(),
                b_fmt.name(),
                out.shards,
                out.report.dispatches,
                out.report.real_pairs,
                out.wall,
                out.max_err
            );
            if out.shards < out.shards_requested {
                println!(
                    "note: planner clamped {} requested shards to {} band(s)",
                    out.shards_requested, out.shards
                );
            }
            if remote {
                // same job, unsharded, on the leader: remote execution must
                // be bit-identical, not merely within verify tolerance
                let local = client
                    .job(a, b)
                    .keep_result(true)
                    .shards(1)
                    .submit()?
                    .wait()?;
                let (remote_c, local_c) = (out.c.as_ref(), local.c.as_ref());
                let identical = match (remote_c, local_c) {
                    (Some(r), Some(l)) => r.bit_pattern() == l.bit_pattern(),
                    _ => false,
                };
                if !identical {
                    return Err("remote result is NOT bit-identical to the local run".into());
                }
                println!("remote result bit-identical to local: ok");
            }
            let snap = client.metrics();
            if snap.operand_conversions > 0 {
                println!(
                    "ingestion: {} operand conversion(s) to canonical CRS",
                    snap.operand_conversions
                );
            }
            if shards > 1 {
                println!(
                    "shard metrics: {} bands, wall p50={}us p99={}us, queue p50={}us",
                    snap.shards_executed,
                    snap.shard_wall_p50_us,
                    snap.shard_wall_p99_us,
                    snap.shard_queue_p50_us
                );
            }
            if remote {
                println!(
                    "transport: {} remote band(s), {} retries, {} hedges won, \
                     {} worker(s) lost, {} B replication(s), {} staged reuse(s)",
                    snap.remote_bands,
                    snap.band_retries,
                    snap.hedges_won,
                    snap.workers_lost,
                    snap.prepare_replications,
                    snap.prepare_reuse
                );
            }
            drop(client);
            server.shutdown();
            Ok(())
        }
        "worker" => {
            // remote shard worker: bind, print the bound address (the CI
            // smoke scrapes it), serve leaders until killed
            let listen = args.str_or("listen", "127.0.0.1:7070");
            let geom = Geometry::default();
            let reg = Arc::new(Registry::with_default_kernels(
                geom,
                args.get_or("tile-workers", 4usize)?,
            ));
            let listener = std::net::TcpListener::bind(listen)
                .map_err(|e| format!("worker bind {listen}: {e}"))?;
            let bound = listener
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| listen.to_string());
            println!("worker listening on {bound} ({} kernels)", reg.len());
            spmm_accel::engine::remote::serve(listener, reg).map_err(|e| e.to_string())
        }
        "serve" => {
            let workers = args.get_or("workers", 2usize)?;
            let jobs = args.get_or("jobs", 16usize)?;
            let (kernel, prefer_pjrt) = parse_kernel_spec(args)?;
            let coalesce = CoalesceConfig {
                enabled: !args.has("no-coalesce"),
                ..Default::default()
            };
            // learned selection: --model-path enables persistence (and the
            // shutdown refit); --refit-every controls the in-flight cadence
            let learn = LearnConfig {
                refit_every: args.get_or("refit-every", 8u64)?,
                margin: args.get_or("margin", 0.1f64)?,
                model_path: args.str_opt("model-path").map(PathBuf::from),
                ..Default::default()
            };
            // --max-queue-delay <ms> arms the admission gate: submissions
            // predicted to wait longer are shed with a typed Overloaded
            // error (and a retry-after hint) instead of blocking
            let admission = AdmissionConfig {
                max_queue_delay: args
                    .get::<u64>("max-queue-delay")?
                    .map(std::time::Duration::from_millis),
                ..Default::default()
            };
            let server = Server::start(ServerConfig {
                workers,
                queue_depth: 8,
                kernel,
                prefer_pjrt,
                geometry: Geometry::default(),
                tile_workers: args.get_or("tile-workers", 1usize)?,
                artifacts_dir: Manifest::default_dir(),
                coalesce,
                learn,
                admission,
                ..Default::default()
            });
            let client = server.client();
            let a = Arc::new(datasets::uniform(256, 256, 0.03, 1));
            let t0 = std::time::Instant::now();
            // all jobs share one B: the coalescer builds PreparedB once per
            // worker and the LRU keeps it across micro-batches
            let batch = (0..jobs as u64)
                .map(|i| client.job(a.clone(), a.clone()).id(i).keep_result(false).build());
            let handles = client.submit_many(batch);
            let mut shed_errs = 0u64;
            for res in JobHandle::batch_wait_all(handles) {
                match res {
                    Ok(_) => {}
                    // under an armed gate, sheds are expected traffic
                    // management, not a CLI failure — report and go on
                    Err(e @ JobError::Overloaded { .. }) => {
                        shed_errs += 1;
                        eprintln!("job shed: {e}");
                    }
                    Err(e) => return Err(format!("job failed: {e}")),
                }
            }
            let snap = client.metrics();
            println!(
                "{} jobs on {} workers ({kernel:?}) in {:?}: p50={}us p99={}us \
                 queue p50={}us dispatches={}",
                snap.jobs_completed,
                workers,
                t0.elapsed(),
                snap.p50_us,
                snap.p99_us,
                snap.queue_p50_us,
                snap.dispatches
            );
            println!(
                "coalescing({}): {} PreparedB builds for {} jobs, {} cache hits, \
                 {} jobs rode shared prepares",
                if coalesce.enabled { "on" } else { "off" },
                snap.prepare_builds,
                snap.jobs_completed,
                snap.prepare_cache_hits,
                snap.coalesced_jobs
            );
            if snap.workspace_pool_hits + snap.workspace_pool_misses > 0 {
                println!(
                    "workspace pool: {} reuses / {} allocations across the run",
                    snap.workspace_pool_hits, snap.workspace_pool_misses
                );
            }
            println!(
                "kernel log: {} (cost_hint, ingest_cost, wall) observations recorded \
                 (Metrics::kernel_log)",
                snap.kernel_observations
            );
            if snap.jobs_shed + snap.deadline_drops + snap.workers_readmitted + shed_errs > 0 {
                println!(
                    "traffic: {} shed (admission), {} deadline drops, {} workers readmitted",
                    snap.jobs_shed, snap.deadline_drops, snap.workers_readmitted
                );
            }
            if snap.model_refits > 0 {
                println!(
                    "learned selection: {} model refit(s), calibrated kernels:",
                    snap.model_refits
                );
                for c in server.metrics.calibration() {
                    println!(
                        "  ({:>7}, {:>9}) scale~{:.3e} us/unit over {} samples, err~{:.1}us",
                        c.format.name(),
                        c.algorithm.name(),
                        c.scale,
                        c.samples,
                        c.mean_abs_err_us
                    );
                }
            }
            drop(client);
            server.shutdown();
            Ok(())
        }
        "kernels" => {
            let geom = Geometry::default();
            let reg = Registry::with_default_kernels(
                geom,
                args.get_or("tile-workers", 4usize)?,
            );
            let a = datasets::uniform(256, 512, 0.05, 1);
            let b = datasets::uniform(512, 256, 0.05, 2);
            println!("registered kernels (cost hints on 256x512x256 @ 5%):");
            for k in reg.kernels() {
                let h = k.cost_hint(&a, &b);
                println!(
                    "  ({:>7}, {:>9}) {:<12} flops~{:.3e} prepare~{:.3e}",
                    k.format().name(),
                    k.algorithm().name(),
                    k.name(),
                    h.flops,
                    h.prepare_words
                );
            }
            let sel = reg.select(&a, &b).expect("non-empty registry");
            println!("auto-select would pick: {}", sel.name());
            Ok(())
        }
        "trace" => {
            // export the column-order access trace of a dataset for gem5
            let name = args.str_or("dataset", "docword");
            let out = args.str_opt("out").ok_or("usage: spmm-accel trace --dataset <name> --format <crs|incrs> --out <file> [--cols N]")?;
            let fmt = args.str_or("format", "incrs");
            let seed = args.get_or("seed", 42u64)?;
            let m = datasets::load(name, None, seed)?;
            let col_limit = args.get::<usize>("cols")?;
            let mut t = spmm_accel::cachesim::TraceSink::new();
            match fmt {
                "crs" => {
                    spmm_accel::access::read_columns_csr(&m, col_limit, &mut t);
                }
                "incrs" => {
                    let incrs = spmm_accel::formats::InCrs::from_csr(&m)?;
                    spmm_accel::access::read_columns_incrs(&incrs, col_limit, &mut t);
                }
                other => return Err(format!("unknown format {other:?} (crs|incrs)")),
            }
            let f = std::fs::File::create(out).map_err(|e| e.to_string())?;
            let mut w = std::io::BufWriter::new(f);
            t.export(&mut w).map_err(|e| e.to_string())?;
            println!("wrote {} accesses ({fmt}, {name}) to {out}", t.len());
            Ok(())
        }
        "info" => {
            let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
            match Manifest::load(&dir) {
                Ok(m) => {
                    println!(
                        "artifacts at {:?}: block={} pairs={} slots={} dense_dim={}",
                        dir, m.block, m.pairs, m.slots, m.dense_dim
                    );
                    for (name, e) in &m.artifacts {
                        println!("  {name}: {:?} ({} args)", e.file.file_name().unwrap(), e.args.len());
                    }
                }
                Err(e) => println!("no artifacts: {e}"),
            }
            Ok(())
        }
        _ => {
            println!(
                "spmm-accel — InCRS + synchronized systolic SpMM (Golnari & Malik 2019)\n\
                 \n\
                 usage: spmm-accel <exp|gen|convert|locate|spmm|worker|serve|kernels|info> [flags]\n\
                 \n\
                 algorithms (--kernel): dense | gustavson | gustavson-fast | inner | outer \
                 | tiled | block | auto\n\
                 \n\
                 examples:\n\
                 \u{20}  spmm-accel exp --id table2\n\
                 \u{20}  spmm-accel exp --id engines --scale 0.5\n\
                 \u{20}  spmm-accel exp --id selection --scale 0.5   # learned-selection calibration\n\
                 \u{20}  spmm-accel gen --dataset docword --out /tmp/docword.mtx\n\
                 \u{20}  spmm-accel spmm --rows 512 --cols 512 --density 0.05 --kernel tiled --tile-workers 4\n\
                 \u{20}  spmm-accel spmm --kernel gustavson-fast --tile-workers 4   # vectorized pooled Gustavson\n\
                 \u{20}  spmm-accel spmm --kernel tiled --shards 4   # row-band sharded execution\n\
                 \u{20}  spmm-accel worker --listen 127.0.0.1:7070   # remote shard worker\n\
                 \u{20}  spmm-accel spmm --kernel tiled --shards 4 --transport socket \
                 --peers 127.0.0.1:7070   # cross-host sharding (bit-checked vs local)\n\
                 \u{20}  spmm-accel spmm --kernel outer --shards 2 --b-format csc   # outer-product merge (hyper-sparse)\n\
                 \u{20}  spmm-accel spmm --kernel inner --format incrs\n\
                 \u{20}  spmm-accel spmm --a-format coo --b-format incrs   # non-CSR operand ingestion\n\
                 \u{20}  spmm-accel serve --workers 4 --jobs 32 --kernel auto [--no-coalesce]\n\
                 \u{20}  spmm-accel serve --workers 2 --jobs 64 --max-queue-delay 5   # admission \
                 control: shed past a 5ms predicted queue delay\n\
                 \u{20}  spmm-accel serve --kernel auto --model-path /tmp/cost.model --refit-every 8 \
                 --margin 0.1\n\
                 \u{20}  spmm-accel kernels"
            );
            Ok(())
        }
    }
}
