//! Column-order read drivers — the Fig 3 workload.
//!
//! The paper's SpMM memory experiment simplifies the first operand to a
//! vector and measures the column-order traversal of the second operand `B`
//! stored row-ordered (CRS vs InCRS): "to read one column of data stored in
//! a row-based format, many of the non-zeros of each row are accessed to
//! locate the elements of that column" (§II). The driver probes every
//! (row, col) cell in column-major order via `locate`, exactly the paper's
//! per-element access model, and can stream the resulting addresses into
//! either a counting sink (Table II "MA ratio") or the cache simulator
//! (Fig 3).

use crate::formats::csr::Csr;
use crate::formats::incrs::InCrs;
use crate::formats::traits::{AccessSink, SparseMatrix};

/// Result of one full column-order traversal.
#[derive(Clone, Copy, Debug, Default)]
pub struct ColumnReadStats {
    pub cells_probed: u64,
    pub nonzeros_found: u64,
}

/// Generic column-order traversal over any format with a monomorphized
/// locate.
///
/// `col_limit` restricts how many columns are probed, but the probed columns
/// are spread evenly across the FULL column range (stride sampling): the
/// paper resized datasets by removing *rows* and explicitly kept all columns
/// ("the columns' lengths and distributions of non-zeros are important
/// factors"), and probing a prefix would bias CRS scans short.
pub fn read_columns<M, S, F>(
    m: &M,
    locate: F,
    col_limit: Option<usize>,
    sink: &mut S,
) -> ColumnReadStats
where
    M: SparseMatrix,
    S: AccessSink,
    F: Fn(&M, usize, usize, &mut S) -> Option<f32>,
{
    let (rows, cols) = m.shape();
    let n_probe = col_limit.unwrap_or(cols).min(cols);
    let mut stats = ColumnReadStats::default();
    for t in 0..n_probe {
        let j = t * cols / n_probe;
        for i in 0..rows {
            stats.cells_probed += 1;
            if locate(m, i, j, sink).is_some() {
                stats.nonzeros_found += 1;
            }
        }
    }
    stats
}

/// Column-order traversal of a CRS matrix (the paper's baseline).
pub fn read_columns_csr<S: AccessSink>(
    m: &Csr,
    col_limit: Option<usize>,
    sink: &mut S,
) -> ColumnReadStats {
    read_columns(m, |m, i, j, s| m.locate(i, j, s), col_limit, sink)
}

/// Column-order traversal of an InCRS matrix (the paper's proposal).
pub fn read_columns_incrs<S: AccessSink>(
    m: &InCrs,
    col_limit: Option<usize>,
    sink: &mut S,
) -> ColumnReadStats {
    read_columns(m, |m, i, j, s| m.locate(i, j, s), col_limit, sink)
}

/// SpMV v×B with column-order access to B — the full Fig 3 kernel, including
/// the (dense) input-vector and output accesses so "total run time" has the
/// same composition as the paper's gem5 runs.
pub fn spmv_column_order<S: AccessSink, F>(
    rows: usize,
    cols: usize,
    v_base: u64,
    out_base: u64,
    mut locate: F,
    sink: &mut S,
) -> u64
where
    F: FnMut(usize, usize, &mut S) -> Option<f32>,
{
    use crate::formats::traits::Site;
    let mut macs = 0u64;
    for j in 0..cols {
        let mut acc = 0.0f32;
        for i in 0..rows {
            if let Some(b) = locate(i, j, sink) {
                sink.touch(v_base + 4 * i as u64, Site::Dense);
                acc += b; // v[i]*b; value of v irrelevant to access counts
                macs += 1;
            }
        }
        let _ = acc;
        sink.touch(out_base + 4 * j as u64, Site::Dense);
    }
    macs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::formats::incrs::InCrs;
    use crate::formats::traits::CountSink;

    #[test]
    fn traversal_finds_every_nonzero() {
        let csr = uniform(40, 300, 0.06, 21);
        let incrs = InCrs::from_csr(&csr).unwrap();
        let mut s1 = CountSink::default();
        let st1 = read_columns_csr(&csr, None, &mut s1);
        let mut s2 = CountSink::default();
        let st2 = read_columns_incrs(&incrs, None, &mut s2);
        assert_eq!(st1.nonzeros_found as usize, csr.nnz());
        assert_eq!(st2.nonzeros_found as usize, csr.nnz());
        assert_eq!(st1.cells_probed, 40 * 300);
    }

    #[test]
    fn incrs_reduces_accesses_by_the_predicted_factor() {
        // docword-like slice: the Table II mechanism at small scale
        let csr = uniform(60, 2048, 0.04, 5);
        let incrs = InCrs::from_csr(&csr).unwrap();
        let mut s_crs = CountSink::default();
        read_columns_csr(&csr, None, &mut s_crs);
        let mut s_in = CountSink::default();
        read_columns_incrs(&incrs, None, &mut s_in);
        let ratio = s_crs.total as f64 / s_in.total as f64;
        // CRS ≈ ½·N·D ≈ 41 accesses/probe; InCRS ≈ 2.3 → ratio >> 5
        assert!(ratio > 5.0, "MA ratio {ratio}");
    }

    #[test]
    fn col_limit_truncates() {
        let csr = uniform(10, 100, 0.1, 6);
        let mut s = CountSink::default();
        let st = read_columns_csr(&csr, Some(7), &mut s);
        assert_eq!(st.cells_probed, 70);
    }

    #[test]
    fn spmv_counts_macs() {
        let csr = uniform(20, 50, 0.2, 8);
        let mut s = CountSink::default();
        let macs = spmv_column_order(
            20,
            50,
            1 << 40,
            (1 << 40) + 4096,
            |i, j, sink| csr.locate(i, j, sink),
            &mut s,
        );
        assert_eq!(macs as usize, csr.nnz());
        // output written once per column
        assert!(s.total > 0);
    }
}
