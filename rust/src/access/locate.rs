//! Random-access cost measurement (paper Table I): average memory accesses
//! to locate one arbitrary element in each format.

use crate::formats::traits::{CountSink, SparseMatrix};
use crate::util::rng::Rng;

/// Measured locate cost for one format.
#[derive(Clone, Debug)]
pub struct LocateCost {
    pub format: &'static str,
    pub probes: u64,
    pub total_accesses: u64,
    pub hits: u64,
    /// Analytic expectation per the paper's Table I (None for dense/CCS/InCRS
    /// where the paper gives the closed forms elsewhere).
    pub analytic: Option<f64>,
}

impl LocateCost {
    pub fn avg(&self) -> f64 {
        self.total_accesses as f64 / self.probes.max(1) as f64
    }
}

/// Paper Table I closed forms, in the same notation (M rows, N cols, D
/// density, b InCRS block width).
pub fn analytic_cost(m: &dyn SparseMatrix) -> Option<f64> {
    use crate::formats::traits::FormatKind::*;
    let (rows, cols) = m.shape();
    let d = m.density();
    let n = cols as f64;
    match m.kind() {
        Ellpack | Lil | Csr => Some(0.5 * n * d),
        Jad => Some(n * d),
        Coo | Sll => Some(0.5 * rows as f64 * n * d),
        Dense => Some(1.0),
        Csc => Some(0.5 * rows as f64 * d),
        InCrs => Some(crate::formats::incrs::BLOCK as f64 / 2.0 + 1.0),
    }
}

/// Probe `probes` uniformly random (i, j) cells and return the measured
/// average access count. Probing uniformly over *all* cells (hit or miss)
/// matches the paper's "read one arbitrary element" model.
pub fn measure(m: &dyn SparseMatrix, probes: u64, seed: u64) -> LocateCost {
    let (rows, cols) = m.shape();
    let mut rng = Rng::new(seed);
    let mut sink = CountSink::default();
    let mut hits = 0u64;
    for _ in 0..probes {
        let i = rng.usize_below(rows);
        let j = rng.usize_below(cols);
        if m.locate_dyn(i, j, &mut sink).is_some() {
            hits += 1;
        }
    }
    LocateCost {
        format: m.kind().name(),
        probes,
        total_accesses: sink.total,
        hits,
        analytic: analytic_cost(m),
    }
}

/// Probe only cells that are known non-zero (locate cost conditional on a
/// hit — the quantity InCRS's b/2+1 estimate describes).
pub fn measure_hits(m: &dyn SparseMatrix, probes: u64, seed: u64) -> LocateCost {
    let coo = m.to_coo();
    let nnz = coo.entries.len();
    let mut rng = Rng::new(seed);
    let mut sink = CountSink::default();
    let mut hits = 0u64;
    for _ in 0..probes {
        let (i, j, _) = coo.entries[rng.usize_below(nnz)];
        if m.locate_dyn(i as usize, j as usize, &mut sink).is_some() {
            hits += 1;
        }
    }
    assert_eq!(hits, probes, "{}: probe of a known non-zero missed", m.kind().name());
    LocateCost {
        format: m.kind().name(),
        probes,
        total_accesses: sink.total,
        hits,
        analytic: analytic_cost(m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::formats::convert::{from_coo, ALL_KINDS};
    use crate::formats::traits::{FormatKind, SparseMatrix};

    #[test]
    fn measured_tracks_analytic_for_crs() {
        let csr = uniform(64, 512, 0.08, 3);
        let coo = csr.to_coo();
        let m = from_coo(FormatKind::Csr, &coo).unwrap();
        let cost = measure(m.as_ref(), 4000, 7);
        let analytic = cost.analytic.unwrap(); // 0.5*N*D ≈ 20.5
        // locate also touches ptr + val; allow generous band
        let avg = cost.avg();
        assert!(
            avg > 0.5 * analytic && avg < 2.5 * analytic,
            "avg {avg} vs analytic {analytic}"
        );
    }

    #[test]
    fn incrs_is_cheapest_sparse_format() {
        let csr = uniform(48, 2048, 0.05, 11);
        let coo = csr.to_coo();
        let mut costs = std::collections::BTreeMap::new();
        for kind in ALL_KINDS {
            let m = from_coo(kind, &coo).unwrap();
            costs.insert(kind, measure(m.as_ref(), 1500, 5).avg());
        }
        let incrs = costs[&FormatKind::InCrs];
        for (&kind, &c) in &costs {
            if kind != FormatKind::Dense && kind != FormatKind::InCrs && kind != FormatKind::Csc {
                assert!(
                    incrs < c,
                    "InCRS {incrs} should beat {:?} {c}",
                    kind
                );
            }
        }
        assert!(costs[&FormatKind::Dense] <= 1.0 + 1e-9);
    }

    #[test]
    fn table1_ordering_holds() {
        // COO/SLL (O(M·N·D)) must cost far more than row-based formats.
        let csr = uniform(32, 256, 0.1, 2);
        let coo = csr.to_coo();
        let crs_cost = measure(from_coo(FormatKind::Csr, &coo).unwrap().as_ref(), 800, 1).avg();
        let coo_cost = measure(from_coo(FormatKind::Coo, &coo).unwrap().as_ref(), 800, 1).avg();
        let jad_cost = measure(from_coo(FormatKind::Jad, &coo).unwrap().as_ref(), 800, 1).avg();
        assert!(coo_cost > 4.0 * crs_cost, "coo {coo_cost} vs crs {crs_cost}");
        assert!(jad_cost > 1.2 * crs_cost, "jad {jad_cost} vs crs {crs_cost}");
    }

    #[test]
    fn measure_hits_always_hits() {
        let csr = uniform(16, 128, 0.1, 4);
        let coo = csr.to_coo();
        let m = from_coo(FormatKind::InCrs, &coo).unwrap();
        let cost = measure_hits(m.as_ref(), 500, 9);
        assert_eq!(cost.hits, 500);
        // hit cost ≈ ptr + counter + ~half-block scan + val: small
        assert!(cost.avg() < 10.0, "{}", cost.avg());
    }
}
