//! Access-pattern drivers: random-element locate cost (Table I) and
//! column-order traversal of a row-stored matrix (Table II / Fig 3).

pub mod column;
pub mod locate;

pub use column::{
    read_columns, read_columns_csr, read_columns_incrs, spmv_column_order,
    ColumnReadStats,
};
pub use locate::{analytic_cost, measure, measure_hits, LocateCost};
