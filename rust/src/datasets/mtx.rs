//! MatrixMarket (.mtx) coordinate reader/writer — the drop-in path for the
//! real UFL/UCI datasets when a user has them (DESIGN.md §2: the synthetic
//! generator is the default substrate, real files override it).

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::formats::coo::Coo;

/// Read a MatrixMarket coordinate file (general, real/integer/pattern).
pub fn read(path: &Path) -> Result<Coo, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{path:?}: {e}"))?;
    read_from(BufReader::new(f))
}

pub fn read_from(r: impl BufRead) -> Result<Coo, String> {
    let mut lines = r.lines();
    let header = lines
        .next()
        .ok_or("empty file")?
        .map_err(|e| e.to_string())?;
    let h = header.to_ascii_lowercase();
    if !h.starts_with("%%matrixmarket matrix coordinate") {
        return Err(format!("unsupported MatrixMarket header: {header}"));
    }
    let pattern = h.contains(" pattern ") || h.ends_with(" pattern")
        || h.contains(" pattern general") || h.split_whitespace().any(|w| w == "pattern");
    let symmetric = h.split_whitespace().any(|w| w == "symmetric");

    let mut dims: Option<(usize, usize, usize)> = None;
    let mut entries: Vec<(u32, u32, f32)> = Vec::new();
    for line in lines {
        let line = line.map_err(|e| e.to_string())?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        if dims.is_none() {
            let m: usize = it.next().ok_or("bad size line")?.parse().map_err(|e| format!("{e}"))?;
            let n: usize = it.next().ok_or("bad size line")?.parse().map_err(|e| format!("{e}"))?;
            let nnz: usize = it.next().ok_or("bad size line")?.parse().map_err(|e| format!("{e}"))?;
            dims = Some((m, n, nnz));
            entries.reserve(nnz);
            continue;
        }
        let i: usize = it.next().ok_or("bad entry")?.parse().map_err(|e| format!("{e}"))?;
        let j: usize = it.next().ok_or("bad entry")?.parse().map_err(|e| format!("{e}"))?;
        let v: f32 = if pattern {
            1.0
        } else {
            it.next().ok_or("missing value")?.parse().map_err(|e| format!("{e}"))?
        };
        if i == 0 || j == 0 {
            return Err("MatrixMarket is 1-indexed; found 0".into());
        }
        entries.push((i as u32 - 1, j as u32 - 1, v));
        if symmetric && i != j {
            entries.push((j as u32 - 1, i as u32 - 1, v));
        }
    }
    let (m, n, nnz) = dims.ok_or("missing size line")?;
    let expected = if symmetric { None } else { Some(nnz) };
    if let Some(e) = expected {
        if entries.len() != e {
            return Err(format!("expected {e} entries, found {}", entries.len()));
        }
    }
    Ok(Coo::new(m, n, entries))
}

/// Write a COO matrix as MatrixMarket coordinate/real/general.
pub fn write(coo: &Coo, path: &Path) -> Result<(), String> {
    use crate::formats::traits::SparseMatrix;
    let mut f = std::fs::File::create(path).map_err(|e| format!("{path:?}: {e}"))?;
    let (m, n) = coo.shape();
    let mut out = String::new();
    out.push_str("%%MatrixMarket matrix coordinate real general\n");
    out.push_str(&format!("{m} {n} {}\n", coo.nnz()));
    for &(r, c, v) in &coo.entries {
        out.push_str(&format!("{} {} {}\n", r + 1, c + 1, v));
    }
    f.write_all(out.as_bytes()).map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::traits::SparseMatrix;
    use std::io::Cursor;

    #[test]
    fn parse_general_real() {
        let src = "%%MatrixMarket matrix coordinate real general\n\
                   % comment\n\
                   3 4 2\n\
                   1 1 2.5\n\
                   3 4 -1\n";
        let c = read_from(Cursor::new(src)).unwrap();
        assert_eq!(c.shape(), (3, 4));
        assert_eq!(c.get(0, 0), Some(2.5));
        assert_eq!(c.get(2, 3), Some(-1.0));
    }

    #[test]
    fn parse_pattern_symmetric() {
        let src = "%%MatrixMarket matrix coordinate pattern symmetric\n\
                   3 3 2\n\
                   2 1\n\
                   3 3\n";
        let c = read_from(Cursor::new(src)).unwrap();
        assert_eq!(c.get(1, 0), Some(1.0));
        assert_eq!(c.get(0, 1), Some(1.0)); // mirrored
        assert_eq!(c.get(2, 2), Some(1.0)); // diagonal not duplicated
        assert_eq!(c.nnz(), 3);
    }

    #[test]
    fn rejects_bad_header_and_counts() {
        assert!(read_from(Cursor::new("%%MatrixMarket matrix array real\n1 1\n1\n")).is_err());
        let short = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(read_from(Cursor::new(short)).is_err());
        let zero_idx = "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n";
        assert!(read_from(Cursor::new(zero_idx)).is_err());
    }

    #[test]
    fn write_read_roundtrip() {
        let c = Coo::new(2, 3, vec![(0, 2, 1.5), (1, 0, -2.0)]);
        let dir = std::env::temp_dir().join("spmm_accel_mtx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.mtx");
        write(&c, &p).unwrap();
        let back = read(&p).unwrap();
        assert_eq!(back.entries, c.entries);
        std::fs::remove_file(&p).ok();
    }
}
