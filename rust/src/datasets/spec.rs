//! Registry of the paper's evaluation datasets (Tables II and IV).
//!
//! The originals come from the UFL Sparse Matrix Collection and the UCI
//! repository; we cannot redistribute them, so each entry records the
//! *published* dimension, density, and per-row non-zero spread, and the
//! generator (`synth.rs`) synthesizes a matrix matching those moments
//! (DESIGN.md §2 Substitutions). A MatrixMarket loader (`mtx.rs`) lets real
//! files replace the synthetic ones transparently.
//!
//! Note on the paper's Table II: for Norris and Mks the stated density is
//! inconsistent with the stated avg non-zeros/row (e.g. Norris: 360 nz over
//! 3 600 columns is D = 10%, not 1%). All of the paper's *derived* columns
//! (MA ratio ≈ N·D/(b+2), storage ratio) follow the nnz-per-row numbers, so
//! we honor `nnz_row` and report the resulting density. EXPERIMENTS.md
//! documents the discrepancy per dataset.

/// Per-row non-zero spread as published: (min, avg, max).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NnzRow {
    pub min: usize,
    pub avg: f64,
    pub max: usize,
}

/// How non-zero columns are placed within a row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ColumnDist {
    /// Uniform random distinct columns.
    Uniform,
    /// Zipf-like popularity over columns (exponent), modeling the skewed
    /// column degrees of bag-of-words / graph datasets. Used by ablations.
    Zipf(f64),
    /// Diagonal-band locality: row i's columns fall within a band of the
    /// given width centered on the row's diagonal position. Models the
    /// locality structure of circuit/mesh/web matrices (UFL's Schenk-like
    /// families) — crucial for Fig 4/5, where the synchronized mesh's
    /// round fast-forward exploits exactly this locality.
    Banded(usize),
}

#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub rows: usize,
    pub cols: usize,
    /// Published density (may disagree with nnz_row — see module docs).
    pub stated_density: f64,
    pub nnz_row: NnzRow,
    pub dist: ColumnDist,
}

impl DatasetSpec {
    /// Density implied by the honored nnz-per-row spec.
    pub fn implied_density(&self) -> f64 {
        self.nnz_row.avg / self.cols as f64
    }

    pub fn expected_nnz(&self) -> usize {
        (self.nnz_row.avg * self.rows as f64) as usize
    }
}

/// Table II datasets (InCRS memory-access evaluation; already resized by the
/// authors to fit gem5 runtimes — we reproduce the resized shapes).
pub const TABLE2: [DatasetSpec; 5] = [
    DatasetSpec {
        name: "amazon",
        rows: 300,
        cols: 10_000,
        stated_density: 0.14,
        nnz_row: NnzRow { min: 501, avg: 1400.0, max: 2011 },
        dist: ColumnDist::Uniform,
    },
    DatasetSpec {
        name: "belcastro",
        rows: 370,
        cols: 22_000,
        stated_density: 0.06,
        nnz_row: NnzRow { min: 1, avg: 1300.0, max: 6787 },
        dist: ColumnDist::Uniform,
    },
    DatasetSpec {
        name: "docword",
        rows: 700,
        cols: 12_000,
        stated_density: 0.04,
        nnz_row: NnzRow { min: 2, avg: 480.0, max: 906 },
        dist: ColumnDist::Uniform,
    },
    DatasetSpec {
        name: "norris",
        rows: 1_200,
        cols: 3_600,
        stated_density: 0.01,
        nnz_row: NnzRow { min: 3, avg: 360.0, max: 795 },
        dist: ColumnDist::Uniform,
    },
    DatasetSpec {
        name: "mks",
        rows: 3_500,
        cols: 7_500,
        stated_density: 0.015,
        nnz_row: NnzRow { min: 18, avg: 150.0, max: 957 },
        dist: ColumnDist::Uniform,
    },
];

/// Table IV datasets (architecture evaluation, A×Aᵀ), ordered by density.
/// The paper gives dimensions only for the first four; for Arenas, Bates,
/// Gleich and Sch we choose square shapes in the UFL collections' typical
/// range so the density column is honored exactly (DESIGN.md §2).
pub const TABLE4: [DatasetSpec; 8] = [
    DatasetSpec {
        name: "amazon",
        rows: 1_500,
        cols: 10_000,
        stated_density: 0.14,
        nnz_row: NnzRow { min: 501, avg: 1400.0, max: 2011 },
        dist: ColumnDist::Uniform,
    },
    DatasetSpec {
        name: "docword",
        rows: 1_500,
        cols: 12_000,
        stated_density: 0.04,
        nnz_row: NnzRow { min: 2, avg: 480.0, max: 906 },
        dist: ColumnDist::Uniform,
    },
    DatasetSpec {
        name: "mks",
        rows: 7_500,
        cols: 7_500,
        stated_density: 0.015,
        nnz_row: NnzRow { min: 18, avg: 112.5, max: 957 },
        dist: ColumnDist::Uniform,
    },
    DatasetSpec {
        name: "norris",
        rows: 3_600,
        cols: 3_600,
        stated_density: 0.01,
        nnz_row: NnzRow { min: 3, avg: 36.0, max: 180 },
        dist: ColumnDist::Uniform,
    },
    DatasetSpec {
        name: "arenas",
        rows: 10_000,
        cols: 10_000,
        stated_density: 0.0085,
        nnz_row: NnzRow { min: 1, avg: 85.0, max: 420 },
        dist: ColumnDist::Banded(2048),
    },
    DatasetSpec {
        name: "bates",
        rows: 12_000,
        cols: 12_000,
        stated_density: 0.0011,
        nnz_row: NnzRow { min: 1, avg: 13.2, max: 70 },
        dist: ColumnDist::Banded(1024),
    },
    DatasetSpec {
        name: "gleich",
        rows: 16_000,
        cols: 16_000,
        stated_density: 0.00095,
        nnz_row: NnzRow { min: 1, avg: 15.2, max: 80 },
        dist: ColumnDist::Banded(1024),
    },
    DatasetSpec {
        name: "sch",
        rows: 20_000,
        cols: 20_000,
        stated_density: 0.00057,
        nnz_row: NnzRow { min: 1, avg: 11.4, max: 60 },
        dist: ColumnDist::Banded(768),
    },
];

/// Look up a spec by name in both tables (Table IV takes precedence for the
/// architecture experiments' shapes; `table2()` for the memory experiments).
pub fn by_name(name: &str) -> Option<DatasetSpec> {
    TABLE4
        .iter()
        .chain(TABLE2.iter())
        .find(|s| s.name == name)
        .copied()
}

pub fn table2_by_name(name: &str) -> Option<DatasetSpec> {
    TABLE2.iter().find(|s| s.name == name).copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper_rows() {
        assert_eq!(TABLE2.len(), 5);
        let dw = table2_by_name("docword").unwrap();
        assert_eq!((dw.rows, dw.cols), (700, 12_000));
        assert!((dw.implied_density() - 0.04).abs() < 1e-9);
    }

    #[test]
    fn table4_is_density_ordered() {
        for w in TABLE4.windows(2) {
            assert!(
                w[0].stated_density >= w[1].stated_density,
                "{} before {}",
                w[0].name,
                w[1].name
            );
        }
    }

    #[test]
    fn documented_norris_discrepancy() {
        // Table II Norris: stated D=1% but avg nnz/row implies 10% —
        // we honor nnz_row (see module docs); this test pins the fact.
        let n = table2_by_name("norris").unwrap();
        assert!(n.implied_density() > 5.0 * n.stated_density);
    }

    #[test]
    fn consistent_specs_elsewhere() {
        for s in TABLE4 {
            let implied = s.implied_density();
            assert!(
                (implied - s.stated_density).abs() / s.stated_density < 0.25,
                "{}: implied {implied} vs stated {}",
                s.name,
                s.stated_density
            );
        }
    }

    #[test]
    fn lookup() {
        assert!(by_name("sch").is_some());
        assert!(by_name("unknown").is_none());
        // amazon appears in both tables with different rows
        assert_eq!(by_name("amazon").unwrap().rows, 1_500);
        assert_eq!(table2_by_name("amazon").unwrap().rows, 300);
    }
}
