//! Synthetic sparse-matrix generator matching a [`DatasetSpec`]'s moments.
//!
//! Per-row population is drawn from a two-sided triangular-mixture that hits
//! the published (min, avg, max) exactly in expectation; column positions
//! are uniform distinct (or Zipf-skewed for ablations). Deterministic from
//! the seed — the same spec+seed reproduces bit-identical matrices on every
//! run, which the experiment harness relies on.

use super::spec::{ColumnDist, DatasetSpec, NnzRow};
use crate::formats::csr::Csr;
use crate::util::rng::Rng;

/// Draw one row population in `[min, max]` with expectation `avg`.
///
/// Mixture of U[min, avg] and U[avg, max] with the weight solving
/// `p·(min+avg)/2 + (1-p)·(avg+max)/2 = avg`.
fn draw_nnz(rng: &mut Rng, spec: NnzRow) -> usize {
    let (lo, hi, avg) = (spec.min as f64, spec.max as f64, spec.avg);
    if spec.min == spec.max {
        return spec.min;
    }
    debug_assert!(lo <= avg && avg <= hi, "nnz spec violated: {spec:?}");
    let mean_lo = (lo + avg) / 2.0;
    let mean_hi = (avg + hi) / 2.0;
    // p*mean_lo + (1-p)*mean_hi = avg
    let p = if (mean_hi - mean_lo).abs() < 1e-12 {
        0.5
    } else {
        ((mean_hi - avg) / (mean_hi - mean_lo)).clamp(0.0, 1.0)
    };
    let (a, b) = if rng.bool(p) { (lo, avg) } else { (avg, hi) };
    let x = a + rng.f64() * (b - a);
    (x.round() as usize).clamp(spec.min, spec.max)
}

/// Zipf-ish column sampler: popularity ∝ 1/(rank+1)^s over a shuffled
/// column permutation (so hot columns aren't all at the left edge).
struct ZipfCols {
    perm: Vec<u32>,
    cdf: Vec<f64>,
}

impl ZipfCols {
    fn new(cols: usize, s: f64, rng: &mut Rng) -> ZipfCols {
        let mut perm: Vec<u32> = (0..cols as u32).collect();
        rng.shuffle(&mut perm);
        let mut cdf = Vec::with_capacity(cols);
        let mut acc = 0.0;
        for r in 0..cols {
            acc += 1.0 / ((r + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        ZipfCols { perm, cdf }
    }

    fn draw(&self, rng: &mut Rng) -> u32 {
        let u = rng.f64();
        let r = self.cdf.partition_point(|&c| c < u);
        self.perm[r.min(self.perm.len() - 1)]
    }
}

/// Generate a CSR matrix for `spec` with the given seed.
pub fn generate(spec: &DatasetSpec, seed: u64) -> Csr {
    let mut rng = Rng::new(seed ^ fxhash(spec.name));
    let rows = spec.rows;
    let cols = spec.cols;
    let zipf = match spec.dist {
        ColumnDist::Uniform | ColumnDist::Banded(_) => None,
        ColumnDist::Zipf(s) => Some(ZipfCols::new(cols, s, &mut rng)),
    };
    let band = match spec.dist {
        ColumnDist::Banded(w) => Some(w.min(cols)),
        _ => None,
    };

    let mut row_ptr = Vec::with_capacity(rows + 1);
    row_ptr.push(0u32);
    let mut col_idx: Vec<u32> = Vec::with_capacity(spec.expected_nnz());
    let mut vals: Vec<f32> = Vec::with_capacity(spec.expected_nnz());
    let mut scratch: Vec<u64> = Vec::new();
    let mut seen = vec![false; cols];

    for row in 0..rows {
        let k = draw_nnz(&mut rng, spec.nnz_row).min(cols);
        match &zipf {
            None if band.is_some() => {
                // band centered on the row's diagonal position
                let w = band.unwrap().max(k);
                let center = row * cols / rows;
                let lo = center.saturating_sub(w / 2).min(cols - w);
                let picked = rng.sample_sorted(w, k, &mut scratch);
                col_idx.extend(picked.into_iter().map(|c| c + lo as u32));
            }
            None => {
                let picked = rng.sample_sorted(cols, k, &mut scratch);
                col_idx.extend_from_slice(&picked);
            }
            Some(z) => {
                // rejection for distinctness; k << cols in practice
                let mut picked = Vec::with_capacity(k);
                while picked.len() < k {
                    let c = z.draw(&mut rng);
                    if !seen[c as usize] {
                        seen[c as usize] = true;
                        picked.push(c);
                    }
                }
                for &c in &picked {
                    seen[c as usize] = false;
                }
                picked.sort_unstable();
                col_idx.extend_from_slice(&picked);
            }
        }
        for _ in 0..k {
            // values uniform in [0.5, 1.5): away from zero so products
            // never cancel to exactly 0 (keeps nnz accounting stable)
            vals.push(0.5 + rng.f32());
        }
        row_ptr.push(col_idx.len() as u32);
    }
    Csr::from_parts(rows, cols, row_ptr, col_idx, vals)
}

/// Deterministic name hash (FNV-1a) for seed derivation.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Generate a small ad-hoc uniform matrix (tests/examples): `rows × cols`
/// with per-row population ~ Binomial(cols, density) clamped to ≥ 0.
pub fn uniform(rows: usize, cols: usize, density: f64, seed: u64) -> Csr {
    let spec = DatasetSpec {
        name: "uniform",
        rows,
        cols,
        stated_density: density,
        nnz_row: NnzRow {
            min: 0,
            avg: density * cols as f64,
            max: ((2.0 * density * cols as f64).ceil() as usize).min(cols).max(1),
        },
        dist: ColumnDist::Uniform,
    };
    generate(&spec, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::spec::{table2_by_name, TABLE2};
    use crate::formats::traits::SparseMatrix;

    #[test]
    fn deterministic() {
        let spec = table2_by_name("docword").unwrap();
        let a = generate(&spec, 7);
        let b = generate(&spec, 7);
        assert_eq!(a.row_ptr, b.row_ptr);
        assert_eq!(a.col_idx, b.col_idx);
        let c = generate(&spec, 8);
        assert_ne!(a.col_idx, c.col_idx);
    }

    #[test]
    fn honors_row_bounds_and_mean() {
        for spec in TABLE2 {
            let m = generate(&spec, 1);
            let (min, avg, max) = m.nnz_row_stats();
            assert!(
                min >= spec.nnz_row.min,
                "{}: min {min} < {}",
                spec.name,
                spec.nnz_row.min
            );
            assert!(
                max <= spec.nnz_row.max,
                "{}: max {max} > {}",
                spec.name,
                spec.nnz_row.max
            );
            let rel = (avg - spec.nnz_row.avg).abs() / spec.nnz_row.avg;
            assert!(rel < 0.08, "{}: avg {avg} vs {}", spec.name, spec.nnz_row.avg);
        }
    }

    #[test]
    fn rows_sorted_distinct() {
        let m = uniform(50, 200, 0.1, 3);
        for i in 0..50 {
            let (cs, _) = m.row(i);
            for w in cs.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn zipf_skews_column_degree() {
        let spec = DatasetSpec {
            name: "zipf-test",
            rows: 400,
            cols: 500,
            stated_density: 0.05,
            nnz_row: NnzRow { min: 10, avg: 25.0, max: 40 },
            dist: ColumnDist::Zipf(1.1),
        };
        let m = generate(&spec, 5);
        let mut deg = vec![0usize; 500];
        for &c in &m.col_idx {
            deg[c as usize] += 1;
        }
        deg.sort_unstable_by(|a, b| b.cmp(a));
        let top_decile: usize = deg[..50].iter().sum();
        let total: usize = deg.iter().sum();
        assert!(
            top_decile as f64 > 0.35 * total as f64,
            "top-10% columns hold {top_decile}/{total}"
        );
    }

    #[test]
    fn banded_stays_in_band() {
        let spec = DatasetSpec {
            name: "band-test",
            rows: 500,
            cols: 500,
            stated_density: 0.02,
            nnz_row: NnzRow { min: 2, avg: 10.0, max: 20 },
            dist: ColumnDist::Banded(64),
        };
        let m = generate(&spec, 3);
        for i in 0..500 {
            let (cs, _) = m.row(i);
            for &c in cs {
                let d = (c as i64 - i as i64).unsigned_abs();
                assert!(d <= 64, "row {i} col {c} outside band");
            }
        }
        assert!(m.nnz() > 3000);
    }

    #[test]
    fn banded_generator_for_sparse_table4_datasets() {
        let spec = crate::datasets::spec::by_name("sch").unwrap();
        assert!(matches!(spec.dist, ColumnDist::Banded(_)));
    }

    #[test]
    fn uniform_density() {
        let m = uniform(100, 1000, 0.05, 9);
        let d = m.nnz() as f64 / 100_000.0;
        assert!((d - 0.05).abs() < 0.01, "density {d}");
    }

    #[test]
    fn values_away_from_zero() {
        let m = uniform(10, 100, 0.2, 2);
        assert!(m.vals.iter().all(|&v| (0.5..1.5).contains(&v)));
    }
}
