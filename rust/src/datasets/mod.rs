//! Evaluation datasets: registry of the paper's nine datasets, a synthetic
//! generator matching their published moments, and a MatrixMarket loader
//! for real data (DESIGN.md §2 Substitutions).

pub mod mtx;
pub mod spec;
pub mod synth;

pub use spec::{by_name, table2_by_name, ColumnDist, DatasetSpec, NnzRow, TABLE2, TABLE4};
pub use synth::{generate, uniform};

use crate::formats::csr::Csr;

/// Load a dataset: a real `.mtx` file if `path` is given, else synthesize
/// from the registry spec.
pub fn load(name: &str, mtx_path: Option<&std::path::Path>, seed: u64) -> Result<Csr, String> {
    if let Some(p) = mtx_path {
        return Ok(Csr::from_coo(&mtx::read(p)?));
    }
    let spec = by_name(name).ok_or_else(|| format!("unknown dataset {name:?}"))?;
    Ok(generate(&spec, seed))
}
