//! Conventional dense systolic matrix multiplier (paper Fig 2a) — processes
//! every element including zeros, so its latency is density-independent.
//!
//! Cycle model: each `mesh × mesh` output tile streams the full inner
//! dimension `K` through the array once, plus `2·mesh` fill/drain skew;
//! tiles = ⌈M/mesh⌉ · ⌈N/mesh⌉ passes.

#[derive(Clone, Copy, Debug)]
pub struct ConvMmConfig {
    /// Mesh edge N_conv.
    pub mesh: usize,
}

impl Default for ConvMmConfig {
    /// Paper Table V: 96×96 (same input bandwidth as the 64×64 sync mesh
    /// because dense streams carry no index bits — see `arch::model`).
    fn default() -> Self {
        ConvMmConfig { mesh: 96 }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct ConvMmStats {
    pub cycles: u64,
    pub tiles: u64,
    /// All MACs issued (including on zeros).
    pub macs_issued: u64,
}

/// Latency of C(M×N) = A(M×K) × B(K×N) on the dense systolic mesh.
pub fn cycles(m: usize, n: usize, k: usize, cfg: ConvMmConfig) -> ConvMmStats {
    let t = ((m + cfg.mesh - 1) / cfg.mesh) as u64 * ((n + cfg.mesh - 1) / cfg.mesh) as u64;
    ConvMmStats {
        cycles: t * (k as u64 + 2 * cfg.mesh as u64),
        tiles: t,
        macs_issued: t * (cfg.mesh * cfg.mesh) as u64 * k as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_tile() {
        let s = cycles(64, 64, 1000, ConvMmConfig { mesh: 96 });
        assert_eq!(s.tiles, 1);
        assert_eq!(s.cycles, 1000 + 192);
    }

    #[test]
    fn tiling_rounds_up() {
        let s = cycles(97, 96, 10, ConvMmConfig { mesh: 96 });
        assert_eq!(s.tiles, 2);
        assert_eq!(s.cycles, 2 * (10 + 192));
    }

    #[test]
    fn density_independence() {
        // the whole point: conventional MM's cost has no density term
        let a = cycles(512, 512, 512, ConvMmConfig::default());
        assert_eq!(a.cycles, 36 * (512 + 192));
    }
}
