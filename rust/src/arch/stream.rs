//! Sorted sparse operand streams — what the mesh's rows and columns consume.
//!
//! A stream is one row of `A` (or one column of `B`, i.e. one row of `Bᵀ`)
//! as parallel (index, value) arrays sorted by index. Round partitioning
//! (paper §IV.B.b: synchronization every `R` index positions) is computed
//! here both as per-round slices (functional simulation) and as per-round
//! count histograms (the fast cycle model).

/// Borrowed view of one operand stream.
#[derive(Clone, Copy, Debug)]
pub struct StreamRef<'a> {
    pub idx: &'a [u32],
    pub val: &'a [f32],
}

impl<'a> StreamRef<'a> {
    pub fn new(idx: &'a [u32], val: &'a [f32]) -> StreamRef<'a> {
        debug_assert_eq!(idx.len(), val.len());
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "stream not sorted");
        StreamRef { idx, val }
    }

    pub fn len(&self) -> usize {
        self.idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    /// Sub-stream with indices in `[lo, hi)` (one synchronization round).
    pub fn slice_range(&self, lo: u32, hi: u32) -> StreamRef<'a> {
        let a = self.idx.partition_point(|&x| x < lo);
        let b = self.idx.partition_point(|&x| x < hi);
        StreamRef {
            idx: &self.idx[a..b],
            val: &self.val[a..b],
        }
    }
}

/// Per-round non-zero counts for one stream: `hist[k]` = #indices in
/// `[k·r, (k+1)·r)`. `n_rounds` = ceil(index_space / r).
pub fn round_histogram(idx: &[u32], r: usize, n_rounds: usize) -> Vec<u16> {
    let mut h = vec![0u16; n_rounds];
    for &x in idx {
        let k = x as usize / r;
        debug_assert!(k < n_rounds, "index {x} outside {n_rounds} rounds of {r}");
        h[k] = h[k].saturating_add(1);
    }
    h
}

/// Flat row-major histogram matrix for many streams (rows × n_rounds),
/// plus an element-wise max over groups of `group` consecutive streams —
/// the precomputation behind the fast mesh cycle model.
pub struct RoundHists {
    pub n_rounds: usize,
    /// per-stream histograms, row-major [streams × n_rounds]
    pub per_stream: Vec<u16>,
    pub n_streams: usize,
}

impl RoundHists {
    pub fn from_csr(m: &crate::formats::csr::Csr, r: usize) -> RoundHists {
        use crate::formats::traits::SparseMatrix;
        let (rows, cols) = m.shape();
        let n_rounds = (cols + r - 1) / r;
        let mut per_stream = vec![0u16; rows * n_rounds];
        for i in 0..rows {
            let (idx, _) = m.row(i);
            let base = i * n_rounds;
            for &x in idx {
                per_stream[base + x as usize / r] += 1;
            }
        }
        RoundHists {
            n_rounds,
            per_stream,
            n_streams: rows,
        }
    }

    #[inline]
    pub fn stream(&self, i: usize) -> &[u16] {
        &self.per_stream[i * self.n_rounds..(i + 1) * self.n_rounds]
    }

    /// Element-wise max over stream groups of size `group` (the mesh tile's
    /// row/column bundle): returns [n_groups × n_rounds].
    pub fn group_max(&self, group: usize) -> (usize, Vec<u16>) {
        let n_groups = (self.n_streams + group - 1) / group;
        let mut out = vec![0u16; n_groups * self.n_rounds];
        for g in 0..n_groups {
            let dst = &mut out[g * self.n_rounds..(g + 1) * self.n_rounds];
            for i in (g * group)..((g + 1) * group).min(self.n_streams) {
                let src = self.stream(i);
                for (d, &s) in dst.iter_mut().zip(src) {
                    if s > *d {
                        *d = s;
                    }
                }
            }
        }
        (n_groups, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::formats::traits::SparseMatrix;

    #[test]
    fn slice_range_partitions_stream() {
        let idx = [1u32, 5, 8, 9, 31, 32, 64];
        let val = [1.0f32; 7];
        let s = StreamRef::new(&idx, &val);
        let r0 = s.slice_range(0, 32);
        assert_eq!(r0.idx, &[1, 5, 8, 9, 31]);
        let r1 = s.slice_range(32, 64);
        assert_eq!(r1.idx, &[32]);
        let r2 = s.slice_range(64, 96);
        assert_eq!(r2.idx, &[64]);
    }

    #[test]
    fn histogram_counts_match_slices() {
        let idx = [0u32, 3, 31, 32, 95];
        let h = round_histogram(&idx, 32, 3);
        assert_eq!(h, vec![3, 1, 1]);
    }

    #[test]
    fn hists_from_csr_sum_to_nnz() {
        let m = uniform(30, 200, 0.1, 4);
        let h = RoundHists::from_csr(&m, 32);
        let total: u64 = h.per_stream.iter().map(|&x| x as u64).sum();
        assert_eq!(total as usize, m.nnz());
        for i in 0..30 {
            let row_total: usize = h.stream(i).iter().map(|&x| x as usize).sum();
            assert_eq!(row_total, m.row_nnz(i));
        }
    }

    #[test]
    fn group_max_dominates_members() {
        let m = uniform(20, 128, 0.15, 9);
        let h = RoundHists::from_csr(&m, 32);
        let (n_groups, gm) = h.group_max(8);
        assert_eq!(n_groups, 3);
        for g in 0..n_groups {
            for i in (g * 8)..((g + 1) * 8).min(20) {
                for k in 0..h.n_rounds {
                    assert!(gm[g * h.n_rounds + k] >= h.stream(i)[k]);
                }
            }
        }
    }
}
