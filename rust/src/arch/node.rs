//! The mesh nodes, implemented exactly as the paper's pseudo-code.
//!
//! * [`SyncNode`] — Algorithm 2: the proposed design's comparator +
//!   operand-buffer + flag + MAC node. Consumes one operand from the row
//!   stream *and* one from the column stream every cycle; the operand with
//!   the larger index is buffered instead of stalling, and the smaller-index
//!   operand is matched against the buffer (binary search — the paper notes
//!   the buffer is sorted, at most `log2(depth)` comparisons, or a CAM).
//! * [`fpic_merge`] — Algorithm 1: FPIC's two-pointer sparse dot product,
//!   consuming one or two operands per cycle.

/// Which matrix's operands currently occupy the buffer (paper's `flag_op`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flag {
    A,
    B,
}

/// Sentinel index for an exhausted stream (∞ — never matches a real index
/// and always compares greater).
pub const INF: u32 = u32::MAX;

/// One node of the proposed synchronized mesh (paper Algorithm 2).
#[derive(Clone, Debug)]
pub struct SyncNode {
    buf_idx: Vec<u32>,
    buf_val: Vec<f32>,
    flag: Option<Flag>,
    pub acc: f32,
    /// MACs actually performed (useful-work accounting).
    pub macs: u64,
    /// buffer searches performed (cost accounting for the CAM/binary search)
    pub searches: u64,
}

impl SyncNode {
    pub fn new(depth: usize) -> SyncNode {
        SyncNode {
            buf_idx: Vec::with_capacity(depth),
            buf_val: Vec::with_capacity(depth),
            flag: None,
            acc: 0.0,
            macs: 0,
            searches: 0,
        }
    }

    /// Round boundary: "On starting a new round all the operand buffers are
    /// reset since any remaining buffer operands are no longer needed."
    pub fn reset_round(&mut self) {
        self.buf_idx.clear();
        self.buf_val.clear();
        self.flag = None;
    }

    /// End of an output-tile pass: emit and clear the accumulator.
    pub fn take_acc(&mut self) -> f32 {
        let v = self.acc;
        self.acc = 0.0;
        self.reset_round();
        v
    }

    fn search(&mut self, idx: u32) -> Option<f32> {
        self.searches += 1;
        match self.buf_idx.binary_search(&idx) {
            Ok(p) => Some(self.buf_val[p]),
            Err(_) => None,
        }
    }

    /// One cycle (paper Algorithm 2, verbatim). `a`/`b` are the operands
    /// arriving on the row/column stream this cycle; `None` = exhausted
    /// stream (index ∞). Both streams advance unconditionally (lines 27-28)
    /// — that's the design's whole point.
    pub fn step(&mut self, a: Option<(u32, f32)>, b: Option<(u32, f32)>) {
        let (ai, av) = a.map_or((INF, 0.0), |x| x);
        let (bi, bv) = b.map_or((INF, 0.0), |x| x);
        if ai == bi {
            // line 1-3: match (or both ∞ — no work), MAC + reset
            if ai != INF {
                self.acc += av * bv;
                self.macs += 1;
            }
            self.buf_idx.clear();
            self.buf_val.clear();
            self.flag = None;
        } else if ai > bi {
            // lines 4-14: b has the smaller index; a gets buffered
            if self.flag == Some(Flag::A) {
                if let Some(v) = self.search(bi) {
                    self.acc += v * bv;
                    self.macs += 1;
                }
            } else {
                self.buf_idx.clear();
                self.buf_val.clear();
                self.flag = Some(Flag::A);
            }
            if ai != INF {
                debug_assert!(self.buf_idx.last().map_or(true, |&l| l < ai));
                self.buf_idx.push(ai);
                self.buf_val.push(av);
            }
        } else {
            // lines 15-25: symmetric — a smaller, b buffered
            if self.flag == Some(Flag::B) {
                if let Some(v) = self.search(ai) {
                    self.acc += v * av;
                    self.macs += 1;
                }
            } else {
                self.buf_idx.clear();
                self.buf_val.clear();
                self.flag = Some(Flag::B);
            }
            if bi != INF {
                debug_assert!(self.buf_idx.last().map_or(true, |&l| l < bi));
                self.buf_idx.push(bi);
                self.buf_val.push(bv);
            }
        }
    }

    pub fn buffer_len(&self) -> usize {
        self.buf_idx.len()
    }
}

/// Algorithm 1 (FPIC node): two-pointer sparse dot product. Returns
/// `(cycles, dot)` — one comparison per cycle, terminating when either
/// stream exhausts (no further matches are possible).
pub fn fpic_merge(a: super::stream::StreamRef, b: super::stream::StreamRef) -> (u64, f32) {
    let (mut i, mut j) = (0usize, 0usize);
    let mut cycles = 0u64;
    let mut dot = 0.0f32;
    while i < a.len() && j < b.len() {
        cycles += 1;
        let (ai, bi) = (a.idx[i], b.idx[j]);
        if ai == bi {
            dot += a.val[i] * b.val[j];
            i += 1;
            j += 1;
        } else if ai > bi {
            j += 1;
        } else {
            i += 1;
        }
    }
    (cycles, dot)
}

/// FPIC merge cycle count only (hot path of the cycle model — no values).
pub fn fpic_merge_cycles(a: &[u32], b: &[u32]) -> u64 {
    let (mut i, mut j) = (0usize, 0usize);
    let mut cycles = 0u64;
    while i < a.len() && j < b.len() {
        cycles += 1;
        let (ai, bi) = (a[i], b[j]);
        if ai == bi {
            i += 1;
            j += 1;
        } else if ai > bi {
            j += 1;
        } else {
            i += 1;
        }
    }
    cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::stream::StreamRef;

    /// Drive one node through two full (padded) streams round by round and
    /// return its accumulator — the reference harness for Algorithm 2.
    pub fn run_node(
        a_idx: &[u32],
        a_val: &[f32],
        b_idx: &[u32],
        b_val: &[f32],
        r: usize,
        index_space: u32,
    ) -> f32 {
        let a = StreamRef::new(a_idx, a_val);
        let b = StreamRef::new(b_idx, b_val);
        let mut node = SyncNode::new(r);
        let mut lo = 0u32;
        while lo < index_space {
            let hi = lo + r as u32;
            let ra = a.slice_range(lo, hi);
            let rb = b.slice_range(lo, hi);
            let steps = ra.len().max(rb.len());
            for t in 0..steps {
                let ao = (t < ra.len()).then(|| (ra.idx[t], ra.val[t]));
                let bo = (t < rb.len()).then(|| (rb.idx[t], rb.val[t]));
                node.step(ao, bo);
            }
            node.reset_round();
            lo = hi;
        }
        node.acc
    }

    fn dot(a_idx: &[u32], a_val: &[f32], b_idx: &[u32], b_val: &[f32]) -> f32 {
        let mut s = 0.0;
        for (i, &ai) in a_idx.iter().enumerate() {
            if let Ok(p) = b_idx.binary_search(&ai) {
                s += a_val[i] * b_val[p];
            }
        }
        s
    }

    #[test]
    fn aligned_streams_mac_every_cycle() {
        let idx = [2u32, 7, 9];
        let av = [1.0f32, 2.0, 3.0];
        let bv = [4.0f32, 5.0, 6.0];
        let got = run_node(&idx, &av, &idx, &bv, 32, 32);
        assert_eq!(got, 1.0 * 4.0 + 2.0 * 5.0 + 3.0 * 6.0);
    }

    #[test]
    fn offset_match_found_via_buffer() {
        // a = [(5,x)], b = [(1,_), (5,y)]: the (5,5) match needs the buffer
        let got = run_node(&[5], &[2.0], &[1, 5], &[9.0, 3.0], 32, 32);
        assert_eq!(got, 6.0);
    }

    #[test]
    fn flag_flip_preserves_future_matches() {
        // worked example from DESIGN review: a=[2,9,11], b=[5,6,9]
        let got = run_node(
            &[2, 9, 11],
            &[1.0, 2.0, 3.0],
            &[5, 6, 9],
            &[1.0, 1.0, 10.0],
            32,
            32,
        );
        assert_eq!(got, 20.0); // only (9,9): 2*10
    }

    #[test]
    fn disjoint_streams_accumulate_nothing() {
        let got = run_node(&[0, 2, 4], &[1.0; 3], &[1, 3, 5], &[1.0; 3], 32, 32);
        assert_eq!(got, 0.0);
    }

    #[test]
    fn cross_round_indices_cannot_match_and_dont() {
        // indices land in different rounds; buffers reset between rounds
        let got = run_node(&[1, 40], &[1.0, 2.0], &[1, 40], &[3.0, 4.0], 32, 96);
        assert_eq!(got, 1.0 * 3.0 + 2.0 * 4.0);
    }

    #[test]
    fn random_streams_match_reference_dot() {
        let mut rng = crate::util::rng::Rng::new(0xAB);
        let mut scratch = Vec::new();
        for case in 0..300 {
            let space = 128u32;
            let na = rng.usize_below(40);
            let nb = rng.usize_below(40);
            let a_idx = rng.sample_sorted(space as usize, na, &mut scratch);
            let b_idx = rng.sample_sorted(space as usize, nb, &mut scratch);
            let a_val: Vec<f32> = (0..na).map(|_| rng.f32() + 0.5).collect();
            let b_val: Vec<f32> = (0..nb).map(|_| rng.f32() + 0.5).collect();
            let want = dot(&a_idx, &a_val, &b_idx, &b_val);
            let got = run_node(&a_idx, &a_val, &b_idx, &b_val, 32, space);
            assert!(
                (got - want).abs() < 1e-4 * want.abs().max(1.0),
                "case {case}: got {got}, want {want}\n a={a_idx:?}\n b={b_idx:?}"
            );
        }
    }

    #[test]
    fn buffer_never_exceeds_round_depth() {
        let mut rng = crate::util::rng::Rng::new(7);
        let mut scratch = Vec::new();
        let r = 16usize;
        for _ in 0..100 {
            let na = rng.usize_below(30);
            let nb = rng.usize_below(30);
            let a_idx = rng.sample_sorted(64, na, &mut scratch);
            let b_idx = rng.sample_sorted(64, nb, &mut scratch);
            let a_val = vec![1.0f32; a_idx.len()];
            let b_val = vec![1.0f32; b_idx.len()];
            let a = StreamRef::new(&a_idx, &a_val);
            let b = StreamRef::new(&b_idx, &b_val);
            let mut node = SyncNode::new(r);
            let mut lo = 0u32;
            while lo < 64 {
                let (ra, rb) = (a.slice_range(lo, lo + r as u32), b.slice_range(lo, lo + r as u32));
                for t in 0..ra.len().max(rb.len()) {
                    node.step(
                        (t < ra.len()).then(|| (ra.idx[t], ra.val[t])),
                        (t < rb.len()).then(|| (rb.idx[t], rb.val[t])),
                    );
                    assert!(node.buffer_len() <= r, "buffer {} > R {r}", node.buffer_len());
                }
                node.reset_round();
                lo += r as u32;
            }
        }
    }

    #[test]
    fn fpic_merge_matches_dot_and_counts_cycles() {
        let a_idx = [1u32, 4, 6, 9];
        let a_val = [1.0f32, 2.0, 3.0, 4.0];
        let b_idx = [2u32, 4, 9];
        let b_val = [5.0f32, 6.0, 7.0];
        let (cycles, d) = fpic_merge(
            StreamRef::new(&a_idx, &a_val),
            StreamRef::new(&b_idx, &b_val),
        );
        assert_eq!(d, 2.0 * 6.0 + 4.0 * 7.0);
        // merge trace: (1,2)a,(4,2)b,(4,4)m,(6,9)a,(9,9)m -> 5 cycles
        assert_eq!(cycles, 5);
        assert_eq!(fpic_merge_cycles(&a_idx, &b_idx), 5);
    }

    #[test]
    fn fpic_merge_empty_streams() {
        let (c, d) = fpic_merge(
            StreamRef::new(&[], &[]),
            StreamRef::new(&[1], &[1.0]),
        );
        assert_eq!((c, d), (0, 0.0));
    }
}
