//! Design-point calculator: the paper's fairness equations (1) and (2) and
//! the Table V resource accounting.
//!
//! Widths (paper §V.C): index 16 bits, value 32 bits, so a sparse operand is
//! `W_tot = 48` bits and a dense operand `W_val = 32` bits.

pub const W_IDX: u64 = 16;
pub const W_VAL: u64 = 32;
pub const W_TOT: u64 = W_IDX + W_VAL;

/// FPIC geometry constants from [11]: 8×8 units, 32-element buffers, and
/// 2×64 buffers per unit (64 for A + 64 for B).
pub const FPIC_DIM: u64 = 8;
pub const FPIC_BUFFERS_PER_UNIT: u64 = 2 * FPIC_DIM * FPIC_DIM;
pub const BUFFER_ELEMS: u64 = 32;

/// Eq (1): `2·N_synch·W_tot = 2·8·k_FPIC·W_tot` — FPIC unit count matching
/// the sync mesh's input bandwidth.
pub fn fpic_units_same_bandwidth(n_synch: usize) -> usize {
    (n_synch as u64 / FPIC_DIM).max(1) as usize
}

/// Eq (2): `N_synch² = 2·8²·k_FPIC` — FPIC unit count matching the sync
/// mesh's total buffer capacity.
pub fn fpic_units_same_buffer(n_synch: usize) -> usize {
    ((n_synch * n_synch) as u64 / FPIC_BUFFERS_PER_UNIT).max(1) as usize
}

/// Conventional mesh edge with the same input bandwidth as the sync mesh:
/// `N_conv = (W_tot / W_val) · N_synch` (dense operands carry no indices).
pub fn conv_mesh_same_bandwidth(n_synch: usize) -> usize {
    (n_synch as u64 * W_TOT / W_VAL) as usize
}

/// One Table V row.
#[derive(Clone, Debug, PartialEq)]
pub struct DesignPoint {
    pub name: &'static str,
    pub units: usize,
    pub mesh: usize,
    /// Input bandwidth in bits/cycle.
    pub bw_bits_per_cycle: u64,
    pub macs: u64,
    /// Total operand-buffer capacity in bytes.
    pub buffer_bytes: u64,
}

impl DesignPoint {
    pub fn sync(n_synch: usize, round: usize) -> DesignPoint {
        DesignPoint {
            name: "this work",
            units: 1,
            mesh: n_synch,
            bw_bits_per_cycle: 2 * n_synch as u64 * W_TOT,
            macs: (n_synch * n_synch) as u64,
            // one operand buffer per node, `round` elements of W_TOT bits
            buffer_bytes: (n_synch * n_synch) as u64 * round as u64 * W_TOT / 8,
        }
    }

    pub fn fpic(units: usize, name: &'static str) -> DesignPoint {
        DesignPoint {
            name,
            units,
            mesh: FPIC_DIM as usize,
            bw_bits_per_cycle: 2 * FPIC_DIM * units as u64 * W_TOT,
            macs: units as u64 * FPIC_DIM * FPIC_DIM,
            buffer_bytes: units as u64 * FPIC_BUFFERS_PER_UNIT * BUFFER_ELEMS * W_TOT / 8,
        }
    }

    pub fn conventional(mesh: usize) -> DesignPoint {
        DesignPoint {
            name: "conv. MM",
            units: 1,
            mesh,
            bw_bits_per_cycle: 2 * mesh as u64 * W_VAL,
            macs: (mesh * mesh) as u64,
            buffer_bytes: 0,
        }
    }
}

/// The paper's Table V design points for a given sync-mesh size (64 in the
/// paper) and round (32).
pub fn table5(n_synch: usize, round: usize) -> [DesignPoint; 4] {
    [
        DesignPoint::sync(n_synch, round),
        DesignPoint::fpic(fpic_units_same_bandwidth(n_synch), "FPIC-same BW"),
        DesignPoint::fpic(fpic_units_same_buffer(n_synch), "FPIC-same buffer"),
        DesignPoint::conventional(conv_mesh_same_bandwidth(n_synch)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table5_numbers() {
        let [sync, fpic_bw, fpic_buf, conv] = table5(64, 32);

        assert_eq!(sync.bw_bits_per_cycle, 6144); // "6 kb/cycles"
        assert_eq!(sync.macs, 4096);
        assert_eq!(sync.buffer_bytes, 768 * 1024); // 768 kB

        assert_eq!(fpic_bw.units, 8);
        assert_eq!(fpic_bw.macs, 512);
        assert_eq!(fpic_bw.bw_bits_per_cycle, 6144);
        assert_eq!(fpic_bw.buffer_bytes, 192 * 1024); // 192 kB

        assert_eq!(fpic_buf.units, 32);
        assert_eq!(fpic_buf.macs, 2048);
        assert_eq!(fpic_buf.bw_bits_per_cycle, 24 * 1024); // 24 kb/cycle
        assert_eq!(fpic_buf.buffer_bytes, 768 * 1024); // 768 kB

        assert_eq!(conv.mesh, 96);
        assert_eq!(conv.macs, 9216);
        assert_eq!(conv.bw_bits_per_cycle, 6144);
    }

    #[test]
    fn equations_scale_linearly() {
        assert_eq!(fpic_units_same_bandwidth(16), 2);
        assert_eq!(fpic_units_same_bandwidth(128), 16);
        assert_eq!(fpic_units_same_buffer(16), 2);
        assert_eq!(fpic_units_same_buffer(128), 128);
        assert_eq!(conv_mesh_same_bandwidth(32), 48);
    }
}
