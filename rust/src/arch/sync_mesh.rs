//! The proposed synchronized systolic SpMM mesh (paper §IV.B).
//!
//! Two implementations that agree on cycle counts by construction and are
//! cross-validated by tests:
//!
//! * [`multiply_functional`] — node-level simulation: every node runs
//!   Algorithm 2 verbatim with its operand buffer and flag; used to verify
//!   *what* the architecture computes (C == A×B) and the buffer-depth /
//!   synchronization invariants. O(mesh² · cycles) — for tests and small
//!   inputs.
//! * [`cycle_model`] — stream-level model computing only *how long* it
//!   takes. Per output tile pass, per round `k`, every active stream must
//!   push its in-round operands one per cycle and then wait for the slowest
//!   (paper: "they wait for the rest of the rows and columns to finish the
//!   round"), so the round costs the max in-round count; a pass adds `mesh` pipeline skew
//!   (drain overlaps the next pass's fill).
//!
//! Cost accounting assumptions (same for FPIC and conventional MM, per the
//! paper §V.A: "we assume a single cycle latency for all operations
//! including MAC and comparisons").

use super::stream::{RoundHists, StreamRef};
use crate::formats::csr::Csr;
use crate::formats::dense::Dense;
use crate::formats::traits::SparseMatrix;

#[derive(Clone, Copy, Debug)]
pub struct SyncMeshConfig {
    /// Mesh edge N_synch (N×N nodes).
    pub mesh: usize,
    /// Round size R (synchronization granularity and operand-buffer depth).
    pub round: usize,
}

impl Default for SyncMeshConfig {
    /// Paper Table V design point: 64×64 mesh, R = 32.
    fn default() -> Self {
        SyncMeshConfig { mesh: 64, round: 32 }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct SyncMeshStats {
    pub cycles: u64,
    /// Useful MACs performed (index matches found).
    pub macs: u64,
    /// Buffer searches performed.
    pub searches: u64,
    /// Output-tile passes executed.
    pub passes: u64,
    /// Synchronization rounds with at least one operand.
    pub active_rounds: u64,
}

impl SyncMeshStats {
    /// MAC-array utilization: useful MACs / (nodes × cycles).
    pub fn utilization(&self, mesh: usize) -> f64 {
        self.macs as f64 / ((mesh * mesh) as f64 * self.cycles.max(1) as f64)
    }
}

/// Cycle cost of one round given the max in-round operand count: streaming
/// the operands one per cycle. Globally empty rounds are free — the round
/// counter fast-forwards (streams are sorted, so all heads already being
/// past the boundary is detectable combinationally), and the barrier itself
/// costs no dead cycle: the synchronization signal overlaps the last
/// operand's consumption.
#[inline]
fn round_cycles(max_count: u64) -> u64 {
    max_count
}

/// Node-level functional simulation computing `C = A × B` where `b_t` is
/// `Bᵀ` in CSR (its rows are B's columns). Returns (C, stats).
pub fn multiply_functional(a: &Csr, b_t: &Csr, cfg: SyncMeshConfig) -> (Dense, SyncMeshStats) {
    assert_eq!(
        a.cols(),
        b_t.cols(),
        "inner dimensions (A cols vs Bᵀ cols) must agree"
    );
    let m = a.rows();
    let n = b_t.rows(); // = B.cols
    let k_space = a.cols() as u32;
    let mesh = cfg.mesh;
    let r = cfg.round as u32;
    let mut c = Dense::zeros(m, n);
    let mut stats = SyncMeshStats::default();

    let mut nodes: Vec<super::node::SyncNode> =
        (0..mesh * mesh).map(|_| super::node::SyncNode::new(cfg.round)).collect();

    let n_row_tiles = (m + mesh - 1) / mesh;
    let n_col_tiles = (n + mesh - 1) / mesh;
    for ti in 0..n_row_tiles {
        let rows = (ti * mesh)..((ti + 1) * mesh).min(m);
        for tj in 0..n_col_tiles {
            let cols = (tj * mesh)..((tj + 1) * mesh).min(n);
            stats.passes += 1;
            stats.cycles += mesh as u64; // pipeline skew (drain overlaps next fill)

            let a_streams: Vec<StreamRef> = rows
                .clone()
                .map(|i| {
                    let (idx, val) = a.row(i);
                    StreamRef::new(idx, val)
                })
                .collect();
            let b_streams: Vec<StreamRef> = cols
                .clone()
                .map(|j| {
                    let (idx, val) = b_t.row(j);
                    StreamRef::new(idx, val)
                })
                .collect();

            let mut lo = 0u32;
            while lo < k_space {
                let hi = lo.saturating_add(r).min(k_space);
                let ra: Vec<StreamRef> =
                    a_streams.iter().map(|s| s.slice_range(lo, hi)).collect();
                let rb: Vec<StreamRef> =
                    b_streams.iter().map(|s| s.slice_range(lo, hi)).collect();
                let steps = ra
                    .iter()
                    .chain(rb.iter())
                    .map(|s| s.len())
                    .max()
                    .unwrap_or(0) as u64;
                stats.cycles += round_cycles(steps);
                if steps > 0 {
                    stats.active_rounds += 1;
                }
                for t in 0..steps as usize {
                    for (pi, sa) in ra.iter().enumerate() {
                        let ao = (t < sa.len()).then(|| (sa.idx[t], sa.val[t]));
                        for (pj, sb) in rb.iter().enumerate() {
                            let bo = (t < sb.len()).then(|| (sb.idx[t], sb.val[t]));
                            nodes[pi * mesh + pj].step(ao, bo);
                        }
                    }
                }
                for node in nodes.iter_mut() {
                    node.reset_round();
                }
                lo = hi;
            }

            // drain accumulators into C
            for (pi, i) in rows.clone().enumerate() {
                for (pj, j) in cols.clone().enumerate() {
                    *c.at_mut(i, j) = nodes[pi * mesh + pj].take_acc();
                }
            }
        }
    }
    for node in &nodes {
        stats.macs += node.macs;
        stats.searches += node.searches;
    }
    (c, stats)
}

/// Fast stream-level cycle model — identical accounting, no value movement.
/// Handles Table-IV-scale datasets in milliseconds-to-seconds.
pub fn cycle_model(a: &Csr, b_t: &Csr, cfg: SyncMeshConfig) -> SyncMeshStats {
    assert_eq!(a.cols(), b_t.cols());
    let mesh = cfg.mesh;
    let ha = RoundHists::from_csr(a, cfg.round);
    let (ga_n, ga) = ha.group_max(mesh);
    // A×Aᵀ fast path: reuse the same histograms when a and b_t coincide
    let same = std::ptr::eq(a, b_t);
    let (hb, gb_n, gb);
    if same {
        (gb_n, gb) = (ga_n, ga.clone());
        hb = None;
    } else {
        let h = RoundHists::from_csr(b_t, cfg.round);
        let (n, g) = h.group_max(mesh);
        (gb_n, gb) = (n, g);
        hb = Some(h);
    }
    let _ = hb;
    let n_rounds = ha.n_rounds;

    let mut stats = SyncMeshStats::default();
    stats.macs = useful_macs(a, b_t);
    for gi in 0..ga_n {
        let ra = &ga[gi * n_rounds..(gi + 1) * n_rounds];
        for gj in 0..gb_n {
            let rb = &gb[gj * n_rounds..(gj + 1) * n_rounds];
            stats.passes += 1;
            stats.cycles += mesh as u64; // pipeline skew, as in the functional sim
            let mut pass_cycles = 0u64;
            let mut active = 0u64;
            for k in 0..n_rounds {
                let mx = ra[k].max(rb[k]) as u64;
                pass_cycles += round_cycles(mx);
                active += (mx > 0) as u64;
            }
            stats.cycles += pass_cycles;
            stats.active_rounds += active;
        }
    }
    stats
}

/// Exact count of index matches (useful MACs) for C = A × B with `b_t` = Bᵀ;
/// used by the cycle models for utilization accounting.
pub fn useful_macs(a: &Csr, b_t: &Csr) -> u64 {
    // MAC count = Σ_{i,j} |row_i(A) ∩ row_j(Bᵀ)| = Σ_k nnz_col_k(A)·nnz_row...
    // cheaper: count per k-index: (#rows of A with k) × (#rows of Bᵀ with k)
    let mut a_cnt = vec![0u32; a.cols()];
    for &c in &a.col_idx {
        a_cnt[c as usize] += 1;
    }
    if std::ptr::eq(a, b_t) {
        return a_cnt.iter().map(|&x| x as u64 * x as u64).sum();
    }
    let mut b_cnt = vec![0u32; b_t.cols()];
    for &c in &b_t.col_idx {
        b_cnt[c as usize] += 1;
    }
    a_cnt
        .iter()
        .zip(&b_cnt)
        .map(|(&x, &y)| x as u64 * y as u64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::spmm::dense::multiply as dense_ref;

    fn small_cfg() -> SyncMeshConfig {
        SyncMeshConfig { mesh: 4, round: 8 }
    }

    #[test]
    fn functional_matches_dense_reference() {
        let a = uniform(10, 24, 0.3, 1);
        let b = uniform(24, 9, 0.25, 2);
        let b_t = b.transpose();
        let (c, stats) = multiply_functional(&a, &b_t, small_cfg());
        let want = dense_ref(&a, &b);
        assert!(
            c.max_abs_diff(&want) < 1e-4,
            "max diff {}",
            c.max_abs_diff(&want)
        );
        assert!(stats.cycles > 0);
        assert!(stats.macs > 0);
    }

    #[test]
    fn functional_a_at_self_transpose() {
        let a = uniform(12, 20, 0.2, 3);
        let a_t = a.transpose();
        let (c, _) = multiply_functional(&a, &a, small_cfg()); // A×Aᵀ: b_t = (Aᵀ)ᵀ = A
        let want = dense_ref(&a, &a_t);
        assert!(c.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn cycle_model_agrees_with_functional() {
        for seed in 0..5 {
            let a = uniform(13, 40, 0.15, seed);
            let b = uniform(40, 11, 0.2, seed + 100);
            let b_t = b.transpose();
            let cfg = small_cfg();
            let (_, f) = multiply_functional(&a, &b_t, cfg);
            let m = cycle_model(&a, &b_t, cfg);
            assert_eq!(f.cycles, m.cycles, "seed {seed}");
            assert_eq!(f.passes, m.passes);
            assert_eq!(f.active_rounds, m.active_rounds);
            assert_eq!(f.macs, m.macs, "useful MAC accounting");
        }
    }

    #[test]
    fn denser_input_costs_more_cycles() {
        let cfg = SyncMeshConfig { mesh: 8, round: 32 };
        let sparse = uniform(32, 256, 0.02, 5);
        let dense = uniform(32, 256, 0.2, 5);
        let cs = cycle_model(&sparse, &sparse, cfg).cycles;
        let cd = cycle_model(&dense, &dense, cfg).cycles;
        assert!(cd > cs, "{cd} !> {cs}");
    }

    #[test]
    fn empty_matrix_costs_only_fill() {
        let a = uniform(8, 64, 0.0, 1);
        let cfg = small_cfg();
        let s = cycle_model(&a, &a, cfg);
        // 2x2 tile passes of `mesh` skew each, zero round work
        assert_eq!(s.passes, 4);
        assert_eq!(s.cycles, 4 * 4);
        assert_eq!(s.macs, 0);
    }

    #[test]
    fn utilization_below_one() {
        let a = uniform(32, 128, 0.1, 9);
        let s = cycle_model(&a, &a, SyncMeshConfig { mesh: 8, round: 32 });
        let u = s.utilization(8);
        assert!(u > 0.0 && u < 1.0, "utilization {u}");
    }
}
