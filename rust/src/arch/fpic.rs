//! FPIC baseline (Jamro et al. 2015, paper §IV.A) — the state-of-the-art
//! comparison point.
//!
//! An FPIC unit is an 8×8 systolic-like array where *every node reads its
//! operands independently* from 32-element row/column buffers (no sharing,
//! no synchronized movement) and runs Algorithm 1. A tile of 8×8 outputs
//! finishes when its slowest node's merge finishes. The paper scales FPIC
//! to `k` units assuming perfect load balancing: latency(k) = latency(1)/k
//! (§V.C) — we adopt the same best-case assumption.
//!
//! Two fidelities:
//! * [`Fidelity::Exact`] — run all 64 merges per tile (also produces C;
//!   used for correctness tests and small datasets).
//! * [`Fidelity::MaxNode`] — per tile, merge only the (max-nnz row,
//!   max-nnz col) pair and use it as the tile latency. The max-merge node
//!   is almost always the max-length pair since merge length is dominated
//!   by na+nb; the error is bounded by the match count and is validated
//!   against Exact in tests. Needed for the Table-IV-scale sweeps.

use super::node::{fpic_merge, fpic_merge_cycles};
use super::stream::StreamRef;
use crate::formats::csr::Csr;
use crate::formats::dense::Dense;
use crate::formats::traits::SparseMatrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fidelity {
    Exact,
    MaxNode,
}

#[derive(Clone, Copy, Debug)]
pub struct FpicConfig {
    /// Number of 8×8 units (k_FPIC in the paper's equations 1/2).
    pub units: usize,
    /// Unit edge — fixed to 8 in the paper/original design.
    pub unit_dim: usize,
    pub fidelity: Fidelity,
    /// Model the buffer-fill bandwidth bound (the paper's core critique:
    /// "each MAC node reads all its arguments directly from the inputs"
    /// with NO sharing, so every row/column stream is fetched once per node
    /// — `unit_dim`× duplicate traffic through the unit's 2·unit_dim
    /// operands/cycle input port). When a tile's duplicate-fetch time
    /// exceeds its slowest merge, the tile is fill-bound. Disable for the
    /// infinite-bandwidth ablation.
    pub model_bandwidth: bool,
}

impl Default for FpicConfig {
    fn default() -> Self {
        FpicConfig {
            units: 1,
            unit_dim: 8,
            fidelity: Fidelity::MaxNode,
            model_bandwidth: true,
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct FpicStats {
    /// Cycles on a single unit.
    pub cycles_one_unit: u64,
    /// Cycles with k units under perfect load balance (paper's assumption).
    pub cycles: u64,
    pub tiles: u64,
    pub macs: u64,
    /// Tiles whose latency was the buffer fill, not the merge.
    pub fill_bound_tiles: u64,
}

/// Simulate C = A × B (with `b_t` = Bᵀ in CSR) on FPIC. Returns stats and,
/// in Exact mode, the computed product.
pub fn simulate(a: &Csr, b_t: &Csr, cfg: FpicConfig) -> (FpicStats, Option<Dense>) {
    assert_eq!(a.cols(), b_t.cols());
    let m = a.rows();
    let n = b_t.rows();
    let d = cfg.unit_dim;
    let mut stats = FpicStats::default();
    let mut c = match cfg.fidelity {
        Fidelity::Exact => Some(Dense::zeros(m, n)),
        Fidelity::MaxNode => None,
    };

    let n_row_tiles = (m + d - 1) / d;
    let n_col_tiles = (n + d - 1) / d;
    for ti in 0..n_row_tiles {
        let rows = (ti * d)..((ti + 1) * d).min(m);
        for tj in 0..n_col_tiles {
            let cols = (tj * d)..((tj + 1) * d).min(n);
            stats.tiles += 1;
            let merge_cycles = match cfg.fidelity {
                Fidelity::Exact => {
                    let mut tile_cycles = 0u64;
                    for i in rows.clone() {
                        let (ai, av) = a.row(i);
                        let sa = StreamRef::new(ai, av);
                        for j in cols.clone() {
                            let (bi, bv) = b_t.row(j);
                            let sb = StreamRef::new(bi, bv);
                            let (cyc, dot) = fpic_merge(sa, sb);
                            tile_cycles = tile_cycles.max(cyc);
                            if dot != 0.0 {
                                *c.as_mut().unwrap().at_mut(i, j) = dot;
                            }
                        }
                    }
                    tile_cycles
                }
                Fidelity::MaxNode => {
                    // the slowest node is (max-nnz row, max-nnz col) to
                    // first order; merge exactly that one pair
                    let i_star = rows
                        .clone()
                        .max_by_key(|&i| a.row_nnz(i))
                        .expect("non-empty tile");
                    let j_star = cols
                        .clone()
                        .max_by_key(|&j| b_t.row_nnz(j))
                        .expect("non-empty tile");
                    let (ai, _) = a.row(i_star);
                    let (bi, _) = b_t.row(j_star);
                    fpic_merge_cycles(ai, bi)
                }
            };
            let tile_cycles = if cfg.model_bandwidth {
                // Every node in a unit row/column reads its own copy of the
                // stream: d·(Σ na + Σ nb) operand fetches through a
                // 2·d operands/cycle input port -> (Σ na + Σ nb)/2 cycles.
                let sum_a: u64 = rows.clone().map(|i| a.row_nnz(i) as u64).sum();
                let sum_b: u64 = cols.clone().map(|j| b_t.row_nnz(j) as u64).sum();
                let fill = (d as u64 * (sum_a + sum_b) + 2 * d as u64 - 1) / (2 * d as u64);
                if fill > merge_cycles {
                    stats.fill_bound_tiles += 1;
                }
                fill.max(merge_cycles)
            } else {
                merge_cycles
            };
            stats.cycles_one_unit += tile_cycles;
        }
    }
    stats.macs = super::sync_mesh::useful_macs(a, b_t);
    stats.cycles = (stats.cycles_one_unit + cfg.units as u64 - 1) / cfg.units as u64;
    (stats, c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::spmm::dense::multiply as dense_ref;

    #[test]
    fn exact_mode_computes_the_product() {
        let a = uniform(11, 30, 0.2, 1);
        let b = uniform(30, 13, 0.25, 2);
        let b_t = b.transpose();
        let (stats, c) = simulate(
            &a,
            &b_t,
            FpicConfig { units: 1, fidelity: Fidelity::Exact, ..FpicConfig::default() },
        );
        let want = dense_ref(&a, &b);
        assert!(c.unwrap().max_abs_diff(&want) < 1e-4);
        assert!(stats.cycles > 0);
        assert_eq!(stats.tiles, 2 * 2);
    }

    #[test]
    fn maxnode_is_close_to_exact() {
        for seed in 0..4 {
            let a = uniform(40, 200, 0.08, seed);
            let (exact, _) = simulate(
                &a,
                &a,
                FpicConfig { units: 1, fidelity: Fidelity::Exact, ..FpicConfig::default() },
            );
            let (fast, _) = simulate(
                &a,
                &a,
                FpicConfig { units: 1, fidelity: Fidelity::MaxNode, ..FpicConfig::default() },
            );
            let rel = (exact.cycles as f64 - fast.cycles as f64).abs() / exact.cycles as f64;
            assert!(
                rel < 0.12,
                "seed {seed}: exact {} vs maxnode {} (rel {rel})",
                exact.cycles,
                fast.cycles
            );
            // MaxNode can only under- or slightly mis-estimate; it must not
            // exceed exact by more than the match slack
            assert!(fast.cycles_one_unit <= exact.cycles_one_unit);
        }
    }

    #[test]
    fn k_units_divide_latency() {
        let a = uniform(32, 64, 0.1, 3);
        let (one, _) = simulate(&a, &a, FpicConfig::default());
        let (eight, _) = simulate(
            &a,
            &a,
            FpicConfig { units: 8, ..FpicConfig::default() },
        );
        assert_eq!(eight.cycles, (one.cycles_one_unit + 7) / 8);
    }

    #[test]
    fn empty_matrix_zero_cycles() {
        let a = uniform(8, 16, 0.0, 1);
        let (s, _) = simulate(&a, &a, FpicConfig::default());
        assert_eq!(s.cycles, 0);
    }
}
