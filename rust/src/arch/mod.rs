//! Cycle-accurate architecture simulators (paper §IV/§V.C).
//!
//! * [`sync_mesh`] — the paper's proposed synchronized comparator mesh
//!   (Algorithm 2): node-level functional sim + fast stream-level cycle
//!   model, cross-validated.
//! * [`fpic`] — the FPIC baseline (Algorithm 1, 8×8 units, independent
//!   per-node reads, perfect k-unit load-balance scaling).
//! * [`conventional`] — dense systolic MM (density-independent).
//! * [`model`] — the paper's fairness equations (1)/(2) and Table V
//!   resource accounting.
//!
//! All simulators share the paper's §V.A assumptions: memory supplies
//! operands every cycle, and every MAC/comparison is single-cycle.

pub mod conventional;
pub mod fpic;
pub mod model;
pub mod node;
pub mod stream;
pub mod sync_mesh;

pub use conventional::{cycles as conv_cycles, ConvMmConfig, ConvMmStats};
pub use fpic::{simulate as fpic_simulate, Fidelity, FpicConfig, FpicStats};
pub use model::{table5, DesignPoint};
pub use sync_mesh::{
    cycle_model as sync_cycle_model, multiply_functional as sync_multiply,
    useful_macs, SyncMeshConfig, SyncMeshStats,
};
