//! ELLPACK (paper §II.A.1): two `rows × width` matrices holding padded
//! non-zero values and their column indices, where `width` is the maximum
//! row population. Random access scans the target row's slots — Table I
//! groups it with CRS/LiL at ≈ ½·N·D accesses.

use super::coo::Coo;
use super::traits::{
    AccessSink, AddressSpace, FormatKind, Region, Site, SparseMatrix,
};

const PAD: u32 = u32::MAX;

#[derive(Clone, Debug)]
pub struct Ellpack {
    rows: usize,
    cols: usize,
    pub width: usize,
    /// rows × width, row-major, PAD-filled tail per row, sorted per row.
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
    nnz: usize,
    r_idx: Region,
    r_val: Region,
}

impl Ellpack {
    pub fn from_coo(c: &Coo) -> Ellpack {
        let mut space = AddressSpace::default();
        Self::from_coo_with_space(c, &mut space)
    }

    pub fn from_coo_with_space(c: &Coo, space: &mut AddressSpace) -> Ellpack {
        let (rows, cols) = c.shape();
        let mut per_row: Vec<usize> = vec![0; rows];
        for &(r, _, _) in &c.entries {
            per_row[r as usize] += 1;
        }
        let width = per_row.iter().copied().max().unwrap_or(0);
        let mut col_idx = vec![PAD; rows * width];
        let mut vals = vec![0.0f32; rows * width];
        let mut cursor = vec![0usize; rows];
        for &(r, cc, v) in &c.entries {
            let r = r as usize;
            let k = r * width + cursor[r];
            col_idx[k] = cc;
            vals[k] = v;
            cursor[r] += 1;
        }
        Ellpack {
            rows,
            cols,
            width,
            col_idx,
            vals,
            nnz: c.nnz(),
            r_idx: space.alloc(rows * width, 4),
            r_val: space.alloc(rows * width, 4),
        }
    }

    /// Scan the row's slots in order; PAD or an index past `j` ends a miss.
    /// (The row base is computed, not loaded — ELLPACK has no pointer
    /// vector, which is exactly why Table I charges it only the scan.)
    pub fn locate(&self, i: usize, j: usize, sink: &mut impl AccessSink) -> Option<f32> {
        let tj = j as u32;
        let base = i * self.width;
        for s in 0..self.width {
            sink.touch(self.r_idx.at(base + s), Site::Idx);
            let c = self.col_idx[base + s];
            if c == tj {
                sink.touch(self.r_val.at(base + s), Site::Val);
                return Some(self.vals[base + s]);
            }
            if c > tj {
                // PAD == u32::MAX also lands here
                return None;
            }
        }
        None
    }
}

impl SparseMatrix for Ellpack {
    fn kind(&self) -> FormatKind {
        FormatKind::Ellpack
    }
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        self.nnz
    }
    fn storage_words(&self) -> usize {
        2 * self.rows * self.width
    }
    fn locate_dyn(&self, i: usize, j: usize, mut sink: &mut dyn AccessSink) -> Option<f32> {
        self.locate(i, j, &mut sink)
    }
    fn to_coo(&self) -> Coo {
        let mut entries = Vec::with_capacity(self.nnz);
        for i in 0..self.rows {
            for s in 0..self.width {
                let k = i * self.width + s;
                if self.col_idx[k] != PAD {
                    entries.push((i as u32, self.col_idx[k], self.vals[k]));
                }
            }
        }
        Coo::new(self.rows, self.cols, entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::traits::CountSink;

    fn sample() -> Ellpack {
        Ellpack::from_coo(&Coo::new(
            3,
            4,
            vec![
                (0, 0, 1.0),
                (0, 2, 2.0),
                (1, 3, 3.0),
                (2, 0, 4.0),
                (2, 1, 5.0),
            ],
        ))
    }

    #[test]
    fn width_is_max_row_population() {
        let m = sample();
        assert_eq!(m.width, 2);
        assert_eq!(m.storage_words(), 2 * 3 * 2);
    }

    #[test]
    fn locate_values() {
        let m = sample();
        assert_eq!(m.get(0, 2), Some(2.0));
        assert_eq!(m.get(1, 3), Some(3.0));
        assert_eq!(m.get(1, 0), None);
        assert_eq!(m.get(2, 3), None);
    }

    #[test]
    fn padding_terminates_scan() {
        let m = sample();
        // row 1 has 1 real slot + 1 pad; probing col 0 (< 3) stops at slot 0
        let mut s = CountSink::default();
        assert_eq!(m.locate(1, 0, &mut s), None);
        assert_eq!(s.total, 1);
    }

    #[test]
    fn coo_roundtrip() {
        let m = sample();
        let back = Ellpack::from_coo(&m.to_coo());
        assert_eq!(back.col_idx, m.col_idx);
        assert_eq!(back.vals, m.vals);
    }

    #[test]
    fn empty_matrix() {
        let m = Ellpack::from_coo(&Coo::new(2, 2, vec![]));
        assert_eq!(m.width, 0);
        assert_eq!(m.get(1, 1), None);
    }
}
