//! Jagged Diagonal (JAD, paper §II.A.5): rows are sorted by descending
//! population; storage interleaves "the first non-zero of every row, then
//! the second non-zero of every row, ...". `jad_ptr[d]` points at the start
//! of jagged diagonal `d`.
//!
//! Random access to `(i, j)`: find the row's sorted position, then step
//! through diagonals — each step needs `jad_ptr[d]` *and* the column index,
//! which is why Table I charges JAD ≈ N·D (twice the CRS scan).

use super::coo::Coo;
use super::traits::{
    AccessSink, AddressSpace, FormatKind, Region, Site, SparseMatrix,
};

#[derive(Clone, Debug)]
pub struct Jad {
    rows: usize,
    cols: usize,
    /// perm[p] = original row stored at sorted position p.
    pub perm: Vec<u32>,
    /// inv_perm[original row] = sorted position.
    pub inv_perm: Vec<u32>,
    /// jad_ptr[d] = offset of diagonal d; len = max_row_nnz + 1.
    pub jad_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
    r_perm: Region,
    r_jp: Region,
    r_idx: Region,
    r_val: Region,
}

impl Jad {
    pub fn from_coo(c: &Coo) -> Jad {
        let mut space = AddressSpace::default();
        Self::from_coo_with_space(c, &mut space)
    }

    pub fn from_coo_with_space(c: &Coo, space: &mut AddressSpace) -> Jad {
        let (rows, cols) = c.shape();
        let mut per_row: Vec<Vec<(u32, f32)>> = vec![Vec::new(); rows];
        for &(r, cc, v) in &c.entries {
            per_row[r as usize].push((cc, v));
        }
        // sort rows by descending population (stable: ties keep row order)
        let mut perm: Vec<u32> = (0..rows as u32).collect();
        perm.sort_by_key(|&r| std::cmp::Reverse(per_row[r as usize].len()));
        let mut inv_perm = vec![0u32; rows];
        for (p, &r) in perm.iter().enumerate() {
            inv_perm[r as usize] = p as u32;
        }
        let max_nnz = per_row.iter().map(Vec::len).max().unwrap_or(0);
        let mut jad_ptr = Vec::with_capacity(max_nnz + 1);
        let mut col_idx = Vec::with_capacity(c.nnz());
        let mut vals = Vec::with_capacity(c.nnz());
        jad_ptr.push(0);
        for d in 0..max_nnz {
            for &r in &perm {
                if let Some(&(cc, v)) = per_row[r as usize].get(d) {
                    col_idx.push(cc);
                    vals.push(v);
                } else {
                    break; // rows sorted by population: rest are shorter
                }
            }
            jad_ptr.push(col_idx.len() as u32);
        }
        Jad {
            rows,
            cols,
            perm,
            inv_perm,
            jad_ptr,
            col_idx,
            vals,
            r_perm: space.alloc(rows, 4),
            r_jp: space.alloc(max_nnz + 1, 4),
            r_idx: space.alloc(c.nnz(), 4),
            r_val: space.alloc(c.nnz(), 4),
        }
    }

    /// Number of rows that have a d-th non-zero (diagonal d length).
    fn diag_len(&self, d: usize) -> usize {
        (self.jad_ptr[d + 1] - self.jad_ptr[d]) as usize
    }

    /// Per the paper's cost model: 1 access to map the row (perm lookup),
    /// then per diagonal 1 access to `jad_ptr` + 1 to the column index —
    /// "unlike CRS, the NZs of a row are not stored sequentially; locate
    /// each one of them using jadPtr".
    pub fn locate(&self, i: usize, j: usize, sink: &mut impl AccessSink) -> Option<f32> {
        sink.touch(self.r_perm.at(i), Site::Aux);
        let p = self.inv_perm[i] as usize;
        let tj = j as u32;
        let ndiag = self.jad_ptr.len() - 1;
        for d in 0..ndiag {
            sink.touch(self.r_jp.at(d), Site::JadPtr);
            if p >= self.diag_len(d) {
                return None; // row exhausted
            }
            let k = self.jad_ptr[d] as usize + p;
            sink.touch(self.r_idx.at(k), Site::Idx);
            let c = self.col_idx[k];
            if c == tj {
                sink.touch(self.r_val.at(k), Site::Val);
                return Some(self.vals[k]);
            }
            if c > tj {
                return None; // row columns ascend across diagonals
            }
        }
        None
    }
}

impl SparseMatrix for Jad {
    fn kind(&self) -> FormatKind {
        FormatKind::Jad
    }
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        self.col_idx.len()
    }
    fn storage_words(&self) -> usize {
        self.rows + self.jad_ptr.len() + 2 * self.nnz()
    }
    fn locate_dyn(&self, i: usize, j: usize, mut sink: &mut dyn AccessSink) -> Option<f32> {
        self.locate(i, j, &mut sink)
    }
    fn to_coo(&self) -> Coo {
        let mut entries = Vec::with_capacity(self.nnz());
        let ndiag = self.jad_ptr.len() - 1;
        for d in 0..ndiag {
            for p in 0..self.diag_len(d) {
                let k = self.jad_ptr[d] as usize + p;
                entries.push((self.perm[p], self.col_idx[k], self.vals[k]));
            }
        }
        Coo::new(self.rows, self.cols, entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::traits::CountSink;

    fn sample() -> Jad {
        // row populations: r0=2, r1=1, r2=2 -> perm [0,2,1] (stable desc)
        Jad::from_coo(&Coo::new(
            3,
            4,
            vec![
                (0, 0, 1.0),
                (0, 2, 2.0),
                (1, 3, 3.0),
                (2, 0, 4.0),
                (2, 1, 5.0),
            ],
        ))
    }

    #[test]
    fn permutation_sorts_by_population() {
        let m = sample();
        assert_eq!(m.perm, vec![0, 2, 1]);
        assert_eq!(m.inv_perm, vec![0, 2, 1]);
        // diagonal 0 = first nz of rows [0,2,1] = cols [0,0,3]
        assert_eq!(&m.col_idx[..3], &[0, 0, 3]);
        // diagonal 1 = second nz of rows [0,2] = cols [2,1]
        assert_eq!(&m.col_idx[3..], &[2, 1]);
        assert_eq!(m.jad_ptr, vec![0, 3, 5]);
    }

    #[test]
    fn locate_values() {
        let m = sample();
        for (i, j, v) in [(0, 0, 1.0), (0, 2, 2.0), (1, 3, 3.0), (2, 0, 4.0), (2, 1, 5.0)] {
            assert_eq!(m.get(i, j), Some(v), "({i},{j})");
        }
        assert_eq!(m.get(0, 1), None);
        assert_eq!(m.get(1, 0), None);
        assert_eq!(m.get(2, 3), None);
    }

    #[test]
    fn per_step_cost_is_twice_crs() {
        let m = sample();
        // (2,1): perm + d0(jad_ptr+idx) + d1(jad_ptr+idx) + val = 6
        let mut s = CountSink::default();
        assert_eq!(m.locate(2, 1, &mut s), Some(5.0));
        assert_eq!(s.total, 6);
        assert_eq!(s.site(Site::JadPtr), 2);
    }

    #[test]
    fn coo_roundtrip() {
        let m = sample();
        let rt = Jad::from_coo(&m.to_coo());
        assert_eq!(rt.col_idx, m.col_idx);
        assert_eq!(rt.vals, m.vals);
        assert_eq!(rt.perm, m.perm);
    }

    #[test]
    fn empty_and_uniform() {
        let e = Jad::from_coo(&Coo::new(2, 2, vec![]));
        assert_eq!(e.get(0, 0), None);
        let u = Jad::from_coo(&Coo::new(
            2,
            2,
            vec![(0, 0, 1.0), (0, 1, 2.0), (1, 0, 3.0), (1, 1, 4.0)],
        ));
        assert_eq!(u.get(1, 1), Some(4.0));
    }
}
