//! Co-ordinate list (COO) — also the canonical interchange form: every
//! format converts to/from COO, so any-to-any conversion is two hops.
//!
//! Stored as three parallel arrays (row, col, val). Random access has no
//! pointer structure at all: a linear scan over all entries stored before
//! the target (paper Table I: ≈ ½·M·N·D accesses).

use super::traits::{
    AccessSink, AddressSpace, FormatKind, Region, SparseMatrix,
};

#[derive(Clone, Debug)]
pub struct Coo {
    rows: usize,
    cols: usize,
    /// Entries sorted row-major (row, then col), unique coordinates.
    pub entries: Vec<(u32, u32, f32)>,
    r_row: Region,
    r_col: Region,
    r_val: Region,
}

impl Coo {
    /// Build from (possibly unsorted, must-be-unique) triplets.
    ///
    /// Entries that already arrive in row-major order — every CSR/format
    /// `to_coo()` render, MatrixMarket files written by this crate — skip
    /// the sort entirely: one ordered-scan check replaces the O(n log n)
    /// call (the format-polymorphic-ingestion fast path the ROADMAP
    /// names). The check uses strict ordering, so duplicate coordinates
    /// still take the sort path and trip the duplicate assert below.
    pub fn new(rows: usize, cols: usize, mut entries: Vec<(u32, u32, f32)>) -> Coo {
        let row_major = entries
            .windows(2)
            .all(|w| (w[0].0, w[0].1) < (w[1].0, w[1].1));
        if !row_major {
            entries.sort_unstable_by_key(|&(r, c, _)| (r, c));
        }
        for w in entries.windows(2) {
            assert!(
                (w[0].0, w[0].1) != (w[1].0, w[1].1),
                "duplicate coordinate ({}, {})",
                w[0].0,
                w[0].1
            );
        }
        if let Some(&(r, c, _)) = entries.last() {
            let max_r = entries.iter().map(|e| e.0).max().unwrap_or(r);
            let max_c = entries.iter().map(|e| e.1).max().unwrap_or(c);
            assert!((max_r as usize) < rows, "row {max_r} out of {rows}");
            assert!((max_c as usize) < cols, "col {max_c} out of {cols}");
        }
        let mut space = AddressSpace::default();
        Self::with_space(rows, cols, entries, &mut space)
    }

    /// Like [`Coo::new`] but placing arrays in a caller-owned address space
    /// (so multiple matrices in one simulation get disjoint addresses).
    pub fn with_space(
        rows: usize,
        cols: usize,
        entries: Vec<(u32, u32, f32)>,
        space: &mut AddressSpace,
    ) -> Coo {
        let n = entries.len();
        Coo {
            rows,
            cols,
            entries,
            r_row: space.alloc(n, 4),
            r_col: space.alloc(n, 4),
            r_val: space.alloc(n, 4),
        }
    }

    /// Dense -> COO (drops exact zeros).
    pub fn from_dense(rows: usize, cols: usize, data: &[f32]) -> Coo {
        assert_eq!(data.len(), rows * cols);
        let mut entries = Vec::new();
        for i in 0..rows {
            for j in 0..cols {
                let v = data[i * cols + j];
                if v != 0.0 {
                    entries.push((i as u32, j as u32, v));
                }
            }
        }
        Coo::new(rows, cols, entries)
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut d = vec![0.0f32; self.rows * self.cols];
        for &(r, c, v) in &self.entries {
            d[r as usize * self.cols + c as usize] = v;
        }
        d
    }

    /// Random access with the paper's COO cost model: scan entries from the
    /// start; each scanned record is one access (the row/col pair is read as
    /// one unit); the value read on a hit is one more.
    pub fn locate(&self, i: usize, j: usize, sink: &mut impl AccessSink) -> Option<f32> {
        let (ti, tj) = (i as u32, j as u32);
        for (k, &(r, c, v)) in self.entries.iter().enumerate() {
            sink.touch(self.r_row.at(k), super::traits::Site::Entry);
            if r > ti || (r == ti && c > tj) {
                return None;
            }
            if r == ti && c == tj {
                sink.touch(self.r_val.at(k), super::traits::Site::Val);
                return Some(v);
            }
        }
        None
    }

    /// Entries of row `i` as (col, val), sorted by col — no accounting.
    pub fn row(&self, i: usize) -> Vec<(u32, f32)> {
        let i = i as u32;
        let lo = self.entries.partition_point(|e| e.0 < i);
        let hi = self.entries.partition_point(|e| e.0 <= i);
        self.entries[lo..hi].iter().map(|&(_, c, v)| (c, v)).collect()
    }

    /// Column-index region (used by cache-trace drivers).
    pub fn col_region(&self) -> Region {
        self.r_col
    }

    /// Structural invariants of the entry list: strictly row-major order
    /// (which also implies unique coordinates) and in-bounds coordinates.
    /// [`Coo::new`] establishes both, but `entries` is `pub`, so
    /// corruption can enter after construction.
    pub fn validate_invariants(&self) -> Result<(), super::error::FormatError> {
        let err = |detail: String| super::error::FormatError::CorruptStructure {
            format: "coo",
            detail,
        };
        for w in self.entries.windows(2) {
            if (w[0].0, w[0].1) >= (w[1].0, w[1].1) {
                return Err(err(format!(
                    "entries not strictly row-major at ({}, {}) then ({}, {})",
                    w[0].0, w[0].1, w[1].0, w[1].1
                )));
            }
        }
        for &(r, c, _) in &self.entries {
            if r as usize >= self.rows || c as usize >= self.cols {
                return Err(err(format!(
                    "entry ({r}, {c}) out of bounds ({} × {})",
                    self.rows, self.cols
                )));
            }
        }
        Ok(())
    }
}

impl SparseMatrix for Coo {
    fn kind(&self) -> FormatKind {
        FormatKind::Coo
    }
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        self.entries.len()
    }
    fn storage_words(&self) -> usize {
        3 * self.entries.len() // row + col + val per entry
    }
    fn locate_dyn(&self, i: usize, j: usize, mut sink: &mut dyn AccessSink) -> Option<f32> {
        self.locate(i, j, &mut sink)
    }
    fn to_coo(&self) -> Coo {
        self.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::traits::CountSink;

    fn sample() -> Coo {
        // 3x4:
        // [1 0 2 0]
        // [0 0 0 3]
        // [4 5 0 0]
        Coo::new(
            3,
            4,
            vec![
                (2, 1, 5.0),
                (0, 0, 1.0),
                (0, 2, 2.0),
                (1, 3, 3.0),
                (2, 0, 4.0),
            ],
        )
    }

    #[test]
    fn validate_invariants_accepts_valid_and_rejects_corruption() {
        let m = sample();
        assert_eq!(m.validate_invariants(), Ok(()));
        // construction sorted the entries; break the order afterwards
        let mut bad = m.clone();
        bad.entries.swap(0, 1);
        assert!(bad
            .validate_invariants()
            .is_err_and(|e| e.to_string().contains("row-major")));
        // duplicate coordinate is also an ordering violation (strict <)
        let mut bad = m.clone();
        bad.entries[1] = bad.entries[0];
        assert!(bad.validate_invariants().is_err());
        let mut bad = m.clone();
        bad.entries[4] = (2, 9, 1.0);
        assert!(bad
            .validate_invariants()
            .is_err_and(|e| e.to_string().contains("out of bounds")));
    }

    #[test]
    fn sorts_entries() {
        let c = sample();
        let coords: Vec<(u32, u32)> = c.entries.iter().map(|e| (e.0, e.1)).collect();
        assert_eq!(coords, vec![(0, 0), (0, 2), (1, 3), (2, 0), (2, 1)]);
    }

    #[test]
    fn dense_roundtrip() {
        let c = sample();
        let d = c.to_dense();
        let c2 = Coo::from_dense(3, 4, &d);
        assert_eq!(c.entries, c2.entries);
    }

    #[test]
    fn locate_hits_and_misses() {
        let c = sample();
        assert_eq!(c.get(0, 2), Some(2.0));
        assert_eq!(c.get(2, 1), Some(5.0));
        assert_eq!(c.get(0, 1), None);
        assert_eq!(c.get(2, 3), None);
    }

    #[test]
    fn locate_cost_grows_with_position() {
        let c = sample();
        let mut early = CountSink::default();
        c.locate(0, 0, &mut early);
        let mut late = CountSink::default();
        c.locate(2, 1, &mut late);
        assert!(late.total > early.total, "{} !> {}", late.total, early.total);
        // last entry: scans all 5 entries + 1 value read
        assert_eq!(late.total, 6);
    }

    #[test]
    fn row_extraction() {
        let c = sample();
        assert_eq!(c.row(0), vec![(0, 1.0), (2, 2.0)]);
        assert_eq!(c.row(1), vec![(3, 3.0)]);
        assert_eq!(c.row(2), vec![(0, 4.0), (1, 5.0)]);
    }

    #[test]
    fn row_major_fast_path_matches_the_sorting_path_bitwise() {
        let sorted = sample().entries.clone();
        let mut reversed = sorted.clone();
        reversed.reverse();
        let fast = Coo::new(3, 4, sorted); // already row-major: no sort
        let slow = Coo::new(3, 4, reversed); // forces the sort path
        assert_eq!(fast.entries.len(), slow.entries.len());
        for (x, y) in fast.entries.iter().zip(&slow.entries) {
            assert_eq!((x.0, x.1, x.2.to_bits()), (y.0, y.1, y.2.to_bits()));
        }
    }

    #[test]
    #[should_panic(expected = "duplicate coordinate")]
    fn rejects_duplicates() {
        Coo::new(2, 2, vec![(0, 0, 1.0), (0, 0, 2.0)]);
    }

    #[test]
    #[should_panic(expected = "duplicate coordinate")]
    fn rejects_duplicates_in_row_major_input() {
        // adjacent duplicates fail the strict-order check, take the sort
        // path, and still trip the duplicate assert
        Coo::new(2, 2, vec![(0, 0, 1.0), (0, 0, 2.0), (1, 1, 3.0)]);
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn rejects_out_of_bounds() {
        Coo::new(2, 2, vec![(0, 5, 1.0)]);
    }

    #[test]
    fn storage_words() {
        assert_eq!(sample().storage_words(), 15);
    }
}
