//! Single Linear List (SLL, paper §II.A.3/4): all non-zeros stored
//! sequentially as (row, col, val) records in one array — like COO but as an
//! array-of-structs instead of three parallel arrays. Same Table I cost
//! (≈ ½·M·N·D: no pointer, scan everything before the target) but a
//! different cache footprint, which is why both exist in the eval.

use super::coo::Coo;
use super::traits::{
    AccessSink, AddressSpace, FormatKind, Region, Site, SparseMatrix,
};

#[derive(Clone, Debug)]
pub struct Sll {
    rows: usize,
    cols: usize,
    /// Row-major sorted (row, col, val) records.
    pub records: Vec<(u32, u32, f32)>,
    r_rec: Region,
}

impl Sll {
    pub fn from_coo(c: &Coo) -> Sll {
        let mut space = AddressSpace::default();
        Self::from_coo_with_space(c, &mut space)
    }

    pub fn from_coo_with_space(c: &Coo, space: &mut AddressSpace) -> Sll {
        let (rows, cols) = c.shape();
        Sll {
            rows,
            cols,
            records: c.entries.clone(),
            // one record = row u32 + col u32 + val f32 = 12 bytes
            r_rec: space.alloc(c.nnz(), 12),
        }
    }

    /// Linear scan of the record array; one access per scanned record, plus
    /// the value read (within the same record — counted separately so the
    /// per-site split stays comparable with COO).
    pub fn locate(&self, i: usize, j: usize, sink: &mut impl AccessSink) -> Option<f32> {
        let (ti, tj) = (i as u32, j as u32);
        for (k, &(r, c, v)) in self.records.iter().enumerate() {
            sink.touch(self.r_rec.at(k), Site::Entry);
            if r > ti || (r == ti && c > tj) {
                return None;
            }
            if r == ti && c == tj {
                sink.touch(self.r_rec.at(k) + 8, Site::Val);
                return Some(v);
            }
        }
        None
    }
}

impl SparseMatrix for Sll {
    fn kind(&self) -> FormatKind {
        FormatKind::Sll
    }
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        self.records.len()
    }
    fn storage_words(&self) -> usize {
        3 * self.records.len()
    }
    fn locate_dyn(&self, i: usize, j: usize, mut sink: &mut dyn AccessSink) -> Option<f32> {
        self.locate(i, j, &mut sink)
    }
    fn to_coo(&self) -> Coo {
        Coo::new(self.rows, self.cols, self.records.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::traits::CountSink;

    fn sample() -> Sll {
        Sll::from_coo(&Coo::new(
            3,
            4,
            vec![
                (0, 0, 1.0),
                (0, 2, 2.0),
                (1, 3, 3.0),
                (2, 0, 4.0),
                (2, 1, 5.0),
            ],
        ))
    }

    #[test]
    fn locate_values() {
        let m = sample();
        assert_eq!(m.get(1, 3), Some(3.0));
        assert_eq!(m.get(1, 2), None);
    }

    #[test]
    fn scan_cost_is_position() {
        let m = sample();
        let mut s = CountSink::default();
        m.locate(2, 0, &mut s); // 4th record + value
        assert_eq!(s.total, 5);
        let mut s = CountSink::default();
        m.locate(0, 0, &mut s);
        assert_eq!(s.total, 2);
    }

    #[test]
    fn early_exit_on_passed_coordinate() {
        let m = sample();
        let mut s = CountSink::default();
        assert_eq!(m.locate(0, 3, &mut s), None);
        // scans (0,0),(0,2),(1,3): third record exceeds (0,3)
        assert_eq!(s.total, 3);
    }

    #[test]
    fn coo_roundtrip() {
        let m = sample();
        assert_eq!(Sll::from_coo(&m.to_coo()).records, m.records);
    }
}
