//! Compressed Column Storage (CCS) — the transpose of CRS (paper §II.A.6).
//!
//! Column-order access is trivial here; *row*-order access pays the linear
//! scan. CCS exists in the eval as the "store it in both orders" strawman
//! the paper argues is impractical for large datasets.

use super::coo::Coo;
use super::csr::Csr;
use super::error::FormatError;
use super::traits::{
    AccessSink, AddressSpace, FormatKind, Region, Site, SparseMatrix,
};

#[derive(Clone, Debug)]
pub struct Csc {
    rows: usize,
    cols: usize,
    pub col_ptr: Vec<u32>, // len cols+1
    pub row_idx: Vec<u32>, // len nnz, sorted within each column
    pub vals: Vec<f32>,
    r_ptr: Region,
    r_idx: Region,
    r_val: Region,
}

impl Csc {
    pub fn from_coo(c: &Coo) -> Csc {
        let mut space = AddressSpace::default();
        Self::from_coo_with_space(c, &mut space)
    }

    pub fn from_coo_with_space(c: &Coo, space: &mut AddressSpace) -> Csc {
        let (rows, cols) = c.shape();
        // reuse the CSR transpose machinery: CSC of M == CSR of Mᵀ
        let csr_t = Csr::from_coo(c).transpose();
        let nnz = csr_t.nnz();
        Csc {
            rows,
            cols,
            col_ptr: csr_t.row_ptr.clone(),
            row_idx: csr_t.col_idx.clone(),
            vals: csr_t.vals.clone(),
            r_ptr: space.alloc(cols + 1, 4),
            r_idx: space.alloc(nnz, 4),
            r_val: space.alloc(nnz, 4),
        }
    }

    /// Direct CRS → CCS without a COO hop: the CCS arrays of `M` *are* the
    /// CRS arrays of `Mᵀ`, and [`Csr::transpose`] is a stable counting
    /// sort, so the arrays are moved (not cloned) out of the transpose and
    /// carry exactly the bits [`Csc::from_coo`] would produce.
    pub fn from_csr(m: &Csr) -> Csc {
        let mut space = AddressSpace::default();
        let t = m.transpose();
        let nnz = t.nnz();
        Csc {
            rows: m.rows(),
            cols: m.cols(),
            col_ptr: t.row_ptr,
            row_idx: t.col_idx,
            vals: t.vals,
            r_ptr: space.alloc(m.cols() + 1, 4),
            r_idx: space.alloc(nnz, 4),
            r_val: space.alloc(nnz, 4),
        }
    }

    /// Column `j` as (row indices, vals) — the cheap direction.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f32]) {
        let lo = self.col_ptr[j] as usize;
        let hi = self.col_ptr[j + 1] as usize;
        (&self.row_idx[lo..hi], &self.vals[lo..hi])
    }

    /// Mirror of CRS locate: pointer + linear scan of the *column*.
    pub fn locate(&self, i: usize, j: usize, sink: &mut impl AccessSink) -> Option<f32> {
        sink.touch(self.r_ptr.at(j), Site::Ptr);
        let lo = self.col_ptr[j] as usize;
        let hi = self.col_ptr[j + 1] as usize;
        let ti = i as u32;
        for k in lo..hi {
            sink.touch(self.r_idx.at(k), Site::Idx);
            let r = self.row_idx[k];
            if r == ti {
                sink.touch(self.r_val.at(k), Site::Val);
                return Some(self.vals[k]);
            }
            if r > ti {
                return None;
            }
        }
        None
    }

    /// Structural invariants of the CCS arrays — the column-major mirror
    /// of [`Csr::validate_invariants`]: pointer length/endpoints,
    /// monotonicity, strictly-increasing in-bounds row indices per
    /// column, index/value agreement.
    pub fn validate_invariants(&self) -> Result<(), FormatError> {
        let err = |detail: String| FormatError::CorruptStructure {
            format: "ccs",
            detail,
        };
        if self.col_ptr.len() != self.cols + 1 {
            return Err(err(format!(
                "col_ptr len {} != cols+1 ({})",
                self.col_ptr.len(),
                self.cols + 1
            )));
        }
        if self.col_ptr.first() != Some(&0) {
            return Err(err("col_ptr[0] != 0".into()));
        }
        for (j, w) in self.col_ptr.windows(2).enumerate() {
            if w[0] > w[1] {
                return Err(err(format!(
                    "col_ptr not monotone at col {j}: {} > {}",
                    w[0], w[1]
                )));
            }
        }
        if self.row_idx.len() != self.vals.len() {
            return Err(err(format!(
                "row_idx len {} != vals len {}",
                self.row_idx.len(),
                self.vals.len()
            )));
        }
        let last = self.col_ptr.last().copied().unwrap_or(0) as usize;
        if last != self.row_idx.len() {
            return Err(err(format!(
                "col_ptr end {last} != nnz {}",
                self.row_idx.len()
            )));
        }
        for j in 0..self.cols {
            let lo = self.col_ptr[j] as usize;
            let hi = self.col_ptr[j + 1] as usize;
            let rs = &self.row_idx[lo..hi];
            for w in rs.windows(2) {
                if w[0] >= w[1] {
                    return Err(err(format!(
                        "col {j}: row_idx not strictly increasing ({} then {})",
                        w[0], w[1]
                    )));
                }
            }
            if let Some(&r) = rs.last() {
                if r as usize >= self.rows {
                    return Err(err(format!(
                        "col {j}: row {r} out of bounds (rows = {})",
                        self.rows
                    )));
                }
            }
        }
        Ok(())
    }

    /// Sequential read of one whole column (the ideal Fig-3 comparator):
    /// pointer + every (idx, val) pair in the column.
    pub fn read_col(&self, j: usize, sink: &mut impl AccessSink) -> usize {
        sink.touch(self.r_ptr.at(j), Site::Ptr);
        let lo = self.col_ptr[j] as usize;
        let hi = self.col_ptr[j + 1] as usize;
        for k in lo..hi {
            sink.touch(self.r_idx.at(k), Site::Idx);
            sink.touch(self.r_val.at(k), Site::Val);
        }
        hi - lo
    }
}

impl SparseMatrix for Csc {
    fn kind(&self) -> FormatKind {
        FormatKind::Csc
    }
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        self.row_idx.len()
    }
    fn storage_words(&self) -> usize {
        (self.cols + 1) + 2 * self.nnz()
    }
    fn locate_dyn(&self, i: usize, j: usize, mut sink: &mut dyn AccessSink) -> Option<f32> {
        self.locate(i, j, &mut sink)
    }
    fn to_coo(&self) -> Coo {
        let mut entries = Vec::with_capacity(self.nnz());
        for j in 0..self.cols {
            let (rs, vs) = self.col(j);
            for (&r, &v) in rs.iter().zip(vs) {
                entries.push((r, j as u32, v));
            }
        }
        Coo::new(self.rows, self.cols, entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::traits::CountSink;

    #[test]
    fn validate_invariants_accepts_valid_and_rejects_corruption() {
        let m = sample();
        assert_eq!(m.validate_invariants(), Ok(()));
        let mut bad = m.clone();
        bad.col_ptr[1] = 90;
        assert!(bad
            .validate_invariants()
            .is_err_and(|e| e.to_string().contains("not monotone")));
        let mut bad = m.clone();
        bad.row_idx[0] = 70;
        assert!(bad
            .validate_invariants()
            .is_err_and(|e| e.to_string().contains("out of bounds")
                || e.to_string().contains("strictly increasing")));
        let mut bad = m.clone();
        bad.vals.pop();
        assert!(bad.validate_invariants().is_err());
    }

    fn sample() -> Csc {
        Csc::from_coo(&Coo::new(
            3,
            4,
            vec![
                (0, 0, 1.0),
                (0, 2, 2.0),
                (1, 3, 3.0),
                (2, 0, 4.0),
                (2, 1, 5.0),
            ],
        ))
    }

    #[test]
    fn structure() {
        let m = sample();
        assert_eq!(m.col_ptr, vec![0, 2, 3, 4, 5]);
        assert_eq!(m.col(0), (&[0u32, 2][..], &[1.0f32, 4.0][..]));
    }

    #[test]
    fn locate_matches_csr_values() {
        let m = sample();
        assert_eq!(m.get(0, 2), Some(2.0));
        assert_eq!(m.get(2, 1), Some(5.0));
        assert_eq!(m.get(1, 1), None);
    }

    #[test]
    fn column_read_is_sequential_and_cheap() {
        let m = sample();
        let mut s = CountSink::default();
        let n = m.read_col(0, &mut s);
        assert_eq!(n, 2);
        assert_eq!(s.total, 1 + 2 * 2); // ptr + 2*(idx+val)
    }

    #[test]
    fn from_csr_matches_the_coo_route_bit_for_bit() {
        let coo = sample().to_coo();
        let via_coo = Csc::from_coo(&coo);
        let via_csr = Csc::from_csr(&Csr::from_coo(&coo));
        assert_eq!(via_csr.shape(), via_coo.shape());
        assert_eq!(via_csr.col_ptr, via_coo.col_ptr);
        assert_eq!(via_csr.row_idx, via_coo.row_idx);
        assert_eq!(
            via_csr.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            via_coo.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn coo_roundtrip() {
        let m = sample();
        let back = Csc::from_coo(&m.to_coo());
        assert_eq!(back.col_ptr, m.col_ptr);
        assert_eq!(back.row_idx, m.row_idx);
        assert_eq!(back.vals, m.vals);
    }
}
