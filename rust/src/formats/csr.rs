//! Compressed Row Storage (CRS/CSR) — the base format the paper augments.
//!
//! Random access to `B[i][j]` is: one access to the row pointer, then a
//! linear scan of the row's column indices until `j` is found or passed
//! (paper Table I: ≈ ½·N·D accesses on average).
//!
//! The paper deliberately uses linear (not binary) search: "CRS may not
//! benefit in practice from binary search due to poor caching behavior"
//! (§III footnote 2). We implement linear scan to match, and ship binary
//! search as an ablation (`locate_binary`) so the claim itself is testable
//! under the cache simulator.

use super::coo::Coo;
use super::error::FormatError;
use super::traits::{
    AccessSink, AddressSpace, FormatKind, Region, Site, SparseMatrix,
};

#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    pub row_ptr: Vec<u32>, // len rows+1
    pub col_idx: Vec<u32>, // len nnz, sorted within each row
    pub vals: Vec<f32>,    // len nnz
    r_ptr: Region,
    r_idx: Region,
    r_val: Region,
}

impl Csr {
    pub fn from_coo(c: &Coo) -> Csr {
        let mut space = AddressSpace::default();
        Self::from_coo_with_space(c, &mut space)
    }

    pub fn from_coo_with_space(c: &Coo, space: &mut AddressSpace) -> Csr {
        let (rows, cols) = c.shape();
        let nnz = c.nnz();
        let mut row_ptr = vec![0u32; rows + 1];
        let mut col_idx = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        for &(r, cidx, v) in &c.entries {
            row_ptr[r as usize + 1] += 1;
            col_idx.push(cidx);
            vals.push(v);
        }
        for i in 0..rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        Csr {
            rows,
            cols,
            row_ptr,
            col_idx,
            vals,
            r_ptr: space.alloc(rows + 1, 4),
            r_idx: space.alloc(nnz, 4),
            r_val: space.alloc(nnz, 4),
        }
    }

    /// Build directly from parts (used by generators to skip COO).
    pub fn from_parts(
        rows: usize,
        cols: usize,
        row_ptr: Vec<u32>,
        col_idx: Vec<u32>,
        vals: Vec<f32>,
    ) -> Csr {
        assert_eq!(row_ptr.len(), rows + 1);
        assert_eq!(col_idx.len(), vals.len());
        assert_eq!(*row_ptr.last().unwrap() as usize, col_idx.len());
        debug_assert!((0..rows).all(|i| {
            let (lo, hi) = (row_ptr[i] as usize, row_ptr[i + 1] as usize);
            col_idx[lo..hi].windows(2).all(|w| w[0] < w[1])
                && col_idx[lo..hi].iter().all(|&c| (c as usize) < cols)
        }));
        let mut space = AddressSpace::default();
        let nnz = col_idx.len();
        Csr {
            rows,
            cols,
            row_ptr,
            col_idx,
            vals,
            r_ptr: space.alloc(rows + 1, 4),
            r_idx: space.alloc(nnz, 4),
            r_val: space.alloc(nnz, 4),
        }
    }

    /// Row `i` as (cols, vals) slices — the zero-cost row-order access that
    /// CRS is built for (identical in CRS and InCRS, §V.B).
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let lo = self.row_ptr[i] as usize;
        let hi = self.row_ptr[i + 1] as usize;
        (&self.col_idx[lo..hi], &self.vals[lo..hi])
    }

    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        (self.row_ptr[i + 1] - self.row_ptr[i]) as usize
    }

    /// Paper cost model: 1 access for the row pointer, then one access per
    /// scanned column index, plus one for the value on a hit.
    pub fn locate(&self, i: usize, j: usize, sink: &mut impl AccessSink) -> Option<f32> {
        sink.touch(self.r_ptr.at(i), Site::Ptr);
        let lo = self.row_ptr[i] as usize;
        let hi = self.row_ptr[i + 1] as usize;
        let tj = j as u32;
        for k in lo..hi {
            sink.touch(self.r_idx.at(k), Site::Idx);
            let c = self.col_idx[k];
            if c == tj {
                sink.touch(self.r_val.at(k), Site::Val);
                return Some(self.vals[k]);
            }
            if c > tj {
                return None;
            }
        }
        None
    }

    /// Ablation: binary search over the row (footnote 2 of the paper).
    pub fn locate_binary(&self, i: usize, j: usize, sink: &mut impl AccessSink) -> Option<f32> {
        sink.touch(self.r_ptr.at(i), Site::Ptr);
        let mut lo = self.row_ptr[i] as usize;
        let mut hi = self.row_ptr[i + 1] as usize;
        let tj = j as u32;
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            sink.touch(self.r_idx.at(mid), Site::Idx);
            match self.col_idx[mid].cmp(&tj) {
                std::cmp::Ordering::Equal => {
                    sink.touch(self.r_val.at(mid), Site::Val);
                    return Some(self.vals[mid]);
                }
                std::cmp::Ordering::Less => lo = mid + 1,
                std::cmp::Ordering::Greater => hi = mid,
            }
        }
        None
    }

    pub fn ptr_region(&self) -> Region {
        self.r_ptr
    }
    pub fn idx_region(&self) -> Region {
        self.r_idx
    }
    pub fn val_region(&self) -> Region {
        self.r_val
    }

    /// Rows `lo..hi` as an owned CSR of shape `(hi-lo) × cols` — the shard
    /// executor's row-band slice (`engine::shard`). Column structure and
    /// value bits are copied verbatim, so a row-decomposable kernel
    /// produces bit-identical rows on the band.
    pub fn row_band(&self, lo: usize, hi: usize) -> Csr {
        assert!(
            lo <= hi && hi <= self.rows,
            "row band {lo}..{hi} outside 0..{}",
            self.rows
        );
        let p0 = self.row_ptr[lo];
        let p1 = self.row_ptr[hi] as usize;
        let row_ptr: Vec<u32> = self.row_ptr[lo..=hi].iter().map(|&p| p - p0).collect();
        Csr::from_parts(
            hi - lo,
            self.cols,
            row_ptr,
            self.col_idx[p0 as usize..p1].to_vec(),
            self.vals[p0 as usize..p1].to_vec(),
        )
    }

    /// Transpose (rows of the result = columns of self), used to build
    /// column streams for A×Aᵀ and the CCS comparison.
    pub fn transpose(&self) -> Csr {
        let mut cnt = vec![0u32; self.cols + 1];
        for &c in &self.col_idx {
            cnt[c as usize + 1] += 1;
        }
        for i in 0..self.cols {
            cnt[i + 1] += cnt[i];
        }
        let mut col_idx = vec![0u32; self.nnz()];
        let mut vals = vec![0.0f32; self.nnz()];
        let mut cursor = cnt.clone();
        for i in 0..self.rows {
            let (cs, vs) = self.row(i);
            for (&c, &v) in cs.iter().zip(vs) {
                let k = cursor[c as usize] as usize;
                col_idx[k] = i as u32;
                vals[k] = v;
                cursor[c as usize] += 1;
            }
        }
        Csr::from_parts(self.cols, self.rows, cnt, col_idx, vals)
    }

    /// Shape, structure, and raw value bits as one comparable vector —
    /// the sparse twin of `Dense::bit_pattern`: two CSRs are bitwise
    /// identical (same shape, row pointers, column indices, and f32 value
    /// bits) iff their patterns are equal. The bit-identity suites compare
    /// through this so the definition lives in one place.
    pub fn bit_pattern(&self) -> Vec<u32> {
        let mut bits = Vec::with_capacity(2 + self.row_ptr.len() + 2 * self.col_idx.len());
        bits.push(self.rows as u32);
        bits.push(self.cols as u32);
        bits.extend_from_slice(&self.row_ptr);
        bits.extend_from_slice(&self.col_idx);
        bits.extend(self.vals.iter().map(|v| v.to_bits()));
        bits
    }

    /// Check every structural invariant of the CSR arrays: pointer length
    /// and endpoints, monotonicity, strictly-increasing in-bounds column
    /// indices per row, and index/value array agreement. The fields are
    /// `pub` (tests and generators build them directly), so corruption
    /// *can* enter — the engine asserts this at prepare/execute
    /// boundaries via [`crate::formats::strict_check`] under the
    /// `strict-invariants` feature.
    pub fn validate_invariants(&self) -> Result<(), FormatError> {
        let err = |detail: String| FormatError::CorruptStructure {
            format: "crs",
            detail,
        };
        if self.row_ptr.len() != self.rows + 1 {
            return Err(err(format!(
                "row_ptr len {} != rows+1 ({})",
                self.row_ptr.len(),
                self.rows + 1
            )));
        }
        if self.row_ptr.first() != Some(&0) {
            return Err(err("row_ptr[0] != 0".into()));
        }
        for (i, w) in self.row_ptr.windows(2).enumerate() {
            if w[0] > w[1] {
                return Err(err(format!(
                    "row_ptr not monotone at row {i}: {} > {}",
                    w[0], w[1]
                )));
            }
        }
        if self.col_idx.len() != self.vals.len() {
            return Err(err(format!(
                "col_idx len {} != vals len {}",
                self.col_idx.len(),
                self.vals.len()
            )));
        }
        let last = self.row_ptr.last().copied().unwrap_or(0) as usize;
        if last != self.col_idx.len() {
            return Err(err(format!(
                "row_ptr end {last} != nnz {}",
                self.col_idx.len()
            )));
        }
        for i in 0..self.rows {
            let lo = self.row_ptr[i] as usize;
            let hi = self.row_ptr[i + 1] as usize;
            let cs = &self.col_idx[lo..hi];
            for w in cs.windows(2) {
                if w[0] >= w[1] {
                    return Err(err(format!(
                        "row {i}: col_idx not strictly increasing ({} then {})",
                        w[0], w[1]
                    )));
                }
            }
            // strictly increasing ⇒ only the last index can breach cols
            if let Some(&c) = cs.last() {
                if c as usize >= self.cols {
                    return Err(err(format!(
                        "row {i}: col {c} out of bounds (cols = {})",
                        self.cols
                    )));
                }
            }
        }
        Ok(())
    }

    /// Average non-zeros per row (the quantity Table II keys on).
    pub fn nnz_row_stats(&self) -> (usize, f64, usize) {
        let mut min = usize::MAX;
        let mut max = 0usize;
        for i in 0..self.rows {
            let n = self.row_nnz(i);
            min = min.min(n);
            max = max.max(n);
        }
        (
            if self.rows == 0 { 0 } else { min },
            self.nnz() as f64 / self.rows.max(1) as f64,
            max,
        )
    }
}

impl SparseMatrix for Csr {
    fn kind(&self) -> FormatKind {
        FormatKind::Csr
    }
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        self.col_idx.len()
    }
    fn storage_words(&self) -> usize {
        (self.rows + 1) + 2 * self.nnz()
    }
    fn locate_dyn(&self, i: usize, j: usize, mut sink: &mut dyn AccessSink) -> Option<f32> {
        self.locate(i, j, &mut sink)
    }
    fn to_coo(&self) -> Coo {
        let mut entries = Vec::with_capacity(self.nnz());
        for i in 0..self.rows {
            let (cs, vs) = self.row(i);
            for (&c, &v) in cs.iter().zip(vs) {
                entries.push((i as u32, c, v));
            }
        }
        Coo::new(self.rows, self.cols, entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::traits::CountSink;

    fn sample() -> Csr {
        // [1 0 2 0]
        // [0 0 0 3]
        // [4 5 0 0]
        Csr::from_coo(&Coo::new(
            3,
            4,
            vec![
                (0, 0, 1.0),
                (0, 2, 2.0),
                (1, 3, 3.0),
                (2, 0, 4.0),
                (2, 1, 5.0),
            ],
        ))
    }

    #[test]
    fn structure() {
        let m = sample();
        assert_eq!(m.row_ptr, vec![0, 2, 3, 5]);
        assert_eq!(m.col_idx, vec![0, 2, 3, 0, 1]);
        assert_eq!(m.row(2), (&[0u32, 1][..], &[4.0f32, 5.0][..]));
    }

    #[test]
    fn locate_all_cells() {
        let m = sample();
        let dense = Dense4x3();
        for i in 0..3 {
            for j in 0..4 {
                let want = dense[i][j];
                let got = m.get(i, j).unwrap_or(0.0);
                assert_eq!(got, want, "({i},{j})");
            }
        }
    }

    #[allow(non_snake_case)]
    fn Dense4x3() -> [[f32; 4]; 3] {
        [
            [1.0, 0.0, 2.0, 0.0],
            [0.0, 0.0, 0.0, 3.0],
            [4.0, 5.0, 0.0, 0.0],
        ]
    }

    #[test]
    fn locate_costs_match_scan_position() {
        let m = sample();
        // (2,1): ptr + scan idx{0,1} + val = 4 accesses
        let mut s = CountSink::default();
        m.locate(2, 1, &mut s);
        assert_eq!(s.total, 4);
        assert_eq!(s.site(Site::Ptr), 1);
        assert_eq!(s.site(Site::Idx), 2);
        assert_eq!(s.site(Site::Val), 1);
        // miss with early exit: (0,1) scans idx 0 (c=0 < 1) then idx 2
        let mut s = CountSink::default();
        assert_eq!(m.locate(0, 1, &mut s), None);
        assert_eq!(s.total, 3); // ptr + 2 idx
    }

    #[test]
    fn binary_locate_agrees_with_linear() {
        let m = sample();
        let mut sink = CountSink::default();
        for i in 0..3 {
            for j in 0..4 {
                assert_eq!(
                    m.locate(i, j, &mut sink),
                    m.locate_binary(i, j, &mut sink),
                    "({i},{j})"
                );
            }
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.shape(), (4, 3));
        assert_eq!(t.get(3, 1), Some(3.0));
        assert_eq!(t.get(1, 2), Some(5.0));
        let tt = t.transpose();
        assert_eq!(tt.row_ptr, m.row_ptr);
        assert_eq!(tt.col_idx, m.col_idx);
        assert_eq!(tt.vals, m.vals);
    }

    #[test]
    fn coo_roundtrip() {
        let m = sample();
        let back = Csr::from_coo(&m.to_coo());
        assert_eq!(back.row_ptr, m.row_ptr);
        assert_eq!(back.col_idx, m.col_idx);
        assert_eq!(back.vals, m.vals);
        assert_eq!(back.bit_pattern(), m.bit_pattern());
    }

    #[test]
    fn bit_pattern_discriminates_shape_structure_and_value_bits() {
        let m = sample();
        assert_eq!(m.bit_pattern(), m.clone().bit_pattern());
        let mut tweaked = m.clone();
        tweaked.vals[0] = -tweaked.vals[0];
        assert_ne!(m.bit_pattern(), tweaked.bit_pattern());
        // ±0.0 compare equal as floats but differ in bits — the pattern
        // is strictly bitwise
        let z_pos = Csr::from_parts(1, 1, vec![0, 1], vec![0], vec![0.0]);
        let z_neg = Csr::from_parts(1, 1, vec![0, 1], vec![0], vec![-0.0]);
        assert_eq!(z_pos.vals, z_neg.vals, "floats compare equal");
        assert_ne!(z_pos.bit_pattern(), z_neg.bit_pattern());
        // same entries, different declared shape
        let wide = Csr::from_coo(&Coo::new(
            3,
            5,
            m.to_coo().entries.clone(),
        ));
        assert_ne!(m.bit_pattern(), wide.bit_pattern());
    }

    #[test]
    fn stats() {
        let m = sample();
        let (min, avg, max) = m.nnz_row_stats();
        assert_eq!((min, max), (1, 2));
        assert!((avg - 5.0 / 3.0).abs() < 1e-9);
        assert_eq!(m.storage_words(), 4 + 10);
    }

    #[test]
    fn row_band_slices_structure_and_bits() {
        let m = sample();
        let band = m.row_band(1, 3);
        assert_eq!(band.shape(), (2, 4));
        assert_eq!(band.row_ptr, vec![0, 1, 3]);
        assert_eq!(band.row(0), (&[3u32][..], &[3.0f32][..]));
        assert_eq!(band.row(1), (&[0u32, 1][..], &[4.0f32, 5.0][..]));
        // full band is the identity; empty band is a 0-row matrix
        let all = m.row_band(0, 3);
        assert_eq!(all.row_ptr, m.row_ptr);
        assert_eq!(all.col_idx, m.col_idx);
        let none = m.row_band(2, 2);
        assert_eq!(none.shape(), (0, 4));
        assert_eq!(none.nnz(), 0);
    }

    #[test]
    fn validate_invariants_accepts_valid_matrices() {
        assert_eq!(sample().validate_invariants(), Ok(()));
        // degenerate shapes are valid too
        let empty = Csr::from_coo(&Coo::new(0, 0, vec![]));
        assert_eq!(empty.validate_invariants(), Ok(()));
    }

    #[test]
    fn validate_invariants_rejects_each_corruption_kind() {
        let m = sample();
        let expect_err = |bad: &Csr, needle: &str| {
            let e = bad
                .validate_invariants()
                .expect_err(&format!("corruption undetected: {needle}"));
            assert!(
                e.to_string().contains(needle),
                "{e} does not mention {needle:?}"
            );
        };
        let mut bad = m.clone();
        bad.row_ptr[1] = 9; // 9 > row_ptr[2] = 3
        expect_err(&bad, "not monotone");
        let mut bad = m.clone();
        bad.row_ptr[0] = 1;
        expect_err(&bad, "row_ptr[0]");
        let mut bad = m.clone();
        bad.row_ptr.pop();
        expect_err(&bad, "rows+1");
        let mut bad = m.clone();
        bad.row_ptr[3] = 4; // end != nnz
        expect_err(&bad, "nnz");
        let mut bad = m.clone();
        bad.col_idx.swap(0, 1); // row 0 becomes [2, 0]
        expect_err(&bad, "strictly increasing");
        let mut bad = m.clone();
        bad.col_idx[2] = 99; // row 1 single entry, out of 4 cols
        expect_err(&bad, "out of bounds");
        let mut bad = m.clone();
        bad.vals.pop();
        expect_err(&bad, "vals len");
    }

    #[test]
    fn empty_rows() {
        let m = Csr::from_coo(&Coo::new(3, 3, vec![(1, 1, 7.0)]));
        assert_eq!(m.row_nnz(0), 0);
        assert_eq!(m.row_nnz(2), 0);
        assert_eq!(m.get(0, 0), None);
        assert_eq!(m.get(1, 1), Some(7.0));
        let mut s = CountSink::default();
        assert_eq!(m.locate(0, 0, &mut s), None);
        assert_eq!(s.total, 1); // empty row: ptr only
    }
}
