//! List-of-Lists (LiL, paper §II.A.2): a head-pointer vector into per-row
//! singly linked lists of (col, val, next) nodes. Random access walks the
//! target row's list — Table I groups it with CRS/ELLPACK at ≈ ½·N·D.
//!
//! Nodes live in one arena but are *interleaved across rows* in insertion
//! order (as a real pointer-chasing structure would be after incremental
//! construction), so the cache simulator sees the poor locality that
//! distinguishes LiL from CRS even though the access *count* matches.

use super::coo::Coo;
use super::traits::{
    AccessSink, AddressSpace, FormatKind, Region, Site, SparseMatrix,
};

const NIL: u32 = u32::MAX;

#[derive(Clone, Debug)]
pub struct Lil {
    rows: usize,
    cols: usize,
    pub heads: Vec<u32>, // per row, NIL if empty
    /// Node arena: (col, val, next). Interleaved round-robin across rows.
    pub nodes: Vec<(u32, f32, u32)>,
    r_head: Region,
    r_node: Region,
}

impl Lil {
    pub fn from_coo(c: &Coo) -> Lil {
        let mut space = AddressSpace::default();
        Self::from_coo_with_space(c, &mut space)
    }

    pub fn from_coo_with_space(c: &Coo, space: &mut AddressSpace) -> Lil {
        let (rows, cols) = c.shape();
        // Gather per-row column lists first.
        let mut per_row: Vec<Vec<(u32, f32)>> = vec![Vec::new(); rows];
        for &(r, cc, v) in &c.entries {
            per_row[r as usize].push((cc, v));
        }
        // Allocate nodes round-robin across rows (k-th element of every row,
        // then (k+1)-th, ...) to model interleaved incremental insertion.
        let mut heads = vec![NIL; rows];
        let mut prev: Vec<u32> = vec![NIL; rows];
        let mut nodes: Vec<(u32, f32, u32)> = Vec::with_capacity(c.nnz());
        let max_len = per_row.iter().map(Vec::len).max().unwrap_or(0);
        for k in 0..max_len {
            for (r, row) in per_row.iter().enumerate() {
                if let Some(&(cc, v)) = row.get(k) {
                    let id = nodes.len() as u32;
                    nodes.push((cc, v, NIL));
                    if prev[r] == NIL {
                        heads[r] = id;
                    } else {
                        nodes[prev[r] as usize].2 = id;
                    }
                    prev[r] = id;
                }
            }
        }
        Lil {
            rows,
            cols,
            heads,
            nodes,
            r_head: space.alloc(rows, 4),
            // a node is (col u32, val f32, next u32) = 12 bytes
            r_node: space.alloc(c.nnz(), 12),
        }
    }

    /// 1 access for the head pointer + 1 per visited node (+1 value read on
    /// hit) — the node record (col + next) is charged as one touched word to
    /// match the paper's per-element counting for LiL.
    pub fn locate(&self, i: usize, j: usize, sink: &mut impl AccessSink) -> Option<f32> {
        sink.touch(self.r_head.at(i), Site::Ptr);
        let tj = j as u32;
        let mut cur = self.heads[i];
        while cur != NIL {
            sink.touch(self.r_node.at(cur as usize), Site::Idx);
            let (c, v, next) = self.nodes[cur as usize];
            if c == tj {
                sink.touch(self.r_node.at(cur as usize) + 4, Site::Val);
                return Some(v);
            }
            if c > tj {
                return None;
            }
            cur = next;
        }
        None
    }
}

impl SparseMatrix for Lil {
    fn kind(&self) -> FormatKind {
        FormatKind::Lil
    }
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        self.nodes.len()
    }
    fn storage_words(&self) -> usize {
        self.rows + 3 * self.nodes.len() // heads + (col,val,next) per node
    }
    fn locate_dyn(&self, i: usize, j: usize, mut sink: &mut dyn AccessSink) -> Option<f32> {
        self.locate(i, j, &mut sink)
    }
    fn to_coo(&self) -> Coo {
        let mut entries = Vec::with_capacity(self.nodes.len());
        for i in 0..self.rows {
            let mut cur = self.heads[i];
            while cur != NIL {
                let (c, v, next) = self.nodes[cur as usize];
                entries.push((i as u32, c, v));
                cur = next;
            }
        }
        Coo::new(self.rows, self.cols, entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::traits::CountSink;

    fn sample() -> Lil {
        Lil::from_coo(&Coo::new(
            3,
            4,
            vec![
                (0, 0, 1.0),
                (0, 2, 2.0),
                (1, 3, 3.0),
                (2, 0, 4.0),
                (2, 1, 5.0),
            ],
        ))
    }

    #[test]
    fn lists_preserve_row_order() {
        let m = sample();
        assert_eq!(m.to_coo().row(0), vec![(0, 1.0), (2, 2.0)]);
        assert_eq!(m.to_coo().row(2), vec![(0, 4.0), (1, 5.0)]);
    }

    #[test]
    fn nodes_are_interleaved() {
        let m = sample();
        // round-robin construction: first node of each row come first
        let first_cols: Vec<u32> = m.nodes.iter().take(3).map(|n| n.0).collect();
        assert_eq!(first_cols, vec![0, 3, 0]); // rows 0,1,2 first elements
    }

    #[test]
    fn locate_values_and_cost() {
        let m = sample();
        assert_eq!(m.get(2, 1), Some(5.0));
        assert_eq!(m.get(1, 0), None);
        let mut s = CountSink::default();
        m.locate(2, 1, &mut s); // head + node(0) + node(1) + val
        assert_eq!(s.total, 4);
    }

    #[test]
    fn empty_row() {
        let m = Lil::from_coo(&Coo::new(2, 2, vec![(1, 0, 9.0)]));
        assert_eq!(m.heads[0], NIL);
        let mut s = CountSink::default();
        assert_eq!(m.locate(0, 1, &mut s), None);
        assert_eq!(s.total, 1);
    }
}
