//! Any-to-any format conversion through canonical COO, plus a boxed
//! constructor used by the CLI and the Table I harness.

use super::coo::Coo;
use super::csc::Csc;
use super::csr::Csr;
use super::dense::Dense;
use super::ell::Ellpack;
use super::error::FormatError;
use super::incrs::{InCrs, InCrsParams};
use super::jad::Jad;
use super::lil::Lil;
use super::sll::Sll;
use super::traits::{FormatKind, SparseMatrix};

/// Build any format from canonical COO.
pub fn from_coo(kind: FormatKind, coo: &Coo) -> Result<Box<dyn SparseMatrix>, FormatError> {
    Ok(match kind {
        FormatKind::Dense => Box::new(Dense::from_coo(coo)),
        FormatKind::Coo => Box::new(coo.clone()),
        FormatKind::Csr => Box::new(Csr::from_coo(coo)),
        FormatKind::Csc => Box::new(Csc::from_coo(coo)),
        FormatKind::Sll => Box::new(Sll::from_coo(coo)),
        FormatKind::Ellpack => Box::new(Ellpack::from_coo(coo)),
        FormatKind::Lil => Box::new(Lil::from_coo(coo)),
        FormatKind::Jad => Box::new(Jad::from_coo(coo)),
        FormatKind::InCrs => Box::new(InCrs::from_csr(&Csr::from_coo(coo))?),
    })
}

/// InCRS with explicit geometry.
pub fn incrs_with_params(coo: &Coo, params: InCrsParams) -> Result<InCrs, FormatError> {
    InCrs::from_csr_params(&Csr::from_coo(coo), params)
}

/// Convert between any two formats (via COO).
pub fn convert(
    m: &dyn SparseMatrix,
    to: FormatKind,
) -> Result<Box<dyn SparseMatrix>, FormatError> {
    from_coo(to, &m.to_coo())
}

/// Parse a format name as used on the CLI (see [`FormatKind::parse`]).
pub fn parse_kind(s: &str) -> Result<FormatKind, FormatError> {
    FormatKind::parse(s)
}

/// All format kinds, in Table I order.
pub const ALL_KINDS: [FormatKind; 9] = FormatKind::ALL;

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Coo {
        Coo::new(
            4,
            6,
            vec![
                (0, 1, 1.0),
                (0, 5, 2.0),
                (1, 0, 3.0),
                (2, 2, 4.0),
                (2, 3, 5.0),
                (2, 4, 6.0),
                (3, 0, 7.0),
            ],
        )
    }

    #[test]
    fn every_format_roundtrips_through_coo() {
        let coo = sample();
        for kind in ALL_KINDS {
            let m = from_coo(kind, &coo).unwrap();
            assert_eq!(m.kind(), kind);
            let back = m.to_coo();
            assert_eq!(back.entries, coo.entries, "{:?}", kind);
            assert_eq!(m.nnz(), coo.nnz(), "{:?}", kind);
            assert_eq!(m.shape(), coo.shape(), "{:?}", kind);
        }
    }

    #[test]
    fn every_format_locates_every_cell_identically() {
        let coo = sample();
        let dense = coo.to_dense();
        for kind in ALL_KINDS {
            let m = from_coo(kind, &coo).unwrap();
            for i in 0..4 {
                for j in 0..6 {
                    let want = dense[i * 6 + j];
                    let got = m.get(i, j).unwrap_or(0.0);
                    assert_eq!(got, want, "{:?} ({i},{j})", kind);
                }
            }
        }
    }

    #[test]
    fn convert_between_formats() {
        let coo = sample();
        let csr = from_coo(FormatKind::Csr, &coo).unwrap();
        let jad = convert(csr.as_ref(), FormatKind::Jad).unwrap();
        assert_eq!(jad.kind(), FormatKind::Jad);
        assert_eq!(jad.to_coo().entries, coo.entries);
    }

    #[test]
    fn parse_kind_aliases() {
        assert_eq!(parse_kind("CRS").unwrap(), FormatKind::Csr);
        assert_eq!(parse_kind("csr").unwrap(), FormatKind::Csr);
        assert_eq!(parse_kind("incrs").unwrap(), FormatKind::InCrs);
        assert_eq!(
            parse_kind("nope").unwrap_err(),
            super::FormatError::UnknownFormat("nope".into())
        );
    }

    #[test]
    fn parse_kind_inverts_name_exhaustively() {
        for kind in ALL_KINDS {
            assert_eq!(parse_kind(kind.name()).unwrap(), kind, "{kind:?}");
        }
    }
}
