//! Dense row-major storage — the baseline in which one arbitrary element
//! costs exactly one memory access (paper §II.B).

use super::coo::Coo;
use super::traits::{
    AccessSink, AddressSpace, FormatKind, Region, Site, SparseMatrix,
};

#[derive(Clone, Debug)]
pub struct Dense {
    rows: usize,
    cols: usize,
    pub data: Vec<f32>,
    region: Region,
}

impl Dense {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Dense {
        let mut space = AddressSpace::default();
        Self::with_space(rows, cols, data, &mut space)
    }

    pub fn with_space(
        rows: usize,
        cols: usize,
        data: Vec<f32>,
        space: &mut AddressSpace,
    ) -> Dense {
        assert_eq!(data.len(), rows * cols);
        Dense {
            rows,
            cols,
            data,
            region: space.alloc(rows * cols, 4),
        }
    }

    pub fn zeros(rows: usize, cols: usize) -> Dense {
        Dense::new(rows, cols, vec![0.0; rows * cols])
    }

    pub fn from_coo(c: &Coo) -> Dense {
        let (rows, cols) = c.shape();
        Dense::new(rows, cols, c.to_dense())
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn locate(&self, i: usize, j: usize, sink: &mut impl AccessSink) -> Option<f32> {
        let k = i * self.cols + j;
        sink.touch(self.region.at(k), Site::Dense);
        Some(self.data[k])
    }

    /// The raw bit pattern of every value — the exact-compare side of the
    /// shard layer's bit-reproducibility checks (`a.bit_pattern() ==
    /// b.bit_pattern()` ⇔ bitwise-identical results; plain `==` on f32
    /// would conflate 0.0/-0.0 and fail on NaN).
    pub fn bit_pattern(&self) -> Vec<u32> {
        self.data.iter().map(|v| v.to_bits()).collect()
    }

    /// Max |a - b| against another dense matrix (test/verification helper).
    pub fn max_abs_diff(&self, other: &Dense) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative Frobenius error ||a-b|| / max(||b||, eps).
    pub fn rel_fro_err(&self, want: &Dense) -> f64 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&want.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        (num.sqrt()) / den.sqrt().max(1e-30)
    }
}

impl SparseMatrix for Dense {
    fn kind(&self) -> FormatKind {
        FormatKind::Dense
    }
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }
    fn storage_words(&self) -> usize {
        self.rows * self.cols
    }
    fn locate_dyn(&self, i: usize, j: usize, mut sink: &mut dyn AccessSink) -> Option<f32> {
        self.locate(i, j, &mut sink)
    }
    fn to_coo(&self) -> Coo {
        Coo::from_dense(self.rows, self.cols, &self.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::traits::CountSink;

    #[test]
    fn single_access_per_locate() {
        let d = Dense::new(2, 3, vec![1.0, 0.0, 2.0, 3.0, 4.0, 0.0]);
        let mut s = CountSink::default();
        assert_eq!(d.locate(1, 1, &mut s), Some(4.0));
        assert_eq!(s.total, 1);
        // zeros are still "found" in dense storage
        let mut s2 = CountSink::default();
        assert_eq!(d.locate(0, 1, &mut s2), Some(0.0));
        assert_eq!(s2.total, 1);
    }

    #[test]
    fn coo_roundtrip() {
        let d = Dense::new(2, 2, vec![0.0, 1.5, -2.0, 0.0]);
        let back = Dense::from_coo(&d.to_coo());
        assert_eq!(d.data, back.data);
        assert_eq!(d.nnz(), 2);
    }

    #[test]
    fn diff_helpers() {
        let a = Dense::new(1, 2, vec![1.0, 2.0]);
        let b = Dense::new(1, 2, vec![1.0, 2.5]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-6);
        assert!(a.rel_fro_err(&a) < 1e-12);
    }
}
