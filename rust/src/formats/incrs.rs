//! Indexed Compressed Row Storage (InCRS) — the paper's §III contribution.
//!
//! InCRS augments CRS with one *counter-vector* word per `S`-column section
//! of each row. With the paper's parameters (S = 256, b = 32) the 64-bit
//! word packs:
//!
//! ```text
//!   bits  0..16   number of non-zeros in this row BEFORE this section
//!   bits 16..64   8 blocks × 6 bits: non-zeros INSIDE each b-column block
//! ```
//!
//! Locating `B[i][j]` becomes: 1 access to the row pointer, 1 access to the
//! counter word, then a scan limited to the non-zeros of one b-column block
//! — ≈ b/2 + 1 accesses instead of CRS's ≈ ½·N·D (paper §III.A).
//!
//! Construction checks the paper's packing assumptions (≤ 65 535 non-zeros
//! before a section, block population fits its bit field) and fails loudly
//! instead of silently corrupting counters.

use super::coo::Coo;
use super::csr::Csr;
use super::error::FormatError;
use super::traits::{
    AccessSink, AddressSpace, FormatKind, Region, Site, SparseMatrix,
};

/// Paper defaults: 256-column sections of 32-column blocks.
pub const SECTION: usize = 256;
pub const BLOCK: usize = 32;

/// Tunable InCRS geometry (paper §III.B: "these parameters can be adjusted
/// for a given dataset").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InCrsParams {
    /// Section width in columns (S).
    pub section: usize,
    /// Block width in columns (b); must divide `section`.
    pub block: usize,
}

impl Default for InCrsParams {
    fn default() -> Self {
        InCrsParams {
            section: SECTION,
            block: BLOCK,
        }
    }
}

impl InCrsParams {
    pub fn blocks_per_section(&self) -> usize {
        self.section / self.block
    }

    /// Bits needed to count up to `block` non-zeros in one block.
    pub fn bits_per_block(&self) -> u32 {
        usize::BITS - self.block.leading_zeros() // ceil(log2(block+1))
    }

    /// Validate that a counter-vector fits one 64-bit word (paper §III.B).
    pub fn validate(&self) -> Result<(), FormatError> {
        let bad = |reason: String| FormatError::BadParams {
            section: self.section,
            block: self.block,
            reason,
        };
        if self.block == 0 || self.section == 0 {
            return Err(bad("section/block must be positive".into()));
        }
        if self.section % self.block != 0 {
            return Err(bad(format!(
                "block {} must divide section {}",
                self.block, self.section
            )));
        }
        let bits = 16 + self.blocks_per_section() as u32 * self.bits_per_block();
        if bits > 64 {
            return Err(bad(format!(
                "counter-vector needs {bits} bits > 64 (S={}, b={})",
                self.section, self.block
            )));
        }
        Ok(())
    }
}

#[derive(Clone, Debug)]
pub struct InCrs {
    rows: usize,
    cols: usize,
    pub params: InCrsParams,
    // --- the underlying CRS arrays ---
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
    // --- the paper's addition ---
    /// rows × sections_per_row counter words, row-major.
    pub counters: Vec<u64>,
    sections_per_row: usize,
    r_ptr: Region,
    r_idx: Region,
    r_val: Region,
    r_cnt: Region,
}

impl InCrs {
    pub fn from_csr(m: &Csr) -> Result<InCrs, FormatError> {
        Self::from_csr_params(m, InCrsParams::default())
    }

    pub fn from_csr_params(m: &Csr, params: InCrsParams) -> Result<InCrs, FormatError> {
        let mut space = AddressSpace::default();
        Self::from_csr_with_space(m, params, &mut space)
    }

    pub fn from_csr_with_space(
        m: &Csr,
        params: InCrsParams,
        space: &mut AddressSpace,
    ) -> Result<InCrs, FormatError> {
        params.validate()?;
        let (rows, cols) = m.shape();
        let spr = (cols + params.section - 1) / params.section;
        let bps = params.blocks_per_section();
        let bits = params.bits_per_block();
        let mut counters = vec![0u64; rows * spr];

        for i in 0..rows {
            let (cs, _) = m.row(i);
            // count nonzeros per (section, block)
            let mut before_section = 0usize; // running prefix
            let mut k = 0usize;
            for s in 0..spr {
                if before_section > u16::MAX as usize {
                    return Err(FormatError::CounterOverflow {
                        row: i,
                        detail: format!(
                            "row {i}: {before_section} non-zeros before section {s} \
                             exceeds the 16-bit prefix (paper assumes <= 65535/row)"
                        ),
                    });
                }
                let mut word = before_section as u64; // bits 0..16
                let sec_end = ((s + 1) * params.section).min(cols) as u32;
                let mut in_section = 0usize;
                for blk in 0..bps {
                    let blk_end =
                        ((s * params.section + (blk + 1) * params.block) as u32).min(sec_end);
                    let mut cnt = 0u64;
                    while k < cs.len() && cs[k] < blk_end {
                        cnt += 1;
                        k += 1;
                    }
                    if cnt >= (1 << bits) {
                        return Err(FormatError::CounterOverflow {
                            row: i,
                            detail: format!(
                                "row {i} section {s} block {blk}: {cnt} non-zeros \
                                 overflow the {bits}-bit field"
                            ),
                        });
                    }
                    word |= cnt << (16 + blk as u32 * bits);
                    in_section += cnt as usize;
                }
                counters[i * spr + s] = word;
                before_section += in_section;
            }
            debug_assert_eq!(k, cs.len(), "row {i}: unconsumed non-zeros");
        }

        let nnz = m.nnz();
        Ok(InCrs {
            rows,
            cols,
            params,
            row_ptr: m.row_ptr.clone(),
            col_idx: m.col_idx.clone(),
            vals: m.vals.clone(),
            counters,
            sections_per_row: spr,
            r_ptr: space.alloc(rows + 1, 4),
            r_idx: space.alloc(nnz, 4),
            r_val: space.alloc(nnz, 4),
            r_cnt: space.alloc(rows * spr, 8),
        })
    }

    #[inline]
    fn decode(&self, word: u64, upto_block: usize) -> (usize, usize) {
        // returns (nnz before target block within row, nnz inside target block)
        let bits = self.params.bits_per_block();
        let mask = (1u64 << bits) - 1;
        let mut before = (word & 0xFFFF) as usize; // section prefix
        for blk in 0..upto_block {
            before += ((word >> (16 + blk as u32 * bits)) & mask) as usize;
        }
        let inside = ((word >> (16 + upto_block as u32 * bits)) & mask) as usize;
        (before, inside)
    }

    /// Structural invariants of the InCRS arrays: the underlying CSR
    /// checks (pointer endpoints/monotonicity, strictly-sorted in-bounds
    /// indices, nnz agreement) **plus** the paper's addition — every
    /// counter word's 16-bit section prefix and per-block bit fields must
    /// agree with the column indices they summarize (a stale counter
    /// silently mis-routes every `locate` into the wrong run of
    /// non-zeros).
    pub fn validate_invariants(&self) -> Result<(), FormatError> {
        let err = |detail: String| FormatError::CorruptStructure {
            format: "incrs",
            detail,
        };
        self.params.validate()?;
        // CSR-core checks, inline (the arrays are this struct's own)
        if self.row_ptr.len() != self.rows + 1 {
            return Err(err(format!(
                "row_ptr len {} != rows+1 ({})",
                self.row_ptr.len(),
                self.rows + 1
            )));
        }
        if self.row_ptr.first() != Some(&0) {
            return Err(err("row_ptr[0] != 0".into()));
        }
        for (i, w) in self.row_ptr.windows(2).enumerate() {
            if w[0] > w[1] {
                return Err(err(format!(
                    "row_ptr not monotone at row {i}: {} > {}",
                    w[0], w[1]
                )));
            }
        }
        if self.col_idx.len() != self.vals.len() {
            return Err(err(format!(
                "col_idx len {} != vals len {}",
                self.col_idx.len(),
                self.vals.len()
            )));
        }
        let last = self.row_ptr.last().copied().unwrap_or(0) as usize;
        if last != self.col_idx.len() {
            return Err(err(format!(
                "row_ptr end {last} != nnz {}",
                self.col_idx.len()
            )));
        }
        let spr = self.sections_per_row;
        let expected_spr = (self.cols + self.params.section - 1) / self.params.section;
        if spr != expected_spr {
            return Err(err(format!(
                "sections_per_row {spr} != ceil(cols/section) = {expected_spr}"
            )));
        }
        if self.counters.len() != self.rows * spr {
            return Err(err(format!(
                "counters len {} != rows×sections ({})",
                self.counters.len(),
                self.rows * spr
            )));
        }
        let bps = self.params.blocks_per_section();
        let bits = self.params.bits_per_block();
        let mask = (1u64 << bits) - 1;
        for i in 0..self.rows {
            let lo = self.row_ptr[i] as usize;
            let hi = self.row_ptr[i + 1] as usize;
            let cs = &self.col_idx[lo..hi];
            for w in cs.windows(2) {
                if w[0] >= w[1] {
                    return Err(err(format!(
                        "row {i}: col_idx not strictly increasing ({} then {})",
                        w[0], w[1]
                    )));
                }
            }
            if let Some(&c) = cs.last() {
                if c as usize >= self.cols {
                    return Err(err(format!(
                        "row {i}: col {c} out of bounds (cols = {})",
                        self.cols
                    )));
                }
            }
            // replay the construction walk and compare against the words
            let mut k = 0usize;
            for s in 0..spr {
                let word = self.counters[i * spr + s];
                let prefix = (word & 0xFFFF) as usize;
                if prefix != k {
                    return Err(err(format!(
                        "row {i} section {s}: prefix {prefix} != {k} non-zeros before it"
                    )));
                }
                let sec_end = (((s + 1) * self.params.section).min(self.cols)) as u32;
                for blk in 0..bps {
                    let blk_end = ((s * self.params.section + (blk + 1) * self.params.block)
                        as u32)
                        .min(sec_end);
                    let mut cnt = 0u64;
                    while k < cs.len() && cs[k] < blk_end {
                        cnt += 1;
                        k += 1;
                    }
                    let stored = (word >> (16 + blk as u32 * bits)) & mask;
                    if stored != cnt {
                        return Err(err(format!(
                            "row {i} section {s} block {blk}: counter says {stored} \
                             non-zeros, indices say {cnt}"
                        )));
                    }
                }
            }
            if k != cs.len() {
                return Err(err(format!(
                    "row {i}: {} non-zeros beyond the last section",
                    cs.len() - k
                )));
            }
        }
        Ok(())
    }

    /// The paper's locate: row pointer (1) + counter word (1) + scan of the
    /// target block's non-zeros (+ value on hit).
    pub fn locate(&self, i: usize, j: usize, sink: &mut impl AccessSink) -> Option<f32> {
        sink.touch(self.r_ptr.at(i), Site::Ptr);
        let start = self.row_ptr[i] as usize;

        let sec = j / self.params.section;
        let blk = (j % self.params.section) / self.params.block;
        let cidx = i * self.sections_per_row + sec;
        sink.touch(self.r_cnt.at(cidx), Site::Counter);
        let (before, inside) = self.decode(self.counters[cidx], blk);

        let tj = j as u32;
        let lo = start + before;
        for k in lo..lo + inside {
            sink.touch(self.r_idx.at(k), Site::Idx);
            let c = self.col_idx[k];
            if c == tj {
                sink.touch(self.r_val.at(k), Site::Val);
                return Some(self.vals[k]);
            }
            if c > tj {
                return None;
            }
        }
        None
    }

    /// Words of storage added over plain CRS (Table II "storage ratio"
    /// denominator): one word per counter-vector.
    pub fn counter_words(&self) -> usize {
        self.counters.len()
    }

    /// Paper §III.C: estimated MA reduction factor  N·D / (b + 2).
    pub fn estimated_ma_ratio(&self) -> f64 {
        let nd = self.nnz() as f64 / self.rows.max(1) as f64; // avg nnz/row = N·D
        nd / (self.params.block as f64 + 2.0)
    }

    /// Paper §III.C: estimated storage ratio  2·D·S / (2·D·S + 1).
    pub fn estimated_storage_ratio(&self) -> f64 {
        let d = self.density();
        let s = self.params.section as f64;
        2.0 * d * s / (2.0 * d * s + 1.0)
    }

    pub fn ptr_region(&self) -> Region {
        self.r_ptr
    }
    pub fn idx_region(&self) -> Region {
        self.r_idx
    }
    pub fn val_region(&self) -> Region {
        self.r_val
    }
    pub fn counter_region(&self) -> Region {
        self.r_cnt
    }

    /// Density D = nnz / size (convenience mirroring SparseMatrix::density).
    fn density(&self) -> f64 {
        self.col_idx.len() as f64 / (self.rows as f64 * self.cols as f64)
    }
}

impl SparseMatrix for InCrs {
    fn kind(&self) -> FormatKind {
        FormatKind::InCrs
    }
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        self.col_idx.len()
    }
    fn storage_words(&self) -> usize {
        (self.rows + 1) + 2 * self.nnz() + self.counters.len()
    }
    fn locate_dyn(&self, i: usize, j: usize, mut sink: &mut dyn AccessSink) -> Option<f32> {
        self.locate(i, j, &mut sink)
    }
    fn to_coo(&self) -> Coo {
        let mut entries = Vec::with_capacity(self.nnz());
        for i in 0..self.rows {
            let lo = self.row_ptr[i] as usize;
            let hi = self.row_ptr[i + 1] as usize;
            for k in lo..hi {
                entries.push((i as u32, self.col_idx[k], self.vals[k]));
            }
        }
        Coo::new(self.rows, self.cols, entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::traits::CountSink;

    /// Small geometry so tests exercise multi-section rows: S=8, b=2
    /// (fig 1 of the paper uses exactly S=8, b=2).
    fn small_params() -> InCrsParams {
        InCrsParams {
            section: 8,
            block: 2,
        }
    }

    fn fig1_like() -> InCrs {
        // One row of 24 columns; non-zeros at cols 1,3,4,8,9,10,11,13,20.
        let entries: Vec<(u32, u32, f32)> = [1u32, 3, 4, 8, 9, 10, 11, 13, 20]
            .iter()
            .map(|&c| (0u32, c, c as f32 + 0.5))
            .collect();
        let csr = Csr::from_coo(&Coo::new(1, 24, entries));
        InCrs::from_csr_params(&csr, small_params()).unwrap()
    }

    #[test]
    fn validate_invariants_accepts_valid_and_rejects_corruption() {
        let m = fig1_like();
        assert_eq!(m.validate_invariants(), Ok(()));
        // a stale counter word (the InCRS-specific hazard): bump one
        // block field so it disagrees with the indices it summarizes
        let mut bad = m.clone();
        bad.counters[0] += 1 << 16;
        assert!(bad
            .validate_invariants()
            .is_err_and(|e| e.to_string().contains("counter says")));
        // a wrong section prefix
        let mut bad = m.clone();
        bad.counters[1] ^= 1; // prefix bits of section 1
        assert!(bad
            .validate_invariants()
            .is_err_and(|e| e.to_string().contains("prefix")));
        // CSR-core corruption is caught too
        let mut bad = m.clone();
        bad.row_ptr[1] = 4;
        assert!(bad.validate_invariants().is_err());
        let mut bad = m.clone();
        bad.col_idx[0] = 3; // duplicate of the next index
        assert!(bad.validate_invariants().is_err());
        // counters array truncated
        let mut bad = m.clone();
        bad.counters.pop();
        assert!(bad
            .validate_invariants()
            .is_err_and(|e| e.to_string().contains("counters len")));
    }

    #[test]
    fn params_validation() {
        assert!(InCrsParams::default().validate().is_ok());
        assert!(InCrsParams { section: 256, block: 3 }.validate().is_err());
        // 64 blocks x 1 bit... block=1 -> bits=1, 256 blocks -> 272 bits: too big
        assert!(InCrsParams { section: 256, block: 1 }.validate().is_err());
        assert!(InCrsParams { section: 0, block: 0 }.validate().is_err());
    }

    #[test]
    fn default_params_pack_exactly_64_bits() {
        let p = InCrsParams::default();
        assert_eq!(p.blocks_per_section(), 8);
        assert_eq!(p.bits_per_block(), 6);
        assert_eq!(16 + 8 * 6, 64);
    }

    #[test]
    fn counter_words_match_fig1() {
        let m = fig1_like();
        // sections: cols 0-7 (3 nz), 8-15 (5 nz), 16-23 (1 nz)
        assert_eq!(m.counters.len(), 3);
        // section 1 (cols 8..16): prefix = 3; blocks (8,9)=2,(10,11)=2,(12,13)=1,(14,15)=0
        let w = m.counters[1];
        assert_eq!(w & 0xFFFF, 3);
        let bits = m.params.bits_per_block();
        let cnt =
            |blk: u32| -> u64 { (w >> (16 + blk * bits)) & ((1 << bits) - 1) };
        assert_eq!((cnt(0), cnt(1), cnt(2), cnt(3)), (2, 2, 1, 0));
    }

    #[test]
    fn locate_every_cell_matches_csr() {
        let m = fig1_like();
        let csr = Csr::from_coo(&m.to_coo());
        for j in 0..24 {
            assert_eq!(m.get(0, j), csr.get(0, j), "col {j}");
        }
    }

    #[test]
    fn locate_cost_is_block_bounded() {
        let m = fig1_like();
        // col 13 lives in section 1 block 2 with 1 non-zero:
        // ptr + counter + 1 idx + 1 val = 4
        let mut s = CountSink::default();
        assert_eq!(m.locate(0, 13, &mut s), Some(13.5));
        assert_eq!(s.total, 4);
        assert_eq!(s.site(Site::Counter), 1);
        // miss in an empty block costs ptr + counter only
        let mut s = CountSink::default();
        assert_eq!(m.locate(0, 15, &mut s), None);
        assert_eq!(s.total, 2);
    }

    #[test]
    fn storage_accounting() {
        let m = fig1_like();
        // CRS words: (1+1) ptr + 2*9 = 20; + 3 counters
        assert_eq!(m.storage_words(), 23);
        assert_eq!(m.counter_words(), 3);
    }

    #[test]
    fn default_geometry_roundtrip() {
        // matrix wider than one section with the real S=256/b=32 params
        let mut entries = Vec::new();
        for i in 0..4u32 {
            for &c in &[0u32, 31, 32, 255, 256, 300, 511, 600] {
                entries.push((i, c + i, (i * 1000 + c) as f32));
            }
        }
        let csr = Csr::from_coo(&Coo::new(4, 700, entries));
        let incrs = InCrs::from_csr(&csr).unwrap();
        for i in 0..4 {
            for j in 0..700 {
                assert_eq!(incrs.get(i, j), csr.get(i, j), "({i},{j})");
            }
        }
    }

    #[test]
    fn overflow_detection_block() {
        // 70 nonzeros in one 32-wide block is impossible; but with b=64,
        // bits=7, 64 nz fits; use b=2 with 3 nz via duplicate... instead:
        // block=2 allows cnt<=3 (2 bits); can't overflow with distinct cols.
        // The reachable overflow is the 16-bit section prefix:
        let cols = 70_000usize;
        let entries: Vec<(u32, u32, f32)> =
            (0..cols as u32).map(|c| (0, c, 1.0)).collect();
        let csr = Csr::from_coo(&Coo::new(1, cols, entries));
        let err = InCrs::from_csr(&csr).unwrap_err();
        assert!(matches!(err, FormatError::CounterOverflow { row: 0, .. }), "{err}");
        assert!(err.to_string().contains("16-bit prefix"), "{err}");
    }

    #[test]
    fn estimates_match_paper_formulas() {
        let m = fig1_like();
        let nd = 9.0; // avg nnz/row
        assert!((m.estimated_ma_ratio() - nd / 4.0).abs() < 1e-9);
        let d = 9.0 / 24.0;
        let s = 8.0;
        assert!(
            (m.estimated_storage_ratio() - 2.0 * d * s / (2.0 * d * s + 1.0)).abs() < 1e-9
        );
    }

    #[test]
    fn ragged_last_section() {
        // cols=20 with S=8: last section is 4 columns wide
        let entries = vec![(0u32, 17u32, 1.0f32), (0, 19, 2.0)];
        let csr = Csr::from_coo(&Coo::new(1, 20, entries));
        let m = InCrs::from_csr_params(&csr, small_params()).unwrap();
        assert_eq!(m.get(0, 17), Some(1.0));
        assert_eq!(m.get(0, 19), Some(2.0));
        assert_eq!(m.get(0, 18), None);
    }
}
