//! Core abstractions: memory-access accounting and the sparse-matrix trait.
//!
//! The paper's Table I/II/Fig 3 all measure *memory accesses to locate
//! elements*. We make that a first-class concept: every format lays its
//! arrays out in a virtual address space, and every `locate(i, j)` reports
//! each word it touches to an [`AccessSink`]. A counting sink reproduces the
//! paper's access-count analytics; the cache-simulator sink replays the same
//! address stream through the gem5-parameter hierarchy (Fig 3).

use super::coo::Coo;

/// Which data structure a memory access touched. Doubles as the "PC" proxy
/// for the stride prefetcher (distinct access sites train distinct streams,
/// like gem5's PC-indexed stride table).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Site {
    /// Row/column pointer vector (CRS/CCS/LiL heads, ELLPACK row base).
    Ptr = 0,
    /// Column- (or row-) index vector entries.
    Idx = 1,
    /// Non-zero value entries.
    Val = 2,
    /// InCRS counter-vector words.
    Counter = 3,
    /// JAD's jagged-diagonal pointer vector.
    JadPtr = 4,
    /// COO/SLL entry records.
    Entry = 5,
    /// Permutation / auxiliary metadata.
    Aux = 6,
    /// Dense array elements.
    Dense = 7,
}

pub const NUM_SITES: usize = 8;

/// Receives every simulated memory access. Monomorphized into the format
/// hot loops — implementations must keep `touch` tiny and `#[inline]`.
pub trait AccessSink {
    fn touch(&mut self, addr: u64, site: Site);
}

/// Blanket impl so generic code can also run over `&mut dyn AccessSink`.
impl AccessSink for &mut (dyn AccessSink + '_) {
    #[inline]
    fn touch(&mut self, addr: u64, site: Site) {
        (**self).touch(addr, site)
    }
}

/// Sink that discards accesses (pure value lookups).
#[derive(Default, Debug)]
pub struct NullSink;

impl AccessSink for NullSink {
    #[inline]
    fn touch(&mut self, _addr: u64, _site: Site) {}
}

/// Counting sink: total + per-site access counts (Table I/II analytics).
#[derive(Default, Debug, Clone)]
pub struct CountSink {
    pub total: u64,
    pub by_site: [u64; NUM_SITES],
}

impl CountSink {
    pub fn site(&self, s: Site) -> u64 {
        self.by_site[s as usize]
    }
}

impl AccessSink for CountSink {
    #[inline]
    fn touch(&mut self, _addr: u64, site: Site) {
        self.total += 1;
        self.by_site[site as usize] += 1;
    }
}

/// A contiguous array in the simulated address space.
#[derive(Clone, Copy, Debug)]
pub struct Region {
    pub base: u64,
    pub elem_bytes: u64,
}

impl Region {
    #[inline]
    pub fn at(&self, i: usize) -> u64 {
        self.base + i as u64 * self.elem_bytes
    }
}

/// Bump allocator for simulated array placement. Each array starts on a
/// fresh 4 KiB page (realistic malloc behavior, and it keeps arrays from
/// sharing cache lines, which would flatter hit rates).
#[derive(Debug)]
pub struct AddressSpace {
    next: u64,
}

impl Default for AddressSpace {
    fn default() -> Self {
        // Leave page 0 unused so address 0 never appears (useful as a
        // sentinel in the prefetcher).
        AddressSpace { next: 4096 }
    }
}

impl AddressSpace {
    pub fn alloc(&mut self, elems: usize, elem_bytes: u64) -> Region {
        let base = self.next;
        let len = elems as u64 * elem_bytes;
        self.next = (base + len + 4095) & !4095;
        Region { base, elem_bytes }
    }

    pub fn bytes_used(&self) -> u64 {
        self.next
    }
}

/// Identifies the concrete storage format (Table I rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FormatKind {
    Dense,
    Csr,
    Csc,
    Coo,
    Sll,
    Ellpack,
    Lil,
    Jad,
    InCrs,
}

impl FormatKind {
    /// Every format, in the paper's Table I order (also re-exported as
    /// `formats::ALL_KINDS`).
    pub const ALL: [FormatKind; 9] = [
        FormatKind::Dense,
        FormatKind::Ellpack,
        FormatKind::Lil,
        FormatKind::Csr,
        FormatKind::Jad,
        FormatKind::Coo,
        FormatKind::Sll,
        FormatKind::Csc,
        FormatKind::InCrs,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FormatKind::Dense => "dense",
            FormatKind::Csr => "CRS",
            FormatKind::Csc => "CCS",
            FormatKind::Coo => "COO",
            FormatKind::Sll => "SLL",
            FormatKind::Ellpack => "ELLPACK",
            FormatKind::Lil => "LiL",
            FormatKind::Jad => "JAD",
            FormatKind::InCrs => "InCRS",
        }
    }

    /// Parse a format name (case-insensitive; accepts both the paper
    /// spellings CRS/CCS and the common csr/csc/ell aliases). The inverse
    /// of [`FormatKind::name`]: `parse(name(k)) == k` for every variant,
    /// locked by an exhaustive test.
    pub fn parse(s: &str) -> Result<FormatKind, super::error::FormatError> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dense" => FormatKind::Dense,
            "coo" => FormatKind::Coo,
            "crs" | "csr" => FormatKind::Csr,
            "ccs" | "csc" => FormatKind::Csc,
            "sll" => FormatKind::Sll,
            "ellpack" | "ell" => FormatKind::Ellpack,
            "lil" => FormatKind::Lil,
            "jad" => FormatKind::Jad,
            "incrs" => FormatKind::InCrs,
            other => {
                return Err(super::error::FormatError::UnknownFormat(other.into()))
            }
        })
    }
}

/// Object-safe surface shared by all formats: metadata, storage accounting,
/// polymorphic random access, and conversion back to canonical COO.
pub trait SparseMatrix {
    fn kind(&self) -> FormatKind;
    fn shape(&self) -> (usize, usize);
    fn nnz(&self) -> usize;
    /// Storage in machine words (the paper counts one word per stored value,
    /// index, pointer, or counter-vector — Table II "storage ratio").
    fn storage_words(&self) -> usize;
    /// Random access with memory-access accounting (dyn-sink variant; the
    /// hot paths use the concrete formats' generic `locate`).
    fn locate_dyn(&self, i: usize, j: usize, sink: &mut dyn AccessSink) -> Option<f32>;
    fn to_coo(&self) -> Coo;

    fn rows(&self) -> usize {
        self.shape().0
    }
    fn cols(&self) -> usize {
        self.shape().1
    }
    /// Density D = nnz / (rows*cols).
    fn density(&self) -> f64 {
        let (m, n) = self.shape();
        self.nnz() as f64 / (m as f64 * n as f64)
    }
    /// Plain value lookup without accounting.
    fn get(&self, i: usize, j: usize) -> Option<f32> {
        let mut sink = NullSink;
        self.locate_dyn(i, j, &mut sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_space_page_aligns() {
        let mut a = AddressSpace::default();
        let r1 = a.alloc(10, 4);
        let r2 = a.alloc(3, 8);
        assert_eq!(r1.base % 4096, 0);
        assert_eq!(r2.base % 4096, 0);
        assert!(r2.base >= r1.at(10));
        assert_ne!(r1.base, 0, "page 0 must stay unused");
    }

    #[test]
    fn region_addressing() {
        let r = Region { base: 4096, elem_bytes: 4 };
        assert_eq!(r.at(0), 4096);
        assert_eq!(r.at(3), 4108);
    }

    #[test]
    fn count_sink_counts_by_site() {
        let mut s = CountSink::default();
        s.touch(0, Site::Ptr);
        s.touch(4, Site::Idx);
        s.touch(8, Site::Idx);
        assert_eq!(s.total, 3);
        assert_eq!(s.site(Site::Idx), 2);
        assert_eq!(s.site(Site::Val), 0);
    }

    #[test]
    fn format_names() {
        assert_eq!(FormatKind::InCrs.name(), "InCRS");
        assert_eq!(FormatKind::Csr.name(), "CRS");
    }

    #[test]
    fn parse_inverts_name_for_every_variant() {
        for kind in FormatKind::ALL {
            assert_eq!(FormatKind::parse(kind.name()).unwrap(), kind, "{kind:?}");
        }
        assert!(matches!(
            FormatKind::parse("nope"),
            Err(crate::formats::FormatError::UnknownFormat(_))
        ));
    }
}
