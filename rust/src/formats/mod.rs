//! Sparse-matrix storage formats (paper §II) with memory-access accounting.
//!
//! Every format in the paper's Table I is implemented, plus the paper's
//! contribution, [`incrs::InCrs`]. All formats share:
//!
//! * a canonical interchange form ([`coo::Coo`]) for any↔any conversion
//!   (typed failures: [`error::FormatError`]),
//! * a typed, cheaply-cloneable operand handle ([`operand::MatrixOperand`])
//!   the serving stack ingests in any native format,
//! * a simulated address-space layout, so random accesses produce *address
//!   streams* the cache simulator can replay (Fig 3), and
//! * `locate(i, j, sink)` random access that reports every word it touches
//!   to an [`traits::AccessSink`] (Table I/II access counting).
//!
//! The core execution formats ([`Csr`], [`Csc`], [`Coo`], [`InCrs`])
//! additionally expose `validate_invariants()` — monotone index pointers,
//! strictly-sorted in-bounds indices, nnz consistency, counter-word
//! agreement — which the engine asserts at prepare/execute boundaries via
//! [`strict_check`] when the `strict-invariants` feature is on.

pub mod convert;
pub mod coo;
pub mod csc;
pub mod csr;
pub mod dense;
pub mod ell;
pub mod error;
pub mod incrs;
pub mod jad;
pub mod lil;
pub mod operand;
pub mod sll;
pub mod traits;

pub use convert::{convert, from_coo, parse_kind, ALL_KINDS};
pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;
pub use dense::Dense;
pub use ell::Ellpack;
pub use error::FormatError;
pub use incrs::{InCrs, InCrsParams};
pub use jad::Jad;
pub use lil::Lil;
pub use operand::MatrixOperand;
pub use sll::Sll;
pub use traits::{
    AccessSink, AddressSpace, CountSink, FormatKind, NullSink, Region, Site,
    SparseMatrix,
};

/// Run a structural-invariant check at an execution boundary.
///
/// Under the `strict-invariants` feature a violation panics with the
/// boundary's `context` and the typed [`FormatError`] — corruption is
/// caught where it *enters* the engine, not wherever the bad index later
/// explodes. Without the feature (the default) the closure is never
/// called, so the O(nnz) validation costs nothing in production builds.
/// CI runs the full test suite both ways.
#[inline]
pub fn strict_check(context: &str, check: impl FnOnce() -> Result<(), FormatError>) {
    #[cfg(feature = "strict-invariants")]
    if let Err(e) = check() {
        // lint would not fire here (formats is outside P1's scope), but
        // for the record: panicking is the point — this is a debug
        // assertion about memory-safety-adjacent corruption, not a
        // recoverable serving error
        panic!("strict-invariants violated at {context}: {e}");
    }
    #[cfg(not(feature = "strict-invariants"))]
    {
        let _ = (context, check);
    }
}

#[cfg(test)]
mod strict_tests {
    use super::*;

    fn corrupt() -> Result<(), FormatError> {
        Err(FormatError::CorruptStructure {
            format: "crs",
            detail: "injected".into(),
        })
    }

    #[cfg(feature = "strict-invariants")]
    #[test]
    #[should_panic(expected = "strict-invariants violated at unit-test")]
    fn panics_on_violation_when_enabled() {
        strict_check("unit-test", corrupt);
    }

    #[cfg(not(feature = "strict-invariants"))]
    #[test]
    fn is_a_noop_when_disabled() {
        // the closure must not even run
        strict_check("unit-test", || {
            unreachable!("validation executed without the feature")
        });
        strict_check("unit-test", corrupt);
    }

    #[test]
    fn passing_checks_are_silent_either_way() {
        strict_check("unit-test", || Ok(()));
    }
}
