//! Sparse-matrix storage formats (paper §II) with memory-access accounting.
//!
//! Every format in the paper's Table I is implemented, plus the paper's
//! contribution, [`incrs::InCrs`]. All formats share:
//!
//! * a canonical interchange form ([`coo::Coo`]) for any↔any conversion
//!   (typed failures: [`error::FormatError`]),
//! * a typed, cheaply-cloneable operand handle ([`operand::MatrixOperand`])
//!   the serving stack ingests in any native format,
//! * a simulated address-space layout, so random accesses produce *address
//!   streams* the cache simulator can replay (Fig 3), and
//! * `locate(i, j, sink)` random access that reports every word it touches
//!   to an [`traits::AccessSink`] (Table I/II access counting).

pub mod convert;
pub mod coo;
pub mod csc;
pub mod csr;
pub mod dense;
pub mod ell;
pub mod error;
pub mod incrs;
pub mod jad;
pub mod lil;
pub mod operand;
pub mod sll;
pub mod traits;

pub use convert::{convert, from_coo, parse_kind, ALL_KINDS};
pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;
pub use dense::Dense;
pub use ell::Ellpack;
pub use error::FormatError;
pub use incrs::{InCrs, InCrsParams};
pub use jad::Jad;
pub use lil::Lil;
pub use operand::MatrixOperand;
pub use sll::Sll;
pub use traits::{
    AccessSink, AddressSpace, CountSink, FormatKind, NullSink, Region, Site,
    SparseMatrix,
};
