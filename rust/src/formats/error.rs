//! Typed errors for the formats layer.
//!
//! Every fallible operation in `formats` — name parsing, InCRS geometry
//! validation, counter-vector construction — reports one of these variants
//! instead of a bare `String`, so callers match on the failure shape. The
//! engine lifts them into `EngineError::Format` (and the coordinator into
//! `JobError::Format`) via `From`; a `From<FormatError> for String` bridge
//! keeps legacy stringly-typed call sites (the CLI) compiling while they
//! migrate.

use std::fmt;

/// What went wrong inside the formats layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FormatError {
    /// A format name (CLI `--a-format`, `convert --to`, …) did not parse.
    UnknownFormat(String),
    /// An algorithm name (`--kernel`) did not parse.
    UnknownAlgorithm(String),
    /// InCRS geometry rejected by `InCrsParams::validate` (paper §III.B
    /// packing assumptions).
    BadParams {
        section: usize,
        block: usize,
        reason: String,
    },
    /// A counter field overflowed while building InCRS from CSR (the
    /// paper's ≤65 535-nonzeros-per-row prefix or the per-block bit field).
    CounterOverflow { row: usize, detail: String },
    /// A structural invariant of a format's arrays is violated — a
    /// non-monotone index pointer, an unsorted or out-of-bounds index,
    /// an nnz inconsistency, a counter word disagreeing with the indices.
    /// Reported by the formats' `validate_invariants()` and asserted at
    /// engine boundaries by `formats::strict_check` under the
    /// `strict-invariants` feature.
    CorruptStructure {
        /// Format name (`crs`, `ccs`, `coo`, `incrs`).
        format: &'static str,
        detail: String,
    },
}

impl fmt::Display for FormatError {
    fn fmt(&self, w: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            // phrasing kept from the pre-typed messages so logs stay greppable
            FormatError::UnknownFormat(name) => write!(w, "unknown format {name:?}"),
            FormatError::UnknownAlgorithm(name) => {
                write!(w, "unknown algorithm {name:?}")
            }
            FormatError::BadParams { reason, .. } => write!(w, "{reason}"),
            FormatError::CounterOverflow { detail, .. } => write!(w, "{detail}"),
            FormatError::CorruptStructure { format, detail } => {
                write!(w, "corrupt {format} structure: {detail}")
            }
        }
    }
}

impl std::error::Error for FormatError {}

/// Legacy bridge for stringly-typed call sites (CLI, eval drivers) so `?`
/// keeps working while they migrate to matching on the variants.
impl From<FormatError> for String {
    fn from(e: FormatError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_keeps_the_established_phrasing() {
        assert_eq!(
            FormatError::UnknownFormat("nope".into()).to_string(),
            "unknown format \"nope\""
        );
        assert_eq!(
            FormatError::UnknownAlgorithm("nope".into()).to_string(),
            "unknown algorithm \"nope\""
        );
        let bad = FormatError::BadParams {
            section: 256,
            block: 3,
            reason: "block 3 must divide section 256".into(),
        };
        assert!(bad.to_string().contains("must divide"));
        let overflow = FormatError::CounterOverflow {
            row: 7,
            detail: "row 7: 70000 non-zeros before section 1 exceeds the 16-bit prefix".into(),
        };
        assert!(overflow.to_string().contains("16-bit prefix"));
        let corrupt = FormatError::CorruptStructure {
            format: "crs",
            detail: "row_ptr not monotone at row 3".into(),
        };
        assert_eq!(
            corrupt.to_string(),
            "corrupt crs structure: row_ptr not monotone at row 3"
        );
    }

    #[test]
    fn implements_std_error_and_string_bridge() {
        let e: Box<dyn std::error::Error> =
            Box::new(FormatError::UnknownFormat("x".into()));
        assert!(!e.to_string().is_empty());
        let s: String = FormatError::UnknownAlgorithm("y".into()).into();
        assert!(s.contains("unknown algorithm"));
    }
}
