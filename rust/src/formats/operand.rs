//! [`MatrixOperand`] — the typed, cheaply-cloneable operand handle the
//! whole stack ingests.
//!
//! The paper's central claim is that *format choice drives SpMM cost*: its
//! compact random-access format (InCRS) wins precisely when data arrives in
//! the "wrong" order. The serving surface therefore accepts operands **as
//! they arrive** — any Table-I format, wrapped in an `Arc` so handles clone
//! in O(1) — and the engine sees (and costs) the conversion instead of
//! forcing callers to pre-convert out of band. CSR submissions stay
//! zero-cost (`to_csr` is an `Arc` share); InCRS reuses its embedded CSR
//! arrays; CCS transposes directly in either direction (no COO hop); every
//! other format converts through canonical COO, whose sorted entry order
//! makes the conversion deterministic — a job submitted in any native
//! format produces output **bit-identical** to the same job submitted
//! pre-converted.

use std::sync::Arc;

use super::coo::Coo;
use super::csc::Csc;
use super::csr::Csr;
use super::dense::Dense;
use super::ell::Ellpack;
use super::error::FormatError;
use super::incrs::InCrs;
use super::jad::Jad;
use super::lil::Lil;
use super::sll::Sll;
use super::traits::{FormatKind, SparseMatrix};

/// A matrix operand in whichever storage format it arrived in. Cloning is
/// one `Arc` bump; the underlying matrix is immutable and shared.
#[derive(Clone, Debug)]
pub enum MatrixOperand {
    Dense(Arc<Dense>),
    Csr(Arc<Csr>),
    Csc(Arc<Csc>),
    Coo(Arc<Coo>),
    Sll(Arc<Sll>),
    Ell(Arc<Ellpack>),
    Lil(Arc<Lil>),
    Jad(Arc<Jad>),
    InCrs(Arc<InCrs>),
}

impl MatrixOperand {
    /// The operand as the object-safe format trait (metadata, `to_coo`).
    pub fn as_sparse(&self) -> &dyn SparseMatrix {
        match self {
            MatrixOperand::Dense(m) => m.as_ref(),
            MatrixOperand::Csr(m) => m.as_ref(),
            MatrixOperand::Csc(m) => m.as_ref(),
            MatrixOperand::Coo(m) => m.as_ref(),
            MatrixOperand::Sll(m) => m.as_ref(),
            MatrixOperand::Ell(m) => m.as_ref(),
            MatrixOperand::Lil(m) => m.as_ref(),
            MatrixOperand::Jad(m) => m.as_ref(),
            MatrixOperand::InCrs(m) => m.as_ref(),
        }
    }

    /// Native storage format of this operand.
    pub fn format(&self) -> FormatKind {
        self.as_sparse().kind()
    }

    pub fn shape(&self) -> (usize, usize) {
        self.as_sparse().shape()
    }

    pub fn rows(&self) -> usize {
        self.shape().0
    }

    pub fn cols(&self) -> usize {
        self.shape().1
    }

    pub fn nnz(&self) -> usize {
        self.as_sparse().nnz()
    }

    /// True when both handles share one underlying allocation (same format
    /// variant, same `Arc`) — the identity the coordinator's micro-batch
    /// coalescer groups by.
    pub fn same_source(&self, other: &MatrixOperand) -> bool {
        use MatrixOperand::*;
        match (self, other) {
            (Dense(a), Dense(b)) => Arc::ptr_eq(a, b),
            (Csr(a), Csr(b)) => Arc::ptr_eq(a, b),
            (Csc(a), Csc(b)) => Arc::ptr_eq(a, b),
            (Coo(a), Coo(b)) => Arc::ptr_eq(a, b),
            (Sll(a), Sll(b)) => Arc::ptr_eq(a, b),
            (Ell(a), Ell(b)) => Arc::ptr_eq(a, b),
            (Lil(a), Lil(b)) => Arc::ptr_eq(a, b),
            (Jad(a), Jad(b)) => Arc::ptr_eq(a, b),
            (InCrs(a), InCrs(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }

    /// The operand as canonical CSR. Zero-cost for CSR operands (`Arc`
    /// share); InCRS copies its embedded CSR arrays directly (no COO
    /// round-trip); CCS transposes directly (its arrays *are* the CSR
    /// arrays of the transpose, and `Csr::transpose` is a stable counting
    /// sort — same bits as the COO route, one pass instead of two); every
    /// other format converts through COO, whose sorted entries make the
    /// result deterministic — and therefore bit-stable across repeated
    /// conversions of the same content.
    pub fn to_csr(&self) -> Result<Arc<Csr>, FormatError> {
        Ok(match self {
            MatrixOperand::Csr(m) => Arc::clone(m),
            MatrixOperand::InCrs(m) => Arc::new(Csr::from_parts(
                m.rows(),
                m.cols(),
                m.row_ptr.clone(),
                m.col_idx.clone(),
                m.vals.clone(),
            )),
            MatrixOperand::Csc(m) => {
                let t = Csr::from_parts(
                    m.cols(),
                    m.rows(),
                    m.col_ptr.clone(),
                    m.row_idx.clone(),
                    m.vals.clone(),
                );
                Arc::new(t.transpose())
            }
            other => Arc::new(Csr::from_coo(&other.as_sparse().to_coo())),
        })
    }

    /// The operand as CCS — the column-major twin of [`to_csr`](Self::to_csr),
    /// used by the outer-product backend's CSC ingestion path. `Arc` share
    /// when the operand already is CCS; CSR transposes directly via
    /// [`Csc::from_csr`]; everything else goes through canonical COO.
    pub fn to_csc(&self) -> Result<Arc<Csc>, FormatError> {
        Ok(match self {
            MatrixOperand::Csc(m) => Arc::clone(m),
            MatrixOperand::Csr(m) => Arc::new(Csc::from_csr(m)),
            other => Arc::new(Csc::from_coo(&other.as_sparse().to_coo())),
        })
    }

    /// Convert to `to`, sharing the existing allocation when the operand is
    /// already in that format. Conversion goes through canonical COO (value
    /// bits pass through untouched), except the cheap CSR/InCRS fast paths.
    pub fn convert(&self, to: FormatKind) -> Result<MatrixOperand, FormatError> {
        if self.format() == to {
            return Ok(self.clone());
        }
        if to == FormatKind::Csr {
            return Ok(MatrixOperand::Csr(self.to_csr()?));
        }
        if to == FormatKind::Csc {
            return Ok(MatrixOperand::Csc(self.to_csc()?));
        }
        let coo = self.as_sparse().to_coo();
        Ok(match to {
            FormatKind::Dense => MatrixOperand::Dense(Arc::new(Dense::from_coo(&coo))),
            FormatKind::Csr | FormatKind::Csc => unreachable!("handled above"),
            FormatKind::Coo => MatrixOperand::Coo(Arc::new(coo)),
            FormatKind::Sll => MatrixOperand::Sll(Arc::new(Sll::from_coo(&coo))),
            FormatKind::Ellpack => MatrixOperand::Ell(Arc::new(Ellpack::from_coo(&coo))),
            FormatKind::Lil => MatrixOperand::Lil(Arc::new(Lil::from_coo(&coo))),
            FormatKind::Jad => MatrixOperand::Jad(Arc::new(Jad::from_coo(&coo))),
            FormatKind::InCrs => {
                MatrixOperand::InCrs(Arc::new(InCrs::from_csr(&Csr::from_coo(&coo))?))
            }
        })
    }

    /// Estimated words touched converting this operand to canonical CSR —
    /// the ingestion cost `Registry::select_native` charges instead of
    /// assuming CSR arrives free. 0 for CSR; InCRS pays its array copies;
    /// CCS pays one counting-sort transpose; everything else pays the COO
    /// round-trip.
    pub fn conversion_words(&self) -> f64 {
        conversion_words(self.format(), self.nnz(), self.rows())
    }
}

/// Words touched converting `nnz` non-zeros (over `rows` rows) from
/// `native` into canonical CSR. Shape of the estimate, not a cycle count —
/// it only needs to be monotone and zero for the free path.
pub fn conversion_words(native: FormatKind, nnz: usize, rows: usize) -> f64 {
    match native {
        FormatKind::Csr => 0.0,
        // direct array copies: idx + val + row pointers
        FormatKind::InCrs => (2 * nnz + rows + 1) as f64,
        // direct counting-sort transpose: idx + val written once, plus a
        // counting pass — cheaper than the COO round-trip, dearer than a
        // straight copy
        FormatKind::Csc => (3 * nnz + rows + 1) as f64,
        // to_coo (3 words/entry) + CSR build (2 words/entry + pointers)
        _ => (5 * nnz + rows + 1) as f64,
    }
}

macro_rules! operand_from {
    ($ty:ty, $variant:ident) => {
        impl From<Arc<$ty>> for MatrixOperand {
            fn from(m: Arc<$ty>) -> MatrixOperand {
                MatrixOperand::$variant(m)
            }
        }
        impl From<$ty> for MatrixOperand {
            fn from(m: $ty) -> MatrixOperand {
                MatrixOperand::$variant(Arc::new(m))
            }
        }
    };
}

operand_from!(Dense, Dense);
operand_from!(Csr, Csr);
operand_from!(Csc, Csc);
operand_from!(Coo, Coo);
operand_from!(Sll, Sll);
operand_from!(Ellpack, Ell);
operand_from!(Lil, Lil);
operand_from!(Jad, Jad);
operand_from!(InCrs, InCrs);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::convert::ALL_KINDS;

    fn sample() -> Coo {
        Coo::new(
            4,
            6,
            vec![
                (0, 1, 1.0),
                (0, 5, 2.0),
                (1, 0, 3.0),
                (2, 2, 4.0),
                (2, 3, 5.0),
                (2, 4, 6.0),
                (3, 0, 7.0),
            ],
        )
    }

    #[test]
    fn metadata_matches_every_native_format() {
        let coo = sample();
        let base = MatrixOperand::from(coo.clone());
        for kind in ALL_KINDS {
            let op = base.convert(kind).unwrap();
            assert_eq!(op.format(), kind);
            assert_eq!(op.shape(), coo.shape(), "{kind:?}");
            assert_eq!(op.nnz(), coo.nnz(), "{kind:?}");
        }
    }

    #[test]
    fn csr_to_csr_is_an_arc_share() {
        let csr = Arc::new(Csr::from_coo(&sample()));
        let op = MatrixOperand::from(Arc::clone(&csr));
        assert!(Arc::ptr_eq(&op.to_csr().unwrap(), &csr));
        // convert to the same format is also a share
        match op.convert(FormatKind::Csr).unwrap() {
            MatrixOperand::Csr(shared) => assert!(Arc::ptr_eq(&shared, &csr)),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(op.conversion_words(), 0.0);
    }

    #[test]
    fn incrs_to_csr_skips_the_coo_roundtrip_and_matches() {
        let csr = Csr::from_coo(&sample());
        let incrs = InCrs::from_csr(&csr).unwrap();
        let op = MatrixOperand::from(incrs);
        let back = op.to_csr().unwrap();
        assert_eq!(back.row_ptr, csr.row_ptr);
        assert_eq!(back.col_idx, csr.col_idx);
        assert_eq!(back.vals, csr.vals);
        assert!(op.conversion_words() > 0.0);
    }

    #[test]
    fn csc_to_csr_direct_transpose_matches_the_coo_route() {
        let coo = sample();
        let csc = Csc::from_coo(&coo);
        let op = MatrixOperand::from(csc);
        let direct = op.to_csr().unwrap();
        let via_coo = Csr::from_coo(&op.as_sparse().to_coo());
        assert_eq!(direct.row_ptr, via_coo.row_ptr);
        assert_eq!(direct.col_idx, via_coo.col_idx);
        assert_eq!(
            direct.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            via_coo.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn to_csc_shares_when_native_and_transposes_csr_directly() {
        let csc = Arc::new(Csc::from_coo(&sample()));
        let op = MatrixOperand::from(Arc::clone(&csc));
        assert!(Arc::ptr_eq(&op.to_csc().unwrap(), &csc));
        match op.convert(FormatKind::Csc).unwrap() {
            MatrixOperand::Csc(shared) => assert!(Arc::ptr_eq(&shared, &csc)),
            other => panic!("unexpected {other:?}"),
        }
        // CSR source takes the direct-transpose path, same arrays as COO
        let csr_op = MatrixOperand::from(Csr::from_coo(&sample()));
        let got = csr_op.to_csc().unwrap();
        assert_eq!(got.col_ptr, csc.col_ptr);
        assert_eq!(got.row_idx, csc.row_idx);
        assert_eq!(
            got.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            csc.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn csc_ingestion_tier_sits_between_incrs_and_the_coo_formats() {
        let csc_w = conversion_words(FormatKind::Csc, 100, 10);
        assert!(conversion_words(FormatKind::InCrs, 100, 10) < csc_w);
        assert!(csc_w < conversion_words(FormatKind::Coo, 100, 10));
    }

    #[test]
    fn every_conversion_preserves_value_bits() {
        let coo = sample();
        let want = coo.to_dense();
        let base = MatrixOperand::from(coo);
        for from in ALL_KINDS {
            let x = base.convert(from).unwrap();
            for to in ALL_KINDS {
                let y = x.convert(to).unwrap();
                let got = y.as_sparse().to_coo().to_dense();
                assert_eq!(got.len(), want.len(), "{from:?}->{to:?}");
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.to_bits(), w.to_bits(), "{from:?}->{to:?}");
                }
            }
        }
    }

    #[test]
    fn same_source_is_arc_identity_within_a_variant() {
        let a = MatrixOperand::from(Arc::new(sample()));
        let b = a.clone();
        assert!(a.same_source(&b));
        let c = MatrixOperand::from(sample());
        assert!(!a.same_source(&c), "distinct allocations must differ");
        let d = a.convert(FormatKind::Csr).unwrap();
        assert!(!a.same_source(&d), "different variants never share a source");
    }

    #[test]
    fn conversion_cost_is_zero_only_for_csr() {
        for kind in ALL_KINDS {
            let w = conversion_words(kind, 100, 10);
            if kind == FormatKind::Csr {
                assert_eq!(w, 0.0);
            } else {
                assert!(w > 0.0, "{kind:?}");
            }
        }
    }
}
