//! Shared experiment plumbing: results carry both a paper-style text table
//! and a JSON document; the CLI prints the former and can persist the
//! latter for EXPERIMENTS.md bookkeeping.

use crate::util::json::Json;
use crate::util::tables::Table;

#[derive(Debug)]
pub struct ExpResult {
    pub id: &'static str,
    pub table: Table,
    pub json: Json,
}

impl ExpResult {
    pub fn print(&self) {
        self.table.print();
    }

    pub fn save(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.id));
        std::fs::write(&path, self.json.to_string_pretty())?;
        Ok(path)
    }
}

/// Common experiment knobs (from the CLI).
#[derive(Clone, Copy, Debug)]
pub struct ExpOptions {
    pub seed: u64,
    /// Scale factor in (0, 1] applied to workload sizes (columns probed,
    /// probe counts) — the paper's own "resize for simulation time" knob.
    pub scale: f64,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions { seed: 42, scale: 1.0 }
    }
}

impl ExpOptions {
    pub fn scaled(&self, n: usize) -> usize {
        ((n as f64 * self.scale).round() as usize).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling() {
        let o = ExpOptions { seed: 1, scale: 0.25 };
        assert_eq!(o.scaled(1000), 250);
        assert_eq!(o.scaled(1), 1);
        let full = ExpOptions::default();
        assert_eq!(full.scaled(123), 123);
    }

    #[test]
    fn save_writes_json() {
        let r = ExpResult {
            id: "test_exp",
            table: Table::new("t", &["a"]),
            json: Json::Num(1.0),
        };
        let dir = std::env::temp_dir().join("spmm_accel_reports");
        let p = r.save(&dir).unwrap();
        assert!(p.exists());
        std::fs::remove_file(p).ok();
    }
}
