//! Table II: InCRS cost/benefit on the paper's five datasets — estimated
//! and *measured* MA ratio for a column-order read, and the storage ratio.

use super::report::{ExpOptions, ExpResult};
use crate::access::column::{read_columns_csr, read_columns_incrs};
use crate::datasets::spec::TABLE2;
use crate::datasets::synth::generate;
use crate::formats::incrs::InCrs;
use crate::formats::traits::{CountSink, SparseMatrix};
use crate::util::json::{obj, Json};
use crate::util::tables::{sig, Table};

pub struct Table2Row {
    pub name: &'static str,
    pub rows: usize,
    pub cols: usize,
    pub density: f64,
    pub nnz_row: (usize, f64, usize),
    pub est_ma_ratio: f64,
    pub meas_ma_ratio: f64,
    pub est_storage_ratio: f64,
    pub meas_storage_ratio: f64,
}

pub fn run_rows(opts: ExpOptions) -> Vec<Table2Row> {
    TABLE2
        .iter()
        .map(|spec| {
            let m = generate(spec, opts.seed);
            let incrs = InCrs::from_csr(&m).expect("InCRS build");
            let col_limit = Some(opts.scaled(m.cols()));

            let mut s_crs = CountSink::default();
            read_columns_csr(&m, col_limit, &mut s_crs);
            let mut s_in = CountSink::default();
            read_columns_incrs(&incrs, col_limit, &mut s_in);

            let crs_words = (m.rows() + 1) + 2 * m.nnz();
            Table2Row {
                name: spec.name,
                rows: m.rows(),
                cols: m.cols(),
                density: m.density(),
                nnz_row: m.nnz_row_stats(),
                est_ma_ratio: incrs.estimated_ma_ratio(),
                meas_ma_ratio: s_crs.total as f64 / s_in.total.max(1) as f64,
                est_storage_ratio: incrs.estimated_storage_ratio(),
                meas_storage_ratio: crs_words as f64 / incrs.storage_words() as f64,
            }
        })
        .collect()
}

pub fn run(opts: ExpOptions) -> ExpResult {
    let rows = run_rows(opts);
    let mut table = Table::new(
        "Table II — cost and benefit of InCRS vs CRS (paper est. MA ratios: 42/39/14/11/3)",
        &[
            "dataset", "dim (MxN)", "D", "NZ/row (min,avg,max)",
            "MA ratio est", "MA ratio meas", "storage ratio est", "storage ratio meas",
        ],
    );
    let mut json_rows = Vec::new();
    for r in &rows {
        table.row(vec![
            r.name.to_string(),
            format!("{}x{}", r.rows, r.cols),
            format!("{:.1}%", r.density * 100.0),
            format!("({}, {:.0}, {})", r.nnz_row.0, r.nnz_row.1, r.nnz_row.2),
            sig(r.est_ma_ratio),
            sig(r.meas_ma_ratio),
            sig(r.est_storage_ratio),
            sig(r.meas_storage_ratio),
        ]);
        json_rows.push(obj([
            ("dataset", Json::from(r.name)),
            ("est_ma_ratio", Json::Num(r.est_ma_ratio)),
            ("meas_ma_ratio", Json::Num(r.meas_ma_ratio)),
            ("est_storage_ratio", Json::Num(r.est_storage_ratio)),
            ("meas_storage_ratio", Json::Num(r.meas_storage_ratio)),
        ]));
    }
    ExpResult {
        id: "table2",
        table,
        json: Json::Arr(json_rows),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_of_table2_holds() {
        // scaled down for test time: probe 3% of columns
        let rows = run_rows(ExpOptions { seed: 3, scale: 0.03 });
        assert_eq!(rows.len(), 5);
        let by_name = |n: &str| rows.iter().find(|r| r.name == n).unwrap();
        // paper ordering: amazon/belcastro benefit most, mks least
        assert!(by_name("amazon").est_ma_ratio > by_name("mks").est_ma_ratio * 5.0);
        // storage ratio in the paper's 0.85-1.0 band
        for r in &rows {
            assert!(
                (0.80..1.0).contains(&r.meas_storage_ratio),
                "{}: {}",
                r.name,
                r.meas_storage_ratio
            );
            // measured MA ratio must show a clear win wherever estimated does
            if r.est_ma_ratio > 5.0 {
                assert!(r.meas_ma_ratio > 5.0, "{}: {}", r.name, r.meas_ma_ratio);
            }
        }
    }
}
