//! Fig 3: CRS vs InCRS under the gem5-parameter memory hierarchy — cache
//! access counts, memory-access time, and total run time, CRS normalized to
//! InCRS, per Table II dataset.

use super::report::{ExpOptions, ExpResult};
use crate::cachesim::config::HierarchyConfig;
use crate::cachesim::runner::{compare, Comparison};
use crate::datasets::spec::TABLE2;
use crate::datasets::synth::generate;
use crate::formats::incrs::InCrsParams;
use crate::util::json::{obj, Json};
use crate::util::tables::{human, sig, Table};

pub struct Fig3Row {
    pub name: &'static str,
    pub cmp: Comparison,
}

pub fn run_rows(opts: ExpOptions, cfg: HierarchyConfig) -> Vec<Fig3Row> {
    TABLE2
        .iter()
        .map(|spec| {
            let m = generate(spec, opts.seed);
            let col_limit = Some(opts.scaled(spec.cols));
            let cmp = compare(&m, InCrsParams::default(), cfg, col_limit)
                .expect("fig3 comparison");
            Fig3Row {
                name: spec.name,
                cmp,
            }
        })
        .collect()
}

pub fn run(opts: ExpOptions) -> ExpResult {
    let cfg = HierarchyConfig::default();
    let rows = run_rows(opts, cfg);
    let mut table = Table::new(
        "Fig 3 — CRS normalized to InCRS under the Table-III hierarchy \
         (paper: L1-access reductions 49x Belcastro, 31x Docword; total ~14-49x)",
        &[
            "dataset", "L1 acc (CRS)", "L1 acc ratio", "L2 acc ratio",
            "mem time ratio", "total time ratio", "L1 hit% CRS", "L1 hit% InCRS",
        ],
    );
    let mut json_rows = Vec::new();
    for r in &rows {
        table.row(vec![
            r.name.to_string(),
            human(r.cmp.crs.stats.l1_accesses),
            sig(r.cmp.l1_access_ratio()),
            sig(r.cmp.l2_access_ratio()),
            sig(r.cmp.mem_time_ratio()),
            sig(r.cmp.total_time_ratio()),
            format!("{:.1}", r.cmp.crs.stats.l1_hit_rate() * 100.0),
            format!("{:.1}", r.cmp.incrs.stats.l1_hit_rate() * 100.0),
        ]);
        json_rows.push(obj([
            ("dataset", Json::from(r.name)),
            ("l1_ratio", Json::Num(r.cmp.l1_access_ratio())),
            ("l2_ratio", Json::Num(r.cmp.l2_access_ratio())),
            ("mem_time_ratio", Json::Num(r.cmp.mem_time_ratio())),
            ("total_time_ratio", Json::Num(r.cmp.total_time_ratio())),
        ]));
    }
    ExpResult {
        id: "fig3",
        table,
        json: Json::Arr(json_rows),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shape_holds_scaled() {
        let rows = run_rows(
            ExpOptions { seed: 5, scale: 0.02 },
            HierarchyConfig::default(),
        );
        assert_eq!(rows.len(), 5);
        for r in &rows {
            // InCRS reduces both raw accesses and total time everywhere
            assert!(
                r.cmp.l1_access_ratio() > 1.5,
                "{}: l1 ratio {}",
                r.name,
                r.cmp.l1_access_ratio()
            );
            assert!(
                r.cmp.total_time_ratio() > 1.0,
                "{}: time ratio {}",
                r.name,
                r.cmp.total_time_ratio()
            );
        }
        // datasets with heavier rows benefit more (amazon vs mks)
        let amazon = rows.iter().find(|r| r.name == "amazon").unwrap();
        let mks = rows.iter().find(|r| r.name == "mks").unwrap();
        assert!(amazon.cmp.l1_access_ratio() > mks.cmp.l1_access_ratio());
    }
}
