//! Experiment drivers: one per paper table/figure (DESIGN.md §4 index).
//!
//! Each driver returns an [`report::ExpResult`] carrying a paper-style text
//! table and a JSON document; the CLI (`spmm-accel exp --id <id>`) and the
//! `paper_tables` bench both dispatch through [`run_experiment`].

pub mod ablations;
pub mod engines;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod report;
pub mod selection;
pub mod table1;
pub mod table2;

pub use report::{ExpOptions, ExpResult};

/// All experiment ids: the paper's tables/figures in paper order, then the
/// repo's own `engines` kernel comparison and the learned-selection
/// calibration study.
pub const ALL_EXPERIMENTS: [&str; 9] = [
    "table1", "table2", "fig3", "table4", "fig4a", "fig4b", "fig5", "engines", "selection",
];
// table5 is parameter accounting, printed alongside fig5

/// Dispatch an experiment by id.
pub fn run_experiment(id: &str, opts: ExpOptions) -> Result<Vec<ExpResult>, String> {
    Ok(match id {
        "table1" => vec![table1::run(opts)],
        "table2" => vec![table2::run(opts)],
        "fig3" => vec![fig3::run(opts)],
        "table4" => vec![fig5::run_table4(opts)],
        "table5" => vec![fig5::run_table5()],
        "fig4a" => vec![fig4::run_a(opts)],
        "fig4b" => vec![fig4::run_b(opts)],
        "fig5" => vec![fig5::run_table5(), fig5::run(opts)],
        "engines" => vec![engines::run(opts)],
        "selection" => vec![selection::run(opts)],
        "ablations" => ablations::run_all(opts),
        "all" => {
            let mut out = Vec::new();
            for id in ALL_EXPERIMENTS {
                out.extend(run_experiment(id, opts)?);
            }
            out
        }
        other => return Err(format!("unknown experiment {other:?}; try one of {ALL_EXPERIMENTS:?} or `all`")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_rejects_unknown() {
        assert!(run_experiment("nope", ExpOptions::default()).is_err());
    }

    #[test]
    fn table5_is_instant() {
        let r = run_experiment("table5", ExpOptions::default()).unwrap();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, "table5");
    }
}
