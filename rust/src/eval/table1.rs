//! Table I: measured cost of locating one arbitrary element per format,
//! against the paper's closed-form complexities.

use super::report::{ExpOptions, ExpResult};
use crate::access::locate::{measure, LocateCost};
use crate::datasets::synth::uniform;
use crate::formats::convert::{from_coo, ALL_KINDS};
use crate::formats::traits::SparseMatrix;
use crate::util::json::{obj, Json};
use crate::util::tables::{sig, Table};

/// Workload: a mid-size synthetic matrix (the complexity *ratios* are what
/// Table I pins; its formulas are dimension-generic).
pub fn run(opts: ExpOptions) -> ExpResult {
    let rows = opts.scaled(256);
    let cols = opts.scaled(2048);
    let probes = opts.scaled(20_000) as u64;
    let m = uniform(rows, cols, 0.05, opts.seed);
    let coo = m.to_coo();

    let mut table = Table::new(
        &format!(
            "Table I — avg memory accesses to locate one element ({}x{}, D=5%, {} probes)",
            rows, cols, probes
        ),
        &["format", "analytic (paper)", "analytic value", "measured avg MA", "storage words"],
    );
    let mut rows_json = Vec::new();
    for kind in ALL_KINDS {
        let mat = from_coo(kind, &coo).expect("convert");
        let cost: LocateCost = measure(mat.as_ref(), probes, opts.seed + 1);
        let formula = match kind.name() {
            "ELLPACK" | "LiL" | "CRS" => "1/2 · N · D",
            "JAD" => "N · D",
            "COO" | "SLL" => "1/2 · M · N · D",
            "dense" => "1",
            "CCS" => "1/2 · M · D",
            "InCRS" => "b/2 + 1",
            _ => "?",
        };
        table.row(vec![
            kind.name().to_string(),
            formula.to_string(),
            cost.analytic.map(sig).unwrap_or_default(),
            sig(cost.avg()),
            mat.storage_words().to_string(),
        ]);
        rows_json.push(obj([
            ("format", Json::from(kind.name())),
            ("analytic", Json::Num(cost.analytic.unwrap_or(f64::NAN))),
            ("measured", Json::Num(cost.avg())),
            ("storage_words", Json::from(mat.storage_words())),
        ]));
    }
    ExpResult {
        id: "table1",
        table,
        json: obj([
            ("rows", Json::from(rows)),
            ("cols", Json::from(cols)),
            ("probes", Json::from(probes)),
            ("formats", Json::Arr(rows_json)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_all_formats_and_sane_ordering() {
        let r = run(ExpOptions { seed: 1, scale: 0.1 });
        assert_eq!(r.table.rows.len(), 9);
        // measured column: dense=1 must be minimal, COO/SLL maximal
        let measured: Vec<f64> = r
            .json
            .at(&["formats"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|f| f.at(&["measured"]).unwrap().as_f64().unwrap())
            .collect();
        let names: Vec<&str> = r
            .json
            .at(&["formats"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|f| f.at(&["format"]).unwrap().as_str().unwrap())
            .collect();
        let get = |n: &str| measured[names.iter().position(|&x| x == n).unwrap()];
        assert!(get("dense") <= 1.0 + 1e-9);
        assert!(get("COO") > get("CRS") * 3.0);
        assert!(get("InCRS") < get("CRS"));
    }
}
