//! `engines` experiment: every registered kernel on the same workload —
//! measured wall time, work accounting, and max error vs the dense oracle.
//!
//! This is the eval-side consumer of the unified execution layer: it walks
//! `engine::Registry` rather than naming algorithms, so a newly registered
//! backend shows up in the report (and in `spmm-accel exp --id engines`)
//! with no further wiring. The serial-vs-parallel tiled rows double as a
//! quick sanity check of the executor's scaling.

use std::time::Instant;

use super::report::{ExpOptions, ExpResult};
use crate::datasets::synth::uniform;
use crate::engine::{
    Algorithm, EngineError, EngineOutput, Registry, SpmmKernel, TiledConfig, TiledKernel,
};
use crate::spmm::plan::Geometry;
use crate::util::json::{obj, Json};
use crate::util::tables::{sig, Table};

pub fn run(opts: ExpOptions) -> ExpResult {
    let n = opts.scaled(768);
    let a = uniform(n, n, 0.02, opts.seed);
    let b = uniform(n, n, 0.02, opts.seed + 1);
    let oracle = crate::spmm::dense::multiply(&a, &b);

    let reg = Registry::with_default_kernels(Geometry::default(), 1);
    // a second tiled entry at 4 workers would collide on the registry key,
    // so benchmark it out-of-band below
    let tiled4 = TiledKernel::new(TiledConfig { block: 32, workers: 4 });

    let mut table = Table::new(
        &format!("Engines — registered kernels on uniform {n}x{n} @ 2% (seed {})", opts.seed),
        &["kernel", "format", "algorithm", "wall ms", "dispatches", "real pairs", "max err"],
    );
    let mut rows = Vec::new();
    let mut run_one = |name: &str, fmt: &str, alg: &str, out: Result<EngineOutput, EngineError>, wall_ms: f64| {
        match out {
            Ok(o) => {
                let err = o.c.max_abs_diff(&oracle);
                table.row(vec![
                    name.into(),
                    fmt.into(),
                    alg.into(),
                    sig(wall_ms),
                    o.stats.dispatches.to_string(),
                    o.stats.real_pairs.to_string(),
                    format!("{err:.2e}"),
                ]);
                rows.push(obj([
                    ("kernel", Json::from(name)),
                    ("format", Json::from(fmt)),
                    ("algorithm", Json::from(alg)),
                    ("wall_ms", Json::from(wall_ms)),
                    ("dispatches", Json::from(o.stats.dispatches)),
                    ("real_pairs", Json::from(o.stats.real_pairs)),
                    ("max_err", Json::from(err as f64)),
                ]));
            }
            Err(e) => {
                table.row(vec![
                    name.into(),
                    fmt.into(),
                    alg.into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    format!("error: {e}"),
                ]);
            }
        }
    };

    // the dense oracle is the reference itself; skip it to keep the run fast
    let kernels: Vec<_> = reg
        .kernels()
        .filter(|k| k.algorithm() != Algorithm::Dense)
        .cloned()
        .collect();
    for k in &kernels {
        let t = Instant::now();
        let out = k.run(&a, &b);
        let wall_ms = t.elapsed().as_secs_f64() * 1e3;
        run_one(k.name(), k.format().name(), k.algorithm().name(), out, wall_ms);
    }
    {
        let t = Instant::now();
        let out = tiled4.run(&a, &b);
        let wall_ms = t.elapsed().as_secs_f64() * 1e3;
        run_one("tiled-4w", "CRS", "tiled", out, wall_ms);
    }
    drop(run_one);
    let keys: Vec<Json> = reg
        .keys()
        .iter()
        .map(|(f, alg)| Json::from(format!("{}/{}", f.name(), alg.name())))
        .collect();

    ExpResult {
        id: "engines",
        table,
        json: obj([
            ("n", Json::from(n)),
            ("density", Json::from(0.02)),
            ("seed", Json::from(opts.seed)),
            ("registered", Json::Arr(keys)),
            ("runs", Json::Arr(rows)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engines_experiment_runs_scaled_down() {
        let r = run(ExpOptions { seed: 7, scale: 0.1 });
        assert_eq!(r.id, "engines");
        assert!(r.table.rows.len() >= 5, "rows: {}", r.table.rows.len());
        // every run row must agree with the oracle
        for row in &r.table.rows {
            let err_cell = row.last().unwrap();
            assert!(!err_cell.starts_with("error"), "{row:?}");
        }
        let runs = r.json.at(&["runs"]).unwrap().as_arr().unwrap();
        for run in runs {
            assert!(run.at(&["max_err"]).unwrap().as_f64().unwrap() < 1e-3);
        }
    }
}
