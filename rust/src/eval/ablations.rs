//! Ablation studies — the design-choice experiments DESIGN.md §4 calls out,
//! including the paper's stated future work ("we will study the parameter
//! selection process in more detail", §V.C) and its footnote-2 claim about
//! binary search.
//!
//! * `incrs_params` — (S, b) sweep: measured MA per locate vs counter
//!   storage overhead (paper §III.C tradeoff).
//! * `round_size`  — sync-mesh R sweep: latency vs buffer size (paper
//!   §IV.B.b tradeoff).
//! * `fpic_bandwidth` — FPIC with/without the duplicate-fetch bound (our
//!   model's key term; the "infinite bandwidth" variant is the paper's
//!   stated best case for FPIC).
//! * `search_policy` — linear vs binary CRS row search *under the cache
//!   simulator* (paper footnote 2: binary search saves accesses but has
//!   "poor caching behavior").
//! * `column_dist`  — uniform vs Zipf vs banded placement at equal density:
//!   which data structure favors which design.

use super::report::{ExpOptions, ExpResult};
use crate::access::locate::measure;
use crate::arch::fpic::{simulate as fpic_simulate, FpicConfig};
use crate::arch::sync_mesh::{cycle_model, SyncMeshConfig};
use crate::cachesim::{Hierarchy, HierarchyConfig};
use crate::datasets::spec::{ColumnDist, DatasetSpec, NnzRow};
use crate::datasets::synth::{generate, uniform};
use crate::formats::incrs::{InCrs, InCrsParams};
use crate::formats::traits::SparseMatrix;
use crate::util::json::{obj, Json};
use crate::util::tables::{human, sig, Table};

/// (S, b) parameter-selection study (the paper's future work).
pub fn incrs_params(opts: ExpOptions) -> ExpResult {
    let m = uniform(
        opts.scaled(400),
        8192,
        0.05,
        opts.seed,
    );
    let crs_words = (m.rows() + 1) + 2 * m.nnz();
    let mut table = Table::new(
        "Ablation — InCRS (S, b) parameter selection (paper §V.C future work)",
        &["S", "b", "counter bits", "meas MA/locate", "est b/2+1", "storage overhead %"],
    );
    let mut json_rows = Vec::new();
    for (s, b) in [
        (512usize, 64usize),
        (256, 64),
        (256, 32), // the paper's choice
        (128, 32),
        (128, 16),
        (64, 8),
    ] {
        let params = InCrsParams { section: s, block: b };
        if params.validate().is_err() {
            continue;
        }
        let incrs = match InCrs::from_csr_params(&m, params) {
            Ok(x) => x,
            Err(_) => continue,
        };
        let cost = measure(&incrs, opts.scaled(20_000) as u64, opts.seed + 1);
        let overhead =
            100.0 * (incrs.storage_words() - crs_words) as f64 / crs_words as f64;
        table.row(vec![
            s.to_string(),
            b.to_string(),
            format!("16+{}x{}", params.blocks_per_section(), params.bits_per_block()),
            sig(cost.avg()),
            sig(b as f64 / 2.0 + 1.0),
            sig(overhead),
        ]);
        json_rows.push(obj([
            ("section", Json::from(s)),
            ("block", Json::from(b)),
            ("ma_per_locate", Json::Num(cost.avg())),
            ("storage_overhead_pct", Json::Num(overhead)),
        ]));
    }
    ExpResult {
        id: "ablation_incrs_params",
        table,
        json: Json::Arr(json_rows),
    }
}

/// Sync-mesh round-size sweep (paper §IV.B.b tradeoff).
pub fn round_size(opts: ExpOptions) -> ExpResult {
    let dense = uniform(opts.scaled(512), 2048, 0.1, opts.seed);
    let sparse = generate(
        &DatasetSpec {
            name: "banded",
            rows: opts.scaled(2048),
            cols: 2048,
            stated_density: 0.005,
            nnz_row: NnzRow { min: 1, avg: 10.0, max: 40 },
            dist: ColumnDist::Banded(256),
        },
        opts.seed,
    );
    let mut table = Table::new(
        "Ablation — synchronization round size R (buffer depth = R)",
        &["R", "dense cycles", "sparse(banded) cycles", "buffer kB (64x64 mesh)"],
    );
    let mut json_rows = Vec::new();
    for r in [8usize, 16, 32, 64, 128] {
        let cfg = SyncMeshConfig { mesh: 64, round: r };
        let cd = cycle_model(&dense, &dense, cfg).cycles;
        let cs = cycle_model(&sparse, &sparse, cfg).cycles;
        let buf_kb = 64 * 64 * r as u64 * 48 / 8 / 1024;
        table.row(vec![
            r.to_string(),
            human(cd),
            human(cs),
            buf_kb.to_string(),
        ]);
        json_rows.push(obj([
            ("round", Json::from(r)),
            ("dense_cycles", Json::from(cd)),
            ("sparse_cycles", Json::from(cs)),
            ("buffer_kb", Json::from(buf_kb)),
        ]));
    }
    ExpResult {
        id: "ablation_round_size",
        table,
        json: Json::Arr(json_rows),
    }
}

/// FPIC with and without the duplicate-fetch bandwidth bound.
pub fn fpic_bandwidth(opts: ExpOptions) -> ExpResult {
    let mut table = Table::new(
        "Ablation — FPIC input-bandwidth modeling (duplicate per-node fetches)",
        &["dataset", "cycles (BW-bound)", "cycles (infinite BW)", "ratio", "fill-bound tiles %"],
    );
    let mut json_rows = Vec::new();
    for (name, m) in [
        ("dense 14%", uniform(opts.scaled(512), 4096, 0.14, opts.seed)),
        ("sparse 0.5%", uniform(opts.scaled(2048), 2048, 0.005, opts.seed)),
    ] {
        let (bw, _) = fpic_simulate(&m, &m, FpicConfig { units: 8, ..FpicConfig::default() });
        let (inf, _) = fpic_simulate(
            &m,
            &m,
            FpicConfig { units: 8, model_bandwidth: false, ..FpicConfig::default() },
        );
        table.row(vec![
            name.to_string(),
            human(bw.cycles),
            human(inf.cycles),
            sig(bw.cycles as f64 / inf.cycles.max(1) as f64),
            sig(100.0 * bw.fill_bound_tiles as f64 / bw.tiles.max(1) as f64),
        ]);
        json_rows.push(obj([
            ("dataset", Json::from(name)),
            ("bw_cycles", Json::from(bw.cycles)),
            ("inf_cycles", Json::from(inf.cycles)),
        ]));
    }
    ExpResult {
        id: "ablation_fpic_bandwidth",
        table,
        json: Json::Arr(json_rows),
    }
}

/// Linear vs binary CRS row search under the cache hierarchy (footnote 2).
pub fn search_policy(opts: ExpOptions) -> ExpResult {
    let m = uniform(opts.scaled(300), 8192, 0.08, opts.seed);
    let mut rng = crate::util::rng::Rng::new(opts.seed + 2);
    let probes: Vec<(usize, usize)> = (0..opts.scaled(150_000))
        .map(|_| (rng.usize_below(m.rows()), rng.usize_below(m.cols())))
        .collect();

    let run = |binary: bool| {
        let mut h = Hierarchy::new(HierarchyConfig::default());
        for &(i, j) in &probes {
            if binary {
                m.locate_binary(i, j, &mut h);
            } else {
                m.locate(i, j, &mut h);
            }
        }
        h.stats()
    };
    let lin = run(false);
    let bin = run(true);

    let mut table = Table::new(
        "Ablation — CRS row search policy under the Table-III hierarchy (paper footnote 2)",
        &["policy", "L1 accesses", "L1 hit %", "mem cycles", "cycles/probe"],
    );
    for (name, s) in [("linear", lin), ("binary", bin)] {
        table.row(vec![
            name.to_string(),
            human(s.l1_accesses),
            format!("{:.1}", s.l1_hit_rate() * 100.0),
            human(s.mem_cycles),
            sig(s.mem_cycles as f64 / probes.len() as f64),
        ]);
    }
    let json = obj([
        ("linear_mem_cycles", Json::from(lin.mem_cycles)),
        ("binary_mem_cycles", Json::from(bin.mem_cycles)),
        ("linear_hit_rate", Json::Num(lin.l1_hit_rate())),
        ("binary_hit_rate", Json::Num(bin.l1_hit_rate())),
    ]);
    ExpResult {
        id: "ablation_search_policy",
        table,
        json,
    }
}

/// Column-placement ablation at equal density.
///
/// Fixed-size square workload (scale-invariant on purpose: the locality
/// effect needs the band to be sparse *per round*, which tiny scaled
/// variants wouldn't be — see the in-band density note below).
pub fn column_dist(opts: ExpOptions) -> ExpResult {
    // 6 nz per row in a 512-wide band = 0.37 nz per 32-round per stream:
    // sparse enough that the sync mesh's round fast-forward pays off.
    let base = DatasetSpec {
        name: "dist-ablation",
        rows: 2048,
        cols: 2048,
        stated_density: 0.003,
        nnz_row: NnzRow { min: 1, avg: 6.0, max: 24 },
        dist: ColumnDist::Uniform,
    };
    let mut table = Table::new(
        "Ablation — column placement at equal density (sync mesh vs FPIC)",
        &["distribution", "sync cycles", "FPIC(sameBW) cycles", "speedup"],
    );
    let mut json_rows = Vec::new();
    for (name, dist) in [
        ("uniform", ColumnDist::Uniform),
        ("zipf(1.1)", ColumnDist::Zipf(1.1)),
        ("banded(512)", ColumnDist::Banded(512)),
    ] {
        let spec = DatasetSpec { dist, ..base };
        let m = generate(&spec, opts.seed);
        let sync = cycle_model(&m, &m, SyncMeshConfig::default()).cycles;
        let (fp, _) = fpic_simulate(&m, &m, FpicConfig { units: 8, ..FpicConfig::default() });
        table.row(vec![
            name.to_string(),
            human(sync),
            human(fp.cycles),
            sig(fp.cycles as f64 / sync.max(1) as f64),
        ]);
        json_rows.push(obj([
            ("dist", Json::from(name)),
            ("sync_cycles", Json::from(sync)),
            ("fpic_cycles", Json::from(fp.cycles)),
        ]));
    }
    ExpResult {
        id: "ablation_column_dist",
        table,
        json: Json::Arr(json_rows),
    }
}

pub fn run_all(opts: ExpOptions) -> Vec<ExpResult> {
    vec![
        incrs_params(opts),
        round_size(opts),
        fpic_bandwidth(opts),
        search_policy(opts),
        column_dist(opts),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ExpOptions {
        ExpOptions { seed: 3, scale: 0.1 }
    }

    #[test]
    fn incrs_param_monotonicity() {
        let r = incrs_params(small());
        // smaller b -> smaller measured MA (col 3), larger overhead (col 5)
        let rows = &r.table.rows;
        assert!(rows.len() >= 4);
        let ma: Vec<f64> = rows.iter().map(|r| r[3].parse().unwrap()).collect();
        let ov: Vec<f64> = rows.iter().map(|r| r[5].parse().unwrap()).collect();
        assert!(ma.first().unwrap() >= ma.last().unwrap());
        assert!(ov.first().unwrap() <= ov.last().unwrap());
    }

    #[test]
    fn binary_search_saves_time_but_not_hit_rate() {
        let r = search_policy(small());
        let lin_hit = r.json.at(&["linear_hit_rate"]).unwrap().as_f64().unwrap();
        let bin_hit = r.json.at(&["binary_hit_rate"]).unwrap().as_f64().unwrap();
        // the paper's footnote: binary search has the worse hit rate...
        assert!(bin_hit < lin_hit, "binary {bin_hit} !< linear {lin_hit}");
    }

    #[test]
    fn banded_data_maximizes_sync_advantage() {
        let r = column_dist(small());
        let arr = r.json.as_arr().unwrap();
        let get = |name: &str| {
            arr.iter()
                .find(|x| x.at(&["dist"]).unwrap().as_str().unwrap() == name)
                .map(|x| {
                    x.at(&["fpic_cycles"]).unwrap().as_f64().unwrap()
                        / x.at(&["sync_cycles"]).unwrap().as_f64().unwrap()
                })
                .unwrap()
        };
        assert!(get("banded(512)") > get("uniform"));
    }

    #[test]
    fn round_size_renders() {
        let r = round_size(small());
        assert_eq!(r.table.rows.len(), 5);
    }
}
