//! `selection` experiment: the learned-selection loop, offline — measure
//! every registered kernel on heterogeneous workloads, least-squares-fit
//! each kernel's cost constant from the measurements (`engine::learn`),
//! then compare what static cost-hint ranking and the fitted model pick
//! for the same jobs.
//!
//! This is the eval-side twin of the serving loop: the server fits from
//! `Metrics::kernel_log` every `LearnConfig::refit_every` jobs, while this
//! driver fits from a deliberate sweep so the per-kernel scale constants
//! (µs per raw cost unit) are visible in one table run.

use std::time::Instant;

use super::report::{ExpOptions, ExpResult};
use crate::datasets::synth::uniform;
use crate::engine::{Algorithm, CostModel, FittedModel, Registry, Sample, SpmmKernel};
use crate::formats::Csr;
use crate::spmm::plan::Geometry;
use crate::util::json::{obj, Json};
use crate::util::tables::{sig, Table};

/// Usable observations a kernel needs before its constant is trusted —
/// lower than the serving default because the sweep below is deliberate
/// (every kernel sees every workload) rather than selection-skewed.
const MIN_SAMPLES: usize = 4;
const REPS: usize = 3;

fn workloads(opts: ExpOptions) -> Vec<(&'static str, Csr, Csr)> {
    let n = opts.scaled(512);
    let s = opts.seed;
    vec![
        ("square 2%", uniform(n, n, 0.02, s), uniform(n, n, 0.02, s + 1)),
        (
            "tall-skinny 5%",
            uniform(2 * n, n / 2, 0.05, s + 2),
            uniform(n / 2, n, 0.05, s + 3),
        ),
        (
            "hyper-sparse 0.3%",
            uniform(n, n, 0.003, s + 4),
            uniform(n, n, 0.003, s + 5),
        ),
    ]
}

pub fn run(opts: ExpOptions) -> ExpResult {
    let reg = Registry::with_default_kernels(Geometry::default(), 1);
    let work = workloads(opts);

    // calibration sweep: every non-oracle kernel on every workload, REPS
    // times, logging exactly the score selection would rank (CSR-native
    // operands, so ingest cost matches the selection-time charge)
    let mut samples = Vec::new();
    for (_, a, b) in &work {
        for _ in 0..REPS {
            for k in reg.kernels() {
                if k.algorithm() == Algorithm::Dense {
                    continue;
                }
                let predicted = k.cost_hint(a, b).total() + k.ingest_cost(b, None);
                let t = Instant::now();
                if k.run(a, b).is_err() {
                    continue;
                }
                samples.push(Sample {
                    format: k.format(),
                    algorithm: k.algorithm(),
                    predicted,
                    wall_us: t.elapsed().as_micros() as u64,
                });
            }
        }
    }
    let fit = FittedModel::fit(&samples, MIN_SAMPLES);

    // fitted registry: same kernels, selection now consults the model
    let mut fitted_reg = Registry::with_default_kernels(Geometry::default(), 1);
    let model = CostModel::new(0.0); // offline: no incumbent to protect
    model.publish(fit.clone());
    fitted_reg.set_cost_model(model);

    let mut table = Table::new(
        &format!(
            "Selection — static cost hints vs fitted model ({} samples, {} kernels calibrated, \
             seed {})",
            samples.len(),
            fit.len(),
            opts.seed
        ),
        &["workload", "static pick", "static ms", "fitted pick", "fitted ms"],
    );
    let mut rows = Vec::new();
    let timed = |k: &std::sync::Arc<dyn SpmmKernel>, a: &Csr, b: &Csr| {
        let t = Instant::now();
        let _ = k.run(a, b);
        t.elapsed().as_secs_f64() * 1e3
    };
    for (name, a, b) in &work {
        let (static_k, fitted_k) = match (reg.select(a, b), fitted_reg.select(a, b)) {
            (Some(s), Some(f)) => (s, f),
            _ => continue, // default registry is never empty
        };
        let static_ms = timed(&static_k, a, b);
        let fitted_ms = timed(&fitted_k, a, b);
        table.row(vec![
            (*name).into(),
            static_k.name().into(),
            sig(static_ms),
            fitted_k.name().into(),
            sig(fitted_ms),
        ]);
        rows.push(obj([
            ("workload", Json::from(*name)),
            ("static_kernel", Json::from(static_k.name())),
            ("static_ms", Json::from(static_ms)),
            ("fitted_kernel", Json::from(fitted_k.name())),
            ("fitted_ms", Json::from(fitted_ms)),
        ]));
    }

    let calibration: Vec<Json> = fit
        .entries()
        .map(|(&(f, alg), c)| {
            obj([
                ("format", Json::from(f.name())),
                ("algorithm", Json::from(alg.name())),
                ("scale_us_per_unit", Json::from(c.scale)),
                ("samples", Json::from(c.samples)),
                ("mean_abs_err_us", Json::from(c.mean_abs_err_us)),
            ])
        })
        .collect();

    ExpResult {
        id: "selection",
        table,
        json: obj([
            ("seed", Json::from(opts.seed)),
            ("samples", Json::from(samples.len())),
            ("min_samples", Json::from(MIN_SAMPLES)),
            ("calibration", Json::Arr(calibration)),
            ("workloads", Json::Arr(rows)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_experiment_runs_scaled_down() {
        let r = run(ExpOptions { seed: 11, scale: 0.15 });
        assert_eq!(r.id, "selection");
        // one comparison row per workload, each with a real kernel name on
        // both sides (fitted falls back to the static pick when the sweep's
        // walls are below timer resolution — still a valid pick)
        assert_eq!(r.table.rows.len(), 3, "{:?}", r.table.rows);
        for row in &r.table.rows {
            assert!(!row[1].is_empty() && !row[3].is_empty(), "{row:?}");
        }
        let runs = r.json.at(&["workloads"]).unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 3);
    }
}
