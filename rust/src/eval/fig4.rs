//! Fig 4: proposed mesh vs FPIC at equal input bandwidth (Eq 1, Fig 4a)
//! and at equal total buffer size (Eq 2, Fig 4b), sweeping the mesh size,
//! on a high-density and a low-density dataset (paper: A×Aᵀ).

use super::report::{ExpOptions, ExpResult};
use crate::arch::fpic::{simulate as fpic_simulate, Fidelity, FpicConfig};
use crate::arch::model::{fpic_units_same_bandwidth, fpic_units_same_buffer};
use crate::arch::sync_mesh::{cycle_model, SyncMeshConfig};
use crate::datasets::spec::by_name;
use crate::datasets::synth::generate;
use crate::formats::csr::Csr;
use crate::util::json::{obj, Json};
use crate::util::tables::{sig, Table};

/// Which fairness constraint fixes the FPIC unit count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Constraint {
    SameBandwidth,
    SameBuffer,
}

pub struct Fig4Point {
    pub dataset: &'static str,
    pub mesh: usize,
    pub fpic_units: usize,
    pub sync_cycles: u64,
    pub fpic_cycles: u64,
}

impl Fig4Point {
    /// The plotted quantity: FPIC latency / sync-mesh latency.
    pub fn speedup(&self) -> f64 {
        self.fpic_cycles as f64 / self.sync_cycles.max(1) as f64
    }
}

/// A×Aᵀ on one dataset across mesh sizes under one constraint.
pub fn sweep(
    a: &Csr,
    dataset: &'static str,
    meshes: &[usize],
    constraint: Constraint,
    round: usize,
) -> Vec<Fig4Point> {
    meshes
        .iter()
        .map(|&mesh| {
            let sync = cycle_model(a, a, SyncMeshConfig { mesh, round });
            let units = match constraint {
                Constraint::SameBandwidth => fpic_units_same_bandwidth(mesh),
                Constraint::SameBuffer => fpic_units_same_buffer(mesh),
            };
            let (fp, _) = fpic_simulate(
                a,
                a,
                FpicConfig {
                    units,
                    unit_dim: 8,
                    fidelity: Fidelity::MaxNode,
                    model_bandwidth: true,
                },
            );
            Fig4Point {
                dataset,
                mesh,
                fpic_units: units,
                sync_cycles: sync.cycles,
                fpic_cycles: fp.cycles,
            }
        })
        .collect()
}

/// Paper setup: one high-density (Amazon, 14%) and one low-density (Sch,
/// 0.057%) dataset. `scale` shrinks the matrices for quick runs.
pub fn run_constraint(opts: ExpOptions, constraint: Constraint) -> Vec<Fig4Point> {
    let meshes = [16usize, 32, 64, 128];
    let mut out = Vec::new();
    for name in ["amazon", "sch"] {
        let mut spec = by_name(name).expect("registry");
        spec.rows = opts.scaled(spec.rows);
        // keep the column space (density structure) intact, like the paper's
        // row-only resizing
        let a = generate(&spec, opts.seed);
        out.extend(sweep(&a, name, &meshes, constraint, 32));
    }
    out
}

fn result_for(id: &'static str, title: &str, points: Vec<Fig4Point>) -> ExpResult {
    let mut table = Table::new(
        title,
        &["dataset", "N_synch", "k_FPIC", "sync cycles", "FPIC cycles", "speedup (FPIC/sync)"],
    );
    let mut json_rows = Vec::new();
    for p in &points {
        table.row(vec![
            p.dataset.to_string(),
            p.mesh.to_string(),
            p.fpic_units.to_string(),
            p.sync_cycles.to_string(),
            p.fpic_cycles.to_string(),
            sig(p.speedup()),
        ]);
        json_rows.push(obj([
            ("dataset", Json::from(p.dataset)),
            ("mesh", Json::from(p.mesh)),
            ("fpic_units", Json::from(p.fpic_units)),
            ("sync_cycles", Json::from(p.sync_cycles)),
            ("fpic_cycles", Json::from(p.fpic_cycles)),
            ("speedup", Json::Num(p.speedup())),
        ]));
    }
    ExpResult {
        id,
        table,
        json: Json::Arr(json_rows),
    }
}

pub fn run_a(opts: ExpOptions) -> ExpResult {
    result_for(
        "fig4a",
        "Fig 4a — same input bandwidth (paper: sync 2.5-20x faster, high D; 4-58x, low D)",
        run_constraint(opts, Constraint::SameBandwidth),
    )
}

pub fn run_b(opts: ExpOptions) -> ExpResult {
    result_for(
        "fig4b",
        "Fig 4b — same overall buffer size (paper: sync still faster at lower BW)",
        run_constraint(opts, Constraint::SameBuffer),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;

    #[test]
    fn sync_beats_fpic_at_equal_bandwidth() {
        let a = uniform(128, 1024, 0.1, 7);
        let pts = sweep(&a, "test", &[16, 32, 64], Constraint::SameBandwidth, 32);
        for p in &pts {
            assert!(
                p.speedup() > 1.0,
                "mesh {}: speedup {}",
                p.mesh,
                p.speedup()
            );
        }
    }

    #[test]
    fn banded_sparse_data_shows_large_speedup() {
        // The paper's low-density datasets are locality-structured (circuit
        // matrices); the sync mesh's round fast-forward exploits the
        // locality while FPIC's duplicate fetches cannot.
        use crate::datasets::spec::{ColumnDist, DatasetSpec, NnzRow};
        use crate::datasets::synth::generate;
        let spec = DatasetSpec {
            name: "sparse-banded",
            rows: 512,
            cols: 512,
            stated_density: 0.01,
            nnz_row: NnzRow { min: 1, avg: 5.0, max: 12 },
            dist: ColumnDist::Banded(64),
        };
        let sparse = generate(&spec, 1);
        let ss = sweep(&sparse, "s", &[32], Constraint::SameBandwidth, 32)[0].speedup();
        assert!(ss > 1.5, "banded sparse speedup {ss}");
    }

    #[test]
    fn same_buffer_constraint_gives_fpic_more_units() {
        let a = uniform(64, 128, 0.05, 2);
        let bw = sweep(&a, "t", &[64], Constraint::SameBandwidth, 32)[0].fpic_units;
        let buf = sweep(&a, "t", &[64], Constraint::SameBuffer, 32)[0].fpic_units;
        assert!(buf > bw, "{buf} !> {bw}"); // 32 vs 8 at mesh 64
    }
}
