//! Fig 5 + Tables IV/V: end latency of the four fixed design points on the
//! eight A×Aᵀ datasets, normalized to the proposed synchronized mesh.

use super::report::{ExpOptions, ExpResult};
use crate::arch::conventional::{cycles as conv_cycles, ConvMmConfig};
use crate::arch::fpic::{simulate as fpic_simulate, Fidelity, FpicConfig};
use crate::arch::model::{self, DesignPoint};
use crate::arch::sync_mesh::{cycle_model, SyncMeshConfig};
use crate::datasets::spec::TABLE4;
use crate::datasets::synth::generate;
use crate::formats::traits::SparseMatrix;
use crate::util::json::{obj, Json};
use crate::util::tables::{human, sig, Table};

pub struct Fig5Row {
    pub dataset: &'static str,
    pub density: f64,
    pub sync_cycles: u64,
    pub fpic_bw_cycles: u64,
    pub fpic_buf_cycles: u64,
    pub conv_cycles: u64,
}

impl Fig5Row {
    pub fn norm(&self, cycles: u64) -> f64 {
        cycles as f64 / self.sync_cycles.max(1) as f64
    }
}

/// Run all four Table-V design points on one dataset (A×Aᵀ).
pub fn run_dataset(
    a: &crate::formats::csr::Csr,
    name: &'static str,
    n_synch: usize,
    round: usize,
) -> Fig5Row {
    let sync = cycle_model(a, a, SyncMeshConfig { mesh: n_synch, round });
    let (fp_bw, _) = fpic_simulate(
        a,
        a,
        FpicConfig {
            units: model::fpic_units_same_bandwidth(n_synch),
            unit_dim: 8,
            fidelity: Fidelity::MaxNode,
            model_bandwidth: true,
        },
    );
    let (fp_buf, _) = fpic_simulate(
        a,
        a,
        FpicConfig {
            units: model::fpic_units_same_buffer(n_synch),
            unit_dim: 8,
            fidelity: Fidelity::MaxNode,
            model_bandwidth: true,
        },
    );
    let conv = conv_cycles(
        a.rows(),
        a.rows(), // C = A×Aᵀ is M×M
        a.cols(),
        ConvMmConfig {
            mesh: model::conv_mesh_same_bandwidth(n_synch),
        },
    );
    Fig5Row {
        dataset: name,
        density: a.density(),
        sync_cycles: sync.cycles,
        fpic_bw_cycles: fp_bw.cycles,
        fpic_buf_cycles: fp_buf.cycles,
        conv_cycles: conv.cycles,
    }
}

pub fn run_rows(opts: ExpOptions) -> Vec<Fig5Row> {
    TABLE4
        .iter()
        .map(|spec| {
            let mut s = *spec;
            s.rows = opts.scaled(s.rows);
            if s.rows < spec.rows {
                // square datasets shrink both ways (A×Aᵀ needs cols = K
                // intact only for rectangular bag-of-words shapes)
                if spec.rows == spec.cols {
                    s.cols = s.rows;
                    s.nnz_row = crate::datasets::spec::NnzRow {
                        min: spec.nnz_row.min.min(s.cols),
                        avg: (spec.nnz_row.avg * s.cols as f64 / spec.cols as f64).max(1.0),
                        max: spec.nnz_row.max.min(s.cols),
                    };
                }
            }
            let a = generate(&s, opts.seed);
            run_dataset(&a, spec.name, 64, 32)
        })
        .collect()
}

pub fn run(opts: ExpOptions) -> ExpResult {
    let rows = run_rows(opts);
    let mut table = Table::new(
        "Fig 5 — latency normalized to the proposed sync mesh (Table V designs; \
         paper: conv 1.5-39x, FPIC 2-30x slower)",
        &[
            "dataset", "D", "sync cycles", "FPIC-sameBW x", "FPIC-sameBuf x", "conv MM x",
        ],
    );
    let mut json_rows = Vec::new();
    for r in &rows {
        table.row(vec![
            r.dataset.to_string(),
            format!("{:.3}%", r.density * 100.0),
            human(r.sync_cycles),
            sig(r.norm(r.fpic_bw_cycles)),
            sig(r.norm(r.fpic_buf_cycles)),
            sig(r.norm(r.conv_cycles)),
        ]);
        json_rows.push(obj([
            ("dataset", Json::from(r.dataset)),
            ("density", Json::Num(r.density)),
            ("sync_cycles", Json::from(r.sync_cycles)),
            ("fpic_bw_norm", Json::Num(r.norm(r.fpic_bw_cycles))),
            ("fpic_buf_norm", Json::Num(r.norm(r.fpic_buf_cycles))),
            ("conv_norm", Json::Num(r.norm(r.conv_cycles))),
        ]));
    }
    ExpResult {
        id: "fig5",
        table,
        json: Json::Arr(json_rows),
    }
}

/// Table IV: the architecture datasets as generated (dims, density, nnz).
pub fn run_table4(opts: ExpOptions) -> ExpResult {
    let mut table = Table::new(
        "Table IV — architecture evaluation datasets (synthetic, spec-matched)",
        &["dataset", "dim", "D stated", "D generated", "nnz", "nnz/row (min,avg,max)"],
    );
    let mut json_rows = Vec::new();
    for spec in &TABLE4 {
        let a = generate(spec, opts.seed);
        let (mn, avg, mx) = a.nnz_row_stats();
        table.row(vec![
            spec.name.to_string(),
            format!("{}x{}", spec.rows, spec.cols),
            format!("{:.3}%", spec.stated_density * 100.0),
            format!("{:.3}%", a.density() * 100.0),
            human(a.nnz() as u64),
            format!("({mn}, {avg:.0}, {mx})"),
        ]);
        json_rows.push(obj([
            ("dataset", Json::from(spec.name)),
            ("density", Json::Num(a.density())),
            ("nnz", Json::from(a.nnz())),
        ]));
    }
    ExpResult {
        id: "table4",
        table,
        json: Json::Arr(json_rows),
    }
}

/// Table V: the design points' resource accounting.
pub fn run_table5() -> ExpResult {
    let points = model::table5(64, 32);
    let mut table = Table::new(
        "Table V — SpMM design parameters",
        &["design", "#units, NxN", "BW (kb/cycle)", "#MACs", "buffer (kB)"],
    );
    let mut json_rows = Vec::new();
    for p in &points {
        table.row(vec![
            p.name.to_string(),
            format!("{}, {}x{}", p.units, p.mesh, p.mesh),
            sig(p.bw_bits_per_cycle as f64 / 1024.0),
            p.macs.to_string(),
            (p.buffer_bytes / 1024).to_string(),
        ]);
        json_rows.push(design_json(p));
    }
    ExpResult {
        id: "table5",
        table,
        json: Json::Arr(json_rows),
    }
}

fn design_json(p: &DesignPoint) -> Json {
    obj([
        ("name", Json::from(p.name)),
        ("units", Json::from(p.units)),
        ("mesh", Json::from(p.mesh)),
        ("bw_bits_per_cycle", Json::from(p.bw_bits_per_cycle)),
        ("macs", Json::from(p.macs)),
        ("buffer_bytes", Json::from(p.buffer_bytes)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;

    #[test]
    fn design_points_order_as_in_paper() {
        // mid density: sync fastest; conv competitive; FPIC-sameBW slowest
        let a = uniform(256, 256, 0.01, 11);
        let r = run_dataset(&a, "t", 64, 32);
        assert!(r.norm(r.fpic_bw_cycles) > 1.0, "fpic bw {}", r.norm(r.fpic_bw_cycles));
        assert!(
            r.fpic_bw_cycles > r.fpic_buf_cycles,
            "more units must be faster"
        );
    }

    #[test]
    fn conv_advantage_shrinks_with_density() {
        let dense = uniform(192, 192, 0.14, 3);
        let sparse = uniform(192, 192, 0.003, 3);
        let rd = run_dataset(&dense, "d", 64, 32);
        let rs = run_dataset(&sparse, "s", 64, 32);
        // conv MM looks worse (normalized) as density falls
        assert!(
            rs.norm(rs.conv_cycles) > rd.norm(rd.conv_cycles),
            "sparse {} !> dense {}",
            rs.norm(rs.conv_cycles),
            rd.norm(rd.conv_cycles)
        );
    }

    #[test]
    fn table5_renders() {
        let r = run_table5();
        assert_eq!(r.table.rows.len(), 4);
    }
}
