//! gem5-substitute memory-hierarchy simulator (paper Table III / Fig 3).
//!
//! Trace-driven, in-order, two-level (L1 32 KiB 2-way, L2 1 MiB 8-way, both
//! LRU with 64 B blocks) with an L1 stride prefetcher of degree 4. The
//! formats' `locate` calls feed it the exact address streams their array
//! layouts produce, so CRS's long sequential scans and InCRS's short jumpy
//! probes hit the hierarchy the same way they would in the paper's gem5
//! runs (DESIGN.md §2 explains why this substitution preserves Fig 3).

pub mod cache;
pub mod config;
pub mod hierarchy;
pub mod prefetch;
pub mod runner;
pub mod stats;
pub mod trace;

pub use cache::Cache;
pub use config::{CacheConfig, HierarchyConfig};
pub use hierarchy::Hierarchy;
pub use runner::{compare, run_crs, run_incrs, CacheRun, Comparison};
pub use stats::HierarchyStats;
pub use trace::TraceSink;
