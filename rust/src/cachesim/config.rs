//! gem5 simulation parameters (paper Table III), as data.

/// One cache level's geometry and hit latency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub ways: usize,
    pub block_bytes: usize,
    pub hit_latency: u64,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        self.size_bytes / (self.ways * self.block_bytes)
    }
}

/// Full hierarchy configuration (paper Table III values by default).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HierarchyConfig {
    pub l1: CacheConfig,
    pub l2: CacheConfig,
    /// Memory (beyond-L2) latency in cycles @ 1 GHz.
    pub mem_latency: u64,
    /// Stride prefetcher degree (0 disables prefetching).
    pub prefetch_degree: usize,
}

impl Default for HierarchyConfig {
    /// Paper Table III: L1 32 KiB 2-way LRU (hit 2), L2 1 MiB 8-way LRU
    /// (hit 20), 64 B blocks, stride prefetching with degree 4. Memory
    /// latency is not stated in the paper; 100 cycles @ 1 GHz is gem5's
    /// typical DDR3 round-trip and is an explicit knob here.
    fn default() -> Self {
        HierarchyConfig {
            l1: CacheConfig {
                size_bytes: 32 * 1024,
                ways: 2,
                block_bytes: 64,
                hit_latency: 2,
            },
            l2: CacheConfig {
                size_bytes: 1024 * 1024,
                ways: 8,
                block_bytes: 64,
                hit_latency: 20,
            },
            mem_latency: 100,
            prefetch_degree: 4,
        }
    }
}

impl HierarchyConfig {
    pub fn no_prefetch(mut self) -> Self {
        self.prefetch_degree = 0;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_geometry() {
        let c = HierarchyConfig::default();
        assert_eq!(c.l1.sets(), 256); // 32KiB / (2 * 64B)
        assert_eq!(c.l2.sets(), 2048); // 1MiB / (8 * 64B)
        assert_eq!(c.l1.hit_latency, 2);
        assert_eq!(c.l2.hit_latency, 20);
        assert_eq!(c.prefetch_degree, 4);
    }
}
