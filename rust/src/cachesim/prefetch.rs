//! Stride prefetcher (gem5's `StridePrefetcher`, paper Table III: degree 4).
//!
//! gem5 trains stride streams per PC at *cache-line* granularity. Our traces
//! carry no PCs, so the access [`Site`](crate::formats::traits::Site) (which
//! array / code location touched the word) is the PC proxy — one table entry
//! per site, which matches how the format code's load sites map to
//! instructions.
//!
//! Line granularity matters for both fidelity and simulator speed: word-level
//! sequential scans (CRS's inner loop) touch the same line many times; the
//! prefetcher only observes/issues when the demand stream moves to a new
//! line, so a 16-words-per-line scan trains one stride event per line, not
//! sixteen.

use crate::formats::traits::{Site, NUM_SITES};

#[derive(Clone, Copy, Debug, Default)]
struct StrideEntry {
    /// last demanded line address (addr >> block_bits); 0 = untrained
    last_line: u64,
    /// stride in lines
    stride: i64,
    confidence: u8,
}

#[derive(Clone, Debug)]
pub struct StridePrefetcher {
    table: [StrideEntry; NUM_SITES],
    degree: usize,
    block_bits: u32,
    /// prefetch candidates issued over the run (stat).
    pub issued: u64,
}

/// Confidence threshold before prefetches are issued (gem5 default: 2
/// consecutive confirmations).
const THRESHOLD: u8 = 2;

impl StridePrefetcher {
    pub fn new(degree: usize) -> StridePrefetcher {
        Self::with_block_bits(degree, 6) // 64 B lines
    }

    pub fn with_block_bits(degree: usize, block_bits: u32) -> StridePrefetcher {
        StridePrefetcher {
            table: [StrideEntry::default(); NUM_SITES],
            degree,
            block_bits,
            issued: 0,
        }
    }

    /// Observe a demand access; emits up to `degree` *line* prefetch
    /// candidates via `emit` (no allocation on the hot path). Same-line
    /// repeats are ignored entirely — the common case in scans, so this
    /// early-out carries the simulator's throughput.
    #[inline]
    pub fn train(&mut self, addr: u64, site: Site, mut emit: impl FnMut(u64)) {
        if self.degree == 0 {
            return;
        }
        let line = addr >> self.block_bits;
        let e = &mut self.table[site as usize];
        if line == e.last_line {
            return; // same line: nothing new to learn or fetch
        }
        let stride = line as i64 - e.last_line as i64;
        if e.last_line != 0 && stride == e.stride {
            if e.confidence < u8::MAX {
                e.confidence += 1;
            }
        } else {
            e.stride = stride;
            e.confidence = 0;
        }
        e.last_line = line;
        if e.confidence >= THRESHOLD {
            let mut next = line;
            for _ in 0..self.degree {
                next = (next as i64 + e.stride) as u64;
                self.issued += 1;
                emit(next << self.block_bits);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_line_stride_triggers_prefetch() {
        let mut p = StridePrefetcher::new(4);
        let mut fetched = Vec::new();
        for i in 0..6u64 {
            p.train(0x1000 + i * 64, Site::Idx, |a| fetched.push(a));
        }
        assert!(!fetched.is_empty());
        // candidates continue the +64 line stride, line-aligned
        assert!(fetched.iter().all(|a| a % 64 == 0));
        assert!(fetched.windows(2).any(|w| w[1] == w[0] + 64));
    }

    #[test]
    fn word_scans_train_at_line_granularity() {
        // 32 word accesses over 2 lines: only the line transition trains
        let mut p = StridePrefetcher::new(4);
        let mut n = 0;
        for i in 0..32u64 {
            p.train(0x2000 + i * 4, Site::Idx, |_| n += 1);
        }
        // 1 line transition: not enough confidence for prefetching yet
        assert_eq!(n, 0);
        // keep scanning: by the 4th line the +1 stride is confident
        for i in 32..160u64 {
            p.train(0x2000 + i * 4, Site::Idx, |_| n += 1);
        }
        assert!(n > 0, "sequential scan must eventually prefetch");
    }

    #[test]
    fn random_addresses_stay_quiet() {
        let mut p = StridePrefetcher::new(4);
        let mut rng = crate::util::rng::Rng::new(1);
        let mut n = 0;
        for _ in 0..1000 {
            p.train(rng.below(1 << 30), Site::Idx, |_| n += 1);
        }
        assert!(n < 40, "spurious prefetches: {n}");
    }

    #[test]
    fn sites_train_independently() {
        let mut p = StridePrefetcher::new(2);
        let mut n_idx = 0;
        let mut n_val = 0;
        for i in 0..10u64 {
            p.train(0x10000 + i * 64, Site::Idx, |_| n_idx += 1);
            p.train(0x90000 + i * 64, Site::Val, |_| n_val += 1);
        }
        assert!(n_idx > 0 && n_val > 0);
    }

    #[test]
    fn degree_zero_disables() {
        let mut p = StridePrefetcher::new(0);
        let mut n = 0;
        for i in 0..10u64 {
            p.train(i * 64, Site::Idx, |_| n += 1);
        }
        assert_eq!(n, 0);
    }
}
