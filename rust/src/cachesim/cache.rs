//! One set-associative LRU cache level.
//!
//! This sits on the Fig-3 hot path (billions of simulated accesses), so the
//! implementation is deliberately flat: one tag array and one LRU-stamp
//! array indexed by `set * ways + way`, no per-set structures, no hashing,
//! no allocation after construction.

use super::config::CacheConfig;

const INVALID: u64 = u64::MAX;

#[derive(Clone, Debug)]
pub struct Cache {
    block_bits: u32,
    set_mask: u64,
    ways: usize,
    /// tag per line, INVALID when empty; index = set*ways + way.
    tags: Vec<u64>,
    /// LRU stamps (monotone counter values); larger = more recent.
    stamps: Vec<u64>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    /// Lines installed by prefetch (subset of misses' fills).
    pub prefetch_fills: u64,
    /// Prefetched lines that later saw a demand hit.
    pub prefetch_useful: u64,
    /// bit per line: was this line installed by a prefetch and not yet used
    prefetched: Vec<bool>,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Cache {
        let sets = cfg.sets();
        assert!(sets.is_power_of_two(), "sets must be a power of two: {sets}");
        assert!(cfg.block_bytes.is_power_of_two());
        Cache {
            block_bits: cfg.block_bytes.trailing_zeros(),
            set_mask: sets as u64 - 1,
            ways: cfg.ways,
            tags: vec![INVALID; sets * cfg.ways],
            stamps: vec![0; sets * cfg.ways],
            tick: 0,
            hits: 0,
            misses: 0,
            prefetch_fills: 0,
            prefetch_useful: 0,
            prefetched: vec![false; sets * cfg.ways],
        }
    }

    #[inline]
    fn set_and_tag(&self, addr: u64) -> (usize, u64) {
        let line = addr >> self.block_bits;
        ((line & self.set_mask) as usize, line >> self.set_mask.count_ones())
    }

    /// Demand access. Returns true on hit. On miss the line is installed
    /// (the caller charges the next level).
    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        self.tick += 1;
        let (set, tag) = self.set_and_tag(addr);
        let base = set * self.ways;
        // hit path: scan the ways (ways is 2 or 8 — unrolled nicely)
        for w in 0..self.ways {
            if self.tags[base + w] == tag {
                self.hits += 1;
                self.stamps[base + w] = self.tick;
                if self.prefetched[base + w] {
                    self.prefetched[base + w] = false;
                    self.prefetch_useful += 1;
                }
                return true;
            }
        }
        self.misses += 1;
        self.install(base, tag, false);
        false
    }

    /// Prefetch fill: installs the line if absent; never counts as a demand
    /// hit/miss. Returns true if the line was newly installed (the caller
    /// charges next-level bandwidth for real fills only).
    #[inline]
    pub fn prefetch(&mut self, addr: u64) -> bool {
        self.tick += 1;
        let (set, tag) = self.set_and_tag(addr);
        let base = set * self.ways;
        for w in 0..self.ways {
            if self.tags[base + w] == tag {
                return false; // already resident
            }
        }
        self.prefetch_fills += 1;
        self.install(base, tag, true);
        true
    }

    /// True if the address is currently resident (no state change).
    pub fn contains(&self, addr: u64) -> bool {
        let (set, tag) = self.set_and_tag(addr);
        let base = set * self.ways;
        (0..self.ways).any(|w| self.tags[base + w] == tag)
    }

    #[inline]
    fn install(&mut self, base: usize, tag: u64, via_prefetch: bool) {
        // find LRU way (or an invalid one — stamp 0 loses to any touched way)
        let mut victim = 0usize;
        let mut best = u64::MAX;
        for w in 0..self.ways {
            let s = self.stamps[base + w];
            if self.tags[base + w] == INVALID {
                victim = w;
                break;
            }
            if s < best {
                best = s;
                victim = w;
            }
        }
        self.tags[base + victim] = tag;
        self.stamps[base + victim] = self.tick;
        self.prefetched[base + victim] = via_prefetch;
    }

    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::config::CacheConfig;

    fn tiny() -> Cache {
        // 4 sets x 2 ways x 64B = 512B
        Cache::new(CacheConfig {
            size_bytes: 512,
            ways: 2,
            block_bytes: 64,
            hit_latency: 1,
        })
    }

    #[test]
    fn cold_miss_then_hit() {
        let mut c = tiny();
        assert!(!c.access(0x1000));
        assert!(c.access(0x1000));
        assert!(c.access(0x103F)); // same 64B line
        assert!(!c.access(0x1040)); // next line
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 2);
    }

    #[test]
    fn lru_eviction_within_set() {
        let mut c = tiny();
        // set index = (addr>>6) & 3; use addresses mapping to set 0:
        let a = 0u64; // line 0, set 0
        let b = 4 * 64; // line 4, set 0
        let d = 8 * 64; // line 8, set 0
        c.access(a);
        c.access(b);
        c.access(a); // a is MRU, b is LRU
        c.access(d); // evicts b
        assert!(c.contains(a));
        assert!(!c.contains(b));
        assert!(c.contains(d));
    }

    #[test]
    fn hits_plus_misses_equals_accesses() {
        let mut c = tiny();
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..10_000 {
            c.access(rng.below(1 << 16));
        }
        assert_eq!(c.accesses(), 10_000);
    }

    #[test]
    fn prefetch_installs_without_demand_counting() {
        let mut c = tiny();
        assert!(c.prefetch(0x2000));
        assert!(!c.prefetch(0x2000)); // already resident
        assert_eq!(c.accesses(), 0);
        assert!(c.access(0x2000)); // demand hit on prefetched line
        assert_eq!(c.prefetch_useful, 1);
        assert_eq!(c.prefetch_fills, 1);
    }

    #[test]
    fn sequential_within_line_hits() {
        let mut c = tiny();
        let mut hits = 0;
        for i in 0..64u64 {
            if c.access(0x4000 + i * 4) {
                hits += 1;
            }
        }
        // 64 word accesses over 4 lines: 4 misses, 60 hits... wait: 64*4B =
        // 256B = 4 lines -> 4 misses
        assert_eq!(c.misses, 4);
        assert_eq!(hits, 60);
    }
}
