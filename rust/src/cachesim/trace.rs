//! Address-trace capture and replay.
//!
//! [`TraceSink`] records the exact (address, site) stream a format
//! traversal produces. Traces can be:
//!
//! * replayed through [`Hierarchy`](super::Hierarchy) (regression fixtures,
//!   deterministic cache experiments decoupled from format code), or
//! * exported as text for *actual gem5* (`se.py --mem-trace` style
//!   ingestion), closing the loop on the DESIGN.md §2 substitution: anyone
//!   with gem5 can validate our Table-III hierarchy against the original
//!   simulator using the very same access stream.
//!
//! Format: one record per line, `R <hex-addr> <site-id>` — trivially
//! convertible to gem5's protobuf/ASCII trace formats.

use std::io::{BufRead, Write};

use super::hierarchy::Hierarchy;
use super::stats::HierarchyStats;
use crate::formats::traits::{AccessSink, Site, NUM_SITES};

/// In-memory trace recorder (also an [`AccessSink`]).
#[derive(Default, Debug, Clone)]
pub struct TraceSink {
    pub records: Vec<(u64, Site)>,
}

impl TraceSink {
    pub fn new() -> TraceSink {
        TraceSink::default()
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Replay the trace through a hierarchy and return its stats.
    pub fn replay(&self, h: &mut Hierarchy) -> HierarchyStats {
        for &(addr, site) in &self.records {
            h.touch(addr, site);
        }
        h.stats()
    }

    /// Write the text trace format.
    pub fn export(&self, w: &mut impl Write) -> std::io::Result<()> {
        let mut buf = String::with_capacity(self.records.len() * 16);
        for &(addr, site) in &self.records {
            buf.push_str(&format!("R {addr:x} {}\n", site as u8));
            if buf.len() > 1 << 20 {
                w.write_all(buf.as_bytes())?;
                buf.clear();
            }
        }
        w.write_all(buf.as_bytes())
    }

    /// Read the text trace format back.
    pub fn import(r: impl BufRead) -> Result<TraceSink, String> {
        let mut out = TraceSink::new();
        for (n, line) in r.lines().enumerate() {
            let line = line.map_err(|e| e.to_string())?;
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let mut it = t.split_whitespace();
            let op = it.next().ok_or_else(|| format!("line {n}: empty"))?;
            if op != "R" {
                return Err(format!("line {n}: unsupported op {op:?}"));
            }
            let addr = u64::from_str_radix(
                it.next().ok_or_else(|| format!("line {n}: missing addr"))?,
                16,
            )
            .map_err(|e| format!("line {n}: {e}"))?;
            let site_id: u8 = it
                .next()
                .ok_or_else(|| format!("line {n}: missing site"))?
                .parse()
                .map_err(|e| format!("line {n}: {e}"))?;
            out.records.push((addr, site_from_id(site_id).ok_or_else(
                || format!("line {n}: bad site {site_id}"),
            )?));
        }
        Ok(out)
    }
}

impl AccessSink for TraceSink {
    #[inline]
    fn touch(&mut self, addr: u64, site: Site) {
        self.records.push((addr, site));
    }
}

fn site_from_id(id: u8) -> Option<Site> {
    use Site::*;
    [Ptr, Idx, Val, Counter, JadPtr, Entry, Aux, Dense]
        .into_iter()
        .find(|&s| s as u8 == id)
        .filter(|_| (id as usize) < NUM_SITES)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::access::column::read_columns_csr;
    use crate::cachesim::config::HierarchyConfig;
    use crate::datasets::synth::uniform;

    #[test]
    fn capture_replay_equals_direct() {
        let m = uniform(30, 512, 0.08, 3);
        // direct
        let mut h1 = Hierarchy::new(HierarchyConfig::default());
        read_columns_csr(&m, Some(64), &mut h1);
        let direct = h1.stats();
        // captured + replayed
        let mut t = TraceSink::new();
        read_columns_csr(&m, Some(64), &mut t);
        let mut h2 = Hierarchy::new(HierarchyConfig::default());
        let replayed = t.replay(&mut h2);
        assert_eq!(direct.l1_accesses, replayed.l1_accesses);
        assert_eq!(direct.l1_hits, replayed.l1_hits);
        assert_eq!(direct.mem_cycles, replayed.mem_cycles);
    }

    #[test]
    fn export_import_roundtrip() {
        let mut t = TraceSink::new();
        t.touch(0x1000, Site::Ptr);
        t.touch(0xdeadbeef, Site::Counter);
        t.touch(0x42, Site::Dense);
        let mut buf = Vec::new();
        t.export(&mut buf).unwrap();
        let back = TraceSink::import(std::io::Cursor::new(buf)).unwrap();
        assert_eq!(back.records, t.records);
    }

    #[test]
    fn import_rejects_garbage() {
        assert!(TraceSink::import(std::io::Cursor::new("W 1000 0\n")).is_err());
        assert!(TraceSink::import(std::io::Cursor::new("R zz 0\n")).is_err());
        assert!(TraceSink::import(std::io::Cursor::new("R 10 99\n")).is_err());
        // comments and blanks are fine
        let ok = TraceSink::import(std::io::Cursor::new("# hdr\n\nR 10 1\n")).unwrap();
        assert_eq!(ok.len(), 1);
    }
}
