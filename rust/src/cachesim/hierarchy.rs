//! Two-level hierarchy: L1 + L2 + memory with latency accounting and an
//! L1-side stride prefetcher. Implements [`AccessSink`] so any format's
//! `locate` can be replayed through it directly (Fig 3).

use super::cache::Cache;
use super::config::HierarchyConfig;
use super::prefetch::StridePrefetcher;
use super::stats::HierarchyStats;
use crate::formats::traits::{AccessSink, Site, NUM_SITES};

pub struct Hierarchy {
    pub cfg: HierarchyConfig,
    l1: Cache,
    l2: Cache,
    pf: StridePrefetcher,
    /// total memory time in cycles (latency-accumulated, in-order model —
    /// the paper's gem5 setup is a single in-order core)
    pub mem_cycles: u64,
    pub mem_fetches: u64,
    accesses_by_site: [u64; NUM_SITES],
    /// scratch for prefetch candidates (avoid per-access alloc)
    pf_buf: [u64; 16],
}

impl Hierarchy {
    pub fn new(cfg: HierarchyConfig) -> Hierarchy {
        assert!(cfg.prefetch_degree <= 16);
        Hierarchy {
            cfg,
            l1: Cache::new(cfg.l1),
            l2: Cache::new(cfg.l2),
            pf: StridePrefetcher::new(cfg.prefetch_degree),
            mem_cycles: 0,
            mem_fetches: 0,
            accesses_by_site: [0; NUM_SITES],
            pf_buf: [0; 16],
        }
    }

    /// One demand access; returns its latency in cycles.
    #[inline]
    pub fn demand(&mut self, addr: u64, site: Site) -> u64 {
        self.accesses_by_site[site as usize] += 1;
        let mut lat = self.cfg.l1.hit_latency;
        if !self.l1.access(addr) {
            lat += self.cfg.l2.hit_latency;
            if !self.l2.access(addr) {
                lat += self.cfg.mem_latency;
                self.mem_fetches += 1;
            }
        }
        self.mem_cycles += lat;

        // train the prefetcher on the demand stream; fills go into L1+L2
        // (gem5's L1 stride prefetcher fills into the L1).
        let mut n = 0usize;
        let buf = &mut self.pf_buf;
        self.pf.train(addr, site, |a| {
            if n < buf.len() {
                buf[n] = a;
                n += 1;
            }
        });
        for k in 0..n {
            let a = self.pf_buf[k];
            if self.l1.prefetch(a) {
                // line came from L2 or memory; model fill path without
                // charging demand latency (overlapped), but count traffic
                if !self.l2.access(a) {
                    self.mem_fetches += 1;
                }
            }
        }
        lat
    }

    pub fn stats(&self) -> HierarchyStats {
        HierarchyStats {
            l1_accesses: self.l1.accesses(),
            l1_hits: self.l1.hits,
            l1_misses: self.l1.misses,
            l2_accesses: self.l2.accesses(),
            l2_hits: self.l2.hits,
            l2_misses: self.l2.misses,
            mem_fetches: self.mem_fetches,
            mem_cycles: self.mem_cycles,
            prefetch_fills: self.l1.prefetch_fills,
            prefetch_useful: self.l1.prefetch_useful,
            accesses_by_site: self.accesses_by_site,
        }
    }
}

impl AccessSink for Hierarchy {
    #[inline]
    fn touch(&mut self, addr: u64, site: Site) {
        self.demand(addr, site);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Hierarchy {
        Hierarchy::new(HierarchyConfig::default())
    }

    #[test]
    fn latency_decomposition() {
        let mut h = small();
        // cold: L1 miss + L2 miss + memory
        assert_eq!(h.demand(0x10000, Site::Idx), 2 + 20 + 100);
        // hot: L1 hit
        assert_eq!(h.demand(0x10000, Site::Idx), 2);
        let s = h.stats();
        assert_eq!(s.l1_accesses, 2);
        assert_eq!(s.l1_hits, 1);
        assert_eq!(s.mem_cycles, 124);
    }

    #[test]
    fn l2_catches_l1_evictions() {
        let mut h = small();
        // touch 3 lines mapping to the same L1 set (L1: 256 sets, 2 ways).
        // set stride = 256*64 = 16KiB
        let a = 0x100000u64;
        let b = a + 16 * 1024;
        let c = a + 32 * 1024;
        h.demand(a, Site::Idx);
        h.demand(b, Site::Idx);
        h.demand(c, Site::Idx); // evicts a from L1 (LRU)
        let lat = h.demand(a, Site::Idx); // L1 miss, L2 hit
        assert_eq!(lat, 2 + 20);
    }

    #[test]
    fn sequential_stream_benefits_from_prefetch() {
        let run = |degree: usize| {
            let mut h = Hierarchy::new(if degree == 0 {
                HierarchyConfig::default().no_prefetch()
            } else {
                HierarchyConfig::default()
            });
            let mut cycles = 0;
            for i in 0..20_000u64 {
                cycles += h.demand(0x200000 + i * 4, Site::Idx);
            }
            cycles
        };
        let with = run(4);
        let without = run(0);
        assert!(
            with < without,
            "prefetching should help sequential: {with} !< {without}"
        );
    }

    #[test]
    fn site_accounting() {
        let mut h = small();
        h.demand(0x1000, Site::Ptr);
        h.demand(0x2000, Site::Idx);
        h.demand(0x3000, Site::Idx);
        let s = h.stats();
        assert_eq!(s.accesses_by_site[Site::Ptr as usize], 1);
        assert_eq!(s.accesses_by_site[Site::Idx as usize], 2);
    }
}
