//! Cache-hierarchy statistics: the quantities Fig 3 plots.

use crate::formats::traits::NUM_SITES;

#[derive(Clone, Copy, Debug, Default)]
pub struct HierarchyStats {
    pub l1_accesses: u64,
    pub l1_hits: u64,
    pub l1_misses: u64,
    pub l2_accesses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
    pub mem_fetches: u64,
    /// Total memory time in cycles (sum of access latencies).
    pub mem_cycles: u64,
    pub prefetch_fills: u64,
    pub prefetch_useful: u64,
    pub accesses_by_site: [u64; NUM_SITES],
}

impl HierarchyStats {
    pub fn l1_hit_rate(&self) -> f64 {
        self.l1_hits as f64 / self.l1_accesses.max(1) as f64
    }

    pub fn l2_hit_rate(&self) -> f64 {
        self.l2_hits as f64 / self.l2_accesses.max(1) as f64
    }

    /// Total run time model for the Fig-3 workload: memory time plus one
    /// issue cycle per access for the non-memory work of the in-order core
    /// (compare/branch per scanned element).
    pub fn total_cycles(&self) -> u64 {
        self.mem_cycles + self.l1_accesses
    }

    /// Invariant check used by tests and debug assertions.
    pub fn consistent(&self) -> bool {
        self.l1_hits + self.l1_misses == self.l1_accesses
            && self.l2_hits + self.l2_misses == self.l2_accesses
            // every L2 *demand* access is an L1 miss; prefetch fills add more
            && self.l2_accesses >= self.l1_misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates() {
        let s = HierarchyStats {
            l1_accesses: 10,
            l1_hits: 8,
            l1_misses: 2,
            l2_accesses: 2,
            l2_hits: 1,
            l2_misses: 1,
            mem_cycles: 150,
            ..Default::default()
        };
        assert!((s.l1_hit_rate() - 0.8).abs() < 1e-12);
        assert!(s.consistent());
        assert_eq!(s.total_cycles(), 160);
    }
}
