//! Fig-3 experiment runner: replay a column-order traversal of a dataset
//! through the gem5-parameter hierarchy, for CRS and for InCRS, and report
//! the normalized ratios the paper plots.

use super::config::HierarchyConfig;
use super::hierarchy::Hierarchy;
use super::stats::HierarchyStats;
use crate::access::column::{read_columns_csr, read_columns_incrs};
use crate::formats::csr::Csr;
use crate::formats::incrs::{InCrs, InCrsParams};

/// One format's run through the hierarchy.
#[derive(Clone, Copy, Debug)]
pub struct CacheRun {
    pub stats: HierarchyStats,
    pub cells_probed: u64,
    pub nonzeros_found: u64,
}

/// CRS-vs-InCRS comparison on one dataset (one Fig-3 dataset group).
#[derive(Clone, Copy, Debug)]
pub struct Comparison {
    pub crs: CacheRun,
    pub incrs: CacheRun,
}

impl Comparison {
    /// The four bars Fig 3 plots (CRS normalized to InCRS).
    pub fn l1_access_ratio(&self) -> f64 {
        self.crs.stats.l1_accesses as f64 / self.incrs.stats.l1_accesses.max(1) as f64
    }
    pub fn l2_access_ratio(&self) -> f64 {
        self.crs.stats.l2_accesses as f64 / self.incrs.stats.l2_accesses.max(1) as f64
    }
    pub fn mem_time_ratio(&self) -> f64 {
        self.crs.stats.mem_cycles as f64 / self.incrs.stats.mem_cycles.max(1) as f64
    }
    pub fn total_time_ratio(&self) -> f64 {
        self.crs.stats.total_cycles() as f64 / self.incrs.stats.total_cycles().max(1) as f64
    }
}

/// Run the column-order traversal of `m` through a fresh hierarchy in CRS
/// form. `col_limit` optionally truncates (paper-style resize knob).
pub fn run_crs(m: &Csr, cfg: HierarchyConfig, col_limit: Option<usize>) -> CacheRun {
    let mut h = Hierarchy::new(cfg);
    let st = read_columns_csr(m, col_limit, &mut h);
    CacheRun {
        stats: h.stats(),
        cells_probed: st.cells_probed,
        nonzeros_found: st.nonzeros_found,
    }
}

pub fn run_incrs(
    m: &Csr,
    params: InCrsParams,
    cfg: HierarchyConfig,
    col_limit: Option<usize>,
) -> Result<CacheRun, String> {
    let incrs = InCrs::from_csr_params(m, params)?;
    let mut h = Hierarchy::new(cfg);
    let st = read_columns_incrs(&incrs, col_limit, &mut h);
    Ok(CacheRun {
        stats: h.stats(),
        cells_probed: st.cells_probed,
        nonzeros_found: st.nonzeros_found,
    })
}

/// Full Fig-3 comparison for one dataset.
pub fn compare(
    m: &Csr,
    params: InCrsParams,
    cfg: HierarchyConfig,
    col_limit: Option<usize>,
) -> Result<Comparison, String> {
    let crs = run_crs(m, cfg, col_limit);
    let incrs = run_incrs(m, params, cfg, col_limit)?;
    debug_assert_eq!(crs.nonzeros_found, incrs.nonzeros_found);
    Ok(Comparison { crs, incrs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::uniform;
    use crate::formats::traits::SparseMatrix;

    #[test]
    fn incrs_wins_on_all_fig3_metrics() {
        let m = uniform(80, 2048, 0.05, 13);
        let cmp = compare(
            &m,
            InCrsParams::default(),
            HierarchyConfig::default(),
            None,
        )
        .unwrap();
        assert!(cmp.l1_access_ratio() > 3.0, "l1 {}", cmp.l1_access_ratio());
        assert!(cmp.total_time_ratio() > 1.5, "time {}", cmp.total_time_ratio());
        assert!(cmp.crs.stats.consistent());
        assert!(cmp.incrs.stats.consistent());
        assert_eq!(cmp.crs.nonzeros_found as usize, m.nnz());
    }

    #[test]
    fn ratios_grow_with_row_population() {
        // denser rows -> bigger CRS scans -> bigger InCRS advantage
        let sparse = uniform(60, 1024, 0.02, 1);
        let dense = uniform(60, 1024, 0.15, 1);
        let cfg = HierarchyConfig::default();
        let p = InCrsParams::default();
        let r_sparse = compare(&sparse, p, cfg, None).unwrap().l1_access_ratio();
        let r_dense = compare(&dense, p, cfg, None).unwrap().l1_access_ratio();
        assert!(
            r_dense > r_sparse,
            "dense rows {r_dense} should beat sparse rows {r_sparse}"
        );
    }
}
