//! # spmm-accel
//!
//! Production-grade reproduction of *"Sparse Matrix to Matrix
//! Multiplication: A Representation and Architecture for Acceleration"*
//! (Golnari & Malik, 2019) as a three-layer Rust + JAX + Pallas system.
//!
//! The paper contributes (1) **InCRS**, a CRS variant with per-section
//! counter-vectors that makes column-order access to a row-stored sparse
//! matrix cheap, and (2) a **synchronized systolic mesh** for SpMM that
//! shares operand streams along rows/columns of a comparator+MAC mesh.
//!
//! Crate layout (see DESIGN.md for the full inventory):
//!
//! * [`formats`] — all Table-I sparse formats + [`formats::InCrs`], with
//!   memory-access accounting on random access.
//! * [`datasets`] — the paper's nine datasets as deterministic synthetic
//!   matrices (+ MatrixMarket loader).
//! * [`access`] — random-access and column-order-read drivers (Tables I/II).
//! * [`cachesim`] — gem5-parameter two-level cache hierarchy + stride
//!   prefetcher driven by the formats' address streams (Fig 3).
//! * [`arch`] — cycle-accurate simulators: the proposed synchronized mesh
//!   (paper Algorithm 2), FPIC (Algorithm 1), conventional systolic MM
//!   (Figs 4/5, Table V).
//! * [`spmm`] — CPU SpMM algorithms + 32×32 blocking/planning for the
//!   accelerator dispatch path.
//! * [`runtime`] — PJRT execution of the AOT-compiled Pallas kernels.
//! * [`coordinator`] — job scheduler/router/batching server (L3).
//! * [`eval`] — drivers that regenerate every table and figure.

pub mod access;
pub mod arch;
pub mod cachesim;
pub mod coordinator;
pub mod datasets;
pub mod eval;
pub mod formats;
pub mod runtime;
pub mod spmm;
pub mod util;
