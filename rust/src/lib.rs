//! # spmm-accel
//!
//! Production-grade reproduction of *"Sparse Matrix to Matrix
//! Multiplication: A Representation and Architecture for Acceleration"*
//! (Golnari & Malik, 2019) as a three-layer Rust + JAX + Pallas system.
//!
//! The paper contributes (1) **InCRS**, a CRS variant with per-section
//! counter-vectors that makes column-order access to a row-stored sparse
//! matrix cheap, and (2) a **synchronized systolic mesh** for SpMM that
//! shares operand streams along rows/columns of a comparator+MAC mesh.
//!
//! ## Execution model
//!
//! All numeric SpMM execution flows through one dispatch layer, the
//! [`engine`] module: a [`engine::SpmmKernel`] trait (prepare / execute /
//! cost-hint) and a [`engine::Registry`] keyed by `(FormatKind,
//! Algorithm)`. The CPU algorithms in [`spmm`], the multi-threaded tiled
//! executor ([`engine::tiled`]), and the accelerator plan path
//! ([`runtime`], PJRT or its CPU twin) are all registered kernels; the
//! [`coordinator`] server, the CLI, the eval drivers, and the benches
//! resolve them through the registry. Failures are typed
//! ([`engine::EngineError`]) end to end. Adding a backend = implementing
//! the trait + one `register` call (see [`engine`] docs).
//!
//! ```ignore
//! let reg = Registry::with_default_kernels(Geometry::default(), 4);
//! let k = reg.resolve(FormatKind::InCrs, Algorithm::Inner).unwrap();
//! let out = k.run(&a, &b)?;           // prepare (InCRS build) + execute
//! // or: reg.select(&a, &b)           // cost-hint auto-selection
//! ```
//!
//! ## Serving model
//!
//! The [`coordinator`] wraps the engine in a batching server; callers use
//! the typed client API ([`coordinator::SpmmClient`]): `JobBuilder`
//! construction, `JobHandle` futures (`wait` / `wait_timeout` /
//! `try_poll` / `batch_wait_all`), `submit_many`/`stream` batch entry
//! points, and [`coordinator::JobError`] instead of stringly errors.
//! Operands are typed [`formats::MatrixOperand`] handles — **any Table-I
//! format, submitted as it arrived** (`client.job(coo, incrs)` works as
//! well as `client.job(arc_csr_a, arc_csr_b)`): CSR stays zero-cost via
//! `Arc` identity, everything else is ingested server-side
//! (identity-memoized, metered as `operand_conversions`, typed
//! [`formats::FormatError`] on failure) and auto-selection
//! ([`engine::Registry::select_native`]) charges the conversion from the
//! native format instead of assuming free CSR. Results are bit-identical
//! to pre-converted submission. The server micro-batches jobs sharing a
//! `B` operand so `SpmmKernel::prepare` runs once per batch
//! (content-fingerprinted for real-prepare kernels — InCRS counters,
//! densification, tiled/accel **blockization** (`PreparedB::Blocked`,
//! built once and shared by every shard worker), the fast Gustavson
//! kernel's **workspace pool** (`PreparedB::Pooled`, accumulator
//! workspaces reused across jobs and shard workers), the outer-product
//! kernel's **merge-buffer pool** (`PreparedB::OuterPooled`, partial-
//! product runs recycled across jobs) — with a bounded LRU
//! keeping each `PreparedB` across batches) — the paper's "one
//! representation build, many multiplies" amortization at the serving
//! layer. Coalescing stats (`prepare_builds`, `prepare_cache_hits`,
//! `coalesced_jobs`, `operand_conversions`, `workspace_pool_hits`)
//! surface in [`coordinator::MetricsSnapshot`], and every executed job
//! logs a `(cost_hint, ingest_cost, measured wall)` datapoint into the
//! bounded [`coordinator::Metrics::kernel_log`] — the exact scores
//! selection ranked, not an execute-time recomputation. The
//! **learned-selection loop** ([`engine::learn`]) closes over that log:
//! every `LearnConfig::refit_every` completed jobs the server
//! least-squares-fits per-kernel scale constants (µs per cost unit) and
//! publishes them to every worker's registry through a shared
//! [`engine::CostModel`], so `Auto` selection ranks candidates in
//! predicted microseconds — gated on full calibration (otherwise the
//! static ranking decides, bit-for-bit), damped by per-workload-class
//! hysteresis, persisted bit-exactly to a versioned plain-text model file
//! (`LearnConfig::model_path`) and warm-loaded on restart. Refit counts
//! (`model_refits`) and per-kernel calibration errors
//! ([`coordinator::Metrics::calibration`]) are metered. Jobs may
//! additionally ask for
//! **sharded row-band execution** (`JobBuilder::shards(n)` →
//! [`engine::shard`]): contiguous bands on channel-connected shard
//! workers sharing one `PreparedB`, merged with no cross-shard reduction
//! — bit-identical to the unsharded run at any shard count (a clamped
//! shard request is surfaced in `JobOutput::shards_requested` and the
//! `shard_clamps` metric, never silent). The same executor runs
//! **cross-host** over [`engine::transport`]: a versioned wire format
//! ships each row band and every `PreparedB` variant (floats as IEEE-754
//! bit patterns; `Pooled`/`OuterPooled` pools rebuilt host-local) to
//! socket shard workers (`spmm-accel worker`, [`engine::remote`]), with
//! fingerprint-keyed operand replication into each worker's
//! `PreparedCache`, per-band timeout/retry, straggler hedging (first
//! bit-identical answer wins), and loss recovery that resubmits **only a
//! dead worker's outstanding bands** — all metered
//! (`remote_bands`, `band_retries`, `hedges_won`, `workers_lost`,
//! `prepare_replications`, `prepare_reuse`). Because planning and the
//! row-copy merge never leave the leader, the socket path is
//! bit-identical to in-process and unsharded execution for every
//! registered kernel (`tests/prop_transport.rs`).
//!
//! ```ignore
//! let server = Server::start(ServerConfig::default());
//! let client = server.client();
//! let out = client.job(a, b).verify(true).submit()?.wait()?;  // any operand format
//! let out = client.job(coo_matrix, incrs_matrix).submit()?.wait()?;
//! let handles = client.submit_many(jobs);           // shared-B coalescing
//! let results = JobHandle::batch_wait_all(handles); // submission order
//! server.shutdown();                                // drains, never drops
//! ```
//!
//! ## Determinism contract
//!
//! Every registered kernel is **bit-identical** to scalar Gustavson at
//! any worker, shard, or merge fan-in count: reductions happen in one
//! fixed, documented order (ascending K), never in thread-completion or
//! hash-iteration order. The contract is enforced three ways: sampled
//! (the `prop_*` bit-identity suites), statically ([`analysis`] — the
//! `detlint` pass run by `cargo test --test repo_lint` bans unordered
//! hash collections, accumulation-order hazards, and unjustified panics
//! in the serving path), and structurally (the core formats'
//! `validate_invariants()`, asserted at engine boundaries under the
//! `strict-invariants` feature). See README "Correctness tooling".
//!
//! ## Crate layout
//!
//! * [`formats`] — all Table-I sparse formats + [`formats::InCrs`], with
//!   memory-access accounting on random access.
//! * [`datasets`] — the paper's nine datasets as deterministic synthetic
//!   matrices (+ MatrixMarket loader).
//! * [`access`] — random-access and column-order-read drivers (Tables I/II).
//! * [`cachesim`] — gem5-parameter two-level cache hierarchy + stride
//!   prefetcher driven by the formats' address streams (Fig 3).
//! * [`arch`] — cycle-accurate simulators: the proposed synchronized mesh
//!   (paper Algorithm 2), FPIC (Algorithm 1), conventional systolic MM
//!   (Figs 4/5, Table V).
//! * [`spmm`] — CPU SpMM algorithm bodies + 32×32 blocking/planning for the
//!   accelerator dispatch path.
//! * [`engine`] — **the unified execution layer**: kernel trait, registry,
//!   multi-threaded tiled executor, accelerator adapter, and the
//!   distributed shard transport (wire format + socket leader/worker).
//! * [`runtime`] — PJRT execution of the AOT-compiled Pallas kernels
//!   (feature `pjrt`; CPU twin otherwise).
//! * [`coordinator`] — job router/scheduler/batching server (L3) over the
//!   kernel registry.
//! * [`eval`] — drivers that regenerate every table and figure, plus the
//!   `engines` kernel-comparison experiment.
//! * [`analysis`] — `detlint`, the repo-native static-analysis pass
//!   enforcing the determinism/panic-safety contracts.
//!
//! ## Features
//!
//! * `pjrt` — enables the PJRT runtime (`runtime::engine`). Off by
//!   default so the crate builds and tests green in offline environments;
//!   every PJRT-dependent test skips itself with a message when the
//!   feature or the artifacts are absent. **Enabling it requires first
//!   adding the vendored `xla` bindings** (see the feature comment in
//!   Cargo.toml) — without them `--features pjrt` does not compile.
//! * `strict-invariants` — asserts the formats' `validate_invariants()`
//!   at engine prepare/execute boundaries ([`formats::strict_check`]).
//!   Off by default (the checks are O(nnz) per boundary); CI runs the
//!   full suite a second time with it enabled.

pub mod access;
pub mod analysis;
pub mod arch;
pub mod cachesim;
pub mod coordinator;
pub mod datasets;
pub mod engine;
pub mod eval;
pub mod formats;
pub mod runtime;
pub mod spmm;
pub mod util;
