//! Property + acceptance suite for the format-polymorphic operand API:
//!
//! 1. `MatrixOperand::convert` round-trips across **all** format pairs with
//!    a bit-equal dense render (values pass through conversions untouched);
//! 2. typed error cases: bad `InCrsParams`, counter overflow on conversion,
//!    unknown format/algorithm names, shape mismatch through the client;
//! 3. the acceptance property: every kernel registered in the default
//!    registry accepts a non-CSR `MatrixOperand` via the client and
//!    produces output **bit-identical** to pre-converted CSR submission,
//!    at shard counts {1, 4};
//! 4. a `prop_shard`-style check that Blocked-`PreparedB` sharded runs
//!    (tiled/accel kernels preparing a blockized B once, shared by every
//!    shard worker) match the PR 3 baselines bit for bit.

use std::sync::Arc;

use spmm_accel::coordinator::{JobError, Server, ServerConfig};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::{
    shard, Registry, ShardConfig, SpmmKernel, TiledConfig, TiledKernel,
};
use spmm_accel::formats::coo::Coo;
use spmm_accel::formats::csr::Csr;
use spmm_accel::formats::incrs::{InCrs, InCrsParams};
use spmm_accel::formats::traits::{FormatKind, SparseMatrix};
use spmm_accel::formats::{FormatError, MatrixOperand, ALL_KINDS};
use spmm_accel::spmm::plan::Geometry;
use spmm_accel::util::ptest::check;
use spmm_accel::util::rng::Rng;

const BLOCK: usize = 16;

fn registry() -> Registry {
    Registry::with_default_kernels(Geometry { block: BLOCK, pairs: 32, slots: 16 }, 2)
}

/// Random COO with small dimensions and mixed density.
fn gen_coo(rng: &mut Rng) -> Coo {
    let rows = rng.usize_below(24) + 1;
    let cols = rng.usize_below(40) + 1;
    let density = rng.f64() * 0.4;
    uniform(rows, cols, density, rng.next_u64()).to_coo()
}

fn dense_bits(op: &MatrixOperand) -> Vec<u32> {
    op.as_sparse()
        .to_coo()
        .to_dense()
        .iter()
        .map(|v| v.to_bits())
        .collect()
}

/// 1. Conversion round-trips across every (from, to) format pair render to
/// the same dense bits as the source.
#[test]
fn prop_convert_roundtrips_bit_equal_across_all_pairs() {
    check(0x0EAD, 8, gen_coo, |coo| {
        let base = MatrixOperand::from(coo.clone());
        let want = dense_bits(&base);
        for from in ALL_KINDS {
            let x = base
                .convert(from)
                .map_err(|e| format!("convert to {from:?}: {e}"))?;
            if x.format() != from {
                return Err(format!("{from:?} reports {:?}", x.format()));
            }
            for to in ALL_KINDS {
                let y = x
                    .convert(to)
                    .map_err(|e| format!("{from:?}->{to:?}: {e}"))?;
                if dense_bits(&y) != want {
                    return Err(format!("{from:?}->{to:?} changed value bits"));
                }
                if (y.shape(), y.nnz()) != (coo.shape(), coo.nnz()) {
                    return Err(format!("{from:?}->{to:?} lost metadata"));
                }
            }
        }
        Ok(())
    });
}

/// `to_csr` on every native format renders the same CSR arrays.
#[test]
fn prop_to_csr_is_canonical_for_every_format() {
    check(0x0EAE, 12, gen_coo, |coo| {
        let want = Csr::from_coo(coo);
        let base = MatrixOperand::from(coo.clone());
        for from in ALL_KINDS {
            let csr = base
                .convert(from)
                .and_then(|op| op.to_csr())
                .map_err(|e| format!("{from:?}: {e}"))?;
            if csr.row_ptr != want.row_ptr || csr.col_idx != want.col_idx {
                return Err(format!("{from:?} changed structure"));
            }
            let same_vals = csr
                .vals
                .iter()
                .zip(&want.vals)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            if !same_vals || csr.vals.len() != want.vals.len() {
                return Err(format!("{from:?} changed value bits"));
            }
        }
        Ok(())
    });
}

/// 2. Typed error cases surface the right variants end to end.
#[test]
fn typed_errors_surface_the_right_variants() {
    // bad InCRS geometry
    let bad = InCrsParams { section: 256, block: 3 };
    assert!(matches!(
        bad.validate(),
        Err(FormatError::BadParams { section: 256, block: 3, .. })
    ));
    // counter overflow during conversion: one row with > 65535 nonzeros
    let cols = 70_000usize;
    let entries: Vec<(u32, u32, f32)> = (0..cols as u32).map(|c| (0, c, 1.0)).collect();
    let wide = MatrixOperand::from(Coo::new(1, cols, entries));
    match wide.convert(FormatKind::InCrs) {
        Err(FormatError::CounterOverflow { row: 0, detail }) => {
            assert!(detail.contains("16-bit prefix"), "{detail}")
        }
        other => panic!("expected CounterOverflow, got {other:?}"),
    }
    // unknown names parse to typed errors
    assert!(matches!(
        FormatKind::parse("nope"),
        Err(FormatError::UnknownFormat(_))
    ));
    assert!(matches!(
        spmm_accel::engine::Algorithm::parse("nope"),
        Err(FormatError::UnknownAlgorithm(_))
    ));
    // shape mismatch through the client, with non-CSR operands
    let s = Server::start(ServerConfig {
        workers: 1,
        queue_depth: 4,
        geometry: Geometry { block: BLOCK, pairs: 32, slots: 16 },
        ..Default::default()
    });
    let client = s.client();
    let a = uniform(4, 5, 0.5, 1).to_coo();
    let b = uniform(7, 4, 0.5, 2).to_coo();
    let err = client.job(a, b).submit().unwrap().wait().unwrap_err();
    assert_eq!(err, JobError::ShapeMismatch { a: (4, 5), b: (7, 4) });
    drop(client);
    s.shutdown();
}

/// 3. ACCEPTANCE: every registered kernel accepts non-CSR operands via the
/// client and is bit-identical to pre-converted CSR submission at shard
/// counts {1, 4}.
#[test]
fn every_kernel_serves_non_csr_operands_bit_identically_at_1_and_4_shards() {
    let keys = registry().keys();
    assert!(keys.len() >= 8, "registry too small: {keys:?}");
    assert!(
        keys.contains(&(FormatKind::Csr, spmm_accel::engine::Algorithm::GustavsonFast)),
        "the fast Gustavson kernel must ride this suite: {keys:?}"
    );
    let s = Server::start(ServerConfig {
        workers: 2,
        queue_depth: 8,
        geometry: Geometry { block: BLOCK, pairs: 32, slots: 16 },
        tile_workers: 2,
        ..Default::default()
    });
    let client = s.client();
    let a = Arc::new(uniform(64, 48, 0.2, 50));
    let b = Arc::new(uniform(48, 40, 0.2, 51));
    // the non-CSR arrival forms under test
    let a_coo = MatrixOperand::from(Arc::clone(&a)).convert(FormatKind::Coo).unwrap();
    let b_incrs = MatrixOperand::from(Arc::clone(&b)).convert(FormatKind::InCrs).unwrap();
    for (format, algorithm) in keys {
        for shards in [1usize, 4] {
            let run = |ao: MatrixOperand, bo: MatrixOperand| {
                client
                    .job(ao, bo)
                    .kernel(format, algorithm)
                    .shards(shards)
                    .submit()
                    .unwrap()
                    .wait()
                    .unwrap_or_else(|e| {
                        panic!("{format:?}/{algorithm:?} @ {shards} shards: {e}")
                    })
            };
            let want = run(
                MatrixOperand::from(Arc::clone(&a)),
                MatrixOperand::from(Arc::clone(&b)),
            );
            let got = run(a_coo.clone(), b_incrs.clone());
            assert_eq!(
                want.c.as_ref().unwrap().bit_pattern(),
                got.c.as_ref().unwrap().bit_pattern(),
                "{format:?}/{algorithm:?} @ {shards} shards: non-CSR submission \
                 diverges bitwise from pre-converted CSR"
            );
        }
    }
    let snap = client.metrics();
    assert!(snap.operand_conversions > 0, "{snap:?}");
    assert_eq!(snap.jobs_failed, 0, "{snap:?}");
    drop(client);
    s.shutdown();
}

/// 4. Blocked-`PreparedB`: the blocked kernels prepare a blockized B once;
/// sharded execution over that single shared grid matches both the
/// unsharded kernel and the PR 3 tiled baseline bit for bit.
#[test]
fn blocked_prepared_b_sharded_runs_match_pr3_baselines() {
    let a = uniform(96, 64, 0.15, 60);
    let b = uniform(64, 52, 0.15, 61);
    // tiled kernel: prepare must be Blocked, and shard::execute over the
    // shared grid must equal the standalone executor (the PR 3 path)
    let k = TiledKernel::new(TiledConfig { block: BLOCK, workers: 2 });
    let prepared = k.prepare(&b).unwrap();
    assert!(
        matches!(prepared, spmm_accel::engine::PreparedB::Blocked(_)),
        "tiled prepare must produce a Blocked operand"
    );
    let baseline = spmm_accel::engine::tiled::execute(
        &a,
        &b,
        TiledConfig { block: BLOCK, workers: 2 },
    )
    .unwrap()
    .0
    .bit_pattern();
    let unsharded = k.execute(&a, &prepared).unwrap().c.bit_pattern();
    assert_eq!(unsharded, baseline, "Blocked path diverges from PR 3 executor");
    for shards in [1usize, 4] {
        let out = shard::execute(
            &k,
            &a,
            Some(&b),
            &prepared,
            ShardConfig { shards, block: BLOCK },
        )
        .unwrap();
        assert_eq!(
            out.c.bit_pattern(),
            baseline,
            "Blocked sharded run @ {shards} diverges from PR 3 baseline"
        );
    }
    // every blocked kernel in the registry (tiled + accel/Block) round-trips
    // prepare -> sharded execute bit-identically to its unsharded run
    for kernel in registry().kernels() {
        let prepared = kernel.prepare(&b).unwrap();
        let want = kernel.execute(&a, &prepared).unwrap().c.bit_pattern();
        for shards in [1usize, 4] {
            let out = shard::execute(
                kernel.as_ref(),
                &a,
                Some(&b),
                &prepared,
                ShardConfig { shards, block: BLOCK },
            )
            .unwrap();
            assert_eq!(
                out.c.bit_pattern(),
                want,
                "{} @ {shards} shards diverges with prepared {}",
                kernel.name(),
                prepared.label()
            );
        }
    }
}

/// The inner-InCRS kernel adopting a native InCRS operand through a real
/// server stays bit-identical to the rebuild path.
#[test]
fn incrs_native_adoption_is_bit_identical_through_the_server() {
    let s = Server::start(ServerConfig {
        workers: 1,
        queue_depth: 4,
        geometry: Geometry { block: BLOCK, pairs: 32, slots: 16 },
        ..Default::default()
    });
    let client = s.client();
    let a = Arc::new(uniform(32, 300, 0.15, 70));
    let b_csr = Arc::new(uniform(300, 40, 0.15, 71));
    let b_native = Arc::new(InCrs::from_csr(&b_csr).unwrap());
    let run = |bo: MatrixOperand| {
        client
            .job(MatrixOperand::from(Arc::clone(&a)), bo)
            .kernel(FormatKind::InCrs, spmm_accel::engine::Algorithm::Inner)
            .submit()
            .unwrap()
            .wait()
            .unwrap()
    };
    let want = run(MatrixOperand::from(Arc::clone(&b_csr)));
    let got = run(MatrixOperand::InCrs(Arc::clone(&b_native)));
    assert_eq!(
        want.c.as_ref().unwrap().bit_pattern(),
        got.c.as_ref().unwrap().bit_pattern(),
        "adopted native InCRS diverges from the rebuild path"
    );
    drop(client);
    s.shutdown();
}
