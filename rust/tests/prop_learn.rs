//! Property tests for the learned-selection loop (`engine::learn`):
//!
//! 1. least-squares fitting recovers a planted per-kernel cost constant
//!    from noiseless observations at any magnitude,
//! 2. the versioned plain-text model file round-trips every fitted f64
//!    bit-exactly (IEEE-754 bit patterns, not decimal renderings),
//! 3. hysteresis bounds selection flapping: oscillating refits whose
//!    predicted advantage stays inside the margin never switch the
//!    incumbent, and
//! 4. a fitted model steering selection is *only* steering — for every
//!    registered kernel, forcing it through the fitted path produces
//!    bit-identical results to invoking that kernel directly.

use std::sync::Arc;

use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::{
    Algorithm, Calibration, CostModel, FittedModel, KernelKey, Registry, Sample, SpmmKernel,
};
use spmm_accel::formats::traits::{FormatKind, SparseMatrix};
use spmm_accel::spmm::plan::Geometry;
use spmm_accel::util::ptest::check;
use spmm_accel::util::rng::Rng;

/// A planted fitting problem: one kernel key, a true scale, and raw scores
/// placed so the true walls land in [10, 10^4] µs — measurable, but still
/// quantized to whole µs like a real timer.
fn gen_planted(rng: &mut Rng) -> (f64, Vec<f64>) {
    // true scale across 8 orders of magnitude — covers sub-µs SIMD
    // kernels up to slow accelerator paths
    let scale = 1e-6 * 10f64.powi(rng.usize_below(8) as i32) * (0.5 + rng.f64());
    let n = 8 + rng.usize_below(24);
    let scores = (0..n).map(|_| (10.0 + rng.f64() * 1e4) / scale).collect();
    (scale, scores)
}

#[test]
fn fit_recovers_planted_constants_at_any_magnitude() {
    check(0x5CA1E, 40, gen_planted, |(scale, scores)| {
        let samples: Vec<Sample> = scores
            .iter()
            .map(|&x| Sample {
                format: FormatKind::Csr,
                algorithm: Algorithm::Gustavson,
                predicted: x,
                wall_us: (scale * x).round() as u64,
            })
            .collect();
        let fit = FittedModel::fit(&samples, 4);
        let cal = fit
            .get((FormatKind::Csr, Algorithm::Gustavson))
            .ok_or("planted key not calibrated")?;
        // µs quantization perturbs each observation by at most ±0.5µs on a
        // ≥10µs wall, so the weighted fit lands within a few percent
        let rel = (cal.scale - *scale).abs() / scale;
        if rel > 0.06 {
            return Err(format!(
                "planted {scale:.3e}, fitted {:.3e} (rel err {rel:.3})",
                cal.scale
            ));
        }
        Ok(())
    });
}

/// Random calibration table with scales and errors across the full range
/// of representable-but-sane f64s.
fn gen_model(rng: &mut Rng) -> FittedModel {
    let keys: [KernelKey; 4] = [
        (FormatKind::Csr, Algorithm::Gustavson),
        (FormatKind::Csr, Algorithm::Tiled),
        (FormatKind::InCrs, Algorithm::Inner),
        (FormatKind::Csc, Algorithm::OuterProduct),
    ];
    let mut m = FittedModel::new();
    for key in keys.iter().take(1 + rng.usize_below(4)) {
        m.insert(
            *key,
            Calibration {
                // deliberately awkward decimals: f64s whose shortest decimal
                // rendering would not round-trip through naive formatting
                scale: (rng.f64() + 1e-9) / (3.0 + rng.f64()),
                samples: rng.next_u64() % 10_000,
                mean_abs_err_us: rng.f64() * 1e4 / 7.0,
            },
        );
    }
    m
}

#[test]
fn persisted_models_round_trip_bit_exactly() {
    let dir = std::env::temp_dir();
    check(0xB17E, 30, gen_model, |m| {
        // text round-trip: every f64 must come back with identical bits
        let back = FittedModel::from_text(&m.to_text()).map_err(|e| e.to_string())?;
        for ((k, a), (bk, b)) in m.entries().zip(back.entries()) {
            if k != bk {
                return Err(format!("key changed: {k:?} vs {bk:?}"));
            }
            if a.scale.to_bits() != b.scale.to_bits()
                || a.mean_abs_err_us.to_bits() != b.mean_abs_err_us.to_bits()
                || a.samples != b.samples
            {
                return Err(format!("{k:?} drifted: {a:?} vs {b:?}"));
            }
        }
        if back.len() != m.len() {
            return Err("entry count changed".into());
        }
        // file round-trip: save/load goes through the same text form
        let path = dir.join(format!("spmm_prop_learn_{}.model", std::process::id()));
        m.save(&path).map_err(|e| e.to_string())?;
        let loaded = FittedModel::load(&path).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        if loaded != back {
            return Err("file round-trip differs from text round-trip".into());
        }
        Ok(())
    });
}

#[test]
fn hysteresis_bounds_flapping_under_oscillating_refits() {
    let key_a: KernelKey = (FormatKind::Csr, Algorithm::Gustavson);
    let key_b: KernelKey = (FormatKind::Csr, Algorithm::Tiled);
    let cal = |scale: f64| Calibration { scale, samples: 16, mean_abs_err_us: 0.0 };
    let model = CostModel::new(0.25);
    let scored = [(key_a, 1000.0), (key_b, 1000.0)];

    // oscillating measurements: the two kernels trade a 10% advantage each
    // refit, always inside the 25% margin — the first pick must hold
    let mut first = None;
    for round in 0..12 {
        let (sa, sb) = if round % 2 == 0 { (1.0, 1.1) } else { (1.1, 1.0) };
        let mut m = FittedModel::new();
        m.insert(key_a, cal(sa));
        m.insert(key_b, cal(sb));
        model.publish(m);
        let pick = model.choose(7, &scored).expect("fully calibrated");
        match first {
            None => first = Some(pick),
            Some(p) => assert_eq!(pick, p, "flapped on round {round}"),
        }
    }
    assert_eq!(model.switches(), 0, "in-margin oscillation must never switch");

    // a decisive 10x advantage must still switch exactly once
    let mut m = FittedModel::new();
    m.insert(key_a, cal(10.0));
    m.insert(key_b, cal(1.0));
    model.publish(m);
    assert_eq!(model.choose(7, &scored), Some(1));
    assert_eq!(model.switches(), 1, "decisive advantage switches exactly once");
}

/// A fitted model that makes `target` the runaway winner and every other
/// key prohibitively expensive — all keys calibrated, so the fitted path
/// (not the static fallback) decides.
fn forcing_model(registry: &Registry, target: KernelKey) -> FittedModel {
    let mut m = FittedModel::new();
    for key in registry.keys() {
        if key == (FormatKind::Dense, Algorithm::Dense) {
            continue; // dense never enters the candidate set
        }
        let scale = if key == target { 1e-12 } else { 1e6 };
        m.insert(key, Calibration { scale, samples: 32, mean_abs_err_us: 0.0 });
    }
    m
}

#[test]
fn fitted_selection_forces_each_kernel_with_bit_identical_results() {
    let geometry = Geometry { block: 16, pairs: 32, slots: 16 };
    let a = uniform(96, 64, 0.08, 21);
    let b = uniform(64, 80, 0.08, 22);

    let static_reg = Registry::with_default_kernels(geometry, 2);
    for key in static_reg.keys() {
        if key == (FormatKind::Dense, Algorithm::Dense) {
            continue;
        }
        // fresh registry + model per key: no incumbent carries over
        let mut reg = Registry::with_default_kernels(geometry, 2);
        let model = CostModel::new(0.0);
        model.publish(forcing_model(&reg, key));
        reg.set_cost_model(model);

        let picked = reg.select(&a, &b).expect("non-empty registry");
        assert_eq!(
            (picked.format(), picked.algorithm()),
            key,
            "fitted model failed to force {key:?}"
        );
        let direct: Arc<dyn SpmmKernel> =
            static_reg.resolve(key.0, key.1).expect("registered kernel");
        let via_model = picked.run(&a, &b).expect("forced kernel runs");
        let reference = direct.run(&a, &b).expect("direct kernel runs");
        assert_eq!(
            via_model.c.data, reference.c.data,
            "{key:?}: fitted-path result differs bitwise from direct invocation"
        );
        assert_eq!(via_model.c.shape(), reference.c.shape());
    }
}
